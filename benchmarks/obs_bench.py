"""obs section: cost-model-vs-measured validation + trace artifacts.

Replays the Table-3 generators through the obs calibration harness
(``repro.obs.calibrate``) per backend, joining the analytic cost model
in ``benchmarks/device_model.py`` (RTX-3090-class constants) against
MEASURED span durations from the tracer, and emits:

  * BENCH rows (via ``benchmarks/run.py obs``): per-backend
    ``predicted_over_observed`` ratio per dataset, per-mode shard
    imbalance under the 8-virtual-device mesh, compile-vs-steady window
    split, and one retrace-ledger row with ``expected_max_traces`` —
    the CI recompile ceiling (each registered executable should trace
    at most once in a fresh smoke process; more means a retrace leak).
  * ``results/obs_smoke.trace.json`` — Chrome-trace export of the whole
    run (drop onto ``about:tracing`` / Perfetto), validated before
    writing.
  * ``results/obs_smoke.jsonl``     — the raw JSONL span/event dump the
    ``python -m repro.obs.report`` dashboard consumes.

The predicted/observed ratio is NOT expected to be ~1.0 here: the model
prices a GPU while CI measures CPU (pallas under interpret).  The
witness is that the ratio exists, is finite and positive, and is stable
per backend — which is what validates the model for RELATIVE decisions
(tile choice, scheme choice, format ranking).
"""
from __future__ import annotations

import sys

from repro.obs import calibrate, trace as obs_trace
from repro.obs.ledger import LEDGER

from . import device_model
from .common import RANK, load_datasets
from .run import RESULTS_DIR

# Backend → device-model format.  segment and pallas both implement the
# paper's fused mode-specific layout ("ours"); coo is the ParTI-like
# naive baseline.
_BACKEND_FMT = {"segment": "ours", "pallas": "ours", "coo": "naive-coo"}

_SMOKE_DATASETS = ("uber", "nips")
_FULL_DATASETS = ("chicago", "enron", "nips", "uber", "vast")


def _predict_fn(tensor, mode, backend):
    return device_model.mode_cost(
        tensor, mode, _BACKEND_FMT[backend]).total_s


def _ledger_row(expected_max_traces: int) -> dict:
    row = {"name": "obs/ledger", "section": "ledger",
           "expected_max_traces": expected_max_traces}
    total_blocks = 0
    for kind in LEDGER.kinds():
        s = LEDGER.stats(kind)
        total_blocks += s["blocks"]
        row[f"{kind}_blocks"] = s["blocks"]
        row[f"{kind}_traces"] = s["traces"]
    row["blocks"] = total_blocks
    row["traces"] = LEDGER.stats()["traces"]
    return row


def main(argv: list[str] | None = None) -> list[dict]:
    argv = list(sys.argv[1:] if argv is None else argv)
    smoke = "--smoke" in argv
    names = _SMOKE_DATASETS if smoke else _FULL_DATASETS
    backends = ("segment", "coo") if smoke else ("segment", "coo", "pallas")
    scale = 0.02 if smoke else None
    datasets = (load_datasets(scale=scale) if scale is not None
                else load_datasets())

    LEDGER.reset()
    rows: list[dict] = []
    with obs_trace.capture("obs_bench") as tr:
        for name in names:
            t = datasets[name]
            print(f"obs: calibrating {name} "
                  f"(nnz={t.nnz}, backends={backends}) ...")
            rows.extend(calibrate.calibrate_tensor(
                name, t, rank=RANK, backends=backends,
                predict_fn=_predict_fn,
                reps=2 if smoke else 3,
                imbalance_reps=5 if smoke else 20))

        # Retrace ceiling: every executable registered during this run
        # should have traced exactly once (fresh process, fixed shapes).
        ledger = _ledger_row(expected_max_traces=sum(
            LEDGER.stats(k)["blocks"] for k in LEDGER.kinds()))
        rows.append(ledger)

    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    chrome = RESULTS_DIR / "obs_smoke.trace.json"
    jsonl = RESULTS_DIR / "obs_smoke.jsonl"
    doc = tr.to_chrome()
    obs_trace.validate_chrome(doc)        # never commit an invalid trace
    tr.dump_chrome(chrome)
    tr.dump_jsonl(jsonl)
    print(f"obs: {len(tr.records())} trace records -> {chrome.name}, "
          f"{jsonl.name}")

    for r in rows:
        if r["section"] == "ratio":
            print(f"  {r['dataset']:10s} {r['backend']:8s} "
                  f"pred/obs={r['predicted_over_observed']:.3g}  "
                  f"compile={r['compile_overhead_s']:.3f}s "
                  f"steady={r['steady_window_s']:.4f}s")
        elif r["section"] == "imbalance":
            print(f"  {r['dataset']:10s} imbalance "
                  f"measured<={r['max_measured_imbalance']:.3f} "
                  f"nnz<={r['max_nnz_imbalance']:.3f}")
        else:
            print(f"  ledger: {r['blocks']} executables, "
                  f"traces={r['traces']} "
                  f"(ceiling {r['expected_max_traces']})")
    return rows


if __name__ == "__main__":
    main()
