"""Roofline report generator: reads the dry-run JSON and renders the
per-(arch x shape x mesh) table for EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import json
import sys


def fmt_row(r: dict) -> str:
    if "skipped" in r:
        return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | — | "
                f"skip: {r['skipped'][:40]}… |")
    if "error" in r:
        return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | — | "
                f"ERROR {r['error'][:40]} |")
    t = r["roofline"]
    return ("| {arch} | {shape} | {mesh} | {tc:.2e} | {tm:.2e} | {tl:.2e} | "
            "{dom} | useful={ur} fits={fits} |").format(
        arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
        tc=t["t_compute_s"], tm=t["t_memory_s"], tl=t["t_collective_s"],
        dom=t["dominant"],
        ur=f"{r['useful_flop_ratio']:.2f}" if r.get("useful_flop_ratio") else "-",
        fits="Y" if r.get("fits_hbm") else "N")


def render(results: list[dict]) -> str:
    lines = [
        "| arch | shape | mesh | compute s | memory s | collective s | "
        "dominant | notes |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in results:
        lines.append(fmt_row(r))
    return "\n".join(lines)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun_baseline.json"
    try:
        with open(path) as f:
            results = json.load(f)
    except FileNotFoundError:
        print(f"roofline/skipped,0,no dry-run results at {path} "
              "(run python -m repro.launch.dryrun --all first)")
        return
    print(render(results))


if __name__ == "__main__":
    main()
