"""Pallas MTTKRP kernel micro-bench: VMEM/MXU cost model + interpret-mode
validation timing.

Real TPU wall-time is unavailable in this container (kernels run in
interpret mode), so the kernel is scored by its structural roofline:
per-grid-step VMEM footprint, MXU utilization of the one-hot
gather/scatter matmuls, padding overhead from slab packing, and HBM
traffic — the quantities BlockSpec tiling controls.
"""
from __future__ import annotations

import time

import numpy as np

import jax.numpy as jnp

from repro.core import make_plan, mttkrp, random_sparse
from repro.kernels import ops as kops

from .common import RANK, load_datasets


def kernel_cost_model(packed: kops.PackedModeLayout, factors, *,
                      lane=128, sublane=8) -> dict:
    """Static kernel cost per mode sweep (all grid steps)."""
    T, BR, R = packed.tile, packed.block_rows, factors[0].shape[1]
    W = len(factors)
    G = packed.num_slabs
    # VMEM per step: slabs + output block + resident factors
    vmem = (W * T * 4 + T * 4 + T * 4 + BR * R * 4
            + sum(int(np.prod(f.shape)) * 4 for f in factors))
    # MXU work: scatter matmul (T x BR) @ (T x R) per step (+ gathers when
    # one-hot).  Efficiency = achieved macs / padded-tile macs.
    mxu_macs = G * T * BR * R
    pad_eff = 1.0 - packed.pad_fraction
    lane_eff = min(R, lane) / lane
    hbm = (G * T * (W + 2) * 4) + packed.num_row_blocks * BR * R * 4
    return {
        "grid_steps": G,
        "vmem_bytes_per_step": int(vmem),
        "vmem_ok": vmem < 16 * 2**20,
        "mxu_macs": int(mxu_macs),
        "pad_efficiency": pad_eff,
        "lane_efficiency": lane_eff,
        "hbm_bytes": int(hbm),
    }


def run():
    rows = []
    t = random_sparse((2048, 1024, 512), 100_000, seed=7,
                      distribution="powerlaw")
    plan = make_plan(t, kappa=8)
    rng = np.random.default_rng(0)
    factors = [jnp.asarray(rng.standard_normal((I, RANK)).astype(np.float32))
               for I in t.shape]
    for mode in range(t.nmodes):
        packed = plan.packed(mode)
        in_modes = plan.layouts[mode].input_modes()
        cost = kernel_cost_model(packed, [factors[w] for w in in_modes])
        # beyond-paper: BlockSpec auto-tuning vs the default tiling
        br, tl = kops.auto_tiles(plan.layouts[mode], rank=RANK)
        auto = kops.estimate_pack_cost(
            plan.layouts[mode], br, tl, RANK,
            sum(t.shape[w] for w in in_modes))
        dflt = kops.estimate_pack_cost(
            plan.layouts[mode], kops.DEFAULT_BLOCK_ROWS, kops.DEFAULT_TILE,
            RANK, sum(t.shape[w] for w in in_modes))
        # interpret-mode correctness + CPU wall (not TPU-representative)
        t0 = time.perf_counter()
        out_pal = mttkrp(plan, factors, mode, backend="pallas")
        out_pal.block_until_ready()
        wall = time.perf_counter() - t0
        out_ref = mttkrp(plan, factors, mode, backend="segment")
        err = float(jnp.max(jnp.abs(out_pal - out_ref)))
        rows.append({"mode": mode, "wall_s": wall, "max_err": err,
                     "auto_tiles": (br, tl),
                     "auto_cost_gain": dflt["cost"] / auto["cost"],
                     "auto_pad_eff": 1.0 - auto["pad_fraction"], **cost})
    return rows


def main():
    print("name,us_per_call,derived")
    for r in run():
        print(f"kernel/mode{r['mode']},{r['wall_s']*1e6:.0f},"
              f"err={r['max_err']:.1e};grid={r['grid_steps']};"
              f"vmem={r['vmem_bytes_per_step']};vmem_ok={r['vmem_ok']};"
              f"pad_eff={r['pad_efficiency']:.3f};"
              f"auto={r['auto_tiles']};auto_gain={r['auto_cost_gain']:.2f}x;"
              f"auto_pad_eff={r['auto_pad_eff']:.3f}")


if __name__ == "__main__":
    main()
