"""ALS engine benchmark: device-resident fused sweep vs the host loop.

Measures, per Table-3 dataset generator (CI-scaled):

  * wall time per ALS iteration for engine="host" (per-mode device->host
    sync + numpy solve + factor re-upload) vs engine="fused" (one jitted
    sweep, state device-resident), compile excluded via a warm-up run;
  * host syncs per iteration for both engines (the overhead the paper's
    thesis says dominates the small-tensor regime) — asserted, not just
    reported: the fused engine must do <= 1 sync per ``CHECK_EVERY``
    iterations (+1 final materialization);
  * the partition plan each timed config ran under (per-mode block_rows /
    tile / rank_block / slab cap, via ``core.plan``), so a perf regression
    is attributable to a planning change rather than guessed at;
  * a SEPARABLE ``mttkrp_seconds`` for the fused engine: the sweep stages
    are ``jax.named_scope``-annotated for real profiler traces, and
    ``profile_mttkrp=True`` times a jitted MTTKRP-only replay of the same
    check windows (kernel cost is independent of factor values, so the
    replay is faithful) — reported as ``mttkrp_s_per_iter`` and as the
    fraction of fused time spent in the bottleneck kernel.

Output: ``name,us_per_call,derived`` CSV like the other sections.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import cpd_als, make_plan, plan_tensor
from repro.core.als_device import cpd_als_fused

from .common import KAPPA, load_datasets

RANK = 16
ITERS = 6
CHECK_EVERY = 2


def bench_one(name, tensor, *, rank=RANK, iters=ITERS,
              check_every=CHECK_EVERY) -> dict:
    plan = make_plan(tensor, KAPPA)
    # The static plan this tensor's bucket class executes under — printed
    # with every timing row so planning changes are attributable.
    pplan = plan_tensor(tensor, rank, KAPPA)

    # Warm-up both engines (jit compile + plan device upload), then time.
    cpd_als(tensor, rank, plan=plan, n_iters=1, tol=-1.0, engine="host")
    t0 = time.perf_counter()
    host = cpd_als(tensor, rank, plan=plan, n_iters=iters, tol=-1.0,
                   engine="host")
    host_s = time.perf_counter() - t0

    # Warm-up must use the same check window: the scan block length is part
    # of the executable key, so warming with n_iters=1 would leave the
    # window-`check_every` executable to compile inside the timed region.
    cpd_als_fused(tensor, rank, plan=plan, n_iters=check_every, tol=-1.0,
                  check_every=check_every)
    t0 = time.perf_counter()
    fused = cpd_als_fused(tensor, rank, plan=plan, n_iters=iters, tol=-1.0,
                          check_every=check_every)
    fused_s = time.perf_counter() - t0

    # Separate the bottleneck kernel from solve time: one more (warm) run
    # with the MTTKRP-only window replay enabled — the timed region above
    # stays replay-free.
    prof = cpd_als_fused(tensor, rank, plan=plan, n_iters=iters, tol=-1.0,
                         check_every=check_every, profile_mttkrp=True)
    mttkrp_s = prof.mttkrp_seconds

    # The sync-count probe (acceptance): <= 1 per check_every iters + final.
    budget = -(-iters // check_every) + 1
    assert fused.host_syncs <= budget, (fused.host_syncs, budget)
    assert abs(host.fits[-1] - fused.fits[-1]) < 1e-3, (
        host.fits[-1], fused.fits[-1])

    return {
        "dataset": name,
        "shape": tensor.shape,
        "nnz": tensor.nnz,
        "host_s_per_iter": host_s / iters,
        "fused_s_per_iter": fused_s / iters,
        "speedup": host_s / max(fused_s, 1e-12),
        "host_syncs_per_iter": host.host_syncs / iters,
        "fused_syncs_per_iter": fused.host_syncs / iters,
        "mttkrp_s_per_iter": mttkrp_s / iters,
        "mttkrp_frac": mttkrp_s / max(fused_s, 1e-12),
        "plan": pplan.describe(),
    }


def run(scale: float | None = None) -> list[dict]:
    kw = {} if scale is None else {"scale": scale}
    return [bench_one(name, t) for name, t in load_datasets(**kw).items()]


def main():
    rows = run()
    print("name,us_per_call,derived")
    for r in rows:
        print(f"als/{r['dataset']}/host,{r['host_s_per_iter']*1e6:.0f},"
              f"syncs_per_iter={r['host_syncs_per_iter']:.1f}")
        print(f"als/{r['dataset']}/fused,{r['fused_s_per_iter']*1e6:.0f},"
              f"syncs_per_iter={r['fused_syncs_per_iter']:.2f};"
              f"speedup={r['speedup']:.2f}x;"
              f"mttkrp_us_per_iter={r['mttkrp_s_per_iter']*1e6:.0f};"
              f"mttkrp_frac={r['mttkrp_frac']:.2f};plan={r['plan']}")
    gmean = float(np.exp(np.mean([np.log(r["speedup"]) for r in rows])))
    print(f"als/geomean-speedup,0,{gmean:.2f}x")
    return rows


if __name__ == "__main__":
    main()
