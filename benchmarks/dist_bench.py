"""Distributed ALS smoke benchmark: the shard_map fused sweep on a
virtual 8-device CPU mesh.

jax pins its device count at first init, so the measured run happens in a
fresh subprocess with ``XLA_FLAGS=--xla_force_host_platform_device_count``
set — the same trick ``tests/distributed`` uses — and this module just
parses its CSV back out.  Measured per Table-3-style tensor:

  * wall time per ALS iteration for single-device fused vs distributed
    (8 virtual devices; on CPU the shards serialize, so this is a
    correctness/overhead smoke, not a scaling claim);
  * host syncs per iteration for the distributed engine — asserted <= 1
    per ``check_every`` window (+1 final), i.e. zero per-iteration syncs
    inside a window;
  * the fp32 agreement of the final fit with the single-device engine;
  * a masked/weighted completion row (``method="masked"`` with
    fractional observation confidences): per-shard residual scatter,
    psum of partial valued MTTKRPs, weighted sharded fit — the
    distributed path of the weighted-observations front door;
  * per-tensor collective-payload accounting: bytes moved per sweep by
    the full-array psum vs the scheme-1 row-sharded all-gather
    (``collective="gather"``), with an fp32 agreement check between the
    two collectives.

Output: ``name,us_per_call,derived`` CSV like the other sections, plus
``ROW {json}`` lines the runner stores as BENCH_dist.json rows.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

DEVICES = 8

_CHILD = """
    import json
    import time
    import numpy as np
    from repro.core import cpd_als, random_sparse
    from repro.core.distributed import (
        collective_payload_bytes, cpd_als_distributed,
        make_distributed_plan, resolve_collectives)

    def row(r):
        print("ROW " + json.dumps(r))

    ITERS, CHECK = 6, 3
    for name, shape, nnz in (("uber-like", (60, 24, 160), 2000),
                             ("tiny-mode", (48, 32, 6), 1500)):
        t = random_sparse(shape, nnz, seed=7, distribution="powerlaw")
        # warm-up (compile + plan build), then time
        single = cpd_als(t, rank=8, n_iters=1, tol=-1.0, check_every=1)
        t0 = time.perf_counter()
        single = cpd_als(t, rank=8, n_iters=ITERS, tol=-1.0,
                         check_every=CHECK)
        single_s = time.perf_counter() - t0

        plan = make_distributed_plan(t)
        cpd_als_distributed(t, rank=8, plan=plan, n_iters=CHECK, tol=-1.0,
                            check_every=CHECK)
        t0 = time.perf_counter()
        dist = cpd_als_distributed(t, rank=8, plan=plan, n_iters=ITERS,
                                   tol=-1.0, check_every=CHECK)
        dist_s = time.perf_counter() - t0

        assert dist.host_syncs <= ITERS // CHECK + 1, dist.host_syncs
        assert abs(dist.fits[-1] - single.fits[-1]) < 1e-3, (
            dist.fits[-1], single.fits[-1])
        schemes = "/".join(m.scheme.name[0] + m.scheme.name[-1]
                           for m in plan.modes)
        print(f"dist/{name}/single,{single_s / ITERS * 1e6:.0f},"
              f"fit={single.fits[-1]:.4f}")
        print(f"dist/{name}/shard_map-8dev,{dist_s / ITERS * 1e6:.0f},"
              f"fit={dist.fits[-1]:.4f};"
              f"syncs_per_iter={dist.host_syncs / ITERS:.2f};"
              f"schemes={schemes}")
        row({"name": f"dist/{name}", "section": "als",
             "single_us_per_iter": single_s / ITERS * 1e6,
             "dist_us_per_iter": dist_s / ITERS * 1e6,
             "fit": float(dist.fits[-1]),
             "syncs_per_iter": dist.host_syncs / ITERS,
             "schemes": schemes})

        # Collective payload: scheme-1 modes swap the full (I_d, R) psum
        # for an all-gather of each device's owned row slice (+ int32
        # destination map); the gather run must agree with psum to fp32.
        cols = resolve_collectives(plan, "gather")
        psum_b = collective_payload_bytes(plan, 8, None)
        gath_b = collective_payload_bytes(plan, 8, cols)
        if cols is not None:
            g = cpd_als_distributed(t, rank=8, plan=plan, n_iters=ITERS,
                                    tol=-1.0, check_every=CHECK,
                                    collective="gather")
            assert abs(g.fits[-1] - dist.fits[-1]) < 1e-3, (
                g.fits[-1], dist.fits[-1])
        row({"name": f"dist/{name}/collective", "section": "collective",
             "collectives": list(cols) if cols is not None else None,
             "psum_payload_bytes": psum_b,
             "gather_payload_bytes": gath_b,
             "payload_ratio": psum_b / gath_b if gath_b else None})
        print(f"dist/{name}/collective,0,psum_B={psum_b};"
              f"gather_B={gath_b};ratio={psum_b / max(gath_b, 1):.2f}")

    # Masked/weighted completion under shard_map: per-shard residual
    # scatter + psum of partial valued MTTKRPs, weighted sharded fit.
    t = random_sparse((48, 32, 6), 1500, seed=7, distribution="powerlaw")
    w = np.random.default_rng(1).uniform(0.25, 1.0, t.nnz).astype(np.float32)
    single = cpd_als(t, rank=8, n_iters=ITERS, tol=-1.0, check_every=CHECK,
                     method="masked", weights=w)
    mplan = make_distributed_plan(t, method="masked", weights=w)
    cpd_als_distributed(t, rank=8, plan=mplan, n_iters=CHECK, tol=-1.0,
                        check_every=CHECK, method="masked")
    t0 = time.perf_counter()
    dist = cpd_als_distributed(t, rank=8, plan=mplan, n_iters=ITERS,
                               tol=-1.0, check_every=CHECK, method="masked")
    dist_s = time.perf_counter() - t0
    assert dist.host_syncs <= ITERS // CHECK + 1, dist.host_syncs
    assert abs(dist.fits[-1] - single.fits[-1]) < 1e-3, (
        dist.fits[-1], single.fits[-1])
    print(f"dist/masked-weighted/shard_map-8dev,{dist_s / ITERS * 1e6:.0f},"
          f"fit={dist.fits[-1]:.4f};single_fit={single.fits[-1]:.4f};"
          f"syncs_per_iter={dist.host_syncs / ITERS:.2f}")
    row({"name": "dist/masked-weighted", "section": "als",
         "dist_us_per_iter": dist_s / ITERS * 1e6,
         "fit": float(dist.fits[-1]),
         "syncs_per_iter": dist.host_syncs / ITERS})
"""


def run(devices: int = DEVICES) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(_CHILD)],
                         capture_output=True, text=True, env=env,
                         timeout=1200)
    if out.returncode != 0:
        raise RuntimeError(f"distributed smoke failed:\n{out.stdout}\n"
                           f"{out.stderr}")
    return out.stdout


def main() -> list[dict]:
    print("name,us_per_call,derived")
    rows = []
    for line in run().splitlines():
        if line.startswith("ROW "):
            rows.append(json.loads(line[4:]))
        else:
            print(line)
    return rows


if __name__ == "__main__":
    main()
