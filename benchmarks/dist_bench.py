"""Distributed ALS smoke benchmark: the shard_map fused sweep on a
virtual 8-device CPU mesh.

jax pins its device count at first init, so the measured run happens in a
fresh subprocess with ``XLA_FLAGS=--xla_force_host_platform_device_count``
set — the same trick ``tests/distributed`` uses — and this module just
parses its CSV back out.  Measured per Table-3-style tensor:

  * wall time per ALS iteration for single-device fused vs distributed
    (8 virtual devices; on CPU the shards serialize, so this is a
    correctness/overhead smoke, not a scaling claim);
  * host syncs per iteration for the distributed engine — asserted <= 1
    per ``check_every`` window (+1 final), i.e. zero per-iteration syncs
    inside a window;
  * the fp32 agreement of the final fit with the single-device engine;
  * a masked/weighted completion row (``method="masked"`` with
    fractional observation confidences): per-shard residual scatter,
    psum of partial valued MTTKRPs, weighted sharded fit — the
    distributed path of the weighted-observations front door.

Output: ``name,us_per_call,derived`` CSV like the other sections.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

DEVICES = 8

_CHILD = """
    import time
    import numpy as np
    from repro.core import cpd_als, random_sparse
    from repro.core.distributed import cpd_als_distributed, make_distributed_plan

    ITERS, CHECK = 6, 3
    for name, shape, nnz in (("uber-like", (60, 24, 160), 2000),
                             ("tiny-mode", (48, 32, 6), 1500)):
        t = random_sparse(shape, nnz, seed=7, distribution="powerlaw")
        # warm-up (compile + plan build), then time
        single = cpd_als(t, rank=8, n_iters=1, tol=-1.0, check_every=1)
        t0 = time.perf_counter()
        single = cpd_als(t, rank=8, n_iters=ITERS, tol=-1.0,
                         check_every=CHECK)
        single_s = time.perf_counter() - t0

        plan = make_distributed_plan(t)
        cpd_als_distributed(t, rank=8, plan=plan, n_iters=CHECK, tol=-1.0,
                            check_every=CHECK)
        t0 = time.perf_counter()
        dist = cpd_als_distributed(t, rank=8, plan=plan, n_iters=ITERS,
                                   tol=-1.0, check_every=CHECK)
        dist_s = time.perf_counter() - t0

        assert dist.host_syncs <= ITERS // CHECK + 1, dist.host_syncs
        assert abs(dist.fits[-1] - single.fits[-1]) < 1e-3, (
            dist.fits[-1], single.fits[-1])
        schemes = "/".join(m.scheme.name[0] + m.scheme.name[-1]
                           for m in plan.modes)
        print(f"dist/{name}/single,{single_s / ITERS * 1e6:.0f},"
              f"fit={single.fits[-1]:.4f}")
        print(f"dist/{name}/shard_map-8dev,{dist_s / ITERS * 1e6:.0f},"
              f"fit={dist.fits[-1]:.4f};"
              f"syncs_per_iter={dist.host_syncs / ITERS:.2f};"
              f"schemes={schemes}")

    # Masked/weighted completion under shard_map: per-shard residual
    # scatter + psum of partial valued MTTKRPs, weighted sharded fit.
    t = random_sparse((48, 32, 6), 1500, seed=7, distribution="powerlaw")
    w = np.random.default_rng(1).uniform(0.25, 1.0, t.nnz).astype(np.float32)
    single = cpd_als(t, rank=8, n_iters=ITERS, tol=-1.0, check_every=CHECK,
                     method="masked", weights=w)
    mplan = make_distributed_plan(t, method="masked", weights=w)
    cpd_als_distributed(t, rank=8, plan=mplan, n_iters=CHECK, tol=-1.0,
                        check_every=CHECK, method="masked")
    t0 = time.perf_counter()
    dist = cpd_als_distributed(t, rank=8, plan=mplan, n_iters=ITERS,
                               tol=-1.0, check_every=CHECK, method="masked")
    dist_s = time.perf_counter() - t0
    assert dist.host_syncs <= ITERS // CHECK + 1, dist.host_syncs
    assert abs(dist.fits[-1] - single.fits[-1]) < 1e-3, (
        dist.fits[-1], single.fits[-1])
    print(f"dist/masked-weighted/shard_map-8dev,{dist_s / ITERS * 1e6:.0f},"
          f"fit={dist.fits[-1]:.4f};single_fit={single.fits[-1]:.4f};"
          f"syncs_per_iter={dist.host_syncs / ITERS:.2f}")
"""


def run(devices: int = DEVICES) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(_CHILD)],
                         capture_output=True, text=True, env=env,
                         timeout=1200)
    if out.returncode != 0:
        raise RuntimeError(f"distributed smoke failed:\n{out.stdout}\n"
                           f"{out.stderr}")
    return out.stdout


def main():
    print("name,us_per_call,derived")
    print(run(), end="")


if __name__ == "__main__":
    main()
