"""Fig. 3 — total execution time: ours vs baseline formats.

Paper claims (RTX 3090, CUDA): geomean speedup 2.4x vs BLCO, 8.9x vs
MM-CSF, 7.9x vs ParTI.

Three instruments, strongest first:
  device-model  GPU-architectural cost model fed by measured layout
                statistics (benchmarks/device_model.py) — the
                apples-to-apples comparison against the paper's numbers.
  traffic       bytes-moved ratios (hardware-independent lower bound).
  cpu-wall      wall clock of the JAX re-implementations on this CPU
                container — reported for transparency; a CPU has no SMs,
                atomics or L1-resident accumulators, so the published
                ordering is NOT expected to hold here.
"""
from __future__ import annotations

import numpy as np

from .common import (BLCOLikeEngine, CSFLikeEngine, engine_naive_coo,
                     engine_ours, load_datasets, time_engine, traffic_model)
from .device_model import total_cost

FMTS = ("blco-like", "csf-like", "naive-coo")


def run(iters: int = 2) -> list[dict]:
    rows = []
    for name, t in load_datasets().items():
        engines = {
            "ours": engine_ours,
            "blco-like": BLCOLikeEngine(t),
            "csf-like": CSFLikeEngine(t),
            "naive-coo": engine_naive_coo,
        }
        row = {"dataset": name, "nnz": t.nnz, "shape": t.shape}
        for fmt, eng in engines.items():
            r = time_engine(t, eng, iters=iters)
            row[f"{fmt}_cpu_s"] = r["mttkrp_seconds"]
            row[f"{fmt}_traffic"] = traffic_model(t, fmt)
            row[f"{fmt}_model_s"] = total_cost(t, fmt)
        for fmt in FMTS:
            row[f"model_speedup_vs_{fmt}"] = (
                row[f"{fmt}_model_s"] / row["ours_model_s"])
            row[f"traffic_ratio_vs_{fmt}"] = (
                row[f"{fmt}_traffic"] / row["ours_traffic"])
            row[f"cpu_speedup_vs_{fmt}"] = (
                row[f"{fmt}_cpu_s"] / row["ours_cpu_s"])
        rows.append(row)
    return rows


def main():
    rows = run()
    print("name,us_per_call,derived")
    geo = {f: [] for f in FMTS}
    for r in rows:
        print(f"fig3/{r['dataset']}/ours,{r['ours_model_s']*1e6:.0f},"
              f"nnz={r['nnz']};cpu_s={r['ours_cpu_s']:.3f}")
        for fmt in FMTS:
            print(f"fig3/{r['dataset']}/{fmt},{r[f'{fmt}_model_s']*1e6:.0f},"
                  f"model_speedup={r[f'model_speedup_vs_{fmt}']:.2f}x;"
                  f"traffic_ratio={r[f'traffic_ratio_vs_{fmt}']:.2f}x;"
                  f"cpu_speedup={r[f'cpu_speedup_vs_{fmt}']:.2f}x")
            geo[fmt].append(r[f"model_speedup_vs_{fmt}"])
    paper = {"blco-like": "2.4x", "csf-like": "8.9x", "naive-coo": "7.9x"}
    for fmt, v in geo.items():
        gm = float(np.exp(np.mean(np.log(v))))
        print(f"fig3/geomean_model_speedup_vs_{fmt},{gm:.3f},paper={paper[fmt]}")


if __name__ == "__main__":
    main()
