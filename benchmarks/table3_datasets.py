"""Table III — dataset characteristics (synthetic FROSTT stand-ins).

Verifies the generators reproduce the structural features that drive the
paper's results: mode counts, dimension ratios, nnz, and per-mode
fiber-density skew (Zipf), and reports which load-balancing scheme the
adaptive rule picks per mode (kappa=82, as on the paper's RTX 3090).
"""
from __future__ import annotations

import numpy as np

from repro.core.load_balance import choose_scheme

from .common import KAPPA, load_datasets


def run():
    rows = []
    for name, t in load_datasets().items():
        deg_skew = []
        schemes = []
        for d in range(t.nmodes):
            deg = t.mode_degrees(d)
            nz = deg[deg > 0]
            deg_skew.append(float(nz.max() / max(nz.mean(), 1e-9)))
            schemes.append(choose_scheme(t.shape[d], KAPPA).value)
        rows.append({
            "dataset": name, "shape": t.shape, "nnz": t.nnz,
            "density": t.density, "max_over_mean_degree": deg_skew,
            "adaptive_schemes": schemes,
        })
    return rows


def main():
    rows = run()
    print("name,us_per_call,derived")
    for r in rows:
        print(f"table3/{r['dataset']},0,shape={'x'.join(map(str, r['shape']))};"
              f"nnz={r['nnz']};schemes={r['adaptive_schemes']};"
              f"skew={[round(s,1) for s in r['max_over_mean_degree']]}")


if __name__ == "__main__":
    main()
