"""Pod serving benchmark: mesh-sharded batch axis + on-device convergence
+ double-buffered dispatch, on a virtual 8-device CPU mesh.

jax pins its device count at first init, so the measured run happens in a
fresh subprocess with ``XLA_FLAGS=--xla_force_host_platform_device_count``
set (the same trick ``dist_bench`` and ``tests/distributed`` use); the
child prints one ``ROW {json}`` line per witness and this module parses
them back into the runner's structured rows.  The witnesses:

  * ``pod/one-dispatch`` — a multi-window decomposition is ONE device
    dispatch: exactly one ``pod.dispatch`` span in the trace, every
    result reports ``host_syncs == 1``, and the on-device while_loop ran
    all its windows (``pod.window`` event);
  * ``pod/load-balance`` — per-device nnz load of the dispatched lanes
    (shard_map splits the batch into contiguous per-device blocks) and
    the max/mean imbalance factor;
  * ``pod/lane-placement`` — load-aware lane placement on a jittered-nnz
    2-lanes-per-device batch: the placed per-device imbalance must be no
    worse than the arrival-order contiguous split (both read from the
    same ``pod.dispatch`` span);
  * ``pod/agreement`` — max fp32 deviation of the pod factors/fits from
    the single-device batched engine on the same requests;
  * ``pod/overlap`` — a double-buffered service stream through the pod
    engine: overlap fraction (host assembly hidden behind device
    compute) must be > 0, plus device occupancy and per-device dispatch
    counts;
  * ``pod/ledger`` — pod-block executables and their retrace ceiling
    (one trace per registered block; more is a jit cache
    re-specializing).

On CPU the 8 virtual shards serialize, so wall times here are
correctness/overhead smokes, not scaling claims.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

DEVICES = 8

_CHILD = """
    import json
    import numpy as np
    from repro.core import random_sparse
    from repro.launch.mesh import make_batch_mesh
    from repro.obs import trace as obs_trace
    from repro.obs.ledger import LEDGER
    from repro.serve import BatchedEngine
    from repro.serve.scheduler import DecompositionService

    SMOKE = {smoke}
    RANK = 3 if SMOKE else 8
    B, NNZ, ITERS, CHECK = (8, 480, 10, 2) if SMOKE else (16, 1500, 24, 3)
    SHAPE = (18, 13, 9) if SMOKE else (60, 24, 40)

    def row(r):
        print("ROW " + json.dumps(r))

    ts = [random_sparse(SHAPE, NNZ - 7 * i, seed=i,
                        distribution="powerlaw") for i in range(B)]
    cap = NNZ
    kw = dict(n_iters=ITERS, tol=-1.0, seeds=list(range(B)), nnz_cap=cap)

    plain = BatchedEngine(rank=RANK, kappa=2, backend="segment",
                          check_every=CHECK)
    ref = plain.decompose_batch(ts, **kw)

    pod = BatchedEngine(rank=RANK, kappa=2, backend="segment",
                        check_every=CHECK, mesh=make_batch_mesh({devices}))
    pod.decompose_batch(ts[:1], n_iters=CHECK, tol=-1.0, seeds=[0],
                        nnz_cap=cap)                       # warm 1-lane pod
    with obs_trace.capture() as tr:
        res = pod.decompose_batch(ts, **kw)
    events = tr.records()
    dispatches = [e for e in events if e["name"] == "pod.dispatch"]
    windows = [e for e in events if e["name"] == "pod.window"]
    assert len(dispatches) == 1 and len(windows) == 1, (
        [e["name"] for e in events])
    row({{"name": "pod/one-dispatch", "section": "dispatch",
         "pod_dispatch_spans": len(dispatches),
         "host_syncs": max(r.host_syncs for r in res),
         "windows": windows[0]["args"]["windows"],
         "max_windows": dispatches[0]["args"]["max_windows"],
         "sweeps_per_window": CHECK, "devices": {devices}, "B": B}})

    dev_nnz = dispatches[0]["args"]["device_nnz"]
    mean = sum(dev_nnz) / len(dev_nnz)
    row({{"name": "pod/load-balance", "section": "balance",
         "device_nnz": dev_nnz,
         "imbalance": max(dev_nnz) / mean if mean else 1.0}})

    fit_err = max(float(np.abs(np.asarray(a.fits)
                               - np.asarray(b.fits)).max())
                  for a, b in zip(res, ref))
    fac_err = max(float(np.abs(np.asarray(Fa) - np.asarray(Fb)).max())
                  for a, b in zip(res, ref)
                  for Fa, Fb in zip(a.factors, b.factors))
    row({{"name": "pod/agreement", "section": "agreement",
         "max_fit_err": fit_err, "max_factor_err": fac_err,
         "tolerance": 1e-3}})
    assert fit_err < 1e-3 and fac_err < 1e-2, (fit_err, fac_err)

    # Load-aware lane placement: 2 lanes/device with shuffled jittered
    # nnz — the placed (heaviest-first greedy) per-device load must be
    # no worse than the arrival-order contiguous split, both recorded
    # on the same pod.dispatch span.
    rng = np.random.default_rng(0)
    sizes = rng.permutation([max(NNZ - 23 * i, 40)
                             for i in range(2 * {devices})]).tolist()
    ts2 = [random_sparse(SHAPE, int(s), seed=200 + i,
                         distribution="powerlaw")
           for i, s in enumerate(sizes)]
    with obs_trace.capture() as tr2:
        pod.decompose_batch(ts2, n_iters=CHECK, tol=-1.0,
                            seeds=list(range(len(ts2))), nnz_cap=cap)
    d2 = [e for e in tr2.records()
          if e["name"] == "pod.dispatch"][0]["args"]
    assert d2["lane_placement"] == "balanced", d2
    assert d2["imbalance"] <= d2["imbalance_contiguous"] + 1e-9, d2
    row({{"name": "pod/lane-placement", "section": "balance",
         "B": len(ts2), "devices": {devices},
         "imbalance": d2["imbalance"],
         "imbalance_contiguous": d2["imbalance_contiguous"],
         "imbalance_delta": d2["imbalance_contiguous"] - d2["imbalance"],
         "device_nnz": d2["device_nnz"],
         "device_nnz_contiguous": d2["device_nnz_contiguous"]}})

    # Double-buffered stream through the pod engine: 3 flushes, each
    # flush's host assembly overlapping the previous flush's dispatch.
    svc = DecompositionService(rank=RANK, max_batch={devices},
                               mesh=make_batch_mesh({devices}),
                               double_buffer=True)
    futs = [svc.submit(random_sparse(SHAPE, NNZ, seed=100 + i,
                                     distribution="powerlaw"),
                       n_iters=ITERS, tol=-1.0, seed=i)
            for i in range(3 * {devices})]
    svc.drain()
    for f in futs:
        assert f.result().engine == "pod"
    d = svc.snapshot()["dispatch"]
    row({{"name": "pod/overlap", "section": "overlap",
         "dispatches": d["count"],
         "overlap_fraction": d["overlap_fraction"],
         "assembly_s": d["assembly_s"], "execute_s": d["execute_s"],
         "device_occupancy": d["device_occupancy"],
         "device_dispatches": d["device_dispatches"]}})

    s = LEDGER.stats("pod_block")
    row({{"name": "pod/ledger", "section": "ledger",
         "blocks": s["blocks"], "traces": s["traces"],
         "expected_max_traces": s["blocks"]}})
"""


def run(devices: int = DEVICES, smoke: bool = False) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    code = textwrap.dedent(_CHILD).format(devices=devices, smoke=smoke)
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True, env=env,
                         timeout=1200)
    if out.returncode != 0:
        raise RuntimeError(f"pod smoke failed:\n{out.stdout}\n{out.stderr}")
    return out.stdout


def main(argv: list[str] | None = None) -> list[dict]:
    argv = list(sys.argv[1:] if argv is None else argv)
    smoke = "--smoke" in argv
    rows = []
    print("name,us_per_call,derived")
    for line in run(smoke=smoke).splitlines():
        if not line.startswith("ROW "):
            continue
        r = json.loads(line[4:])
        rows.append(r)
        derived = ";".join(f"{k}={v}" for k, v in r.items()
                           if k not in ("name", "section"))
        print(f"{r['name']},0,{derived}")
    return rows


if __name__ == "__main__":
    main()
