"""Fig. 5 — GPU global memory requirement of the mode-specific format.

Reports, per dataset: bytes for all N mode-specific copies + factor
matrices (R=32 fp32), both as concretely stored (int32 indices) and via
the paper's analytic bit-packed model (sum log2(I_h) + 32 bits / nnz).
Also extrapolates the FULL (unscaled) FROSTT tensors to verify the
paper's small-tensor premise: all copies fit a 24 GB RTX 3090 / 16 GB
v5e HBM.
"""
from __future__ import annotations

import numpy as np

from repro.core import build_all_mode_layouts, format_memory_report
from repro.core.coo import FROSTT_SHAPES

from .common import KAPPA, RANK, load_datasets


def full_scale_analytic(name: str) -> dict:
    shape, nnz = FROSTT_SHAPES[name]
    N = len(shape)
    bits = sum(np.log2(max(2, s)) for s in shape) + 32
    copies = int(N * nnz * bits / 8)
    factors = int(sum(shape) * RANK * 4)
    stored = int(N * nnz * (4 * N + 4))   # int32 indices + f32 value
    return {"analytic_copies": copies, "factors": factors,
            "stored_copies": stored,
            "fits_24g": (stored + factors) < 24e9,
            "fits_16g": (stored + factors) < 16e9}


def run():
    rows = []
    for name, t in load_datasets().items():
        layouts = build_all_mode_layouts(t, KAPPA)
        rep = format_memory_report(t, layouts)
        rep["dataset"] = name
        rep["full_scale"] = full_scale_analytic(name)
        rows.append(rep)
    rows.append({"dataset": "nell-1(analytic-only)",
                 "full_scale": full_scale_analytic("nell-1")})
    return rows


def main():
    rows = run()
    print("name,us_per_call,derived")
    for r in rows:
        fs = r["full_scale"]
        extra = (f"full_stored={fs['stored_copies']/1e9:.2f}GB;"
                 f"fits24G={fs['fits_24g']};fits16G={fs['fits_16g']}")
        if "total_bytes" in r:
            print(f"fig5/{r['dataset']},{r['total_bytes']},"
                  f"scaled_copies={r['copies_bytes']};{extra}")
        else:
            print(f"fig5/{r['dataset']},0,{extra}")


if __name__ == "__main__":
    main()
