"""Fig. 4 — impact of the adaptive load-balancing scheme.

Paper claim: adaptive gives geomean 2.2x over scheme-1-only and 1.3x over
scheme-2-only.  Mechanisms: scheme 1 on a small output mode cannot fill
all SMs (idling); scheme 2 on a large output mode pays global atomics.
The device cost model prices both from measured partitionings; CPU wall
time is reported as a proxy alongside.
"""
from __future__ import annotations

import numpy as np

from repro.core import Scheme, partition_mode
from repro.core.load_balance import choose_scheme

from .common import KAPPA, engine_ours, load_datasets, time_engine
from .device_model import total_cost


def _cost_policy_total(t, kappa=KAPPA):
    """Beyond-paper: per-mode argmin of the modeled cost (see
    core.load_balance.choose_scheme_cost_based)."""
    from repro.core.load_balance import choose_scheme_cost_based
    from .device_model import mode_cost

    return sum(
        mode_cost(t, d, "ours",
                  scheme=choose_scheme_cost_based(t, d, kappa)).total_s
        for d in range(t.nmodes)
    )


def run(iters: int = 2):
    rows = []
    for name, t in load_datasets().items():
        ta = total_cost(t, "ours", scheme=None)                       # adaptive
        t1 = total_cost(t, "ours", scheme=Scheme.INDEX_PARTITION)     # s1 only
        t2 = total_cost(t, "ours", scheme=Scheme.NNZ_PARTITION)      # s2 only
        tc = _cost_policy_total(t)                                    # beyond-paper
        m_ad = time_engine(t, engine_ours, iters=iters, scheme=None)
        m_s1 = time_engine(t, engine_ours, iters=iters,
                           scheme=Scheme.INDEX_PARTITION)
        m_s2 = time_engine(t, engine_ours, iters=iters,
                           scheme=Scheme.NNZ_PARTITION)
        picks = [choose_scheme(t.shape[d], KAPPA).value
                 for d in range(t.nmodes)]
        rows.append({
            "dataset": name,
            "adaptive_model_s": ta,
            "model_speedup_vs_s1": t1 / ta,
            "model_speedup_vs_s2": t2 / ta,
            "cost_policy_model_s": tc,
            "cost_vs_adaptive": ta / tc,
            "cpu_adaptive_s": m_ad["mttkrp_seconds"],
            "cpu_s1_s": m_s1["mttkrp_seconds"],
            "cpu_s2_s": m_s2["mttkrp_seconds"],
            "picks": picks,
        })
    return rows


def main():
    rows = run()
    print("name,us_per_call,derived")
    g1, g2 = [], []
    for r in rows:
        print(f"fig4/{r['dataset']}/adaptive,{r['adaptive_model_s']*1e6:.0f},"
              f"picks={r['picks']};model_speedup_vs_s1="
              f"{r['model_speedup_vs_s1']:.2f}x;vs_s2="
              f"{r['model_speedup_vs_s2']:.2f}x;"
              f"cpu_s={r['cpu_adaptive_s']:.3f}")
        g1.append(r["model_speedup_vs_s1"])
        g2.append(r["model_speedup_vs_s2"])
    print(f"fig4/geomean_model_speedup_vs_s1,"
          f"{float(np.exp(np.mean(np.log(g1)))):.3f},paper=2.2x")
    print(f"fig4/geomean_model_speedup_vs_s2,"
          f"{float(np.exp(np.mean(np.log(g2)))):.3f},paper=1.3x")
    gc = [r["cost_vs_adaptive"] for r in rows]
    print(f"fig4/geomean_costpolicy_vs_adaptive,"
          f"{float(np.exp(np.mean(np.log(gc)))):.3f},beyond-paper")


if __name__ == "__main__":
    main()
