"""Shared benchmark machinery: datasets, baseline MTTKRP formats, timing.

Baseline formats are honest JAX re-implementations of the *algorithmic
idea* of each published baseline (their CUDA kernels cannot run here):

  naive-coo   ParTI-like: unsorted COO, materialized (nnz, R) Khatri-Rao
              intermediate written back per mode, scatter-add updates.
  csf-like    MM-CSF-like: ONE tensor copy sorted for a single mode;
              the other modes run with unsorted scatter-adds (the cost
              MM-CSF pays for avoiding per-mode copies).
  blco-like   BLCO-like: single linearized copy (64-bit packed indices),
              unpacked on the fly each mode, segment-summed after an
              on-device sort per mode (BLCO's conflict resolution).
  ours        mode-specific layouts + adaptive load balancing (the paper).

All run through the SAME CPD-ALS driver so total-execution-time ratios
are apples-to-apples.  CPU wall-time is a proxy for GPU time; the
memory-traffic model (bytes moved per mode) is hardware-independent and
reported alongside.
"""
from __future__ import annotations

import functools
import pathlib
import socket
import subprocess
import time
from typing import Callable

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import SparseTensor, frostt_like, make_plan, mttkrp
from repro.core.layout import build_mode_layout
from repro.core.load_balance import Scheme
from repro.kernels import ref as kref

# CI-sized FROSTT stand-ins (same mode-count / dimension ratios, nnz
# scaled; see core.coo.frostt_like).
BENCH_SCALE = 0.04
DATASETS = ("chicago", "enron", "nell-1", "nips", "uber", "vast")
RANK = 32
KAPPA = 82    # the paper's RTX 3090 SM count — kept for comparability

_REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]


@functools.lru_cache(maxsize=1)
def _static_provenance() -> dict:
    def _git(*args: str) -> str:
        try:
            out = subprocess.run(["git", *args], cwd=_REPO_ROOT,
                                 capture_output=True, text=True, timeout=10)
        except (OSError, subprocess.SubprocessError):
            return ""
        return out.stdout.strip() if out.returncode == 0 else ""

    return {
        "git_sha": _git("rev-parse", "HEAD") or "unknown",
        "git_dirty": bool(_git("status", "--porcelain")),
        "host": socket.gethostname(),
        "jax_version": jax.__version__,
        "device": jax.devices()[0].platform,
    }


def provenance() -> dict:
    """Run provenance stamped into every ``BENCH_*.json`` and history
    record: git sha (+ dirty flag), hostname, jax version, device
    platform — cached once per process — plus a fresh UTC timestamp.
    The regression gate keys its cross-machine portability rules off the
    (host, device) pair, so every emitter must carry it."""
    out = dict(_static_provenance())
    out["ts_utc"] = time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime())
    return out


def load_datasets(scale: float = BENCH_SCALE, include_nell: bool = False):
    names = DATASETS + (("nell-1",) if include_nell else ())
    out = {}
    for n in names:
        sc = scale * (0.1 if n in ("enron", "vast") else 0.01 if n == "nell-1" else 1.0)
        out[n] = frostt_like(n, scale=sc, seed=42)
    return out


# ---------------------------------------------------------------------------
# Baseline engines (mttkrp_fn signatures match core.cpd.cpd_als)
# ---------------------------------------------------------------------------


def engine_ours(plan, factors, mode):
    return mttkrp(plan, factors, mode, backend="segment")


def engine_naive_coo(plan, factors, mode):
    """ParTI-like: unsorted scatter-add with materialized KRP rows."""
    t = plan.tensor
    return kref.mttkrp_coo(
        jnp.asarray(t.indices), jnp.asarray(t.values),
        [jnp.asarray(f) for f in factors], mode, t.shape[mode])


class CSFLikeEngine:
    """One copy sorted for mode 0 only; other modes pay unsorted updates."""

    def __init__(self, tensor: SparseTensor):
        self.layout0 = build_mode_layout(tensor, 0, 1)
        self.tensor = tensor

    def __call__(self, plan, factors, mode):
        if mode == 0:
            lay = self.layout0
            in_modes = lay.input_modes()
            out = kref.mttkrp_sorted_segments(
                jnp.asarray(lay.indices[:, in_modes]), jnp.asarray(lay.rows),
                jnp.asarray(lay.values), [jnp.asarray(factors[w]) for w in in_modes],
                lay.num_rows)
            res = jnp.zeros_like(out).at[jnp.asarray(lay.row_perm)].set(out)
            return res
        # other modes: traverse the mode-0-ordered copy, scatter-add
        lay = self.layout0
        idx = jnp.asarray(lay.indices)
        vals = jnp.asarray(lay.values)
        return kref.mttkrp_coo(idx, vals, [jnp.asarray(f) for f in factors],
                               mode, self.tensor.shape[mode])


class BLCOLikeEngine:
    """Single linearized (packed int64) copy; per-mode unpack + sort."""

    def __init__(self, tensor: SparseTensor):
        self.tensor = tensor
        shape = tensor.shape
        self.bits = [int(np.ceil(np.log2(max(2, s)))) for s in shape]
        assert sum(self.bits) <= 63, "tensor too large to linearize in 63b"
        key = np.zeros(tensor.nnz, dtype=np.int64)
        for d in range(tensor.nmodes):
            key = (key << self.bits[d]) | tensor.indices[:, d].astype(np.int64)
        self.packed = jnp.asarray(key)
        self.values = jnp.asarray(tensor.values)

    def _unpack(self):
        cols = []
        shift = 0
        for d in reversed(range(self.tensor.nmodes)):
            mask = (1 << self.bits[d]) - 1
            cols.append((self.packed >> shift) & mask)
            shift += self.bits[d]
        return list(reversed(cols))

    def __call__(self, plan, factors, mode):
        cols = self._unpack()
        idx_d = cols[mode].astype(jnp.int32)
        # BLCO resolves conflicts by sorting nnz by output index per mode.
        order = jnp.argsort(idx_d)
        acc = self.values[order, None].astype(jnp.float32)
        for w in range(self.tensor.nmodes):
            if w == mode:
                continue
            acc = acc * jnp.take(jnp.asarray(factors[w]),
                                 cols[w].astype(jnp.int32)[order], axis=0)
        return jax.ops.segment_sum(
            acc, idx_d[order], num_segments=self.tensor.shape[mode],
            indices_are_sorted=True)


def time_engine(tensor: SparseTensor, engine: Callable, *, rank=RANK,
                iters=3, kappa=KAPPA, scheme=None) -> dict:
    """Time total MTTKRP seconds across all modes x iters inside CPD-ALS."""
    from repro.core.cpd import cpd_als

    plan = make_plan(tensor, kappa, scheme=scheme)
    res = cpd_als(tensor, rank, plan=plan, n_iters=iters, tol=-1.0,
                  mttkrp_fn=engine)
    return {
        "mttkrp_seconds": res.mttkrp_seconds,
        "total_seconds": res.total_seconds,
        "fit": res.fits[-1],
        "iters": res.iters,
    }


def traffic_model(tensor: SparseTensor, fmt: str, *, rank=RANK) -> int:
    """Bytes moved to/from 'global memory' per full all-modes MTTKRP sweep —
    the architecture-independent cost the paper optimizes.  Counts, per
    mode: nnz reads (indices+value), input-factor row gathers, output
    writes, and any intermediate (nnz, R) materialization."""
    N, nnz = tensor.nmodes, tensor.nnz
    R4 = rank * 4
    total = 0
    for d in range(N):
        nnz_bytes = nnz * (4 * N + 4)
        gathers = nnz * (N - 1) * R4
        out = tensor.shape[d] * R4
        if fmt == "ours":
            total += nnz_bytes + gathers + out          # fused: no intermediates
        elif fmt == "naive-coo":
            # materialized KRP intermediate written+read + atomic RMW on out
            total += nnz_bytes + gathers + 2 * nnz * R4 + 2 * nnz * R4
        elif fmt == "csf-like":
            fused = d == 0
            total += nnz_bytes + gathers + (out if fused else 2 * nnz * R4)
        elif fmt == "blco-like":
            # packed key reads + unpack writes + sorted segment pass
            total += nnz * 8 + nnz * 4 * N + gathers + out + nnz * R4
        else:
            raise ValueError(fmt)
    return total
