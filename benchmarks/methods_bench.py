"""Decomposition-methods benchmark: every registered method on the shared
substrate, sequential and through the batched service.

Per method (plain cp / nncp / masked / streaming):

  * sequential fused wall time per iteration and final fit on a
    powerlaw-skewed synthetic (nonneg values for nncp; 50%-observed
    low-rank for masked, reporting held-out reconstruction error —
    the completion workload's actual figure of merit);
  * weighted completion (the ``weights=`` front door): noisy observed
    entries down-weighted to confidence 0.1 vs a uniform-confidence fit
    of the same data — the held-out error gap is what per-entry
    observation weights buy;
  * a mixed-method service stream: interleaved {cp, nncp, masked}
    requests of one shape class, batched into method-keyed buckets —
    reported as stream wall time, batches flushed, and padding overhead
    (the "methods layer rides the serving layer" probe);
  * streaming: k increments of warm-started folding vs one cold batch
    refit of the same union tensor (speedup = refit time / total
    increment time, plus the fit gap).

``--smoke`` shrinks sizes/iters for CI.  Rows carry the bucket plan
fingerprint so perf shifts are attributable to planning changes.
"""
from __future__ import annotations

import sys
import time

import numpy as np

from repro.core import SparseTensor, cpd_als, plan_tensor, random_sparse
from repro.methods import StreamingCP, list_methods
from repro.serve import DecompositionService

RANK = 8
KAPPA = 2


def _dense_low_rank(shape, rank, seed):
    rng = np.random.default_rng(seed)
    factors = [rng.standard_normal((I, rank)).astype(np.float32)
               for I in shape]
    full = np.einsum("ir,jr,kr->ijk", *factors)
    coords = np.indices(shape).reshape(len(shape), -1).T.astype(np.int32)
    return coords, full.reshape(-1).astype(np.float32)


def bench_sequential(shape, nnz, iters, rank) -> list[dict]:
    rows = []
    t = random_sparse(shape, nnz, seed=0, distribution="powerlaw")
    t_pos = SparseTensor(t.indices, np.abs(t.values) + 0.1, t.shape)
    plan_fp = plan_tensor(t, rank, KAPPA).describe()
    for method, tensor in (("cp", t), ("nncp", t_pos), ("masked", t)):
        # Warm-up with the SAME check window: the scan block length is
        # part of the executable key.
        cpd_als(tensor, rank, kappa=KAPPA, n_iters=2, tol=-1.0,
                check_every=2, method=method)
        t0 = time.perf_counter()
        res = cpd_als(tensor, rank, kappa=KAPPA, n_iters=iters, tol=-1.0,
                      check_every=2, method=method)
        wall = time.perf_counter() - t0
        rows.append({
            "name": f"methods/{method}/sequential",
            "method": method, "shape": shape, "nnz": tensor.nnz,
            "s_per_iter": wall / iters, "fit": res.fits[-1],
            "plan": plan_fp,
        })
    return rows


def bench_completion(shape, rank, iters) -> dict:
    """Masked CP on 50% observed entries of an exact low-rank tensor:
    held-out reconstruction error is the workload's figure of merit."""
    coords, vals = _dense_low_rank(shape, rank, seed=7)
    rng = np.random.default_rng(8)
    perm = rng.permutation(len(coords))
    half = len(coords) // 2
    obs, held = perm[:half], perm[half:]
    t_obs = SparseTensor(coords[obs], vals[obs], shape)
    t0 = time.perf_counter()
    res = cpd_als(t_obs, rank, kappa=KAPPA, n_iters=iters, tol=-1.0,
                  check_every=5, method="masked")
    wall = time.perf_counter() - t0
    pred = res.reconstruct_at(coords[held])
    rel = float(np.linalg.norm(pred - vals[held])
                / max(np.linalg.norm(vals[held]), 1e-12))
    return {"name": "methods/masked/completion-50pct", "method": "masked",
            "shape": shape, "observed": int(half), "wall_s": wall,
            "fit": res.fits[-1], "heldout_rel_err": rel}


def bench_weighted_completion(shape, rank, iters, noise=0.3) -> dict:
    """Weighted completion (the ``weights=`` front door): half the
    observed entries are corrupted with noise and down-weighted to
    confidence 0.1.  The figure of merit is the held-out error of the
    weighted run vs the same data fitted with uniform confidence — the
    gap is what per-entry observation weights buy."""
    coords, vals = _dense_low_rank(shape, rank, seed=9)
    rng = np.random.default_rng(10)
    perm = rng.permutation(len(coords))
    half = len(coords) // 2
    obs, held = perm[:half], perm[half:]
    ov = vals[obs].copy()
    noisy = rng.random(half) < 0.5
    ov[noisy] += noise * np.abs(ov).mean() * rng.standard_normal(
        int(noisy.sum())).astype(np.float32) * 10
    w = np.where(noisy, 0.1, 1.0).astype(np.float32)
    t_obs = SparseTensor(coords[obs], ov, shape)
    t0 = time.perf_counter()
    res_w = cpd_als(t_obs, rank, kappa=KAPPA, n_iters=iters, tol=-1.0,
                    check_every=5, method="masked", weights=w)
    wall = time.perf_counter() - t0
    res_u = cpd_als(t_obs, rank, kappa=KAPPA, n_iters=iters, tol=-1.0,
                    check_every=5, method="masked")
    truth = vals[held]
    rel_w = float(np.linalg.norm(res_w.reconstruct_at(coords[held]) - truth)
                  / max(np.linalg.norm(truth), 1e-12))
    rel_u = float(np.linalg.norm(res_u.reconstruct_at(coords[held]) - truth)
                  / max(np.linalg.norm(truth), 1e-12))
    return {"name": "methods/masked/weighted-completion", "method": "masked",
            "shape": shape, "observed": int(half),
            "downweighted": int(noisy.sum()), "wall_s": wall,
            "fit": res_w.fits[-1], "heldout_rel_err_weighted": rel_w,
            "heldout_rel_err_uniform": rel_u,
            "err_ratio_uniform_over_weighted": rel_u / max(rel_w, 1e-12)}


def bench_mixed_stream(shape, nnz, n_each, iters, rank) -> dict:
    svc = DecompositionService(rank=rank, kappa=KAPPA, max_batch=4,
                               max_wait_s=10.0)
    futs = []
    t0 = time.perf_counter()
    for i in range(n_each):
        t = random_sparse(shape, nnz - 11 * i, seed=i,
                          distribution="powerlaw")
        t_pos = SparseTensor(t.indices, np.abs(t.values) + 0.1, t.shape)
        futs.append(svc.submit(t, n_iters=iters, tol=-1.0, seed=i))
        futs.append(svc.submit(t_pos, n_iters=iters, tol=-1.0, seed=i,
                               method="nncp"))
        futs.append(svc.submit(t, n_iters=iters, tol=-1.0, seed=i,
                               method="masked"))
    svc.drain()
    for f in futs:
        f.result()
    wall = time.perf_counter() - t0
    snap = svc.snapshot()
    return {"name": "methods/mixed-stream", "requests": len(futs),
            "wall_s": wall, "batches": snap["batches"],
            "padding_overhead": snap["padding_overhead"],
            "cache_hit_rate": snap["cache_hit_rate"],
            "density_tracked_buckets": snap["density_tracked_buckets"]}


def bench_streaming(shape, rank, chunks, refine_iters, cold_iters) -> dict:
    coords, vals = _dense_low_rank(shape, rank, seed=5)
    rng = np.random.default_rng(6)
    parts = np.array_split(rng.permutation(len(coords)), chunks)
    t_full = SparseTensor(coords, vals, shape)

    s = StreamingCP(rank, refine_iters=refine_iters, check_every=4)
    s.start(SparseTensor(coords[parts[0]], vals[parts[0]], shape),
            n_iters=cold_iters, tol=-1.0, seed=2)
    t0 = time.perf_counter()
    for p in parts[1:]:
        s.update(SparseTensor(coords[p], vals[p], shape))
    inc_wall = time.perf_counter() - t0

    # Warm-up with the SAME check window (block length is part of the
    # executable key): n_iters=6 @ check_every=4 compiles both the
    # window-4 block and the remainder window-2 block the timed refit uses.
    cpd_als(t_full, rank, kappa=1, n_iters=6, tol=-1.0, seed=2,
            check_every=4)
    t0 = time.perf_counter()
    ref = cpd_als(t_full, rank, kappa=1, n_iters=cold_iters, tol=-1.0,
                  seed=2, check_every=4)
    refit_wall = time.perf_counter() - t0
    return {"name": "methods/streaming", "increments": chunks - 1,
            "refine_iters": refine_iters,
            "increment_wall_s": inc_wall, "refit_wall_s": refit_wall,
            "speedup_vs_refit": refit_wall / max(inc_wall, 1e-12),
            "stream_fit": s.fit, "refit_fit": ref.fits[-1]}


def run(smoke: bool = False) -> list[dict]:
    if smoke:
        shape, nnz, iters, n_each = (18, 13, 9), 350, 4, 2
        cshape, citers = (10, 8, 6), 30
        chunks, refine, cold = 3, 4, 16
    else:
        shape, nnz, iters, n_each = (64, 48, 32), 4000, 8, 4
        cshape, citers = (14, 12, 10), 60
        chunks, refine, cold = 4, 6, 30
    rows = bench_sequential(shape, nnz, iters, RANK)
    rows.append(bench_completion(cshape, 3, citers))
    rows.append(bench_weighted_completion(cshape, 3, citers))
    rows.append(bench_mixed_stream(shape, nnz, n_each, iters, RANK))
    rows.append(bench_streaming(cshape, 3, chunks, refine, cold))
    return rows


def main(argv: list[str] | None = None):
    argv = sys.argv[1:] if argv is None else argv
    smoke = "--smoke" in argv
    rows = run(smoke=smoke)
    print("name,us_per_call,derived")
    print(f"methods/registered,0,{';'.join(list_methods())}")
    for r in rows:
        us = r.get("s_per_iter", r.get("wall_s", 0.0)) * 1e6
        derived = ";".join(f"{k}={v}" for k, v in r.items()
                           if k not in ("name", "shape"))
        print(f"{r['name']},{us:.0f},{derived}")
    return rows


if __name__ == "__main__":
    main()
