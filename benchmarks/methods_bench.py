"""Decomposition-methods benchmark: every registered method on the shared
substrate, sequential and through the batched service.

Per method (plain cp / nncp / masked / streaming):

  * sequential fused wall time per iteration and final fit on a
    powerlaw-skewed synthetic (nonneg values for nncp; 50%-observed
    low-rank for masked, reporting held-out reconstruction error —
    the completion workload's actual figure of merit);
  * weighted completion (the ``weights=`` front door): noisy observed
    entries down-weighted to confidence 0.1 vs a uniform-confidence fit
    of the same data — the held-out error gap is what per-entry
    observation weights buy;
  * a mixed-method service stream: ROUNDS of interleaved {cp, nncp,
    masked} requests of one shape class, batched into method-keyed
    buckets — reported as stream wall time, batches flushed, padding
    overhead, and the steady-state executable-cache hit rate (round 1
    compiles each method bucket once; every later round must hit — the
    "methods layer rides the serving layer" probe);
  * streaming: a session routed through ``ALSRunner`` folds many small
    increments into bucket-quantized session state.  Reported per the
    zero-retrace contract: ``s_per_increment`` (mean warm-increment
    wall), ``host_merge_s`` (total O(nnz+m) merge time),
    ``cache_hit_rate`` over the whole session, ``speedup_vs_refit``
    (one WARM cold-start refit of the union tensor vs one increment —
    the fair steady-state comparison), and ``speedup_vs_retrace_refit``
    (refit at a NOVEL nnz class, compile included — what every
    increment actually paid before sessions were bucket-quantized).

``--smoke`` shrinks sizes/iters for CI.  Rows carry the bucket plan
fingerprint so perf shifts are attributable to planning changes.
"""
from __future__ import annotations

import sys
import time

import numpy as np

from repro.core import SparseTensor, cpd_als, plan_tensor, random_sparse
from repro.methods import list_methods
from repro.runtime import ALSRunner
from repro.serve import DecompositionService

RANK = 8
KAPPA = 2


def _dense_low_rank(shape, rank, seed):
    rng = np.random.default_rng(seed)
    factors = [rng.standard_normal((I, rank)).astype(np.float32)
               for I in shape]
    full = np.einsum("ir,jr,kr->ijk", *factors)
    coords = np.indices(shape).reshape(len(shape), -1).T.astype(np.int32)
    return coords, full.reshape(-1).astype(np.float32)


def bench_sequential(shape, nnz, iters, rank) -> list[dict]:
    rows = []
    t = random_sparse(shape, nnz, seed=0, distribution="powerlaw")
    t_pos = SparseTensor(t.indices, np.abs(t.values) + 0.1, t.shape)
    plan_fp = plan_tensor(t, rank, KAPPA).describe()
    for method, tensor in (("cp", t), ("nncp", t_pos), ("masked", t)):
        # Warm-up with the SAME check window: the scan block length is
        # part of the executable key.
        cpd_als(tensor, rank, kappa=KAPPA, n_iters=2, tol=-1.0,
                check_every=2, method=method)
        t0 = time.perf_counter()
        res = cpd_als(tensor, rank, kappa=KAPPA, n_iters=iters, tol=-1.0,
                      check_every=2, method=method)
        wall = time.perf_counter() - t0
        rows.append({
            "name": f"methods/{method}/sequential",
            "method": method, "shape": shape, "nnz": tensor.nnz,
            "s_per_iter": wall / iters, "fit": res.fits[-1],
            "plan": plan_fp,
        })
    return rows


def bench_completion(shape, rank, iters) -> dict:
    """Masked CP on 50% observed entries of an exact low-rank tensor:
    held-out reconstruction error is the workload's figure of merit."""
    coords, vals = _dense_low_rank(shape, rank, seed=7)
    rng = np.random.default_rng(8)
    perm = rng.permutation(len(coords))
    half = len(coords) // 2
    obs, held = perm[:half], perm[half:]
    t_obs = SparseTensor(coords[obs], vals[obs], shape)
    t0 = time.perf_counter()
    res = cpd_als(t_obs, rank, kappa=KAPPA, n_iters=iters, tol=-1.0,
                  check_every=5, method="masked")
    wall = time.perf_counter() - t0
    pred = res.reconstruct_at(coords[held])
    rel = float(np.linalg.norm(pred - vals[held])
                / max(np.linalg.norm(vals[held]), 1e-12))
    return {"name": "methods/masked/completion-50pct", "method": "masked",
            "shape": shape, "observed": int(half), "wall_s": wall,
            "fit": res.fits[-1], "heldout_rel_err": rel}


def bench_weighted_completion(shape, rank, iters, noise=0.3) -> dict:
    """Weighted completion (the ``weights=`` front door): half the
    observed entries are corrupted with noise and down-weighted to
    confidence 0.1.  The figure of merit is the held-out error of the
    weighted run vs the same data fitted with uniform confidence — the
    gap is what per-entry observation weights buy."""
    coords, vals = _dense_low_rank(shape, rank, seed=9)
    rng = np.random.default_rng(10)
    perm = rng.permutation(len(coords))
    half = len(coords) // 2
    obs, held = perm[:half], perm[half:]
    ov = vals[obs].copy()
    noisy = rng.random(half) < 0.5
    ov[noisy] += noise * np.abs(ov).mean() * rng.standard_normal(
        int(noisy.sum())).astype(np.float32) * 10
    w = np.where(noisy, 0.1, 1.0).astype(np.float32)
    t_obs = SparseTensor(coords[obs], ov, shape)
    t0 = time.perf_counter()
    res_w = cpd_als(t_obs, rank, kappa=KAPPA, n_iters=iters, tol=-1.0,
                    check_every=5, method="masked", weights=w)
    wall = time.perf_counter() - t0
    res_u = cpd_als(t_obs, rank, kappa=KAPPA, n_iters=iters, tol=-1.0,
                    check_every=5, method="masked")
    truth = vals[held]
    rel_w = float(np.linalg.norm(res_w.reconstruct_at(coords[held]) - truth)
                  / max(np.linalg.norm(truth), 1e-12))
    rel_u = float(np.linalg.norm(res_u.reconstruct_at(coords[held]) - truth)
                  / max(np.linalg.norm(truth), 1e-12))
    return {"name": "methods/masked/weighted-completion", "method": "masked",
            "shape": shape, "observed": int(half),
            "downweighted": int(noisy.sum()), "wall_s": wall,
            "fit": res_w.fits[-1], "heldout_rel_err_weighted": rel_w,
            "heldout_rel_err_uniform": rel_u,
            "err_ratio_uniform_over_weighted": rel_u / max(rel_w, 1e-12)}


def bench_mixed_stream(shape, nnz, n_each, iters, rank, rounds) -> dict:
    """``rounds`` waves of the same request mix: round 1 compiles one
    executable per method bucket, every later round must reuse them —
    the steady-state ``cache_hit_rate`` is (rounds-1)/rounds by
    construction and CI pins it >= 0.8 so the retrace regression can
    never silently return."""
    svc = DecompositionService(rank=rank, kappa=KAPPA, max_batch=4,
                               max_wait_s=10.0)
    futs = []
    t0 = time.perf_counter()
    for r in range(rounds):
        for i in range(n_each):
            t = random_sparse(shape, nnz - 11 * i, seed=100 * r + i,
                              distribution="powerlaw")
            t_pos = SparseTensor(t.indices, np.abs(t.values) + 0.1, t.shape)
            futs.append(svc.submit(t, n_iters=iters, tol=-1.0, seed=i))
            futs.append(svc.submit(t_pos, n_iters=iters, tol=-1.0, seed=i,
                                   method="nncp"))
            futs.append(svc.submit(t, n_iters=iters, tol=-1.0, seed=i,
                                   method="masked"))
        # Drain per round: deterministic per-method batches of n_each.
        svc.drain()
    for f in futs:
        f.result()
    wall = time.perf_counter() - t0
    snap = svc.snapshot()
    return {"name": "methods/mixed-stream", "requests": len(futs),
            "rounds": rounds,
            "wall_s": wall, "batches": snap["batches"],
            "padding_overhead": snap["padding_overhead"],
            "cache_hit_rate": snap["cache_hit_rate"],
            "density_tracked_buckets": snap["density_tracked_buckets"]}


def bench_streaming(shape, rank, n_start, inc_size, n_increments,
                    refine_iters, cold_iters) -> dict:
    """One runner-routed session, many small increments — the steady
    state the bucket quantization buys.  ``n_start + inc_size *
    n_increments`` is chosen to stay within the start's geometric session
    cap, so EVERY increment reuses the cold start's executable (cap
    crossings are the rare, logarithmically-many exceptions and are
    exercised by the tests, not timed here); the only cache miss in the
    whole session is the cold start's first window.  Two speedups:

      * ``speedup_vs_refit``       — WARM cold-start refit of the union
        tensor vs one increment.  The honest steady-state comparison
        (both sides amortize compiles away); >= 1 means an increment is
        at least as cheap as redecomposing from scratch.
      * ``speedup_vs_retrace_refit`` — refit at a NOVEL nnz class with
        the compile included: what a pre-quantization session actually
        paid per increment (every union nnz was novel), i.e. the
        regression this PR removes."""
    coords, vals = _dense_low_rank(shape, rank, seed=5)
    rng = np.random.default_rng(6)
    perm = rng.permutation(len(coords))
    n_union = n_start + inc_size * n_increments
    t_full = SparseTensor(coords[perm[:n_union]], vals[perm[:n_union]],
                          shape)

    runner = ALSRunner(rank, kappa=1, check_every=4)
    s = runner.open_stream(refine_iters=refine_iters)
    s.start(SparseTensor(coords[perm[:n_start]], vals[perm[:n_start]],
                         shape),
            n_iters=cold_iters, tol=-1.0, seed=2)
    t0 = time.perf_counter()
    for k in range(n_increments):
        lo = n_start + k * inc_size
        sl = perm[lo:lo + inc_size]
        s.update(SparseTensor(coords[sl], vals[sl], shape))
    inc_wall = time.perf_counter() - t0
    s_per_inc = inc_wall / n_increments
    snap = runner.service.snapshot()

    # Warm refit baseline: same check window (the block length is part of
    # the executable key), same union nnz class.
    cpd_als(t_full, rank, kappa=1, n_iters=4, tol=-1.0, seed=2,
            check_every=4)
    t0 = time.perf_counter()
    ref = cpd_als(t_full, rank, kappa=1, n_iters=cold_iters, tol=-1.0,
                  seed=2, check_every=4)
    refit_wall = time.perf_counter() - t0

    # Retrace refit baseline: one entry fewer than the union — an nnz
    # this process has NEVER compiled, so the wall time includes the jit
    # retrace every pre-quantization increment paid.
    t_novel = SparseTensor(coords[perm[:n_union - 1]],
                           vals[perm[:n_union - 1]], shape)
    t0 = time.perf_counter()
    cpd_als(t_novel, rank, kappa=1, n_iters=cold_iters, tol=-1.0,
            seed=2, check_every=4)
    retrace_wall = time.perf_counter() - t0

    return {"name": "methods/streaming",
            "increments": n_increments,
            "refine_iters": refine_iters,
            "nnz_start": n_start, "nnz_final": s.tensor.nnz,
            "bucket_cap": s.bucket_cap,
            "evictions": s.evictions,
            "cache_hit_rate": snap["cache_hit_rate"],
            "s_per_increment": s_per_inc,
            "host_merge_s": s.merge_seconds,
            "increment_wall_s": inc_wall, "refit_wall_s": refit_wall,
            "retrace_refit_wall_s": retrace_wall,
            "speedup_vs_refit": refit_wall / max(s_per_inc, 1e-12),
            "speedup_vs_retrace_refit":
                retrace_wall / max(s_per_inc, 1e-12),
            "stream_fit": s.fit, "refit_fit": ref.fits[-1],
            "fit_gap": abs(s.fit - ref.fits[-1])}


def run(smoke: bool = False) -> list[dict]:
    if smoke:
        shape, nnz, iters, n_each, rounds = (18, 13, 9), 350, 4, 2, 6
        cshape, citers = (10, 8, 6), 30
        # start nnz 352 -> geometric session cap 432; 10 increments of 8
        # land exactly on 432, so the whole session shares ONE executable
        n_start, inc, n_inc, refine, cold = 352, 8, 10, 4, 32
    else:
        shape, nnz, iters, n_each, rounds = (64, 48, 32), 4000, 8, 4, 6
        cshape, citers = (14, 12, 10), 60
        # start nnz 1344 -> cap 1458; 11 increments of 10 stay within it
        n_start, inc, n_inc, refine, cold = 1344, 10, 11, 4, 32
    rows = bench_sequential(shape, nnz, iters, RANK)
    rows.append(bench_completion(cshape, 3, citers))
    rows.append(bench_weighted_completion(cshape, 3, citers))
    rows.append(bench_mixed_stream(shape, nnz, n_each, iters, RANK, rounds))
    rows.append(bench_streaming(cshape, 3, n_start, inc, n_inc, refine,
                                cold))
    return rows


def main(argv: list[str] | None = None):
    argv = sys.argv[1:] if argv is None else argv
    smoke = "--smoke" in argv
    rows = run(smoke=smoke)
    print("name,us_per_call,derived")
    print(f"methods/registered,0,{';'.join(list_methods())}")
    for r in rows:
        us = r.get("s_per_iter", r.get("wall_s", 0.0)) * 1e6
        derived = ";".join(f"{k}={v}" for k, v in r.items()
                           if k not in ("name", "shape"))
        print(f"{r['name']},{us:.0f},{derived}")
    return rows


if __name__ == "__main__":
    main()
