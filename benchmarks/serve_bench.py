"""Serving benchmark: batched decomposition service vs sequential runner.

Streams of Table-3-style requests (same shape family, nnz in one or a few
buckets — the serving scenario the fused engine was built for) are pushed
through both front doors:

  * sequential — ``ALSRunner(mode="sequential")``: one fused decomposition
    per request, executable reuse across the stream, but every request
    pays its own dispatch chain and result materialization.
  * batched    — the ``repro.serve`` service: requests are bucketed,
    padded, stacked B-high, and each ``check_every`` window of the whole
    batch is ONE vmapped dispatch.

Reported per stream: decompositions/sec for both paths, the throughput
ratio, padding overhead, batch occupancy, p50/p99 latency, and the
executable-cache hit rate.  Two stream flavors:

  * ``uniform`` — constant nnz: sequential gets full executable reuse,
    so the ratio isolates the pure batching win;
  * ``jitter``  — nnz varies a few % request-to-request: the sequential
    path retraces per distinct nnz while the bucketed service pads every
    request into a shared executable — the bucketing win on top.

``--smoke`` shrinks everything for CI; the full run asserts the
acceptance bar (batched >= 2x sequential at B >= 8, padding < 15%).

Output: ``name,us_per_call,derived`` CSV like the other sections.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import random_sparse
from repro.obs import health as obs_health
from repro.runtime import ALSRunner
from repro.serve import BucketPolicy, DecompositionService

# Small rank + few-hundred nnz is the paper's overhead-dominated serving
# regime: one decomposition is mostly dispatch/transfer overhead, which is
# exactly what the batch amortizes.  (On a real accelerator the batch also
# parallelizes the compute; on CPU vmap serializes it, so these numbers
# are a lower bound on the batching win.)
RANK = 8
N_ITERS = 5
CHECK_EVERY = 5
MAX_BATCH = 8

# Small-tensor request classes: mode-count / dimension ratios follow
# Table-3 datasets (chicago 4-mode with tiny inner modes, uber 4-mode,
# nips-like 3-mode), nnz scaled to the overhead-dominated regime.
STREAM_SHAPES = {
    "chicago-like": ((128, 24, 77, 32), 500),
    "uber-like": ((60, 24, 160, 200), 500),
    "nips-like": ((180, 200, 400), 500),
}

# Deliberately loose SLOs: the benchmark's job is to witness that the
# live health evaluator runs against real serving gauges (the row
# carries the verdict), not to fail CI on a loaded box.  Tight targets
# belong in deployment configs.
SLO = obs_health.SLOPolicy(
    latency_p99_s=60.0,
    queue_depth=100_000,
    queue_age_s=600.0,
    cache_hit_rate_min=0.01,
    batch_occupancy_min=0.05,
    min_events=8,
)


def make_stream(shape, base_nnz, m, *, jitter=0.0, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for i in range(m):
        nnz = int(base_nnz * (1.0 - jitter * rng.random()))
        out.append(random_sparse(shape, nnz, seed=1000 + i,
                                 distribution="powerlaw"))
    return out


def bench_stream(name, stream, *, rank, n_iters, check_every, backend,
                 max_batch) -> dict:
    # -- sequential front door --------------------------------------------
    seq = ALSRunner(rank, backend=backend, mode="sequential",
                    check_every=check_every)
    seq.decompose(stream[0], n_iters=n_iters, tol=-1.0)        # warm-up
    t0 = time.perf_counter()
    for t in stream:
        seq.decompose(t, n_iters=n_iters, tol=-1.0)
    seq_s = time.perf_counter() - t0

    # -- batched service ---------------------------------------------------
    svc = DecompositionService(rank, backend=backend,
                               check_every=check_every, max_batch=max_batch,
                               max_wait_s=1e9, slo=SLO)
    # warm-up: compile each (bucket, B, window) class the stream will touch
    # with the SAME n_iters the timed run uses (window sizes are part of
    # the executable key)
    policy = svc.scheduler.policy
    for cap in sorted({policy.bucket_for(t).nnz_cap for t in stream}):
        grp = [t for t in stream if policy.bucket_for(t).nnz_cap == cap]
        svc.engine.decompose_batch(grp[:max_batch], n_iters=n_iters,
                                   tol=-1.0,
                                   seeds=list(range(len(grp[:max_batch]))),
                                   nnz_cap=cap)
    t0 = time.perf_counter()
    futs = [svc.submit(t, n_iters=n_iters, tol=-1.0) for t in stream]
    svc.drain()
    for f in futs:
        f.result()
    bat_s = time.perf_counter() - t0
    snap = svc.snapshot()

    # The static plan of the stream's dominant bucket (core.plan) — every
    # timed row names its slab cap / tile / rank block so perf shifts are
    # attributable to planning changes.
    caps = sorted({policy.bucket_for(t).nnz_cap for t in stream})
    bplan = svc.engine.bucket_plan(tuple(stream[0].shape), caps[-1])

    m = len(stream)
    return {
        "stream": name,
        "requests": m,
        "plan": bplan.describe(),
        "seq_rps": m / seq_s,
        "bat_rps": m / bat_s,
        "speedup": seq_s / max(bat_s, 1e-12),
        "padding_overhead": snap["padding_overhead"],
        "batch_occupancy": snap["batch_occupancy"],
        "latency_p50_s": snap["latency_p50_s"],
        "latency_p99_s": snap["latency_p99_s"],
        "cache_hit_rate": snap["cache_hit_rate"],
        "batches": snap["batches"],
        # Live snapshot gauges, verbatim — obs.report renders these as
        # dispatch/queue/health tables and the history ledger flattens
        # their scalar leaves into trend metrics.
        "dispatch": snap["dispatch"],
        "queue": snap["queue"],
        "streams": snap["streams"],
        "health": snap["health"],
    }


def run(*, smoke=False, backend="segment", max_batch=MAX_BATCH,
        rank=RANK) -> list[dict]:
    m = max_batch * (1 if smoke else 3)
    n_iters = 3 if smoke else N_ITERS
    rows = []
    shapes = dict(list(STREAM_SHAPES.items())[:1] if smoke
                  else STREAM_SHAPES.items())
    for name, (shape, nnz) in shapes.items():
        for flavor, jitter in (("uniform", 0.0), ("jitter", 0.05)):
            stream = make_stream(shape, nnz, m, jitter=jitter)
            rows.append(bench_stream(
                f"{name}/{flavor}", stream, rank=rank, n_iters=n_iters,
                check_every=CHECK_EVERY, backend=backend,
                max_batch=max_batch))
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny stream for CI (no acceptance assertions)")
    ap.add_argument("--no-check", action="store_true",
                    help="report only; skip the wall-clock acceptance "
                         "assertions (used by the aggregate benchmarks.run "
                         "so a loaded box cannot abort later sections)")
    ap.add_argument("--backend", default="segment",
                    choices=["segment", "coo", "pallas"])
    ap.add_argument("--max-batch", type=int, default=MAX_BATCH)
    ap.add_argument("--rank", type=int, default=RANK)
    args = ap.parse_args(argv)

    rows = run(smoke=args.smoke, backend=args.backend,
               max_batch=args.max_batch, rank=args.rank)
    print("name,us_per_call,derived")
    for r in rows:
        print(f"serve/{r['stream']}/sequential,"
              f"{1e6/max(r['seq_rps'],1e-12):.0f},"
              f"rps={r['seq_rps']:.2f}")
        print(f"serve/{r['stream']}/batched-B{args.max_batch},"
              f"{1e6/max(r['bat_rps'],1e-12):.0f},"
              f"rps={r['bat_rps']:.2f};speedup={r['speedup']:.2f}x;"
              f"pad={r['padding_overhead']*100:.1f}%;"
              f"occ={r['batch_occupancy']*100:.0f}%;"
              f"p50={r['latency_p50_s']*1e3:.0f}ms;"
              f"p99={r['latency_p99_s']*1e3:.0f}ms;"
              f"cache_hit={r['cache_hit_rate']*100:.0f}%;"
              f"plan={r['plan']}")
        h = r["health"]
        breaches = ";".join(f"{b['slo']}[{b['scope']}]"
                            for b in h["breaches"]) or "-"
        print(f"serve/{r['stream']}/health,0,"
              f"status={h['status']};checked={h['checked']};"
              f"breaches={breaches};"
              f"overlap={r['dispatch']['overlap_fraction']:.2f};"
              f"queue_peak={r['queue']['peak_depth']}")
    gmean = float(np.exp(np.mean([np.log(r["speedup"]) for r in rows])))
    worst_pad = max(r["padding_overhead"] for r in rows)
    print(f"serve/geomean-speedup,0,{gmean:.2f}x")
    print(f"serve/max-padding-overhead,0,{worst_pad*100:.1f}%")

    if not args.smoke and not args.no_check and args.max_batch >= 8:
        # Acceptance: batched >= 2x sequential on a Table-3-style
        # same-shape stream, padding < 15% under the default policy.
        best = max(r["speedup"] for r in rows)
        assert gmean >= 2.0, f"batched speedup {gmean:.2f}x < 2x"
        assert best >= 2.0, f"best stream speedup {best:.2f}x < 2x"
        assert worst_pad < 0.15, f"padding overhead {worst_pad:.2%} >= 15%"
    return rows


if __name__ == "__main__":
    main()
