"""Benchmark runner: one section per paper table/figure + kernel + roofline.

``PYTHONPATH=src python -m benchmarks.run``            — everything
``PYTHONPATH=src python -m benchmarks.run fig3 fig5``  — a subset
Output: ``name,us_per_call,derived`` CSV per section.
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    want = set(sys.argv[1:])

    def on(name):
        return not want or name in want

    sections = []
    if on("table3"):
        from . import table3_datasets
        sections.append(("table3 (dataset characteristics)", table3_datasets.main))
    if on("fig3"):
        from . import fig3_total_time
        sections.append(("fig3 (total execution time vs baselines)", fig3_total_time.main))
    if on("fig4"):
        from . import fig4_load_balance
        sections.append(("fig4 (adaptive load balancing ablation)", fig4_load_balance.main))
    if on("fig5"):
        from . import fig5_memory
        sections.append(("fig5 (memory consumption)", fig5_memory.main))
    if on("kernel"):
        from . import kernel_bench
        sections.append(("pallas kernel micro-bench", kernel_bench.main))
    if on("als"):
        from . import als_bench
        sections.append(("ALS engine (fused device-resident vs host loop)", als_bench.main))
    if on("serve"):
        from . import serve_bench
        # own argv: the runner's section args must not leak into
        # serve_bench's argparse, and its timing-dependent acceptance
        # assertions must not abort the remaining sections
        sections.append(("serving (batched service vs sequential runner)",
                         lambda: serve_bench.main(["--no-check"])))
    if on("dist"):
        from . import dist_bench
        # subprocess with forced host devices: jax pins its device count
        # at first init, so the 8-device mesh cannot share this process
        sections.append(("distributed ALS smoke (shard_map, 8 virtual devices)",
                         dist_bench.main))
    if on("roofline"):
        from . import roofline
        sections.append(("roofline table (from dry-run)", roofline.main))

    for title, fn in sections:
        print(f"\n===== {title} =====")
        t0 = time.time()
        fn()
        print(f"===== done in {time.time()-t0:.1f}s =====")


if __name__ == "__main__":
    main()
