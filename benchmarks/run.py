"""Benchmark runner: one section per paper table/figure + kernel + roofline
+ the system layers (ALS engines, serving, distributed, methods).

``PYTHONPATH=src python -m benchmarks.run``               — everything
``PYTHONPATH=src python -m benchmarks.run fig3 fig5``     — a subset
``PYTHONPATH=src python -m benchmarks.run methods --smoke`` — CI-sized

Output: ``name,us_per_call,derived`` CSV per section, plus one
machine-readable ``results/BENCH_<name>.json`` per section run —
{config, rows (with plan fingerprints where the section reports them),
wall time, timestamp} — so the perf trajectory is trackable across PRs
instead of living in scrollback.
"""
from __future__ import annotations

import json
import pathlib
import sys
import time

from repro.obs import clock as obs_clock
from repro.obs import history as obs_history

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[1] / "results"
HISTORY_PATH = RESULTS_DIR / "BENCH_history.jsonl"


def _clean(obj):
    if isinstance(obj, dict):
        return {str(k): _clean(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_clean(v) for v in obj]
    if hasattr(obj, "item"):          # numpy scalars
        return obj.item()
    if isinstance(obj, (str, int, float, bool)) or obj is None:
        return obj
    return repr(obj)


def emit_json(name: str, wall_s: float, rows, config: dict) -> pathlib.Path:
    """Write one section's machine-readable result file AND append the
    same (provenance-stamped) payload to the append-only history ledger.
    ``rows`` is the section's structured output (list of dicts) when it
    provides one, else None — wall time, config, and provenance are
    always recorded."""
    from . import common

    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    path = RESULTS_DIR / f"BENCH_{name}.json"
    prov = common.provenance()
    rows = _clean(rows)
    config = _clean(config)

    path.write_text(json.dumps({
        "name": name,
        "config": config,
        "wall_s": wall_s,
        "rows": rows,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "provenance": prov,
    }, indent=2) + "\n")
    record = obs_history.make_record(
        name, rows=rows if isinstance(rows, list) else None,
        wall_s=wall_s, config=config, provenance=prov)
    obs_history.append(HISTORY_PATH, record)
    return path


_SECTIONS = ("table3", "fig3", "fig4", "fig5", "kernel", "als", "serve",
             "methods", "dist", "pod", "roofline", "obs")
_FLAGS = ("--smoke",)

# The streaming row once buried a 370x retrace regression behind a bare
# speedup number.  These fields are the regression's witnesses (hit rate,
# per-increment cost, host merge cost); a methods run whose streaming row
# lacks any of them fails the whole runner loudly.
_STREAMING_REQUIRED = ("cache_hit_rate", "s_per_increment", "host_merge_s")


def _check_methods_rows(rows) -> None:
    streaming = [r for r in (rows or [])
                 if isinstance(r, dict)
                 and r.get("name") == "methods/streaming"]
    if not streaming:
        sys.exit("methods section produced no 'methods/streaming' row")
    missing = [f for f in _STREAMING_REQUIRED if f not in streaming[0]]
    if missing:
        sys.exit(f"methods/streaming row is missing required fields "
                 f"{missing}; present: {sorted(streaming[0])}")


# The obs section's witnesses: every backend row must carry a finite
# predicted-vs-observed ratio, every imbalance row the measured factor,
# and the ledger row must stay under its recompile ceiling (a fresh
# process traces each executable at most once — more is a retrace leak).
def _check_obs_rows(rows) -> None:
    rows = [r for r in (rows or []) if isinstance(r, dict)]
    ratio = [r for r in rows if r.get("section") == "ratio"]
    imb = [r for r in rows if r.get("section") == "imbalance"]
    ledger = [r for r in rows if r.get("section") == "ledger"]
    if not ratio or not imb or not ledger:
        sys.exit(f"obs section missing row kinds: ratio={len(ratio)} "
                 f"imbalance={len(imb)} ledger={len(ledger)}")
    for r in ratio:
        po = r.get("predicted_over_observed")
        if not isinstance(po, float) or not (po > 0.0):
            sys.exit(f"obs row {r.get('name')} has no positive "
                     f"predicted_over_observed (got {po!r})")
    for r in imb:
        if not isinstance(r.get("max_measured_imbalance"), float):
            sys.exit(f"obs row {r.get('name')} lacks "
                     f"max_measured_imbalance")
    led = ledger[0]
    traces, ceiling = led.get("traces"), led.get("expected_max_traces")
    if traces is not None and traces > ceiling:
        sys.exit(f"retrace ledger over ceiling: {traces} traces for "
                 f"{ceiling} executables — a jit cache is re-specializing")


# The pod section's witnesses: a multi-window run costs ONE dispatch
# (host_syncs == 1, exactly one pod.dispatch span), the double-buffered
# stream hid some host assembly behind device compute (overlap fraction
# > 0), and the pod-block executables stayed under the retrace ceiling.
def _check_pod_rows(rows) -> None:
    rows = [r for r in (rows or []) if isinstance(r, dict)]
    by_name = {r.get("name"): r for r in rows}
    disp = by_name.get("pod/one-dispatch")
    if not disp:
        sys.exit("pod section produced no 'pod/one-dispatch' row")
    if disp.get("pod_dispatch_spans") != 1 or disp.get("host_syncs") != 1:
        sys.exit(f"pod multi-window run was not one dispatch: {disp}")
    if not disp.get("windows", 0) > 1:
        sys.exit(f"pod dispatch ran {disp.get('windows')} windows — the "
                 f"one-dispatch witness needs a MULTI-window run")
    over = by_name.get("pod/overlap")
    if not over:
        sys.exit("pod section produced no 'pod/overlap' row")
    if not (isinstance(over.get("overlap_fraction"), float)
            and over["overlap_fraction"] > 0.0):
        sys.exit(f"double-buffered stream showed no assembly/compute "
                 f"overlap: {over}")
    agree = by_name.get("pod/agreement")
    if not agree or not agree.get("max_fit_err", 1.0) < 1e-3:
        sys.exit(f"pod vs single-device agreement failed: {agree}")
    lane = by_name.get("pod/lane-placement")
    if not lane:
        sys.exit("pod section produced no 'pod/lane-placement' row")
    if not (isinstance(lane.get("imbalance"), float)
            and lane["imbalance"] <= lane.get("imbalance_contiguous",
                                              0.0) + 1e-9):
        sys.exit(f"load-aware lane placement did not improve on the "
                 f"contiguous split: {lane}")
    led = by_name.get("pod/ledger")
    if not led:
        sys.exit("pod section produced no 'pod/ledger' row")
    traces, ceiling = led.get("traces"), led.get("expected_max_traces")
    if traces is not None and traces > ceiling:
        sys.exit(f"pod-block retrace ledger over ceiling: {traces} traces "
                 f"for {ceiling} executables")


def main() -> None:
    argv = sys.argv[1:]
    flags = {a for a in argv if a.startswith("--")}
    want = {a for a in argv if not a.startswith("--")}
    # A typo must fail loudly, not select zero sections and exit 0 green.
    unknown = sorted((want - set(_SECTIONS)) | (flags - set(_FLAGS)))
    if unknown:
        sys.exit(f"unknown section/flag {unknown}; sections: "
                 f"{', '.join(_SECTIONS)}; flags: {', '.join(_FLAGS)}")
    smoke = "--smoke" in flags

    def on(name):
        return not want or name in want

    # (name, title, fn) — fn returns structured rows or None.
    sections = []
    if on("table3"):
        from . import table3_datasets
        sections.append(("table3", "table3 (dataset characteristics)",
                         table3_datasets.main))
    if on("fig3"):
        from . import fig3_total_time
        sections.append(("fig3", "fig3 (total execution time vs baselines)",
                         fig3_total_time.main))
    if on("fig4"):
        from . import fig4_load_balance
        sections.append(("fig4", "fig4 (adaptive load balancing ablation)",
                         fig4_load_balance.main))
    if on("fig5"):
        from . import fig5_memory
        sections.append(("fig5", "fig5 (memory consumption)",
                         fig5_memory.main))
    if on("kernel"):
        from . import kernel_bench
        sections.append(("kernel", "pallas kernel micro-bench",
                         kernel_bench.main))
    if on("als"):
        from . import als_bench
        sections.append(("als", "ALS engine (fused device-resident vs "
                         "host loop)", als_bench.main))
    if on("serve"):
        from . import serve_bench
        # own argv: the runner's section args must not leak into
        # serve_bench's argparse, and its timing-dependent acceptance
        # assertions must not abort the remaining sections
        serve_args = ["--no-check"] + (["--smoke"] if smoke else [])
        sections.append(("serve", "serving (batched service vs sequential "
                         "runner)", lambda: serve_bench.main(serve_args)))
    if on("methods"):
        from . import methods_bench
        sections.append(("methods", "decomposition methods (nncp / masked "
                         "/ streaming / mixed-method service)",
                         lambda: methods_bench.main(
                             ["--smoke"] if smoke else [])))
    if on("dist"):
        from . import dist_bench
        # subprocess with forced host devices: jax pins its device count
        # at first init, so the 8-device mesh cannot share this process
        sections.append(("dist", "distributed ALS smoke (shard_map, 8 "
                         "virtual devices)", dist_bench.main))
    if on("pod"):
        from . import pod_bench
        # subprocess like dist: the 8-device batch mesh cannot share a
        # process whose jax already pinned its device count
        sections.append(("pod", "pod serving (mesh-sharded batch, "
                         "on-device convergence, double-buffered dispatch)",
                         lambda: pod_bench.main(["--smoke"] if smoke
                                                else [])))
    if on("roofline"):
        from . import roofline
        sections.append(("roofline", "roofline table (from dry-run)",
                         roofline.main))
    if on("obs"):
        from . import obs_bench
        sections.append(("obs", "observability (cost model vs measured, "
                         "trace artifacts)",
                         lambda: obs_bench.main(["--smoke"] if smoke
                                                else [])))

    for name, title, fn in sections:
        print(f"\n===== {title} =====")
        t0 = obs_clock.now()
        rows = fn()
        wall = obs_clock.now() - t0
        if name == "methods":
            _check_methods_rows(rows if isinstance(rows, list) else None)
        if name == "obs":
            _check_obs_rows(rows if isinstance(rows, list) else None)
        if name == "pod":
            _check_pod_rows(rows if isinstance(rows, list) else None)
        path = emit_json(name, wall, rows if isinstance(rows, list) else None,
                         {"argv": argv, "smoke": smoke})
        print(f"===== done in {wall:.1f}s -> {path.relative_to(path.parents[1])} =====")


if __name__ == "__main__":
    main()
