"""GPU-architectural cost model for spMTTKRP formats.

The paper's wins come from GPU mechanisms a CPU cannot exhibit (atomic
serialization, SM idling, L1-resident accumulators), so wall-clock on
this container inverts the published ordering.  This model prices each
format from MEASURED layout statistics — per-partition loads, per-row
conflict degrees, bytes moved — using RTX-3090-class constants, and is
the instrument used to compare against the paper's Fig. 3/4 ratios.
Every term is listed below; change the constants to re-price.

time(mode) = t_traffic + t_atomic + t_launch
  t_traffic = bytes_moved/BW * imbalance   (imbalance = max_load*kappa/total:
              SMs finish when the slowest partition finishes; scheme 1 on a
              mode with I_d < kappa leaves SMs idle -> imbalance > 1)
  t_atomic  = nnz*R atomic adds at ATOMIC_TPUT.  Local (L1) atomics cost
              LOCAL_FACTOR of global (paper's scheme-1 Local_Update);
              UNSORTED formats pay UNSORTED_FACTOR extra (random-address
              conflicts; sorted traversals stream each output line once).
  t_launch  = per-mode fixed cost (kernel scheduling).

The model reproduces the paper's adaptive-vs-forced-scheme ratios from
measured partitionings; absolute baseline gaps (ParTI/MM-CSF 8-9x) also
include those systems' implementation overheads (per-iteration resorts,
semi-sparse intermediates, kernel-launch storms) that a first-principles
traffic+atomics model deliberately does not invent — see EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import SparseTensor
from repro.core.load_balance import Scheme, partition_mode

BW = 936.2e9          # GDDR6X B/s (Table II)
ATOMIC_TPUT = 1.2e11  # global atomic adds/s across the device
LOCAL_FACTOR = 0.1    # L1/shared atomic cost vs global
UNSORTED_FACTOR = 2.0  # random-address atomic conflicts (unsorted COO)
LAUNCH = 2e-6         # s per mode sweep
KAPPA = 82
R = 32
F4 = 4                # fp32 bytes


@dataclasses.dataclass
class ModeCost:
    traffic_s: float
    atomic_s: float
    total_s: float
    bytes_moved: float
    imbalance: float


def _gather_bytes(t: SparseTensor, mode: int) -> float:
    """nnz reads + input-factor row gathers (all formats pay these)."""
    N = t.nmodes
    return t.nnz * (4 * N + 4) + t.nnz * (N - 1) * R * F4


def _atomic_cost(nnz_updates: float, I_d: int, *, local: bool,
                 kappa: int = KAPPA, unsorted: bool = False) -> float:
    c = nnz_updates * R / ATOMIC_TPUT
    if local:
        return c * LOCAL_FACTOR
    return c * (UNSORTED_FACTOR if unsorted else 1.0)


def mode_cost(t: SparseTensor, mode: int, fmt: str, *,
              scheme: Scheme | None = None, kappa: int = KAPPA) -> ModeCost:
    deg = t.mode_degrees(mode)
    max_deg = float(deg.max()) if len(deg) else 0.0
    I_d = t.shape[mode]
    base_bytes = _gather_bytes(t, mode)
    out_bytes = I_d * R * F4

    if fmt == "ours":
        sch = scheme or (Scheme.INDEX_PARTITION if I_d >= kappa
                         else Scheme.NNZ_PARTITION)
        part = partition_mode(t, mode, kappa, scheme=sch)
        imb = part.imbalance()
        bytes_moved = base_bytes + out_bytes
        if sch == Scheme.INDEX_PARTITION:
            # partition-private rows: L1-resident accumulators, no global
            # atomics (sorted segmented update)
            atomic = _atomic_cost(t.nnz, I_d, local=True, kappa=kappa)
        else:
            # shared rows: global atomics, but perfectly balanced nnz
            atomic = _atomic_cost(t.nnz, I_d, local=False, kappa=kappa)
    elif fmt == "naive-coo":
        # ParTI-like: materialized (nnz, R) KRP intermediate (write+read) +
        # global atomic RMW on the output
        part = partition_mode(t, mode, kappa, scheme=Scheme.NNZ_PARTITION)
        imb = part.imbalance()
        bytes_moved = base_bytes + out_bytes + 2 * t.nnz * R * F4 \
            + 2 * t.nnz * R * F4
        atomic = _atomic_cost(t.nnz, I_d, local=False, kappa=kappa,
                              unsorted=True)
    elif fmt == "csf-like":
        # MM-CSF-like: fused+fiber-local for its ONE sorted mode, global
        # atomics when traversing in the wrong mode order
        fused = mode == 0
        part = partition_mode(t, mode, kappa, scheme=Scheme.NNZ_PARTITION)
        imb = part.imbalance()
        bytes_moved = base_bytes + out_bytes + (0 if fused else t.nnz * R * F4)
        atomic = _atomic_cost(t.nnz, I_d, local=fused, kappa=kappa,
                              unsorted=not fused)
    elif fmt == "blco-like":
        # BLCO: single linearized copy (8B keys), on-the-fly unpack, block
        # conflict resolution ~ hierarchical atomics (between local/global)
        part = partition_mode(t, mode, kappa, scheme=Scheme.NNZ_PARTITION)
        imb = part.imbalance()
        bytes_moved = t.nnz * 8 + t.nnz * (t.nmodes - 1) * R * F4 + out_bytes \
            + t.nnz * R * F4 * 0.5
        atomic = 0.6 * _atomic_cost(t.nnz, I_d, local=False, kappa=kappa)
    else:
        raise ValueError(fmt)

    traffic = bytes_moved / BW * imb
    total = traffic + atomic + LAUNCH
    return ModeCost(traffic, atomic, total, bytes_moved, imb)


def total_cost(t: SparseTensor, fmt: str, *, scheme=None, kappa=KAPPA) -> float:
    return sum(
        mode_cost(t, d, fmt, scheme=scheme, kappa=kappa).total_s
        for d in range(t.nmodes)
    )
