"""Generate EXPERIMENTS.md from collected results JSON."""
import json

BASE = json.load(open("results/dryrun_baseline.json"))
try:
    P1 = json.load(open("results/perf_iterations.json"))
except FileNotFoundError:
    P1 = []
try:
    P2 = json.load(open("results/perf_iterations2.json"))
except FileNotFoundError:
    P2 = []


def cell(arch, shape, mesh="16x16", rows=BASE):
    for r in rows:
        if (r.get("arch"), r.get("shape"), r.get("mesh")) == (arch, shape, mesh):
            return r
    return None


def row_md(r):
    if "skipped" in r:
        reason = ("needs sub-quadratic attention — pure full-attention arch"
                  if "sub-quadratic" in r["skipped"] else r["skipped"][:50])
        return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | — | — "
                f"| — | skip: {reason} |")
    t = r["roofline"]
    return ("| {a} | {s} | {m} | {tc:.2e} | {tm:.2e} | {tl:.2e} | {dom} | "
            "{ur:.2f} | {fits} | {note} |").format(
        a=r["arch"], s=r["shape"], m=r["mesh"], tc=t["t_compute_s"],
        tm=t["t_memory_s"], tl=t["t_collective_s"], dom=t["dominant"],
        ur=r["useful_flop_ratio"] or 0,
        fits="Y" if r["fits_hbm"] else "N",
        note=f"compile {r['compile_seconds']}s")


def iter_row(r, base):
    if "error" in r:
        return f"| {r['iteration']} | ERROR {r['error'][:50]} | | | | |"
    t, bt = r["roofline"], base["roofline"]
    def cmp(a, b):
        return f"{a:.3g} ({b/a:.1f}x)" if a and b else f"{a:.3g}"
    return ("| {i} | {tc} | {tm} | {tl} | {bound} | fits={f}, state {st:.2e} |"
            .format(i=r["iteration"],
                    tc=cmp(t["t_compute_s"], bt["t_compute_s"]),
                    tm=cmp(t["t_memory_s"], bt["t_memory_s"]),
                    tl=cmp(t["t_collective_s"], bt["t_collective_s"]),
                    bound=cmp(t["bound_step_s"], bt["bound_step_s"]),
                    f="Y" if r["fits_hbm"] else "N",
                    st=r["state_bytes_per_device"]))


ok = [r for r in BASE if "skipped" not in r and "error" not in r]
skips = [r for r in BASE if "skipped" in r]
fails = [r for r in BASE if "error" in r]

doc = []
doc.append("""# EXPERIMENTS

All numbers in this file are produced by code in this repository:
`python -m repro.launch.dryrun --all` (dry-run/roofline),
`python -m benchmarks.run` (paper figures), and
`results/hillclimb*.py` (§Perf iterations).  Container is CPU-only; TPU
v5e is the modeled target (197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI per chip).

## §Paper-claims validation (the faithful reproduction)

The paper evaluates pure kernel speedup on an RTX 3090.  This container
has no GPU, so three instruments are used (benchmarks/):

All six FROSTT datasets of Table III run (synthetic stand-ins with exact
small-mode dimensions and power-law fiber skew; nnz scaled for CI).

| claim (paper) | instrument | result |
|---|---|---|
| adaptive LB beats scheme-1-only, geomean 2.2x (Fig 4) | device cost model over measured partitionings | **2.17x** ✓ |
| adaptive LB beats scheme-2-only, geomean 1.3x (Fig 4) | same | **1.10x** (direction ✓; gap analysed below) |
| 7.9x vs ParTI-like naive COO (Fig 3) | same | **2.34x** (direction ✓) |
| 8.9x vs MM-CSF-like (Fig 3) | same | **1.46x** (direction ✓) |
| 2.4x vs BLCO-like (Fig 3) | same | **1.02x** (parity; see below) |
| all tensor copies fit device memory (Fig 5) | analytic, full-scale FROSTT | ✓ all six datasets < 16 GB |
| mode-specific format removes intermediate traffic | traffic model | 1.9–2.3x fewer bytes than naive COO ✓ |
| >4-mode support (vs baselines' 4) | vast (5 modes) runs through all engines ✓ |

Why the absolute Fig-3 gaps are smaller than published: the cost model
prices only first-principles terms (traffic, imbalance, atomic
throughput).  The published 8-9x additionally contains the baselines'
implementation overheads (ParTI's semi-sparse intermediates and kernel
launches, MM-CSF's per-mode re-sorts, BLCO's conflict-resolution pass),
which we deliberately do not invent numbers for.  The scheme-2 gap
(1.10x vs 1.3x): our scaled tensors put several modes just above
I_d ~ kappa where the paper's threshold rule mispicks — fixed by the
beyond-paper cost-based selector (§Perf, +1.17x geomean).

CPU wall-clock of all four formats is also reported by
`benchmarks.run fig3` for transparency; on a CPU (no SMs, no atomics, no
L1-resident accumulators) the published ordering does not and should not
reproduce — the device model is the comparable instrument.

Correctness of the reproduction is pinned by tests: MTTKRP == dense
matricization oracle across modes/backends/schemes (incl. the Pallas
kernel in interpret mode), CPD-ALS fit -> 0.999 on fully-observed
low-rank tensors, Graham 4/3 bound holds for greedy scheme-1, and the
distributed shard_map engine equals the oracle for both schemes.
""")

doc.append(f"""## §Dry-run (multi-pod)

Meshes: single-pod (data=16, model=16) = 256 chips; multi-pod
(pod=2, data=16, model=16) = 512 chips.  Every (arch x shape x mesh)
cell is `jit(step).lower(...).compile()`-proofed with explicit
shardings; costs come from two small UNROLLED probe compiles
extrapolated affinely in depth (scan bodies are counted once by XLA
cost analysis — extrapolation validated against a fully-unrolled
internvl2 compile: collective bytes exact, FLOPs within ~11%,
conservative), plus an exact analytic correction for attention-chunk
scans.  Memory is reported from (a) XLA memory_analysis (per device)
and (b) exact sharded state bytes + an activation model.

**Result: {len(ok)}/80 cells compile and shard cleanly; {len(skips)} cells are
assignment-mandated skips (long_500k on pure full-attention archs);
{len(fails)} failures.**

Notable findings from the compiled HLO:
* GSPMD emits an "involuntary full rematerialization" warning for
  head-dim-sharded KV caches (contracting-dim sharding forces f32
  resharding copies) — diagnosed and fixed in §Perf iteration A4 by
  sequence-splitting the cache instead (flash-decoding layout).
* A globally-sorted MoE dispatch destroys batch sharding (GSPMD
  replicates expert GEMMs across the data axis; 5x FLOP inflation)
  — fixed before baselining by per-row dispatch (see models/mlp.py).
* decode_32k for qwen1.5-32b does not fit HBM at bf16 with batch-only
  cache sharding (344 GB/chip) — driven to fit in §Perf.
""")

doc.append("## §Roofline (baseline, all cells)\n")
doc.append("Terms are whole-step seconds per chip: compute = HLO_FLOPs /"
           " 197e12, memory = HLO_bytes / 819e9, collective = modeled ring"
           " wire bytes / 50e9.  `useful` = MODEL_FLOPS (6·N·D train /"
           " 2·N_active·D inference) / HLO_FLOPs.\n")
doc.append("| arch | shape | mesh | compute s | memory s | collective s | "
           "dominant | useful | fits | notes |")
doc.append("|---|---|---|---|---|---|---|---|---|---|")
for r in BASE:
    doc.append(row_md(r))

doc.append("""
Reading the table:
* **Every cell is memory-term dominated.**  Two causes: (i) XLA-CPU
  "bytes accessed" counts unfused op traffic (a TPU backend fuses
  elementwise chains into matmuls; the true memory term is lower), so
  the memory column is an upper bound; (ii) several cells have real
  memory pathologies that §Perf removes (f32 upcasts of whole KV caches,
  MoE dispatch buffers, unchunked f32 logits).
* `useful` ~ 0.75-0.80 for dense train cells is expected: remat=full
  re-executes the forward (8·N·D/6·N·D = 0.75) and causal attention is
  computed as full rectangles (2x) — both are explicit engineering
  choices visible to the model.
* decode cells have tiny useful ratios because decode FLOPs are
  dominated by attention over the cache (not in 2·N·D) plus dequant /
  cache-update traffic: decode is bandwidth-bound, as on real hardware.
* MoE archs: granite's fine-grained experts (d_ff=512) make dispatch
  traffic dominate (useful 0.22-0.41) — the paper-technique-representative
  pathology that §Perf attacks (its dispatch IS a scheme-2-style sparse
  mode contraction).
* whisper/hymba prefill carry the largest collective terms
  (TP all-reduces of (B,S,d) per layer + GSPMD reshards).
""")

perf_cells = [
    ("A", "qwen1.5-32b", "decode_32k",
     "worst roofline fraction; does not fit HBM at baseline"),
    ("B", "hymba-1.5b", "prefill_32k",
     "most collective-bound cell (t_coll/t_mem = 0.65)"),
    ("C", "granite-moe-1b-a400m", "train_4k",
     "paper-technique representative: fine-grained sparse dispatch"),
]
doc.append("""## §Perf (hillclimb: hypothesis -> change -> measure -> verdict)

Three cells selected per the assignment (worst fraction / most
collective-bound / most paper-representative), iterated until <5% gains.
Baselines = the §Roofline table above.  Ratios in parentheses are
improvement vs that cell's baseline.
""")
for tag, arch, shape, why in perf_cells:
    b = cell(arch, shape)
    doc.append(f"### Cell {tag}: {arch} x {shape} x 16x16 — {why}\n")
    doc.append("| iter | compute s | memory s | collective s | bound step s | state |")
    doc.append("|---|---|---|---|---|---|")
    t = b["roofline"]
    doc.append(f"| base | {t['t_compute_s']:.3g} | {t['t_memory_s']:.3g} | "
               f"{t['t_collective_s']:.3g} | {t['bound_step_s']:.3g} | "
               f"fits={'Y' if b['fits_hbm'] else 'N'}, state "
               f"{b['state_bytes_per_device']:.2e} |")
    for r in P1 + P2:
        if r.get("arch") == arch and r.get("shape") == shape:
            doc.append(iter_row(r, b))
    doc.append("")

doc.append(open("results/perf_narrative.md").read()
           if __import__("os").path.exists("results/perf_narrative.md") else "")

with open("EXPERIMENTS.md", "w") as f:
    f.write("\n".join(doc))
print("wrote EXPERIMENTS.md", len("\n".join(doc)), "chars")
