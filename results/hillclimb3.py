import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json, traceback
from repro.launch.dryrun import run_cell
ITERS = [
    ("C5", "granite-moe-1b-a400m", "train_4k", dict(
        extra_rules=dict(seq_act="model"),
        overrides=dict(moe_dense_eval=True, loss_chunk=1024, remat="dots"))),
    ("C6", "granite-moe-1b-a400m", "train_4k", dict(
        extra_rules=dict(seq_act="model"),
        overrides=dict(moe_dense_eval=True, loss_chunk=1024, remat="none"))),
]
out = []
for tag, arch, shape, kw in ITERS:
    try:
        r = run_cell(arch, shape, multi_pod=False, **kw)
        r["iteration"] = tag
        t = r["roofline"]
        print(f"[{tag}] {arch} {shape}: tc={t['t_compute_s']:.3e} "
              f"tm={t['t_memory_s']:.3e} tl={t['t_collective_s']:.3e} "
              f"fits={r['fits_hbm']} state={r['state_bytes_per_device']:.3e} "
              f"act={r['activation_bytes_per_device_est']:.3e} "
              f"mfu_ub={r['mfu_upper_bound']:.4f}", flush=True)
    except Exception as e:
        r = {"iteration": tag, "arch": arch, "shape": shape,
             "error": f"{type(e).__name__}: {e}"}
        print(f"[{tag}] FAIL: {r['error']}", flush=True)
    out.append(r)
    json.dump(out, open("results/perf_iterations3.json", "w"), indent=1)
print("DONE")
