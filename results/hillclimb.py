import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json, traceback
from repro.launch.dryrun import run_cell

ITERS = [
    # Cell A: qwen1.5-32b decode_32k (worst roofline fraction / doesn't fit)
    ("A1", "qwen1.5-32b", "decode_32k", dict(
        kv_model_axis=True,
        extra_rules=dict(kv_heads="model", kv_hd="model"))),
    ("A2", "qwen1.5-32b", "decode_32k", dict(
        kv_model_axis=True, quant_kv=True,
        extra_rules=dict(kv_heads="model", kv_hd="model"))),
    ("A3", "qwen1.5-32b", "decode_32k", dict(
        kv_model_axis=True, quant_kv=True,
        extra_rules=dict(kv_heads="model", kv_hd="model"),
        overrides=dict(attn_bf16_dot=True))),
    # Cell B: hymba-1.5b prefill_32k (most collective-bound)
    ("B1", "hymba-1.5b", "prefill_32k", dict(
        extra_rules=dict(fsdp=("data", "model"), tensor=None,
                         experts=None, vocab=None))),
    ("B2", "hymba-1.5b", "prefill_32k", dict(
        extra_rules=dict(fsdp=("data", "model"), tensor=None,
                         experts=None, vocab=None),
        overrides=dict(attn_bf16_dot=True))),
    # Cell C: granite-moe train_4k (dispatch-bound fine-grained MoE —
    # the paper-technique-representative sparse-dispatch cell)
    ("C1", "granite-moe-1b-a400m", "train_4k", dict(
        overrides=dict(moe_dense_eval=True))),
    ("C2", "granite-moe-1b-a400m", "train_4k", dict(
        overrides=dict(moe_dense_eval=True, loss_chunk=1024))),
    ("C3", "granite-moe-1b-a400m", "train_4k", dict(
        overrides=dict(moe_dense_eval=True, loss_chunk=1024,
                       attn_bf16_dot=True))),
]

out = []
for tag, arch, shape, kw in ITERS:
    try:
        r = run_cell(arch, shape, multi_pod=False, **kw)
        r["iteration"] = tag
        t = r["roofline"]
        print(f"[{tag}] {arch} {shape}: tc={t['t_compute_s']:.3e} "
              f"tm={t['t_memory_s']:.3e} tl={t['t_collective_s']:.3e} "
              f"dom={t['dominant']} fits={r['fits_hbm']} "
              f"state={r['state_bytes_per_device']:.3e} "
              f"mfu_ub={r['mfu_upper_bound']:.4f}", flush=True)
    except Exception as e:
        r = {"iteration": tag, "arch": arch, "shape": shape,
             "error": f"{type(e).__name__}: {e}",
             "traceback": traceback.format_exc()[-1500:]}
        print(f"[{tag}] FAIL: {r['error']}", flush=True)
    out.append(r)
    with open("results/perf_iterations.json", "w") as f:
        json.dump(out, f, indent=1)
print("DONE")
