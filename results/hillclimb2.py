import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json, traceback
from repro.launch.dryrun import run_cell

ITERS = [
    # A4: flash-decoding seq-split cache (bf16), rules: seq->model
    ("A4", "qwen1.5-32b", "decode_32k", dict(
        kv_seq_model=True,
        extra_rules=dict(seq="model"),
        overrides=dict(attn_bf16_dot=True))),
    # A5: + int8 cache
    ("A5", "qwen1.5-32b", "decode_32k", dict(
        kv_seq_model=True, quant_kv=True,
        extra_rules=dict(seq="model"),
        overrides=dict(attn_bf16_dot=True))),
    # B3: Megatron-SP residual stream (keep TP), bf16 dots
    ("B3", "hymba-1.5b", "prefill_32k", dict(
        extra_rules=dict(seq_act="model"),
        overrides=dict(attn_bf16_dot=True))),
    # B4: SP + bf16 on the baseline TP WITHOUT bf16 flag, to isolate SP
    ("B4", "hymba-1.5b", "prefill_32k", dict(
        extra_rules=dict(seq_act="model"))),
    # C4: dense-eval + chunked CE + Megatron-SP residual
    ("C4", "granite-moe-1b-a400m", "train_4k", dict(
        extra_rules=dict(seq_act="model"),
        overrides=dict(moe_dense_eval=True, loss_chunk=1024))),
]
out = []
for tag, arch, shape, kw in ITERS:
    try:
        r = run_cell(arch, shape, multi_pod=False, **kw)
        r["iteration"] = tag
        t = r["roofline"]
        print(f"[{tag}] {arch} {shape}: tc={t['t_compute_s']:.3e} "
              f"tm={t['t_memory_s']:.3e} tl={t['t_collective_s']:.3e} "
              f"dom={t['dominant']} fits={r['fits_hbm']} "
              f"state={r['state_bytes_per_device']:.3e} "
              f"mfu_ub={r['mfu_upper_bound']:.4f}", flush=True)
    except Exception as e:
        r = {"iteration": tag, "arch": arch, "shape": shape,
             "error": f"{type(e).__name__}: {e}",
             "traceback": traceback.format_exc()[-1500:]}
        print(f"[{tag}] FAIL: {r['error']}", flush=True)
    out.append(r)
    with open("results/perf_iterations2.json", "w") as f:
        json.dump(out, f, indent=1)
print("DONE")
