"""Nonnegative CP (HALS) properties: factors provably >= 0 and fit
monotone nondecreasing per window — the method's two contracts — plus
equivalence between the sequential and batched front doors."""
import numpy as np
import pytest

from repro.core import SparseTensor, cpd_als, cpd_als_fused, random_sparse
from repro.serve import BatchedEngine

# Window-boundary float noise allowance for the monotonicity assertion
# (each HALS column update is an exact nonneg minimization in exact
# arithmetic; f32 accumulation can wobble in the last few ulps).
_MONO_SLACK = 1e-5


def _nonneg_tensor(shape, nnz, seed):
    t = random_sparse(shape, nnz, seed=seed, distribution="powerlaw")
    return SparseTensor(t.indices, np.abs(t.values) + 0.1, t.shape)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
@pytest.mark.parametrize("backend", ["segment", "coo"])
def test_factors_nonnegative_and_fit_monotone(seed, backend):
    t = _nonneg_tensor((16, 12, 9), 380, seed)
    res = cpd_als(t, 4, n_iters=8, tol=-1.0, check_every=2, seed=seed,
                  backend=backend, method="nncp")
    for F in res.factors:
        assert (F >= 0.0).all(), "HALS produced a negative factor entry"
    assert (res.weights >= 0.0).all()
    for a, b in zip(res.fits, res.fits[1:]):
        assert b >= a - _MONO_SLACK, (a, b)


def test_pallas_backend_nonneg_and_matches_segment():
    t = _nonneg_tensor((16, 12, 9), 380, 5)
    seg = cpd_als(t, 4, n_iters=4, tol=-1.0, check_every=2, method="nncp")
    pal = cpd_als(t, 4, n_iters=4, tol=-1.0, check_every=2, method="nncp",
                  backend="pallas")
    for F in pal.factors:
        assert (F >= 0.0).all()
    np.testing.assert_allclose(pal.fits, seg.fits, rtol=1e-5, atol=1e-5)


def test_monotone_on_four_mode_tensor():
    t = _nonneg_tensor((9, 8, 7, 6), 320, 7)
    res = cpd_als(t, 3, n_iters=6, tol=-1.0, check_every=3, method="nncp")
    for a, b in zip(res.fits, res.fits[1:]):
        assert b >= a - _MONO_SLACK
    for F in res.factors:
        assert (F >= 0.0).all()


def test_batched_nncp_matches_sequential():
    """One vmapped dispatch over B nonneg decompositions == B sequential
    fused nncp runs (same seeds) to fp32 tolerance, and every batched
    factor stays nonnegative."""
    ts = [_nonneg_tensor((16, 12, 9), 380 - 13 * i, 10 + i)
          for i in range(3)]
    eng = BatchedEngine(rank=4, kappa=2, backend="segment", check_every=2)
    batch = eng.decompose_batch(ts, n_iters=4, tol=-1.0, seeds=[4, 5, 6],
                                nnz_cap=384, method="nncp")
    for i, t in enumerate(ts):
        ref = cpd_als_fused(t, 4, kappa=2, n_iters=4, tol=-1.0, seed=4 + i,
                            backend="segment", check_every=2, method="nncp")
        np.testing.assert_allclose(batch[i].fits, ref.fits,
                                   rtol=1e-5, atol=1e-5)
        for Fb, Fr in zip(batch[i].factors, ref.factors):
            assert (Fb >= 0.0).all()
            np.testing.assert_allclose(Fb, Fr, rtol=1e-4, atol=1e-4)


def test_nonneg_init_is_nonneg():
    from repro.methods.nncp import init_state_host_nonneg

    factors, grams, weights = init_state_host_nonneg((11, 7, 5), 4, 3)
    for F in factors:
        assert (F > 0.0).all()
    assert (weights == 1.0).all()
