"""Property tests for per-entry observation weights (the CP-WOPT-style
front door): weight-0 entries are EXACTLY absent, all-ones weights are
exactly the unweighted masked path, rescaling the weight vector leaves
the argmin invariant, and nnz padding stays exact for weighted buckets.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import SparseTensor, cpd_als, cpd_als_fused, random_sparse
from repro.serve import BatchedEngine

SHAPE = (16, 12, 9)


def _weighted_tensor(nnz, seed):
    t = random_sparse(SHAPE, nnz, seed=seed, distribution="powerlaw")
    w = (np.random.default_rng(seed + 100)
         .uniform(0.25, 1.75, t.nnz).astype(np.float32))
    return t, w


# ---------------------------------------------------------------------------
# weight-0 entry == entry absent (bit-identical factors)
# ---------------------------------------------------------------------------


def _weight0_equals_absent(nnz, seed, backend, ndrop):
    """Zeroing an entry's weight produces BIT-identical factors to
    deleting the entry: its residual is exactly +0.0 in the valued
    MTTKRP and the fit, and stable layout sorts keep every other entry's
    accumulation order."""
    t, w = _weighted_tensor(nnz, seed)
    drop = np.random.default_rng(seed).choice(t.nnz, size=ndrop,
                                              replace=False)
    keep = np.ones(t.nnz, bool)
    keep[drop] = False
    w0 = w.copy()
    w0[drop] = 0.0
    kw = dict(n_iters=4, tol=-1.0, check_every=2, method="masked",
              backend=backend)
    a = cpd_als(t, 3, weights=w0, **kw)
    t_red = SparseTensor(t.indices[keep], t.values[keep], t.shape)
    b = cpd_als(t_red, 3, weights=w[keep], **kw)
    for Fa, Fb in zip(a.factors, b.factors):
        assert np.array_equal(Fa, Fb), "factors not bit-identical"
    np.testing.assert_allclose(a.fits, b.fits, rtol=1e-6, atol=1e-7)


if HAVE_HYPOTHESIS:
    @settings(max_examples=8, deadline=None)
    @given(st.sampled_from([180, 300, 420]), st.integers(0, 4),
           st.sampled_from(["segment", "coo"]), st.integers(1, 24))
    def test_property_weight0_equals_absent(nnz, seed, backend, ndrop):
        _weight0_equals_absent(nnz, seed, backend, ndrop)
else:
    @pytest.mark.parametrize("nnz,seed,backend,ndrop",
                             [(180, 0, "segment", 1), (300, 2, "coo", 24),
                              (420, 4, "segment", 7), (300, 1, "coo", 12)])
    def test_property_weight0_equals_absent(nnz, seed, backend, ndrop):
        """Fixed-example fallback when hypothesis is unavailable."""
        _weight0_equals_absent(nnz, seed, backend, ndrop)


def test_weight0_equals_absent_pallas():
    """Same property through the slab-packed valued-scatter path — to
    fp32 tolerance rather than bitwise: deleting an interior entry shifts
    later entries into different slabs, so the kernel's per-tile matmuls
    reassociate (bit-identity is specific to APPENDED padding, which
    cannot move real entries)."""
    t, w = _weighted_tensor(300, 3)
    drop = np.random.default_rng(3).choice(t.nnz, size=9, replace=False)
    keep = np.ones(t.nnz, bool)
    keep[drop] = False
    w0 = w.copy()
    w0[drop] = 0.0
    kw = dict(n_iters=4, tol=-1.0, check_every=2, method="masked",
              backend="pallas")
    a = cpd_als(t, 3, weights=w0, **kw)
    t_red = SparseTensor(t.indices[keep], t.values[keep], t.shape)
    b = cpd_als(t_red, 3, weights=w[keep], **kw)
    for Fa, Fb in zip(a.factors, b.factors):
        np.testing.assert_allclose(Fa, Fb, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# all-ones weights == the unweighted masked path
# ---------------------------------------------------------------------------


def _ones_equals_none(nnz, seed, backend):
    t, _ = _weighted_tensor(nnz, seed)
    kw = dict(n_iters=4, tol=-1.0, check_every=2, method="masked",
              backend=backend)
    a = cpd_als(t, 3, weights=np.ones(t.nnz, np.float32), **kw)
    b = cpd_als(t, 3, **kw)
    for Fa, Fb in zip(a.factors, b.factors):
        assert np.array_equal(Fa, Fb)
    assert a.fits == b.fits


if HAVE_HYPOTHESIS:
    @settings(max_examples=6, deadline=None)
    @given(st.sampled_from([180, 300, 420]), st.integers(0, 4),
           st.sampled_from(["segment", "coo", "pallas"]))
    def test_property_ones_equals_unweighted(nnz, seed, backend):
        _ones_equals_none(nnz, seed, backend)
else:
    @pytest.mark.parametrize("nnz,seed,backend",
                             [(180, 0, "segment"), (300, 2, "coo"),
                              (420, 1, "pallas")])
    def test_property_ones_equals_unweighted(nnz, seed, backend):
        """Fixed-example fallback when hypothesis is unavailable."""
        _ones_equals_none(nnz, seed, backend)


# ---------------------------------------------------------------------------
# weight rescaling leaves the argmin invariant
# ---------------------------------------------------------------------------


def _low_rank_observed(shape, rank, seed, frac=0.6):
    rng = np.random.default_rng(seed)
    factors = [rng.standard_normal((I, rank)).astype(np.float32)
               for I in shape]
    full = np.einsum("ir,jr,kr->ijk", *factors)
    coords = np.indices(shape).reshape(len(shape), -1).T.astype(np.int32)
    obs = coords[rng.permutation(len(coords))[: int(len(coords) * frac)]]
    return SparseTensor(obs, full[tuple(obs.T)].astype(np.float32), shape)


def _rescaling_invariance(scale, seed):
    """``w`` and ``c*w`` define the same weighted LS objective up to a
    constant factor, so they share stationary points (the EM trajectory's
    RATE does depend on the scale — weights act as per-entry step sizes
    in the filled-tensor update — so the sharp testable form is
    fixed-point invariance): a converged solution under ``w`` stays put
    under ``c*w``, and the fit — whose numerator and denominator both
    scale by sqrt(c) — is unchanged."""
    from repro.core import state_from_factors

    t = _low_rank_observed((10, 8, 6), 2, seed)
    w = (np.random.default_rng(seed + 7)
         .uniform(0.5, 1.5, t.nnz).astype(np.float32))
    a = cpd_als(t, 2, weights=w, n_iters=150, tol=1e-9, check_every=10,
                method="masked", seed=1)
    assert a.fits[-1] > 0.99, f"reference run did not converge: {a.fits[-1]}"
    warm = state_from_factors(a.factors, a.weights)
    b = cpd_als(t, 2, weights=scale * w, n_iters=6, tol=-1.0,
                check_every=6, method="masked", init_state=warm)
    assert abs(a.fits[-1] - b.fits[-1]) < 1e-3, (a.fits[-1], b.fits[-1])
    ra, rb = a.reconstruct_at(t.indices), b.reconstruct_at(t.indices)
    rel = (np.linalg.norm(ra - rb)
           / max(np.linalg.norm(ra), 1e-12))
    assert rel < 1e-2, f"rescaled argmin drifted: rel={rel:.2e}"


if HAVE_HYPOTHESIS:
    @settings(max_examples=5, deadline=None)
    @given(st.sampled_from([0.25, 0.5, 2.0, 8.0]), st.integers(0, 3))
    def test_property_weight_rescaling_argmin_invariant(scale, seed):
        _rescaling_invariance(scale, seed)
else:
    @pytest.mark.parametrize("scale,seed",
                             [(0.25, 0), (0.5, 2), (2.0, 1), (8.0, 3)])
    def test_property_weight_rescaling_argmin_invariant(scale, seed):
        """Fixed-example fallback when hypothesis is unavailable."""
        _rescaling_invariance(scale, seed)


# ---------------------------------------------------------------------------
# padding invariance extends to weighted buckets
# ---------------------------------------------------------------------------


def _weighted_bucket_padding(nnz_list, cap, backend):
    """Batched bucket-mates with user weights + weight-0 nnz padding match
    their sequential weighted runs: padding appends weight-0 entries, the
    general exact-no-op mechanism."""
    ts, ws = [], []
    for i, nnz in enumerate(nnz_list):
        t, w = _weighted_tensor(nnz, i)
        ts.append(t)
        ws.append(w)
    eng = BatchedEngine(rank=3, kappa=2, backend=backend, check_every=2)
    batch = eng.decompose_batch(ts, n_iters=4, tol=-1.0,
                                seeds=list(range(7, 7 + len(ts))),
                                nnz_cap=cap, method="masked", weights=ws)
    for i, t in enumerate(ts):
        ref = cpd_als_fused(t, 3, kappa=2, n_iters=4, tol=-1.0, seed=7 + i,
                            backend="segment", check_every=2,
                            method="masked", weights=ws[i])
        np.testing.assert_allclose(batch[i].fits, ref.fits,
                                   rtol=1e-5, atol=1e-5)
        for Fb, Fr in zip(batch[i].factors, ref.factors):
            np.testing.assert_allclose(Fb, Fr, rtol=1e-4, atol=1e-4)


if HAVE_HYPOTHESIS:
    @settings(max_examples=5, deadline=None)
    @given(st.lists(st.sampled_from([180, 240, 300, 380]),
                    min_size=2, max_size=3),
           st.sampled_from(["segment", "coo"]))
    def test_property_weighted_bucket_padding_invariance(nnz_list, backend):
        _weighted_bucket_padding(nnz_list, 384, backend)
else:
    @pytest.mark.parametrize("nnz_list,backend",
                             [([180, 300, 380], "segment"),
                              ([240, 240], "coo"),
                              ([380, 180], "segment")])
    def test_property_weighted_bucket_padding_invariance(nnz_list, backend):
        """Fixed-example fallback when hypothesis is unavailable."""
        _weighted_bucket_padding(nnz_list, 384, backend)


# ---------------------------------------------------------------------------
# kernels layer: weights pack alongside values
# ---------------------------------------------------------------------------


def test_weighted_packing_roundtrip():
    """``pack_layout(weights=...)`` places each entry's weight at its
    value's slab slot (padding weight 0), and ``weighted_vals()`` equals
    weighting the values up front — one packed artifact serves both the
    weighted and unweighted kernels."""
    from repro.core.layout import build_mode_layout
    from repro.kernels import ops as kops

    t = random_sparse((30, 9, 7), 400, seed=6, distribution="powerlaw")
    w = (np.random.default_rng(0)
         .uniform(0.0, 2.0, t.nnz).astype(np.float32))
    lay = build_mode_layout(t, 0, 2)
    packed = kops.pack_layout(lay, block_rows=8, tile=64, weights=w)
    # Weights land at the same slots as their values.
    rebuilt = np.zeros_like(packed.wts_packed)
    rebuilt[0, packed.val_scatter] = w[lay.perm]
    np.testing.assert_array_equal(rebuilt, packed.wts_packed)
    # weighted_vals == packing pre-weighted values.
    pre = kops.pack_layout(lay, block_rows=8, tile=64)
    manual = np.zeros_like(pre.vals_packed)
    manual[0, pre.val_scatter] = (lay.values.astype(np.float32)
                                  * w[lay.perm])
    np.testing.assert_allclose(packed.weighted_vals(), manual,
                               rtol=1e-6, atol=1e-7)
    assert pre.wts_packed is None and pre.weighted_vals() is pre.vals_packed
    # The one-shot kernel entries consume the weighted values: a weighted
    # packing executes the weighted MTTKRP, matching the weighted COO
    # oracle (weight-0 entries vanish).
    import jax.numpy as jnp
    from repro.kernels import ref as kref

    factors = [jnp.asarray(np.random.default_rng(1)
                           .standard_normal((I, 4)).astype(np.float32))
               for I in t.shape]
    got = np.asarray(kops.mttkrp_packed_ref(
        packed, [factors[m] for m in packed.input_modes]))
    want = np.asarray(kref.mttkrp_coo(
        jnp.asarray(t.indices), jnp.asarray(t.values.astype(np.float32)),
        factors, 0, t.shape[0], entry_weights=jnp.asarray(w)))
    # packed output is in relabeled row space
    want_rel = want[lay.row_perm]
    np.testing.assert_allclose(got, want_rel, rtol=1e-4, atol=1e-5)
