"""Method registry: catalogue contents, routing errors, bucket keying."""
import pytest

from repro.methods import (MethodSpec, batchable_methods, get_method,
                           list_methods, register_method)
from repro.serve import BucketPolicy


def test_builtin_methods_registered():
    names = list_methods()
    for m in ("cp", "nncp", "masked", "streaming"):
        assert m in names


def test_batchable_excludes_stateful():
    bat = batchable_methods()
    assert "streaming" not in bat
    assert {"cp", "nncp", "masked"} <= set(bat)


def test_unknown_method_lists_options():
    with pytest.raises(KeyError, match="registered"):
        get_method("definitely-not-a-method")


def test_double_registration_rejected():
    with pytest.raises(ValueError, match="already registered"):
        register_method(MethodSpec(name="cp"))


def test_masked_spec_contract():
    spec = get_method("masked")
    assert spec.valued_mode_data and spec.weighted_fit
    assert spec.build_sweep is not None


def test_streaming_spec_is_stateful():
    spec = get_method("streaming")
    assert spec.stateful and spec.session_factory is not None
    assert spec.build_sweep is None


def test_buckets_key_on_method(rng):
    from repro.core import random_sparse

    policy = BucketPolicy()
    t = random_sparse((10, 8, 6), 200, seed=0)
    b_cp = policy.bucket_for(t)
    b_nn = policy.bucket_for(t, "nncp")
    assert b_cp != b_nn
    assert b_cp.shape == b_nn.shape and b_cp.nnz_cap == b_nn.nnz_cap
    assert b_cp.method == "cp" and b_nn.method == "nncp"
    assert b_nn.key == (b_nn.shape, b_nn.nnz_cap, "nncp")


def test_stateful_method_rejected_by_sweep_path():
    from repro.core import cpd_als, random_sparse

    t = random_sparse((10, 8, 6), 150, seed=0)
    with pytest.raises(ValueError, match="stateful"):
        cpd_als(t, 3, method="streaming", n_iters=2)


def test_host_engine_rejects_methods():
    from repro.core import cpd_als, random_sparse

    t = random_sparse((10, 8, 6), 150, seed=0)
    with pytest.raises(ValueError, match="fused"):
        cpd_als(t, 3, method="nncp", engine="host", n_iters=2)
