"""Streaming CP: k warm-started increments match a batch refit to fp32
tolerance, sessions are restartable/routable, and the inner method is
pluggable."""
import numpy as np
import pytest

from repro.core import SparseTensor, cpd_als, random_sparse
from repro.methods import StreamingCP
from repro.runtime import ALSRunner


def _dense_low_rank(shape, rank, seed):
    rng = np.random.default_rng(seed)
    factors = [rng.standard_normal((I, rank)).astype(np.float32)
               for I in shape]
    full = np.einsum("ir,jr,kr->ijk", *factors)
    coords = np.indices(shape).reshape(len(shape), -1).T.astype(np.int32)
    return coords, full.reshape(-1).astype(np.float32)


def test_streaming_matches_batch_refit():
    """After k increments the streamed decomposition matches a converged
    cold batch refit of the same union tensor to fp32 tolerance (fit and
    reconstruction at the observed coordinates — the factor-permutation-
    invariant comparison).  Seeds are pinned to convergent inits for both
    paths (CP-ALS swamps are a property of the problem, not the
    streaming machinery)."""
    shape, R = (12, 10, 8), 3
    coords, vals = _dense_low_rank(shape, R, seed=5)
    rng = np.random.default_rng(9)
    chunks = np.array_split(rng.permutation(len(coords)), 4)
    t_full = SparseTensor(coords, vals, shape)

    s = StreamingCP(R, refine_iters=8, check_every=4)
    s.start(SparseTensor(coords[chunks[0]], vals[chunks[0]], shape),
            n_iters=24, tol=-1.0, seed=2)
    for i, c in enumerate(chunks[1:]):
        # a slightly larger budget on the LAST fold (the union tensor is
        # final there) polishes to the refit's converged fit
        s.update(SparseTensor(coords[c], vals[c], shape),
                 refine_iters=16 if i == len(chunks) - 2 else None)
    assert s.increments == 3
    assert s.tensor.nnz == len(coords)

    ref = cpd_als(t_full, R, n_iters=48, tol=-1.0, check_every=4, seed=2)
    assert abs(s.fit - ref.fits[-1]) < 1e-4, (s.fit, ref.fits[-1])
    rec_s = s.result.reconstruct_at(coords)
    rec_b = ref.reconstruct_at(coords)
    for rec in (rec_s, rec_b):
        rel = np.linalg.norm(rec - vals) / np.linalg.norm(vals)
        assert rel < 1e-3, rel
    np.testing.assert_allclose(rec_s, rec_b, rtol=0, atol=1e-3)


def test_increment_is_cheaper_than_refit():
    """The per-increment iteration budget is refine_iters, not a full
    refit's n_iters — the entire point of the fold."""
    shape = (12, 10, 8)
    t = random_sparse(shape, 500, seed=1, distribution="powerlaw")
    s = StreamingCP(3, refine_iters=2, check_every=2)
    s.start(SparseTensor(t.indices[:300], t.values[:300], shape),
            n_iters=10, tol=-1.0)
    res = s.update(SparseTensor(t.indices[300:], t.values[300:], shape))
    assert res.iters == 2


def test_duplicate_coordinates_accumulate():
    """Streaming an increment that revisits existing coordinates ADDS
    values (the accumulation semantics of COO streams)."""
    shape = (8, 6, 5)
    t = random_sparse(shape, 100, seed=3)
    s = StreamingCP(2, refine_iters=1, check_every=1)
    s.start(t, n_iters=2, tol=-1.0)
    s.update(t)      # same coords again -> values double, nnz unchanged
    assert s.tensor.nnz == t.nnz
    np.testing.assert_allclose(
        np.sort(s.tensor.values), np.sort(2.0 * t.values), rtol=1e-6)


def test_streaming_through_runner_batched_service():
    """open_stream routes cold fit and warm refinements through the
    bucketed batched service; the warm state threads via init_state."""
    shape = (14, 10, 8)
    t = random_sparse(shape, 420, seed=4, distribution="powerlaw")
    runner = ALSRunner(3, kappa=2, check_every=2)
    assert runner.mode == "batched"
    s = runner.open_stream(refine_iters=3)
    s.start(SparseTensor(t.indices[:250], t.values[:250], shape),
            n_iters=6, tol=-1.0)
    fit0 = s.fit
    res = s.update(SparseTensor(t.indices[250:], t.values[250:], shape))
    assert res.engine == "batched"
    assert res.iters == 3
    assert len(runner.history) == 2         # cold fit + one refinement
    assert np.isfinite(fit0) and np.isfinite(s.fit)


def test_streaming_nonnegative_inner_method():
    """A streamed nonnegative decomposition stays nonnegative across
    increments (warm HALS preserves the invariant)."""
    shape = (10, 8, 6)
    t = random_sparse(shape, 300, seed=6)
    t = SparseTensor(t.indices, np.abs(t.values) + 0.1, shape)
    s = StreamingCP(3, method="nncp", refine_iters=3, check_every=1)
    s.start(SparseTensor(t.indices[:150], t.values[:150], shape),
            n_iters=5, tol=-1.0)
    s.update(SparseTensor(t.indices[150:], t.values[150:], shape))
    for F in s.result.factors:
        assert (F >= 0.0).all()


def test_update_threads_session_seed():
    """update() refines with the SESSION's start seed, not a hardcoded 0
    — restarted-vs-continuous sessions stay reproducible."""
    seen = []

    class Recorder(StreamingCP):
        def _fit(self, n_iters, tol, seed, init_state):
            seen.append(seed)
            return super()._fit(n_iters, tol, seed, init_state)

    s = Recorder(2, refine_iters=1, check_every=1)
    t = random_sparse((6, 5, 4), 40, seed=0)
    s.start(SparseTensor(t.indices[:25], t.values[:25], (6, 5, 4)),
            n_iters=2, tol=-1.0, seed=17)
    s.update(SparseTensor(t.indices[25:], t.values[25:], (6, 5, 4)))
    assert seen == [17, 17]


def test_update_before_start_raises():
    s = StreamingCP(3)
    with pytest.raises(RuntimeError, match="start"):
        s.update(random_sparse((5, 4, 3), 20, seed=0))


def test_shape_mismatch_raises():
    s = StreamingCP(3)
    s.start(random_sparse((5, 4, 3), 20, seed=0), n_iters=1, tol=-1.0)
    with pytest.raises(ValueError, match="shape"):
        s.update(random_sparse((5, 4, 4), 20, seed=0))


def test_streaming_wrapping_stateful_method_rejected():
    with pytest.raises(ValueError, match="sweep-based"):
        StreamingCP(3, method="streaming")
