"""Mixed-method serving: a stream of {cp, nncp, masked} requests batches
into method-keyed buckets and every result matches its sequential
single-tensor counterpart to fp32 tolerance — plus the row-density
feedback loop from serve.metrics into core.plan."""
import numpy as np

from repro.core import SparseTensor, cpd_als_fused, random_sparse
from repro.core import plan as plan_mod
from repro.serve import DecompositionService, ServiceMetrics


def _stream(shape, nnz, n=2):
    ts = [random_sparse(shape, nnz - 13 * i, seed=i,
                        distribution="powerlaw") for i in range(n)]
    pos = [SparseTensor(t.indices, np.abs(t.values) + 0.1, t.shape)
           for t in ts]
    return ts, pos


def test_mixed_stream_batches_per_method_and_matches_sequential():
    shape, nnz, R = (16, 12, 9), 380, 3
    ts, pos = _stream(shape, nnz)
    svc = DecompositionService(rank=R, kappa=2, max_batch=4, max_wait_s=60.0)

    futs = []
    for i, t in enumerate(ts):
        futs.append((svc.submit(t, n_iters=3, tol=-1.0, seed=i), "cp", t, i))
        futs.append((svc.submit(t, n_iters=3, tol=-1.0, seed=i,
                                method="masked"), "masked", t, i))
    for i, t in enumerate(pos):
        futs.append((svc.submit(t, n_iters=3, tol=-1.0, seed=i,
                                method="nncp"), "nncp", t, i))
    # Nothing flushed yet (long max_wait, under max_batch per bucket):
    # the three method classes queue independently.
    buckets = {f[0]._bucket for f in futs}
    assert {b.method for b in buckets} == {"cp", "masked", "nncp"}
    svc.drain()

    for fut, method, t, i in futs:
        res = fut.result()
        assert res.engine == "batched"
        ref = cpd_als_fused(t, R, kappa=2, n_iters=3, tol=-1.0, seed=i,
                            backend="segment", check_every=4, method=method)
        np.testing.assert_allclose(res.fits, ref.fits, rtol=1e-4, atol=1e-4)
        for Fb, Fr in zip(res.factors, ref.factors):
            np.testing.assert_allclose(Fb, Fr, rtol=1e-4, atol=1e-4)

    snap = svc.snapshot()
    assert snap["completed"] == len(futs)
    # One bucket class per (nnz-cap, method) combination was tracked.
    assert snap["density_tracked_buckets"] == len(buckets)


def test_methods_share_one_bucket_plan():
    """Different methods of one (shape, nnz_cap) class reuse the SAME
    cached PartitionPlan — the methods layer rides the planning layer,
    it does not fork it."""
    svc = DecompositionService(rank=3, kappa=2, max_batch=2,
                               max_wait_s=60.0)
    p1 = svc.engine.bucket_plan((16, 12, 9), 384)
    p2 = svc.engine.bucket_plan((16, 12, 9), 384)
    assert p1 is p2     # lru-cached identity


# -- row-density feedback (serve.metrics -> core.plan) ----------------------


def test_density_profile_reflects_skew():
    t_skew = random_sparse((256, 10, 8), 1500, seed=0,
                           distribution="powerlaw")
    t_unif = random_sparse((256, 10, 8), 1500, seed=0,
                           distribution="uniform")
    p_skew = plan_mod.density_profile(t_skew.indices, t_skew.shape, 0)
    p_unif = plan_mod.density_profile(t_unif.indices, t_unif.shape, 0)
    assert abs(sum(p_skew) - 1.0) < 1e-9
    # powerlaw concentrates mass in the hottest bin beyond uniform
    assert p_skew[0] > p_unif[0] + 0.05
    # descending-sorted: monotone nonincreasing bins
    assert all(a >= b - 1e-12 for a, b in zip(p_skew, p_skew[1:]))


def test_metrics_density_ewma_and_quantization():
    m = ServiceMetrics()
    key = ((16, 12, 9), 384, "cp")
    assert m.row_density(key) is None
    hot = tuple([1.0] + [0.0] * (plan_mod.DENSITY_BINS - 1))
    flat = tuple([1.0 / plan_mod.DENSITY_BINS] * plan_mod.DENSITY_BINS)
    m.record_density(key, (hot, flat, flat))
    d = m.row_density(key)
    assert d is not None and len(d) == 3
    assert d[0][0] == 1.0
    # EWMA moves toward a new observation; quantization keeps the value
    # on the 1/16 grid (hashable, bounded plan-cache churn).
    m.record_density(key, (flat, flat, flat))
    d2 = m.row_density(key)
    assert d2[0][0] < 1.0
    for mode_prof in d2:
        for x in mode_prof:
            assert abs(x * 16 - round(x * 16)) < 1e-9


def test_plan_bucket_accepts_observed_density():
    """A skewed observed profile changes the cost model's row_ptr (and may
    change the chosen tiling) but NEVER the validity envelope: slab_cap
    still bounds any member distribution."""
    shape, cap, rank = (2048, 24, 16), 4096, 16
    uniform = plan_mod.plan_bucket(shape, cap, rank, 1)
    hot = tuple([0.9] + [0.1 / (plan_mod.DENSITY_BINS - 1)]
                * (plan_mod.DENSITY_BINS - 1))
    flat = tuple([1.0 / plan_mod.DENSITY_BINS] * plan_mod.DENSITY_BINS)
    skewed = plan_mod.plan_bucket(shape, cap, rank, 1,
                                  density=(hot, flat, flat))
    for mp_u, mp_s in zip(uniform.modes, skewed.modes):
        # the cap formula is a pure function of the chosen tiling
        assert mp_s.slab_cap == plan_mod.slab_cap(
            mp_s.num_rows, cap, mp_s.block_rows, mp_s.tile)
        assert mp_u.nnz_cap == mp_s.nnz_cap == cap
    # same inputs -> same cached plan object (density part of the key)
    again = plan_mod.plan_bucket(shape, cap, rank, 1,
                                 density=(hot, flat, flat))
    assert again is skewed and skewed is not uniform


def test_scheduler_threads_density_into_engine(monkeypatch):
    """After the first flush of a bucket, subsequent flushes pass the
    observed (EWMA, quantized) density into the engine's bucket plan."""
    svc = DecompositionService(rank=3, kappa=2, max_batch=2,
                               max_wait_s=60.0)
    seen = []
    orig = svc.engine.prepare_batch

    def spy(tensors, **kw):
        seen.append(kw.get("density"))
        return orig(tensors, **kw)

    monkeypatch.setattr(svc.engine, "prepare_batch", spy)
    t = random_sparse((16, 12, 9), 380, seed=0, distribution="powerlaw")
    svc.submit(t, n_iters=2, tol=-1.0).result()
    svc.submit(t, n_iters=2, tol=-1.0).result()
    assert len(seen) == 2
    assert seen[0] is None                  # nothing observed yet
    assert seen[1] is not None              # fed back from flush #1
    assert len(seen[1]) == 3                # one profile per mode
    assert all(len(p) == plan_mod.DENSITY_BINS for p in seen[1])
