"""Masked/weighted CP completion: recovers a known low-rank tensor from
50% observed entries (held-out reconstruction — the figure of merit plain
CP cannot reach because it treats missing as zero), agrees across
backends, stays exact under serving nnz padding (weight-0 entries), and
matches the kernels-layer reference entry point."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import SparseTensor, cpd_als, cpd_als_fused, random_sparse
from repro.serve import BatchedEngine


def _low_rank_split(shape, rank, seed, observed_frac=0.5):
    """(observed tensor, held-out coords, held-out values, full values)."""
    rng = np.random.default_rng(seed)
    factors = [rng.standard_normal((I, rank)).astype(np.float32)
               for I in shape]
    full = np.einsum("ir,jr,kr->ijk", *factors)
    coords = np.indices(shape).reshape(len(shape), -1).T.astype(np.int32)
    perm = rng.permutation(len(coords))
    k = int(len(coords) * observed_frac)
    obs, held = coords[perm[:k]], coords[perm[k:]]
    t_obs = SparseTensor(obs, full[tuple(obs.T)].astype(np.float32), shape)
    return t_obs, held, full[tuple(held.T)].astype(np.float32)


def test_completion_recovers_heldout_entries():
    """EM masked CP from 50% observed entries of an exact rank-3 tensor
    reconstructs the UNOBSERVED half to small relative error; plain CP on
    the same data (missing treated as zero) cannot."""
    t_obs, held, truth = _low_rank_split((14, 12, 10), 3, seed=0)
    res = cpd_als(t_obs, 3, n_iters=60, tol=-1.0, check_every=5,
                  method="masked")
    pred = res.reconstruct_at(held)
    rel = np.linalg.norm(pred - truth) / np.linalg.norm(truth)
    assert rel < 0.05, f"held-out relative error {rel:.3f}"
    assert res.fits[-1] > 0.99

    plain = cpd_als(t_obs, 3, n_iters=60, tol=-1.0, check_every=5)
    rel_plain = (np.linalg.norm(plain.reconstruct_at(held) - truth)
                 / np.linalg.norm(truth))
    assert rel_plain > 10 * rel, (rel_plain, rel)


@pytest.mark.parametrize("backend", ["coo", "pallas"])
def test_backends_match_segment(backend):
    t = random_sparse((16, 12, 9), 380, seed=3, distribution="powerlaw")
    seg = cpd_als(t, 3, n_iters=5, tol=-1.0, check_every=2, method="masked")
    other = cpd_als(t, 3, n_iters=5, tol=-1.0, check_every=2,
                    method="masked", backend=backend)
    np.testing.assert_allclose(other.fits, seg.fits, rtol=1e-5, atol=1e-5)
    for Fa, Fb in zip(other.factors, seg.factors):
        np.testing.assert_allclose(Fa, Fb, rtol=1e-4, atol=1e-4)


def test_batched_masked_matches_sequential_with_padding():
    """Bucket-mates of DIFFERENT real nnz (so padding is actually
    exercised) match their sequential single-tensor runs: weight-0
    padding entries are exact no-ops for the masked objective."""
    ts = [random_sparse((16, 12, 9), 380 - 31 * i, seed=i,
                        distribution="powerlaw") for i in range(3)]
    eng = BatchedEngine(rank=3, kappa=2, backend="segment", check_every=2)
    batch = eng.decompose_batch(ts, n_iters=4, tol=-1.0, seeds=[7, 8, 9],
                                nnz_cap=384, method="masked")
    for i, t in enumerate(ts):
        ref = cpd_als_fused(t, 3, kappa=2, n_iters=4, tol=-1.0, seed=7 + i,
                            backend="segment", check_every=2,
                            method="masked")
        np.testing.assert_allclose(batch[i].fits, ref.fits,
                                   rtol=1e-5, atol=1e-5)
        for Fb, Fr in zip(batch[i].factors, ref.factors):
            np.testing.assert_allclose(Fb, Fr, rtol=1e-4, atol=1e-4)


def test_batched_masked_pallas_backend():
    ts = [random_sparse((16, 12, 9), 380 - 31 * i, seed=i,
                        distribution="powerlaw") for i in range(2)]
    eng = BatchedEngine(rank=3, kappa=2, backend="pallas", check_every=2)
    batch = eng.decompose_batch(ts, n_iters=3, tol=-1.0, seeds=[1, 2],
                                nnz_cap=512, method="masked")
    for i, t in enumerate(ts):
        ref = cpd_als_fused(t, 3, kappa=2, n_iters=3, tol=-1.0, seed=1 + i,
                            backend="segment", check_every=2,
                            method="masked")
        np.testing.assert_allclose(batch[i].fits, ref.fits,
                                   rtol=1e-4, atol=1e-4)


def test_masked_kernel_entry_point_matches_em_identity():
    """kernels.ref.mttkrp_masked_residual == MTTKRP of the EM-filled
    DENSE tensor (model + W*(X - model)) computed by the dense oracle."""
    from repro.kernels import ref as kref

    rng = np.random.default_rng(4)
    shape, R = (7, 6, 5), 3
    t = random_sparse(shape, 60, seed=4)
    factors = [rng.standard_normal((I, R)).astype(np.float32)
               for I in shape]
    weights = rng.uniform(0.5, 1.5, R).astype(np.float32)
    ew = np.ones(t.nnz, np.float32)

    got = np.asarray(kref.mttkrp_masked_residual(
        jnp.asarray(t.indices), jnp.asarray(t.values.astype(np.float32)),
        jnp.asarray(ew), [jnp.asarray(F) for F in factors],
        jnp.asarray(weights), 0, shape[0]))

    model = np.einsum("r,ir,jr,kr->ijk", weights, *factors)
    filled = model.copy()
    filled[tuple(t.indices.T)] = t.values   # W=1 on observed coords
    dense_t = SparseTensor(
        np.indices(shape).reshape(3, -1).T.astype(np.int32),
        filled.reshape(-1).astype(np.float32), shape)
    # MTTKRP(filled, 0) via the dense oracle, weights folded in afterwards.
    want = kref.mttkrp_dense(dense_t, factors, 0)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_val_scatter_roundtrip():
    """kernels.ops val_scatter places every layout-order value at its
    packed slot: scattering the layout values reproduces vals_packed."""
    from repro.core.layout import build_mode_layout
    from repro.kernels import ops as kops

    t = random_sparse((30, 9, 7), 400, seed=6, distribution="powerlaw")
    lay = build_mode_layout(t, 0, 2)
    packed = kops.pack_layout(lay, block_rows=8, tile=64)
    rebuilt = np.zeros_like(packed.vals_packed)
    rebuilt[0, packed.val_scatter] = lay.values.astype(np.float32)
    np.testing.assert_array_equal(rebuilt, packed.vals_packed)
