"""Zero-retrace streaming sessions: bucket-quantized padding is exact,
the incremental sorted merge is bit-identical to the naive history
re-sort, confidence-decay eviction equals fitting the surviving weighted
tensor, and checkpointed sessions continue identically to uninterrupted
ones."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import SparseTensor, cpd_als_fused, random_sparse
from repro.core.coo import _linearize
from repro.core.plan import session_cap
from repro.methods import StreamingCP
from repro.methods.streaming import _canonical, _merge_sorted
from repro.runtime import ALSRunner
from repro.serve.buckets import BucketPolicy, pad_weights

SHAPE = (10, 8, 6)


def _rand_coo(n, seed, shape=SHAPE):
    rng = np.random.default_rng(seed)
    idx = np.stack([rng.integers(0, s, n) for s in shape],
                   axis=1).astype(np.int32)
    vals = rng.standard_normal(n).astype(np.float32)
    return idx, vals


def _run_session(policy, backend, seed=3, method="cp", **kw):
    t = random_sparse(SHAPE, 130, seed=seed, distribution="powerlaw")
    if method == "nncp":
        t = SparseTensor(t.indices, np.abs(t.values) + 0.1, SHAPE)
    s = StreamingCP(3, method=method, backend=backend, refine_iters=2,
                    check_every=2, policy=policy, **kw)
    s.start(SparseTensor(t.indices[:70], t.values[:70], SHAPE),
            n_iters=4, tol=-1.0, seed=seed)
    s.update(SparseTensor(t.indices[70:105], t.values[70:105], SHAPE))
    s.update(SparseTensor(t.indices[105:], t.values[105:], SHAPE))
    return s


# ---------------------------------------------------------------------------
# bucket-quantized padding is exact
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["segment", "coo"])
@pytest.mark.parametrize("method", ["cp", "nncp"])
def test_quantized_increment_bit_identical(backend, method):
    """The padded (bucket-quantized) session produces BIT-identical
    factors to the unpadded (policy=None) session across increments:
    zero-valued origin padding is an exact no-op for every backend."""
    sq = _run_session("auto", backend, method=method)
    su = _run_session(None, backend, method=method)
    assert sq.bucket_cap > 0 and su.bucket_cap == 0
    for Fq, Fu in zip(sq.result.factors, su.result.factors):
        np.testing.assert_array_equal(Fq, Fu)
    np.testing.assert_array_equal(sq.result.weights, su.result.weights)


def test_quantized_increment_pallas_fp32():
    """Pallas reduces in a different (slab) order, so the quantized
    session matches the unquantized one to fp32 tolerance there."""
    sq = _run_session("auto", "pallas")
    su = _run_session(None, "pallas")
    for Fq, Fu in zip(sq.result.factors, su.result.factors):
        np.testing.assert_allclose(Fq, Fu, rtol=0, atol=1e-5)


def test_weighted_session_padding_exact():
    """A masked (weighted-fit) session pads weights with 0: the quantized
    weighted session bit-matches the unquantized one."""
    rng = np.random.default_rng(11)
    t = random_sparse(SHAPE, 120, seed=11)
    w = rng.uniform(0.3, 1.0, t.nnz).astype(np.float32)
    out = []
    for policy in ("auto", None):
        s = StreamingCP(3, method="masked", refine_iters=2, check_every=2,
                        policy=policy)
        s.start(SparseTensor(t.indices[:70], t.values[:70], SHAPE),
                n_iters=4, tol=-1.0, seed=1, weights=w[:70])
        s.update(SparseTensor(t.indices[70:], t.values[70:], SHAPE),
                 weights=w[70:])
        out.append(s)
    for Fq, Fu in zip(out[0].result.factors, out[1].result.factors):
        np.testing.assert_array_equal(Fq, Fu)


if HAVE_HYPOTHESIS:

    @settings(max_examples=15, deadline=None)
    @given(n_start=st.integers(20, 120), n_delta=st.integers(1, 80),
           seed=st.integers(0, 1000))
    def test_merge_matches_naive_dedup_property(n_start, n_delta, seed):
        """Property: the O(nnz+m) sorted merge of any delta into any
        session list is BITWISE the concat + stable-sort dedup of the
        union (keys, indices, values, and weights)."""
        ia, va = _rand_coo(n_start, seed)
        ib, vb = _rand_coo(n_delta, seed + 1)
        rng = np.random.default_rng(seed + 2)
        wa = rng.uniform(0.1, 2.0, n_start).astype(np.float32)
        wb = rng.uniform(0.1, 2.0, n_delta).astype(np.float32)
        ka, cia, cva, cwa = _canonical(ia, va, wa, SHAPE)
        kb, cib, cvb, cwb = _canonical(ib, vb, wb, SHAPE)
        got = _merge_sorted(ka, cia, cva, cwa, kb, cib, cvb, cwb)
        want = _canonical(np.concatenate([ia, ib]),
                          np.concatenate([va, vb]),
                          np.concatenate([wa, wb]), SHAPE)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(g, w)

    @settings(max_examples=10, deadline=None)
    @given(nnz=st.integers(30, 200), seed=st.integers(0, 1000))
    def test_quantized_padding_property(nnz, seed):
        """Property: for any session size, one quantized increment is
        bit-identical to the unpadded increment (segment backend)."""
        idx, vals = _rand_coo(nnz, seed)
        half = nnz // 2 + 1
        outs = []
        for policy in ("auto", None):
            s = StreamingCP(2, refine_iters=1, check_every=1,
                            policy=policy)
            s.start(SparseTensor(idx[:half], vals[:half], SHAPE),
                    n_iters=2, tol=-1.0, seed=seed)
            s.update(SparseTensor(idx[half:], vals[half:], SHAPE))
            outs.append(s)
        for Fq, Fu in zip(outs[0].result.factors, outs[1].result.factors):
            np.testing.assert_array_equal(Fq, Fu)


# ---------------------------------------------------------------------------
# incremental merge semantics
# ---------------------------------------------------------------------------


def test_session_tensor_is_canonical():
    """The session's tensor stays in linearized-key order across merges
    (the invariant the O(nnz+m) merge relies on)."""
    s = _run_session("auto", "segment")
    keys = _linearize(s.tensor.indices, SHAPE)
    assert np.all(np.diff(keys) > 0)        # strictly sorted = deduped too


def test_merge_empty_delta():
    s = StreamingCP(2, refine_iters=1, check_every=1)
    t = random_sparse(SHAPE, 50, seed=0)
    s.start(t, n_iters=2, tol=-1.0)
    nnz0 = s.tensor.nnz
    s.update(SparseTensor(np.zeros((0, 3), np.int32),
                          np.zeros(0, np.float32), SHAPE))
    assert s.tensor.nnz == nnz0 and s.increments == 1


# ---------------------------------------------------------------------------
# confidence-decay eviction
# ---------------------------------------------------------------------------


def test_eviction_matches_surviving_weighted_tensor():
    """Eviction property: after decayed-below-floor entries are dropped,
    the session state equals exactly the surviving entries and weights —
    and refitting the session is refitting that surviving weighted
    tensor (verified against a direct weighted fused fit from the same
    warm state)."""
    # Tiny min_cap so the first merge crosses a bucket boundary, and a
    # floor above one decay step (0.6 > 0.5^1) so that crossing actually
    # drops the start entries (refreshed-at-1.0 delta entries survive).
    policy = BucketPolicy(mode="geometric", growth=1.5, min_cap=8)
    decay, floor = 0.5, 0.6
    s = StreamingCP(2, method="masked", refine_iters=2, check_every=2,
                    policy=policy, decay=decay, weight_floor=floor)
    t = random_sparse(SHAPE, 60, seed=21)
    s.start(SparseTensor(t.indices[:30], t.values[:30], SHAPE),
            n_iters=3, tol=-1.0, seed=4)
    # Track the expected weighted set by hand.
    exp_k, exp_i, exp_v, exp_w = _canonical(
        t.indices[:30], t.values[:30],
        np.ones(30, np.float32), SHAPE)
    for lo, hi in ((30, 45), (45, 60)):
        d_idx, d_val = t.indices[lo:hi], t.values[lo:hi]
        exp_w = exp_w * np.float32(decay)
        dk, di, dv, dw = _canonical(d_idx, d_val,
                                    np.ones(hi - lo, np.float32), SHAPE)
        exp_k, exp_i, exp_v, exp_w = _merge_sorted(
            exp_k, exp_i, exp_v, exp_w, dk, di, dv, dw)
        if session_cap(len(exp_k), s.bucket_cap, policy) > s.bucket_cap:
            keep = exp_w >= np.float32(floor)
            exp_k, exp_i = exp_k[keep], exp_i[keep]
            exp_v, exp_w = exp_v[keep], exp_w[keep]
        s.update(SparseTensor(d_idx, d_val, SHAPE))
    assert s.evictions > 0
    np.testing.assert_array_equal(s.tensor.indices, exp_i)
    np.testing.assert_array_equal(s.tensor.values, exp_v)
    np.testing.assert_array_equal(s.session_weights, exp_w)
    # Refitting the session IS fitting the surviving weighted tensor.
    from repro.core.als_device import state_from_factors
    warm = state_from_factors(s.result.factors, s.result.weights)
    # The empty update decays weights once more before fitting (decay is
    # applied per update(), delta or not), so hand the direct fit the
    # same decayed weights; with identical tensor, weights, and warm
    # state the only remaining difference is the stream's weight-0
    # bucket padding, which is exact for masked (PR 5 property).
    res_direct = cpd_als_fused(
        SparseTensor(exp_i, exp_v, SHAPE), 2, n_iters=2, tol=-1.0,
        check_every=2, method="masked", init_state=warm,
        weights=exp_w * np.float32(decay))
    res_stream = s.update(SparseTensor(np.zeros((0, 3), np.int32),
                                       np.zeros(0, np.float32), SHAPE))
    for Fd, Fs in zip(res_direct.factors, res_stream.factors):
        np.testing.assert_allclose(Fd, Fs, rtol=0, atol=2e-5)


def test_no_eviction_without_floor():
    s = _run_session("auto", "segment", decay=0.5)
    assert s.evictions == 0
    assert s.session_weights is not None
    assert s.entry_weights is None          # cp: bookkeeping only


def test_decay_validation():
    with pytest.raises(ValueError, match="decay"):
        StreamingCP(2, decay=1.5)
    with pytest.raises(ValueError, match="weight_floor"):
        StreamingCP(2, weight_floor=-0.1)


# ---------------------------------------------------------------------------
# durable sessions
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip_matches_uninterrupted(tmp_path):
    """save -> restore -> update matches the uninterrupted session's
    update to fp32 tolerance (bitwise, in fact: the snapshot is the full
    host state — tensor, weights, factors, seed, decay clock, cap)."""
    t = random_sparse(SHAPE, 140, seed=31)
    s1 = StreamingCP(3, refine_iters=2, check_every=2, decay=0.9)
    s1.start(SparseTensor(t.indices[:80], t.values[:80], SHAPE),
             n_iters=4, tol=-1.0, seed=6)
    s1.update(SparseTensor(t.indices[80:110], t.values[80:110], SHAPE))
    s1.save(tmp_path / "sess")

    s2 = StreamingCP.restore(tmp_path / "sess")
    assert s2.increments == s1.increments
    assert s2.seed == s1.seed
    assert s2.bucket_cap == s1.bucket_cap
    r1 = s1.update(SparseTensor(t.indices[110:], t.values[110:], SHAPE))
    r2 = s2.update(SparseTensor(t.indices[110:], t.values[110:], SHAPE))
    assert abs(r1.fits[-1] - r2.fits[-1]) < 1e-6
    for F1, F2 in zip(r1.factors, r2.factors):
        np.testing.assert_allclose(F1, F2, rtol=0, atol=1e-6)


def test_checkpoint_weighted_roundtrip(tmp_path):
    """Weighted (masked) session state — including per-entry confidence
    weights — survives the roundtrip."""
    rng = np.random.default_rng(41)
    t = random_sparse(SHAPE, 100, seed=41)
    w = rng.uniform(0.2, 1.0, t.nnz).astype(np.float32)
    s1 = StreamingCP(2, method="masked", refine_iters=2, check_every=2)
    s1.start(SparseTensor(t.indices[:60], t.values[:60], SHAPE),
             n_iters=3, tol=-1.0, seed=2, weights=w[:60])
    s1.save(tmp_path / "w")
    s2 = StreamingCP.restore(tmp_path / "w")
    np.testing.assert_array_equal(s1.session_weights, s2.session_weights)
    r1 = s1.update(SparseTensor(t.indices[60:], t.values[60:], SHAPE),
                   weights=w[60:])
    r2 = s2.update(SparseTensor(t.indices[60:], t.values[60:], SHAPE),
                   weights=w[60:])
    for F1, F2 in zip(r1.factors, r2.factors):
        np.testing.assert_allclose(F1, F2, rtol=0, atol=1e-6)


def test_restore_rejects_foreign_checkpoint(tmp_path):
    from repro.checkpoint.manager import CheckpointManager
    mgr = CheckpointManager(str(tmp_path / "x"), async_save=False)
    mgr.save(0, {"a": np.zeros(3)}, extra={"kind": "other"}, block=True)
    with pytest.raises(ValueError, match="not a streaming session"):
        StreamingCP.restore(tmp_path / "x")


def test_save_before_start_raises(tmp_path):
    with pytest.raises(RuntimeError, match="start"):
        StreamingCP(2).save(tmp_path / "y")


def test_runner_resume_from(tmp_path):
    """ALSRunner.open_stream(resume_from=...) returns a fresh session
    when the directory has no committed checkpoint and resumes (routed
    through the runner) when it does."""
    runner = ALSRunner(3, check_every=2)
    path = tmp_path / "stream"
    s = runner.open_stream(refine_iters=2, resume_from=str(path))
    assert s.increments == 0 and s.runner is runner
    t = random_sparse(SHAPE, 90, seed=51)
    s.start(SparseTensor(t.indices[:50], t.values[:50], SHAPE),
            n_iters=4, tol=-1.0, seed=7)
    s.save(path)

    runner2 = ALSRunner(3, check_every=2)
    s2 = runner2.open_stream(resume_from=str(path))
    assert s2.runner is runner2
    assert s2.increments == 0 and s2.seed == 7
    res = s2.update(SparseTensor(t.indices[50:], t.values[50:], SHAPE))
    assert res.engine == "batched"
    assert np.isfinite(s2.fit)


# ---------------------------------------------------------------------------
# observability
# ---------------------------------------------------------------------------


def test_session_stats_and_service_gauges():
    """Runner-routed sessions surface per-session gauges in the service
    metrics snapshot (bucket residency, evictions, latency percentiles)
    and mirror them in session.stats()."""
    runner = ALSRunner(2, check_every=2)
    s = runner.open_stream(refine_iters=2, session_id="probe")
    t = random_sparse(SHAPE, 80, seed=61)
    s.start(SparseTensor(t.indices[:50], t.values[:50], SHAPE),
            n_iters=2, tol=-1.0)
    s.update(SparseTensor(t.indices[50:], t.values[50:], SHAPE))
    snap = runner.service.snapshot()
    assert "probe" in snap["streams"]
    g = snap["streams"]["probe"]
    assert g["increments"] == 1 == s.increments   # updates only, not start
    assert g["nnz"] == s.tensor.nnz
    assert g["bucket_cap"] == s.bucket_cap
    assert g["increment_p99_s"] >= g["increment_p50_s"] >= 0.0
    st = s.stats()
    assert st["session_id"] == "probe"
    assert st["nnz"] == g["nnz"]
    assert st["merge_seconds"] > 0.0


def test_sweep_trace_stats_counts_retraces():
    """The sequential-path trace counter sees what lru stats cannot: a
    novel nnz retraces inside one cached block."""
    from repro.core.als_device import sweep_trace_stats
    t1 = random_sparse(SHAPE, 77, seed=71)
    t2 = random_sparse(SHAPE, 78, seed=72)
    cpd_als_fused(t1, 2, n_iters=2, tol=-1.0, check_every=2)
    s0 = sweep_trace_stats()
    if s0["traces"] is None:
        pytest.skip("jit cache introspection unavailable on this jax")
    cpd_als_fused(t1, 2, n_iters=2, tol=-1.0, check_every=2)  # warm: 0 new
    s1 = sweep_trace_stats()
    assert s1["traces"] == s0["traces"]
    cpd_als_fused(t2, 2, n_iters=2, tol=-1.0, check_every=2)  # novel nnz
    s2 = sweep_trace_stats()
    assert s2["traces"] > s1["traces"]


def test_session_cap_is_monotone():
    pol = BucketPolicy(mode="geometric", growth=1.5, min_cap=128)
    cap = session_cap(100, 0, pol)
    assert cap == 128
    cap2 = session_cap(300, cap, pol)
    assert cap2 >= cap and cap2 >= 300
    # shrink never happens even if nnz drops (eviction)
    assert session_cap(10, cap2, pol) == cap2


def test_pad_weights():
    w = np.array([0.5, 1.0], np.float32)
    out = pad_weights(w, 5)
    np.testing.assert_array_equal(out, [0.5, 1.0, 0.0, 0.0, 0.0])
    assert pad_weights(w, 2) is w
    with pytest.raises(ValueError, match="exceeds"):
        pad_weights(w, 1)
