"""Mode-specific layout invariants."""
import numpy as np
import pytest

from repro.core import Scheme, build_all_mode_layouts, build_mode_layout, random_sparse
from repro.core.coo import _linearize


@pytest.mark.parametrize("kappa", [1, 4, 82])
def test_layout_is_permutation_of_tensor(kappa):
    t = random_sparse((60, 33, 21), 1500, seed=2, distribution="powerlaw")
    for lay in build_all_mode_layouts(t, kappa):
        # same multiset of (coords, value)
        k1 = _linearize(t.indices, t.shape)
        k2 = _linearize(lay.indices, t.shape)
        assert sorted(k1.tolist()) == sorted(k2.tolist())
        np.testing.assert_allclose(np.sort(t.values), np.sort(lay.values))


def test_rows_sorted_and_row_ptr():
    t = random_sparse((50, 20, 10), 900, seed=3, distribution="powerlaw")
    for d in range(3):
        lay = build_mode_layout(t, d, 7)
        assert np.all(np.diff(lay.rows) >= 0), "relabeled rows must be sorted"
        # row_ptr consistency
        for r in (0, 1, lay.num_rows // 2, lay.num_rows - 1):
            s, e = lay.row_ptr[r], lay.row_ptr[r + 1]
            assert np.all(lay.rows[s:e] == r)
        # relabel round-trip
        orig_rows = lay.row_perm[lay.rows]
        np.testing.assert_array_equal(orig_rows, lay.indices[:, d])


def test_partition_row_ranges_disjoint():
    t = random_sparse((90, 45, 30), 1200, seed=4, distribution="powerlaw")
    lay = build_mode_layout(t, 0, 8, scheme=Scheme.INDEX_PARTITION)
    assert np.all(lay.row_lo[1:] == lay.row_hi[:-1]), "contiguous ranges"
    assert lay.row_lo[0] == 0 and lay.row_hi[-1] == lay.num_rows
    # nnz of partition p touch only rows in [lo, hi)
    for p in range(8):
        s, e = lay.part_offsets[p], lay.part_offsets[p + 1]
        if e > s:
            assert lay.rows[s:e].min() >= lay.row_lo[p]
            assert lay.rows[s:e].max() < lay.row_hi[p]


def test_memory_report_matches_paper_model():
    from repro.core import format_memory_report
    t = random_sparse((100, 50, 25), 2000, seed=5)
    layouts = build_all_mode_layouts(t, 82)
    rep = format_memory_report(t, layouts)
    # N copies of (indices + rows + values)
    expect = 3 * (2000 * 3 * 4 + 2000 * 4 + 2000 * 4)
    assert rep["copies_bytes"] == expect
    assert rep["analytic_copies_bytes"] < rep["copies_bytes"]  # bit-packing tighter
