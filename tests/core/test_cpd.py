"""CPD-ALS behaviour: fit recovery, monotonicity, engine equivalence."""
import itertools

import numpy as np
import pytest

from repro.core import cpd_als, low_rank_sparse, random_sparse
from repro.core.coo import SparseTensor


def _dense_lowrank(shape, R, seed):
    rng = np.random.default_rng(seed)
    F = [rng.standard_normal((I, R)).astype(np.float32) for I in shape]
    dense = np.einsum("ir,jr,kr->ijk", *F)
    idx = np.array(list(itertools.product(*[range(s) for s in shape])),
                   dtype=np.int32)
    return SparseTensor(idx, dense.reshape(-1).astype(np.float32), shape), F


def test_exact_recovery_fully_observed():
    t, _ = _dense_lowrank((12, 10, 8), 3, seed=0)
    res = cpd_als(t, rank=3, n_iters=50, kappa=4, tol=1e-9)
    assert res.fits[-1] > 0.999


def test_fit_nondecreasing_tail():
    t = random_sparse((30, 20, 15), 1500, seed=1, distribution="powerlaw")
    res = cpd_als(t, rank=6, n_iters=12, kappa=8, tol=-1.0)
    fits = np.array(res.fits)
    # ALS fit is monotone up to tiny fp noise
    assert np.all(np.diff(fits) > -1e-4), fits


@pytest.mark.parametrize("backend", ["segment", "coo"])
def test_backends_equivalent_trajectories(backend):
    t = random_sparse((25, 18, 12), 800, seed=2)
    a = cpd_als(t, rank=4, n_iters=4, kappa=4, tol=-1.0, backend="segment")
    b = cpd_als(t, rank=4, n_iters=4, kappa=4, tol=-1.0, backend=backend)
    np.testing.assert_allclose(a.fits, b.fits, rtol=1e-4, atol=1e-5)


def test_noisy_lowrank_fit_reasonable():
    t, _ = low_rank_sparse((20, 20, 20), 4000, rank=3, seed=3, noise=0.01)
    res = cpd_als(t, rank=3, n_iters=30, kappa=8)
    assert res.fits[-1] > 0.25  # sampled mask => partial fit, but well above 0


def test_weights_and_normalization():
    t, _ = _dense_lowrank((10, 9, 8), 2, seed=4)
    res = cpd_als(t, rank=2, n_iters=30, kappa=2)
    for F in res.factors:
        norms = np.linalg.norm(F, axis=0)
        np.testing.assert_allclose(norms, 1.0, atol=1e-3)
    assert np.all(res.weights > 0)


def test_reconstruct_at_matches_values():
    t, _ = _dense_lowrank((8, 7, 6), 2, seed=5)
    res = cpd_als(t, rank=2, n_iters=40, kappa=2, tol=1e-10)
    approx = res.reconstruct_at(t.indices)
    err = np.linalg.norm(approx - t.values) / np.linalg.norm(t.values)
    assert err < 0.02
