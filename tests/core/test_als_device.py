"""Device-resident fused ALS engine vs the host loop: trajectory
equivalence, sync counting, executable-cache reuse, engine delegation."""
import numpy as np
import pytest

from repro.core import (cpd_als, cpd_als_fused, random_sparse,
                        sweep_cache_stats)


@pytest.mark.parametrize("shape,nnz,R", [
    ((25, 18, 12), 800, 4),            # 3-mode
    ((16, 12, 10, 8), 600, 5),         # 4-mode
])
@pytest.mark.parametrize("backend", ["segment", "pallas", "coo"])
def test_fused_matches_host_trajectory(shape, nnz, R, backend):
    """Same seed => fused (f32 on-device solve) and host (f64 numpy solve)
    produce the same fit trajectory to 1e-4."""
    t = random_sparse(shape, nnz, seed=2, distribution="powerlaw")
    host = cpd_als(t, rank=R, n_iters=4, kappa=4, tol=-1.0,
                   backend=backend, engine="host")
    fused = cpd_als_fused(t, rank=R, n_iters=4, kappa=4, tol=-1.0,
                          backend=backend)
    assert host.engine == "host" and fused.engine == "fused"
    np.testing.assert_allclose(fused.fits, host.fits, rtol=1e-4, atol=1e-4)
    for Fh, Ff in zip(host.factors, fused.factors):
        assert Fh.shape == Ff.shape


def test_fused_host_sync_budget():
    """<= 1 host sync per check_every iterations (+1 final materialization)."""
    t = random_sparse((30, 20, 15), 1000, seed=3, distribution="powerlaw")
    res = cpd_als_fused(t, rank=4, n_iters=8, kappa=4, tol=-1.0,
                        check_every=4)
    assert res.iters == 8
    assert res.host_syncs <= 8 // 4 + 1
    # host loop for the same run syncs every mode of every iteration
    host = cpd_als(t, rank=4, n_iters=8, kappa=4, tol=-1.0, engine="host")
    assert host.host_syncs >= 8 * t.nmodes


def test_fused_sweep_cache_reused_across_same_shape_tensors():
    """Second decomposition of a same-shape tensor must not rebuild the
    jitted sweep (zero retrace for the serving scenario)."""
    t1 = random_sparse((22, 14, 9), 500, seed=4)
    t2 = random_sparse((22, 14, 9), 500, seed=5)
    cpd_als_fused(t1, rank=3, n_iters=2, kappa=2, tol=-1.0)
    before = sweep_cache_stats()
    cpd_als_fused(t2, rank=3, n_iters=2, kappa=2, tol=-1.0)
    after = sweep_cache_stats()
    assert after["currsize"] == before["currsize"]
    assert after["hits"] == before["hits"] + 1


def test_cpd_als_delegates_to_fused_by_default():
    t = random_sparse((20, 12, 8), 400, seed=6)
    res = cpd_als(t, rank=3, n_iters=3, kappa=2, tol=-1.0)
    assert res.engine == "fused"
    # custom mttkrp_fn forces the host loop
    from repro.core import make_plan, mttkrp

    res2 = cpd_als(t, rank=3, n_iters=3, kappa=2, tol=-1.0,
                   mttkrp_fn=lambda plan, factors, mode: mttkrp(
                       plan, factors, mode, backend="segment"))
    assert res2.engine == "host"
    np.testing.assert_allclose(res.fits, res2.fits, rtol=1e-4, atol=1e-4)


def test_fused_convergence_break_matches_host():
    """With check_every=1 the fused engine stops at the same iteration."""
    t = random_sparse((18, 14, 10), 600, seed=7)
    host = cpd_als(t, rank=3, n_iters=20, kappa=2, tol=1e-4, engine="host")
    fused = cpd_als_fused(t, rank=3, n_iters=20, kappa=2, tol=1e-4,
                          check_every=1)
    assert abs(host.iters - fused.iters) <= 1   # f32-vs-f64 fit jitter
    np.testing.assert_allclose(fused.fits[-1], host.fits[-1], atol=1e-3)


@pytest.mark.parametrize("mode,engine_name", [("sequential", "fused"),
                                              ("batched", "batched")])
def test_als_runner_serves_repeated_requests(mode, engine_name):
    """Runtime integration: ALSRunner routes through the fused engine
    (sequential) or the vmapped service (batched) and records per-request
    latency/sync/cache stats."""
    from repro.runtime import ALSRunner

    runner = ALSRunner(rank=3, kappa=2, check_every=2, mode=mode)
    for seed in (0, 1, 2):
        t = random_sparse((20, 12, 8), 400, seed=seed)
        res = runner.decompose(t, n_iters=4, tol=-1.0)
        assert res.engine == engine_name
    assert len(runner.history) == 3
    assert all(r["host_syncs"] <= 4 // 2 + 1 for r in runner.history)
    # satellite: per-request executable-cache deltas distinguish retrace
    # stragglers from contention stragglers — first request compiles, the
    # same-shape repeats must hit the cache.
    assert runner.history[0]["sweep_cache_misses"] >= 1
    assert all(r["sweep_cache_misses"] == 0 for r in runner.history[1:])
    assert all(r["sweep_cache_hits"] >= 1 for r in runner.history[1:])


def test_fused_scan_window_is_one_dispatch_per_block():
    """The check_every window runs as one lax.scan dispatch: host syncs are
    ceil(iters/k)+1 and the fit history still has one entry per sweep."""
    t = random_sparse((24, 16, 10), 700, seed=9, distribution="powerlaw")
    res = cpd_als_fused(t, rank=3, n_iters=7, kappa=2, tol=-1.0,
                        check_every=3)
    assert res.iters == 7
    assert len(res.fits) == 7              # 3 + 3 + 1 (remainder block)
    assert res.host_syncs == 3 + 1         # one per window + final


def test_fused_exact_recovery():
    """The fused engine recovers an exactly low-rank tensor like the host."""
    import itertools

    from repro.core.coo import SparseTensor

    rng = np.random.default_rng(0)
    shape, R = (12, 10, 8), 3
    F = [rng.standard_normal((I, R)).astype(np.float32) for I in shape]
    dense = np.einsum("ir,jr,kr->ijk", *F)
    idx = np.array(list(itertools.product(*[range(s) for s in shape])),
                   dtype=np.int32)
    t = SparseTensor(idx, dense.reshape(-1).astype(np.float32), shape)
    res = cpd_als_fused(t, rank=R, n_iters=50, kappa=4, tol=1e-9)
    assert res.fits[-1] > 0.999
