"""MTTKRP engines vs the dense oracle + property tests (hypothesis)."""
import numpy as np
import pytest
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # property tests fall back to fixed examples
    HAVE_HYPOTHESIS = False

from repro.core import (Scheme, low_rank_sparse, make_plan, mttkrp,
                        mttkrp_dense_ref, random_sparse)


def _factors(shape, R, seed=0):
    rng = np.random.default_rng(seed)
    return [jnp.asarray(rng.standard_normal((I, R)).astype(np.float32))
            for I in shape]


@pytest.mark.parametrize("shape,nnz", [
    ((40, 30, 20), 500),
    ((64, 8, 8, 8), 700),           # 4-mode
    ((16, 16, 4, 8, 6), 400),       # 5-mode (beyond the baselines' 4)
    ((100, 3, 7), 250),             # modes smaller than kappa
])
@pytest.mark.parametrize("backend", ["segment", "coo", "pallas"])
def test_backends_match_dense(shape, nnz, backend):
    t = random_sparse(shape, nnz, seed=1, distribution="powerlaw")
    R = 8
    factors = _factors(shape, R)
    plan = make_plan(t, kappa=6)
    for d in range(t.nmodes):
        ref = mttkrp_dense_ref(t, [np.asarray(f) for f in factors], d)
        out = np.asarray(mttkrp(plan, factors, d, backend=backend))
        np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("scheme", [Scheme.INDEX_PARTITION, Scheme.NNZ_PARTITION])
def test_forced_schemes_agree(scheme):
    t = random_sparse((50, 9, 33), 800, seed=3, distribution="powerlaw")
    factors = _factors(t.shape, 16, seed=4)
    plan = make_plan(t, kappa=8, scheme=scheme)
    for d in range(3):
        ref = mttkrp_dense_ref(t, [np.asarray(f) for f in factors], d)
        out = np.asarray(mttkrp(plan, factors, d))
        np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


def _matches_dense_case(shape, nnz, kappa, R):
    """For arbitrary small tensors, every mode's MTTKRP equals the dense
    matricization @ Khatri-Rao product."""
    t = random_sparse(shape, min(nnz, int(np.prod(shape))), seed=7)
    factors = _factors(t.shape, R, seed=8)
    plan = make_plan(t, kappa=kappa)
    for d in range(t.nmodes):
        ref = mttkrp_dense_ref(t, [np.asarray(f) for f in factors], d)
        out = np.asarray(mttkrp(plan, factors, d))
        np.testing.assert_allclose(out, ref, rtol=5e-3, atol=5e-3)


def _linearity_case(mode, alpha, seed):
    """MTTKRP(alpha * X) == alpha * MTTKRP(X) (linearity in tensor values)."""
    t = random_sparse((20, 15, 10), 300, seed=seed % 97)
    from repro.core.coo import SparseTensor
    t2 = SparseTensor(t.indices, (alpha * t.values).astype(np.float32), t.shape)
    factors = _factors(t.shape, 4, seed=9)
    out1 = np.asarray(mttkrp(make_plan(t, 4), factors, mode))
    out2 = np.asarray(mttkrp(make_plan(t2, 4), factors, mode))
    np.testing.assert_allclose(out2, alpha * out1, rtol=1e-3, atol=1e-3)


def _permutation_invariance_case(seed):
    """The COO nnz ordering must not affect the result (the mode-specific
    layout re-sorts internally)."""
    t = random_sparse((25, 12, 18), 400, seed=11)
    rng = np.random.default_rng(seed)
    perm = rng.permutation(t.nnz)
    tp = t.permuted(perm)
    factors = _factors(t.shape, 8, seed=12)
    for d in range(3):
        a = np.asarray(mttkrp(make_plan(t, 5), factors, d))
        b = np.asarray(mttkrp(make_plan(tp, 5), factors, d))
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(
        st.integers(3, 4).flatmap(
            lambda n: st.tuples(*[st.integers(3, 24) for _ in range(n)])),
        st.integers(10, 200),
        st.integers(1, 12),
        st.integers(1, 6),
    )
    def test_property_matches_dense(shape, nnz, kappa, R):
        _matches_dense_case(shape, nnz, kappa, R)

    @settings(max_examples=15, deadline=None)
    @given(st.integers(0, 2), st.floats(-2.0, 2.0), st.integers(0, 10_000))
    def test_property_linearity_in_values(mode, alpha, seed):
        _linearity_case(mode, alpha, seed)

    @settings(max_examples=15, deadline=None)
    @given(st.integers(0, 10_000))
    def test_property_nnz_permutation_invariance(seed):
        _permutation_invariance_case(seed)
else:
    @pytest.mark.parametrize("shape,nnz,kappa,R", [
        ((5, 7, 9), 60, 3, 4), ((4, 4, 4, 4), 120, 6, 2),
        ((24, 3, 11), 200, 12, 6),
    ])
    def test_property_matches_dense(shape, nnz, kappa, R):
        _matches_dense_case(shape, nnz, kappa, R)

    @pytest.mark.parametrize("mode,alpha,seed",
                             [(0, 1.5, 0), (1, -2.0, 42), (2, 0.0, 7)])
    def test_property_linearity_in_values(mode, alpha, seed):
        _linearity_case(mode, alpha, seed)

    @pytest.mark.parametrize("seed", [0, 123, 9999])
    def test_property_nnz_permutation_invariance(seed):
        _permutation_invariance_case(seed)
