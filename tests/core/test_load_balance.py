"""Load-balancing invariants: completeness, disjointness, the 4/3 bound,
adaptive selection."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # property tests skip; the rest still run
    HAVE_HYPOTHESIS = False

from repro.core import (Scheme, balance_bound_holds, choose_scheme,
                        partition_mode, random_sparse)


def test_adaptive_rule():
    assert choose_scheme(100, 82) == Scheme.INDEX_PARTITION
    assert choose_scheme(82, 82) == Scheme.INDEX_PARTITION
    assert choose_scheme(81, 82) == Scheme.NNZ_PARTITION
    assert choose_scheme(2, 82) == Scheme.NNZ_PARTITION


@pytest.mark.parametrize("assignment", ["greedy", "cyclic"])
@pytest.mark.parametrize("kappa", [1, 3, 8, 82])
def test_partition_completeness(assignment, kappa):
    t = random_sparse((120, 40, 7), 2000, seed=5, distribution="powerlaw")
    for d in range(3):
        part = partition_mode(t, d, kappa, assignment=assignment)
        # every nnz exactly once
        assert len(part.perm) == t.nnz
        assert len(np.unique(part.perm)) == t.nnz
        assert part.offsets[0] == 0 and part.offsets[-1] == t.nnz
        assert np.all(np.diff(part.offsets) >= 0)
        # scheme 1: vertex ownership is a partition of the index set, and
        # each partition's nnz all map to its own vertices
        if part.scheme == Scheme.INDEX_PARTITION:
            vp = part.vertex_part
            assert vp.shape == (t.shape[d],)
            assert vp.min() >= 0 and vp.max() < kappa
            idx_d = t.indices[part.perm][:, d]
            for p in range(min(kappa, 10)):
                s, e = part.offsets[p], part.offsets[p + 1]
                assert np.all(vp[idx_d[s:e]] == p)


def test_scheme2_equal_split():
    t = random_sparse((5, 400, 9), 1003, seed=6)
    part = partition_mode(t, 0, 8, scheme=Scheme.NNZ_PARTITION)
    loads = part.loads
    assert loads.max() - loads.min() <= 1
    # ordered by output vertex id
    rows = t.indices[part.perm][:, 0]
    assert np.all(np.diff(rows) >= 0)


def _graham_bound_case(kappa, seed, mode_count):
    """Greedy LPT partitioning respects max_load <= 4/3 * opt_lower_bound."""
    shape = (37, 23, 11)[:mode_count] + (29,)
    t = random_sparse(shape, 600, seed=seed, distribution="powerlaw")
    for d in range(t.nmodes):
        part = partition_mode(t, d, kappa, scheme=Scheme.INDEX_PARTITION,
                              assignment="greedy")
        assert balance_bound_holds(part, t), (
            d, part.loads.max(), part.loads.mean())


if HAVE_HYPOTHESIS:
    @settings(max_examples=30, deadline=None)
    @given(st.integers(2, 64), st.integers(0, 1000), st.integers(2, 3))
    def test_property_graham_bound(kappa, seed, mode_count):
        _graham_bound_case(kappa, seed, mode_count)
else:
    @pytest.mark.parametrize("kappa,seed,mode_count",
                             [(2, 0, 2), (8, 13, 3), (64, 999, 3)])
    def test_property_graham_bound(kappa, seed, mode_count):
        """Fixed-example fallback when hypothesis is unavailable."""
        _graham_bound_case(kappa, seed, mode_count)


def test_greedy_beats_or_matches_cyclic():
    t = random_sparse((300, 300, 300), 20_000, seed=7, distribution="powerlaw")
    worse = 0
    for d in range(3):
        g = partition_mode(t, d, 82, scheme=Scheme.INDEX_PARTITION,
                           assignment="greedy").imbalance()
        c = partition_mode(t, d, 82, scheme=Scheme.INDEX_PARTITION,
                           assignment="cyclic").imbalance()
        worse += g > c + 1e-9
    assert worse == 0, "LPT should never lose to cyclic on max load"
