"""Beyond-paper cost-model scheme selection: correctness + no regressions."""
import numpy as np

from repro.core import (Scheme, choose_scheme, choose_scheme_cost_based,
                        cpd_als, frostt_like, make_plan, mttkrp,
                        mttkrp_dense_ref, random_sparse, scheme_cost)


def test_cost_policy_agrees_far_from_boundary():
    """Far from I_d ~ kappa the cost model must agree with the paper's rule."""
    t = random_sparse((5000, 4), 4000, seed=0, distribution="powerlaw")
    assert choose_scheme_cost_based(t, 0, 82) == Scheme.INDEX_PARTITION
    assert choose_scheme_cost_based(t, 1, 82) == Scheme.NNZ_PARTITION


def test_cost_policy_never_worse_under_model():
    """argmin of modeled cost is by construction <= the threshold pick."""
    for name in ("uber", "vast", "chicago"):
        t = frostt_like(name, scale=0.005, seed=1)
        for d in range(t.nmodes):
            thr = choose_scheme(t.shape[d], 82)
            cb = choose_scheme_cost_based(t, d, 82)
            c_thr = scheme_cost(t, d, 82, thr)
            c_cb = scheme_cost(t, d, 82, cb)
            assert c_cb <= c_thr + 1e-12


def test_cost_policy_plan_still_correct():
    """MTTKRP through a cost-policy plan matches the dense oracle."""
    t = random_sparse((120, 90, 30), 1000, seed=2, distribution="powerlaw")
    rng = np.random.default_rng(0)
    factors = [rng.standard_normal((I, 8)).astype(np.float32)
               for I in t.shape]
    plan = make_plan(t, kappa=82, policy="cost")
    for d in range(3):
        ref = mttkrp_dense_ref(t, factors, d)
        out = np.asarray(mttkrp(plan, factors, d))
        np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


def test_cost_policy_cpd_end_to_end():
    t = frostt_like("uber", scale=0.003, seed=3)
    plan = make_plan(t, kappa=82, policy="cost")
    res = cpd_als(t, rank=8, plan=plan, n_iters=3, tol=-1.0)
    assert np.isfinite(res.fits[-1])
