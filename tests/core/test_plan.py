"""PartitionPlan: static caps bound every member of a bucket class, and
plan-padded execution is BIT-identical to unpadded across all backends."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

import jax.numpy as jnp

from repro.core import (build_all_mode_layouts, make_plan, mttkrp,
                        plan_bucket, plan_layout, plan_tensor, quantize_nnz,
                        random_sparse, slab_cap)
from repro.kernels import ops as kops
from repro.serve.buckets import BucketPolicy, pad_tensor

SHAPE = (18, 13, 9)


def test_quantize_nnz_is_the_bucket_policy_rule():
    """BucketPolicy delegates to core.plan.quantize_nnz — one rule, two
    consumers, no possible disagreement."""
    p = BucketPolicy()
    for n in (1, 127, 128, 129, 700, 5000):
        assert p.nnz_cap(n) == quantize_nnz(n)
    g = BucketPolicy(mode="geometric", growth=1.5, min_cap=64)
    for n in (1, 65, 1000):
        assert g.nnz_cap(n) == quantize_nnz(n, mode="geometric",
                                            growth=1.5, min_cap=64)
    aligned = BucketPolicy.for_plan(256)
    assert aligned.nnz_cap(300) == 512      # lands on a slab boundary


def _assert_slab_cap_bounds(nnz, seed):
    """Any tensor with nnz <= nnz_cap packs within the plan's slab cap,
    for every mode, whatever its row distribution."""
    cap = quantize_nnz(nnz)
    t = random_sparse(SHAPE, nnz, seed=seed, distribution="powerlaw")
    plan = plan_bucket(SHAPE, cap, rank=3, kappa=2)
    for d, lay in enumerate(build_all_mode_layouts(t, 2)):
        mp = plan.modes[d]
        p = kops.pack_layout(lay, block_rows=mp.block_rows, tile=mp.tile)
        assert p.num_slabs <= mp.slab_cap, (d, p.num_slabs, mp.slab_cap)
        assert mp.slab_cap == slab_cap(lay.num_rows, cap, mp.block_rows,
                                       mp.tile)


if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(st.integers(20, 520), st.integers(0, 7))
    def test_property_slab_cap_bounds_any_distribution(nnz, seed):
        _assert_slab_cap_bounds(nnz, seed)
else:
    @pytest.mark.parametrize("nnz,seed", [(20, 0), (333, 3), (512, 5)])
    def test_property_slab_cap_bounds_any_distribution(nnz, seed):
        _assert_slab_cap_bounds(nnz, seed)


def _factors(rng, shape, R):
    return [jnp.asarray(rng.standard_normal((I, R)).astype(np.float32))
            for I in shape]


def _mttkrp_padded_vs_unpadded(nnz, seed, backend):
    """The planning layer's padding is an exact no-op per backend:

      * pallas  — slab-cap padding (appended zero slabs) on the SAME
        unpadded layout: += 0.0 into an initialized block;
      * segment / coo — nnz padding (zero entries at the origin): +0.0
        into row 0's segment, stable sorts keep real-entry order.
    """
    R = 4
    t = random_sparse(SHAPE, nnz, seed=seed, distribution="powerlaw")
    cap = quantize_nnz(nnz)
    rng = np.random.default_rng(seed)
    factors = _factors(rng, SHAPE, R)
    bplan = plan_bucket(SHAPE, cap, rank=R, kappa=2)

    if backend == "pallas":
        for d, lay in enumerate(build_all_mode_layouts(t, 2)):
            mp = bplan.modes[d]
            in_f = [factors[w] for w in lay.input_modes()]
            raw = kops.pack_layout(lay, block_rows=mp.block_rows,
                                   tile=mp.tile)
            capped = kops.pack_layout(lay, block_rows=mp.block_rows,
                                      tile=mp.tile,
                                      num_slabs_cap=mp.slab_cap)
            assert capped.num_slabs == mp.slab_cap
            assert capped.num_real_slabs == raw.num_slabs
            a = np.asarray(kops.mttkrp_packed(raw, in_f,
                                              rank_block=mp.rank_block))
            b = np.asarray(kops.mttkrp_packed(capped, in_f,
                                              rank_block=mp.rank_block))
            assert np.array_equal(a, b), f"mode {d} not bit-identical"
        return

    plain = make_plan(t, 2)
    padded = make_plan(pad_tensor(t, cap), 2)
    for d in range(t.nmodes):
        a = np.asarray(mttkrp(plain, factors, d, backend=backend))
        b = np.asarray(mttkrp(padded, factors, d, backend=backend))
        assert np.array_equal(a, b), f"mode {d} not bit-identical"


if HAVE_HYPOTHESIS:
    @settings(max_examples=9, deadline=None)
    @given(st.sampled_from([170, 300, 450]), st.integers(0, 5),
           st.sampled_from(["segment", "pallas", "coo"]))
    def test_property_plan_padding_invariance(nnz, seed, backend):
        _mttkrp_padded_vs_unpadded(nnz, seed, backend)
else:
    @pytest.mark.parametrize("nnz,seed,backend",
                             [(170, 0, "segment"), (300, 2, "pallas"),
                              (450, 4, "coo"), (300, 1, "segment"),
                              (170, 3, "pallas")])
    def test_property_plan_padding_invariance(nnz, seed, backend):
        """Fixed-example fallback when hypothesis is unavailable."""
        _mttkrp_padded_vs_unpadded(nnz, seed, backend)


def test_vmapped_pallas_bit_identical_to_plain_kernel():
    """Stacked bucket-mates through jax.vmap == each tensor through the
    plain kernel, bit for bit (the property that makes the batched pallas
    backend exact)."""
    import jax

    R, cap = 4, 512
    ts = [random_sparse(SHAPE, 500 - 60 * i, seed=i,
                        distribution="powerlaw") for i in range(3)]
    bplan = plan_bucket(SHAPE, cap, rank=R, kappa=2)
    d = 0
    mp = bplan.modes[d]
    packs, perms = [], []
    for t in ts:
        lay = build_all_mode_layouts(t, 2)[d]
        packs.append(kops.pack_layout(lay, block_rows=mp.block_rows,
                                      tile=mp.tile,
                                      num_slabs_cap=mp.slab_cap))
        perms.append(lay.row_perm)
    rng = np.random.default_rng(0)
    facs = [jnp.asarray(np.stack(
        [rng.standard_normal((I, R)).astype(np.float32) for _ in ts]))
        for I in (SHAPE[1], SHAPE[2])]

    def one(rb, first, idx, vals, lrows, f1, f2):
        from repro.kernels.mttkrp_pallas import mttkrp_pallas
        return mttkrp_pallas(rb, first, idx, vals, lrows, [f1, f2],
                             num_row_blocks=mp.num_row_blocks,
                             block_rows=mp.block_rows, tile=mp.tile,
                             rank_block=mp.rank_block, interpret=True)

    stacked = [jnp.asarray(np.stack([getattr(p, f) for p in packs]))
               for f in ("rb_of", "first", "idx_packed", "vals_packed",
                         "lrows_packed")]
    out = jax.vmap(one)(*stacked, facs[0], facs[1])
    for i, p in enumerate(packs):
        seq = kops.mttkrp_packed(p, [facs[0][i], facs[1][i]],
                                 rank_block=mp.rank_block)
        assert np.array_equal(np.asarray(out[i][: p.num_rows]),
                              np.asarray(seq))


def test_plan_tensor_agrees_with_bucket():
    """A lone tensor's plan is its bucket class's plan (same quantizer)."""
    t = random_sparse(SHAPE, 300, seed=1)
    assert plan_tensor(t, rank=3, kappa=2) is plan_bucket(
        SHAPE, quantize_nnz(300), 3, 2)      # lru-cached identity


def test_plan_layout_pins_to_actual_packing():
    t = random_sparse(SHAPE, 400, seed=2)
    lay = build_all_mode_layouts(t, 2)[1]
    mp = plan_layout(lay, rank=5, block_rows=8, tile=64)
    assert (mp.block_rows, mp.tile) == (8, 64)
    assert mp.num_row_blocks == -(-lay.num_rows // 8)
    assert 1 <= mp.rank_block <= 5
    p = kops.pack_layout(lay, block_rows=8, tile=64)
    assert p.num_slabs <= mp.slab_cap


def test_pack_rejects_overflowing_cap():
    t = random_sparse(SHAPE, 400, seed=3)
    lay = build_all_mode_layouts(t, 2)[0]
    with pytest.raises(ValueError, match="slab"):
        kops.pack_layout(lay, block_rows=8, tile=64, num_slabs_cap=1)


# ---------------------------------------------------------------------------
# Pod plans + density-driven segment partitioning
# ---------------------------------------------------------------------------


def test_pod_plan_dispatch_arithmetic():
    """Batch is rounded up to the quantum FIRST, then to a mesh multiple,
    and the per-device sub-batch divides exactly."""
    from repro.core.plan import plan_pod

    pp = plan_pod((12, 13, 14), 256, 4, num_devices=8, batch_quantum=3)
    assert pp.dispatch_batch(1) == (8, 1)     # 1 -> 3 (quantum) -> 8 (mesh)
    assert pp.dispatch_batch(8) == (16, 2)    # 8 -> 9 -> 16
    assert pp.dispatch_batch(13) == (16, 2)
    assert pp.dispatch_batch(16) == (24, 3)   # 16 -> 18 -> 24
    for b in (1, 5, 8, 13, 16, 40):
        tot, per = pp.dispatch_batch(b)
        # Mesh divisibility is the hard invariant (shard_map slices
        # exactly); the quantum is only a lower-bound rounding step, so
        # the final total need not be a quantum multiple.
        assert tot >= b and tot == per * 8
    with pytest.raises(ValueError):
        pp.dispatch_batch(0)
    # The underlying bucket plan is the SAME cached object plan_bucket
    # hands everyone else — pod sharding adds arithmetic, not a new plan.
    assert pp.bucket is plan_bucket((12, 13, 14), 256, 4, 1, density=None)


def test_observed_density_moves_chosen_kappa():
    """The density feedback loop's observable: a stream whose row mass
    concentrates in the top density bin makes the segment cost chooser
    settle on FEWER partitions for that mode (LPT makespan plateaus at
    the heavy rows' mass), while uniform-prior modes keep the larger
    kappa.  Config pinned to (96, 96, 96) cap=768 where the uniform
    chooser picks kappa=8 and the skewed one kappa=4."""
    from repro.core.plan import DENSITY_BINS

    uni = tuple(1.0 / DENSITY_BINS for _ in range(DENSITY_BINS))
    skew = (1.0,) + (0.0,) * (DENSITY_BINS - 1)
    shape, cap = (96, 96, 96), 768
    pu = plan_bucket(shape, cap, rank=4, kappa=8, density=(uni,) * 3)
    ps = plan_bucket(shape, cap, rank=4, kappa=8, density=(skew, uni, uni))
    assert [m.seg_kappa for m in pu.modes] == [8, 8, 8]
    assert [(m.seg_kappa, m.seg_scheme) for m in ps.modes] == [
        (4, "index"), (8, "index"), (8, "index")]
    # Density-less plans never consult the chooser: seg fields reproduce
    # the caller's kappa with no scheme pin (bit-identical legacy paths).
    p0 = plan_bucket(shape, cap, rank=4, kappa=8)
    assert all(m.seg_kappa == 8 and m.seg_scheme is None for m in p0.modes)
