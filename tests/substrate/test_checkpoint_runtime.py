"""Checkpoint manager + trainer fault-tolerance tests."""
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro import optim
from repro.checkpoint import CheckpointManager
from repro.configs import get_config, reduce_config
from repro.data import TokenPipeline
from repro.launch.mesh import make_host_mesh
from repro.models import get_model
from repro.runtime import StragglerMonitor, Trainer


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "a": jnp.asarray(rng.standard_normal((4, 6)).astype(np.float32)),
        "nested": {"b": jnp.arange(5, dtype=jnp.int32)},
    }


def test_save_restore_roundtrip(tmp_path):
    m = CheckpointManager(tmp_path, keep=2, async_save=False)
    t = _tree()
    m.save(3, t, extra={"step": 3})
    out, extra = m.restore(template=jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t))
    assert extra["step"] == 3
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_keep_k_gc_and_latest(tmp_path):
    m = CheckpointManager(tmp_path, keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        m.save(s, _tree())
    assert m.latest_step() == 4
    assert m._steps() == [3, 4]


def test_torn_checkpoint_ignored(tmp_path):
    m = CheckpointManager(tmp_path, keep=3, async_save=False)
    m.save(1, _tree())
    # simulate a torn write: directory without commit marker
    os.makedirs(tmp_path / "step_2")
    (tmp_path / "step_2" / "meta.msgpack").write_bytes(b"garbage")
    m2 = CheckpointManager(tmp_path, keep=3)
    assert m2.latest_step() == 1
    assert not (tmp_path / "step_2").exists(), "torn ckpt pruned on start"


def test_structure_mismatch_rejected(tmp_path):
    m = CheckpointManager(tmp_path, async_save=False)
    m.save(1, _tree())
    bad = {"a": jax.ShapeDtypeStruct((4, 6), jnp.float32)}
    with pytest.raises(ValueError):
        m.restore(template=bad)


def _mk_trainer(tmp_path, ckpt_every=5, failure_hook=None, seed=7):
    cfg = reduce_config(get_config("granite-moe-1b-a400m"))
    model = get_model(cfg)
    pipe = TokenPipeline(cfg.vocab_size, batch=4, seq_len=24, seed=seed)
    return Trainer(model, mesh=make_host_mesh(), pipeline=pipe,
                   opt_cfg=optim.AdamWConfig(lr=1e-3, warmup_steps=2,
                                             total_steps=50),
                   ckpt_dir=str(tmp_path), ckpt_every=ckpt_every,
                   failure_hook=failure_hook)


def test_crash_restart_bit_identical(tmp_path):
    """Kill mid-run; restart must produce the identical trajectory as an
    uninterrupted run (deterministic pipeline + checkpointed state)."""
    class Boom(RuntimeError):
        pass

    def bomb(step):
        if step == 8:
            raise Boom()

    tr = _mk_trainer(tmp_path / "c", ckpt_every=4, failure_hook=bomb)
    with pytest.raises(Boom):
        tr.run(12, log_every=1000)
    # restart (fresh objects, same dir)
    tr2 = _mk_trainer(tmp_path / "c", ckpt_every=4)
    h2 = tr2.run(12, log_every=1000)
    # resumes after the last COMMITTED checkpoint: step 8 if its async save
    # won the race with the crash, else step 4 — both are correct recovery
    assert h2[0]["step"] in (5, 9)

    tr3 = _mk_trainer(tmp_path / "u", ckpt_every=100)
    h3 = tr3.run(12, log_every=1000)
    assert h2[-1]["step"] == h3[-1]["step"] == 12
    assert h2[-1]["loss"] == pytest.approx(h3[-1]["loss"], abs=0.0), \
        "restart must be bit-identical"


def test_loss_decreases(tmp_path):
    tr = _mk_trainer(tmp_path, ckpt_every=1000)
    h = tr.run(25, log_every=1000)
    first = np.mean([r["loss"] for r in h[:5]])
    last = np.mean([r["loss"] for r in h[-5:]])
    assert last < first, (first, last)


def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor(sigma=3.0, warmup=3)
    for s in range(20):
        flagged = mon.observe(s, 0.10 + 0.001 * (s % 3))
        assert not flagged
    assert mon.observe(20, 1.5) is True
    assert len(mon.events) == 1
    # monitor keeps functioning after the event
    assert mon.observe(21, 0.10) is False


def test_pipeline_determinism_and_restore():
    p1 = TokenPipeline(1000, batch=4, seq_len=16, seed=5)
    batches = [next(p1) for _ in range(5)]
    snap = p1.snapshot()
    more = [next(p1) for _ in range(3)]
    p2 = TokenPipeline(1000, batch=4, seq_len=16, seed=5)
    p2.restore(snap)
    again = [next(p2) for _ in range(3)]
    for a, b in zip(more, again):
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # per-host slicing partitions the global batch
    h0 = TokenPipeline(1000, batch=4, seq_len=16, seed=5,
                       process_index=0, process_count=2)
    h1 = TokenPipeline(1000, batch=4, seq_len=16, seed=5,
                       process_index=1, process_count=2)
    b0, b1 = next(h0), next(h1)
    np.testing.assert_array_equal(
        np.concatenate([b0["tokens"], b1["tokens"]]), batches[0]["tokens"])
