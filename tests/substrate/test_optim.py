"""Optimizer + gradient-compression tests."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro import optim


def test_adamw_converges_quadratic():
    """AdamW minimizes a simple quadratic."""
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    cfg = optim.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                            total_steps=300, schedule="constant",
                            grad_clip=0.0)
    state = optim.init_state(params)
    for _ in range(300):
        g = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        params, state, _ = optim.apply_updates(cfg, params, g, state)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_grad_clip_and_metrics():
    params = {"w": jnp.ones((4, 4))}
    g = {"w": 100.0 * jnp.ones((4, 4))}
    cfg = optim.AdamWConfig(grad_clip=1.0)
    state = optim.init_state(params)
    _, _, m = optim.apply_updates(cfg, params, g, state)
    assert float(m["grad_norm"]) == pytest.approx(400.0, rel=1e-5)


def test_weight_decay_skips_1d():
    cfg = optim.AdamWConfig(lr=1.0, weight_decay=0.5, warmup_steps=0,
                            schedule="constant", grad_clip=0.0)
    params = {"w": jnp.ones((2, 2)), "b": jnp.ones((2,))}
    zeros = jax.tree.map(jnp.zeros_like, params)
    state = optim.init_state(params)
    p2, _, _ = optim.apply_updates(cfg, params, zeros, state)
    assert float(p2["w"][0, 0]) < 1.0     # decayed
    assert float(p2["b"][0]) == 1.0       # not decayed


def test_lr_schedule_shape():
    cfg = optim.AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                            min_lr_ratio=0.1)
    lrs = [float(optim.lr_at(cfg, s)) for s in [0, 5, 10, 55, 100]]
    assert lrs[0] < lrs[1] < lrs[2] == pytest.approx(1e-3)
    assert lrs[3] < lrs[2]
    assert lrs[4] == pytest.approx(1e-4, rel=1e-2)


def test_int8_error_feedback_unbiased_over_time():
    """Compressed psum with error feedback: the ACCUMULATED update over many
    steps converges to the accumulated true mean (error is carried, not
    lost)."""
    rng = np.random.default_rng(0)
    g_true = rng.standard_normal((64,)).astype(np.float32)
    err = jnp.zeros((64,))
    acc_comp = np.zeros(64)
    for step in range(50):
        g = jnp.asarray(g_true + 0.01 * rng.standard_normal(64).astype(np.float32))
        # single-participant psum == identity; exercises quant+feedback path
        q, scale = optim.quantize(g + err)
        deq = optim.dequantize(q, scale)
        err = (g + err) - deq
        acc_comp += np.asarray(deq)
    # average compressed update ~ average true update
    np.testing.assert_allclose(acc_comp / 50, g_true, atol=0.05)


def test_quantize_dequantize_bounds():
    x = jnp.asarray(np.random.default_rng(1).standard_normal((128,)) * 10)
    q, s = optim.quantize(x)
    assert q.dtype == jnp.int8
    rel = float(jnp.abs(optim.dequantize(q, s) - x).max() / jnp.abs(x).max())
    assert rel < 0.02
