""".tns I/O round-trip + synthetic dataset structure tests."""
import numpy as np
import pytest

from repro.core import frostt_like, random_sparse
from repro.core.coo import FROSTT_SHAPES
from repro.data import read_tns, write_tns


def test_tns_roundtrip(tmp_path):
    t = random_sparse((12, 9, 7), 200, seed=1)
    path = str(tmp_path / "t.tns")
    write_tns(path, t)
    t2 = read_tns(path)
    # shape inferred from max index can be smaller; indices/values preserved
    np.testing.assert_array_equal(t.indices, t2.indices)
    np.testing.assert_allclose(t.values, t2.values, rtol=1e-6)


def test_tns_gz_and_comments(tmp_path):
    path = str(tmp_path / "t.tns.gz")
    t = random_sparse((5, 5, 5), 30, seed=2)
    write_tns(path, t)
    t2 = read_tns(path)
    assert t2.nnz == 30


def test_tns_rejects_empty(tmp_path):
    p = tmp_path / "e.tns"
    p.write_text("# just a comment\n")
    with pytest.raises(ValueError):
        read_tns(str(p))


@pytest.mark.parametrize("name", list(FROSTT_SHAPES))
def test_frostt_like_structure(name):
    t = frostt_like(name, scale=0.002, seed=0)
    real_shape, _ = FROSTT_SHAPES[name]
    assert t.nmodes == len(real_shape)
    # small dims preserved exactly (they drive scheme selection)
    for got, real in zip(t.shape, real_shape):
        if real <= 2048:
            assert got == real
    assert t.nnz > 0
    # no duplicate coordinates
    dedup = t.deduplicate()
    assert dedup.nnz == t.nnz
