"""Per-architecture smoke tests: reduced config of the same family, one
train step + decode parity on CPU, asserting shapes and no NaNs."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config, reduce_config
from repro.models import get_model


def _batch(cfg, B=2, S=19, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    batch = {
        "tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size),
    }
    if cfg.num_prefix_tokens:
        batch["prefix_embeds"] = 0.02 * jax.random.normal(
            ks[2], (B, cfg.num_prefix_tokens, cfg.d_model))
    if cfg.enc_layers:
        batch["encoder_embeds"] = 0.1 * jax.random.normal(
            ks[3], (B, cfg.enc_seq, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = reduce_config(get_config(arch))
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    loss, metrics = model.loss(params, batch)
    assert np.isfinite(float(loss)) and float(loss) > 0
    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    leaves = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in leaves)
    assert any(float(jnp.abs(g).max()) > 0 for g in leaves), "no gradient flow"


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    cfg = reduce_config(get_config(arch))
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    B, S = 2, 17
    batch = _batch(cfg, B=B, S=S, seed=1)
    toks = batch["tokens"]
    kw = {}
    if "prefix_embeds" in batch:
        kw["prefix_embeds"] = batch["prefix_embeds"]
    if "encoder_embeds" in batch:
        full, _ = model.forward(params, toks, batch["encoder_embeds"])
        cache = model.init_cache(B, S + 4, dtype=jnp.float32)
        _, cache = model.prefill(params, toks[:, :-1], cache,
                                 encoder_embeds=batch["encoder_embeds"])
    else:
        full, _ = model.forward(params, toks, **kw)
        cache = model.init_cache(B, S + 4, dtype=jnp.float32)
        _, cache = model.prefill(params, toks[:, :-1], cache, **kw)
    dec, _ = model.decode_step(params, toks[:, -1:], cache)
    ref = np.asarray(full[:, -1:])
    rel = np.abs(np.asarray(dec) - ref).max() / max(np.abs(ref).max(), 1e-9)
    assert rel < 5e-4, f"{arch}: decode/forward mismatch rel={rel}"
    assert dec.shape == (B, 1, cfg.padded_vocab)


@pytest.mark.parametrize("arch", ["qwen1.5-32b", "hymba-1.5b"])
def test_quantized_kv_decode_close(arch):
    cfg = reduce_config(get_config(arch))
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    B, S = 2, 17
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, cfg.vocab_size)
    c16 = model.init_cache(B, S + 4, dtype=jnp.float32)
    c8 = model.init_cache(B, S + 4, dtype=jnp.float32, quant_kv=True)
    _, c16 = model.prefill(params, toks[:, :-1], c16)
    _, c8 = model.prefill(params, toks[:, :-1], c8)
    a, _ = model.decode_step(params, toks[:, -1:], c16)
    b, _ = model.decode_step(params, toks[:, -1:], c8)
    # int8 cache is approximate: logits close, argmax preserved
    rel = float(jnp.abs(a - b).max() / jnp.maximum(jnp.abs(a).max(), 1e-9))
    assert rel < 0.05, rel
    assert bool(jnp.all(jnp.argmax(a, -1) == jnp.argmax(b, -1)))


def test_param_count_sanity():
    """Full configs land near their published sizes."""
    expected = {
        "minitron-4b": (3.5e9, 5.0e9),
        "qwen1.5-4b": (3.3e9, 4.8e9),
        "phi4-mini-3.8b": (3.3e9, 4.9e9),
        "qwen1.5-32b": (30e9, 38e9),
        "hymba-1.5b": (1.2e9, 2.0e9),
        "whisper-large-v3": (1.3e9, 1.9e9),
        "dbrx-132b": (120e9, 140e9),
        "granite-moe-1b-a400m": (1.0e9, 1.7e9),
        "mamba2-780m": (0.6e9, 0.95e9),
        "internvl2-1b": (0.45e9, 0.95e9),
    }
    for arch, (lo, hi) in expected.items():
        n = get_config(arch).param_count()
        assert lo < n < hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9},{hi/1e9}]"
    assert 30e9 < get_config("dbrx-132b").active_param_count() < 45e9
