"""CPD-factorized embeddings: lookup correctness + the key identity —
autodiff of the embedding loss == the paper's spMTTKRP engine."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.models import factorized_embed as fe
from repro.models.common import build_params


def _params(V, d, R, seed=0):
    return build_params(fe.cpd_embed_specs(V, d, R), jax.random.PRNGKey(seed),
                        jnp.float32)


def test_lookup_matches_dense_table():
    V, d, R = 97, 16, 6
    p = _params(V, d, R)
    toks = jax.random.randint(jax.random.PRNGKey(1), (3, 11), 0, V)
    via_lookup = fe.cpd_embed_lookup(p, toks, V)
    via_table = jnp.take(fe.dense_table(p, V), toks, axis=0)
    np.testing.assert_allclose(np.asarray(via_lookup), np.asarray(via_table),
                               rtol=1e-5, atol=1e-6)


def test_compression_ratio():
    assert fe.compression_ratio(152_064, 2560, 256) > 100
    V1, V2 = fe.factor_vocab(152_064)
    assert V1 * V2 >= 152_064


@pytest.mark.parametrize("backend", ["segment", "pallas"])
def test_grad_equals_mttkrp(backend):
    """jax.grad of sum(dY * lookup) w.r.t. A and B must equal the mode-0/1
    spMTTKRP of the batch sparse tensor — the paper's kernel computing a
    real LM gradient."""
    V, d, R = 60, 8, 4
    p = _params(V, d, R, seed=2)
    B, S = 2, 13
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, V)
    dY = jax.random.normal(jax.random.PRNGKey(4), (B, S, d))

    def loss(pp):
        return jnp.sum(fe.cpd_embed_lookup(pp, toks, V) * dY)

    auto = jax.grad(loss)(p)
    dA, dB = fe.grad_factors_mttkrp(p, toks, dY, V, kappa=4, backend=backend)
    np.testing.assert_allclose(np.asarray(dA), np.asarray(auto["A"]),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(dB), np.asarray(auto["B"]),
                               rtol=2e-4, atol=2e-4)


def test_repeated_tokens_accumulate():
    """Duplicate tokens in the batch must accumulate gradient mass —
    exactly the conflicting-update case the paper's layouts organize."""
    V, d, R = 30, 4, 3
    p = _params(V, d, R, seed=5)
    toks = jnp.zeros((1, 7), jnp.int32)          # all the same token
    dY = jnp.ones((1, 7, d))
    dA, _ = fe.grad_factors_mttkrp(p, toks, dY, V, kappa=2)
    i1 = int(np.asarray(fe.split_ids(toks, V)[0])[0, 0])
    assert float(jnp.abs(dA[i1]).sum()) > 0
    others = np.delete(np.asarray(dA), i1, axis=0)
    np.testing.assert_allclose(others, 0, atol=1e-7)
