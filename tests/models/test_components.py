"""Component-level model tests: attention masks/windows/rope, SSD math,
MoE routing properties."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.models import ssm
from repro.models import mlp as mlp_mod
from repro.models.attention import (_chunked_attention, attention,
                                    init_attn_cache, quantize_kv)
from repro.models.base import ModelConfig
from repro.models.common import build_params


def _cfg(**kw):
    base = dict(arch="t", family="dense", num_layers=1, d_model=64,
                num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                vocab_size=128, dtype="float32", remat="none", attn_chunk=8)
    base.update(kw)
    return ModelConfig(**base)


def _naive_attention(q, k, v, num_kv, causal=True, window=0):
    B, S, H, hd = q.shape
    G = H // num_kv
    q5 = q.reshape(B, S, num_kv, G, hd)
    s = np.einsum("bqkgd,bskd->bkgqs", q5, k) / np.sqrt(hd)
    i = np.arange(S)
    mask = np.ones((S, S), bool)
    if causal:
        mask &= i[None, :] <= i[:, None]
    if window:
        mask &= i[None, :] > i[:, None] - window
    s = np.where(mask[None, None, None], s, -1e38)
    p = np.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    o = np.einsum("bkgqs,bskd->bqkgd", p, v)
    return o.reshape(B, S, H, hd)


@pytest.mark.parametrize("causal,window", [(True, 0), (True, 5), (False, 0)])
@pytest.mark.parametrize("S", [7, 16, 33])
def test_chunked_attention_vs_naive(causal, window, S):
    rng = np.random.default_rng(0)
    B, H, KH, hd = 2, 4, 2, 16
    q = rng.standard_normal((B, S, H, hd)).astype(np.float32)
    k = rng.standard_normal((B, S, KH, hd)).astype(np.float32)
    v = rng.standard_normal((B, S, KH, hd)).astype(np.float32)
    out = np.asarray(_chunked_attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), num_kv=KH, q0=0,
        causal=causal, window=window, chunk=8))
    ref = _naive_attention(q, k, v, KH, causal, window)
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


def test_ring_buffer_decode_equals_full_cache():
    """Sliding-window decode with a window-sized ring buffer must equal
    decode with a full-length buffer."""
    cfg = _cfg(attn_window=6)
    from repro.models.attention import attn_specs
    p = build_params(attn_specs(cfg), jax.random.PRNGKey(0), jnp.float32)
    B, T = 2, 15
    xs = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (B, T, cfg.d_model))

    full = init_attn_cache(cfg, B, T, jnp.float32)
    ring = init_attn_cache(cfg, B, cfg.attn_window, jnp.float32)
    outs_f, outs_r = [], []
    for t in range(T):
        of, full = attention(cfg, p, xs[:, t:t+1], cache=full,
                             window=cfg.attn_window)
        orr, ring = attention(cfg, p, xs[:, t:t+1], cache=ring,
                              window=cfg.attn_window)
        outs_f.append(of)
        outs_r.append(orr)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate(outs_f, 1)),
        np.asarray(jnp.concatenate(outs_r, 1)), rtol=1e-4, atol=1e-5)


def test_quantize_kv_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 5, 3, 16)) * 3.0
    q, s = quantize_kv(x)
    deq = q.astype(jnp.float32) * s
    rel = float(jnp.abs(deq - x).max() / jnp.abs(x).max())
    assert q.dtype == jnp.int8 and rel < 0.02


def test_ssd_state_invariance_to_chunk_size():
    cfg = _cfg(family="ssm", ssm_state=8, ssm_head_dim=16, ssm_ngroups=2,
               ssm_chunk=4, conv_kernel=4)
    p = build_params(ssm.ssm_specs(cfg), jax.random.PRNGKey(0), jnp.float32)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (2, 24, cfg.d_model))
    y1, _ = ssm.ssd_apply(cfg, p, x)
    import dataclasses
    cfg2 = dataclasses.replace(cfg, ssm_chunk=8)
    y2, _ = ssm.ssd_apply(cfg2, p, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)


def test_moe_routes_all_tokens_with_big_capacity():
    """With capacity_factor >= E/k no token is dropped: output equals the
    gate-weighted sum of per-expert MLPs computed densely."""
    cfg = _cfg(family="moe", num_experts=4, num_experts_per_tok=2,
               moe_dff=32, capacity_factor=8.0)
    p = build_params(mlp_mod.moe_specs(cfg), jax.random.PRNGKey(0), jnp.float32)
    x = 0.3 * jax.random.normal(jax.random.PRNGKey(1), (2, 9, cfg.d_model))
    y, aux = mlp_mod.moe_apply(cfg, p, x)

    # dense reference
    logits = x @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gate, expert = jax.lax.top_k(probs, 2)
    gate = gate / gate.sum(-1, keepdims=True)
    ref = jnp.zeros_like(x)
    for e in range(4):
        h = jax.nn.silu(x @ p["wg"][e]) * (x @ p["wi"][e])
        ye = h @ p["wo"][e]
        w = ((expert == e) * gate).sum(-1)[..., None]
        ref = ref + w * ye
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)
    assert float(aux) > 0


def test_moe_drops_overflow_tokens():
    cfg = _cfg(family="moe", num_experts=2, num_experts_per_tok=1,
               moe_dff=16, capacity_factor=0.25)
    p = build_params(mlp_mod.moe_specs(cfg), jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, cfg.d_model))
    y, _ = mlp_mod.moe_apply(cfg, p, x)
    # capacity 8 per expert * 2 experts = 16 of 64 tokens served
    served = float(jnp.mean(jnp.any(jnp.abs(y) > 0, axis=-1)))
    assert served <= 0.5
