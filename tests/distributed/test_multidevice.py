"""Multi-device tests (subprocess: jax device count is locked at first init,
so each test spawns a fresh interpreter with forced host devices)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def run_py(code: str, devices: int = 8, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_distributed_mttkrp_both_schemes():
    out = run_py("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import random_sparse, mttkrp_dense_ref
        from repro.core.distributed import make_distributed_plan, mttkrp_distributed
        t = random_sparse((64, 40, 3), 1500, seed=2, distribution="powerlaw")
        rng = np.random.default_rng(0)
        factors = [jnp.asarray(rng.standard_normal((I, 8)).astype(np.float32))
                   for I in t.shape]
        plan = make_distributed_plan(t)
        for d in range(3):
            ref = mttkrp_dense_ref(t, [np.asarray(f) for f in factors], d)
            got = np.asarray(mttkrp_distributed(plan, factors, d))
            err = np.abs(got - ref).max()
            assert err < 1e-3, (d, err)
            print("mode", d, plan.modes[d].scheme.name, "ok")
        print("PASS")
    """)
    assert "PASS" in out
    assert "NNZ_PARTITION" in out and "INDEX_PARTITION" in out


def test_distributed_cpd_runs():
    out = run_py("""
        from repro.core import random_sparse
        from repro.core.distributed import cpd_als_distributed
        t = random_sparse((48, 32, 16), 1200, seed=3, distribution="powerlaw")
        res = cpd_als_distributed(t, rank=4, n_iters=4)
        assert res.engine == "distributed"
        assert len(res.fits) >= 1 and res.fits[-1] > 0
        print("PASS", res.fits[-1])
    """)
    assert "PASS" in out


def test_distributed_fused_matches_single_device():
    """The shard_map fused sweep (psum of partial MTTKRPs, one dispatch
    per check window) matches single-device cpd_als to fp32 tolerance on
    an 8-virtual-device mesh, with zero per-iteration host syncs inside a
    window (<= 1 per check_every iters + final materialization)."""
    out = run_py("""
        import numpy as np
        from repro.core import cpd_als, random_sparse
        from repro.core.distributed import cpd_als_distributed
        # mode 2 has I_d = 6 < 8 devices -> scheme 2 (overlapping partials);
        # modes 0/1 are scheme 1 (disjoint partials): one psum sweep serves
        # both load-balancing schemes.
        t = random_sparse((48, 32, 6), 1500, seed=5, distribution="powerlaw")
        ref = cpd_als(t, rank=4, n_iters=6, tol=-1.0, seed=2)
        res = cpd_als_distributed(t, rank=4, n_iters=6, tol=-1.0, seed=2,
                                  check_every=3)
        np.testing.assert_allclose(res.fits, ref.fits, rtol=1e-4, atol=1e-4)
        for Fd, Fr in zip(res.factors, ref.factors):
            np.testing.assert_allclose(Fd, Fr, rtol=1e-3, atol=1e-3)
        assert res.host_syncs <= 6 // 3 + 1, res.host_syncs
        print("PASS", res.fits[-1], res.host_syncs)
    """)
    assert "PASS" in out


def test_elastic_restore_different_topology(tmp_path):
    """Checkpoint on an 8-device mesh, restore onto 4 devices."""
    code1 = f"""
        import jax, jax.numpy as jnp
        from repro.checkpoint import CheckpointManager
        from repro.configs import get_config, reduce_config
        from repro.models import get_model
        from repro.launch.mesh import make_host_mesh
        from repro.launch import shardings as shd
        cfg = reduce_config(get_config("minitron-4b"))
        model = get_model(cfg)
        mesh = make_host_mesh((4, 2), ("data", "model"))
        p_shard = shd.param_shardings(model, mesh)
        with mesh:
            params = jax.jit(model.init, out_shardings=p_shard)(jax.random.PRNGKey(0))
        m = CheckpointManager(r"{tmp_path}", async_save=False)
        m.save(1, params)
        print("SAVED", len(jax.devices()))
    """
    out1 = run_py(code1, devices=8)
    assert "SAVED 8" in out1

    code2 = f"""
        import numpy as np, jax
        from repro.checkpoint import CheckpointManager
        from repro.configs import get_config, reduce_config
        from repro.models import get_model
        from repro.launch.mesh import make_host_mesh
        from repro.launch import shardings as shd
        cfg = reduce_config(get_config("minitron-4b"))
        model = get_model(cfg)
        mesh = make_host_mesh((2, 2), ("data", "model"))
        p_shard = shd.param_shardings(model, mesh)
        m = CheckpointManager(r"{tmp_path}")
        params, _ = m.restore(template=model.abstract_params(), shardings=p_shard)
        devs = {{d.id for leaf in jax.tree.leaves(params)
                for d in leaf.sharding.device_set}}
        assert len(jax.devices()) == 4
        # run a forward step on the restored params to prove usability
        import jax.numpy as jnp
        toks = jnp.zeros((2, 8), jnp.int32)
        with mesh:
            logits, _ = model.forward(params, toks)
        assert bool(jnp.all(jnp.isfinite(logits)))
        print("RESTORED", len(devs))
    """
    out2 = run_py(code2, devices=4)
    assert "RESTORED 4" in out2


def test_compressed_crosspod_mean_matches_exact():
    out = run_py("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.optim.compress import cross_pod_mean
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((4,), ("pod",))
        rng = np.random.default_rng(0)
        g = {"w": jnp.asarray(rng.standard_normal((32, 16)).astype(np.float32))}
        err = {"w": jnp.zeros((32, 16))}
        exact, _ = cross_pod_mean(g, err, mesh, compress=False)
        comp, new_err = cross_pod_mean(g, err, mesh, compress=True)
        rel = float(jnp.abs(comp["w"] - exact["w"]).max()
                    / jnp.abs(exact["w"]).max())
        assert rel < 0.02, rel
        # residual is exactly the quantization error
        assert float(jnp.abs(new_err["w"]).max()) > 0
        print("PASS", rel)
    """, devices=4)
    assert "PASS" in out


def test_train_step_shards_on_2d_mesh():
    out = run_py("""
        import numpy as np, jax, jax.numpy as jnp
        from repro import optim
        from repro.configs import get_config, reduce_config
        from repro.data import TokenPipeline
        from repro.launch.mesh import make_host_mesh
        from repro.launch import shardings as shd, steps as steps_mod
        from repro.models import get_model
        cfg = reduce_config(get_config("dbrx-132b"))
        model = get_model(cfg)
        mesh = make_host_mesh((2, 4), ("data", "model"))
        p_shard = shd.param_shardings(model, mesh)
        o_shard = shd.opt_state_shardings(p_shard, mesh)
        step = steps_mod.make_train_step(model, optim.AdamWConfig(lr=3e-3, warmup_steps=1, total_steps=50))
        jitted = jax.jit(step, in_shardings=(p_shard, o_shard, None),
                         out_shardings=(p_shard, o_shard, None),
                         donate_argnums=(0, 1))
        with mesh:
            params = jax.jit(model.init, out_shardings=p_shard)(jax.random.PRNGKey(0))
            opt = jax.jit(optim.init_state, out_shardings=o_shard)(params)
            pipe = TokenPipeline(cfg.vocab_size, batch=4, seq_len=16, seed=0)
            losses = []
            for _ in range(10):
                b = {k: jnp.asarray(v) for k, v in next(pipe).items()}
                params, opt, m = jitted(params, opt, b)
                losses.append(float(m["loss"]))
        assert all(np.isfinite(losses)), losses
        assert losses[-1] < losses[0]
        print("PASS", losses)
    """, devices=8)
    assert "PASS" in out


def test_gather_collective_matches_psum():
    """Scheme-1 (index-partitioned) modes can skip the full-array psum:
    each device all-gathers only its owned row slice (plus the int32
    destination map) and scatters locally.  The gather run must agree
    with the psum run to fp32, and its recorded collective payload must
    be strictly smaller on every index-partitioned mode."""
    out = run_py("""
        import numpy as np
        from repro.core import random_sparse
        from repro.core.distributed import (
            cpd_als_distributed, collective_payload_bytes,
            make_distributed_plan, resolve_collectives)

        t = random_sparse((64, 48, 32), 2000, seed=4,
                          distribution="powerlaw")
        for method in ("cp", "nncp"):
            a = cpd_als_distributed(t, rank=4, n_iters=5, tol=-1.0, seed=2,
                                    check_every=5, method=method)
            b = cpd_als_distributed(t, rank=4, n_iters=5, tol=-1.0, seed=2,
                                    check_every=5, method=method,
                                    collective="gather")
            np.testing.assert_allclose(b.fits, a.fits, rtol=1e-4, atol=1e-4)
            for Fa, Fb in zip(a.factors, b.factors):
                np.testing.assert_allclose(Fb, Fa, rtol=1e-3, atol=1e-3)

        plan = make_distributed_plan(t)
        cols = resolve_collectives(plan, "gather")
        assert cols is not None and "gather" in cols
        psum_b = collective_payload_bytes(plan, 4, None)
        gath_b = collective_payload_bytes(plan, 4, cols)
        assert gath_b < psum_b, (gath_b, psum_b)
        print("PASS", cols, psum_b, gath_b)
    """)
    assert "PASS" in out


def test_gather_collective_rejects_valued_plans():
    """The gather scatter would drop the padding-row values the masked
    (valued) layout needs, so resolving 'gather' for a weighted plan is a
    hard error instead of silent wrongness."""
    out = run_py("""
        import numpy as np
        from repro.core import random_sparse
        from repro.core.distributed import cpd_als_distributed

        t = random_sparse((48, 32, 16), 1200, seed=6,
                          distribution="powerlaw")
        w = np.random.default_rng(0).uniform(
            0.25, 1.75, t.nnz).astype(np.float32)
        try:
            cpd_als_distributed(t, rank=4, n_iters=2, method="masked",
                                weights=w, collective="gather")
        except ValueError as e:
            print("PASS", e)
        else:
            raise AssertionError("gather accepted a valued plan")
    """)
    assert "PASS" in out
