"""Retrace-ledger semantics + the acceptance cross-check: the streaming
session cache-hit story is reconstructible from trace spans alone and
agrees exactly with ServiceMetrics."""
import numpy as np
import pytest

from repro.core import SparseTensor, random_sparse
from repro.obs import trace as obs_trace
from repro.obs.ledger import LEDGER, RetraceLedger
from repro.runtime import ALSRunner

SHAPE = (10, 8, 6)


class _FakeJit:
    """Mimics jax's version-private trace-count introspection."""

    def __init__(self, n=0):
        self.n = n

    def _cache_size(self):
        return self.n


def test_register_stats_and_reset_rebaseline():
    led = RetraceLedger()
    f = _FakeJit()
    assert led.register("k", ("a", 1), f) is f
    s = led.stats("k")
    assert s == {"blocks": 1, "blocks_new": 1, "traces": 0}
    f.n = 3
    assert led.stats("k")["traces"] == 3
    led.reset()
    s = led.stats("k")
    assert s["traces"] == 0 and s["blocks_new"] == 0
    assert s["blocks"] == 1            # entries survive reset
    f.n = 5                            # 2 retraces since re-baseline
    assert led.stats("k")["traces"] == 2


def test_stats_none_without_introspection():
    led = RetraceLedger()
    led.register("k", "key", object())   # no _cache_size attr
    assert led.stats("k")["traces"] is None
    # one introspectable fn is enough to report a number again
    f = led.register("k", "key2", _FakeJit())
    f.n = 1
    assert led.stats("k")["traces"] == 1


def test_kind_scoping_and_entries():
    led = RetraceLedger()
    led.register("a", "x", _FakeJit())
    fb = led.register("b", "y", _FakeJit())
    fb.n = 2      # two traces after registration
    assert led.kinds() == ["a", "b"]
    assert led.stats("a")["blocks"] == 1
    assert led.stats()["blocks"] == 2
    rows = led.entries("b")
    assert rows == [{"kind": "b", "key": "y", "traces": 2}]


def test_isolated_scopes_deltas():
    led = RetraceLedger()
    f = _FakeJit()
    led.register("k", "x", f)
    f.n = 4
    with led.isolated():
        assert led.stats("k")["traces"] == 0   # entry reset
        f.n = 6
        assert led.stats("k")["traces"] == 2
    assert led.stats("k")["traces"] == 0       # exit reset


def test_registration_emits_compile_event():
    with obs_trace.capture() as tr:
        RetraceLedger().register("demo", ("t", 1), _FakeJit())
    (ev,) = tr.records()
    assert ev["kind"] == "event" and ev["name"] == "ledger.compile"
    assert ev["args"] == {"kind": "demo", "key": "('t', 1)"}


def test_autouse_fixture_rebaselines_global_ledger():
    """The conftest fixture reset() means this test sees zero deltas
    from whatever ran before it."""
    s = LEDGER.stats()
    assert s["blocks_new"] == 0
    assert s["traces"] in (0, None)


# ---------------------------------------------------------------------------
# acceptance: session hit-rate from spans alone == ServiceMetrics
# ---------------------------------------------------------------------------


def test_streaming_hit_rate_reconstructible_from_spans():
    """PR 6's zero-retrace streaming numbers, re-derived two independent
    ways: (a) summing the cache_hits/cache_misses attrs the scheduler
    stamps on its serve.flush spans, (b) ServiceMetrics' own counters.
    They must agree exactly — and warm increments must actually hit."""
    t = random_sparse(SHAPE, 130, seed=61)
    with obs_trace.capture("acceptance") as tr:
        runner = ALSRunner(2, check_every=2)
        s = runner.open_stream(refine_iters=2, session_id="probe")
        s.start(SparseTensor(t.indices[:60], t.values[:60], SHAPE),
                n_iters=2, tol=-1.0)
        s.update(SparseTensor(t.indices[60:95], t.values[60:95], SHAPE))
        s.update(SparseTensor(t.indices[95:], t.values[95:], SHAPE))
        snap = runner.service.snapshot()

    flush = [r for r in tr.records()
             if r["kind"] == "span" and r["name"] == "serve.flush"]
    assert flush, "scheduler emitted no serve.flush spans"
    hits = sum(r["args"]["cache_hits"] for r in flush)
    misses = sum(r["args"]["cache_misses"] for r in flush)
    assert hits == snap["cache_hits"]
    assert misses == snap["cache_misses"]
    rate = hits / (hits + misses) if hits + misses else 0.0
    assert rate == pytest.approx(snap["cache_hit_rate"])
    # bucket-quantized sessions: the warm (second/third) increments
    # reuse the executable, so spans alone must show real hits
    assert hits > 0
    # each flush span also carries its wall time and dispatch size
    for r in flush:
        assert r["args"]["wall_s"] >= 0.0
        assert r["args"]["batch"] >= 1
    # and the session increments show up as stream.increment events
    # (start emits one too, with counted=False — updates only count)
    incs = [r for r in tr.records()
            if r["kind"] == "event" and r["name"] == "stream.increment"]
    counted = [e for e in incs if e["args"]["counted"]]
    assert len(incs) == 3
    assert len(counted) == 2 == s.increments
    assert all(e["args"]["session"] == "probe" for e in incs)
    np.testing.assert_array_less(0, [e["args"]["nnz"] for e in incs])
