"""SLO health: the pure evaluator, edge-triggered breach events, and the
live wiring through ServiceMetrics / DecompositionService — including
reconstructing a breach from a JSONL trace dump alone."""
import json

import pytest

from repro.obs import health
from repro.obs import trace as obs_trace


def _policy(**kw):
    kw.setdefault("min_events", 2)
    return health.SLOPolicy(**kw)


# ---------------------------------------------------------------------------
# evaluate()
# ---------------------------------------------------------------------------


def test_empty_policy_checks_nothing():
    rep = health.evaluate(health.SLOPolicy(), {"completed": 100,
                                               "latency_p99_s": 99.0})
    assert rep == {"status": "ok", "checked": 0, "breaches": []}


def test_latency_ceiling():
    pol = _policy(latency_p99_s=0.5)
    rep = health.evaluate(pol, {"completed": 10, "latency_p99_s": 0.4})
    assert rep["status"] == "ok" and rep["checked"] == 1
    rep = health.evaluate(pol, {"completed": 10, "latency_p99_s": 0.7})
    assert rep["status"] == "breach"
    (b,) = rep["breaches"]
    assert b == {"slo": "latency_p99_s", "scope": "service",
                 "kind": "ceiling", "target": 0.5, "observed": 0.7}
    # no completions -> latency gauge is meaningless, not judged
    rep = health.evaluate(pol, {"completed": 0, "latency_p99_s": 9.0})
    assert rep["checked"] == 0


def test_per_bucket_latency_with_global_fallback():
    pol = _policy(latency_p99_s=1.0,
                  bucket_latency_p99_s={"('a',)": 0.1})
    view = {"completed": 10,
            "bucket_latency_p99_s": {"('a',)": 0.2, "('b',)": 0.5}}
    rep = health.evaluate(pol, view)
    assert rep["checked"] == 2
    (b,) = rep["breaches"]           # 'a' breaches its 0.1; 'b' under 1.0
    assert b["slo"] == "bucket_latency_p99_s" and b["scope"] == "('a',)"


def test_queue_ceilings_judged_even_cold():
    pol = _policy(queue_depth=4, queue_age_s=1.0)
    view = {"completed": 0, "queue": {"depth": 9, "oldest_age_s": 2.5}}
    rep = health.evaluate(pol, view)
    assert rep["status"] == "breach" and rep["checked"] == 2
    assert {b["slo"] for b in rep["breaches"]} == {"queue_depth",
                                                   "queue_age_s"}


def test_floors_arm_only_warm():
    pol = _policy(cache_hit_rate_min=0.5, batch_occupancy_min=0.5)
    cold = {"completed": 1, "cache_hit_rate": 0.0, "batch_occupancy": 0.0}
    assert health.evaluate(pol, cold)["checked"] == 0
    warm = {"completed": 2, "cache_hit_rate": 0.0, "batch_occupancy": 0.9}
    rep = health.evaluate(pol, warm)
    assert rep["checked"] == 2
    (b,) = rep["breaches"]
    assert b["slo"] == "cache_hit_rate" and b["kind"] == "floor"


def test_overlap_floor_needs_dispatch_volume():
    pol = _policy(overlap_fraction_min=0.2)
    view = {"completed": 10,
            "dispatch": {"count": 1, "overlap_fraction": 0.0}}
    assert health.evaluate(pol, view)["checked"] == 0   # too few dispatches
    view["dispatch"]["count"] = 2
    rep = health.evaluate(pol, view)
    assert rep["checked"] == 1 and rep["status"] == "breach"


def test_stream_increment_ceiling_per_session():
    pol = _policy(stream_increment_p99_s=0.1)
    view = {"completed": 0, "streams": {
        "fast": {"increments": 5, "increment_p99_s": 0.01},
        "slow": {"increments": 5, "increment_p99_s": 0.5},
        "cold": {"increments": 0, "increment_p99_s": 0.0},
    }}
    rep = health.evaluate(pol, view)
    assert rep["checked"] == 2
    (b,) = rep["breaches"]
    assert b["scope"] == "slow"


# ---------------------------------------------------------------------------
# HealthMonitor: edge-triggered events
# ---------------------------------------------------------------------------


def test_monitor_emits_on_onset_and_clear_only():
    mon = health.HealthMonitor(_policy(queue_depth=4))
    red = {"queue": {"depth": 9}}
    green = {"queue": {"depth": 0}}
    with obs_trace.capture() as tr:
        assert mon.observe(red)["status"] == "breach"
        mon.observe(red)             # still red: no second event
        mon.observe(red)
        mon.observe(green)           # recovery
        mon.observe(green)
    names = [r["name"] for r in tr.records()]
    assert names.count("health.breach") == 1
    assert names.count("health.clear") == 1
    breach = [r for r in tr.records() if r["name"] == "health.breach"][0]
    assert breach["args"]["slo"] == "queue_depth"
    assert breach["args"]["observed"] == 9.0


def test_monitor_reset_rearms():
    mon = health.HealthMonitor(_policy(queue_depth=4))
    red = {"queue": {"depth": 9}}
    with obs_trace.capture() as tr:
        mon.observe(red)
        mon.reset()
        mon.observe(red)             # re-onset after reset
    names = [r["name"] for r in tr.records()]
    assert names.count("health.breach") == 2


def test_monitor_without_tracer_is_silent():
    mon = health.HealthMonitor(_policy(queue_depth=4))
    assert obs_trace.active() is None
    assert mon.observe({"queue": {"depth": 9}})["status"] == "breach"


# ---------------------------------------------------------------------------
# Live wiring: ServiceMetrics and the service front door
# ---------------------------------------------------------------------------


def _saturate(metrics):
    from repro.serve.metrics import BatchEvent
    metrics.record_submit(0.0)
    metrics.record_batch(
        BatchEvent(bucket_key=("a",), batch_size=4, max_batch=8,
                   real_nnz=100, padded_nnz=128, wall_s=1.0,
                   trigger="max_batch", cache_hits=0, cache_misses=4),
        latencies_s=[2.0, 2.0, 2.0, 2.0], now=1.0)
    metrics.record_queue(depth=50, oldest_age_s=3.0)


def test_service_metrics_snapshot_health():
    from repro.serve.metrics import ServiceMetrics
    slo = _policy(latency_p99_s=0.5, queue_depth=10)
    m = ServiceMetrics(slo=slo)
    _saturate(m)
    snap = m.snapshot()
    assert snap["health"]["status"] == "breach"
    slos = {b["slo"] for b in snap["health"]["breaches"]}
    assert {"latency_p99_s", "queue_depth"} <= slos
    # without a policy the health block reports disabled, never judges
    snap2 = ServiceMetrics().snapshot()
    assert snap2["health"] == {"status": "disabled", "checked": 0,
                               "breaches": []}


def test_breach_reconstructible_from_jsonl_alone(tmp_path):
    from repro.obs import load_jsonl
    from repro.serve.metrics import ServiceMetrics
    m = ServiceMetrics(slo=_policy(queue_depth=10))
    path = tmp_path / "svc.trace.jsonl"
    with obs_trace.capture() as tr:
        _saturate(m)
        assert m.snapshot()["health"]["status"] == "breach"
        m.record_queue(depth=0, oldest_age_s=0.0)
        assert m.snapshot()["health"]["status"] == "ok"
        tr.dump_jsonl(str(path))
    # The dump alone reconstructs the incident: one onset, one recovery.
    records = load_jsonl(str(path))
    breaches = [r for r in records if r.get("name") == "health.breach"]
    clears = [r for r in records if r.get("name") == "health.clear"]
    assert len(breaches) == 1 and len(clears) == 1
    b = breaches[0]["args"]
    assert b["slo"] == "queue_depth" and b["observed"] == 50.0
    assert clears[0]["args"]["slo"] == "queue_depth"


def test_service_end_to_end_latency_breach():
    from repro.core import random_sparse
    from repro.serve import DecompositionService
    # An SLO no real flush can meet: every completed request is a
    # latency spike, so the live snapshot must go red.
    slo = health.SLOPolicy(latency_p99_s=1e-9, min_events=1)
    svc = DecompositionService(rank=2, max_batch=4, max_wait_s=1e9,
                               slo=slo)
    with obs_trace.capture() as tr:
        futs = [svc.submit(random_sparse((8, 7, 6), 40, seed=i),
                           n_iters=2, tol=-1.0, seed=i) for i in range(4)]
        svc.drain()
        for f in futs:
            f.result()
        snap = svc.snapshot()
    assert snap["health"]["status"] == "breach"
    assert any(b["slo"] == "latency_p99_s"
               for b in snap["health"]["breaches"])
    assert any(r["name"] == "health.breach" for r in tr.records())


def test_breach_dict_roundtrips_json():
    b = health.Breach("latency_p99_s", "service", "ceiling", 0.5, 0.7)
    assert json.loads(json.dumps(b.as_dict())) == b.as_dict()
    assert b.key() == ("latency_p99_s", "service")
