"""Trace recorder invariants: random span trees round-trip through both
export formats, the Chrome schema is validated in one place, and the
disabled hot path costs zero allocations per dispatch."""
import gc
import json
import pathlib
import sys

import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.obs import trace as obs_trace

RESULTS = pathlib.Path(__file__).resolve().parents[2] / "results"


# ---------------------------------------------------------------------------
# structural invariants over random span trees
# ---------------------------------------------------------------------------

def _run_tree(tr, tree, path="r"):
    """Open one span per node, children strictly inside the parent."""
    count = 1
    with tr.span(f"n.{path}", depth=len(path)):
        for i, sub in enumerate(tree):
            count += _run_tree(tr, sub, f"{path}.{i}")
    return count


def _check_invariants(records):
    spans = [r for r in records if r["kind"] == "span"]
    by_id = {s["id"]: s for s in spans}
    for s in spans:
        assert s["dur_us"] >= 0.0
        assert s["proc_us"] >= 0.0
        p = s["parent"]
        if p is not None:
            parent = by_id[p]
            # children close before parents, so parents appear later —
            # and the child's interval nests inside the parent's
            assert parent["ts_us"] <= s["ts_us"] + 1e-6
            assert (s["ts_us"] + s["dur_us"]
                    <= parent["ts_us"] + parent["dur_us"] + 1e-6)
            assert s["tid"] == parent["tid"]
    return spans


def _assert_tree_roundtrip(trees):
    tr = obs_trace.Tracer("prop")
    n = sum(_run_tree(tr, t, f"r{i}") for i, t in enumerate(trees))
    spans = _check_invariants(tr.records())
    assert len(spans) == n
    # roots have no parent; everything else parents inside the records
    roots = [s for s in spans if s["parent"] is None]
    assert len(roots) == len(trees)

    # Chrome export: every span becomes one "X" event, and the document
    # survives a JSON round-trip through the shared validator.
    doc = json.loads(json.dumps(tr.to_chrome()))
    events = obs_trace.validate_chrome(doc)
    assert sum(1 for e in events if e["ph"] == "X") == n


if HAVE_HYPOTHESIS:
    # A span tree as nested lists — e.g. [[], [[]]] is a root with two
    # children, the second of which has one child.
    _tree = st.recursive(st.just([]),
                         lambda kids: st.lists(kids, max_size=3),
                         max_leaves=12)

    @settings(max_examples=30, deadline=None)
    @given(trees=st.lists(_tree, min_size=1, max_size=4))
    def test_random_span_trees_nest_and_roundtrip(trees):
        _assert_tree_roundtrip(trees)


def _random_tree(rng, depth=0):
    n_kids = int(rng.integers(0, 4 - depth)) if depth < 3 else 0
    return [_random_tree(rng, depth + 1) for _ in range(n_kids)]


@pytest.mark.parametrize("seed", range(10))
def test_seeded_span_trees_nest_and_roundtrip(seed):
    """Deterministic stand-in for the hypothesis property (which runs
    where hypothesis is installed): seeded random forests exercise the
    same nesting/parenting/export invariants."""
    import numpy as np
    rng = np.random.default_rng(seed)
    trees = [_random_tree(rng) for _ in range(int(rng.integers(1, 5)))]
    _assert_tree_roundtrip(trees)


def test_jsonl_roundtrip(tmp_path):
    tr = obs_trace.Tracer("rt")
    with tr.span("outer", cat="t", k=1):
        tr.event("ping", cat="t", x="y")
        with tr.span("inner"):
            pass
    path = tmp_path / "t.jsonl"
    tr.dump_jsonl(path)
    # first line is the tracer meta; load_jsonl strips it
    first = json.loads(path.read_text().splitlines()[0])
    assert first["kind"] == "meta" and "t0_wall" in first
    back = obs_trace.load_jsonl(str(path))
    assert back == [json.loads(json.dumps(obs_trace._jsonable(r)))
                    for r in tr.records()]
    names = [r["name"] for r in back]
    assert names == ["ping", "inner", "outer"]   # closes in exit order
    # the instant event parents to the then-open span
    outer = next(r for r in back if r["name"] == "outer")
    ping = next(r for r in back if r["name"] == "ping")
    assert ping["parent"] == outer["id"]


def test_mis_nested_exit_does_not_corrupt(tmp_path):
    tr = obs_trace.Tracer("mis")
    a = tr.span("a").__enter__()
    b = tr.span("b").__enter__()
    a.__exit__(None, None, None)       # out of order
    b.__exit__(None, None, None)
    with tr.span("after"):
        pass
    spans = {r["name"]: r for r in tr.records()}
    assert spans["b"]["parent"] == spans["a"]["id"]
    assert spans["after"]["parent"] is None    # stack fully drained
    obs_trace.validate_chrome(tr.to_chrome())


def test_span_error_attr_and_set():
    tr = obs_trace.Tracer("err")
    with pytest.raises(ValueError):
        with tr.span("boom") as sp:
            sp.set(stage="mid")
            raise ValueError("x")
    (rec,) = tr.records()
    assert rec["args"]["error"] == "ValueError"
    assert rec["args"]["stage"] == "mid"


def test_validate_chrome_rejects_bad_docs():
    with pytest.raises(ValueError, match="traceEvents"):
        obs_trace.validate_chrome({"rows": []})
    bad = {"traceEvents": [{"name": "x", "ph": "Q", "pid": 1, "tid": 1}]}
    with pytest.raises(ValueError, match="ph"):
        obs_trace.validate_chrome(bad)
    bad = {"traceEvents": [{"name": "x", "ph": "X", "pid": 1, "tid": 1,
                            "ts": 0.0, "dur": -1.0}]}
    with pytest.raises(ValueError, match="dur"):
        obs_trace.validate_chrome(bad)


def test_capture_restores_previous_tracer():
    assert obs_trace.active() is None
    outer = obs_trace.enable()
    with obs_trace.capture("inner") as tr:
        assert obs_trace.active() is tr is not outer
        obs_trace.event("only.inner")
    assert obs_trace.active() is outer
    assert not outer.records()
    assert [r["name"] for r in tr.records()] == ["only.inner"]
    obs_trace.disable()


# ---------------------------------------------------------------------------
# overhead guard: the disabled hot path allocates nothing
# ---------------------------------------------------------------------------


def _hot_dispatch():
    """The exact guard shape used at the instrumented choke points."""
    tr = obs_trace.active()
    if tr is None:
        return 1            # ... dispatch ...
    with tr.span("als.window", cat="als", window=0):
        return 1


def test_disabled_hot_path_zero_allocations():
    assert obs_trace.active() is None
    for _ in range(100):    # warm any lazy caches
        _hot_dispatch()
    gc.collect()
    gc.disable()
    try:
        before = sys.getallocatedblocks()
        for _ in range(10_000):
            _hot_dispatch()
        after = sys.getallocatedblocks()
    finally:
        gc.enable()
    # zero per-call; a tiny constant slack tolerates interpreter noise
    assert after - before <= 8, (
        f"disabled tracing leaked {after - before} blocks over 10k calls")


def test_null_span_is_inert():
    sp = obs_trace.span("off.path", k=1)      # tracing disabled
    assert sp is obs_trace.NULL
    with sp as s:
        assert s.set(a=2) is s


# ---------------------------------------------------------------------------
# committed smoke artifact stays valid
# ---------------------------------------------------------------------------


def test_committed_smoke_trace_is_valid_chrome():
    path = RESULTS / "obs_smoke.trace.json"
    if not path.exists():
        pytest.skip("no committed obs smoke trace (run benchmarks.run obs)")
    doc = json.loads(path.read_text())
    events = obs_trace.validate_chrome(doc)
    x = [e for e in events if e["ph"] == "X"]
    assert x, "smoke trace has no spans"
    names = {e["name"] for e in x}
    assert "als.window" in names
    assert any(e["ph"] == "i" and e["name"] == "ledger.compile"
               for e in events)
