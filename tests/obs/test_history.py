"""Benchmark-history ledger: schema validation, append/load roundtrip,
row-metric flattening, and the CLI schema gate."""
import json

import pytest

from repro.obs import history

PROV = {"ts_utc": "2026-08-08T00:00:00Z", "git_sha": "a" * 40,
        "git_dirty": False, "host": "ci", "jax_version": "0.4",
        "device": "cpu"}


def _record(section="serve", rows=None, smoke=True, wall_s=1.5):
    return history.make_record(
        section, rows=rows if rows is not None else [{"name": "r0",
                                                      "speedup": 2.0}],
        wall_s=wall_s, config={"argv": [], "smoke": smoke}, provenance=PROV)


def test_make_record_validates_and_stamps():
    rec = _record()
    assert rec["schema"] == history.SCHEMA_VERSION
    assert rec["kind"] == "bench"
    assert rec["git_sha"] == "a" * 40
    assert rec["smoke"] is True
    assert rec["ts_utc"] == PROV["ts_utc"]
    history.validate_record(rec)          # idempotent


def test_validate_names_first_violation():
    rec = _record()
    del rec["git_sha"]
    with pytest.raises(ValueError, match="git_sha"):
        history.validate_record(rec)
    rec = _record()
    rec["wall_s"] = "fast"
    with pytest.raises(ValueError, match="wall_s"):
        history.validate_record(rec)
    rec = _record()
    rec["schema"] = 99
    with pytest.raises(ValueError, match="schema 99"):
        history.validate_record(rec)
    rec = _record()
    rec["rows"] = [{"ok": 1}, "not-a-dict"]
    with pytest.raises(ValueError, match=r"rows\[1\]"):
        history.validate_record(rec)
    with pytest.raises(ValueError, match="object"):
        history.validate_record([1, 2])


def test_append_load_roundtrip(tmp_path):
    path = tmp_path / "hist.jsonl"
    recs = [_record(section=s, wall_s=float(i))
            for i, s in enumerate(("serve", "obs", "serve"))]
    for r in recs:
        history.append(path, r)
    back = history.load(path)
    assert back == recs
    # one sorted-keys JSON object per line, append-only
    lines = path.read_text().splitlines()
    assert len(lines) == 3
    for line in lines:
        obj = json.loads(line)
        assert list(obj) == sorted(obj)


def test_append_rejects_invalid(tmp_path):
    path = tmp_path / "hist.jsonl"
    bad = _record()
    del bad["host"]
    with pytest.raises(ValueError, match="host"):
        history.append(path, bad)
    assert not path.exists()


def test_load_strict_names_line(tmp_path):
    path = tmp_path / "hist.jsonl"
    history.append(path, _record())
    with open(path, "a") as f:
        f.write("{not json\n")
    with pytest.raises(ValueError, match=r"hist\.jsonl:2"):
        history.load(path)
    # forensics mode skips the damage
    assert len(history.load(path, strict=False)) == 1


def test_tail_is_per_section_oldest_first(tmp_path):
    recs = [_record(section="serve", wall_s=float(i)) for i in range(5)]
    recs.insert(2, _record(section="obs"))
    out = history.tail(recs, "serve", 3)
    assert [r["wall_s"] for r in out] == [2.0, 3.0, 4.0]
    assert history.tail(recs, "missing", 3) == []
    with pytest.raises(ValueError):
        history.tail(recs, "serve", 0)


def test_row_metrics_flattening():
    rows = [
        {"name": "s0", "speedup": 2.5, "ok": True, "plan": "m0:t512",
         "bad": float("nan"), "dispatch": {"count": 3, "overlap_fraction":
                                           0.5, "nested": {"deep": 1}},
         "listy": [1, 2]},
        {"dataset": "uber", "measured_s": 0.5},
        {"stream": "sess-1", "increment_p99_s": 0.01},
        {"unnamed": 1.0},
    ]
    m = history.row_metrics(rows)
    assert m["s0"] == {"speedup": 2.5, "dispatch.count": 3.0,
                      "dispatch.overlap_fraction": 0.5}
    assert m["uber"] == {"measured_s": 0.5}
    assert m["sess-1"] == {"increment_p99_s": 0.01}
    assert m["row[3]"] == {"unnamed": 1.0}


def test_plan_fingerprints():
    rows = [{"plan": "m0:t512"}, {"plan": "m0:t256"}, {"plan": "m0:t512"},
            {"noplan": 1}, {"plan": 7}]
    assert history.plan_fingerprints(rows) == ["m0:t256", "m0:t512"]


def test_cli_validate(tmp_path, capsys):
    path = tmp_path / "hist.jsonl"
    history.append(path, _record())
    assert history.main(["validate", str(path)]) == 0
    assert "1 record(s) OK" in capsys.readouterr().out
    with open(path, "a") as f:
        f.write(json.dumps({"schema": 1}) + "\n")
    assert history.main(["validate", str(path)]) == 1
    assert history.main(["validate"]) == 2
    assert history.main([]) == 2
