"""Calibration harness + report dashboard on tiny tensors (jax work is
CI-sized; the full Table-3 replay lives in benchmarks/obs_bench.py)."""
import io
import json

import pytest

from repro.core import random_sparse
from repro.obs import calibrate, report, trace as obs_trace

SHAPE = (12, 9, 7)


@pytest.fixture(scope="module")
def tiny():
    return random_sparse(SHAPE, 120, seed=5)


def test_calibrate_tensor_rows(tiny):
    with obs_trace.capture() as tr:
        rows = calibrate.calibrate_tensor(
            "tiny", tiny, rank=3, backends=("segment",),
            predict_fn=lambda t, d, b: 1e-6,
            kappa=4, reps=1, imbalance_reps=2)
    ratio = [r for r in rows if r["section"] == "ratio"]
    imb = [r for r in rows if r["section"] == "imbalance"]
    assert len(ratio) == 1 and len(imb) == 1

    r = ratio[0]
    assert r["backend"] == "segment" and r["dataset"] == "tiny"
    assert r["measured_s"] > 0.0
    assert r["predicted_s"] == pytest.approx(1e-6 * tiny.nmodes)
    assert r["predicted_over_observed"] == pytest.approx(
        r["predicted_s"] / r["measured_s"])
    assert len(r["per_mode"]) == tiny.nmodes
    for m in r["per_mode"]:
        assert m["measured_s"] > 0.0 and m["ratio"] > 0.0
    # compile split: the cold window includes trace+compile
    assert r["cold_window_s"] >= r["steady_window_s"] > 0.0
    assert r["compile_overhead_s"] >= 0.0

    i = imb[0]
    assert i["kappa"] == 4
    assert len(i["per_mode"]) == tiny.nmodes
    for m in i["per_mode"]:
        assert m["measured_imbalance"] >= 1.0 - 1e-9
        assert m["nnz_imbalance"] >= 1.0 - 1e-9
        assert len(m["shard_nnz"]) == 4
        assert sum(m["shard_nnz"]) == tiny.nnz

    # the measured numbers came THROUGH the tracer
    names = {r["name"] for r in tr.records() if r["kind"] == "span"}
    assert {"calibrate.mode_mttkrp", "calibrate.imbalance",
            "als.window"} <= names


def test_measure_compile_steady_requires_tracer(tiny):
    with pytest.raises(RuntimeError, match="active tracer"):
        calibrate.measure_compile_steady(tiny, 2, "segment")


def test_mode_seconds_without_tracer_falls_back(tiny):
    assert obs_trace.active() is None
    out = calibrate.measure_mode_seconds(tiny, 2, "segment", reps=1)
    assert len(out) == tiny.nmodes and all(s > 0 for s in out)


# ---------------------------------------------------------------------------
# report rendering
# ---------------------------------------------------------------------------


def _sample_tracer():
    tr = obs_trace.Tracer("rep")
    with tr.span("outer", cat="t"):
        tr.event("ledger.compile", cat="compile", kind="sweep_block",
                 key="(k)")
        with tr.span("inner", cat="t"):
            pass
        with tr.span("inner", cat="t"):
            pass
    return tr


def test_aggregate_tree_self_total():
    tr = _sample_tracer()
    spans = [r for r in tr.records() if r["kind"] == "span"]
    agg = report.aggregate_tree(spans)
    assert agg[("outer",)]["count"] == 1
    assert agg[("outer", "inner")]["count"] == 2
    # self = total - children's totals, floored at 0
    outer = agg[("outer",)]
    inner = agg[("outer", "inner")]
    assert outer["self_us"] == pytest.approx(
        max(outer["total_us"] - inner["total_us"], 0.0))


def test_report_main_renders_all_artifact_kinds(tmp_path):
    tr = _sample_tracer()
    jsonl = tmp_path / "t.jsonl"
    chrome = tmp_path / "t.trace.json"
    bench = tmp_path / "BENCH_obs.json"
    tr.dump_jsonl(jsonl)
    tr.dump_chrome(str(chrome))
    bench.write_text(json.dumps({"rows": [
        {"name": "obs/x/segment", "section": "ratio", "dataset": "x",
         "backend": "segment", "predicted_s": 1e-3, "measured_s": 2e-3,
         "predicted_over_observed": 0.5, "compile_overhead_s": 0.1,
         "steady_window_s": 0.01},
        {"name": "obs/x/imbalance", "section": "imbalance", "dataset": "x",
         "per_mode": [{"mode": 0, "scheme": "NNZ_PARTITION",
                       "measured_imbalance": 1.2, "nnz_imbalance": 1.0}]},
        {"name": "obs/ledger", "section": "ledger", "blocks": 3,
         "traces": 3, "expected_max_traces": 3},
    ]}))
    out = io.StringIO()
    rc = report.main([str(jsonl), str(chrome), str(bench)], out=out)
    text = out.getvalue()
    assert rc == 0
    assert text.count("-- span tree --") == 2      # jsonl + chrome
    assert "  inner" in text                       # indented child
    assert "sweep_block" in text                   # ledger section
    assert "pred/obs" in text or "predicted vs observed" in text
    assert "0.5" in text and "1.200" in text
    assert "expected_max_traces: 3" in text


def test_report_help():
    out = io.StringIO()
    assert report.main([], out=out) == 2
    assert "usage:" in out.getvalue()
