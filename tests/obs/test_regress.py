"""Regression gate: direction awareness, noise-calibrated bands, the
injected-2x-slowdown guarantee, and the CLI check/update-baseline flow."""
import json

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # property tests fall back to fixed examples
    HAVE_HYPOTHESIS = False

from repro.obs import history, regress

PROV = {"ts_utc": "2026-08-08T00:00:00Z", "git_sha": "b" * 40,
        "git_dirty": False, "host": "ci", "jax_version": "0.4",
        "device": "cpu"}


def _records(metric_values, section="serve", metric="latency_p99_s",
             row="s0"):
    """One history record per repeat, each with a single-row metric."""
    return [history.make_record(
        section, rows=[{"name": row, metric: float(v)}], wall_s=1.0,
        config={"argv": [], "smoke": True}, provenance=PROV)
        for v in metric_values]


def _baseline(records, sections=("serve",), repeats=None):
    return regress.baseline_from_history(
        records, list(sections), repeats=repeats or len(records))


# ---------------------------------------------------------------------------
# Classification and aggregation
# ---------------------------------------------------------------------------


def test_classify_directions():
    assert regress.classify("latency_p99_s").direction == "down"
    assert regress.classify("cache_hit_rate").direction == "up"
    assert regress.classify("speedup").direction == "up"
    assert regress.classify("padding_overhead").direction == "down"
    assert regress.classify("imbalance_contiguous").direction == "down"
    assert regress.classify("host_syncs").direction == "down"
    # gauge sub-dict keys classify by their leaf
    assert regress.classify("dispatch.overlap_fraction").direction == "up"
    assert regress.classify("queue.oldest_age_s").direction == "down"
    # first-match-wins ordering: a hit RATE is up-good even though it
    # would also match broad down-good timing-ish patterns
    assert regress.classify("cache_hit_rate").pattern == "*hit_rate*"
    assert regress.classify("requests") is None
    assert regress.classify("plan") is None


def test_best_and_spread():
    assert regress.best([3.0, 1.0, 2.0], "down") == 1.0
    assert regress.best([3.0, 1.0, 2.0], "up") == 3.0
    assert regress.rel_spread([1.0]) == 0.0
    assert regress.rel_spread([1.0, 1.1]) == pytest.approx(0.1 / 1.1)
    with pytest.raises(ValueError):
        regress.best([], "down")


def test_portability_split():
    assert regress.classify("latency_p99_s").portable is False
    assert regress.classify("bat_rps").portable is False
    assert regress.classify("cache_hit_rate").portable is True
    assert regress.classify("speedup").portable is True


# ---------------------------------------------------------------------------
# No false positive on in-band jitter (min-of-k)
# ---------------------------------------------------------------------------


def _gate(baseline_values, fresh_values, metric="latency_p99_s", **kw):
    base = _baseline(_records(baseline_values, metric=metric))
    findings = regress.compare_sections(
        base, _records(fresh_values, metric=metric), ["serve"],
        repeats=len(fresh_values), **kw)
    (f,) = [f for f in findings if f.metric == metric]
    return f


def test_no_false_positive_on_inband_jitter_seeded():
    rng = np.random.default_rng(7)
    for _ in range(50):
        base_vals = 1.0 + 0.02 * rng.random(3)
        fresh_vals = 1.0 + 0.02 * rng.random(3)
        f = _gate(list(base_vals), list(fresh_vals))
        assert f.status in ("ok", "improved"), f.describe()


if HAVE_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(st.lists(st.floats(0.98, 1.02), min_size=1, max_size=4),
           st.lists(st.floats(0.98, 1.02), min_size=1, max_size=4))
    def test_no_false_positive_on_inband_jitter(base_vals, fresh_vals):
        f = _gate(base_vals, fresh_vals)
        assert f.status in ("ok", "improved"), f.describe()


def test_one_outlier_repeat_does_not_fail():
    # min-of-k: a single stalled repeat is absorbed as long as any
    # repeat lands in band.
    f = _gate([1.0, 1.0], [5.0, 1.01, 1.0])
    assert f.status == "ok"
    assert f.observed == 1.0


def test_baseline_noise_widens_band_for_agreeing_fresh_repeats():
    # A metric that was demonstrably jittery when the baseline was
    # blessed (speedup swinging ~2x between repeats) must not fail the
    # gate when the fresh repeats happen to agree with each other on
    # the low side: the baseline's recorded spread widens the band.
    base = _baseline(_records([3.54, 1.84], metric="speedup"))
    assert base["noise"]["serve"]["s0"]["speedup"] == pytest.approx(
        (3.54 - 1.84) / 3.54)
    findings = regress.compare_sections(
        base, _records([1.84, 1.86], metric="speedup"), ["serve"],
        repeats=2)
    (f,) = [f for f in findings if f.metric == "speedup"]
    assert f.status == "ok", f.describe()
    # ...but the MAX_REL_TOL cap still catches a shift past the
    # envelope any jitter could justify.
    findings = regress.compare_sections(
        base, _records([0.60, 0.61], metric="speedup"), ["serve"],
        repeats=2)
    (f,) = [f for f in findings if f.metric == "speedup"]
    assert f.status == "regression", f.describe()


def test_baseline_without_noise_block_still_checks():
    # Pre-noise-block baselines (or hand-written ones) gate exactly as
    # before: absent spread contributes 0 to the band.
    base = _baseline(_records([1.0, 1.01]))
    del base["noise"]
    findings = regress.compare_sections(
        base, _records([1.02]), ["serve"], repeats=1)
    (f,) = [f for f in findings if f.metric == "latency_p99_s"]
    assert f.status == "ok", f.describe()


# ---------------------------------------------------------------------------
# Injected 2x slowdown always fails
# ---------------------------------------------------------------------------


def test_injected_2x_slowdown_fails():
    f = _gate([1.0, 1.02, 0.99], [2.0, 2.04, 1.98])
    assert f.status == "regression", f.describe()


def test_2x_fails_even_with_huge_noise_mult():
    # The MAX_REL_TOL cap: no noise calibration can widen the band past
    # 80%, so a clean 2x (rel_change = 1.0) is always out of band.
    f = _gate([1.0], [2.0, 2.6], noise_mult=1e6)
    assert f.tol == regress.MAX_REL_TOL
    assert f.status == "regression", f.describe()


if HAVE_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(st.floats(0.01, 100.0), st.floats(1.0, 50.0),
           st.lists(st.floats(0.95, 1.05), min_size=1, max_size=4))
    def test_2x_slowdown_guarantee(base, noise_mult, jitter):
        f = _gate([base], [2.0 * base * j for j in jitter],
                  noise_mult=noise_mult)
        assert f.status == "regression", f.describe()


# ---------------------------------------------------------------------------
# Direction awareness
# ---------------------------------------------------------------------------


def test_hit_rate_drop_fails_latency_drop_passes():
    # Down-good metric going DOWN is an improvement...
    f = _gate([1.0], [0.4])
    assert f.status == "improved"
    # ...while an up-good metric going down by the same factor regresses.
    f = _gate([0.9], [0.36], metric="cache_hit_rate")
    assert f.status == "regression", f.describe()
    # and an up-good metric going UP is an improvement, not a breach.
    f = _gate([0.5], [0.9], metric="cache_hit_rate")
    assert f.status == "improved"


def test_portable_only_demotes_timings():
    f = _gate([1.0], [3.0], portable_only=True)
    assert f.status == "info"        # timing: not gated cross-machine
    f = _gate([0.9], [0.2], metric="cache_hit_rate", portable_only=True)
    assert f.status == "regression"  # portable ratio still gated


# ---------------------------------------------------------------------------
# Missing witnesses
# ---------------------------------------------------------------------------


def test_missing_section_and_vanished_metric_fail():
    base = _baseline(_records([1.0]))
    findings = regress.compare_sections(base, [], ["serve"], repeats=1)
    assert [f.status for f in findings] == ["missing"]
    # metric vanished from every fresh repeat
    fresh = _records([1.0], metric="other_metric_s")
    findings = regress.compare_sections(base, fresh, ["serve"], repeats=1)
    assert any(f.status == "missing" and f.metric == "latency_p99_s"
               for f in findings)


def test_new_metric_is_not_a_failure():
    base = _baseline(_records([1.0]))
    fresh = [history.make_record(
        "serve", rows=[{"name": "s0", "latency_p99_s": 1.0,
                        "brand_new_s": 5.0}], wall_s=1.0,
        config={"argv": [], "smoke": True}, provenance=PROV)]
    findings = regress.compare_sections(base, fresh, ["serve"], repeats=1)
    by_metric = {f.metric: f.status for f in findings}
    assert by_metric["brand_new_s"] == "new"
    assert by_metric["latency_p99_s"] == "ok"


# ---------------------------------------------------------------------------
# CLI flow: update-baseline then check
# ---------------------------------------------------------------------------


def _write_history(path, records):
    for r in records:
        history.append(path, r)


def test_cli_update_then_clean_check_passes(tmp_path, capsys):
    hist = tmp_path / "hist.jsonl"
    base = tmp_path / "base.json"
    _write_history(hist, _records([1.0, 1.01]))
    assert regress.main(["--history", str(hist), "--baseline", str(base),
                         "--sections", "serve", "--repeats", "2",
                         "--update-baseline"]) == 0
    doc = json.loads(base.read_text())
    assert doc["schema"] == regress.BASELINE_SCHEMA
    assert doc["sections"]["serve"]["s0"]["latency_p99_s"] == 1.0
    # unchanged re-run over the same k repeats passes
    assert regress.main(["--history", str(hist), "--baseline", str(base),
                         "--sections", "serve", "--repeats", "2",
                         "--check"]) == 0
    assert "PASS" in capsys.readouterr().out


def test_cli_injected_slowdown_fails_gate(tmp_path, capsys):
    hist = tmp_path / "hist.jsonl"
    base = tmp_path / "base.json"
    _write_history(hist, _records([1.0, 1.01]))
    assert regress.main(["--history", str(hist), "--baseline", str(base),
                         "--sections", "serve", "--repeats", "2",
                         "--update-baseline"]) == 0
    capsys.readouterr()
    # a 2x-slower pair of fresh records lands in the same ledger
    _write_history(hist, _records([2.0, 2.02]))
    assert regress.main(["--history", str(hist), "--baseline", str(base),
                         "--sections", "serve", "--repeats", "2",
                         "--check"]) == 1
    out = capsys.readouterr().out
    assert "FAIL" in out and "latency_p99_s" in out


def test_cli_requires_exactly_one_mode(tmp_path):
    hist = tmp_path / "hist.jsonl"
    _write_history(hist, _records([1.0]))
    with pytest.raises(SystemExit):
        regress.main(["--history", str(hist), "--sections", "serve"])
    with pytest.raises(SystemExit):
        regress.main(["--history", str(hist), "--sections", "serve",
                      "--check", "--update-baseline"])
