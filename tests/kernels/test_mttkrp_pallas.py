"""Pallas kernel vs the pure-jnp oracle: shape/dtype sweeps (interpret mode)."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import make_plan, mttkrp, random_sparse
from repro.kernels import ops as kops
from repro.kernels.ops import pack_slabs


def _factors(shape, R, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    return [jnp.asarray(rng.standard_normal((I, R)).astype(dtype))
            for I in shape]


@pytest.mark.parametrize("shape,nnz,R", [
    ((64, 32, 16), 1000, 8),
    ((128, 8, 8), 600, 32),
    ((32, 32, 32, 8), 800, 16),       # 4-mode
    ((16, 8, 4, 4, 4), 300, 4),       # 5-mode
    ((257, 63, 5), 900, 33),          # non-aligned dims / rank
])
def test_kernel_matches_oracle_shapes(shape, nnz, R):
    t = random_sparse(shape, nnz, seed=1, distribution="powerlaw")
    factors = _factors(shape, R, seed=2)
    plan = make_plan(t, kappa=4, block_rows=16, tile=64)
    for d in range(t.nmodes):
        pal = np.asarray(mttkrp(plan, factors, d, backend="pallas"))
        seg = np.asarray(mttkrp(plan, factors, d, backend="segment"))
        np.testing.assert_allclose(pal, seg, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype,rtol", [
    (np.float32, 1e-5),
    (jnp.bfloat16, 2e-2),
])
def test_kernel_dtypes(dtype, rtol):
    t = random_sparse((48, 24, 12), 700, seed=3)
    factors = _factors(t.shape, 16, seed=4, dtype=dtype)
    plan = make_plan(t, kappa=2, block_rows=8, tile=32)
    for d in range(3):
        pal = np.asarray(mttkrp(plan, factors, d, backend="pallas"))
        f32 = [f.astype(jnp.float32) for f in factors]
        ref = np.asarray(mttkrp(plan, f32, d, backend="segment"))
        np.testing.assert_allclose(pal, ref, rtol=rtol, atol=rtol)


@pytest.mark.parametrize("block_rows,tile", [(8, 32), (16, 128), (128, 256)])
def test_kernel_blockspec_sweep(block_rows, tile):
    t = random_sparse((100, 40, 20), 1200, seed=5, distribution="powerlaw")
    factors = _factors(t.shape, 8, seed=6)
    plan = make_plan(t, kappa=4, block_rows=block_rows, tile=tile)
    pal = np.asarray(mttkrp(plan, factors, 0, backend="pallas"))
    seg = np.asarray(mttkrp(plan, factors, 0, backend="segment"))
    np.testing.assert_allclose(pal, seg, rtol=1e-5, atol=1e-5)


def test_gather_paths_agree():
    """One-hot MXU gather vs vector-gather path must give identical results."""
    t = random_sparse((300, 12, 9), 500, seed=7)
    factors = _factors(t.shape, 8, seed=8)
    plan = make_plan(t, kappa=2, block_rows=8, tile=32)
    packed = plan.packed(0)
    in_f = [factors[w] for w in plan.layouts[0].input_modes()]
    a = np.asarray(kops.mttkrp_packed(packed, in_f, gather_onehot_max=4096))
    b = np.asarray(kops.mttkrp_packed(packed, in_f, gather_onehot_max=0))
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


def test_packing_invariants():
    t = random_sparse((40, 10, 10), 500, seed=9, distribution="powerlaw")
    plan = make_plan(t, kappa=2, block_rows=8, tile=16)
    lay = plan.layouts[0]
    packed = plan.packed(0)
    # every row block has >= 1 slab; first flags are consistent
    assert packed.num_slabs >= packed.num_row_blocks
    firsts = np.flatnonzero(packed.first)
    assert len(firsts) == packed.num_row_blocks
    assert np.all(np.diff(packed.rb_of) >= 0)
    # padded values sum equals original values sum
    np.testing.assert_allclose(packed.vals_packed.sum(), lay.values.sum(),
                               rtol=1e-5)


def test_empty_row_blocks():
    """Rows with zero nnz must produce zero output rows, not garbage."""
    from repro.core.coo import SparseTensor
    idx = np.array([[0, 0, 0], [0, 1, 1], [63, 2, 2]], np.int32)
    vals = np.array([1.0, 2.0, 3.0], np.float32)
    t = SparseTensor(idx, vals, (64, 3, 3))
    factors = _factors(t.shape, 4, seed=10)
    plan = make_plan(t, kappa=1, block_rows=8, tile=8)
    pal = np.asarray(mttkrp(plan, factors, 0, backend="pallas"))
    seg = np.asarray(mttkrp(plan, factors, 0, backend="segment"))
    np.testing.assert_allclose(pal, seg, rtol=1e-5, atol=1e-6)
    assert np.all(pal[1:63] == 0)


def test_rank_blocked_kernel():
    """Rank tiling (grid (R_blocks, G)) is exact: bit-identical to the
    single-block kernel (columns are independent), and matches the packed
    oracle to f32 rounding, including when R does not divide rank_block."""
    t = random_sparse((96, 40, 24), 1500, seed=21, distribution="powerlaw")
    R = 40                      # rank_block=16 -> 3 blocks, padded to 48
    factors = _factors(t.shape, R, seed=22)
    plan = make_plan(t, kappa=4, block_rows=16, tile=64)
    for mode in range(t.nmodes):
        packed = plan.packed(mode)
        in_f = [factors[w] for w in plan.layouts[mode].input_modes()]
        blocked = np.asarray(kops.mttkrp_packed(packed, in_f, rank_block=16))
        full = np.asarray(kops.mttkrp_packed(packed, in_f))
        ref = np.asarray(kops.mttkrp_packed_ref(packed, in_f))
        np.testing.assert_array_equal(blocked, full)
        np.testing.assert_allclose(blocked, ref, rtol=1e-5, atol=1e-5)


def test_rank_block_forced_by_vmem_budget():
    """auto_rank_block tiles the rank when factors overflow the budget, and
    the auto path through mttkrp_packed stays correct."""
    # Factors far larger than 16 MiB of f32 columns: must tile below R.
    rb = kops.auto_rank_block(64, 128, 256, factor_rows=10**6, num_inputs=2)
    assert 1 <= rb < 64
    assert -(-64 // rb) >= 2
    # Whole rank fits -> no tiling.
    assert kops.auto_rank_block(64, 128, 256, 200, 2) == 64
    # estimate_pack_cost reports the tiling and scales cost by the passes.
    t = random_sparse((64, 32, 16), 800, seed=23)
    plan = make_plan(t, kappa=2, block_rows=16, tile=64)
    lay = plan.layouts[0]
    small = kops.estimate_pack_cost(lay, 16, 64, 32, 48,
                                    vmem_budget=4096)
    big = kops.estimate_pack_cost(lay, 16, 64, 32, 48)
    assert small["num_rank_blocks"] > big["num_rank_blocks"] == 1
    assert small["vmem_ok"] and small["cost"] > big["cost"]
    # End-to-end through the mttkrp wrapper with an explicit small block.
    factors = _factors(t.shape, 32, seed=24)
    a = np.asarray(mttkrp(plan, factors, 0, backend="pallas", rank_block=8))
    b = np.asarray(mttkrp(plan, factors, 0, backend="segment"))
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


def test_auto_tiles_valid_and_correct():
    """auto_tiles picks a VMEM-feasible tiling; the kernel stays exact."""
    t = random_sparse((512, 64, 16), 3000, seed=11, distribution="powerlaw")
    plan0 = make_plan(t, kappa=4)
    for mode in range(3):
        lay = plan0.layouts[mode]
        br, tile = kops.auto_tiles(lay, rank=8)
        assert br in (8, 32, 128, 256) and tile in (64, 128, 256, 512)
        plan = make_plan(t, kappa=4, block_rows=br, tile=tile)
        factors = _factors(t.shape, 8, seed=12)
        pal = np.asarray(mttkrp(plan, factors, mode, backend="pallas"))
        seg = np.asarray(mttkrp(plan, factors, mode, backend="segment"))
        np.testing.assert_allclose(pal, seg, rtol=1e-5, atol=1e-5)


def test_auto_tiles_never_worse_than_default_under_model():
    t = random_sparse((2000, 300, 10), 8000, seed=13, distribution="powerlaw")
    plan = make_plan(t, kappa=4)
    for mode in range(3):
        lay = plan.layouts[mode]
        frows = sum(t.shape[w] for w in lay.input_modes())
        br, tile = kops.auto_tiles(lay, rank=32, factor_rows=frows)
        auto = kops.estimate_pack_cost(lay, br, tile, 32, frows)
        dflt = kops.estimate_pack_cost(lay, kops.DEFAULT_BLOCK_ROWS,
                                       kops.DEFAULT_TILE, 32, frows)
        if dflt["vmem_ok"]:
            assert auto["cost"] <= dflt["cost"] + 1e-9
