# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# single real CPU device.  Multi-device tests spawn subprocesses that set
# --xla_force_host_platform_device_count themselves.
import numpy as np
import pytest

from repro.obs import trace as obs_trace
from repro.obs.ledger import LEDGER


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(autouse=True)
def _obs_isolation():
    """Observability state is test-isolated: the retrace ledger
    re-baselines before each test (so trace-count assertions measure
    only that test's work — no module-global counter leaks across
    tests), and any tracer a test enabled is torn down after it."""
    LEDGER.reset()
    yield
    obs_trace.disable()
    LEDGER.reset()
