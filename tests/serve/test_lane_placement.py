"""Load-aware pod lane placement: serpentine-deal order properties, the
prepare_batch wiring (permutation + inverse), and result restoration."""
import os
import subprocess
import sys
import textwrap
import types

import numpy as np
import pytest

from repro.core.plan import pod_device_nnz, pod_imbalance, pod_lane_order

REPO = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))


# ---------------------------------------------------------------------------
# Order properties (pure planning, no jax)
# ---------------------------------------------------------------------------


def test_identity_cases():
    assert pod_lane_order([5, 3, 8], 1) == [0, 1, 2]
    assert pod_lane_order([], 4) == []
    # not a mesh multiple: the engine pads first; raw order untouched
    assert pod_lane_order([5, 3, 8], 2) == [0, 1, 2]


def test_is_permutation_and_deterministic():
    rng = np.random.default_rng(3)
    for _ in range(30):
        n_dev = int(rng.integers(2, 9))
        per = int(rng.integers(1, 5))
        nnz = rng.integers(1, 10_000, size=n_dev * per).tolist()
        order = pod_lane_order(nnz, n_dev)
        assert sorted(order) == list(range(len(nnz)))
        assert order == pod_lane_order(list(nnz), n_dev)


def test_balanced_never_worse_than_contiguous():
    rng = np.random.default_rng(11)
    for _ in range(100):
        n_dev = int(rng.integers(2, 9))
        per = int(rng.integers(1, 6))
        nnz = rng.integers(1, 10_000, size=n_dev * per).tolist()
        order = pod_lane_order(nnz, n_dev)
        placed = pod_imbalance(nnz, n_dev, order)
        contiguous = pod_imbalance(nnz, n_dev)
        assert placed <= contiguous + 1e-9, (nnz, n_dev, placed, contiguous)


def test_greedy_deal_beats_plain_sort_on_sorted_stream():
    # The motivating case: a descending-nnz stream. A contiguous split
    # of the SORTED list stacks all heavy requests on device 0; the
    # greedy deal pairs heaviest with lightest.
    nnz = [100, 90, 80, 70, 40, 30, 20, 10]
    order = pod_lane_order(nnz, 4)
    loads = pod_device_nnz(nnz, 4, order)
    assert max(loads) - min(loads) <= 20
    assert pod_imbalance(nnz, 4, order) < pod_imbalance(nnz, 4)


def test_device_nnz_helpers():
    nnz = [10, 20, 30, 40]
    assert pod_device_nnz(nnz, 2) == [30, 70]
    assert pod_device_nnz(nnz, 2, [3, 0, 1, 2]) == [50, 50]
    assert pod_imbalance(nnz, 2, [3, 0, 1, 2]) == pytest.approx(1.0)
    assert pod_imbalance([0, 0], 2) == 1.0


# ---------------------------------------------------------------------------
# prepare_batch wiring (fake 4-device mesh; host half only)
# ---------------------------------------------------------------------------


def _fake_mesh(n):
    return types.SimpleNamespace(axis_names=("b",),
                                 devices=np.empty(n, dtype=object))


def _prep(engine, tensors, **kw):
    kw.setdefault("n_iters", 3)
    kw.setdefault("tol", -1.0)
    kw.setdefault("seeds", list(range(len(tensors))))
    return engine.prepare_batch(tensors, **kw)


def test_prepare_batch_places_and_inverts():
    from repro.core import random_sparse
    from repro.serve import BatchedEngine

    rng = np.random.default_rng(0)
    sizes = rng.permutation([300 - 20 * i for i in range(8)]).tolist()
    tensors = [random_sparse((10, 9, 8), int(s), seed=i)
               for i, s in enumerate(sizes)]
    eng = BatchedEngine(rank=3, mesh=_fake_mesh(4))
    prep = _prep(eng, tensors, nnz_cap=320)
    assert prep.batch == 8 and prep.requested == 8
    assert prep.lane_of is not None
    assert sorted(prep.lane_of) == list(range(8))
    # the inverse maps each request back to the lane holding its tensor
    for i, t in enumerate(tensors):
        assert prep.lane_nnz[prep.lane_of[i]] == t.nnz
    # and per-lane iteration knobs moved with their tensors
    iters = [3 + i for i in range(8)]
    prep2 = _prep(eng, tensors, nnz_cap=320, n_iters=iters)
    got = np.asarray(prep2.max_iters_dev)
    for i in range(8):
        assert int(got[prep2.lane_of[i]]) == iters[i]
    # the placed split is no worse balanced than arrival order
    placed = pod_imbalance(prep.lane_nnz, 4)
    arrival = pod_imbalance([t.nnz for t in tensors], 4)
    assert placed <= arrival + 1e-9


def test_contiguous_engine_keeps_arrival_order():
    from repro.core import random_sparse
    from repro.serve import BatchedEngine

    tensors = [random_sparse((10, 9, 8), 100 + 30 * i, seed=i)
               for i in range(4)]
    eng = BatchedEngine(rank=3, mesh=_fake_mesh(4),
                        lane_placement="contiguous")
    prep = _prep(eng, tensors, nnz_cap=256)
    assert prep.lane_of is None
    assert prep.lane_nnz == [t.nnz for t in tensors]
    with pytest.raises(ValueError, match="lane_placement"):
        BatchedEngine(rank=3, lane_placement="best-effort")


def test_placement_covers_padding_lanes():
    from repro.core import random_sparse
    from repro.serve import BatchedEngine

    # 6 requests pad to 8 lanes (repeat-last); placement permutes all 8
    # but only the first `requested` entries of lane_of are consumed.
    tensors = [random_sparse((10, 9, 8), 60 + 37 * i, seed=i)
               for i in range(6)]
    eng = BatchedEngine(rank=3, mesh=_fake_mesh(4))
    prep = _prep(eng, tensors, nnz_cap=256)
    assert prep.requested == 6 and prep.batch == 8
    if prep.lane_of is not None:
        assert sorted(prep.lane_of) == list(range(8))
        for i, t in enumerate(tensors):
            assert prep.lane_nnz[prep.lane_of[i]] == t.nnz


# ---------------------------------------------------------------------------
# End to end on a real 8-device pod (subprocess, slow lane)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_balanced_results_match_contiguous_8dev():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    code = """
        import numpy as np
        from repro.core import random_sparse
        from repro.launch.mesh import make_batch_mesh
        from repro.serve import BatchedEngine

        rng = np.random.default_rng(0)
        sizes = rng.permutation([400 - 20 * i for i in range(16)]).tolist()
        ts = [random_sparse((18, 13, 9), int(s), seed=i,
                            distribution="powerlaw")
              for i, s in enumerate(sizes)]
        kw = dict(n_iters=5, tol=-1.0, seeds=list(range(16)), nnz_cap=512)
        mesh = make_batch_mesh(8)
        bal = BatchedEngine(rank=3, check_every=2, mesh=mesh).\\
            decompose_batch(ts, **kw)
        con = BatchedEngine(rank=3, check_every=2, mesh=mesh,
                            lane_placement="contiguous").\\
            decompose_batch(ts, **kw)
        for a, b in zip(bal, con):
            assert a.fits == b.fits
            for Fa, Fb in zip(a.factors, b.factors):
                np.testing.assert_array_equal(Fa, Fb)
        print("PASS bit-identical across placements")
    """
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    assert "PASS" in out.stdout
