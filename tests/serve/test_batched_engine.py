"""Vmapped batched engine: equivalence with per-tensor fused sweeps,
per-tensor convergence masking, executable-cache reuse."""
import numpy as np
import pytest

from repro.core import cpd_als_fused, make_plan, random_sparse
from repro.serve import BatchedEngine, batched_cache_stats

# Three bucket shapes (incl. a 4-mode one) for the equivalence matrix.
BUCKETS = [
    ((18, 13, 9), 500, 3),
    ((10, 8, 6, 5), 350, 4),
    ((30, 7, 5), 420, 5),
]


def _stream(shape, nnz, n=3):
    return [random_sparse(shape, nnz - 13 * i, seed=i,
                          distribution="powerlaw") for i in range(n)]


@pytest.mark.parametrize("shape,nnz,R", BUCKETS)
def test_batched_matches_sequential_fused(shape, nnz, R):
    """One vmapped dispatch over B tensors == B independent fused runs
    (same seeds), to fp32 tolerance, on 3 bucket shapes."""
    ts = _stream(shape, nnz)
    eng = BatchedEngine(rank=R, kappa=2, backend="segment", check_every=2)
    batch = eng.decompose_batch(ts, n_iters=4, tol=-1.0,
                                seeds=[10, 11, 12], nnz_cap=nnz)
    for i, t in enumerate(ts):
        ref = cpd_als_fused(t, R, kappa=2, n_iters=4, tol=-1.0, seed=10 + i,
                            backend="segment", check_every=2)
        assert batch[i].engine == "batched" and batch[i].iters == ref.iters
        np.testing.assert_allclose(batch[i].fits, ref.fits,
                                   rtol=1e-5, atol=1e-5)
        for Fb, Fr in zip(batch[i].factors, ref.factors):
            np.testing.assert_allclose(Fb, Fr, rtol=1e-4, atol=1e-4)


def test_batched_coo_backend_matches_sequential():
    shape, nnz, R = BUCKETS[0]
    ts = _stream(shape, nnz)
    eng = BatchedEngine(rank=R, kappa=2, backend="coo", check_every=2)
    batch = eng.decompose_batch(ts, n_iters=3, tol=-1.0, seeds=[0, 1, 2],
                                nnz_cap=nnz)
    for i, t in enumerate(ts):
        ref = cpd_als_fused(t, R, kappa=2, n_iters=3, tol=-1.0, seed=i,
                            backend="coo")
        np.testing.assert_allclose(batch[i].fits, ref.fits,
                                   rtol=1e-5, atol=1e-5)


def test_per_tensor_iteration_caps():
    """Requests batched together keep their own n_iters budget: a capped
    tensor's state freezes under the mask while bucket-mates sweep on."""
    ts = _stream((18, 13, 9), 480)
    eng = BatchedEngine(rank=3, kappa=2, backend="segment", check_every=2)
    batch = eng.decompose_batch(ts, n_iters=[2, 5, 3], tol=-1.0,
                                seeds=[0, 1, 2], nnz_cap=480)
    assert [r.iters for r in batch] == [2, 5, 3]
    assert [len(r.fits) for r in batch] == [2, 5, 3]
    # the frozen tensor's factors match a standalone 2-iteration run
    ref = cpd_als_fused(ts[0], 3, kappa=2, n_iters=2, tol=-1.0, seed=0,
                        backend="segment", check_every=2)
    for Fb, Fr in zip(batch[0].factors, ref.factors):
        np.testing.assert_allclose(Fb, Fr, rtol=1e-4, atol=1e-4)


def test_per_tensor_convergence_masking():
    """A converged tensor freezes (fit history stops) while the rest of
    the batch keeps iterating to their budget."""
    ts = _stream((18, 13, 9), 480, n=2)
    eng = BatchedEngine(rank=3, kappa=2, backend="segment", check_every=2)
    batch = eng.decompose_batch(ts, n_iters=6, tol=[1e9, -1.0],
                                seeds=[0, 1], nnz_cap=480)
    # Convergence is judged at window boundaries (the sequential rule):
    # the first boundary compares against -inf (never converges), so
    # tol=1e9 stops at the SECOND boundary, iteration 4.
    assert batch[0].iters == 4
    assert batch[1].iters == 6 and len(batch[1].fits) == 6


def test_convergence_stops_at_same_iteration_as_sequential():
    """For tol > 0 the batched mask must stop a tensor at exactly the
    iteration the sequential fused engine would stop at."""
    t = random_sparse((18, 13, 9), 480, seed=21, distribution="powerlaw")
    ref = cpd_als_fused(t, 3, kappa=2, n_iters=20, tol=1e-3, seed=4,
                        backend="segment", check_every=2)
    eng = BatchedEngine(rank=3, kappa=2, backend="segment", check_every=2)
    got = eng.decompose_batch([t, t], n_iters=20, tol=1e-3, seeds=[4, 4],
                              nnz_cap=480)[0]
    assert got.iters == ref.iters
    np.testing.assert_allclose(got.fits, ref.fits, rtol=1e-5, atol=1e-5)


def test_executable_cache_reused_across_batches():
    """Second batch of the same (bucket, B) class must not recompile."""
    eng = BatchedEngine(rank=3, kappa=2, backend="segment", check_every=2)
    ts1 = _stream((21, 11, 6), 300, n=2)
    ts2 = [random_sparse((21, 11, 6), 300 - 13 * i, seed=40 + i)
           for i in range(2)]
    eng.decompose_batch(ts1, n_iters=4, tol=-1.0, seeds=[0, 1], nnz_cap=320)
    before = batched_cache_stats()
    eng.decompose_batch(ts2, n_iters=4, tol=-1.0, seeds=[2, 3], nnz_cap=320)
    after = batched_cache_stats()
    assert after["currsize"] == before["currsize"]
    assert after["misses"] == before["misses"]
    assert after["hits"] > before["hits"]


def test_batch_rejects_mixed_shapes():
    eng = BatchedEngine(rank=3)
    with pytest.raises(ValueError, match="mixes shapes"):
        eng.decompose_batch([random_sparse((10, 8, 6), 100, seed=0),
                             random_sparse((10, 8, 7), 100, seed=1)])


def test_batched_pallas_matches_sequential_fused():
    """The Pallas backend now stacks (core.plan slab caps): one vmapped
    dispatch over B tensors matches B sequential fused pallas runs under
    the SAME partition plan to fp32 tolerance."""
    shape, nnz, R = (18, 13, 9), 500, 3
    ts = _stream(shape, nnz)
    eng = BatchedEngine(rank=R, kappa=2, backend="pallas", check_every=2)
    cap = nnz
    batch = eng.decompose_batch(ts, n_iters=4, tol=-1.0,
                                seeds=[10, 11, 12], nnz_cap=cap)
    bplan = eng.bucket_plan(shape, cap)
    for i, t in enumerate(ts):
        mplan = make_plan(t, 2, partition=bplan)
        ref = cpd_als_fused(t, R, plan=mplan, kappa=2, n_iters=4, tol=-1.0,
                            seed=10 + i, backend="pallas", check_every=2)
        assert batch[i].iters == ref.iters
        np.testing.assert_allclose(batch[i].fits, ref.fits,
                                   rtol=1e-5, atol=1e-5)
        for Fb, Fr in zip(batch[i].factors, ref.factors):
            np.testing.assert_allclose(Fb, Fr, rtol=1e-4, atol=1e-4)


def test_batched_pallas_bit_identical_to_per_request():
    """Co-batching must never alter an individual pallas result: a B=3
    batch returns BIT-identical factors and weights to serving each
    request alone (B=1) through the same engine.  (The plain non-vmapped
    engine agrees only to fp32 tolerance — XLA lowers the R x R solve
    differently under batching — but batching itself is exact.)  The
    diagnostic fit scalar may drift in the last ulp between the two
    executables (different XLA fusion of the reduction), so it gets a
    tight tolerance rather than equality."""
    shape, nnz, R = (18, 13, 9), 500, 3
    ts = _stream(shape, nnz)
    eng = BatchedEngine(rank=R, kappa=2, backend="pallas", check_every=2)
    b3 = eng.decompose_batch(ts, n_iters=4, tol=-1.0, seeds=[10, 11, 12],
                             nnz_cap=512)
    for i, t in enumerate(ts):
        b1 = eng.decompose_batch([t], n_iters=4, tol=-1.0, seeds=[10 + i],
                                 nnz_cap=512)[0]
        for Fa, Fb in zip(b3[i].factors, b1.factors):
            assert np.array_equal(Fa, Fb)
        assert np.array_equal(b3[i].weights, b1.weights)
        np.testing.assert_allclose(b3[i].fits, b1.fits, rtol=0, atol=1e-6)


def test_empty_batch():
    assert BatchedEngine(rank=3).decompose_batch([]) == []


def test_zero_iteration_budget():
    """n_iters=0 returns the (normalized-init) state without crashing,
    matching the sequential engine's behavior."""
    t = random_sparse((10, 8, 6), 120, seed=0)
    res = BatchedEngine(rank=3).decompose_batch([t], n_iters=0,
                                                tol=-1.0, seeds=[0])[0]
    assert res.iters == 0 and res.fits == []
    assert [F.shape for F in res.factors] == [(10, 3), (8, 3), (6, 3)]
