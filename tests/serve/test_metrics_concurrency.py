"""ServiceMetrics is written by scheduler threads while dashboards read
snapshots: hammer both sides concurrently and require exact final
totals and never-torn intermediate snapshots."""
import threading

import numpy as np

from repro.serve.metrics import BatchEvent, ServiceMetrics

N_WRITERS = 4
BATCHES_PER_WRITER = 200


def _event(i: int) -> BatchEvent:
    return BatchEvent(
        bucket_key=("b", i % 3), batch_size=2, max_batch=4,
        real_nnz=10, padded_nnz=16, wall_s=0.001,
        trigger="max_batch" if i % 2 else "max_wait",
        cache_hits=1, cache_misses=1)


def test_concurrent_writers_and_readers_exact_totals():
    m = ServiceMetrics(window=N_WRITERS * BATCHES_PER_WRITER + 10)
    stop = threading.Event()
    errors: list[str] = []

    def writer(wid: int):
        for i in range(BATCHES_PER_WRITER):
            m.record_submit(now=float(i))
            m.record_submit(now=float(i))
            m.record_batch(_event(i), [0.001, 0.002], now=float(i) + 0.5)
            m.record_density(("b", i % 3),
                             ((0.5, 0.25), None, (1.0,)))

    def reader():
        while not stop.is_set():
            snap = m.snapshot()
            # never torn: completed tracks batches exactly 2:1, and the
            # hit-rate is always computed from a consistent pair
            if snap["completed"] != 2 * snap["batches"]:
                errors.append(
                    f"torn: completed={snap['completed']} "
                    f"batches={snap['batches']}")
            hits, misses = snap["cache_hits"], snap["cache_misses"]
            if hits != misses:   # writers bump them together under lock
                errors.append(f"torn: hits={hits} misses={misses}")

    readers = [threading.Thread(target=reader) for _ in range(2)]
    writers = [threading.Thread(target=writer, args=(w,))
               for w in range(N_WRITERS)]
    for th in readers + writers:
        th.start()
    for th in writers:
        th.join()
    stop.set()
    for th in readers:
        th.join()

    assert not errors, errors[:5]
    total = N_WRITERS * BATCHES_PER_WRITER
    snap = m.snapshot()
    assert snap["submitted"] == 2 * total
    assert snap["completed"] == 2 * total
    assert snap["batches"] == total
    assert snap["cache_hits"] == total
    assert snap["cache_misses"] == total
    assert snap["cache_hit_rate"] == 0.5
    assert snap["flush_triggers"]["max_batch"] + \
        snap["flush_triggers"]["max_wait"] == total
    assert snap["batch_occupancy"] == 0.5


def test_concurrent_density_folds_stay_finite():
    m = ServiceMetrics()
    key = ("bucket", 0)
    rng = np.random.default_rng(0)
    profiles = [tuple(rng.uniform(0.1, 1.0, 4)) for _ in range(8)]

    def fold(p):
        for _ in range(100):
            m.record_density(key, (p, p, None))

    threads = [threading.Thread(target=fold, args=(p,)) for p in profiles]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    prof = m.row_density(key)
    assert prof is not None
    for d in (0, 1):
        vals = np.asarray(prof[d])
        assert np.all(np.isfinite(vals))
        assert np.all(vals >= 0)
