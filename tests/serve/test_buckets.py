"""Bucketing policy: nnz quantization, zero-padding, and the
padding-invariance guarantee (padded decomposition bit-identical)."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # property tests fall back to fixed examples
    HAVE_HYPOTHESIS = False

from repro.core import cpd_als_fused, random_sparse
from repro.serve import BatchedEngine, Bucket, BucketPolicy, pad_tensor


def test_quantum_rounding():
    p = BucketPolicy()                      # quantum=128, min_cap=128
    assert p.nnz_cap(1) == 128
    assert p.nnz_cap(128) == 128
    assert p.nnz_cap(129) == 256
    assert p.nnz_cap(700) == 768
    # worst-case padding fraction is quantum/cap -> small for real streams
    assert Bucket((8, 8, 8), p.nnz_cap(700)).padding_fraction(700) < 0.15


def test_geometric_rounding():
    p = BucketPolicy(mode="geometric", growth=1.5, min_cap=64)
    caps = [p.nnz_cap(n) for n in (1, 64, 65, 100, 1000)]
    assert caps[0] == caps[1] == 64
    assert all(c >= n for c, n in zip(caps, (1, 64, 65, 100, 1000)))
    assert all(b >= a for a, b in zip(caps, caps[1:]))    # monotone
    # bounded relative padding: cap/nnz <= growth (up to ceil rounding)
    assert p.nnz_cap(1000) / 1000 <= 1.5 + 0.01


def test_unknown_mode_raises():
    with pytest.raises(ValueError):
        BucketPolicy(mode="nope").nnz_cap(10)


def test_degenerate_policy_params_rejected():
    with pytest.raises(ValueError):
        BucketPolicy(mode="geometric", growth=1.0)    # would loop forever
    with pytest.raises(ValueError):
        BucketPolicy(quantum=0)


def test_bucket_for_groups_same_shape_and_cap():
    p = BucketPolicy()
    a = random_sparse((20, 12, 8), 400, seed=0)
    b = random_sparse((20, 12, 8), 390, seed=1)
    c = random_sparse((20, 12, 9), 400, seed=2)   # different shape
    assert p.bucket_for(a) == p.bucket_for(b) == Bucket((20, 12, 8), 512)
    assert p.bucket_for(c) != p.bucket_for(a)


def test_pad_tensor_appends_zero_entries_at_origin():
    t = random_sparse((15, 11, 7), 200, seed=3)
    padded = pad_tensor(t, 256)
    assert padded.nnz == 256 and padded.shape == t.shape
    assert np.array_equal(padded.indices[:200], t.indices)
    assert np.array_equal(padded.values[:200], t.values)
    assert np.all(padded.indices[200:] == 0)
    assert np.all(padded.values[200:] == 0.0)
    assert pad_tensor(t, t.nnz) is t              # no-op passthrough
    with pytest.raises(ValueError):
        pad_tensor(t, 100)


def _padding_invariance_case(nnz: int, seed: int, backend: str):
    """Factors from the padded tensor are BIT-identical to the unpadded
    ones: zero entries at the origin add exactly +0.0 to every
    accumulation, and all layout sorts are stable."""
    t = random_sparse((14, 11, 9), nnz, seed=seed, distribution="powerlaw")
    kw = dict(rank=3, kappa=2, n_iters=3, tol=-1.0, seed=seed,
              backend=backend)
    a = cpd_als_fused(t, **kw)
    b = cpd_als_fused(pad_tensor(t, 256), **kw)
    for Fa, Fb in zip(a.factors, b.factors):
        assert np.array_equal(Fa, Fb)
    assert np.array_equal(a.weights, b.weights)


if HAVE_HYPOTHESIS:
    @settings(max_examples=8, deadline=None)
    @given(st.sampled_from([180, 200, 230]), st.integers(0, 5),
           st.sampled_from(["segment", "coo"]))
    def test_property_padding_invariance(nnz, seed, backend):
        _padding_invariance_case(nnz, seed, backend)
else:
    @pytest.mark.parametrize("nnz,seed,backend",
                             [(180, 0, "segment"), (200, 3, "coo"),
                              (230, 5, "segment")])
    def test_property_padding_invariance(nnz, seed, backend):
        """Fixed-example fallback when hypothesis is unavailable."""
        _padding_invariance_case(nnz, seed, backend)


def test_batched_engine_padding_invariant():
    """The vmapped engine gives the same bits whether a tensor fills its
    bucket exactly or is padded up to it."""
    t = random_sparse((14, 11, 9), 200, seed=7, distribution="powerlaw")
    eng = BatchedEngine(rank=3, kappa=2, backend="segment", check_every=2)
    exact = eng.decompose_batch([t], n_iters=3, tol=-1.0, seeds=[1])[0]
    padded = eng.decompose_batch([t], n_iters=3, tol=-1.0, seeds=[1],
                                 nnz_cap=256)[0]
    for Fa, Fb in zip(exact.factors, padded.factors):
        assert np.array_equal(Fa, Fb)
