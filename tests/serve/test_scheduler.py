"""Micro-batching scheduler: flush triggers (max-batch, max-wait, forced),
mixed-bucket streams, future semantics, metrics."""
import numpy as np
import pytest

from repro.core import random_sparse
from repro.serve import (BatchedEngine, BatchScheduler, BucketPolicy,
                         ServiceMetrics)

SHAPE_A = (12, 9, 7)
SHAPE_B = (16, 6, 5)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def make_scheduler(max_batch=3, max_wait_s=1.0):
    clock = FakeClock()
    sched = BatchScheduler(
        BatchedEngine(rank=3, kappa=2, backend="segment", check_every=2),
        policy=BucketPolicy(), max_batch=max_batch, max_wait_s=max_wait_s,
        metrics=ServiceMetrics(), clock=clock)
    return sched, clock


def tensors(shape, n, nnz=100):
    return [random_sparse(shape, nnz, seed=100 + i) for i in range(n)]


def test_max_batch_trigger():
    """The max_batch-th submit flushes the bucket synchronously."""
    sched, _ = make_scheduler(max_batch=3, max_wait_s=1e9)
    futs = [sched.submit(t, n_iters=2, tol=-1.0)
            for t in tensors(SHAPE_A, 3)]
    assert all(f.done() for f in futs)
    assert sched.pending() == 0
    snap = sched.metrics.snapshot()
    assert snap["flush_triggers"]["max_batch"] == 1
    assert snap["batch_occupancy"] == 1.0
    assert snap["completed"] == 3


def test_max_wait_trigger_via_poll():
    """poll() flushes a bucket once its oldest request has waited
    max_wait_s, and not before."""
    sched, clock = make_scheduler(max_batch=8, max_wait_s=1.0)
    (fut,) = [sched.submit(t, n_iters=2, tol=-1.0)
              for t in tensors(SHAPE_A, 1)]
    assert sched.poll() == 0 and not fut.done()      # not expired yet
    clock.advance(1.5)
    assert sched.poll() == 1 and fut.done()
    assert sched.metrics.snapshot()["flush_triggers"]["max_wait"] == 1


def test_max_wait_checked_on_submit():
    """A submit into bucket B flushes an expired bucket A (no dedicated
    poller needed under steady traffic)."""
    sched, clock = make_scheduler(max_batch=8, max_wait_s=1.0)
    fut_a = sched.submit(tensors(SHAPE_A, 1)[0], n_iters=2, tol=-1.0)
    clock.advance(2.0)
    fut_b = sched.submit(tensors(SHAPE_B, 1)[0], n_iters=2, tol=-1.0)
    assert fut_a.done()
    assert not fut_b.done() and sched.pending() == 1


def test_mixed_bucket_stream():
    """Different shapes land in different queues and never co-batch."""
    sched, _ = make_scheduler(max_batch=2, max_wait_s=1e9)
    a = tensors(SHAPE_A, 2)
    b = tensors(SHAPE_B, 2)
    fa1 = sched.submit(a[0], n_iters=2, tol=-1.0)
    fb1 = sched.submit(b[0], n_iters=2, tol=-1.0)
    assert not fa1.done() and not fb1.done()
    fa2 = sched.submit(a[1], n_iters=2, tol=-1.0)   # bucket A reaches 2
    assert fa1.done() and fa2.done() and not fb1.done()
    fb2 = sched.submit(b[1], n_iters=2, tol=-1.0)   # bucket B reaches 2
    assert fb1.done() and fb2.done()
    # each request got factors of ITS OWN shape back
    for fut, t in zip((fa1, fb1, fa2, fb2), (a[0], b[0], a[1], b[1])):
        res = fut.result()
        assert [F.shape[0] for F in res.factors] == list(t.shape)
    snap = sched.metrics.snapshot()
    assert snap["batches"] == 2 and snap["completed"] == 4


def test_result_forces_flush():
    """future.result() never deadlocks: it force-flushes its bucket."""
    sched, _ = make_scheduler(max_batch=8, max_wait_s=1e9)
    fut = sched.submit(tensors(SHAPE_A, 1)[0], n_iters=2, tol=-1.0)
    assert not fut.done()
    res = fut.result()
    assert res.engine == "batched" and res.iters == 2
    assert sched.metrics.snapshot()["flush_triggers"]["forced"] == 1


def test_flush_drains_in_max_batch_chunks():
    sched, _ = make_scheduler(max_batch=2, max_wait_s=1e9)
    futs = [sched.submit(t, n_iters=2, tol=-1.0)
            for t in tensors(SHAPE_A, 5)]
    # submits auto-flushed at 2 and 4; one request still queued
    assert sched.pending() == 1
    assert sched.flush() == 1
    assert all(f.done() for f in futs)
    assert sched.metrics.snapshot()["batches"] == 3


def test_metrics_padding_overhead_and_latency():
    sched, clock = make_scheduler(max_batch=2, max_wait_s=1e9)
    ts = tensors(SHAPE_A, 2, nnz=100)      # bucket cap = 128 -> 28/128 pad
    sched.submit(ts[0], n_iters=2, tol=-1.0)
    clock.advance(0.25)
    sched.submit(ts[1], n_iters=2, tol=-1.0)
    snap = sched.metrics.snapshot()
    np.testing.assert_allclose(snap["padding_overhead"], 28 / 128)
    # first request waited 0.25 fake-seconds, second ~0 (p99 interpolates)
    assert snap["latency_p99_s"] >= 0.24
    # cache counters recorded (cold bucket compiles; warm bucket hits —
    # earlier tests in this module may have warmed the class already)
    assert snap["cache_hits"] + snap["cache_misses"] >= 1


def test_result_timeout_does_not_flush():
    """result(timeout=...) is a bounded wait for someone else's flush —
    it must raise on expiry, not silently run the batch itself."""
    sched, _ = make_scheduler(max_batch=8, max_wait_s=1e9)
    fut = sched.submit(tensors(SHAPE_A, 1)[0], n_iters=2, tol=-1.0)
    with pytest.raises(TimeoutError):
        fut.result(timeout=0.01)
    assert sched.pending() == 1            # still queued, not flushed
    assert fut.result().iters == 2         # unbounded result() flushes


def test_runner_falls_back_to_sequential_for_unbatchable_configs():
    """Configurations the batched engine can't serve keep working through
    the sequential path instead of failing construction; pallas is
    batchable now (core.plan slab caps)."""
    from repro.runtime import ALSRunner

    assert ALSRunner(rank=3).mode == "batched"
    assert ALSRunner(rank=3, backend="pallas").mode == "batched"
    assert ALSRunner(rank=3, engine="host").mode == "sequential"
    with pytest.raises(ValueError):
        ALSRunner(rank=3, engine="host", mode="batched")


def test_cross_bucket_aging_prevents_starvation():
    """A lone request in a quiet bucket must flush even while a busy
    bucket keeps claiming the device with full batches: its aging score
    grows without bound, so some later submit/poll hands it the device
    (starvation freedom of the cross-bucket policy)."""
    sched, clock = make_scheduler(max_batch=2, max_wait_s=10.0)
    lone = sched.submit(tensors(SHAPE_B, 1)[0], n_iters=2, tol=-1.0)
    rounds = 0
    while not lone.done():
        assert rounds < 20, "lone request starved by busy bucket"
        for t in tensors(SHAPE_A, 2):        # busy bucket: full batches
            sched.submit(t, n_iters=2, tol=-1.0)
        clock.advance(1.0)
        rounds += 1
    # flushed by the aging term well before max_wait alone would trigger
    # (age < 10 s when it completed), via a busy-bucket submit.
    assert rounds <= 11
    assert sched.metrics.snapshot()["flush_triggers"]["aging"] >= 1
    assert lone.result().iters == 2


def test_neediest_bucket_flushes_first():
    """When several buckets are ready at once, the highest-scoring one
    (oldest wait here) is executed first."""
    order = []

    class Spy:
        rank = 3
        mesh = None
        num_devices = 1

        # The flush path is split into a host half and a device half;
        # the spy mirrors both seams.
        def prepare_batch(self, ts, **kw):
            order.append(tuple(ts[0].shape))
            return [_fake_result(t) for t in ts]

        def execute_prepared(self, prep):
            return prep

    def _fake_result(t):
        from repro.core.cpd import CPDResult
        return CPDResult(factors=[np.zeros((s, 3)) for s in t.shape],
                         weights=np.ones(3), fits=[0.0], iters=1,
                         mttkrp_seconds=0.0, total_seconds=0.0)

    clock = FakeClock()
    sched = BatchScheduler(Spy(), policy=BucketPolicy(), max_batch=8,
                           max_wait_s=1.0, metrics=ServiceMetrics(),
                           clock=clock)
    sched.submit(tensors(SHAPE_A, 1)[0], n_iters=1, tol=-1.0)
    clock.advance(0.5)
    sched.submit(tensors(SHAPE_B, 1)[0], n_iters=1, tol=-1.0)
    clock.advance(2.0)                       # both expired; A waited longer
    assert sched.poll() == 2
    assert order == [SHAPE_A, SHAPE_B]


def test_engine_error_delivered_via_futures_not_caller():
    """An engine failure belongs to the batch's futures (executor
    semantics); the caller whose submit/flush triggered it still gets its
    own future back."""
    sched, _ = make_scheduler(max_batch=8, max_wait_s=1e9)
    fut = sched.submit(tensors(SHAPE_A, 1)[0], n_iters=2, tol=-1.0)

    def boom(*a, **k):
        raise RuntimeError("engine down")

    sched.engine.prepare_batch = boom      # host half of the flush
    assert sched.flush() == 1              # flush itself does not raise
    assert fut.done()
    with pytest.raises(RuntimeError, match="engine down"):
        fut.result()

    # The device half fails the same way: futures, not the caller.
    sched2, _ = make_scheduler(max_batch=8, max_wait_s=1e9)
    fut2 = sched2.submit(tensors(SHAPE_A, 1)[0], n_iters=2, tol=-1.0)
    sched2.engine.execute_prepared = boom
    assert sched2.flush() == 1
    with pytest.raises(RuntimeError, match="engine down"):
        fut2.result()


def test_per_request_options_survive_batching():
    """n_iters/tol/seed are per-request even when co-batched."""
    sched, _ = make_scheduler(max_batch=2, max_wait_s=1e9)
    ts = tensors(SHAPE_A, 2)
    f1 = sched.submit(ts[0], n_iters=2, tol=-1.0, seed=5)
    f2 = sched.submit(ts[1], n_iters=4, tol=-1.0, seed=6)
    assert f1.result().iters == 2
    assert f2.result().iters == 4
