"""Pod path of the batched engine: mesh-sharded batch axis, on-device
convergence (one dispatch per multi-window run), mesh-multiple padding,
and the double-buffered scheduler flush.

Fast cells run in process on a 1-device batch mesh (pod machinery with
the degenerate mesh must reproduce the host-judged loop); the 8-device
cells spawn a forced-host-device subprocess (jax pins its device count at
first init) and are marked ``slow``.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import random_sparse
from repro.launch.mesh import make_batch_mesh
from repro.serve import BatchedEngine
from repro.serve.scheduler import DecompositionService

REPO = os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))))

SHAPE = (18, 13, 9)


def _stream(n=5, nnz=480):
    return [random_sparse(SHAPE, nnz - 17 * i, seed=i,
                          distribution="powerlaw") for i in range(n)]


def test_pod_one_device_matches_batched():
    """Degenerate pod (mesh of 1): the shard_map + on-device while_loop
    dispatch must agree with the host-judged window loop to fp32 — same
    freeze masking, same per-lane iteration caps, ONE host sync."""
    ts = _stream()
    iters = [10, 6, 10, 25, 25]
    plain = BatchedEngine(rank=3, kappa=2, backend="segment", check_every=4)
    ref = plain.decompose_batch(ts, n_iters=iters, tol=-1.0,
                                seeds=list(range(5)), nnz_cap=512)
    pod = BatchedEngine(rank=3, kappa=2, backend="segment", check_every=4,
                        mesh=make_batch_mesh(1))
    res = pod.decompose_batch(ts, n_iters=iters, tol=-1.0,
                              seeds=list(range(5)), nnz_cap=512)
    assert [r.engine for r in res] == ["pod"] * 5
    assert all(r.host_syncs == 1 for r in res)
    assert [r.iters for r in res] == [r.iters for r in ref]
    for a, b in zip(res, ref):
        np.testing.assert_allclose(a.fits, b.fits, rtol=1e-5, atol=1e-5)
        for Fa, Fb in zip(a.factors, b.factors):
            np.testing.assert_allclose(Fa, Fb, rtol=1e-4, atol=1e-4)


def test_pod_mesh_multiple_padding_is_invisible():
    """B=3 requests on a quantum-2 pod dispatch 4 lanes; the repeated
    trailing request is discarded and the kept results match an unpadded
    single-device run (repeat-pad lanes are independent under vmap)."""
    ts = _stream(n=3)
    plain = BatchedEngine(rank=3, kappa=2, backend="segment", check_every=2)
    ref = plain.decompose_batch(ts, n_iters=4, tol=-1.0, seeds=[7, 8, 9],
                                nnz_cap=512)
    pod = BatchedEngine(rank=3, kappa=2, backend="segment", check_every=2,
                        mesh=make_batch_mesh(1), batch_quantum=2)
    res = pod.decompose_batch(ts, n_iters=4, tol=-1.0, seeds=[7, 8, 9],
                              nnz_cap=512)
    assert len(res) == 3
    for a, b in zip(res, ref):
        np.testing.assert_allclose(a.fits, b.fits, rtol=1e-5, atol=1e-5)
        for Fa, Fb in zip(a.factors, b.factors):
            np.testing.assert_allclose(Fa, Fb, rtol=1e-4, atol=1e-4)


def test_double_buffered_service_matches_sync():
    """The async dispatch path resolves every future with results
    bit-identical to the synchronous flush (same executables, same
    lanes), and the dispatch gauges witness assembly/execute overlap."""
    ts = [random_sparse(SHAPE, 400, seed=i, distribution="powerlaw")
          for i in range(12)]

    def run(double_buffer):
        svc = DecompositionService(rank=3, max_batch=4,
                                   double_buffer=double_buffer)
        futs = [svc.submit(t, n_iters=6, tol=-1.0, seed=i)
                for i, t in enumerate(ts)]
        svc.drain()
        return [f.result() for f in futs], svc.snapshot()

    res_sync, snap_sync = run(False)
    res_db, snap_db = run(True)
    for a, b in zip(res_sync, res_db):
        for Fa, Fb in zip(a.factors, b.factors):
            assert np.array_equal(np.asarray(Fa), np.asarray(Fb))
    d = snap_db["dispatch"]
    assert d["count"] == snap_db["batches"] == 3
    assert d["execute_s"] > 0 and d["assembly_s"] > 0
    # Pipelining witness: some of flush N+1's host assembly ran while
    # flush N's device half was still executing.
    assert d["overlap_s"] > 0 and d["overlap_fraction"] > 0
    assert d["device_dispatches"] == {0: 3}
    # The sync path keeps the gauges too, but by construction assembly
    # and execute never overlap (one thread does both in sequence).
    assert snap_sync["dispatch"]["count"] == 3
    assert snap_sync["dispatch"]["overlap_s"] == 0.0


def _run_pod(code: str, devices: int = 8, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


@pytest.mark.slow
@pytest.mark.parametrize("method", ["cp", "nncp", "masked"])
def test_pod_8dev_matches_single_device(method):
    """The acceptance cell: an 8-virtual-device pod dispatch (mesh-
    sharded batch axis + on-device while_loop convergence) produces
    fp32-identical factors to the single-device batched engine, for
    every method, with bucket zero-padding AND mesh-multiple lane
    padding both in play (B=6 real requests -> 8 lanes)."""
    out = _run_pod(f"""
        import numpy as np
        from repro.core import SparseTensor, random_sparse
        from repro.launch.mesh import make_batch_mesh
        from repro.serve import BatchedEngine

        method = {method!r}
        ts = [random_sparse((18, 13, 9), 480 - 17 * i, seed=i,
                            distribution="powerlaw") for i in range(6)]
        if method == "nncp":
            ts = [SparseTensor(t.indices, np.abs(t.values) + 0.1, t.shape)
                  for t in ts]
        iters = [8, 5, 8, 8, 3, 8]
        kw = dict(n_iters=iters, tol=-1.0, seeds=list(range(6)),
                  nnz_cap=512, method=method)

        plain = BatchedEngine(rank=3, kappa=2, backend="segment",
                              check_every=4)
        ref = plain.decompose_batch(ts, **kw)
        pod = BatchedEngine(rank=3, kappa=2, backend="segment",
                            check_every=4, mesh=make_batch_mesh(8))
        res = pod.decompose_batch(ts, **kw)

        assert len(res) == 6
        assert all(r.engine == "pod" for r in res)
        assert all(r.host_syncs == 1 for r in res), \\
            [r.host_syncs for r in res]
        assert [r.iters for r in res] == [r.iters for r in ref]
        for a, b in zip(res, ref):
            np.testing.assert_allclose(a.fits, b.fits, rtol=1e-4, atol=1e-4)
            for Fa, Fb in zip(a.factors, b.factors):
                np.testing.assert_allclose(Fa, Fb, rtol=1e-3, atol=1e-3)
        print("PASS", method, res[0].fits[-1])
    """)
    assert "PASS" in out


@pytest.mark.slow
def test_pod_8dev_single_dispatch_trace():
    """A multi-window pod decomposition is ONE device dispatch: the obs
    trace shows exactly one ``pod.dispatch`` span and a ``pod.window``
    event reporting every window ran on device (no intermediate host
    round-trips)."""
    out = _run_pod("""
        from repro.core import random_sparse
        from repro.launch.mesh import make_batch_mesh
        from repro.obs import trace as obs_trace
        from repro.serve import BatchedEngine

        ts = [random_sparse((18, 13, 9), 480, seed=i,
                            distribution="powerlaw") for i in range(8)]
        pod = BatchedEngine(rank=3, kappa=2, backend="segment",
                            check_every=2, mesh=make_batch_mesh(8))
        with obs_trace.capture() as tr:
            res = pod.decompose_batch(ts, n_iters=10, tol=-1.0,
                                      seeds=list(range(8)), nnz_cap=512)
        events = tr.records()
        assert all(r.host_syncs == 1 for r in res)
        names = [e["name"] for e in events]
        assert names.count("pod.dispatch") == 1, names
        wins = [e for e in events if e["name"] == "pod.window"]
        assert len(wins) == 1 and wins[0]["args"]["windows"] == 5, wins
        print("PASS", wins[0]["args"])
    """)
    assert "PASS" in out
