"""Cross-path conformance matrix: every method rides every front door.

THE single place that pins the uniformity contract of the methods
subsystem: for each decomposition method (cp / nncp / masked, weighted
and unweighted) the front doors —

  * sequential fused engine   (``cpd_als``)
  * batched service           (``ALSRunner`` -> bucketed vmapped engine)
  * distributed shard_map     (``cpd_als_distributed``, 8 virtual devices)
  * pod batched engine        (batch-axis mesh, on-device convergence)

— must produce fp32-tolerance-identical factors and fits from the same
seed, and request metadata (method, entry weights) must round-trip
unmutated.  The fast cells run sequential-vs-batched across backends in
process; the distributed cells spawn an 8-virtual-device subprocess (jax
pins its device count at first init) and are marked ``slow`` so tier-1
stays fast — CI's distributed job runs them with ``-m slow``.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import cpd_als, cpd_als_fused, random_sparse
from repro.runtime import ALSRunner

REPO = os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))))

SHAPE = (16, 12, 9)

# (method, weighted): the weighted cell exercises the per-entry
# observation-confidence front door end to end.
CASES = [("cp", False), ("nncp", False), ("masked", False),
         ("masked", True)]


def _stream(n=3, seed0=0):
    """Bucket-mates of DIFFERENT nnz, so the service pads every request
    (the conformance claim covers padded execution, not just B=1)."""
    ts = [random_sparse(SHAPE, 380 - 31 * i, seed=seed0 + i,
                        distribution="powerlaw") for i in range(n)]
    rng = np.random.default_rng(42)
    ws = [rng.uniform(0.25, 1.75, t.nnz).astype(np.float32) for t in ts]
    return ts, ws


def _maybe_pos(t, method):
    """nncp wants nonnegative data for a meaningful (still conformant)
    trajectory."""
    if method != "nncp":
        return t
    from repro.core import SparseTensor

    return SparseTensor(t.indices, np.abs(t.values) + 0.1, t.shape)


@pytest.mark.parametrize("backend", ["segment", "coo"])
@pytest.mark.parametrize("method,weighted", CASES)
def test_sequential_vs_batched_service(method, weighted, backend):
    ts, ws = _stream()
    runner = ALSRunner(rank=3, kappa=2, backend=backend, check_every=2)
    for i, t in enumerate(ts):
        t = _maybe_pos(t, method)
        w = ws[i].copy() if weighted else None
        w_before = None if w is None else w.copy()
        res = runner.decompose(t, n_iters=4, tol=-1.0, seed=7 + i,
                               method=method, weights=w)
        ref = cpd_als(t, 3, kappa=2, n_iters=4, tol=-1.0, seed=7 + i,
                      backend=backend, check_every=2, method=method,
                      weights=w)
        np.testing.assert_allclose(res.fits, ref.fits, rtol=1e-5, atol=1e-5)
        for Fb, Fr in zip(res.factors, ref.factors):
            np.testing.assert_allclose(Fb, Fr, rtol=1e-4, atol=1e-4)
        # Metadata round-trip: the result names its method and front
        # door, and the caller's weight vector is never mutated.
        assert res.method == method and ref.method == method
        assert res.engine == "batched" and ref.engine == "fused"
        if w is not None:
            np.testing.assert_array_equal(w, w_before)


@pytest.mark.parametrize("method,weighted",
                         [("masked", False), ("masked", True)])
def test_sequential_vs_batched_service_pallas(method, weighted):
    """One pallas column of the matrix (interpret mode is slow on CPU, so
    only the masked rows — the valued-scatter path — run here; plain-CP
    pallas batching is pinned bit-exactly in tests/core/test_plan.py)."""
    ts, ws = _stream(n=2)
    runner = ALSRunner(rank=3, kappa=2, backend="pallas", check_every=2)
    for i, t in enumerate(ts):
        w = ws[i] if weighted else None
        res = runner.decompose(t, n_iters=3, tol=-1.0, seed=1 + i,
                               method=method, weights=w)
        ref = cpd_als_fused(t, 3, kappa=2, n_iters=3, tol=-1.0, seed=1 + i,
                            backend="segment", check_every=2, method=method,
                            weights=w)
        np.testing.assert_allclose(res.fits, ref.fits, rtol=1e-4, atol=1e-4)
        for Fb, Fr in zip(res.factors, ref.factors):
            np.testing.assert_allclose(Fb, Fr, rtol=1e-3, atol=1e-3)


def _run_dist(code: str, devices: int = 8, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


@pytest.mark.slow
@pytest.mark.parametrize("method,weighted", CASES)
def test_all_three_front_doors_agree(method, weighted):
    """The acceptance matrix: sequential fused, batched service, and the
    8-virtual-device distributed engine produce fp32-tolerance-identical
    factors for every method, weighted masked included.  The tensor's
    smallest mode (I_d = 6 < 8 devices) forces scheme 2 on one mode, so
    the matrix covers both load-balancing schemes' collectives."""
    out = _run_dist(f"""
        import numpy as np
        from repro.core import SparseTensor, cpd_als, random_sparse
        from repro.core.distributed import cpd_als_distributed
        from repro.runtime import ALSRunner

        method, weighted = {method!r}, {weighted!r}
        t = random_sparse((48, 32, 6), 1500, seed=5,
                          distribution="powerlaw")
        if method == "nncp":
            t = SparseTensor(t.indices, np.abs(t.values) + 0.1, t.shape)
        w = (np.random.default_rng(1)
             .uniform(0.25, 1.75, t.nnz).astype(np.float32)
             if weighted else None)

        seq = cpd_als(t, 4, n_iters=6, tol=-1.0, seed=2, check_every=3,
                      method=method, weights=w)
        runner = ALSRunner(rank=4, backend="segment", check_every=3)
        bat = runner.decompose(t, n_iters=6, tol=-1.0, seed=2,
                               method=method, weights=w)
        dist = cpd_als_distributed(t, rank=4, n_iters=6, tol=-1.0, seed=2,
                                   check_every=3, method=method, weights=w)

        assert (seq.engine, bat.engine, dist.engine) == (
            "fused", "batched", "distributed")
        assert seq.method == bat.method == dist.method == method
        for name, res in (("batched", bat), ("distributed", dist)):
            np.testing.assert_allclose(res.fits, seq.fits,
                                       rtol=1e-4, atol=1e-4, err_msg=name)
            for Fa, Fb in zip(res.factors, seq.factors):
                np.testing.assert_allclose(Fa, Fb, rtol=1e-3, atol=1e-3,
                                           err_msg=name)
        print("PASS", method, weighted, seq.fits[-1])
    """)
    assert "PASS" in out


@pytest.mark.slow
@pytest.mark.parametrize("method", ["cp", "nncp", "masked"])
def test_pod_front_door_matches_batched(method):
    """Fourth front door: the mesh-sharded pod engine (8 virtual devices,
    batch axis sharded, convergence judged on device in one dispatch)
    matches the single-device batched engine to fp32 for every method.
    B=6 real requests of DIFFERENT nnz exercise bucket zero-padding AND
    the mesh-multiple repeat-pad (6 -> 8 lanes) simultaneously."""
    out = _run_dist(f"""
        import numpy as np
        from repro.core import SparseTensor, random_sparse
        from repro.launch.mesh import make_batch_mesh
        from repro.serve import BatchedEngine

        method = {method!r}
        ts = [random_sparse((16, 12, 9), 380 - 31 * i, seed=i,
                            distribution="powerlaw") for i in range(6)]
        if method == "nncp":
            ts = [SparseTensor(t.indices, np.abs(t.values) + 0.1, t.shape)
                  for t in ts]
        kw = dict(n_iters=6, tol=-1.0, seeds=[7 + i for i in range(6)],
                  nnz_cap=384, method=method)

        plain = BatchedEngine(rank=3, kappa=2, backend="segment",
                              check_every=3)
        ref = plain.decompose_batch(ts, **kw)
        pod = BatchedEngine(rank=3, kappa=2, backend="segment",
                            check_every=3, mesh=make_batch_mesh(8))
        res = pod.decompose_batch(ts, **kw)

        assert len(res) == 6 and all(r.engine == "pod" for r in res)
        assert all(r.method == method for r in res)
        assert all(r.host_syncs == 1 for r in res)
        for a, b in zip(res, ref):
            np.testing.assert_allclose(a.fits, b.fits, rtol=1e-4, atol=1e-4)
            for Fa, Fb in zip(a.factors, b.factors):
                np.testing.assert_allclose(Fa, Fb, rtol=1e-3, atol=1e-3)
        print("PASS", method, res[0].fits[-1])
    """)
    assert "PASS" in out


@pytest.mark.slow
def test_distributed_weight0_equals_absent():
    """The weight-0 exactness mechanism holds on the distributed path
    too: zeroing an entry's weight matches (to fp32 shard tolerance)
    removing the entry — even though the two runs shard differently."""
    out = _run_dist("""
        import numpy as np
        from repro.core import SparseTensor, random_sparse
        from repro.core.distributed import cpd_als_distributed

        t = random_sparse((48, 32, 6), 1500, seed=9,
                          distribution="powerlaw")
        rng = np.random.default_rng(3)
        w = rng.uniform(0.25, 1.75, t.nnz).astype(np.float32)
        drop = rng.choice(t.nnz, size=40, replace=False)
        keep = np.ones(t.nnz, bool); keep[drop] = False
        w0 = w.copy(); w0[drop] = 0.0

        a = cpd_als_distributed(t, rank=4, n_iters=5, tol=-1.0, seed=2,
                                check_every=5, method="masked", weights=w0)
        t_red = SparseTensor(t.indices[keep], t.values[keep], t.shape)
        b = cpd_als_distributed(t_red, rank=4, n_iters=5, tol=-1.0, seed=2,
                                check_every=5, method="masked",
                                weights=w[keep])
        for Fa, Fb in zip(a.factors, b.factors):
            np.testing.assert_allclose(Fa, Fb, rtol=1e-3, atol=1e-3)
        print("PASS", a.fits[-1], b.fits[-1])
    """)
    assert "PASS" in out
