"""Dry-run machinery tests: sharding resolution, HLO collective parsing,
roofline terms, and a small-mesh end-to-end dry-run (subprocess)."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
import jax

from repro.launch.hlo_analysis import (_shape_bytes, parse_collectives,
                                       roofline_terms)
from repro.launch import mesh as mesh_mod
from repro.launch.mesh import HW
from repro.models import common as mcommon

REPO = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def test_shape_bytes():
    assert _shape_bytes("bf16[8,128]") == 8 * 128 * 2
    assert _shape_bytes("f32[4]") == 16
    assert _shape_bytes("(f32[2,2], s8[4])") == 16 + 4
    assert _shape_bytes("pred[16]") == 16


def test_parse_collectives_counts_and_wire():
    hlo = """
      %ag = bf16[16,256] all-gather(%x), replica_groups={{0,1,2,3}}, dimensions={0}
      %ar.1 = f32[1024] all-reduce(%y), replica_groups={{0,1}}, to_apply=%add
      %rs = f32[64] reduce-scatter(%z), replica_groups={{0,1,2,3}}
      %cp = bf16[8,8] collective-permute(%w), source_target_pairs={{0,1}}
      %mm = f32[8,8] dot(%a, %b)
    """
    st = parse_collectives(hlo, group_size=4)
    assert st.counts == {"all-gather": 1, "all-reduce": 1,
                         "reduce-scatter": 1, "collective-permute": 1}
    ag = 16 * 256 * 2
    assert st.result_bytes["all-gather"] == ag
    # ring model: AG result*(n-1)/n; AR 2*b*(n-1)/n; RS b*n*(n-1)/n; CP b
    expect = (ag * 3 / 4 + 2 * 4096 * 1 / 2 + 256 * 4 * 3 / 4 + 128)
    assert abs(st.wire_bytes - expect) <= 2


def test_roofline_terms_dominance():
    t = roofline_terms(flops=197e12, hbm_bytes=0, wire_bytes=0, n_chips=1,
                       hw=HW)
    assert t["dominant"] == "compute" and abs(t["t_compute_s"] - 1.0) < 1e-9
    t = roofline_terms(flops=0, hbm_bytes=819e9, wire_bytes=1, n_chips=1,
                       hw=HW)
    assert t["dominant"] == "memory"
    t = roofline_terms(flops=1, hbm_bytes=1, wire_bytes=50e9, n_chips=1,
                       hw=HW)
    assert t["dominant"] == "collective"


def test_resolve_pspec_rules():
    mesh = mesh_mod.make_mesh((1, 1), ("data", "model"))
    mcommon.reset_rules()
    # divisible -> sharded; non-divisible -> dropped; duplicates -> dropped
    spec = mcommon.resolve_pspec(("fsdp", "tensor"), (16, 16), mesh)
    assert spec == jax.sharding.PartitionSpec("data", "model")
    spec = mcommon.resolve_pspec(("experts", "fsdp", "tensor"), (4, 8, 8), mesh)
    assert spec == jax.sharding.PartitionSpec("model", "data", None)
    spec = mcommon.resolve_pspec(("tensor",), (7,), mesh)  # 7 % 1 == 0
    assert spec == jax.sharding.PartitionSpec("model")


def test_resolve_pspec_divisibility():
    mesh = mesh_mod.make_mesh((1,), ("model",))
    import jax.sharding as js
    mcommon.reset_rules()
    # 24 heads on 16-way axis would not divide on a real 16-mesh; emulate
    # via direct check of the helper logic with a fake avail
    spec = mcommon.resolve_pspec(("tensor", None), (24, 3), mesh)
    assert spec == js.PartitionSpec("model", None)


@pytest.mark.slow
def test_small_mesh_dryrun_end_to_end():
    """Full dry-run path on an 8-device 'production-shaped' mesh."""
    code = """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import repro.launch.dryrun as dr
        import repro.launch.mesh as mesh_mod
        import jax
        def small_mesh(*, multi_pod=False):
            shape = (2, 2, 2) if multi_pod else (4, 2)
            axes = ("pod", "data", "model") if multi_pod else ("data", "model")
            return mesh_mod.make_mesh(shape, axes)
        dr.make_production_mesh = small_mesh
        import dataclasses
        from repro.configs import get_config, reduce_config
        real_get = dr.get_config
        dr.get_config = lambda a: dataclasses.replace(
            reduce_config(real_get(a)), num_layers=6)
        for mp in (False, True):
            r = dr.run_cell("internvl2-1b", "train_4k", multi_pod=mp)
            assert "error" not in r, r
            assert r["hlo_flops_per_chip"] > 0
            assert r["collective_wire_bytes_per_chip"] >= 0
            assert r["roofline"]["dominant"] in ("compute", "memory", "collective")
            print("MESH", r["mesh"], "OK", r["roofline"]["dominant"])
        print("PASS")
    """
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=1800)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    assert "PASS" in out.stdout
