"""AdamW from scratch (no optax): pytree-native, mixed-precision aware.

Moments are fp32 regardless of param dtype (bf16 training keeps master
statistics in fp32 — standard large-scale practice).  Supports decoupled
weight decay, global-norm gradient clipping, and warmup+cosine schedules.
Optimizer state inherits the parameter sharding (launch/shardings.py),
so ZeRO-style partitioning falls out of FSDP param sharding for free.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    schedule: str = "cosine"   # cosine | constant | linear


def lr_at(cfg: AdamWConfig, step):
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        decay = 1.0
    else:
        t = jnp.clip(
            (step - cfg.warmup_steps)
            / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
            0.0, 1.0,
        )
        if cfg.schedule == "linear":
            decay = 1.0 - (1.0 - cfg.min_lr_ratio) * t
        else:
            decay = cfg.min_lr_ratio + 0.5 * (1 - cfg.min_lr_ratio) * (
                1 + jnp.cos(jnp.pi * t)
            )
    return cfg.lr * warm * decay


def init_state(params) -> dict:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros32, params),
        "nu": jax.tree.map(zeros32, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(cfg: AdamWConfig, params, grads, state,
                  *, decay_mask: Callable[[Any], bool] | None = None):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip else 1.0
    lr = lr_at(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g32 = g.astype(jnp.float32) * scale
        mu2 = cfg.b1 * mu + (1 - cfg.b1) * g32
        nu2 = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g32)
        mhat = mu2 / b1c
        nhat = nu2 / b2c
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        wd = cfg.weight_decay if p.ndim >= 2 else 0.0   # no decay on norms/bias
        p2 = p.astype(jnp.float32) - lr * (delta + wd * p.astype(jnp.float32))
        return p2.astype(p.dtype), mu2, nu2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    new_p, new_mu, new_nu = [], [], []
    for p, g, mu, nu in zip(flat_p, flat_g, flat_mu, flat_nu):
        a, b, c = upd(p, g, mu, nu)
        new_p.append(a); new_mu.append(b); new_nu.append(c)
    return (
        jax.tree.unflatten(treedef, new_p),
        {"mu": jax.tree.unflatten(treedef, new_mu),
         "nu": jax.tree.unflatten(treedef, new_nu),
         "step": step},
        {"grad_norm": gnorm, "lr": lr},
    )
