"""Optimizer substrate: AdamW (from scratch), schedules, gradient
compression for the cross-pod hop."""
from .adamw import AdamWConfig, apply_updates, global_norm, init_state, lr_at
from .compress import compressed_psum_leaf, cross_pod_mean, dequantize, quantize

__all__ = [
    "AdamWConfig", "apply_updates", "global_norm", "init_state", "lr_at",
    "compressed_psum_leaf", "cross_pod_mean", "dequantize", "quantize",
]
