"""Int8 error-feedback gradient compression for the cross-pod (DCN) hop.

At multi-pod scale the per-step gradient all-reduce over the data-center
network dominates; int8 quantization with error feedback (residual carried
to the next step) cuts DCN bytes 4x vs fp32 / 2x vs bf16 at negligible
fit cost [Seide et al. 2014; 1-bit Adam lineage].

Used via shard_map over the 'pod' axis only: within-pod reduction stays
full precision (ICI is cheap), the compressed psum crosses pods.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
try:
    from jax import shard_map
except ImportError:  # older jax keeps shard_map under experimental
    from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def quantize(x, axis=None):
    amax = jnp.max(jnp.abs(x), keepdims=True) if axis is None else \
        jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, 1e-20) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_psum_leaf(g, err, axis_name: str):
    """Error-feedback int8 psum of one leaf across ``axis_name``.

    The wire payload is the int8 tensor + one fp32 scale per pod (a real
    deployment all-gathers the scales — bytes ≈ nnz + 4·npods); the
    quantization error is carried into the next step (error feedback), so
    the scheme is unbiased over time.  Returns (mean grad fp32, residual).
    """
    g32 = g.astype(jnp.float32) + err
    q, scale = quantize(g32)
    deq = dequantize(q, scale)
    new_err = g32 - deq
    total_deq = jax.lax.psum(deq, axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    return total_deq / n, new_err


def cross_pod_mean(grads, err_state, mesh, *, compress: bool = True,
                   axis_name: str = "pod"):
    """Mean gradients across the pod axis, optionally int8-compressed with
    error feedback.  grads/err_state are pytrees; returns (grads, new_err)."""
    if axis_name not in mesh.axis_names:
        return grads, err_state

    every = P(*[None] * 0)  # replicated-in, replicated-out per pod shard

    def body(g, e):
        if not compress:
            n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
            return jax.lax.psum(g.astype(jnp.float32), axis_name) / n, e
        return compressed_psum_leaf(g, e, axis_name)

    def tree_body(gt, et):
        outs = jax.tree.map(body, gt, et)
        gs = jax.tree.map(lambda t: t[0], outs, is_leaf=lambda x: isinstance(x, tuple))
        es = jax.tree.map(lambda t: t[1], outs, is_leaf=lambda x: isinstance(x, tuple))
        return gs, es

    fn = shard_map(
        tree_body, mesh=mesh,
        in_specs=(every, every), out_specs=(every, every),
    )
    return fn(grads, err_state)
