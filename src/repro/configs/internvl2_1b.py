"""InternVL2-1B [arXiv:2404.16821] — InternViT-300M + Qwen2-0.5B LM.

LM backbone: 24L, d_model 896, 14 heads (GQA kv=2, head_dim 64), SwiGLU
d_ff 4864, vocab 151655, QKV bias (Qwen2).  ViT frontend is a STUB per
the assignment: input_specs supplies 256 projected patch embeddings.
"""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    arch="internvl2-1b",
    family="vlm",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151_655,
    qkv_bias=True,
    activation="swiglu",
    num_prefix_tokens=256,
    rope_theta=1_000_000.0,
)
