"""Qwen1.5-32B [hf:Qwen/Qwen1.5-32B; config family verified via Qwen1.5-0.5B].

Dense decoder with QKV bias: 64L, d_model 5120, 40 heads (kv=40,
head_dim 128), SwiGLU d_ff 27392, vocab 152064.
"""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    arch="qwen1.5-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=40,
    head_dim=128,
    d_ff=27392,
    vocab_size=152_064,
    qkv_bias=True,
    activation="swiglu",
    rope_theta=1_000_000.0,
)
