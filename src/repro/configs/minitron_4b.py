"""Minitron-4B — width-pruned Nemotron-4 [arXiv:2407.14679; hf].

Dense decoder: 32L, d_model 3072, 24 heads (GQA kv=8, head_dim 128),
d_ff 9216 with squared-ReLU (Nemotron family), vocab 256000.
"""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    arch="minitron-4b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    head_dim=128,
    d_ff=9216,
    vocab_size=256_000,
    activation="relu2",
    norm="rmsnorm",
    rope_theta=10_000.0,
)
