"""Whisper-large-v3 [arXiv:2212.04356] — encoder-decoder audio backbone.

32 encoder + 32 decoder layers, d_model 1280, 20 heads (MHA, head_dim
64), GELU d_ff 5120, vocab 51866, sinusoidal positions, LayerNorm.
Conv frontend is a STUB per the assignment: input_specs supplies
precomputed mel-frame embeddings (B, 1500, 1280).
"""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    arch="whisper-large-v3",
    family="encdec",
    num_layers=32,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab_size=51_866,
    activation="gelu",
    norm="layernorm",
    pos_embedding="sinusoidal",
    enc_layers=32,
    enc_seq=1500,
)
