"""Mamba2-780m [arXiv:2405.21060] — attention-free SSD decoder.

48L, d_model 1536 (d_inner 3072, 48 SSM heads of dim 64, state 128),
vocab 50280, tied embeddings.  Sub-quadratic: runs the long_500k cell
with constant-size decode state.
"""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    arch="mamba2-780m",
    family="ssm",
    num_layers=48,
    d_model=1536,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50_280,
    tie_embeddings=True,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_ngroups=1,
)
