"""Phi-4-mini (3.8B) [arXiv:2412.08905; hf].

Dense decoder: 32L, d_model 3072, 24 heads (GQA kv=8, head_dim 128),
SwiGLU d_ff 8192, vocab 200064, RoPE.
"""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    arch="phi4-mini-3.8b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=200_064,
    activation="swiglu",
    rope_theta=10_000.0,
)
