"""Architecture config registry: ``get_config("<arch-id>")`` + the paper's
own tensor-dataset configs (FROSTT Table III) for the CPD side.
"""
from __future__ import annotations

import dataclasses
import importlib

from repro.models.base import SHAPES, ModelConfig, ShapeCfg

_ARCH_MODULES = {
    "minitron-4b": "minitron_4b",
    "qwen1.5-4b": "qwen1_5_4b",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "qwen1.5-32b": "qwen1_5_32b",
    "hymba-1.5b": "hymba_1_5b",
    "whisper-large-v3": "whisper_large_v3",
    "dbrx-132b": "dbrx_132b",
    "granite-moe-1b-a400m": "granite_moe_1b",
    "mamba2-780m": "mamba2_780m",
    "internvl2-1b": "internvl2_1b",
}

ARCHS = tuple(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; options: {list(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")
    return mod.CONFIG


def reduce_config(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Shrink a full config to a CPU-smoke-testable sibling of the same
    family: few layers, narrow width, tiny vocab — same code paths."""
    heads = max(2, min(cfg.num_heads, 4)) if cfg.num_heads else 0
    kvh = max(1, min(cfg.num_kv_heads, 2)) if cfg.num_kv_heads else 0
    if heads and kvh:
        heads = (heads // kvh) * kvh  # keep divisible
    hd = 16 if cfg.head_dim else 0
    d = max(32, heads * hd) if heads else 64
    small = dict(
        num_layers=min(cfg.num_layers, 4 if cfg.family == "hybrid" else 2),
        d_model=d,
        num_heads=heads,
        num_kv_heads=kvh,
        head_dim=hd,
        d_ff=min(cfg.d_ff, 4 * d) if cfg.d_ff else 0,
        vocab_size=512,
        dtype="float32",
        remat="none",
        attn_chunk=32,
        vocab_round=64,
    )
    if cfg.num_experts:
        small.update(num_experts=min(cfg.num_experts, 4),
                     num_experts_per_tok=min(cfg.num_experts_per_tok, 2),
                     moe_dff=32)
    if cfg.ssm_state:
        small.update(ssm_state=min(cfg.ssm_state, 16), ssm_head_dim=16,
                     ssm_ngroups=1, ssm_chunk=16, ssm_expand=2)
    if cfg.family == "hybrid":
        small.update(attn_window=16, num_meta_tokens=4,
                     global_attn_layers=(0, 3))
    if cfg.enc_layers:
        small.update(enc_layers=2, enc_seq=24)
    if cfg.num_prefix_tokens:
        small.update(num_prefix_tokens=8)
    small.update(overrides)
    return dataclasses.replace(cfg, **small)


__all__ = ["ARCHS", "SHAPES", "ShapeCfg", "get_config", "reduce_config"]
