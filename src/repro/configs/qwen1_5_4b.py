"""Qwen1.5-4B [hf:Qwen/Qwen1.5-4B; config family verified via Qwen1.5-0.5B].

Dense decoder with QKV bias: 40L, d_model 2560, 20 heads (MHA: kv=20,
head_dim 128), SwiGLU d_ff 6912, vocab 151936.
"""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    arch="qwen1.5-4b",
    family="dense",
    num_layers=40,
    d_model=2560,
    num_heads=20,
    num_kv_heads=20,
    head_dim=128,
    d_ff=6912,
    vocab_size=151_936,
    qkv_bias=True,
    activation="swiglu",
    rope_theta=1_000_000.0,
)
