"""DBRX-base (132B total, 36B active) [hf:databricks/dbrx-base].

MoE decoder: 40L, d_model 6144, 48 heads (GQA kv=8, head_dim 128),
16 experts top-4 with per-expert SwiGLU d_ff 10752, vocab 100352.
"""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    arch="dbrx-132b",
    family="moe",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=10752,
    vocab_size=100_352,
    activation="swiglu",
    num_experts=16,
    num_experts_per_tok=4,
    moe_dff=10752,
    rope_theta=500_000.0,
)
