"""Hymba-1.5B [arXiv:2411.13676; hf] — hybrid parallel attention+Mamba heads.

32L, d_model 1600, 25 attn heads (GQA kv=5, head_dim 64), SwiGLU d_ff
5504, vocab 32001, SSM state 16.  Sliding-window attention (1024) in all
but 3 full-attention layers {first, middle, last}; 128 learned meta
tokens prepended.  Sub-quadratic: runs the long_500k cell.
"""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    arch="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32_001,
    activation="swiglu",
    attn_window=1024,
    global_attn_layers=(0, 15, 31),
    num_meta_tokens=128,
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_ngroups=1,
    rope_theta=10_000.0,
)
