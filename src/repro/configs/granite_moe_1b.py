"""Granite-3.0-1B-A400M [hf:ibm-granite/granite-3.0-1b-a400m-base].

Fine-grained MoE decoder: 24L, d_model 1024, 16 heads (GQA kv=8,
head_dim 64), 32 experts top-8 with per-expert SwiGLU d_ff 512,
vocab 49155.
"""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    arch="granite-moe-1b-a400m",
    family="moe",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49_155,
    activation="swiglu",
    num_experts=32,
    num_experts_per_tok=8,
    moe_dff=512,
    rope_theta=10_000.0,
)
