"""Masked/weighted CP for tensor completion on the shared substrate.

The COO nonzero list is reinterpreted as the OBSERVED-entry set: the
goal is ``min sum_{observed e} w_e (x_e - model_e)^2`` with everything
off the list missing (not zero) — the recommendation/imputation
workload.  The classic EM reduction keeps the whole thing on the sparse
kernels: per mode, fill the missing entries with the current model,

    Xf = model + W * (X - model),

whose MTTKRP splits into (a) the SAME spMTTKRP kernel over the observed
coordinates with per-sweep residual values ``w_e * (x_e - model_e)``,
plus (b) a closed-form rank-R dense term
``(Y_d * lambda) @ hadamard_{w != d}(gram_w)`` — then the ordinary
ridge-regularized LS solve (``ctx.solve``, shared with plain CP).  Each
mode update exactly minimizes the filled-tensor objective, which
majorizes the observed objective at the current iterate, so the observed
loss is monotone nonincreasing (EM).

Residual values change every sweep, so mode data is STRUCTURAL only
(``valued_mode_data``): the canonical->layout permutation (segment), the
canonical->slab ``val_scatter`` (pallas, computed once at pack time in
``kernels.ops``), or nothing (coo) — values are scattered on device
through ``ctx.mttkrp_valued``, never repacked on host.

Per-entry weights make nnz padding exact for the serving path: padded
entries get weight 0 and contribute +0.0 to the residual MTTKRP and the
fit, so a padded masked request is bit-equivalent to the unpadded one —
the same invariance plain CP gets from zero VALUES, recovered here from
zero WEIGHTS (a zero-valued padding entry would otherwise assert the
tensor is observed-zero at the origin and bias the completion).

The fit reported is over observed entries only:
``1 - sqrt(sum w_e (x_e - model_e)^2) / sqrt(sum w_e x_e^2)``.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from ..kernels.ref import cp_model_at_coords
from .registry import MethodSpec, register_method


def make_fit_data(tensor):
    """(indices, values, entry_weights, weighted ||X||²) — all observed
    entries weighted 1 (the serving path appends weight-0 padding)."""
    vals = tensor.values.astype(np.float32)
    return (
        jnp.asarray(tensor.indices),
        jnp.asarray(vals),
        jnp.ones((tensor.nnz,), jnp.float32),
        jnp.asarray(float(vals @ vals), jnp.float32),
    )


def build_sweep(ctx):
    nmodes = ctx.nmodes
    if ctx.mttkrp_valued is None:
        raise NotImplementedError(
            "masked CP needs the valued MTTKRP entry point (not available "
            "on the distributed axis path)")

    model_at = cp_model_at_coords    # one formula, shared with kernels.ref

    def sweep(state, mode_data_all, fit_data):
        factors, grams, weights = list(state[0]), list(state[1]), state[2]
        indices, values, ew, norm_x_sq = fit_data
        for d in range(nmodes):
            # Fresh residual per MODE (the model moved): exact EM.
            with jax.named_scope("residual"):
                resid = ew * (values - model_at(indices, factors, weights))
            with jax.named_scope("mttkrp"):
                M_sp = ctx.mttkrp_valued(d, mode_data_all[d], factors, resid)
            with jax.named_scope("solve"):
                V = ctx.hadamard(grams, exclude=d)
                # Sparse residual term + closed-form dense model term =
                # MTTKRP of the EM-filled tensor (kernels.ref.
                # mttkrp_masked_residual is the reference formulation).
                M = M_sp + (factors[d] * weights[None, :]) @ V
                Yd, lam = ctx.normalize(ctx.solve(M, V))
            factors[d] = Yd
            grams[d] = Yd.T @ Yd
            weights = lam
        with jax.named_scope("fit"):
            resid = values - model_at(indices, factors, weights)
            resid_sq = jnp.sum(ew * resid * resid)
            fit = 1.0 - jnp.sqrt(resid_sq) / jnp.maximum(
                jnp.sqrt(norm_x_sq), 1e-12)
        return (tuple(factors), tuple(grams), weights), fit

    return sweep


MASKED = register_method(MethodSpec(
    name="masked",
    description="Masked/weighted CP completion (EM over observed entries): "
                "residual spMTTKRP + closed-form dense term, observed-only "
                "fit; padding is weight-0 and therefore exact.",
    build_sweep=build_sweep,
    make_fit_data=make_fit_data,
    valued_mode_data=True,
    weighted_fit=True,
))
