"""Masked/weighted CP for tensor completion on the shared substrate.

The COO nonzero list is reinterpreted as the OBSERVED-entry set: the
goal is ``min sum_{observed e} w_e (x_e - model_e)^2`` with everything
off the list missing (not zero) — the recommendation/imputation
workload.  The classic EM reduction keeps the whole thing on the sparse
kernels: per mode, fill the missing entries with the current model,

    Xf = model + W * (X - model),

whose MTTKRP splits into (a) the SAME spMTTKRP kernel over the observed
coordinates with per-sweep residual values ``w_e * (x_e - model_e)``,
plus (b) a closed-form rank-R dense term
``(Y_d * lambda) @ hadamard_{w != d}(gram_w)`` — then the ordinary
ridge-regularized LS solve (``ctx.solve``, shared with plain CP).  Each
mode update exactly minimizes the filled-tensor objective, which
majorizes the observed objective at the current iterate, so the observed
loss is monotone nonincreasing (EM).

Residual values change every sweep, so mode data is STRUCTURAL only
(``valued_mode_data``): the canonical->layout permutation (segment), the
canonical->slab ``val_scatter`` (pallas, computed once at pack time in
``kernels.ops``), or nothing (coo) — values are scattered on device
through ``ctx.mttkrp_valued``, never repacked on host.

Per-entry weights are the USER-facing front door as well as the padding
mechanism: ``cpd_als(method="masked", weights=w)`` (and the batched /
distributed front doors) supply fractional observation confidences à la
CP-WOPT; omitted weights mean weight-1 observed entries.  Every front
door normalizes the vector by ``max(1, w.max())``
(``core.als_device.normalize_entry_weights``): the EM update is a
majorizer only for weights in [0, 1], and the weighted objective —
argmin and fit alike — is invariant under positive rescaling, so the
normalization is unobservable except that the iteration is always
stable.  The serving path appends weight-0 entries on nnz padding — a
weight-0 entry
contributes +0.0 to the residual MTTKRP and the fit, so a padded (or
down-weighted-to-zero) request is bit-equivalent to one without the
entry — the same invariance plain CP gets from zero VALUES, recovered
here from zero WEIGHTS (a zero-valued padding entry would otherwise
assert the tensor is observed-zero at the origin and bias the
completion).

Distributed execution (``core.distributed.cpd_als_distributed(
method="masked")``) runs the same EM update under ``shard_map``: every
device holds a rectangular shard of each mode layout that ALSO carries
its entries' full coordinates, values, and weights, evaluates the
residual locally at its shard's coordinates (factors are replicated),
and the partial residual MTTKRPs ``psum`` over the mesh axis; the
closed-form dense correction is computed from the replicated factors —
identical on every device — so it needs no collective, and the weighted
fit psums per-shard residual mass.  The sweep below branches on
``ctx.axis`` to pick the contract; both branches share the identical
solve tail, so sequential, batched, and distributed masked runs agree to
fp32 tolerance (pinned by ``tests/conformance``).

The fit reported is over observed entries only:
``1 - sqrt(sum w_e (x_e - model_e)^2) / sqrt(sum w_e x_e^2)``.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from ..kernels.ref import cp_model_at_coords
from .registry import MethodSpec, register_method


def make_fit_data(tensor, entry_weights: np.ndarray | None = None):
    """(indices, values, entry_weights, weighted ||X||²).  ``entry_weights``
    default to 1 on every observed entry (the serving path appends
    weight-0 padding); a user-supplied vector carries fractional
    confidences, and the norm term weights accordingly so the reported
    fit stays scale-consistent."""
    vals = tensor.values.astype(np.float32)
    ew = (np.ones((tensor.nnz,), np.float32) if entry_weights is None
          else np.asarray(entry_weights, np.float32))
    return (
        jnp.asarray(tensor.indices),
        jnp.asarray(vals),
        jnp.asarray(ew),
        jnp.asarray(float((ew * vals) @ vals), jnp.float32),
    )


def build_sweep(ctx):
    nmodes = ctx.nmodes
    if ctx.mttkrp_valued is None:
        raise NotImplementedError(
            "masked CP needs the valued MTTKRP entry point (distributed "
            "execution supports the segment backend only)")

    model_at = cp_model_at_coords    # one formula, shared with kernels.ref

    def solve_tail(ctx_, d, M_sp, factors, grams, weights):
        """Shared closed form + solve: identical numerics on every path."""
        V = ctx_.hadamard(grams, exclude=d)
        # Sparse residual term + closed-form dense model term =
        # MTTKRP of the EM-filled tensor (kernels.ref.
        # mttkrp_masked_residual is the reference formulation).
        M = M_sp + (factors[d] * weights[None, :]) @ V
        return ctx_.normalize(ctx_.solve(M, V))

    if ctx.axis is None:
        def sweep(state, mode_data_all, fit_data):
            factors, grams, weights = list(state[0]), list(state[1]), state[2]
            indices, values, ew, _ = fit_data
            for d in range(nmodes):
                # Fresh residual per MODE (the model moved): exact EM.
                with jax.named_scope("residual"):
                    resid = ew * (values
                                  - model_at(indices, factors, weights))
                with jax.named_scope("mttkrp"):
                    M_sp = ctx.mttkrp_valued(d, mode_data_all[d], factors,
                                             resid)
                with jax.named_scope("solve"):
                    Yd, lam = solve_tail(ctx, d, M_sp, factors, grams,
                                         weights)
                factors[d] = Yd
                grams[d] = Yd.T @ Yd
                weights = lam
            with jax.named_scope("fit"):
                fit = ctx.weighted_fit(factors, weights, fit_data)
            return (tuple(factors), tuple(grams), weights), fit

        return sweep

    # Distributed (shard_map) contract: per-mode device-local shard
    # (idx_in, rows, row_perm, idx_full, vals, ew) — the residual is
    # evaluated at THIS shard's coordinates from the replicated factors,
    # the partial residual MTTKRP psums inside ctx.mttkrp_valued, and the
    # dense correction is replicated-exact without a collective.
    def sweep_dist(state, mode_data_all, fit_data):
        factors, grams, weights = list(state[0]), list(state[1]), state[2]
        for d in range(nmodes):
            idx_in, rows, row_perm, idx_full, vals, ew = mode_data_all[d]
            with jax.named_scope("residual"):
                resid = ew * (vals - model_at(idx_full, factors, weights))
            with jax.named_scope("mttkrp"):
                M_sp = ctx.mttkrp_valued(d, (idx_in, rows, row_perm),
                                         factors, resid)
            with jax.named_scope("solve"):
                Yd, lam = solve_tail(ctx, d, M_sp, factors, grams, weights)
            factors[d] = Yd
            grams[d] = Yd.T @ Yd
            weights = lam
        with jax.named_scope("fit"):
            fit = ctx.weighted_fit(factors, weights, fit_data)  # psums
        return (tuple(factors), tuple(grams), weights), fit

    return sweep_dist


MASKED = register_method(MethodSpec(
    name="masked",
    description="Masked/weighted CP completion (EM over observed entries): "
                "residual spMTTKRP + closed-form dense term, observed-only "
                "weighted fit; user-supplied per-entry confidences; "
                "padding is weight-0 and therefore exact.",
    build_sweep=build_sweep,
    make_fit_data=make_fit_data,
    valued_mode_data=True,
    weighted_fit=True,
))
