"""Nonnegative CP via HALS on the shared fused-MTTKRP substrate.

HALS (hierarchical alternating least squares, Cichocki & Phan) replaces
the mode-d normal-equations solve with R exact nonnegative coordinate
minimizations — one per factor column:

    y_r <- max(0, (M[:, r] - sum_{s != r} y_s V[s, r]) / V[r, r])

where ``M`` is the SAME MTTKRP the plain sweep computes and ``V`` the
same Hadamard product of input grams: the kernel substrate is untouched,
only the R x R tail differs.  Each column update exactly minimizes the
quadratic objective over that column subject to y >= 0 (the objective is
coordinate-separable given the others), so the loss is monotone
nonincreasing — i.e. the fit is monotone NONDECREASING — per column, per
mode, per sweep, for ANY input tensor; and the clamp keeps every factor
entry provably >= 0 from a nonnegative init onward (column
normalization divides by a positive scalar and cannot break the
invariant).  ``tests/methods/test_nncp.py`` asserts both properties.

Weight handling mirrors plain CP: factors are stored column-normalized
with the scale in ``weights``; the update absorbs the weights into the
active mode first (``Yt = Y_d * lam`` — model-invariant, so the
monotonicity argument applies to the true objective) and re-extracts
them afterwards, which keeps the shared sparse fit formula valid
unchanged.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from .registry import MethodSpec, register_method

_EPS = 1e-12


def init_state_host_nonneg(tensor_shape, rank: int, seed: int):
    """Strictly nonnegative host init (|N(0,1)| + 0.01): the HALS clamp
    preserves nonnegativity, so the init is where the invariant starts."""
    rng = np.random.default_rng(seed)
    factors = tuple(
        (np.abs(rng.standard_normal((I, rank))) + 0.01).astype(np.float32)
        for I in tensor_shape
    )
    grams = tuple(F.T @ F for F in factors)
    weights = np.ones((rank,), np.float32)
    return (factors, grams, weights)


def build_sweep(ctx):
    nmodes, rank = ctx.nmodes, ctx.rank

    def sweep(state, mode_data_all, fit_data):
        factors, grams, weights = list(state[0]), list(state[1]), state[2]
        for d in range(nmodes):
            with jax.named_scope("mttkrp"):
                M = ctx.one_mttkrp(d, mode_data_all[d], factors)
            with jax.named_scope("hals"):
                V = ctx.hadamard(grams, exclude=d)
                Yt = factors[d] * weights[None, :]
                # R exact nonnegative column minimizations, unrolled (R is
                # static).  A column whose gram diagonal collapsed keeps
                # its previous value instead of dividing by ~0.
                for r in range(rank):
                    num = (M[:, r] - Yt @ V[:, r]
                           + Yt[:, r] * V[r, r])
                    col = jnp.maximum(num, 0.0) / jnp.maximum(V[r, r], _EPS)
                    Yt = Yt.at[:, r].set(
                        jnp.where(V[r, r] > _EPS, col, Yt[:, r]))
                Yd, lam = ctx.normalize(Yt)
            factors[d] = Yd
            grams[d] = Yd.T @ Yd
            weights = lam
        with jax.named_scope("fit"):
            fit = ctx.sparse_fit(factors, grams, weights, fit_data)
        return (tuple(factors), tuple(grams), weights), fit

    return sweep


NONNEGATIVE = register_method(MethodSpec(
    name="nncp",
    description="Nonnegative CP (HALS): factors provably >= 0, fit "
                "monotone nondecreasing; same MTTKRP substrate as plain CP.",
    build_sweep=build_sweep,
    init_state_host=init_state_host_nonneg,
))
