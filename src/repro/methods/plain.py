"""The registry entry for unconstrained CP-ALS.

The sweep itself lives inline in ``core.als_device.build_sweep_fn``
(``method="cp"`` short-circuits before the registry lookup — the hot
default path takes no indirection); this spec exists so 'cp' shows up in
``list_methods()`` and so the serving layer can validate method names
uniformly."""
from __future__ import annotations

from .registry import MethodSpec, register_method

CP = register_method(MethodSpec(
    name="cp",
    description="Unconstrained CP-ALS (ridge-regularized normal equations "
                "with pinv rescue) — the inline substrate path.",
))
