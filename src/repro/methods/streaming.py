"""Streaming CP: fold newly arrived nonzeros into existing factors,
with session state living in the planning layer's static-shape world.

The streaming method is *stateful*: it does not replace the sweep's
inner loop but drives the substrate across calls.  A ``StreamingCP``
session holds the accumulated nonzero set and the current factor state;
``update(delta)`` merges the new nonzeros and runs a handful of
WARM-STARTED refinement sweeps from the current factors (``init_state``
threading in ``core.als_device.cpd_als_fused`` / the batched service)
instead of a full cold refit.

Four mechanisms keep an unbounded stream of increments cheap:

  * **bucket-quantized state** — every fit sees the session tensor padded
    to a monotone bucket cap (``core.plan.session_cap`` over the
    session's ``BucketPolicy``; zero-valued entries at the origin, with
    observation weight 0 for weighted methods — both proven exact
    no-ops), so successive increments inside a bucket present the SAME
    array shapes to the engine and reuse its cached executable instead
    of retracing.  The cap only ever grows (a shrinking cap would
    retrace), and with geometric bucketing the total executable count
    over a session's lifetime is logarithmic in its peak nnz.
  * **incremental sorted merge** — the session's coordinates are kept in
    canonical (linearized-key) order, and each delta folds in with an
    O(nnz + m) two-``searchsorted`` merge instead of a full
    concat + argsort of the entire history; values and per-entry
    confidence weights merge in the same pass (at duplicate coordinates
    both ADD, session entries first — bit-identical to the full
    re-sort's stable accumulation order).
  * **confidence-decay eviction** — with ``decay`` set, per-entry weights
    are EWMA-decayed every increment (``w <- decay * w``, re-observation
    adds fresh mass), and when a merge would cross into a LARGER bucket,
    entries whose weight has decayed below ``weight_floor`` are dropped
    first — so session nnz (and therefore bucket residency) stays
    bounded for unbounded streams.  For weighted-fit inner methods the
    decayed weights also ARE the observation confidences, so old
    observations fade from the objective; for plain cp/nncp they are
    session bookkeeping only.
  * **durable sessions** — ``save()`` / ``restore()`` serialize the whole
    session (tensor, weights, factor state, decay clock, config)
    through ``checkpoint.manager.CheckpointManager``'s atomic-commit
    machinery, so sessions survive restarts and migrate across devices;
    ``runtime.ALSRunner.open_stream(resume_from=...)`` resumes from a
    checkpoint directory.

The inner method is pluggable: ``StreamingCP(rank, method="nncp")``
streams a nonnegative decomposition (a warm nonnegative state stays
nonnegative under HALS), ``method="cp"`` (default) the plain one, and
``method="masked"`` a weighted completion stream: ``start``/``update``
then accept per-entry observation ``weights`` (fractional confidences),
which merge alongside the values.  Increments without weights default to
confidence 1 per entry.

Routed through ``runtime.ALSRunner`` (``runner=`` or
``ALSRunner.open_stream()``), every refinement window goes through the
batched service — the session pre-pads to its own cap, so the service
sees a recurring nnz class and its executable cache hits — and each
increment is recorded as a per-session gauge in the service metrics
(bucket residency, eviction counts, increment latency).
"""
from __future__ import annotations

import itertools

import numpy as np

from ..core import plan as plan_mod
from ..core.coo import SparseTensor, _linearize
from ..obs import clock as obs_clock
from ..obs import trace as obs_trace
from .registry import MethodSpec, get_method, register_method

_SESSION_IDS = itertools.count()


def _canonical(indices: np.ndarray, values: np.ndarray,
               weights: np.ndarray | None, shape):
    """Canonicalize one COO list: sort by linearized key; values AND
    confidence weights sum at duplicate coordinates (same stable order as
    ``SparseTensor.deduplicate``).  Returns ``(keys, idx, vals, wts)``
    with ``wts`` None when ``weights`` is None."""
    keys = _linearize(indices, shape)
    order = np.argsort(keys, kind="stable")
    keys = keys[order]
    vals = values[order].astype(np.float32)
    wts = weights[order].astype(np.float32) if weights is not None else None
    n = len(keys)
    if n == 0:
        return keys, indices[order], vals, wts
    uniq = np.empty(n, dtype=bool)
    uniq[:1] = True
    uniq[1:] = keys[1:] != keys[:-1]
    if uniq.all():
        return keys, indices[order], vals, wts
    starts = np.flatnonzero(uniq)
    vals = np.add.reduceat(vals, starts)
    if wts is not None:
        wts = np.add.reduceat(wts, starts)
    return keys[starts], indices[order][starts], vals, wts


def _merge_sorted(keys_a, idx_a, vals_a, w_a, keys_b, idx_b, vals_b, w_b):
    """O(nnz + m) fold of a canonical delta (b) into the canonical session
    list (a): element positions come from two ``searchsorted`` passes
    instead of re-argsorting the entire history, and the value and
    weight vectors merge in the same pass.  At duplicate coordinates
    values (and weights) ADD with the session entry first — the same
    accumulation order as the full stable re-sort, so the merged list is
    bit-identical to the old concat + dedup path."""
    na, nb = len(keys_a), len(keys_b)
    pos_a = np.arange(na, dtype=np.int64) + np.searchsorted(
        keys_b, keys_a, side="left")
    pos_b = np.arange(nb, dtype=np.int64) + np.searchsorted(
        keys_a, keys_b, side="right")
    n = na + nb
    keys = np.empty(n, dtype=np.int64)
    keys[pos_a] = keys_a
    keys[pos_b] = keys_b
    idx = np.empty((n, idx_a.shape[1]), dtype=idx_a.dtype)
    idx[pos_a] = idx_a
    idx[pos_b] = idx_b
    vals = np.empty(n, dtype=np.float32)
    vals[pos_a] = vals_a
    vals[pos_b] = vals_b
    wts = None
    if w_a is not None:
        wts = np.empty(n, dtype=np.float32)
        wts[pos_a] = w_a
        wts[pos_b] = w_b
    uniq = np.empty(n, dtype=bool)
    uniq[:1] = True
    uniq[1:] = keys[1:] != keys[:-1]
    if uniq.all():
        return keys, idx, vals, wts
    starts = np.flatnonzero(uniq)
    vals = np.add.reduceat(vals, starts)
    if wts is not None:
        wts = np.add.reduceat(wts, starts)
    return keys[starts], idx[starts], vals, wts


class StreamingCP:
    """Incremental CP session over a growing (bounded, bucket-resident)
    nonzero set.

    Parameters beyond the PR-4 ones:

    policy       -- ``"auto"`` (default): quantize the session's fit-time
                    nnz to geometric buckets (growth 1.5) so increments
                    reuse cached executables; a ``serve.buckets
                    .BucketPolicy`` to choose the rule; ``None`` to
                    disable quantization (every fit sees the exact nnz —
                    the comparison baseline, and the PR-4 behavior).
    decay        -- EWMA factor in (0, 1]: per-entry weights are
                    multiplied by it every increment (re-observations
                    add fresh mass).  None (default) disables decay.
    weight_floor -- entries whose decayed weight falls below this are
                    evicted when a merge would grow the bucket.  0
                    (default) never evicts.
    session_id   -- metrics key; autogenerated when omitted.
    """

    def __init__(self, rank: int, *, method: str = "cp",
                 backend: str = "segment", kappa: int = 1,
                 check_every: int = 2, refine_iters: int = 2,
                 solver: str = "auto", runner=None,
                 policy="auto", decay: float | None = None,
                 weight_floor: float = 0.0,
                 session_id: str | None = None):
        inner = get_method(method)
        if inner.stateful:
            raise ValueError(
                f"streaming wraps a sweep-based method, got {method!r}")
        if decay is not None and not (0.0 < float(decay) <= 1.0):
            raise ValueError(f"decay must be in (0, 1], got {decay}")
        if weight_floor < 0.0:
            raise ValueError(f"weight_floor must be >= 0, got {weight_floor}")
        self.rank = int(rank)
        self.method = method
        self.backend = backend
        self.kappa = int(kappa)
        self.check_every = int(check_every)
        self.refine_iters = int(refine_iters)
        self.solver = solver
        self.runner = runner
        if policy == "auto":
            from ..serve.buckets import BucketPolicy

            policy = BucketPolicy(mode="geometric", growth=1.5)
        self.policy = policy
        self.decay = None if decay is None else float(decay)
        self.weight_floor = float(weight_floor)
        self.session_id = (session_id if session_id is not None
                           else f"stream-{next(_SESSION_IDS)}")
        self.seed = 0
        self.increments = 0
        self.evictions = 0
        self.merge_seconds = 0.0
        self._latencies: list[float] = []
        self._shape: tuple[int, ...] | None = None
        self._keys: np.ndarray | None = None
        self._idx: np.ndarray | None = None
        self._vals: np.ndarray | None = None
        self._entry_w: np.ndarray | None = None
        self._cap = 0                      # 0 = no quantization (policy=None)
        self._state = None
        self._result = None

    # -- substrate dispatch -------------------------------------------------

    @property
    def _weighted(self) -> bool:
        return get_method(self.method).weighted_fit

    def _fit_inputs(self):
        """The (tensor, weights) pair a refinement actually fits: the
        session's canonical set, padded to the monotone bucket cap with
        zero-valued (weight-0 for weighted methods) entries — the exact
        no-op padding that makes successive increments share one
        executable class."""
        tensor = SparseTensor(self._idx, self._vals, self._shape)
        fit_w = (self._entry_w
                 if self._weighted and self._entry_w is not None else None)
        if self._cap and tensor.nnz < self._cap:
            from ..serve.buckets import pad_tensor, pad_weights

            if fit_w is not None:
                fit_w = pad_weights(fit_w, self._cap)
            tensor = pad_tensor(tensor, self._cap)
        return tensor, fit_w

    def _fit(self, n_iters, tol, seed, init_state):
        tensor, fit_w = self._fit_inputs()
        if self.runner is not None:
            return self.runner.decompose(
                tensor, n_iters=n_iters, tol=tol, seed=seed,
                method=self.method, init_state=init_state, weights=fit_w)
        from ..core.als_device import cpd_als_fused

        return cpd_als_fused(
            tensor, self.rank, kappa=self.kappa, n_iters=n_iters, tol=tol,
            seed=seed, backend=self.backend, check_every=self.check_every,
            solver=self.solver, method=self.method, init_state=init_state,
            weights=fit_w)

    def _check_weighted(self):
        if not self._weighted:
            raise ValueError(
                f"streaming weights require a weighted-fit inner method "
                f"(e.g. 'masked'), got {self.method!r}")

    def _absorb(self, res):
        from ..core.als_device import state_from_factors

        self._result = res
        self._state = state_from_factors(res.factors, res.weights)
        return res

    def _update_cap(self):
        if self.policy is not None:
            self._cap = plan_mod.session_cap(len(self._keys), self._cap,
                                             self.policy)

    def _maybe_evict(self) -> int:
        """Confidence-decay eviction at bucket boundaries: when the merged
        nnz would cross into a LARGER bucket, drop entries whose decayed
        weight sits below the floor first — often that keeps the session
        inside its current bucket (zero retrace), and always bounds
        residency for unbounded streams."""
        if (self._entry_w is None or self.weight_floor <= 0.0
                or self.policy is None):
            return 0
        if plan_mod.session_cap(len(self._keys), self._cap,
                                self.policy) <= self._cap:
            return 0                     # still inside the bucket
        keep = self._entry_w >= np.float32(self.weight_floor)
        n_evict = int(keep.size - int(keep.sum()))
        if n_evict:
            self._keys = self._keys[keep]
            self._idx = self._idx[keep]
            self._vals = self._vals[keep]
            self._entry_w = self._entry_w[keep]
            self.evictions += n_evict
        return n_evict

    def _record_increment(self, wall_s: float, merge_s: float, evicted: int,
                          count: bool = True):
        if count:
            self._latencies.append(wall_s)
        obs_trace.event(
            "stream.increment", cat="serve", session=self.session_id,
            nnz=len(self._keys), bucket_cap=self._cap or len(self._keys),
            evicted=evicted, wall_s=round(wall_s, 6),
            merge_s=round(merge_s, 6), counted=count)
        if self.runner is not None and getattr(self.runner, "service", None):
            self.runner.service.metrics.record_stream_increment(
                self.session_id, bucket_cap=self._cap or len(self._keys),
                nnz=len(self._keys), evicted=evicted, wall_s=wall_s,
                merge_s=merge_s, count=count)

    # -- public API ---------------------------------------------------------

    def start(self, tensor: SparseTensor, *, n_iters: int = 25,
              tol: float = 1e-5, seed: int = 0,
              weights: np.ndarray | None = None):
        """Cold fit on the initial nonzero set.  ``weights`` — per-entry
        observation confidences (weighted-fit inner methods only); at
        duplicate coordinates confidence mass sums alongside values.
        ``seed`` is the SESSION seed: it also threads through every warm
        refinement, so a restored session refines identically to an
        uninterrupted one."""
        self.increments = 0
        self.evictions = 0
        self.merge_seconds = 0.0
        self._latencies = []
        self.seed = int(seed)
        w = None
        if weights is not None:
            self._check_weighted()
            w = np.asarray(weights, np.float32)
        elif self.decay is not None:
            w = np.ones(tensor.nnz, np.float32)
        t0 = obs_clock.now()
        self._shape = tuple(int(s) for s in tensor.shape)
        self._keys, self._idx, self._vals, self._entry_w = _canonical(
            tensor.indices, tensor.values, w, self._shape)
        self._cap = 0
        self._update_cap()
        merge_s = obs_clock.now() - t0
        self.merge_seconds += merge_s
        res = self._absorb(self._fit(n_iters, tol, self.seed, None))
        # register residency gauges, but the cold fit is NOT an increment
        self._record_increment(obs_clock.now() - t0, merge_s, 0,
                               count=False)
        return res

    def update(self, delta: SparseTensor, *, refine_iters: int | None = None,
               tol: float = -1.0, weights: np.ndarray | None = None):
        """Fold ``delta``'s nonzeros in (values at duplicate coordinates
        ADD — the streaming-accumulation semantics; confidence weights
        add too) and refine the current factors with ``refine_iters``
        warm sweeps.  A weighted stream stays weighted: increments
        without ``weights`` arrive at confidence 1 per entry.  With
        ``decay`` set, existing weights are EWMA-decayed first and
        below-floor entries are evicted at bucket boundaries."""
        if self._keys is None:
            raise RuntimeError("call start() before update()")
        if tuple(delta.shape) != self._shape:
            raise ValueError(
                f"increment shape {tuple(delta.shape)} != stream shape "
                f"{self._shape}")
        t_begin = obs_clock.now()
        w_new = None
        if weights is not None:
            self._check_weighted()
            w_new = np.asarray(weights, np.float32)
        track = (w_new is not None or self._entry_w is not None
                 or self.decay is not None)
        if track:
            if self._entry_w is None:
                self._entry_w = np.ones(len(self._keys), np.float32)
            if self.decay is not None:
                self._entry_w = self._entry_w * np.float32(self.decay)
            if w_new is None:
                w_new = np.ones(delta.nnz, np.float32)
        dk, di, dv, dw = _canonical(delta.indices, delta.values, w_new,
                                    self._shape)
        self._keys, self._idx, self._vals, self._entry_w = _merge_sorted(
            self._keys, self._idx, self._vals, self._entry_w,
            dk, di, dv, dw)
        evicted = self._maybe_evict()
        self._update_cap()
        merge_s = obs_clock.now() - t_begin
        self.merge_seconds += merge_s
        self.increments += 1
        k = self.refine_iters if refine_iters is None else int(refine_iters)
        res = self._absorb(self._fit(k, tol, self.seed, self._state))
        self._record_increment(obs_clock.now() - t_begin, merge_s,
                               evicted)
        return res

    # -- durability ---------------------------------------------------------

    _CKPT_KIND = "streaming_cp"
    _CKPT_VERSION = 1

    def save(self, directory, *, step: int | None = None, keep: int = 3):
        """Durably snapshot the session (tensor, weights, factor state,
        decay clock, config) through the checkpoint manager's
        atomic-commit machinery: the snapshot is visible only after its
        commit marker renames into place, so a crash mid-save never
        leaves a restorable torn session.  ``step`` defaults to the
        increment counter, so keep-k GC retains the k most recent
        increments.  Returns the manager (reusable for later saves)."""
        from ..checkpoint.manager import CheckpointManager

        if self._keys is None:
            raise RuntimeError("nothing to save before start()")
        mgr = (directory if isinstance(directory, CheckpointManager)
               else CheckpointManager(str(directory), keep=keep,
                                      async_save=False))
        factors, _, lam = self._state
        tree = {
            "idx": self._idx,
            "vals": self._vals,
            "keys": self._keys,
            "entry_w": (self._entry_w if self._entry_w is not None
                        else np.zeros((0,), np.float32)),
            "factors": {str(d): np.asarray(F) for d, F in enumerate(factors)},
            "lam": np.asarray(lam),
        }
        pol = None
        if self.policy is not None:
            pol = {"mode": self.policy.mode, "quantum": self.policy.quantum,
                   "growth": self.policy.growth,
                   "min_cap": self.policy.min_cap}
        extra = {
            "kind": self._CKPT_KIND, "version": self._CKPT_VERSION,
            "rank": self.rank, "method": self.method,
            "backend": self.backend, "kappa": self.kappa,
            "check_every": self.check_every,
            "refine_iters": self.refine_iters, "solver": self.solver,
            "shape": list(self._shape), "seed": self.seed,
            "increments": self.increments, "evictions": self.evictions,
            "decay": self.decay, "weight_floor": self.weight_floor,
            "cap": int(self._cap),
            "has_entry_w": self._entry_w is not None,
            "policy": pol, "session_id": self.session_id,
        }
        mgr.save(self.increments if step is None else int(step), tree,
                 extra=extra, block=True)
        return mgr

    @classmethod
    def restore(cls, directory, *, step: int | None = None, runner=None):
        """Rebuild a session from its latest (or ``step``-th) committed
        checkpoint.  The restored session refines identically to the
        uninterrupted one: same canonical tensor, weights, factor state,
        session seed, decay clock, and bucket cap (so even the
        executable class is preserved).  ``runner`` re-routes the
        restored session — a session checkpointed on one host/device
        restores onto any other (the snapshot is host numpy)."""
        from ..checkpoint.manager import CheckpointManager
        from ..core.als_device import state_from_factors

        mgr = (directory if isinstance(directory, CheckpointManager)
               else CheckpointManager(str(directory)))
        arrays, extra = mgr.restore_items(step)
        if extra.get("kind") != cls._CKPT_KIND:
            raise ValueError(
                f"checkpoint in {mgr.dir!r} is not a streaming session "
                f"(kind={extra.get('kind')!r})")
        policy = None
        if extra["policy"] is not None:
            from ..serve.buckets import BucketPolicy

            policy = BucketPolicy(**extra["policy"])
        s = cls(int(extra["rank"]), method=extra["method"],
                backend=extra["backend"], kappa=int(extra["kappa"]),
                check_every=int(extra["check_every"]),
                refine_iters=int(extra["refine_iters"]),
                solver=extra["solver"], runner=runner, policy=policy,
                decay=extra["decay"], weight_floor=extra["weight_floor"],
                session_id=extra.get("session_id"))
        s._shape = tuple(int(x) for x in extra["shape"])
        s._keys = arrays["keys"]
        s._idx = arrays["idx"]
        s._vals = arrays["vals"]
        s._entry_w = arrays["entry_w"] if extra["has_entry_w"] else None
        s._cap = int(extra["cap"])
        s.seed = int(extra["seed"])
        s.increments = int(extra["increments"])
        s.evictions = int(extra["evictions"])
        factors = [arrays[f"factors/{d}"] for d in range(len(s._shape))]
        s._state = state_from_factors(factors, arrays["lam"])
        return s

    # -- read side ----------------------------------------------------------

    @property
    def tensor(self) -> SparseTensor | None:
        """The UNPADDED accumulated tensor in canonical key order (the
        bucket padding exists only at fit time)."""
        if self._keys is None:
            return None
        return SparseTensor(self._idx, self._vals, self._shape)

    @property
    def entry_weights(self) -> np.ndarray | None:
        """Per-entry confidence mass entering the FIT objective (canonical
        order aligned with ``tensor``); None for an unweighted inner
        method (where any decay weights are eviction bookkeeping only)."""
        if self._weighted:
            return self._entry_w
        return None

    @property
    def session_weights(self) -> np.ndarray | None:
        """The decay/eviction weight track itself (also the fit
        confidences for weighted inner methods); None when untracked."""
        return self._entry_w

    @property
    def bucket_cap(self) -> int:
        """Current fit-time nnz residency class (0 = quantization off)."""
        return self._cap

    def stats(self) -> dict:
        """Per-session gauges (the standalone mirror of what runner-routed
        sessions report into ``serve.metrics``)."""
        lat = np.asarray(self._latencies, dtype=np.float64)
        return {
            "session_id": self.session_id,
            "nnz": 0 if self._keys is None else len(self._keys),
            "bucket_cap": self._cap,
            "increments": self.increments,
            "evictions": self.evictions,
            "merge_seconds": self.merge_seconds,
            "increment_p50_s": float(np.percentile(lat, 50)) if lat.size
            else 0.0,
            "increment_p99_s": float(np.percentile(lat, 99)) if lat.size
            else 0.0,
        }

    @property
    def result(self):
        return self._result

    @property
    def fit(self) -> float:
        if self._result is None or not self._result.fits:
            return float("-inf")
        return self._result.fits[-1]


STREAMING = register_method(MethodSpec(
    name="streaming",
    description="Streaming CP: stateful session folding nonzero increments "
                "into existing factors via warm-started refinement sweeps "
                "(inner method pluggable: cp, nncp, or masked with "
                "accumulating per-entry confidences).  Session state is "
                "bucket-quantized for zero-retrace increments, merged "
                "incrementally in O(nnz + m), bounded by confidence-decay "
                "eviction, and durable via checkpoint save/restore.",
    stateful=True,
    session_factory=StreamingCP,
))
