"""Streaming CP: fold newly arrived nonzeros into existing factors.

The streaming method is *stateful*: it does not replace the sweep's
inner loop but drives the substrate across calls.  A ``StreamingCP``
session holds the accumulated tensor and the current factor state;
``update(delta)`` merges the new nonzeros (coordinate-summing
duplicates) and runs a handful of WARM-STARTED refinement sweeps from
the current factors (``init_state`` threading in
``core.als_device.cpd_als_fused`` / the batched service) instead of a
full cold refit — the per-increment cost is ``refine_iters`` sweeps, not
``n_iters``, and the executable cache means an increment that lands in a
warm (shape, nnz-bucket, method) class pays zero retrace.

The inner method is pluggable: ``StreamingCP(rank, method="nncp")``
streams a nonnegative decomposition (a warm nonnegative state stays
nonnegative under HALS), ``method="cp"`` (default) the plain one.

Routed through ``runtime.ALSRunner`` (``runner=`` or
``ALSRunner.open_stream()``), every refinement window goes through the
batched service, so concurrent streaming sessions of the same bucket
class batch into one vmapped dispatch.

``tests/methods/test_streaming.py`` asserts that after k increments the
streamed factors match a batch refit of the full tensor to fp32
tolerance (fit and reconstruction at the observed coordinates — the
factor-permutation-invariant comparison).
"""
from __future__ import annotations

import numpy as np

from ..core.coo import SparseTensor
from .registry import MethodSpec, get_method, register_method


class StreamingCP:
    """Incremental CP session over a growing nonzero set."""

    def __init__(self, rank: int, *, method: str = "cp",
                 backend: str = "segment", kappa: int = 1,
                 check_every: int = 2, refine_iters: int = 2,
                 solver: str = "auto", runner=None):
        inner = get_method(method)
        if inner.stateful:
            raise ValueError(
                f"streaming wraps a sweep-based method, got {method!r}")
        self.rank = int(rank)
        self.method = method
        self.backend = backend
        self.kappa = int(kappa)
        self.check_every = int(check_every)
        self.refine_iters = int(refine_iters)
        self.solver = solver
        self.runner = runner
        self._tensor: SparseTensor | None = None
        self._state = None
        self._result = None
        self.increments = 0

    # -- substrate dispatch -------------------------------------------------

    def _fit(self, tensor, n_iters, tol, seed, init_state):
        if self.runner is not None:
            return self.runner.decompose(
                tensor, n_iters=n_iters, tol=tol, seed=seed,
                method=self.method, init_state=init_state)
        from ..core.als_device import cpd_als_fused

        return cpd_als_fused(
            tensor, self.rank, kappa=self.kappa, n_iters=n_iters, tol=tol,
            seed=seed, backend=self.backend, check_every=self.check_every,
            solver=self.solver, method=self.method, init_state=init_state)

    def _absorb(self, res):
        from ..core.als_device import state_from_factors

        self._result = res
        self._state = state_from_factors(res.factors, res.weights)
        return res

    # -- public API ---------------------------------------------------------

    def start(self, tensor: SparseTensor, *, n_iters: int = 25,
              tol: float = 1e-5, seed: int = 0):
        """Cold fit on the initial nonzero set."""
        self._tensor = tensor.deduplicate()
        self.increments = 0
        return self._absorb(self._fit(self._tensor, n_iters, tol, seed, None))

    def update(self, delta: SparseTensor, *, refine_iters: int | None = None,
               tol: float = -1.0):
        """Fold ``delta``'s nonzeros in (values at duplicate coordinates
        ADD — the streaming-accumulation semantics) and refine the current
        factors with ``refine_iters`` warm sweeps."""
        if self._tensor is None:
            raise RuntimeError("call start() before update()")
        if tuple(delta.shape) != tuple(self._tensor.shape):
            raise ValueError(
                f"increment shape {tuple(delta.shape)} != stream shape "
                f"{tuple(self._tensor.shape)}")
        merged = SparseTensor(
            np.concatenate([self._tensor.indices, delta.indices], axis=0),
            np.concatenate([self._tensor.values.astype(np.float32),
                            delta.values.astype(np.float32)]),
            self._tensor.shape,
        ).deduplicate()
        self._tensor = merged
        self.increments += 1
        k = self.refine_iters if refine_iters is None else int(refine_iters)
        return self._absorb(self._fit(merged, k, tol, 0, self._state))

    # -- read side ----------------------------------------------------------

    @property
    def tensor(self) -> SparseTensor | None:
        return self._tensor

    @property
    def result(self):
        return self._result

    @property
    def fit(self) -> float:
        if self._result is None or not self._result.fits:
            return float("-inf")
        return self._result.fits[-1]


STREAMING = register_method(MethodSpec(
    name="streaming",
    description="Streaming CP: stateful session folding nonzero increments "
                "into existing factors via warm-started refinement sweeps "
                "(inner method pluggable: cp or nncp).",
    stateful=True,
    session_factory=StreamingCP,
))
