"""Streaming CP: fold newly arrived nonzeros into existing factors.

The streaming method is *stateful*: it does not replace the sweep's
inner loop but drives the substrate across calls.  A ``StreamingCP``
session holds the accumulated tensor and the current factor state;
``update(delta)`` merges the new nonzeros (coordinate-summing
duplicates) and runs a handful of WARM-STARTED refinement sweeps from
the current factors (``init_state`` threading in
``core.als_device.cpd_als_fused`` / the batched service) instead of a
full cold refit — the per-increment cost is ``refine_iters`` sweeps, not
``n_iters``, and the executable cache means an increment that lands in a
warm (shape, nnz-bucket, method) class pays zero retrace.

The inner method is pluggable: ``StreamingCP(rank, method="nncp")``
streams a nonnegative decomposition (a warm nonnegative state stays
nonnegative under HALS), ``method="cp"`` (default) the plain one, and
``method="masked"`` a weighted completion stream: ``start``/``update``
then accept per-entry observation ``weights`` (fractional confidences),
which merge alongside the values — at duplicate coordinates both the
value and the confidence mass ADD, so re-observing an entry increases
its weight in the refinement objective.  Increments without weights
default to confidence 1 per entry.

Routed through ``runtime.ALSRunner`` (``runner=`` or
``ALSRunner.open_stream()``), every refinement window goes through the
batched service, so concurrent streaming sessions of the same bucket
class batch into one vmapped dispatch.

``tests/methods/test_streaming.py`` asserts that after k increments the
streamed factors match a batch refit of the full tensor to fp32
tolerance (fit and reconstruction at the observed coordinates — the
factor-permutation-invariant comparison).
"""
from __future__ import annotations

import numpy as np

from ..core.coo import SparseTensor, _linearize
from .registry import MethodSpec, get_method, register_method


def _dedup_weighted(indices: np.ndarray, values: np.ndarray,
                    weights: np.ndarray, shape):
    """Joint canonical dedup: values AND confidence weights sum at
    duplicate coordinates, in the same stable key order as
    ``SparseTensor.deduplicate`` (so the unweighted path and this one
    produce identically-ordered nnz lists)."""
    keys = _linearize(indices, shape)
    order = np.argsort(keys, kind="stable")
    keys_s = keys[order]
    uniq = np.empty(len(keys_s), dtype=bool)
    uniq[:1] = True
    uniq[1:] = keys_s[1:] != keys_s[:-1]
    group = np.cumsum(uniq) - 1
    n = int(group[-1]) + 1 if len(group) else 0
    vals = np.zeros(n, dtype=np.float32)
    np.add.at(vals, group, values[order].astype(np.float32))
    wts = np.zeros(n, dtype=np.float32)
    np.add.at(wts, group, weights[order].astype(np.float32))
    return SparseTensor(indices[order][uniq], vals, shape), wts


class StreamingCP:
    """Incremental CP session over a growing nonzero set."""

    def __init__(self, rank: int, *, method: str = "cp",
                 backend: str = "segment", kappa: int = 1,
                 check_every: int = 2, refine_iters: int = 2,
                 solver: str = "auto", runner=None):
        inner = get_method(method)
        if inner.stateful:
            raise ValueError(
                f"streaming wraps a sweep-based method, got {method!r}")
        self.rank = int(rank)
        self.method = method
        self.backend = backend
        self.kappa = int(kappa)
        self.check_every = int(check_every)
        self.refine_iters = int(refine_iters)
        self.solver = solver
        self.runner = runner
        self._tensor: SparseTensor | None = None
        self._weights: np.ndarray | None = None
        self._state = None
        self._result = None
        self.increments = 0

    # -- substrate dispatch -------------------------------------------------

    def _fit(self, tensor, n_iters, tol, seed, init_state, weights=None):
        if self.runner is not None:
            return self.runner.decompose(
                tensor, n_iters=n_iters, tol=tol, seed=seed,
                method=self.method, init_state=init_state, weights=weights)
        from ..core.als_device import cpd_als_fused

        return cpd_als_fused(
            tensor, self.rank, kappa=self.kappa, n_iters=n_iters, tol=tol,
            seed=seed, backend=self.backend, check_every=self.check_every,
            solver=self.solver, method=self.method, init_state=init_state,
            weights=weights)

    def _check_weighted(self):
        if not get_method(self.method).weighted_fit:
            raise ValueError(
                f"streaming weights require a weighted-fit inner method "
                f"(e.g. 'masked'), got {self.method!r}")

    def _absorb(self, res):
        from ..core.als_device import state_from_factors

        self._result = res
        self._state = state_from_factors(res.factors, res.weights)
        return res

    # -- public API ---------------------------------------------------------

    def start(self, tensor: SparseTensor, *, n_iters: int = 25,
              tol: float = 1e-5, seed: int = 0,
              weights: np.ndarray | None = None):
        """Cold fit on the initial nonzero set.  ``weights`` — per-entry
        observation confidences (weighted-fit inner methods only); at
        duplicate coordinates confidence mass sums alongside values."""
        self.increments = 0
        if weights is not None:
            self._check_weighted()
            w = np.asarray(weights, np.float32)
            self._tensor, self._weights = _dedup_weighted(
                tensor.indices, tensor.values, w, tensor.shape)
        else:
            self._tensor = tensor.deduplicate()
            self._weights = None
        return self._absorb(self._fit(self._tensor, n_iters, tol, seed,
                                      None, self._weights))

    def update(self, delta: SparseTensor, *, refine_iters: int | None = None,
               tol: float = -1.0, weights: np.ndarray | None = None):
        """Fold ``delta``'s nonzeros in (values at duplicate coordinates
        ADD — the streaming-accumulation semantics; confidence weights
        add too) and refine the current factors with ``refine_iters``
        warm sweeps.  A weighted stream stays weighted: increments
        without ``weights`` arrive at confidence 1 per entry."""
        if self._tensor is None:
            raise RuntimeError("call start() before update()")
        if tuple(delta.shape) != tuple(self._tensor.shape):
            raise ValueError(
                f"increment shape {tuple(delta.shape)} != stream shape "
                f"{tuple(self._tensor.shape)}")
        if weights is not None:
            self._check_weighted()
        idx = np.concatenate([self._tensor.indices, delta.indices], axis=0)
        vals = np.concatenate([self._tensor.values.astype(np.float32),
                               delta.values.astype(np.float32)])
        if weights is not None or self._weights is not None:
            w_old = (self._weights if self._weights is not None
                     else np.ones(self._tensor.nnz, np.float32))
            w_new = (np.asarray(weights, np.float32) if weights is not None
                     else np.ones(delta.nnz, np.float32))
            merged, self._weights = _dedup_weighted(
                idx, vals, np.concatenate([w_old, w_new]),
                self._tensor.shape)
        else:
            merged = SparseTensor(idx, vals,
                                  self._tensor.shape).deduplicate()
        self._tensor = merged
        self.increments += 1
        k = self.refine_iters if refine_iters is None else int(refine_iters)
        return self._absorb(self._fit(merged, k, tol, 0, self._state,
                                      self._weights))

    # -- read side ----------------------------------------------------------

    @property
    def tensor(self) -> SparseTensor | None:
        return self._tensor

    @property
    def entry_weights(self) -> np.ndarray | None:
        """Accumulated per-entry confidence mass (canonical order aligned
        with ``tensor``); None for an unweighted stream."""
        return self._weights

    @property
    def result(self):
        return self._result

    @property
    def fit(self) -> float:
        if self._result is None or not self._result.fits:
            return float("-inf")
        return self._result.fits[-1]


STREAMING = register_method(MethodSpec(
    name="streaming",
    description="Streaming CP: stateful session folding nonzero increments "
                "into existing factors via warm-started refinement sweeps "
                "(inner method pluggable: cp, nncp, or masked with "
                "accumulating per-entry confidences).",
    stateful=True,
    session_factory=StreamingCP,
))
