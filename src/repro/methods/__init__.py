"""Decomposition-methods subsystem: many solvers, one MTTKRP substrate.

MTTKRP is the shared bottleneck of the whole CP family, not just
unconstrained ALS — so the engine (fused sweeps), serving (bucketed
vmapped batches), and planning (static partition plans) layers built in
PRs 1–3 are method-agnostic, and this package is the methods layer on
top of them:

  registry   — ``MethodSpec`` catalogue; ``cpd_als(method=...)``,
               ``ALSRunner``, and the batched service route by name, and
               ``serve.buckets`` keys request classes on
               (shape, nnz-bucket, method).
  plain      — unconstrained CP-ALS ('cp', the inline substrate path).
  nncp       — nonnegative CP via HALS: factors provably >= 0, fit
               monotone nondecreasing; identical MTTKRP + gram tail.
  masked     — masked/weighted CP completion: EM residual spMTTKRP
               (per-sweep values threaded through the valued kernel
               entry point) + closed-form dense term; observed-only
               weighted fit with user-supplied per-entry confidences
               (``weights=`` on every front door, sequential / batched /
               distributed); weight-0 padding keeps serving exact.
  streaming  — stateful ``StreamingCP`` session: warm-started refinement
               folds nonzero increments into existing factors without a
               full refit (inner method pluggable; confidence mass
               accumulates at re-observed coordinates).

Adding a solver = writing ``build_sweep(ctx)`` against
``core.als_device.SweepContext`` and registering a ``MethodSpec`` —
bucketing, batching, caching, and scheduling come for free.
"""
from .registry import (MethodSpec, batchable_methods, get_method,
                       list_methods, register_method)
from . import plain as _plain          # noqa: F401  (registers 'cp')
from . import nncp as _nncp            # noqa: F401  (registers 'nncp')
from . import masked as _masked        # noqa: F401  (registers 'masked')
from .streaming import StreamingCP     # (registers 'streaming')

__all__ = [
    "MethodSpec", "register_method", "get_method", "list_methods",
    "batchable_methods", "StreamingCP",
]
