"""Decomposition-method registry: one catalogue of update rules that all
ride the shared fused-MTTKRP substrate.

A *method* is an update rule plugged into the sweep the engines already
know how to run (``core.als_device.build_sweep_fn``): the substrate owns
the MTTKRP kernels, the partition plans, the ``lax.scan`` check windows,
the executable cache, and the vmapped batched service; a method owns
only what is genuinely different about it —

  * ``build_sweep(ctx)``   — given a ``SweepContext`` (MTTKRP primitives,
    ridge solver, sparse fit), return
    ``sweep(state, mode_data_all, fit_data) -> (state, fit)`` with the
    same state pytree contract as plain CP, so the sequential scan
    block, ``jax.vmap``, and donation all apply unchanged.
  * ``init_state_host``    — seeded host-numpy init (e.g. nonnegative).
  * ``make_fit_data``      — ``(tensor, entry_weights=None)`` -> per-
    request device fit inputs when the method's fit differs (e.g.
    masked: per-entry observation weights, defaulting to all-ones).
  * ``valued_mode_data``   — the method re-threads fresh per-sweep values
    through the kernels (structural mode data + the valued MTTKRP entry
    point) instead of consuming values baked into the layout.
  * stateful methods (streaming) ship a ``session_factory`` instead of a
    sweep: they *drive* the substrate across calls rather than replacing
    its inner loop.

Registering a solver is the whole integration: ``cpd_als(method=...)``,
``ALSRunner``, and the batched service route by name, and
``serve.buckets`` keys request classes on (shape, nnz-bucket, method) so
mixed-method streams batch correctly.
"""
from __future__ import annotations

import dataclasses
from typing import Callable


@dataclasses.dataclass(frozen=True)
class MethodSpec:
    """One decomposition method's contract with the substrate."""

    name: str
    description: str = ""
    # (ctx: core.als_device.SweepContext) -> sweep fn; None for the inline
    # CP path and for stateful methods.
    build_sweep: Callable | None = None
    # (shape, rank, seed) -> host state tuple; None -> the shared default.
    init_state_host: Callable | None = None
    # (tensor, entry_weights=None) -> device fit_data pytree; None -> CP's
    # (idx, vals, norm²).
    make_fit_data: Callable | None = None
    # True: mode data is structural-only and the sweep threads fresh
    # values through the valued MTTKRP entry point each call.
    valued_mode_data: bool = False
    # True: fit_data carries per-entry observation weights — the user
    # front door (``weights=``) threads through them, and the serving
    # path zeroes them on nnz padding so padding stays an exact no-op.
    weighted_fit: bool = False
    stateful: bool = False
    session_factory: Callable | None = None


_REGISTRY: dict[str, MethodSpec] = {}


def register_method(spec: MethodSpec, *, override: bool = False) -> MethodSpec:
    if not override and spec.name in _REGISTRY:
        raise ValueError(f"method {spec.name!r} already registered")
    _REGISTRY[spec.name] = spec
    return spec


def get_method(name: str) -> MethodSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown decomposition method {name!r}; registered: "
            f"{sorted(_REGISTRY)}") from None


def list_methods() -> list[str]:
    return sorted(_REGISTRY)


def batchable_methods() -> list[str]:
    """Methods the vmapped batched service can execute directly (stateful
    methods drive the service through their sessions instead)."""
    return sorted(n for n, s in _REGISTRY.items() if not s.stateful)
