"""Compiled-HLO analysis: collective traffic + roofline terms.

cost_analysis() gives HLO FLOPs and bytes; collective bytes are NOT there,
so we parse the (post-SPMD-partitioning) HLO text and sum the sizes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute result, converted to per-device wire traffic with ring
formulas.  Shapes in partitioned HLO are already per-device.
"""
from __future__ import annotations

import dataclasses
import re

import numpy as np

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shape_bytes(txt: str) -> int:
    """Sum of array sizes in a result type, handling tuples."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(txt):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: dict
    result_bytes: dict     # per op kind, per-device result bytes
    wire_bytes: int        # modeled per-device wire traffic (ring algs)

    def as_dict(self):
        return {
            "counts": self.counts,
            "result_bytes": self.result_bytes,
            "wire_bytes": self.wire_bytes,
        }


def parse_collectives(hlo_text: str, *, group_size: int = 16) -> CollectiveStats:
    """Scan HLO for collective ops.  ``group_size`` is the typical
    participant count used for the (n-1)/n ring factor — the dominant mesh
    axis size; exact replica groups vary per op and are parsed when
    present."""
    counts: dict[str, int] = {}
    rbytes: dict[str, int] = {}
    wire = 0.0
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(\([^)]*\)|[\w\[\],]+)\s+([\w\-]+)", s)
        if not m:
            continue
        result_type, op = m.group(1), m.group(2)
        kind = None
        for c in _COLLECTIVES:
            if op == c or op.startswith(c + "-start") or op.startswith(c + "."):
                kind = c
                break
        if kind is None:
            continue
        n = group_size
        gm = re.search(r"replica_groups=\{\{([^}]*)\}", s)
        if gm:
            n = max(len(gm.group(1).split(",")), 1)
        else:
            gm2 = re.search(r"replica_groups=\[(\d+),(\d+)\]", s)
            if gm2:
                n = int(gm2.group(2))
        b = _shape_bytes(result_type)
        counts[kind] = counts.get(kind, 0) + 1
        rbytes[kind] = rbytes.get(kind, 0) + b
        ring = (n - 1) / max(n, 1)
        if kind == "all-gather":
            wire += b * ring                   # result is the gathered buf
        elif kind == "all-reduce":
            wire += 2 * b * ring               # reduce-scatter + all-gather
        elif kind == "reduce-scatter":
            wire += b * n * ring               # result is the scattered buf
        elif kind == "all-to-all":
            wire += b * ring
        elif kind == "collective-permute":
            wire += b
    return CollectiveStats(counts, rbytes, int(wire))


def roofline_terms(
    *,
    flops: float,
    hbm_bytes: float,
    wire_bytes: float,
    n_chips: int,
    hw: dict,
) -> dict:
    """Three roofline terms, in seconds (whole step, already per-device
    because partitioned-HLO costs are per-device)."""
    t_compute = flops / hw["peak_flops_bf16"]
    t_memory = hbm_bytes / hw["hbm_bw"]
    t_collective = wire_bytes / hw["ici_bw"]
    dominant = max(
        ("compute", t_compute), ("memory", t_memory),
        ("collective", t_collective), key=lambda kv: kv[1],
    )[0]
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_collective,
        "dominant": dominant,
        "bound_step_s": max(t_compute, t_memory, t_collective),
    }
