"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch granite-moe-1b-a400m \
        --steps 1000 --batch 32 --seq 512 --ckpt /tmp/run1 [--reduced]

On a real TPU slice this binary is what each host runs (jax.distributed
initializes from the TPU env); on CPU it trains over host devices.
Re-running the same command resumes from the newest committed checkpoint
(crash/preemption recovery); pass a different device topology to restore
elastically.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax

from repro import optim
from repro.configs import ARCHS, get_config, reduce_config
from repro.data import TokenPipeline
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import get_model
from repro.runtime import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internvl2-1b", choices=list(ARCHS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized sibling config (default on CPU)")
    ap.add_argument("--production-mesh", action="store_true",
                    help="use the 16x16 mesh (requires 256 devices)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced or jax.default_backend() == "cpu":
        cfg = reduce_config(cfg)
    cfg = dataclasses.replace(cfg, num_prefix_tokens=0, enc_layers=0)

    mesh = (make_production_mesh() if args.production_mesh
            else make_host_mesh())
    model = get_model(cfg)
    pipe = TokenPipeline(cfg.vocab_size, batch=args.batch, seq_len=args.seq,
                         seed=args.seed,
                         process_index=jax.process_index(),
                         process_count=jax.process_count())
    trainer = Trainer(
        model, mesh=mesh, pipeline=pipe,
        opt_cfg=optim.AdamWConfig(lr=args.lr, warmup_steps=args.steps // 20,
                                  total_steps=args.steps),
        ckpt_dir=args.ckpt, ckpt_every=args.ckpt_every,
        microbatch=args.microbatch,
    )
    hist = trainer.run(args.steps)
    if hist:
        print(f"[train] {args.arch}: loss {hist[0]['loss']:.4f} -> "
              f"{hist[-1]['loss']:.4f}; stragglers: "
              f"{len(trainer.monitor.events)}")


if __name__ == "__main__":
    main()
