"""Production meshes.

Single pod: (data=16, model=16) = 256 chips (TPU v5e-256 pod slice).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the 'pod' axis is
pure data parallelism whose collectives cross the data-center network.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """jax.make_mesh with Auto axis types when the jax version has them
    (jax.sharding.AxisType landed after 0.4.x)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:      # older jax: no explicit-sharding axis types
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(
        shape, axes, axis_types=(axis_type.Auto,) * len(axes)
    )


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


BATCH_AXIS = "batch"


def make_batch_mesh(num_devices: int | None = None):
    """1-D mesh over the batch axis for pod serving.

    The pod engine shards the *request* axis of a batched executable over
    this mesh, so the axis name is fixed (the engine's shard_map specs and
    the all-converged psum both reference it).  Defaults to every device
    jax can see; pass ``num_devices`` to run a pod on a subset.
    """
    n = len(jax.devices()) if num_devices is None else int(num_devices)
    if n < 1:
        raise ValueError("pod mesh needs at least one device")
    return make_mesh((n,), (BATCH_AXIS,))


def make_host_mesh(shape=None, axes=None):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    if shape is None:
        shape, axes = (n,), ("data",)
    return make_mesh(shape, axes)


# TPU v5e hardware constants (per chip) for the roofline model.
HW = {
    "name": "tpu_v5e",
    "peak_flops_bf16": 197e12,     # FLOP/s
    "hbm_bw": 819e9,               # B/s
    "ici_bw": 50e9,                # B/s per link (~4 links usable per chip)
    "dcn_bw": 6.25e9,              # B/s per host cross-pod (assumed 50 Gbit)
    "hbm_bytes": 16e9,
    "vmem_bytes": 128 * 2**20 / 8, # 16 MiB VMEM
}
