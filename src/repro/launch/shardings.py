"""Logical-axis -> mesh sharding resolution for params, optimizer state,
inputs and caches.

Rules (see DESIGN.md §5):
  batch   -> (pod, data)     activations' batch dim
  fsdp    -> data            weights' d_model-adjacent dim (ZeRO-3)
  tensor  -> model           heads / d_ff / expert-ff dims (TP)
  experts -> model            MoE expert dim (EP alias of TP axis)
  vocab   -> model           embedding/logits vocab dim
  seq     -> (None|data)     KV-cache seq dim (context parallelism for
                              batch-1 long-context decode)

Every rule application is divisibility-checked per-dim; non-dividing axes
fall back to replication for that dim (e.g. minitron's 24 heads on a
16-way model axis stay unsharded while its flattened 3072-wide q
projection shards cleanly).
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from ..models import common as mcommon


def param_shardings(model, mesh):
    """NamedShardings for every model parameter from its logical axes."""
    axes = model.param_axes()
    abstract = model.abstract_params()

    def resolve(ax, arr):
        return NamedSharding(mesh, mcommon.resolve_pspec(ax, arr.shape, mesh))

    return jax.tree.map(
        resolve, axes, abstract, is_leaf=lambda x: isinstance(x, tuple)
    )


def opt_state_shardings(param_shardings_tree, mesh):
    """Adam moments inherit param shardings; step counter replicated."""
    return {
        "mu": param_shardings_tree,
        "nu": param_shardings_tree,
        "step": NamedSharding(mesh, P()),
    }


def batch_shardings(specs: dict, mesh, *, seq_sharded: bool = False):
    """Input shardings: batch over (pod,data) when divisible; batch-1
    long-context inputs shard nothing (tokens) — their cache shards seq."""
    out = {}
    for k, v in specs.items():
        dims = [None] * len(v.shape)
        spec = mcommon.resolve_pspec(
            ("batch",) + (None,) * (len(v.shape) - 1), v.shape, mesh
        )
        out[k] = NamedSharding(mesh, spec)
        del dims
    return out


def cache_shardings(cache_tree, mesh, *, seq_axis_ok: bool,
                    kv_model_axis: bool = False,
                    kv_seq_model: bool = False):
    """KV/SSM cache shardings.

    Layout per leaf (stacked segments): (L, B, S, KH, hd) / (L, B, H, N, P)
    or unstacked (B, S, ...).  Batch shards over (pod,data) when divisible;
    otherwise (batch-1 long context) the seq dim shards over data.

    kv_model_axis: additionally shard the kv-heads dim (or head_dim when
    head count doesn't divide) over 'model' — TP-sharded KV cache (§Perf).
    """
    avail = dict(zip(mesh.axis_names, mesh.devices.shape))
    batch_axes = tuple(a for a in ("pod", "data") if a in avail)
    batch_size = int(np.prod([avail[a] for a in batch_axes])) if batch_axes else 1

    def resolve(arr):
        if not hasattr(arr, "shape") or arr.ndim == 0:
            return NamedSharding(mesh, P())
        shape = arr.shape
        # find the batch dim: first dim for unstacked, second for stacked
        # heuristics: stacked leaves have ndim >= 4 with dim0 == n_layers.
        spec = [None] * arr.ndim
        bdim = 0 if arr.ndim <= 3 else 1
        sdim = bdim + 1
        if shape[bdim] % batch_size == 0 and batch_size > 1:
            spec[bdim] = batch_axes if len(batch_axes) > 1 else batch_axes[0]
        elif (
            seq_axis_ok
            and "data" in avail
            and arr.ndim > sdim
            and shape[sdim] % avail["data"] == 0
            and shape[sdim] > 1024
        ):
            spec[sdim] = "data"   # context parallelism over the cache seq
        if (kv_seq_model and "model" in avail and arr.ndim >= sdim + 3
                and spec[sdim] is None and shape[sdim] % avail["model"] == 0
                and shape[sdim] > avail["model"]):
            # flash-decoding style: split the cache SEQ dim over 'model';
            # softmax merges via tiny psums, no contracting-dim resharding
            spec[sdim] = "model"
        elif kv_model_axis and "model" in avail and arr.ndim >= sdim + 3:
            # (..., S, KH, hd): prefer the head dim, fall back to head_dim
            for dim in (sdim + 1, sdim + 2):
                if shape[dim] % avail["model"] == 0 and shape[dim] > 1:
                    spec[dim] = "model"
                    break
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(resolve, cache_tree)
