"""Step-function builders: train / prefill / decode, ready for jit with
explicit shardings (used by the trainer, the server, and the dry-run).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .. import optim
from ..models import common as mcommon


def make_train_step(model, opt_cfg: optim.AdamWConfig, *, microbatch: int = 1):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics).  ``microbatch > 1`` accumulates gradients over batch slices
    via lax.scan (sequential, memory-bounded)."""

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def train_step(params, opt_state, batch):
        if microbatch > 1:
            B = batch["tokens"].shape[0]
            mb = B // microbatch

            def one(carry, i):
                acc, loss_acc = carry
                sl = jax.tree.map(
                    lambda x: jax.lax.dynamic_slice_in_dim(x, i * mb, mb, 0),
                    batch,
                )
                (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, sl
                )
                acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), acc, g
                )
                return (acc, loss_acc + loss), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (grads, loss_sum), _ = jax.lax.scan(
                one, (zeros, 0.0), jnp.arange(microbatch)
            )
            grads = jax.tree.map(lambda g: g / microbatch, grads)
            metrics = {"loss": loss_sum / microbatch}
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True
            )(params, batch)
        params, opt_state, om = optim.apply_updates(
            opt_cfg, params, grads, opt_state
        )
        return params, opt_state, {**metrics, **om}

    return train_step


def make_prefill_step(model):
    def prefill_step(params, cache, batch):
        kw: dict[str, Any] = {}
        if "prefix_embeds" in batch:
            kw["prefix_embeds"] = batch["prefix_embeds"]
        if "encoder_embeds" in batch:
            kw["encoder_embeds"] = batch["encoder_embeds"]
        logits, cache = model.prefill(params, batch["tokens"], cache, **kw)
        return logits, cache

    return prefill_step


def make_decode_step(model):
    def decode_step(params, cache, batch):
        logits, cache = model.decode_step(params, batch["tokens"], cache)
        # greedy next-token (serving returns token ids, not logits, to keep
        # the host transfer tiny)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, cache

    return decode_step
