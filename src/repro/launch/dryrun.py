import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import (jax locks the device
# count at first init).  512 placeholder host devices let jax.make_mesh
# build the production meshes; nothing is ever allocated (AOT lowering
# uses ShapeDtypeStructs only).

"""Multi-pod dry-run driver.

For every (architecture x input shape x mesh) cell:

  1. PROOF compile: the full-depth model (layers scanned) is
     jit(step).lower(**abstract_inputs).compile() — this is the
     deliverable showing the sharding config is coherent at 256/512
     chips.  memory_analysis() is read from this executable.

  2. COST probes: XLA's cost_analysis counts a lax.scan body ONCE, so HLO
     FLOPs/bytes/collectives are measured from two small UNROLLED compiles
     (L1, L2 layers) and extrapolated affinely in L — exact for
     layer-homogeneous stacks (validated against a fully-unrolled compile
     in EXPERIMENTS.md §Dry-run).

Usage:
  python -m repro.launch.dryrun --arch dbrx-132b --shape train_4k --mesh both
  python -m repro.launch.dryrun --all --out results/dryrun.json
"""
import argparse
import dataclasses
import json
import traceback

from ..obs import clock as obs_clock
import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import optim
from repro.configs import ARCHS, get_config
from repro.launch import shardings as shd
from repro.launch import steps as steps_mod
from repro.launch.hlo_analysis import parse_collectives, roofline_terms
from repro.launch.mesh import HW, make_production_mesh
from repro.models import SHAPES, get_model, shape_applicable, token_specs
from repro.models import common as mcommon


def _sharded_nbytes(tree, shardings) -> int:
    """Per-device bytes of a pytree under the given shardings."""
    total = 0
    for arr, sh in zip(jax.tree.leaves(tree), jax.tree.leaves(
            shardings, is_leaf=lambda x: isinstance(x, NamedSharding))):
        n = int(np.prod(arr.shape)) if arr.shape else 1
        n_shards = 1
        if isinstance(sh, NamedSharding):
            axes = dict(zip(sh.mesh.axis_names, sh.mesh.devices.shape))
            for dim_spec in sh.spec:
                if dim_spec is None:
                    continue
                for a in ((dim_spec,) if isinstance(dim_spec, str) else dim_spec):
                    n_shards *= axes[a]
        total += n * arr.dtype.itemsize // max(n_shards, 1)
    return total


def _with_layers(cfg, L: int):
    """Config with depth L, keeping family structure consistent."""
    kw = {"num_layers": L}
    if cfg.family == "hybrid":
        kw["global_attn_layers"] = (0, L // 2, L - 1)
    if cfg.enc_layers:
        kw["enc_layers"] = L
    return dataclasses.replace(cfg, **kw)


def _build(cfg, shape, mesh, *, quant_kv, microbatch, kv_model_axis=False,
           kv_seq_model=False):
    """Build (jitted, abstract_args, state_bytes) for one step kind."""
    model = get_model(cfg)
    params_abs = model.abstract_params()
    p_shard = shd.param_shardings(model, mesh)
    specs = token_specs(cfg, shape)
    in_shard = shd.batch_shardings(specs, mesh)
    B = shape.global_batch

    if shape.kind == "train":
        opt_cfg = optim.AdamWConfig()
        opt_abs = jax.eval_shape(optim.init_state, params_abs)
        o_shard = shd.opt_state_shardings(p_shard, mesh)
        step = steps_mod.make_train_step(model, opt_cfg, microbatch=microbatch)
        jitted = jax.jit(step, in_shardings=(p_shard, o_shard, in_shard),
                         donate_argnums=(0, 1))
        args = (params_abs, opt_abs, specs)
        state = _sharded_nbytes(params_abs, p_shard) + _sharded_nbytes(
            opt_abs, o_shard)
    else:
        cache_abs = jax.eval_shape(
            lambda: model.init_cache(B, shape.seq_len, dtype=jnp.bfloat16,
                                     quant_kv=quant_kv))
        seq_ok = shape.kind == "decode"
        c_shard = shd.cache_shardings(cache_abs, mesh, seq_axis_ok=seq_ok,
                                      kv_model_axis=kv_model_axis,
                                      kv_seq_model=kv_seq_model)
        if seq_ok:
            mcommon.set_rules(seq="data")
        fn = (steps_mod.make_decode_step(model) if shape.kind == "decode"
              else steps_mod.make_prefill_step(model))
        jitted = jax.jit(fn, in_shardings=(p_shard, c_shard, in_shard),
                         donate_argnums=(1,))
        args = (params_abs, cache_abs, specs)
        state = _sharded_nbytes(params_abs, p_shard) + _sharded_nbytes(
            cache_abs, c_shard)
    return jitted, args, state


def _compile_costs(cfg, shape, mesh, *, quant_kv, microbatch,
                   kv_model_axis=False, kv_seq_model=False) -> dict:
    """Compile once; return flops / bytes / collective stats (per device)."""
    jitted, args, _ = _build(cfg, shape, mesh, quant_kv=quant_kv,
                             microbatch=microbatch,
                             kv_model_axis=kv_model_axis,
                             kv_seq_model=kv_seq_model)
    with mesh:
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):   # older jax wraps the dict in a list
        cost = cost[0] if cost else {}
    coll = parse_collectives(compiled.as_text(), group_size=16)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "wire": float(coll.wire_bytes),
        "counts": coll.counts,
    }


def _extrapolate(c1, c2, L1, L2, L):
    out = {}
    for k in ("flops", "bytes", "wire"):
        slope = (c2[k] - c1[k]) / (L2 - L1)
        out[k] = c1[k] + slope * (L - L1)
    counts = {}
    for kind in set(c1["counts"]) | set(c2["counts"]):
        a, b = c1["counts"].get(kind, 0), c2["counts"].get(kind, 0)
        counts[kind] = int(round(a + (b - a) / (L2 - L1) * (L - L1)))
    out["counts"] = counts
    return out


def _attention_correction(cfg, shape) -> tuple[float, float]:
    """Exact analytic FLOPs/bytes of the chunked-attention einsums, which sit
    inside lax.scan bodies and are therefore counted once (not x trip count)
    by XLA cost analysis.  Matches the implementation exactly: full
    (Sq x Skv) rectangles with masking (the 2x causal overcompute is
    deliberately included — it is what the code executes; removing it is a
    §Perf hillclimb item).  Returns GLOBAL (flops, bytes) to add.

    decode shapes need no correction (single-pass attention, fully counted).
    """
    if shape.kind == "decode" or cfg.family == "ssm":
        return 0.0, 0.0
    B = shape.global_batch
    chunk = cfg.attn_chunk
    mult_f = 4.0 if shape.kind == "train" else 1.0   # fwd+remat+2x bwd
    mult_b = 3.0 if shape.kind == "train" else 1.0

    def one(Sq, Skv, H, KH, hd, n_layers):
        nq = max(-(-Sq // chunk), 1)
        nk = max(-(-Skv // chunk), 1)
        discount = 1.0 - 1.0 / (nq * nk)   # the once-counted body
        f = 4.0 * B * H * Sq * Skv * hd * discount
        by = (nq * B * Skv * KH * hd * 8.0 + B * Sq * H * hd * 12.0) * discount
        return n_layers * f * mult_f, n_layers * by * mult_b

    H, KH, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    S = shape.seq_len + cfg.num_meta_tokens + cfg.num_prefix_tokens
    fl, by = 0.0, 0.0
    if cfg.family == "encdec":
        f1, b1 = one(cfg.enc_seq, cfg.enc_seq, H, KH, hd, cfg.enc_layers)
        f2, b2 = one(shape.seq_len, shape.seq_len, H, KH, hd, cfg.num_layers)
        f3, b3 = one(shape.seq_len, cfg.enc_seq, H, KH, hd, cfg.num_layers)
        fl, by = f1 + f2 + f3, b1 + b2 + b3
    elif H:
        fl, by = one(S, S, H, KH, hd, cfg.num_layers)
    return fl, by


def _activation_bytes(cfg, shape, mesh) -> int:
    """Analytic per-device activation estimate (TPU memory model; XLA-CPU's
    buffer assignment is not representative — see EXPERIMENTS.md)."""
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    bsh = np.prod([axes.get(a, 1) for a in ("pod", "data")])
    B_loc = max(shape.global_batch // int(bsh), 1)
    d, L = cfg.d_model, cfg.num_layers
    S = shape.seq_len if shape.kind != "decode" else 1
    V_loc = cfg.padded_vocab // axes.get("model", 1)
    carry = B_loc * S * d * 2                     # bf16 residual per layer
    if shape.kind == "train":
        saved = L * carry                          # remat=full: carries only
        work = 8 * B_loc * S * d * 4               # attn/mlp working set f32
        logits = 2 * B_loc * S * V_loc * 4         # CE fwd+bwd f32
        return int(saved + work + logits)
    work = 6 * B_loc * S * d * 4
    logits = B_loc * 1 * V_loc * 4
    return int(work + logits + carry)


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             quant_kv: bool = False, microbatch: int = 1,
             extra_rules: dict | None = None, probes: bool = True,
             overrides: dict | None = None,
             kv_model_axis: bool = False,
             kv_seq_model: bool = False) -> dict:
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    cell = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "quant_kv": quant_kv,
    }
    if not ok:
        cell["skipped"] = reason
        return cell

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(mesh.devices.size)
    mcommon.reset_rules()
    if extra_rules:
        mcommon.set_rules(**extra_rules)

    # 1. PROOF compile: full depth, scanned.
    t0 = obs_clock.now()
    jitted, args, state_bytes = _build(cfg, shape, mesh, quant_kv=quant_kv,
                                       microbatch=microbatch,
                                       kv_model_axis=kv_model_axis,
                                       kv_seq_model=kv_seq_model)
    with mesh:
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
    compile_s = obs_clock.now() - t0
    mem = compiled.memory_analysis()

    # 2. COST probes: small unrolled depths, affine extrapolation in L.
    L = cfg.num_layers
    if probes:
        if cfg.family == "hybrid":
            L1, L2 = 5, 9
        else:
            L1, L2 = 2, 4
        cfg1 = dataclasses.replace(_with_layers(cfg, L1), scan_layers=False)
        cfg2 = dataclasses.replace(_with_layers(cfg, L2), scan_layers=False)
        c1 = _compile_costs(cfg1, shape, mesh, quant_kv=quant_kv,
                            microbatch=microbatch,
                            kv_model_axis=kv_model_axis,
                            kv_seq_model=kv_seq_model)
        c2 = _compile_costs(cfg2, shape, mesh, quant_kv=quant_kv,
                            microbatch=microbatch,
                            kv_model_axis=kv_model_axis,
                            kv_seq_model=kv_seq_model)
        est = _extrapolate(c1, c2, L1, L2, L)
    else:
        cost = compiled.cost_analysis() or {}
        if isinstance(cost, (list, tuple)):   # older jax wraps it in a list
            cost = cost[0] if cost else {}
        coll = parse_collectives(compiled.as_text(), group_size=16)
        est = {"flops": float(cost.get("flops", 0)),
               "bytes": float(cost.get("bytes accessed", 0)),
               "wire": float(coll.wire_bytes), "counts": coll.counts}

    attn_f, attn_b = _attention_correction(cfg, shape)
    flops = est["flops"] + attn_f / n_chips
    hbm_bytes = est["bytes"] + attn_b / n_chips
    wire = est["wire"]
    terms = roofline_terms(flops=flops, hbm_bytes=hbm_bytes, wire_bytes=wire,
                           n_chips=n_chips, hw=HW)

    N_active = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    model_flops = (6 if shape.kind == "train" else 2) * N_active * tokens
    model_flops_per_chip = model_flops / n_chips

    act_bytes = _activation_bytes(cfg, shape, mesh)
    per_dev = state_bytes + act_bytes
    cell.update({
        "compile_seconds": round(compile_s, 1),
        "n_chips": n_chips,
        "hlo_flops_per_chip": flops,
        "hlo_bytes_per_chip": hbm_bytes,
        "attn_correction_flops_per_chip": attn_f / n_chips,
        "attn_correction_bytes_per_chip": attn_b / n_chips,
        "collective_wire_bytes_per_chip": wire,
        "collective_counts": est["counts"],
        "roofline": terms,
        "model_flops_total": model_flops,
        "model_flops_per_chip": model_flops_per_chip,
        "useful_flop_ratio": (model_flops_per_chip / flops) if flops else None,
        "memory_analysis": {
            "argument_size_in_bytes": int(mem.argument_size_in_bytes),
            "output_size_in_bytes": int(mem.output_size_in_bytes),
            "temp_size_in_bytes": int(mem.temp_size_in_bytes),
            "alias_size_in_bytes": int(mem.alias_size_in_bytes),
        },
        "state_bytes_per_device": state_bytes,
        "activation_bytes_per_device_est": act_bytes,
        "peak_bytes_per_device_est": per_dev,
        "fits_hbm": bool(per_dev < HW["hbm_bytes"]),
        "mfu_upper_bound": (
            model_flops_per_chip / HW["peak_flops_bf16"]
        ) / max(terms["bound_step_s"], 1e-30),
    })
    return cell


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--quant-kv", action="store_true")
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--no-probes", action="store_true")
    ap.add_argument("--out", default=None, help="JSON output path")
    args = ap.parse_args()

    archs = list(ARCHS) if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch} x {shape} x {'2x16x16' if mp else '16x16'}"
                try:
                    r = run_cell(arch, shape, multi_pod=mp,
                                 quant_kv=args.quant_kv,
                                 microbatch=args.microbatch,
                                 probes=not args.no_probes)
                    if "skipped" in r:
                        print(f"[skip] {tag}: {r['skipped']}", flush=True)
                    else:
                        print(
                            f"[ok]   {tag}: compile={r['compile_seconds']}s "
                            f"flops/chip={r['hlo_flops_per_chip']:.3e} "
                            f"dominant={r['roofline']['dominant']} "
                            f"fits={r['fits_hbm']}", flush=True)
                except Exception as e:
                    r = {"arch": arch, "shape": shape,
                         "mesh": "2x16x16" if mp else "16x16",
                         "error": f"{type(e).__name__}: {e}",
                         "traceback": traceback.format_exc()[-2000:]}
                    print(f"[FAIL] {tag}: {r['error']}", flush=True)
                results.append(r)
                # write incrementally so long sweeps are restartable
                if args.out:
                    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
                    with open(args.out, "w") as f:
                        json.dump(results, f, indent=1)
    if args.out:
        print(f"wrote {args.out}")
    return results


if __name__ == "__main__":
    main()
