"""Production serving launcher: continuous batched greedy decoding.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-4b \
        --batch 8 --prompt-len 128 --gen 64 [--quant-kv] [--reduced]

The decode step is jitted with a donated cache (in-place on device);
tokens stream back to the host one id per sequence per step.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config, reduce_config
from repro.obs import clock as obs_clock
from repro.obs import health as obs_health
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_host_mesh
from repro.models import get_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-4b", choices=list(ARCHS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--quant-kv", action="store_true")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--slo-decode-ms", type=float, default=None,
                    help="per-token decode latency SLO; the run is judged "
                         "by obs.health and exits non-zero on breach")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced or jax.default_backend() == "cpu":
        cfg = reduce_config(cfg)
    model = get_model(cfg)
    mesh = make_host_mesh()

    with mesh:
        params = model.init(jax.random.PRNGKey(0))
        B, P, G = args.batch, args.prompt_len, args.gen
        prompts = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0,
                                     cfg.vocab_size)
        kw = {}
        if cfg.num_prefix_tokens:
            kw["prefix_embeds"] = 0.02 * jax.random.normal(
                jax.random.PRNGKey(2), (B, cfg.num_prefix_tokens, cfg.d_model))
        if cfg.enc_layers:
            kw["encoder_embeds"] = 0.1 * jax.random.normal(
                jax.random.PRNGKey(2), (B, cfg.enc_seq, cfg.d_model))
        cache = model.init_cache(B, P + G, dtype=jnp.float32,
                                 quant_kv=args.quant_kv)
        decode = jax.jit(steps_mod.make_decode_step(model),
                         donate_argnums=(1,))

        t0 = obs_clock.now()
        logits, cache = model.prefill(params, prompts, cache, **kw)
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        jax.block_until_ready(tok)
        t_prefill = obs_clock.now() - t0

        toks = [tok]
        t0 = obs_clock.now()
        for _ in range(G - 1):
            nxt, cache = decode(params, cache, {"tokens": tok})
            tok = nxt[:, None]
            toks.append(tok)
        jax.block_until_ready(tok)
        t_decode = obs_clock.now() - t0

    ms_per_tok = t_decode / max(G - 1, 1) * 1e3
    print(f"[serve] {args.arch}: batch={B} prompt={P} gen={G} "
          f"kv={'int8' if args.quant_kv else 'native'}")
    print(f"  prefill {t_prefill*1e3:.1f} ms | "
          f"decode {ms_per_tok:.2f} ms/tok | "
          f"throughput {B*(G-1)/max(t_decode,1e-9):.1f} tok/s")

    if args.slo_decode_ms is not None:
        # obs.health takes any hand-built gauge view; here the per-token
        # decode latency is the one SLO a launcher run can witness.
        policy = obs_health.SLOPolicy(latency_p99_s=args.slo_decode_ms / 1e3,
                                      min_events=1)
        report = obs_health.evaluate(
            policy, {"completed": G - 1, "latency_p99_s": ms_per_tok / 1e3})
        print(f"  [health] {report['status']}: decode {ms_per_tok:.2f} "
              f"ms/tok vs SLO {args.slo_decode_ms:.2f} ms/tok")
        if report["status"] != "ok":
            raise SystemExit(1)


if __name__ == "__main__":
    main()
