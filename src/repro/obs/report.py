"""Terminal dashboard over obs artifacts: ``python -m repro.obs.report``.

Reads any mix of

  * JSONL trace dumps (``Tracer.dump_jsonl`` — first line is meta),
  * Chrome-trace JSON exports (``Tracer.dump_chrome``),
  * ``results/BENCH_obs.json`` calibration outputs (and any other
    ``BENCH_*.json`` — rows carrying ``ServiceMetrics.snapshot()``
    sub-dicts get the dispatch/streams/queue/health gauge tables),

auto-detected per file, and renders:

  * a span tree with total/self wall time aggregated by name along the
    parent chain (children's totals are subtracted from the parent's
    self time),
  * the retrace/compile ledger — ``ledger.compile`` instant events
    grouped by executable-cache kind,
  * the predicted-vs-observed and load-imbalance tables from BENCH rows,
  * the serving gauges: pod/double-buffer dispatch, per-session
    streaming, queue depth/age, and SLO health,
  * ``--history`` — trend tables over the last k runs per section in
    ``results/BENCH_history.jsonl`` (gated metrics only, newest
    rightmost).

Pure stdlib; no jax import, so the dashboard works on any checkout.
"""
from __future__ import annotations

import json
import sys
from collections import defaultdict

from . import history as obs_history
from . import regress as obs_regress
from . import trace as obs_trace

_INDENT = "  "


# ---------------------------------------------------------------------------
# Span-tree aggregation
# ---------------------------------------------------------------------------


def _normalize(records: list[dict]) -> tuple[list[dict], list[dict]]:
    """(spans, events) from either JSONL records or Chrome trace events."""
    spans, events = [], []
    for r in records:
        kind = r.get("kind")
        if kind == "span":
            spans.append(r)
        elif kind == "event":
            events.append(r)
        elif "ph" in r:                       # Chrome trace event
            if r["ph"] == "X":
                spans.append({
                    "kind": "span", "id": r.get("args", {}).get("id"),
                    "parent": r.get("args", {}).get("parent"),
                    "name": r["name"], "cat": r.get("cat", "app"),
                    "tid": r.get("tid", 0), "ts_us": r.get("ts", 0.0),
                    "dur_us": r.get("dur", 0.0),
                    "args": r.get("args", {}),
                })
            elif r["ph"] == "i":
                events.append({
                    "kind": "event", "name": r["name"],
                    "cat": r.get("cat", "app"),
                    "args": r.get("args", {}),
                })
    return spans, events


def aggregate_tree(spans: list[dict]) -> dict:
    """Aggregate spans by their name-path (root → ... → name).

    Returns {path_tuple: {"count", "total_us", "self_us"}}; self time is
    total minus the sum of direct children's totals, floored at zero
    (clock granularity can make child sums overshoot).
    """
    by_id = {s["id"]: s for s in spans if s.get("id") is not None}

    def path_of(s):
        parts, seen = [], set()
        cur = s
        while cur is not None and cur["id"] not in seen:
            seen.add(cur["id"])
            parts.append(cur["name"])
            cur = by_id.get(cur.get("parent"))
        return tuple(reversed(parts))

    agg: dict = defaultdict(lambda: {"count": 0, "total_us": 0.0,
                                     "self_us": 0.0})
    child_total: dict = defaultdict(float)
    for s in spans:
        p = path_of(s)
        agg[p]["count"] += 1
        agg[p]["total_us"] += s["dur_us"]
        if len(p) > 1:
            child_total[p[:-1]] += s["dur_us"]
    for p, row in agg.items():
        row["self_us"] = max(row["total_us"] - child_total.get(p, 0.0), 0.0)
    return dict(agg)


def _fmt_us(us: float) -> str:
    if us >= 1e6:
        return f"{us / 1e6:8.3f}s "
    if us >= 1e3:
        return f"{us / 1e3:8.3f}ms"
    return f"{us:8.1f}µs"


def render_tree(spans: list[dict], out=None) -> None:
    out = out or sys.stdout
    agg = aggregate_tree(spans)
    if not agg:
        print("  (no spans)", file=out)
        return
    print(f"  {'total':>10}  {'self':>10}  {'count':>6}  span", file=out)
    for path in sorted(agg, key=lambda p: (p[:1], -agg[p]["total_us"])):
        row = agg[path]
        name = _INDENT * (len(path) - 1) + path[-1]
        print(f"  {_fmt_us(row['total_us'])}  {_fmt_us(row['self_us'])}"
              f"  {row['count']:6d}  {name}", file=out)


def render_ledger(events: list[dict], out=None) -> None:
    """Group ledger.compile instant events (one per registered
    executable) by kind — the trace-side view of the retrace ledger."""
    out = out or sys.stdout
    compiles = [e for e in events if e["name"] == "ledger.compile"]
    if not compiles:
        print("  (no ledger.compile events)", file=out)
        return
    by_kind: dict = defaultdict(list)
    for e in compiles:
        by_kind[e.get("args", {}).get("kind", "?")].append(
            e.get("args", {}).get("key", "?"))
    for kind in sorted(by_kind):
        keys = by_kind[kind]
        print(f"  {kind:16s} {len(keys):3d} executable(s)", file=out)
        for k in keys:
            print(f"    - {k}", file=out)


# ---------------------------------------------------------------------------
# BENCH_obs tables
# ---------------------------------------------------------------------------


def render_bench(doc: dict, out=None) -> None:
    out = out or sys.stdout
    rows = doc.get("rows", doc if isinstance(doc, list) else [])
    ratio = [r for r in rows if r.get("section") == "ratio"]
    imb = [r for r in rows if r.get("section") == "imbalance"]
    ledger = [r for r in rows if r.get("section") == "ledger"]
    if ratio:
        print("  predicted vs observed (per backend):", file=out)
        print(f"    {'dataset':10s} {'backend':8s} {'pred_s':>10} "
              f"{'meas_s':>10} {'pred/obs':>10} {'compile_s':>10} "
              f"{'steady_s':>10}", file=out)
        for r in ratio:
            po = r.get("predicted_over_observed")
            print(f"    {r['dataset']:10s} {r['backend']:8s} "
                  f"{_num(r.get('predicted_s')):>10} "
                  f"{_num(r.get('measured_s')):>10} "
                  f"{_num(po):>10} "
                  f"{_num(r.get('compile_overhead_s')):>10} "
                  f"{_num(r.get('steady_window_s')):>10}", file=out)
    if imb:
        print("  load imbalance (max/mean shard time):", file=out)
        print(f"    {'dataset':10s} {'mode':>4} {'scheme':10s} "
              f"{'measured':>9} {'nnz-pred':>9}", file=out)
        for r in imb:
            for m in r.get("per_mode", []):
                print(f"    {r['dataset']:10s} {m['mode']:4d} "
                      f"{m['scheme']:10s} {m['measured_imbalance']:9.3f} "
                      f"{m['nnz_imbalance']:9.3f}", file=out)
    if ledger:
        print("  retrace ledger:", file=out)
        for r in ledger:
            for k, v in sorted(r.items()):
                if k in ("name", "section"):
                    continue
                print(f"    {k}: {v}", file=out)
    for r in rows:
        if isinstance(r, dict) and any(
                isinstance(r.get(k), dict)
                for k in ("dispatch", "streams", "queue", "health")):
            render_snapshot(r, out=out, label=_row_label(r))


def _row_label(row: dict) -> str:
    for key in ("name", "dataset", "stream"):
        v = row.get(key)
        if isinstance(v, str) and v:
            return v
    return "snapshot"


def render_snapshot(snap: dict, out=None, label: str = "snapshot") -> None:
    """Gauge tables from a ``ServiceMetrics.snapshot()``-shaped dict —
    pod/double-buffer dispatch, per-session streams, queue, and SLO
    health (whichever sub-dicts are present)."""
    out = out or sys.stdout
    disp = snap.get("dispatch")
    if isinstance(disp, dict) and disp:
        print(f"  {label}: dispatch gauges:", file=out)
        for k in ("count", "assembly_s", "execute_s", "overlap_s",
                  "overlap_fraction", "device_occupancy"):
            if k in disp:
                print(f"    {k}: {_num(disp[k])}", file=out)
        per_dev = disp.get("device_dispatches")
        if per_dev:
            devs = " ".join(f"d{d}:{n}" for d, n in sorted(per_dev.items()))
            print(f"    device_dispatches: {devs}", file=out)
    streams = snap.get("streams")
    if isinstance(streams, dict) and streams:
        print(f"  {label}: streaming sessions:", file=out)
        print(f"    {'session':16s} {'incr':>5} {'evict':>5} "
              f"{'p50_s':>9} {'p99_s':>9} {'merge_s':>9}", file=out)
        for sid, s in sorted(streams.items()):
            print(f"    {str(sid)[:16]:16s} {s.get('increments', 0):5d} "
                  f"{s.get('evictions', 0):5d} "
                  f"{_num(s.get('increment_p50_s')):>9} "
                  f"{_num(s.get('increment_p99_s')):>9} "
                  f"{_num(s.get('merge_s')):>9}", file=out)
    queue = snap.get("queue")
    if isinstance(queue, dict) and queue:
        print(f"  {label}: queue: depth={queue.get('depth')} "
              f"oldest_age_s={_num(queue.get('oldest_age_s'))} "
              f"peak_depth={queue.get('peak_depth')} "
              f"peak_age_s={_num(queue.get('peak_age_s'))}", file=out)
    health = snap.get("health")
    if isinstance(health, dict) and health:
        status = health.get("status", "?")
        print(f"  {label}: health: {status} "
              f"({health.get('checked', 0)} SLO(s) judged)", file=out)
        for b in health.get("breaches", []):
            print(f"    BREACH {b.get('slo')} [{b.get('scope')}]: "
                  f"observed {_num(b.get('observed'))} vs "
                  f"{b.get('kind')} {_num(b.get('target'))}", file=out)


# ---------------------------------------------------------------------------
# History trends
# ---------------------------------------------------------------------------


def render_history(records: list[dict], out=None, k: int = 8,
                   sections: list[str] | None = None) -> None:
    """Trend tables over the ledger: per section, each gated metric's
    last-k values (oldest → newest, one column per run, git sha header).
    Metrics no spec gates are omitted — the trend table answers "is the
    gate about to fire", not "dump everything"."""
    out = out or sys.stdout
    if not records:
        print("  (empty history)", file=out)
        return
    secs = sections or sorted({r["section"] for r in records})
    for sec in secs:
        recs = obs_history.tail(records, sec, k)
        if not recs:
            continue
        labels = [r["git_sha"][:7] + ("*" if r.get("git_dirty") else "")
                  for r in recs]
        series = [obs_history.row_metrics(r.get("rows", [])) for r in recs]
        print(f"-- {sec} ({len(recs)} run(s), oldest -> newest) --",
              file=out)
        print(f"  {'metric':44s} " + " ".join(f"{l:>9}" for l in labels),
              file=out)
        names: list[str] = []
        for s in series:
            for name in s:
                if name not in names:
                    names.append(name)
        shown = 0
        for rname in names:
            metrics: list[str] = []
            for s in series:
                for m in s.get(rname, {}):
                    if m not in metrics:
                        metrics.append(m)
            for metric in metrics:
                spec = obs_regress.classify(metric)
                if spec is None:
                    continue
                vals = [s.get(rname, {}).get(metric) for s in series]
                arrow = "^" if spec.direction == "up" else "v"
                cells = " ".join(f"{_num(v):>9}" for v in vals)
                print(f"  {arrow} {rname + ':' + metric:42s} {cells}",
                      file=out)
                shown += 1
        if not shown:
            print("  (no gated metrics in this section's rows)", file=out)


def _num(x) -> str:
    if x is None:
        return "-"
    if isinstance(x, bool):
        return str(x)
    if isinstance(x, (int, float)):
        return f"{x:.4g}"
    return str(x)


# ---------------------------------------------------------------------------
# Entry
# ---------------------------------------------------------------------------


def _load(path: str):
    """('trace', spans, events) or ('bench', doc) by sniffing the file.
    A whole-file JSON parse distinguishes single-document exports; a
    failure means JSONL (one record per line, meta first)."""
    with open(path) as f:
        text = f.read()
    try:
        doc = json.loads(text)
    except json.JSONDecodeError:
        records = [json.loads(line) for line in text.splitlines()
                   if line.strip()]
        spans, events = _normalize(records)
        return ("trace", spans, events)
    if isinstance(doc, dict) and "traceEvents" in doc:
        obs_trace.validate_chrome(doc)
        spans, events = _normalize(doc["traceEvents"])
        return ("trace", spans, events)
    return ("bench", doc)


_DEFAULT_HISTORY = "results/BENCH_history.jsonl"


def main(argv: list[str] | None = None, out=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    out = out or sys.stdout
    history_path = None
    if "--history" in argv:
        i = argv.index("--history")
        argv.pop(i)
        # optional path operand; default is the repo ledger
        if i < len(argv) and not argv[i].startswith("-") \
                and argv[i].endswith(".jsonl"):
            history_path = argv.pop(i)
        else:
            history_path = _DEFAULT_HISTORY
    if "-h" in argv or "--help" in argv or (not argv and not history_path):
        print("usage: python -m repro.obs.report [--history [LEDGER]] "
              "TRACE_OR_BENCH_FILE...", file=out)
        print(__doc__, file=out)
        return 0 if (argv or history_path) else 2
    if history_path is not None:
        print(f"== {history_path} ==", file=out)
        try:
            records = obs_history.load(history_path, strict=False)
        except OSError as exc:
            print(f"  (cannot read ledger: {exc})", file=out)
            return 1
        render_history(records, out=out)
    for path in argv:
        kind, *rest = _load(path)
        print(f"== {path} ==", file=out)
        if kind == "trace":
            spans, events = rest
            print("-- span tree --", file=out)
            render_tree(spans, out=out)
            print("-- compile/retrace ledger --", file=out)
            render_ledger(events, out=out)
        else:
            (doc,) = rest
            print("-- calibration --", file=out)
            render_bench(doc, out=out)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
