"""Live serving SLO health: policy, evaluator, edge-triggered breaches.

``SLOPolicy`` names the targets a running decomposition service is held
to — per-bucket (and global) p99 request latency, queue depth/age,
cache-hit / double-buffer-overlap / batch-occupancy floors, and a
streaming-increment p99 ceiling.  ``evaluate`` is a pure function from
(policy, gauge view) to a health report; ``HealthMonitor`` wraps it with
edge-triggered ``health.breach`` trace events (one per breach *onset*,
through ``obs.trace``, so a JSONL trace alone reconstructs when each SLO
first went red and ``health.clear`` when it recovered).

The gauge view is the dict shape ``ServiceMetrics.snapshot()`` produces
(which is where the serving tier wires this in — ``snapshot()["health"]``)
but the evaluator itself only reads plain keys, so any caller with
numbers — e.g. the LM serving launcher gating decode latency — can build
a view by hand.

Floors (hit rate, occupancy, overlap) only arm once ``min_events``
batches have completed: a cold service's first flush always misses the
executable cache, and judging a floor on one event is noise, not health.

Pure-stdlib module (plus ``obs.trace``), importable everywhere.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Mapping

from . import trace as obs_trace

__all__ = ["SLOPolicy", "Breach", "evaluate", "HealthMonitor"]


@dataclasses.dataclass(frozen=True)
class SLOPolicy:
    """Targets; ``None`` disables a given SLO.  Latency/age knobs are
    ceilings, ``*_min`` knobs are floors."""

    latency_p99_s: float | None = None
    # str(bucket.key) -> per-bucket p99 ceiling; buckets without an
    # entry fall back to the global latency_p99_s.
    bucket_latency_p99_s: Mapping[str, float] | None = None
    queue_depth: int | None = None
    queue_age_s: float | None = None
    cache_hit_rate_min: float | None = None
    overlap_fraction_min: float | None = None
    batch_occupancy_min: float | None = None
    stream_increment_p99_s: float | None = None
    # Floors arm only after this many completed requests (cold-start
    # flushes always miss the cache; one event is noise).
    min_events: int = 8


@dataclasses.dataclass(frozen=True)
class Breach:
    """One violated SLO.  ``scope`` narrows it (bucket key, session id,
    or "service"); ``kind`` is "ceiling" or "floor"."""

    slo: str
    scope: str
    kind: str
    target: float
    observed: float

    def key(self) -> tuple[str, str]:
        return (self.slo, self.scope)

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def _ceiling(breaches: list[Breach], slo: str, scope: str,
             target: float | None, observed: float | None) -> None:
    if target is not None and observed is not None and observed > target:
        breaches.append(Breach(slo, scope, "ceiling", float(target),
                               float(observed)))


def _floor(breaches: list[Breach], slo: str, scope: str,
           target: float | None, observed: float | None) -> None:
    if target is not None and observed is not None and observed < target:
        breaches.append(Breach(slo, scope, "floor", float(target),
                               float(observed)))


def evaluate(policy: SLOPolicy, view: Mapping) -> dict:
    """Judge one gauge view against the policy.

    ``view`` keys read (all optional — an absent gauge is not judged):
    ``latency_p99_s``, ``bucket_latency_p99_s`` ({bucket: p99}),
    ``queue`` ({depth, oldest_age_s}), ``completed``, ``cache_hit_rate``,
    ``batch_occupancy``, ``dispatch`` ({count, overlap_fraction}),
    ``streams`` ({session: {increment_p99_s}}).

    Returns ``{"status": "ok"|"breach", "breaches": [breach dicts],
    "checked": n}`` — ``checked`` counts the SLOs that actually armed,
    so a green report on a cold service is distinguishable from one
    that judged nothing.
    """
    breaches: list[Breach] = []
    checked = 0
    completed = int(view.get("completed") or 0)
    warm = completed >= policy.min_events

    # -- latency ceilings ---------------------------------------------------
    if (policy.latency_p99_s is not None and completed > 0
            and view.get("latency_p99_s") is not None):
        checked += 1
        _ceiling(breaches, "latency_p99_s", "service",
                 policy.latency_p99_s, view.get("latency_p99_s"))
    per_bucket = view.get("bucket_latency_p99_s") or {}
    targets = policy.bucket_latency_p99_s or {}
    if (targets or policy.latency_p99_s is not None) and per_bucket:
        for bucket, p99 in per_bucket.items():
            target = targets.get(bucket, policy.latency_p99_s)
            if target is None:
                continue
            checked += 1
            _ceiling(breaches, "bucket_latency_p99_s", str(bucket),
                     target, p99)

    # -- queue ceilings (judged even cold: a saturated queue IS the
    # cold-start failure mode) ---------------------------------------------
    queue = view.get("queue") or {}
    if policy.queue_depth is not None and "depth" in queue:
        checked += 1
        _ceiling(breaches, "queue_depth", "service",
                 float(policy.queue_depth), queue.get("depth"))
    if policy.queue_age_s is not None and "oldest_age_s" in queue:
        checked += 1
        _ceiling(breaches, "queue_age_s", "service",
                 policy.queue_age_s, queue.get("oldest_age_s"))

    # -- floors (armed only warm) ------------------------------------------
    if warm:
        if (policy.cache_hit_rate_min is not None
                and view.get("cache_hit_rate") is not None):
            checked += 1
            _floor(breaches, "cache_hit_rate", "service",
                   policy.cache_hit_rate_min, view.get("cache_hit_rate"))
        if (policy.batch_occupancy_min is not None
                and view.get("batch_occupancy") is not None):
            checked += 1
            _floor(breaches, "batch_occupancy", "service",
                   policy.batch_occupancy_min, view.get("batch_occupancy"))
        dispatch = view.get("dispatch") or {}
        if (policy.overlap_fraction_min is not None
                and int(dispatch.get("count") or 0) >= policy.min_events):
            checked += 1
            _floor(breaches, "overlap_fraction", "service",
                   policy.overlap_fraction_min,
                   dispatch.get("overlap_fraction"))

    # -- streaming sessions -------------------------------------------------
    if policy.stream_increment_p99_s is not None:
        for sid, s in (view.get("streams") or {}).items():
            if int(s.get("increments") or 0) < 1:
                continue
            checked += 1
            _ceiling(breaches, "stream_increment_p99_s", str(sid),
                     policy.stream_increment_p99_s,
                     s.get("increment_p99_s"))

    return {
        "status": "breach" if breaches else "ok",
        "checked": checked,
        "breaches": [b.as_dict() for b in breaches],
    }


class HealthMonitor:
    """Stateful wrapper: evaluates a view and emits edge-triggered
    ``health.breach`` / ``health.clear`` trace events — one per breach
    onset/recovery, not per evaluation, so a long-red SLO doesn't flood
    the trace.  Thread-safe (snapshot() is callable from any thread)."""

    def __init__(self, policy: SLOPolicy):
        self.policy = policy
        self._lock = threading.Lock()
        self._active: dict[tuple[str, str], Breach] = {}

    def observe(self, view: Mapping) -> dict:
        report = evaluate(self.policy, view)
        breaches = {(b["slo"], b["scope"]): b for b in report["breaches"]}
        with self._lock:
            new = [b for k, b in breaches.items() if k not in self._active]
            cleared = [b for k, b in self._active.items()
                       if k not in breaches]
            self._active = {k: Breach(**b) for k, b in breaches.items()}
        for b in new:
            obs_trace.event("health.breach", cat="health", **b)
        for b in cleared:
            obs_trace.event("health.clear", cat="health", slo=b.slo,
                            scope=b.scope)
        return report

    def reset(self) -> None:
        with self._lock:
            self._active.clear()
