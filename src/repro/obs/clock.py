"""One clock front door for every layer.

Durations MUST be measured with ``now()`` (``time.perf_counter`` — the
highest-resolution monotonic clock; immune to wall-clock steps from NTP
or suspend, unlike ``time.time``).  ``wall()`` is the epoch clock, for
*timestamps* only (checkpoint metadata, trace-export epoch anchoring) —
never subtract two ``wall()`` readings to time something.

``process()`` (``time.process_time``) measures CPU time consumed by the
process — the span recorder stores both so a trace can separate
wall-blocked time (device dispatch, lock waits) from host compute.

These are aliases, not wrappers: the hot paths that guard on the active
tracer pay no extra Python frame for reading the clock.
"""
from __future__ import annotations

import time

now = time.perf_counter
process = time.process_time
wall = time.time
