"""Cost-model-vs-measured validation harness.

ROADMAP open item 2 flagged that the planning cost models
(``kernels.ops.auto_tiles`` / ``auto_rank_block``,
``benchmarks/device_model.py``) had never been validated against
observed timings.  This module is the measurement side of that loop:

  * ``measure_mode_seconds``   — warm per-mode MTTKRP wall time for a
    backend, measured through *tracer spans* (the numbers reported are
    read back out of the span records, so the harness exercises the
    tracing subsystem end to end rather than keeping a private
    stopwatch).
  * ``measure_shard_imbalance`` — per-mode load-imbalance factor
    (max/mean shard compute time) under a κ-way partition, the
    8-virtual-device mesh by default.  Shards are timed SERIALLY and
    UNPADDED on host (pure numpy segmented MTTKRP): the distributed
    path's rectangular padded shards would equalize the arithmetic and
    destroy exactly the signal being measured.  The measured factor is
    joined against the nnz-count imbalance the partitioner itself
    predicts (``core.load_balance.Partitioning.imbalance``).
  * ``measure_compile_steady`` — runs the fused ALS driver under the
    active tracer and splits the first (cold: trace+compile+execute)
    ``als.window`` span from the median warm window.
  * ``calibrate_tensor``       — one dataset end to end: joins an
    injected ``predict_fn`` (``benchmarks/obs_bench.py`` supplies the
    ``device_model`` predictor; src must not import benchmarks) against
    the measured per-mode seconds, producing the BENCH_obs row schema
    with ``predicted_over_observed`` per backend and the imbalance
    witness per mode.

The predicted/observed RATIO is the honest unit here: the device model
prices an RTX-3090-class GPU while CI measures on CPU (and the pallas
backend under interpret mode), so ratios are expected to sit far from
1.0 — what the harness pins is that they exist, are finite, and stay
STABLE per backend, which is what makes relative cost comparisons
(tiling choices, scheme selection) trustworthy.
"""
from __future__ import annotations

import functools
from typing import Callable

import numpy as np

import jax

from ..core import als_device
from ..core.coo import SparseTensor
from ..core.layout import build_mode_layout
from ..core.load_balance import partition_mode
from ..core.mttkrp import make_plan
from . import trace as obs_trace
from .clock import now as _now
from .ledger import LEDGER

DEFAULT_MESH_KAPPA = 8   # the CI "8-virtual-device mesh" width


# ---------------------------------------------------------------------------
# Per-mode measured MTTKRP (device path, through the tracer)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _mode_mttkrp_fn(backend: str, nmodes: int, rank: int,
                    shapes: tuple[int, ...], pallas_meta: tuple | None,
                    d: int):
    """Jitted single-mode MTTKRP dispatcher on the shared substrate (the
    same kernels every engine runs).  Registered in the retrace ledger
    like any other executable cache."""
    one = als_device._build_one_mttkrp(backend, nmodes, shapes, pallas_meta,
                                       True, None)

    def run(mode_data, factors):
        return one(d, mode_data, factors)

    return LEDGER.register(
        "calibrate_mode", (backend, nmodes, rank, shapes, "mode", d),
        jax.jit(run))


def _random_factors(shapes, rank: int, seed: int):
    rng = np.random.default_rng(seed)
    import jax.numpy as jnp
    return [jnp.asarray(rng.standard_normal((I, rank)).astype(np.float32))
            for I in shapes]


def _span_seconds(tr, start_idx: int, name: str) -> list[float]:
    """Durations (s) of spans named ``name`` recorded since start_idx."""
    return [r["dur_us"] / 1e6 for r in tr.records()[start_idx:]
            if r.get("kind") == "span" and r["name"] == name]


def measure_mode_seconds(tensor: SparseTensor, rank: int, backend: str,
                         *, reps: int = 3, seed: int = 0,
                         dataset: str = "?") -> list[float]:
    """Warm wall seconds of ONE MTTKRP per mode (best of ``reps``),
    measured via ``calibrate.mode_mttkrp`` spans on the active tracer
    (a private fallback timer is used only when tracing is off)."""
    tr = obs_trace.active()
    N = tensor.nmodes
    shapes = tuple(int(s) for s in tensor.shape)
    plan = make_plan(tensor, 1)
    mode_data_all, pallas_meta = als_device._collect_mode_data(
        plan, backend, rank)
    factors = _random_factors(shapes, rank, seed)
    out = []
    for d in range(N):
        fn = _mode_mttkrp_fn(backend, N, rank, shapes, pallas_meta, d)
        jax.block_until_ready(fn(mode_data_all[d], factors))   # compile/warm
        best = None
        for r in range(reps):
            if tr is None:
                t0 = _now()
                jax.block_until_ready(fn(mode_data_all[d], factors))
                dt = _now() - t0
            else:
                i0 = len(tr.records())
                with tr.span("calibrate.mode_mttkrp", cat="calibrate",
                             dataset=dataset, backend=backend, mode=d,
                             rep=r, nnz=tensor.nnz):
                    jax.block_until_ready(fn(mode_data_all[d], factors))
                dt = _span_seconds(tr, i0, "calibrate.mode_mttkrp")[-1]
            best = dt if best is None else min(best, dt)
        out.append(float(best))
    return out


# ---------------------------------------------------------------------------
# Measured per-shard load imbalance (serial, unpadded, pure numpy)
# ---------------------------------------------------------------------------


def _numpy_shard_mttkrp(idx, rows, vals, in_factors, rank: int):
    """Segmented MTTKRP of one shard's (sorted-row) slice in numpy.
    Work scales with the shard's real nnz — no padding, no jit — which
    is what makes per-shard wall time a faithful load proxy."""
    if len(vals) == 0:
        return np.zeros((0, rank), np.float32)
    acc = vals[:, None] * in_factors[0][idx[:, 0]]
    for j in range(1, idx.shape[1]):
        acc = acc * in_factors[j][idx[:, j]]
    starts = np.concatenate(
        [[0], np.flatnonzero(np.diff(rows)) + 1]).astype(np.int64)
    return np.add.reduceat(acc, starts, axis=0)


def measure_shard_imbalance(tensor: SparseTensor, rank: int, *,
                            kappa: int = DEFAULT_MESH_KAPPA,
                            reps: int = 20, seed: int = 0,
                            dataset: str = "?") -> list[dict]:
    """Per-mode measured load-imbalance factor under a κ-way partition.

    For each mode: build the real layout (scheme chosen by the adaptive
    rule, greedy assignment — exactly what the distributed path runs),
    time each shard's segmented MTTKRP serially over ``reps``
    repetitions, and report ``max/mean`` shard time next to the
    partitioner's own nnz-count prediction.  One span per mode carries
    both, so the imbalance table is reconstructible from a trace alone.
    """
    rng = np.random.default_rng(seed)
    shapes = tuple(int(s) for s in tensor.shape)
    in_factors_all = [rng.standard_normal((I, rank)).astype(np.float32)
                      for I in shapes]
    rows_out = []
    for d in range(tensor.nmodes):
        lay = build_mode_layout(tensor, d, kappa)
        part = partition_mode(tensor, d, kappa, scheme=lay.scheme)
        in_modes = lay.input_modes()
        facs = [in_factors_all[w] for w in in_modes]
        off = lay.part_offsets
        with obs_trace.span("calibrate.imbalance", cat="calibrate",
                            dataset=dataset, mode=d, kappa=kappa,
                            scheme=lay.scheme.name) as sp:
            times = []
            for p in range(kappa):
                s, e = int(off[p]), int(off[p + 1])
                idx = lay.indices[s:e][:, in_modes]
                rws = lay.rows[s:e]
                vls = lay.values[s:e].astype(np.float32)
                _numpy_shard_mttkrp(idx, rws, vls, facs, rank)  # warm caches
                t0 = _now()
                for _ in range(reps):
                    _numpy_shard_mttkrp(idx, rws, vls, facs, rank)
                times.append((_now() - t0) / reps)
            times_arr = np.asarray(times)
            mean = float(times_arr.mean())
            measured = float(times_arr.max() / mean) if mean > 0 else 1.0
            predicted = float(part.imbalance())
            sp.set(measured_imbalance=round(measured, 4),
                   nnz_imbalance=round(predicted, 4))
        rows_out.append({
            "mode": d,
            "scheme": lay.scheme.name,
            "shard_nnz": [int(x) for x in np.diff(off)],
            "measured_imbalance": measured,
            "nnz_imbalance": predicted,
            "mean_shard_s": mean,
            "max_shard_s": float(times_arr.max()),
        })
    return rows_out


# ---------------------------------------------------------------------------
# Compile-time vs steady-state split (from als.window spans)
# ---------------------------------------------------------------------------


def measure_compile_steady(tensor: SparseTensor, rank: int, backend: str,
                           *, check_every: int = 2, n_windows: int = 4,
                           seed: int = 0) -> dict:
    """Run the fused driver under the active tracer and split the cold
    first ``als.window`` span (trace + compile + execute) from the
    median warm window.  Requires an active tracer (the harness entry
    installs one); the retrace ledger confirms the cold window is where
    the executable's (only) trace landed."""
    tr = obs_trace.active()
    if tr is None:
        raise RuntimeError(
            "measure_compile_steady needs an active tracer "
            "(obs.trace.enable/capture)")
    i0 = len(tr.records())
    lstats0 = LEDGER.stats("sweep_block")
    als_device.cpd_als_fused(
        tensor, rank, n_iters=check_every * n_windows, tol=-1.0,
        check_every=check_every, backend=backend, seed=seed)
    lstats1 = LEDGER.stats("sweep_block")
    windows = _span_seconds(tr, i0, "als.window")
    if not windows:
        raise RuntimeError("fused driver emitted no als.window spans")
    cold = windows[0]
    warm = float(np.median(windows[1:])) if len(windows) > 1 else cold
    traces = (None if lstats1["traces"] is None or lstats0["traces"] is None
              else lstats1["traces"] - lstats0["traces"])
    return {
        "cold_window_s": float(cold),
        "steady_window_s": warm,
        "compile_overhead_s": float(max(cold - warm, 0.0)),
        "windows": len(windows),
        "sweep_traces": traces,
    }


# ---------------------------------------------------------------------------
# One dataset end to end
# ---------------------------------------------------------------------------


def calibrate_tensor(
    name: str,
    tensor: SparseTensor,
    *,
    rank: int = 32,
    backends: tuple[str, ...] = ("segment", "coo"),
    predict_fn: Callable[[SparseTensor, int, str], float] | None = None,
    kappa: int = DEFAULT_MESH_KAPPA,
    reps: int = 3,
    imbalance_reps: int = 20,
    seed: int = 0,
) -> list[dict]:
    """Calibrate one Table-3 generator: per-backend predicted-vs-observed
    rows plus one per-mode imbalance row.

    ``predict_fn(tensor, mode, backend) -> seconds`` is the cost model
    under test, injected by the caller (``benchmarks/obs_bench.py``
    wires ``benchmarks/device_model.py`` in; src never imports
    benchmarks).  Without it the prediction fields are None and the row
    is measurement-only.
    """
    rows: list[dict] = []
    N = tensor.nmodes
    for backend in backends:
        measured = measure_mode_seconds(
            tensor, rank, backend, reps=reps, seed=seed, dataset=name)
        per_mode = []
        pred_total = 0.0 if predict_fn is not None else None
        for d in range(N):
            pred = (float(predict_fn(tensor, d, backend))
                    if predict_fn is not None else None)
            if pred is not None:
                pred_total += pred
            per_mode.append({
                "mode": d,
                "predicted_s": pred,
                "measured_s": measured[d],
                "ratio": (pred / measured[d]
                          if pred is not None and measured[d] > 0 else None),
            })
        meas_total = float(sum(measured))
        split = measure_compile_steady(tensor, rank, backend, seed=seed)
        rows.append({
            "name": f"obs/{name}/{backend}",
            "section": "ratio",
            "dataset": name,
            "backend": backend,
            "shape": list(int(s) for s in tensor.shape),
            "nnz": int(tensor.nnz),
            "rank": int(rank),
            "predicted_s": pred_total,
            "measured_s": meas_total,
            "predicted_over_observed": (
                pred_total / meas_total
                if pred_total is not None and meas_total > 0 else None),
            "per_mode": per_mode,
            **split,
        })
    imb = measure_shard_imbalance(tensor, rank, kappa=kappa,
                                  reps=imbalance_reps, seed=seed,
                                  dataset=name)
    rows.append({
        "name": f"obs/{name}/imbalance",
        "section": "imbalance",
        "dataset": name,
        "kappa": int(kappa),
        "shape": list(int(s) for s in tensor.shape),
        "nnz": int(tensor.nnz),
        "per_mode": imb,
        "max_measured_imbalance": max(r["measured_imbalance"] for r in imb),
        "max_nnz_imbalance": max(r["nnz_imbalance"] for r in imb),
    })
    return rows
