"""Noise-aware benchmark regression gate over the history ledger.

Compares the last k history records per section (``obs.history``)
against the committed baseline (``results/BENCH_baseline.json``) with
per-metric *direction awareness* — latencies and per-iteration seconds
are down-good, hit rates / speedups / occupancy are up-good — and
tolerance bands calibrated from the k repeats:

  * **min-of-k aggregation.**  The fresh value a metric is judged on is
    its best over the k repeats (min for down-good, max for up-good) —
    the standard defense against one noisy repeat: a transient stall in
    one run cannot fail the gate, while a *real* regression moves every
    repeat and therefore the best.
  * **calibrated bands.**  Each spec carries a static tolerance; the
    effective band additionally widens to ``noise_mult`` x the observed
    relative spread of the repeats — the LARGER of the fresh repeats'
    spread and the spread recorded in the baseline's ``noise`` block
    when it was built — so a metric that is demonstrably jittery is
    held to a band its own noise justifies even when the fresh repeats
    happen to agree with each other on the wrong side of the baseline.
    The band is CAPPED at ``MAX_REL_TOL`` so no amount of jitter can
    mask a 2x change — the injected-slowdown guarantee the tests pin.
  * **portable vs timing metrics.**  Ratio/structural metrics (cache
    hit rate, speedup, padding, occupancy, overlap, imbalance,
    host_syncs) are machine-portable and always gated.  Absolute wall
    times are only comparable on the machine that wrote the baseline;
    CI (whose runners differ from the baseline writer) passes
    ``--portable-only`` to demote them to informational, while the
    default local gate checks both.

Unknown metrics are never gated (reported as unwatched) — the gate only
enforces directions it actually knows.

CLI::

    # gate the last 2 records per section against the baseline
    python -m repro.obs.regress --check --sections serve obs --repeats 2

    # bless the current history tail as the new baseline
    python -m repro.obs.regress --update-baseline --sections serve obs

Pure stdlib; no jax import.
"""
from __future__ import annotations

import argparse
import dataclasses
import fnmatch
import json
import sys

from . import history

__all__ = [
    "MetricSpec", "Finding", "DEFAULT_SPECS", "MAX_REL_TOL", "classify",
    "best", "rel_spread", "compare_metrics", "compare_sections",
    "baseline_from_history", "load_baseline", "main",
]

# No calibrated band may exceed this relative width: a 2x slowdown
# (rel_change = 1.0) is ALWAYS out of band, however noisy the repeats.
MAX_REL_TOL = 0.8

BASELINE_SCHEMA = 1


@dataclasses.dataclass(frozen=True)
class MetricSpec:
    """How one metric family is judged.  ``pattern`` is an fnmatch over
    the flattened metric name (first matching spec wins); ``direction``
    is "down" (smaller is better) or "up"; ``portable`` marks metrics
    comparable across machines (always gated — timings are not)."""

    pattern: str
    direction: str
    rel_tol: float
    abs_tol: float = 0.0
    portable: bool = True


# Ordered: first match wins.  Up-good ratio metrics come before the
# broad timing patterns so e.g. "cache_hit_rate" never falls through to
# a down-good rule.
DEFAULT_SPECS: tuple[MetricSpec, ...] = (
    # -- portable (machine-independent) ratios and counts ------------------
    MetricSpec("*hit_rate*", "up", 0.05, abs_tol=0.02),
    MetricSpec("*speedup*", "up", 0.25, abs_tol=0.05),
    MetricSpec("*_rps", "up", 0.5, portable=False),   # absolute throughput
    MetricSpec("*overlap_fraction*", "up", 0.30, abs_tol=0.05),
    MetricSpec("*occupancy*", "up", 0.15, abs_tol=0.05),
    MetricSpec("*padding_overhead*", "down", 0.10, abs_tol=0.02),
    MetricSpec("*imbalance*", "down", 0.15, abs_tol=0.05),
    MetricSpec("*host_syncs*", "down", 0.0, abs_tol=0.5),
    MetricSpec("*traces", "down", 0.0, abs_tol=0.5),
    MetricSpec("*err*", "down", 0.5, abs_tol=1e-6),
    MetricSpec("*fit_gap*", "down", 0.5, abs_tol=1e-4),
    # -- timings (same-machine only; CI demotes via --portable-only) -------
    MetricSpec("*latency*", "down", 0.5, portable=False),
    MetricSpec("*_s_per_*", "down", 0.5, portable=False),
    MetricSpec("*s_per_increment*", "down", 0.5, portable=False),
    MetricSpec("*_seconds*", "down", 0.5, portable=False),
    MetricSpec("*merge_s", "down", 0.5, portable=False),
    MetricSpec("*_us*", "down", 0.5, portable=False),
    MetricSpec("*_s", "down", 0.5, portable=False),
)


@dataclasses.dataclass
class Finding:
    """One judged (row, metric): status is "regression", "improved",
    "ok", "info" (known metric, not gated in this mode), "new" (no
    baseline value), or "missing" (baselined metric absent from the
    fresh runs — itself a gate failure: a silently dropped witness)."""

    section: str
    row: str
    metric: str
    direction: str | None
    baseline: float | None
    observed: float | None
    values: tuple[float, ...]
    rel_change: float | None     # + means worse, - means better
    tol: float | None
    status: str

    def describe(self) -> str:
        arrow = {"down": "v-good", "up": "^-good"}.get(self.direction or "",
                                                       "ungated")
        chg = ("" if self.rel_change is None
               else f" change={self.rel_change:+.1%} (band {self.tol:.1%})")
        return (f"[{self.status:10s}] {self.section}:{self.row}:"
                f"{self.metric} ({arrow}) baseline={_fmt(self.baseline)} "
                f"observed={_fmt(self.observed)}{chg}")


def _fmt(x: float | None) -> str:
    return "-" if x is None else f"{x:.6g}"


def classify(metric: str,
             specs: tuple[MetricSpec, ...] = DEFAULT_SPECS
             ) -> MetricSpec | None:
    """First matching spec for a flattened metric name (the part after
    the last '.' also tried, so gauge sub-dict keys like
    ``dispatch.overlap_fraction`` classify by their leaf)."""
    leaf = metric.rsplit(".", 1)[-1]
    for spec in specs:
        if fnmatch.fnmatch(metric, spec.pattern) or \
                fnmatch.fnmatch(leaf, spec.pattern):
            return spec
    return None


def best(values: list[float] | tuple[float, ...], direction: str) -> float:
    """Direction-aware best of k repeats (min for down-good timings,
    max for up-good rates)."""
    if not values:
        raise ValueError("no values")
    return min(values) if direction == "down" else max(values)


def rel_spread(values: list[float] | tuple[float, ...]) -> float:
    """Relative spread (max-min over max-abs) of the k repeats — the
    observed noise the tolerance band is calibrated from.  0 for a
    single repeat (the static band alone applies)."""
    if len(values) < 2:
        return 0.0
    lo, hi = min(values), max(values)
    scale = max(abs(lo), abs(hi))
    return (hi - lo) / scale if scale > 0 else 0.0


def compare_metrics(section: str, row: str, metric: str,
                    baseline: float | None, values: list[float], *,
                    specs: tuple[MetricSpec, ...] = DEFAULT_SPECS,
                    noise_mult: float = 2.0,
                    base_spread: float = 0.0,
                    portable_only: bool = False) -> Finding:
    """Judge one metric: direction-aware best-of-k vs the baseline under
    the calibrated band.  ``base_spread`` is the relative spread the
    baseline recorded for this metric when it was built (0 when the
    baseline predates the ``noise`` block or the metric was steady).
    See the module docstring for the rules."""
    spec = classify(metric, specs)
    if spec is None:
        return Finding(section, row, metric, None, baseline,
                       values[0] if values else None,
                       tuple(values), None, None, "info")
    obs = best(values, spec.direction)
    if baseline is None:
        return Finding(section, row, metric, spec.direction, None, obs,
                       tuple(values), None, None, "new")
    spread = max(rel_spread(values), base_spread)
    tol = min(max(spec.rel_tol, noise_mult * spread), MAX_REL_TOL)
    scale = max(abs(baseline), 1e-12)
    if spec.direction == "down":
        delta = obs - baseline               # + is worse
    else:
        delta = baseline - obs               # + is worse
    rel = delta / scale
    out_of_band = delta > tol * scale + spec.abs_tol
    if out_of_band:
        status = ("regression" if spec.portable or not portable_only
                  else "info")
    elif rel < 0:
        status = "improved"
    else:
        status = "ok"
    return Finding(section, row, metric, spec.direction, baseline, obs,
                   tuple(values), rel, tol, status)


def compare_sections(baseline_doc: dict, records: list[dict],
                     sections: list[str], *, repeats: int = 1,
                     specs: tuple[MetricSpec, ...] = DEFAULT_SPECS,
                     noise_mult: float = 2.0,
                     portable_only: bool = False) -> list[Finding]:
    """Gate ``sections``: the last ``repeats`` history records of each
    vs the committed baseline.  A section with a baseline but no fresh
    records, or a baselined metric absent from every fresh repeat, is a
    "missing" finding (a dropped witness fails the gate too)."""
    findings: list[Finding] = []
    base_sections = baseline_doc.get("sections", {})
    base_noise = baseline_doc.get("noise", {})
    for section in sections:
        base = base_sections.get(section, {})
        noise = base_noise.get(section, {})
        fresh = history.tail(records, section, repeats)
        if not fresh:
            findings.append(Finding(section, "-", "-", None, None, None,
                                    (), None, None, "missing"))
            continue
        per_repeat = [history.row_metrics(r["rows"]) for r in fresh]
        rows = set(base)
        for m in per_repeat:
            rows.update(m)
        for row in sorted(rows):
            brow = base.get(row, {})
            metrics = set(brow)
            for m in per_repeat:
                metrics.update(m.get(row, {}))
            for metric in sorted(metrics):
                values = [m[row][metric] for m in per_repeat
                          if metric in m.get(row, {})]
                bval = brow.get(metric)
                if not values:
                    # Baselined metric vanished from every fresh repeat.
                    if classify(metric, specs) is not None:
                        findings.append(Finding(
                            section, row, metric, None, bval, None, (),
                            None, None, "missing"))
                    continue
                findings.append(compare_metrics(
                    section, row, metric, bval, values, specs=specs,
                    noise_mult=noise_mult,
                    base_spread=noise.get(row, {}).get(metric, 0.0),
                    portable_only=portable_only))
    return findings


# ---------------------------------------------------------------------------
# Baseline build / load
# ---------------------------------------------------------------------------


def baseline_from_history(records: list[dict], sections: list[str], *,
                          repeats: int = 1,
                          specs: tuple[MetricSpec, ...] = DEFAULT_SPECS
                          ) -> dict:
    """Build a baseline document from the ledger tail: per section, the
    direction-aware best of the last ``repeats`` records per metric
    (ungated metrics keep the latest value, for the trend tables), plus
    a ``noise`` block recording each gated metric's relative spread
    across those repeats — check time widens its band to the larger of
    this and the fresh repeats' spread, so jitter witnessed when the
    baseline was blessed keeps protecting later runs whose own repeats
    happen to agree."""
    out_sections: dict[str, dict] = {}
    out_noise: dict[str, dict] = {}
    provenance: dict = {}
    for section in sections:
        fresh = history.tail(records, section, repeats)
        if not fresh:
            raise ValueError(f"history has no records for section "
                             f"{section!r}")
        provenance = {
            "git_sha": fresh[-1]["git_sha"],
            "ts_utc": fresh[-1]["ts_utc"],
            "host": fresh[-1]["host"],
            "device": fresh[-1]["device"],
            "smoke": fresh[-1]["smoke"],
        }
        per_repeat = [history.row_metrics(r["rows"]) for r in fresh]
        rows: dict[str, dict[str, float]] = {}
        noise: dict[str, dict[str, float]] = {}
        names = set()
        for m in per_repeat:
            names.update(m)
        for row in sorted(names):
            metrics: dict[str, float] = {}
            keys = set()
            for m in per_repeat:
                keys.update(m.get(row, {}))
            for metric in sorted(keys):
                values = [m[row][metric] for m in per_repeat
                          if metric in m.get(row, {})]
                spec = classify(metric, specs)
                if spec is None:
                    metrics[metric] = values[-1]
                    continue
                metrics[metric] = best(values, spec.direction)
                spread = rel_spread(values)
                if spread > 0.0:
                    noise.setdefault(row, {})[metric] = spread
            rows[row] = metrics
        out_sections[section] = rows
        if noise:
            out_noise[section] = noise
    return {"schema": BASELINE_SCHEMA, "repeats": repeats,
            "provenance": provenance, "sections": out_sections,
            "noise": out_noise}


def load_baseline(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    if not isinstance(doc, dict) or doc.get("schema") != BASELINE_SCHEMA:
        raise ValueError(f"{path}: not a schema-{BASELINE_SCHEMA} baseline")
    if not isinstance(doc.get("sections"), dict):
        raise ValueError(f"{path}: baseline missing 'sections'")
    return doc


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def main(argv: list[str] | None = None, out=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.regress",
        description="noise-aware benchmark regression gate")
    ap.add_argument("--history", default="results/BENCH_history.jsonl")
    ap.add_argument("--baseline", default="results/BENCH_baseline.json")
    ap.add_argument("--sections", nargs="+", required=True)
    ap.add_argument("--repeats", type=int, default=1,
                    help="how many trailing history records per section "
                         "to judge (min-of-k)")
    ap.add_argument("--noise-mult", type=float, default=2.0)
    ap.add_argument("--portable-only", action="store_true",
                    help="gate machine-portable metrics only (CI: the "
                         "runner is not the machine that wrote the "
                         "baseline, so absolute timings are demoted to "
                         "informational)")
    ap.add_argument("--check", action="store_true",
                    help="compare and exit 1 on any regression/missing")
    ap.add_argument("--update-baseline", action="store_true",
                    help="bless the history tail as the new baseline")
    args = ap.parse_args(argv)
    out = out or sys.stdout
    if args.check == args.update_baseline:
        ap.error("exactly one of --check / --update-baseline required")

    records = history.load(args.history)

    if args.update_baseline:
        doc = baseline_from_history(records, args.sections,
                                    repeats=args.repeats)
        with open(args.baseline, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
            f.write("\n")
        n = sum(len(m) for rows in doc["sections"].values()
                for m in rows.values())
        print(f"baseline updated: {args.baseline} "
              f"({len(doc['sections'])} section(s), {n} metric(s), "
              f"sha {doc['provenance'].get('git_sha', '?')[:12]})",
              file=out)
        return 0

    baseline_doc = load_baseline(args.baseline)
    findings = compare_sections(
        baseline_doc, records, args.sections, repeats=args.repeats,
        noise_mult=args.noise_mult, portable_only=args.portable_only)
    counts: dict[str, int] = {}
    for f in findings:
        counts[f.status] = counts.get(f.status, 0) + 1
    bad = [f for f in findings if f.status in ("regression", "missing")]
    for f in findings:
        if f.status in ("regression", "missing", "improved"):
            print(f.describe(), file=out)
    print(f"regression gate: {len(findings)} judged — "
          + ", ".join(f"{k}={v}" for k, v in sorted(counts.items())),
          file=out)
    if bad:
        print(f"FAIL: {len(bad)} out-of-band metric(s); re-run, or bless "
              f"an intentional change with --update-baseline", file=out)
        return 1
    print("PASS: every gated metric within its tolerance band", file=out)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
