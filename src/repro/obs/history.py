"""Append-only benchmark-history ledger: ``results/BENCH_history.jsonl``.

One schema-validated JSON record per ``benchmarks/run.py`` section run,
appended (never rewritten) so the repo accumulates a *trajectory* of
every tracked metric instead of a single point-in-time witness.  The
record carries everything needed to attribute a shift after the fact:

  * provenance — git sha (+ dirty flag), UTC timestamp, hostname,
    jax/device versions (``benchmarks/common.provenance``);
  * the section's structured rows, verbatim (the same rows
    ``BENCH_<section>.json`` holds), plus the plan fingerprints any row
    reported — so a perf shift is attributable to a planning change;
  * the run config (argv, smoke flag) and wall time.

Consumers:

  * ``repro.obs.regress`` — the noise-aware regression gate compares the
    last k records per section against the committed baseline;
  * ``python -m repro.obs.report --history`` — trend tables over the
    ledger;
  * ``python -m repro.obs.history validate <path>`` — CI's JSONL schema
    check (exit 1 on the first malformed record).

Pure stdlib (no jax import), so the ledger loads on any checkout — the
same discipline as ``obs.report``.
"""
from __future__ import annotations

import json
import math
import os
import sys

__all__ = [
    "SCHEMA_VERSION", "make_record", "validate_record", "append", "load",
    "tail", "row_metrics", "plan_fingerprints",
]

SCHEMA_VERSION = 1

# Top-level fields every history record must carry, with their types.
_REQUIRED: dict[str, type | tuple] = {
    "schema": int,
    "kind": str,              # always "bench" today; versioned for growth
    "section": str,
    "ts_utc": str,
    "git_sha": str,
    "host": str,
    "jax_version": str,
    "device": str,
    "wall_s": (int, float),
    "smoke": bool,
    "config": dict,
    "rows": list,
}

# Keys a bench row may use as its identity, in precedence order (the
# sections are not uniform: serve rows key on "stream", paper-table rows
# on "dataset", system rows on "name").
_ROW_NAME_KEYS = ("name", "dataset", "stream")


def validate_record(rec: object) -> None:
    """Raise ``ValueError`` naming the first schema violation."""
    if not isinstance(rec, dict):
        raise ValueError(f"history record must be an object, got "
                         f"{type(rec).__name__}")
    for key, typ in _REQUIRED.items():
        if key not in rec:
            raise ValueError(f"history record missing required field "
                             f"{key!r} (section={rec.get('section')!r})")
        if not isinstance(rec[key], typ):
            raise ValueError(
                f"history field {key!r} must be "
                f"{getattr(typ, '__name__', typ)}, got "
                f"{type(rec[key]).__name__}")
    if rec["schema"] != SCHEMA_VERSION:
        raise ValueError(f"unsupported history schema {rec['schema']} "
                         f"(this checkout reads {SCHEMA_VERSION})")
    for i, row in enumerate(rec["rows"]):
        if not isinstance(row, dict):
            raise ValueError(
                f"history record rows[{i}] must be an object, got "
                f"{type(row).__name__} (section={rec['section']!r})")


def make_record(section: str, *, rows: list | None, wall_s: float,
                config: dict, provenance: dict) -> dict:
    """Build (and validate) one history record.  ``provenance`` is the
    ``benchmarks/common.provenance()`` dict plus a fresh ``ts_utc``;
    sections that return no structured rows record an empty list."""
    rows = [r for r in (rows or []) if isinstance(r, dict)]
    rec = {
        "schema": SCHEMA_VERSION,
        "kind": "bench",
        "section": str(section),
        "ts_utc": str(provenance.get("ts_utc", "")),
        "git_sha": str(provenance.get("git_sha", "unknown")),
        "git_dirty": bool(provenance.get("git_dirty", False)),
        "host": str(provenance.get("host", "unknown")),
        "jax_version": str(provenance.get("jax_version", "unknown")),
        "device": str(provenance.get("device", "unknown")),
        "wall_s": float(wall_s),
        "smoke": bool(config.get("smoke", False)),
        "config": dict(config),
        "plan_fingerprints": plan_fingerprints(rows),
        "rows": rows,
    }
    validate_record(rec)
    return rec


def append(path: str | os.PathLike, record: dict) -> None:
    """Validate and append one record (one JSON line).  Append-only by
    construction: the ledger is never rewritten, so concurrent sections
    and historical runs can only add lines."""
    validate_record(record)
    with open(path, "a") as f:
        f.write(json.dumps(record, sort_keys=True) + "\n")


def load(path: str | os.PathLike, *, strict: bool = True) -> list[dict]:
    """Read the ledger back (oldest first).  ``strict`` validates every
    record and raises on the first malformed line — the CI schema gate;
    ``strict=False`` skips malformed lines (forensics on a damaged
    ledger)."""
    out: list[dict] = []
    with open(path) as f:
        for lineno, line in enumerate(f, start=1):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
                validate_record(rec)
            except (json.JSONDecodeError, ValueError) as exc:
                if strict:
                    raise ValueError(
                        f"{path}:{lineno}: {exc}") from exc
                continue
            out.append(rec)
    return out


def tail(records: list[dict], section: str, k: int) -> list[dict]:
    """The last ``k`` records for ``section``, oldest first."""
    if k < 1:
        raise ValueError("k must be >= 1")
    sec = [r for r in records if r.get("section") == section]
    return sec[-k:]


def plan_fingerprints(rows: list[dict]) -> list[str]:
    """The distinct plan fingerprints the section's rows reported
    (``core.plan.PartitionPlan.describe()`` strings), sorted — part of
    the record so a perf shift is attributable to a planning change."""
    return sorted({str(r["plan"]) for r in rows
                   if isinstance(r, dict) and isinstance(r.get("plan"), str)})


def _row_name(row: dict, index: int) -> str:
    for key in _ROW_NAME_KEYS:
        v = row.get(key)
        if isinstance(v, str) and v:
            return v
    return f"row[{index}]"


def row_metrics(rows: list[dict]) -> dict[str, dict[str, float]]:
    """Flatten a section's rows to ``{row_name: {metric: float}}`` —
    the shape the regression gate and the trend tables consume.

    Numeric scalar fields only; bools and non-finite floats are skipped
    (they are flags/sentinels, not metrics).  Nested dicts of numerics
    (the dispatch/queue gauge sub-dicts) flatten one level with a dotted
    key; deeper nesting and lists are dropped.
    """
    out: dict[str, dict[str, float]] = {}
    for i, row in enumerate(rows):
        if not isinstance(row, dict):
            continue
        metrics: dict[str, float] = {}

        def put(key: str, v: object) -> None:
            if isinstance(v, bool) or not isinstance(v, (int, float)):
                return
            v = float(v)
            if math.isfinite(v):
                metrics[key] = v

        for k, v in row.items():
            if isinstance(v, dict):
                for kk, vv in v.items():
                    put(f"{k}.{kk}", vv)
            else:
                put(str(k), v)
        if metrics:
            out[_row_name(row, i)] = metrics
    return out


def main(argv: list[str] | None = None) -> int:
    """``python -m repro.obs.history validate PATH...`` — exit 1 (with
    the offending line named) on the first malformed record."""
    argv = list(sys.argv[1:] if argv is None else argv)
    if not argv or argv[0] != "validate" or len(argv) < 2:
        print("usage: python -m repro.obs.history validate PATH...",
              file=sys.stderr)
        return 2
    for path in argv[1:]:
        try:
            records = load(path, strict=True)
        except (OSError, ValueError) as exc:
            print(f"INVALID {exc}", file=sys.stderr)
            return 1
        sections = sorted({r["section"] for r in records})
        print(f"{path}: {len(records)} record(s) OK; "
              f"sections: {', '.join(sections) if sections else '(none)'}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
