"""Low-overhead structured span/event recorder.

Design constraints, in priority order:

1. **Disabled is free.**  There is no global "maybe trace" wrapper on the
   hot paths; instrumented call sites do::

       tr = trace.active()
       if tr is None:
           ... dispatch ...          # zero obs allocations, one global read
       else:
           with tr.span("als.window", cat="als", window=k):
               ... dispatch ...

   ``active()`` returns a module global — no locks, no closures, no
   kwargs dict on the disabled branch.  A test asserts the disabled path
   adds zero allocations per dispatch.
2. **Records are plain dicts.**  One dict per finished span/event,
   appended to an in-memory list (CPython list.append is atomic under
   the GIL, so recording from scheduler/session threads needs no lock).
   Span nesting is tracked per thread via ``threading.local`` stacks.
3. **Two export shapes from the same records.**  JSONL (one record per
   line, greppable, the ``repro.obs.report`` input) and Chrome
   ``trace_event`` JSON (``{"traceEvents": [...]}`` with ``ph: "X"``
   complete events in microseconds — drop it into ``about:tracing`` or
   https://ui.perfetto.dev).

Every span carries wall-clock duration (``perf_counter``), process-CPU
duration (``process_time``), thread id, and arbitrary key-value attrs
(set at creation or via ``span.set(...)`` while open).  Timestamps are
offsets from the tracer's start on the monotonic clock; the epoch anchor
(``t0_wall``) is kept once in the tracer meta so exports can reconstruct
absolute times without any wall-clock subtraction in the measurement
path.
"""
from __future__ import annotations

import contextlib
import itertools
import json
import os
import threading
from typing import IO, Any, Iterator

from . import clock

__all__ = [
    "Tracer", "Span", "active", "enable", "disable", "capture", "span",
    "event", "load_jsonl", "validate_chrome",
]


class Span:
    """An open span; a context manager.  ``set(**attrs)`` attaches
    key-value attrs any time before exit.  The record is appended to the
    tracer only when the span closes."""

    __slots__ = ("_tracer", "name", "cat", "args", "id", "parent", "tid",
                 "t0", "_p0", "_stack")

    def __init__(self, tracer: "Tracer", name: str, cat: str,
                 args: dict[str, Any]):
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.args = args
        self.id = next(tracer._ids)
        self.tid = threading.get_ident()
        self._stack = tracer._thread_stack()
        self.parent = self._stack[-1].id if self._stack else None
        self._p0 = clock.process()
        self.t0 = clock.now()

    def set(self, **attrs: Any) -> "Span":
        self.args.update(attrs)
        return self

    def __enter__(self) -> "Span":
        self._stack.append(self)
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        t1 = clock.now()
        p1 = clock.process()
        stack = self._stack
        # Tolerate exits out of creation order (mis-nested user code):
        # remove self wherever it is rather than corrupting the stack.
        if stack and stack[-1] is self:
            stack.pop()
        elif self in stack:  # pragma: no cover - defensive
            stack.remove(self)
        if exc_type is not None:
            self.args.setdefault("error", exc_type.__name__)
        self._tracer._records.append({
            "kind": "span",
            "id": self.id,
            "parent": self.parent,
            "name": self.name,
            "cat": self.cat,
            "tid": self.tid,
            "ts_us": (self.t0 - self._tracer.t0) * 1e6,
            "dur_us": (t1 - self.t0) * 1e6,
            "proc_us": (p1 - self._p0) * 1e6,
            "args": self.args,
        })
        return False


class Tracer:
    """Collects span/event records in memory; export with
    ``dump_jsonl`` / ``dump_chrome`` (or read ``records()`` directly)."""

    def __init__(self, name: str = "repro"):
        self.name = name
        self.t0 = clock.now()
        self.t0_wall = clock.wall()
        self.pid = os.getpid()
        self._ids = itertools.count()
        self._records: list[dict] = []
        self._tls = threading.local()

    # -- recording ----------------------------------------------------------

    def _thread_stack(self) -> list:
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = self._tls.stack = []
        return stack

    def span(self, name: str, cat: str = "app", **attrs: Any) -> Span:
        """Open a span.  Use as a context manager; nesting is inferred
        from the per-thread stack of open spans."""
        return Span(self, name, cat, attrs)

    def event(self, name: str, cat: str = "app", **attrs: Any) -> None:
        """Record an instant event (no duration), parented to the
        innermost open span on this thread."""
        stack = self._thread_stack()
        self._records.append({
            "kind": "event",
            "id": next(self._ids),
            "parent": stack[-1].id if stack else None,
            "name": name,
            "cat": cat,
            "tid": threading.get_ident(),
            "ts_us": (clock.now() - self.t0) * 1e6,
            "args": attrs,
        })

    # -- reading / export ---------------------------------------------------

    def records(self) -> list[dict]:
        """The raw records (live list — copy before mutating)."""
        return self._records

    def meta(self) -> dict:
        return {"kind": "meta", "name": self.name, "pid": self.pid,
                "t0_wall": self.t0_wall}

    def dump_jsonl(self, path_or_file: str | IO[str]) -> None:
        """One JSON record per line; first line is the tracer meta."""
        def _write(f: IO[str]) -> None:
            f.write(json.dumps(self.meta()) + "\n")
            for rec in self._records:
                f.write(json.dumps(_jsonable(rec)) + "\n")
        if isinstance(path_or_file, (str, os.PathLike)):
            with open(path_or_file, "w") as f:
                _write(f)
        else:
            _write(path_or_file)

    def to_chrome(self) -> dict:
        """Chrome ``trace_event`` document (``about:tracing`` /
        Perfetto).  Spans become complete ("X") events, instant events
        become "i"; process/thread metadata rides along as "M"."""
        events: list[dict] = [
            {"name": "process_name", "ph": "M", "pid": self.pid, "tid": 0,
             "args": {"name": self.name}},
        ]
        tids = sorted({r["tid"] for r in self._records})
        for tid in tids:
            events.append({"name": "thread_name", "ph": "M",
                           "pid": self.pid, "tid": tid,
                           "args": {"name": f"thread-{tid}"}})
        for rec in self._records:
            ev = {
                "name": rec["name"],
                "cat": rec.get("cat", "app"),
                "pid": self.pid,
                "tid": rec["tid"],
                "ts": rec["ts_us"],
                "args": _jsonable(rec.get("args", {})),
            }
            if rec["kind"] == "span":
                ev["ph"] = "X"
                ev["dur"] = rec["dur_us"]
                ev["args"]["proc_us"] = rec.get("proc_us")
            else:
                ev["ph"] = "i"
                ev["s"] = "t"
            events.append(ev)
        return {"traceEvents": events, "displayTimeUnit": "ms",
                "otherData": {"t0_wall": self.t0_wall}}

    def dump_chrome(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f, indent=1)


def _jsonable(obj: Any) -> Any:
    """Best-effort conversion of attr values to JSON-serializable types
    (numpy scalars, tuples-as-keys etc. show up in plan attrs)."""
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, (str, int, float, bool)) or obj is None:
        return obj
    item = getattr(obj, "item", None)  # numpy scalars
    if callable(item):
        try:
            return item()
        except Exception:  # pragma: no cover - defensive
            pass
    return str(obj)


# -- module-level switchboard ------------------------------------------------

_ACTIVE: Tracer | None = None


def active() -> Tracer | None:
    """The installed tracer, or None when tracing is disabled.  Hot
    paths read this once and branch; the None branch is allocation-free."""
    return _ACTIVE


def enable(tracer: Tracer | None = None) -> Tracer:
    """Install (and return) the process-wide tracer."""
    global _ACTIVE
    _ACTIVE = tracer if tracer is not None else Tracer()
    return _ACTIVE


def disable() -> Tracer | None:
    """Uninstall the tracer; returns it so callers can still export."""
    global _ACTIVE
    tr, _ACTIVE = _ACTIVE, None
    return tr


@contextlib.contextmanager
def capture(name: str = "repro") -> Iterator[Tracer]:
    """Scoped tracing: installs a fresh Tracer for the with-block and
    restores the previous state after (the usual test/bench entry)."""
    global _ACTIVE
    prev = _ACTIVE
    tr = Tracer(name)
    _ACTIVE = tr
    try:
        yield tr
    finally:
        _ACTIVE = prev


class _NullSpan:
    """Inert span for convenience call sites when tracing is off."""

    __slots__ = ()

    def set(self, **attrs: Any) -> "_NullSpan":
        return self

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        return False


NULL = _NullSpan()


def span(name: str, cat: str = "app", **attrs: Any) -> Span | _NullSpan:
    """Convenience for warm (non-hot) paths: a real span when tracing is
    on, an inert one otherwise.  Hot per-dispatch sites should use the
    ``active()`` guard instead — this form builds a kwargs dict even
    when disabled."""
    tr = _ACTIVE
    return tr.span(name, cat, **attrs) if tr is not None else NULL


def event(name: str, cat: str = "app", **attrs: Any) -> None:
    """Convenience: record an instant event iff tracing is on."""
    tr = _ACTIVE
    if tr is not None:
        tr.event(name, cat, **attrs)


# -- loading / validation ----------------------------------------------------

def load_jsonl(path: str) -> list[dict]:
    """Read a JSONL trace back into records (meta line(s) excluded)."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            if rec.get("kind") != "meta":
                out.append(rec)
    return out


_CHROME_PHASES = {"X", "i", "M", "B", "E"}


def validate_chrome(doc: dict) -> list[dict]:
    """Validate a Chrome trace_event document; returns the event list.

    Raises ``ValueError`` describing the first violation.  Shared by the
    round-trip tests and the committed-artifact check so the schema is
    asserted in exactly one place.
    """
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        raise ValueError("not a Chrome trace: missing 'traceEvents'")
    events = doc["traceEvents"]
    if not isinstance(events, list):
        raise ValueError("'traceEvents' must be a list")
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"event {i}: not an object")
        for key in ("name", "ph", "pid", "tid"):
            if key not in ev:
                raise ValueError(f"event {i}: missing '{key}'")
        if ev["ph"] not in _CHROME_PHASES:
            raise ValueError(f"event {i}: unknown phase {ev['ph']!r}")
        if ev["ph"] in ("X", "i"):
            if not isinstance(ev.get("ts"), (int, float)):
                raise ValueError(f"event {i}: 'ts' must be numeric")
        if ev["ph"] == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                raise ValueError(f"event {i}: 'dur' must be >= 0")
        if "args" in ev and not isinstance(ev["args"], dict):
            raise ValueError(f"event {i}: 'args' must be an object")
    return events
