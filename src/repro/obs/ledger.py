"""The compile/retrace ledger: one registry for every executable cache.

The stack builds jitted executables in four places, each behind its own
``functools.lru_cache`` keyed on everything that *should* force a new
executable (backend, shapes, rank, tiling, method):

  * ``core.als_device._build_sweep_block``   — sequential fused sweeps
  * ``core.als_device._build_mttkrp_block``  — MTTKRP-only replay
  * ``serve.batched_engine._build_batched_block`` — vmapped service blocks
  * ``core.distributed._build_dist_sweep_block``  — shard_map sweeps

The lru hit/miss counters see *builder* calls, but jit re-specializes
per concrete nnz/shape INSIDE one builder entry — the retraces the
counters structurally cannot see.  Each builder therefore registers its
jitted fn here, and the ledger reads the per-executable trace count via
jax's (version-private, best-effort) ``fn._cache_size()`` to report
actual traces as a delta since the last ``reset()``.

This replaces the old ``als_device._SWEEP_BLOCK_REGISTRY`` module-global
list: the ledger is resettable (``reset()`` re-baselines trace counts so
assertions can't leak across tests — an autouse fixture in
tests/conftest.py calls it), scoped queries (``stats(kind=...)``), and
it feeds the tracer: every registration emits a ``ledger.compile`` event
so a trace alone reconstructs the compile story.

Entries are never dropped by ``reset()``: the lru caches keep the fns
alive for the life of the process, and keeping them lets the ledger
distinguish "new block built" (``blocks_new``) from "existing block
retraced" after a reset.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Any, Callable, Iterator

from . import trace as _trace

__all__ = ["RetraceLedger", "LEDGER"]


def _traces_of(fn: Any) -> int | None:
    """Actual trace count of a jitted fn via version-private jax
    introspection; None when the attribute is unavailable."""
    size: Callable[[], int] | None = getattr(fn, "_cache_size", None)
    if size is None:
        return None
    try:
        return int(size())
    except Exception:  # pragma: no cover - defensive
        return None


class RetraceLedger:
    """Thread-safe registry of (kind, key) -> jitted executable."""

    def __init__(self):
        self._lock = threading.Lock()
        # (kind, key) -> {"fn": fn, "baseline": int}
        self._entries: dict[tuple[str, str], dict] = {}
        # keys registered since the last reset()
        self._new: set[tuple[str, str]] = set()

    # -- write side ---------------------------------------------------------

    def register(self, kind: str, key: Any, fn: Any) -> Any:
        """Record a freshly built executable.  Called from inside the
        lru-cached builders, so each (kind, key) registers at most once
        per process; re-registration just refreshes the fn.  Emits a
        ``ledger.compile`` trace event.  Returns ``fn`` for chaining."""
        k = (kind, str(key))
        base = _traces_of(fn)
        with self._lock:
            self._entries[k] = {"fn": fn, "baseline": base or 0}
            self._new.add(k)
        _trace.event("ledger.compile", cat="compile", kind=kind,
                     key=str(key))
        return fn

    def reset(self) -> None:
        """Re-baseline: trace counts and the new-block set read as zero
        after this, so per-test / per-run deltas are isolated.  Entries
        themselves are retained (their executables stay alive in the lru
        caches regardless)."""
        with self._lock:
            for entry in self._entries.values():
                entry["baseline"] = _traces_of(entry["fn"]) or 0
            self._new.clear()

    @contextmanager
    def isolated(self) -> Iterator["RetraceLedger"]:
        """Scoped isolation: reset on entry AND exit, so deltas observed
        inside the block are the block's own and nothing leaks out."""
        self.reset()
        try:
            yield self
        finally:
            self.reset()

    # -- read side ----------------------------------------------------------

    def stats(self, kind: str | None = None) -> dict:
        """``{"blocks", "blocks_new", "traces"}`` for one kind (or all).

        ``blocks`` counts registered executables, ``blocks_new`` those
        registered since the last ``reset()``, and ``traces`` sums
        per-executable trace counts as a delta since ``reset()`` — or
        None when no executable exposes the introspection attribute
        (jax version drift), so callers can skip rather than misreport.
        """
        with self._lock:
            items = [(k, e) for k, e in self._entries.items()
                     if kind is None or k[0] == kind]
            new = sum(1 for k, _ in items if k in self._new)
        total = 0
        have = False
        for _, entry in items:
            n = _traces_of(entry["fn"])
            if n is not None:
                have = True
                total += max(n - entry["baseline"], 0)
        return {"blocks": len(items), "blocks_new": new,
                "traces": total if have else None}

    def entries(self, kind: str | None = None) -> list[dict]:
        """Per-executable rows for the report: kind, key, trace delta."""
        with self._lock:
            items = sorted(
                (k, e) for k, e in self._entries.items()
                if kind is None or k[0] == kind)
        out = []
        for (knd, key), entry in items:
            n = _traces_of(entry["fn"])
            out.append({
                "kind": knd,
                "key": key,
                "traces": None if n is None else max(n - entry["baseline"], 0),
            })
        return out

    def kinds(self) -> list[str]:
        with self._lock:
            return sorted({k for k, _ in self._entries})


#: The process-wide ledger every builder registers into.
LEDGER = RetraceLedger()
