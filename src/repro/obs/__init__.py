"""Unified telemetry: cross-layer tracing, compile/retrace accounting,
and the cost-model-vs-measured validation harness.

Three pieces, consumed by every layer of the stack:

  * ``obs.trace`` — a low-overhead span/event recorder.  Choke points
    across the stack (plan construction, slab packing, fused-sweep
    window dispatch, batched-service flushes, distributed windows,
    streaming increments) report into the ACTIVE tracer when one is
    installed and pay a single ``is None`` check when none is (the
    tracing-disabled hot path adds zero allocations per dispatch —
    enforced by test).  Traces export as JSONL or Chrome-trace JSON
    (viewable in ``about:tracing`` / Perfetto).
  * ``obs.ledger`` — ONE compile/retrace ledger keyed by executable
    cache: every jitted block builder (sequential sweep, MTTKRP replay,
    vmapped batched, distributed shard_map) registers its executables
    here, and per-executable trace counts expose retraces the lru
    hit/miss counters structurally cannot see.  Resettable and
    test-isolated (autouse fixture in tests/conftest.py).
  * ``obs.calibrate`` + ``benchmarks/obs_bench.py`` — replays the
    Table-3 generators per backend, joins predicted cost from the
    GPU-architectural model against measured span durations, and emits
    ``results/BENCH_obs.json`` (predicted-vs-observed ratio, per-mode
    load-imbalance factor, compile-vs-steady breakdown).

The perf-sentinel layer rides on the same artifacts:

  * ``obs.history`` — the append-only benchmark-history ledger
    (``results/BENCH_history.jsonl``): every ``benchmarks/run.py``
    section appends one schema-validated, provenance-stamped record
    (git sha, UTC timestamp, host, jax/device versions, rows, plan
    fingerprints).  ``python -m repro.obs.history validate`` is the CI
    schema gate.
  * ``obs.regress`` — the noise-aware regression gate: direction-aware
    per-metric specs, min/max-of-k best aggregation over the ledger's
    last k runs, tolerance bands widened by observed jitter but capped
    so a 2x shift always fails.  ``python -m repro.obs.regress --check``
    gates CI against the committed ``results/BENCH_baseline.json``;
    ``--update-baseline`` refreshes it.
  * ``obs.health`` — live serving SLO health: ``SLOPolicy`` targets
    (per-bucket p99 latency, queue depth/age, cache-hit / overlap /
    occupancy floors) judged against ``ServiceMetrics.snapshot()``
    views, with edge-triggered ``health.breach`` / ``health.clear``
    trace events so a JSONL trace alone reconstructs every incident.

``python -m repro.obs.report <file>`` renders any JSONL trace, Chrome
trace, or BENCH json as a terminal dashboard; ``--history`` adds trend
tables over the history ledger.

``obs.clock`` is the one monotonic-clock front door (``perf_counter``)
every layer times durations through; ``clock.wall`` is the epoch clock
for timestamps only.

Import discipline: this package's core (``trace``, ``ledger``,
``clock``, ``history``, ``regress``, ``health``) depends on the stdlib
only, so ``repro.core`` and ``repro.kernels`` can import it without
cycles; ``obs.calibrate`` and ``obs.report`` import the rest of the
stack and are therefore NOT imported here eagerly.
``history`` and ``regress`` double as ``python -m`` entrypoints, so they
(and ``health``, for symmetry) are imported explicitly
(``from repro.obs import health``), not eagerly here — an eager package
import of a ``-m`` target trips the runpy double-import warning.
"""
from . import clock  # noqa: F401
from .ledger import LEDGER, RetraceLedger  # noqa: F401
from .trace import (Tracer, active, capture, disable, enable, event,  # noqa: F401
                    load_jsonl, span, validate_chrome)

__all__ = [
    "clock", "LEDGER", "RetraceLedger", "Tracer", "active", "capture",
    "disable", "enable", "event", "load_jsonl", "span", "validate_chrome",
]
