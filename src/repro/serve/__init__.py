"""Serving subsystem: shape-bucketed, micro-batched decomposition service.

One small tensor cannot saturate the device (the paper's
overhead-dominated regime), so the throughput path is executing *many*
decompositions per dispatch:

  buckets        — quantize requests into (shape, nnz-bucket, method)
                   classes; zero-pad nnz to the bucket cap (bit-exact
                   no-op; the masked method gets the same exactness from
                   weight-0 padding).
  batched_engine — stack B bucket-mates, jax.vmap the fused ALS sweep of
                   the bucket's decomposition method (repro.methods),
                   per-tensor convergence masking, warm-start
                   init_states, executable cache.
  scheduler      — per-bucket queues, submit/future semantics,
                   max-batch / max-wait flush triggers, row-density
                   feedback into the bucket's partition plan.
  metrics        — throughput, p50/p99 latency, padding overhead, batch
                   occupancy, cache hit rates, per-bucket row-density
                   EWMA (the planning feedback channel).

``runtime.ALSRunner`` fronts this service (``mode="batched"``);
``benchmarks/serve_bench.py`` measures it against the sequential path.
"""
from .batched_engine import BatchedEngine, batched_cache_stats
from .buckets import Bucket, BucketPolicy, pad_tensor
from .metrics import BatchEvent, ServiceMetrics
from .scheduler import (BatchScheduler, DecompositionFuture,
                        DecompositionService)

__all__ = [
    "Bucket", "BucketPolicy", "pad_tensor",
    "BatchedEngine", "batched_cache_stats",
    "BatchScheduler", "DecompositionFuture", "DecompositionService",
    "BatchEvent", "ServiceMetrics",
]
