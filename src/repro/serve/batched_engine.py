"""Vmapped batched ALS engine: B same-bucket decompositions, one dispatch.

The small-tensor regime is overhead-dominated — a single sweep cannot
saturate the device — so the serving path stacks B bucket-mates (same
shape, nnz padded to the bucket cap, see ``serve.buckets``) and runs
``jax.vmap`` of the *same* closure-free sweep the sequential engine jits
(``core.als_device.build_sweep_fn``).  One dispatch then advances B
decompositions by a whole ``check_every`` window (``lax.scan``, exactly
mirroring the sequential engine's window structure):

  * per-tensor convergence masking: every tensor keeps sweeping until the
    whole batch is done, but a converged (or iteration-capped) tensor's
    state is frozen under ``jnp.where`` — its factors, fit, and iteration
    counter stop changing, so batching never alters an individual
    result.  Convergence is judged on device at window boundaries
    against the previous boundary's fit — the sequential engine's exact
    stopping rule, vectorized — so a request converges at the same
    iteration whichever front door served it (for a uniform-``n_iters``
    batch; mixed budgets can shift a straggler's window grid).
  * the batch state pytree is donated (off-CPU), so XLA reuses the B-way
    buffers in place across windows.
  * executables are cached per (bucket shape, nnz cap, B, rank, backend,
    solver, window, METHOD): a warm bucket class pays zero retrace per
    batch.  ``batched_cache_stats()`` exposes the counters.

Decomposition methods (``repro.methods``) batch through the same door:
``decompose_batch(method=...)`` vmaps that method's sweep under the same
executable cache.  The masked method's mode data is structural-only
(per-sweep residual values are scattered on device), its fit data
carries per-entry observation weights — user-supplied fractional
confidences via ``weights=`` (default 1), zeroed on nnz padding, which
is what keeps padding exact for completion — and ``init_states`` threads
warm starts (the streaming method's increments) through the service.

Backends: ``segment`` (default; per-tensor mode layouts are stacked —
same padded nnz ⇒ identical array shapes regardless of which
load-balancing scheme each tensor picked), ``coo`` (no host-side layout
preprocessing at all), and ``pallas``: each bucket-mate's layout is
packed to the bucket's static ``core.plan`` slab cap, so the slab arrays
share one shape and the kernel vmaps (interpret mode on CPU).  The
pallas path packs the UNPADDED tensors (slab-cap padding replaces nnz
padding), which keeps the batched result bit-identical to the
per-request sequential pallas engine under the same plan (the masked
method packs the PADDED tensors instead — its weight-0 entries are
already exact no-ops and the residual scatter needs one consistent
canonical order).

``density`` (an observed per-bucket row-density profile from
``serve.metrics``) reprices the bucket plan's tilings against the
stream's real skew instead of the uniform prior — see
``core.plan.plan_bucket``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P
try:
    from jax import shard_map
except ImportError:  # older jax keeps shard_map under experimental
    from jax.experimental.shard_map import shard_map

from ..core import als_device
from ..core import plan as plan_mod
from ..core.coo import SparseTensor
from ..core.cpd import CPDResult
from ..core.layout import build_all_mode_layouts
from ..kernels import ops as kops
from ..obs import clock as obs_clock
from ..obs import trace as obs_trace
from ..obs.ledger import LEDGER as _LEDGER
from .buckets import pad_tensor, pad_weights, repeat_pad

_BATCH_BACKENDS = ("segment", "coo", "pallas")


def _all_finite(tree) -> jnp.ndarray:
    """Scalar bool: every float leaf of ``tree`` is finite."""
    leaves = [l for l in jax.tree_util.tree_leaves(tree)
              if jnp.issubdtype(jnp.asarray(l).dtype, jnp.floating)]
    ok = jnp.bool_(True)
    for l in leaves:
        ok = ok & jnp.all(jnp.isfinite(l))
    return ok


def _make_window_runner(backend: str, nmodes: int, rank: int,
                        shapes: tuple[int, ...], interpret: bool,
                        solver: str, block: int,
                        pallas_meta: tuple | None, method: str):
    """The pure one-check-window function shared by the single-device
    batched block and the pod block: ``run_block(carry, mode_data_all,
    fit_data, tol_b, max_iters_b) -> (carry, fits (block, B))`` — a
    ``lax.scan`` of ``block`` vmapped sweeps with per-tensor convergence
    masking and the batch-level pinv-fallback cond.

    The pinv fallback is HOISTED to a batch-level ``lax.cond``: the
    window first scans a fallback-free sweep (under vmap a per-element
    ``lax.cond`` lowers to a select that always pays the small-R SVD);
    only if any float in the result is non-finite does the window re-run
    with the guarded sweep.  Well-conditioned batches — the overwhelming
    majority — never touch the SVD.  (For a method without a solve —
    HALS — the two sweeps coincide and the cond is a cheap no-op.)

    carry = (state, active (B,) bool, last_fit (B,), done (B,) int32)."""
    sweep_fast = als_device.build_sweep_fn(backend, nmodes, rank, shapes,
                                           pallas_meta, interpret, solver,
                                           fallback="none", method=method)
    sweep_safe = als_device.build_sweep_fn(backend, nmodes, rank, shapes,
                                           pallas_meta, interpret, solver,
                                           fallback="cond", method=method)
    vfast = jax.vmap(sweep_fast, in_axes=(0, 0, 0))
    vsafe = jax.vmap(sweep_safe, in_axes=(0, 0, 0))

    def run_block(carry, mode_data_all, fit_data, tol_b, max_iters_b):
        fit_ref = carry[2]       # fit at the previous window boundary

        def make_body(vsweep):
            def body(c, _):
                state, active, last_fit, done = c
                new_state, fit = vsweep(state, mode_data_all, fit_data)

                def freeze(new, old):
                    mask = active.reshape(
                        (active.shape[0],) + (1,) * (new.ndim - 1))
                    return jnp.where(mask, new, old)

                state = jax.tree_util.tree_map(freeze, new_state, state)
                fit = jnp.where(active, fit, last_fit)
                done = done + active.astype(jnp.int32)
                active = active & (done < max_iters_b)
                return (state, active, fit, done), fit
            return body

        fast_carry, fast_fits = lax.scan(make_body(vfast), carry, xs=None,
                                         length=block)
        # Batch-level all-finite gate: carry[2] (the -inf initial boundary
        # fit) is deliberately excluded — only the NEW state and fits
        # decide whether the guarded re-run is needed.
        ok = _all_finite((fast_carry[0], fast_fits))

        def rerun_safe(_):
            return lax.scan(make_body(vsafe), carry, xs=None, length=block)

        (state, active, fit, done), fits = lax.cond(
            ok, lambda _: (fast_carry, fast_fits), rerun_safe, None)
        # Convergence is judged at the WINDOW boundary against the previous
        # boundary's fit — the same rule (and therefore the same stopping
        # iteration) as the sequential fused engine, just vectorized.
        active = active & ~(jnp.abs(fit - fit_ref) < tol_b)
        return (state, active, fit, done), fits

    return run_block


@functools.lru_cache(maxsize=None)
def _build_batched_block(backend: str, nmodes: int, rank: int,
                         shapes: tuple[int, ...], nnz_cap: int, batch: int,
                         interpret: bool, donate: bool, solver: str,
                         block: int, pallas_meta: tuple | None = None,
                         method: str = "cp"):
    """Jitted one-check-window batched block (see ``_make_window_runner``).
    ``nnz_cap`` and ``batch`` are part of the key so the cache honestly
    counts one executable per (bucket, B) class."""
    run_block = _make_window_runner(backend, nmodes, rank, shapes,
                                    interpret, solver, block, pallas_meta,
                                    method)
    return _LEDGER.register(
        "batched_block",
        (backend, nmodes, rank, shapes, "cap", nnz_cap, "B", batch,
         "block", block, "method", method),
        jax.jit(run_block, donate_argnums=(0,) if donate else ()))


@functools.lru_cache(maxsize=None)
def _build_pod_block(mesh_, backend: str, nmodes: int, rank: int,
                     shapes: tuple[int, ...], nnz_cap: int,
                     batch_per_dev: int, interpret: bool, donate: bool,
                     solver: str, block: int, max_windows: int,
                     pallas_meta: tuple | None = None, method: str = "cp"):
    """The pod executable: ``shard_map`` over the mesh's batch axis of a
    ``lax.while_loop`` over whole check windows — a multi-window
    decomposition of B = devices * ``batch_per_dev`` requests is ONE
    device dispatch.

    Each device runs the SAME vmapped window the single-device batched
    block scans (``_make_window_runner``) on its ``batch_per_dev`` lanes;
    the loop condition reads an all-converged flag ``psum``-ed across the
    mesh INSIDE the body (collectives are illegal in a while cond, so the
    flag rides in the loop state) — no host judging between windows.  The
    per-lane ``done < max_iters_b`` freeze caps every lane at exactly its
    own budget, so running full windows only (``max_windows`` =
    ceil(max_iters / block)) produces trajectories identical to the
    single-device engine's remainder-window loop: frozen sweeps are
    no-ops and each lane's fit history is sliced to its own ``done``.

    Returns ``fn(carry, mode_data_all, fit_data, tol_b, max_iters_b) ->
    (carry, fits (max_windows*block, B), windows_run)``."""
    run_block = _make_window_runner(backend, nmodes, rank, shapes,
                                    interpret, solver, block, pallas_meta,
                                    method)
    axis = mesh_.axis_names[0]
    n_dev = int(mesh_.devices.size)
    total_rows = max_windows * block

    def pod_body(carry, mode_data_all, fit_data, tol_b, max_iters_b):
        fits_buf = jnp.zeros((total_rows, carry[1].shape[0]), jnp.float32)

        def wcond(ls):
            _c, _fb, w, global_active = ls
            return (w < max_windows) & global_active

        def wbody(ls):
            c, fb, w, _ = ls
            c, fits_blk = run_block(c, mode_data_all, fit_data, tol_b,
                                    max_iters_b)
            fb = lax.dynamic_update_slice(fb, fits_blk,
                                          (w * block, jnp.int32(0)))
            ga = lax.psum(jnp.any(c[1]).astype(jnp.int32), axis) > 0
            return (c, fb, w + jnp.int32(1), ga)

        carry, fits_buf, w, _ = lax.while_loop(
            wcond, wbody,
            (carry, fits_buf, jnp.int32(0), jnp.bool_(True)))
        return carry, fits_buf, w

    Pb = P(axis)
    fn = shard_map(
        pod_body, mesh=mesh_,
        in_specs=(Pb, Pb, Pb, Pb, Pb),
        out_specs=(Pb, P(None, axis), P()),
        check_rep=False,
    )
    return _LEDGER.register(
        "pod_block",
        (backend, nmodes, rank, shapes, "cap", nnz_cap,
         "B/dev", batch_per_dev, "devices", n_dev, "block", block,
         "windows", max_windows, "method", method),
        jax.jit(fn, donate_argnums=(0,) if donate else ()))


def batched_cache_stats():
    """(hits, misses, currsize) of the batched executable cache, keyed per
    (bucket, B, rank, backend, window, method)."""
    info = _build_batched_block.cache_info()
    return {"hits": info.hits, "misses": info.misses,
            "currsize": info.currsize}


class BatchedEngine:
    """Stacks same-bucket tensors and drives the vmapped fused sweep.

    With ``mesh`` (a 1-D device mesh, e.g. ``launch.mesh.make_batch_mesh``)
    the engine runs the POD path: the batch is padded to a mesh multiple
    (repeat-last-request — exact, lanes are independent), the vmapped
    window is wrapped in ``shard_map`` over the mesh's axis, and the
    whole multi-window decomposition executes as ONE dispatch with
    on-device convergence (``_build_pod_block``).  ``batch_quantum``
    feeds the ``core.plan.PodPlan`` sizing rule so direct engine callers
    and the scheduler agree on dispatched batch sizes."""

    def __init__(self, rank: int, *, kappa: int = 1,
                 backend: str = "segment", check_every: int = 4,
                 interpret: bool = True, donate: bool | None = None,
                 solver: str = "auto", mesh=None, batch_quantum: int = 1,
                 lane_placement: str = "balanced"):
        if backend not in _BATCH_BACKENDS:
            raise ValueError(
                f"batched engine supports {_BATCH_BACKENDS}, got "
                f"{backend!r}")
        if lane_placement not in ("balanced", "contiguous"):
            raise ValueError(
                f"lane_placement must be 'balanced' or 'contiguous', got "
                f"{lane_placement!r}")
        self.rank = rank
        self.kappa = kappa
        self.backend = backend
        self.check_every = max(1, int(check_every))
        self.interpret = bool(interpret)
        if donate is None:
            donate = jax.default_backend() != "cpu"
        self.donate = bool(donate)
        self.solver = als_device.resolve_solver(solver)
        if mesh is not None and len(mesh.axis_names) != 1:
            raise ValueError(
                f"pod mesh must be 1-D (the batch axis), got axes "
                f"{mesh.axis_names}")
        self.mesh = mesh
        self.batch_quantum = max(1, int(batch_quantum))
        self.lane_placement = lane_placement

    @property
    def num_devices(self) -> int:
        """Mesh size of the pod path (1 when running single-device)."""
        return 1 if self.mesh is None else int(self.mesh.devices.size)

    def pod_plan(self, shape: tuple[int, ...], nnz_cap: int,
                 density: tuple | None = None) -> plan_mod.PodPlan:
        """The pod sizing plan for a bucket class (mesh path only)."""
        if self.mesh is None:
            raise ValueError("engine has no mesh; pod_plan is undefined")
        return plan_mod.plan_pod(
            shape, nnz_cap, self.rank, self.kappa,
            num_devices=self.num_devices,
            batch_quantum=self.batch_quantum, density=density)

    # -- data staging -------------------------------------------------------

    def bucket_plan(self, shape: tuple[int, ...], nnz_cap: int,
                    density: tuple | None = None) -> plan_mod.PartitionPlan:
        """The static plan a (shape, nnz_cap) bucket executes under —
        shared with the sequential path for bit-identical results.
        ``density`` (observed per-mode row-density profile) reprices the
        tilings against the stream's real skew."""
        return plan_mod.plan_bucket(tuple(int(s) for s in shape),
                                    int(nnz_cap), self.rank, self.kappa,
                                    density=density)

    def _stack_pallas(self, source: list[SparseTensor], nnz_cap: int,
                      density, structural: bool):
        """Pack each source tensor to the bucket plan's static slab cap:
        slab-cap padding (appended zero slabs) replaces nnz padding, so
        the packed arrays both stack across bucket-mates AND stay
        bit-identical to the tensor's own sequential packing under the
        same plan.  ``structural=True`` (masked) ships the layout
        permutation + value scatter instead of baked values."""
        N = source[0].nmodes
        bplan = self.bucket_plan(tuple(source[0].shape), nnz_cap, density)
        per_mode: list[list[tuple]] = [[] for _ in range(N)]
        keys: list[tuple | None] = [None] * N
        for t in source:
            for d, lay in enumerate(build_all_mode_layouts(t, self.kappa)):
                mp = bplan.modes[d]
                p = kops.pack_layout(lay, block_rows=mp.block_rows,
                                     tile=mp.tile,
                                     num_slabs_cap=mp.slab_cap)
                # Every bucket-mate must pack to the same static
                # identity or vmap stacking is silently wrong.
                if keys[d] is None:
                    keys[d] = p.bucket_key
                elif p.bucket_key != keys[d]:
                    raise AssertionError(
                        f"plan produced mismatched packings for mode "
                        f"{d}: {p.bucket_key} vs {keys[d]}")
                if structural:
                    per_mode[d].append((p.rb_of, p.first, p.idx_packed,
                                        p.lrows_packed, lay.row_perm,
                                        lay.perm.astype(np.int32),
                                        p.val_scatter))
                else:
                    per_mode[d].append((p.rb_of, p.first, p.idx_packed,
                                        p.vals_packed, p.lrows_packed,
                                        lay.row_perm))
        width = 7 if structural else 6
        mode_data_all = tuple(
            tuple(jnp.asarray(np.stack([rec[j] for rec in per_mode[d]]))
                  for j in range(width))
            for d in range(N)
        )
        return mode_data_all, bplan.pallas_meta()

    def _stack_batch(self, tensors: list[SparseTensor],
                     padded: list[SparseTensor], nnz_cap: int,
                     method: str = "cp", density: tuple | None = None,
                     weights: Sequence | None = None):
        """Stacked per-mode device arrays + fit data for the vmapped sweep.

        Returns ``(mode_data_all, fit_data, pallas_meta)``; the meta tuple
        is ``None`` except for the pallas backend, where it carries the
        bucket plan's static tiling (part of the executable key).
        ``weights`` — optional per-request entry-weight vectors (canonical
        order, ``None`` entries meaning all-ones) for weighted-fit
        methods."""
        spec = None
        if method != "cp":
            from ..methods import get_method

            spec = get_method(method)
        structural = spec is not None and spec.valued_mode_data
        N = padded[0].nmodes
        idx = jnp.asarray(np.stack([t.indices for t in padded]))
        vals = jnp.asarray(np.stack(
            [t.values.astype(np.float32) for t in padded]))
        if spec is not None and spec.weighted_fit:
            # Observation weights: the request's own confidences (default
            # 1) on real entries, 0 on nnz padding — the masked analogue
            # of plain CP's exact zero-value padding, generalized to
            # user-supplied fractional weights.  The norm term weights
            # accordingly so the batched fit matches the sequential one.
            if weights is None:
                weights = [None] * len(tensors)
            ew_rows, norms_w = [], []
            for t, w in zip(tensors, weights):
                base = (np.ones(t.nnz, np.float32) if w is None
                        else als_device.normalize_entry_weights(
                            als_device.validate_entry_weights(t.nnz, w)))
                ew_rows.append(pad_weights(base, nnz_cap))
                v = t.values.astype(np.float32)
                norms_w.append(float((base * v) @ v))
            ew = jnp.asarray(np.stack(ew_rows))
            norms = jnp.asarray(np.array(norms_w, dtype=np.float32))
            fit_data = (idx, vals, ew, norms)
        else:
            norms = jnp.asarray(
                np.array([t.norm() ** 2 for t in padded], dtype=np.float32))
            fit_data = (idx, vals, norms)
        if self.backend == "coo":
            if structural:
                return tuple((idx,) for _ in range(N)), fit_data, None
            coo = (idx, vals)
            return tuple(coo for _ in range(N)), fit_data, None
        if self.backend == "pallas":
            # Masked packs the PADDED tensors (weight-0 entries are exact
            # no-ops and the residual scatter needs the padded canonical
            # order); plain/nncp pack the UNPADDED ones for bit-identity
            # with the sequential path.
            source = padded if structural else tensors
            mode_data_all, meta = self._stack_pallas(
                source, nnz_cap, density, structural)
            return mode_data_all, fit_data, meta
        # segment: build each tensor's mode-specific layouts on host, then
        # stack.  Padding to a common nnz is exactly what makes the layout
        # arrays stack — every bucket-mate yields (nnz_cap, ·) per mode.
        per_mode_s: list[list[tuple]] = [[] for _ in range(N)]
        for t in padded:
            for d, lay in enumerate(build_all_mode_layouts(t, self.kappa)):
                im = lay.input_modes()
                if structural:
                    per_mode_s[d].append((lay.indices[:, im], lay.rows,
                                          lay.row_perm,
                                          lay.perm.astype(np.int32)))
                else:
                    per_mode_s[d].append((lay.indices[:, im], lay.rows,
                                          lay.values.astype(np.float32),
                                          lay.row_perm))
        mode_data_all = tuple(
            tuple(jnp.asarray(np.stack([rec[j] for rec in per_mode_s[d]]))
                  for j in range(4))
            for d in range(N)
        )
        return mode_data_all, fit_data, None

    # -- driver -------------------------------------------------------------

    def prepare_batch(
        self,
        tensors: Sequence[SparseTensor],
        *,
        n_iters: int | Sequence[int] = 25,
        tol: float | Sequence[float] = 1e-5,
        seeds: Sequence[int] | None = None,
        nnz_cap: int | None = None,
        method: str = "cp",
        init_states: Sequence[tuple | None] | None = None,
        density: tuple | None = None,
        weights: Sequence | None = None,
    ) -> "_PreparedBatch | None":
        """HOST half of a batch decomposition: validation, pod padding,
        layout stacking, and init-state assembly — everything up to (but
        not including) the device dispatch.  Pure host work, so the
        scheduler's double-buffered flush path can run it for flush N+1
        while flush N computes on device.  Returns ``None`` for an empty
        batch; feed the result to ``execute_prepared``."""
        tensors = list(tensors)
        if not tensors:
            return None
        spec = None
        if method != "cp":
            from ..methods import get_method

            spec = get_method(method)
            if spec.stateful:
                raise ValueError(
                    f"method {method!r} is stateful; drive it through its "
                    f"session API (ALSRunner.open_stream)")
        if weights is not None and any(w is not None for w in weights) and (
                spec is None or not spec.weighted_fit):
            raise ValueError(
                f"per-entry weights require a weighted-fit method "
                f"(e.g. 'masked'), got method={method!r}")
        t_start = obs_clock.now()
        requested = len(tensors)
        shape = tuple(int(s) for s in tensors[0].shape)
        for t in tensors:
            if tuple(t.shape) != shape:
                raise ValueError(
                    f"batch mixes shapes {shape} and {tuple(t.shape)}; "
                    f"bucket before batching")
        N = len(shape)
        cap = int(nnz_cap) if nnz_cap is not None else max(t.nnz
                                                           for t in tensors)

        if seeds is None:
            seeds = [0] * requested
        if len(seeds) != requested:
            raise ValueError("seeds must match batch size")
        if init_states is not None and len(init_states) != requested:
            raise ValueError("init_states must match batch size")
        if weights is not None and len(weights) != requested:
            raise ValueError("weights must match batch size")
        n_iters_b = np.broadcast_to(
            np.asarray(n_iters, dtype=np.int32), (requested,)).copy()
        tol_b = np.broadcast_to(
            np.asarray(tol, dtype=np.float32), (requested,)).copy()

        if self.mesh is not None:
            # Pod sizing: round the batch up to a mesh multiple (through
            # the batch_quantum first — one shared PodPlan rule) and
            # repeat the last request into the padding lanes.  Exact:
            # lanes are independent under vmap/shard_map and the padded
            # lanes' results are discarded below.
            B, _ = self.pod_plan(shape, cap, density).dispatch_batch(
                requested)
            if B > requested:
                tensors = repeat_pad(tensors, B)
                seeds = repeat_pad(list(seeds), B)
                n_iters_b = np.asarray(repeat_pad(list(n_iters_b), B),
                                       dtype=np.int32)
                tol_b = np.asarray(repeat_pad(list(tol_b), B),
                                   dtype=np.float32)
                if init_states is not None:
                    init_states = repeat_pad(list(init_states), B)
                if weights is not None:
                    weights = repeat_pad(list(weights), B)
            # Load-aware lane placement: shard_map splits the stacked
            # batch axis into contiguous per-device blocks, so arrival
            # order decides which device carries the heavy requests.
            # Deal lanes heaviest-first to the least-loaded device;
            # results are un-permuted in _materialize (lanes are
            # independent, so per-request numerics are unchanged).
            lane_of = None
            if self.lane_placement == "balanced":
                order = plan_mod.pod_lane_order(
                    [int(t.nnz) for t in tensors], self.num_devices)
                if order != list(range(B)):
                    tensors = [tensors[i] for i in order]
                    seeds = [seeds[i] for i in order]
                    idx = np.asarray(order)
                    n_iters_b = np.asarray(n_iters_b)[idx]
                    tol_b = np.asarray(tol_b)[idx]
                    if init_states is not None:
                        init_states = [init_states[i] for i in order]
                    if weights is not None:
                        weights = [weights[i] for i in order]
                    lane_of = [0] * B
                    for lane, i in enumerate(order):
                        lane_of[i] = lane
        else:
            B = requested
            lane_of = None

        padded = [pad_tensor(t, cap) for t in tensors]
        mode_data_all, fit_data, pallas_meta = self._stack_batch(
            tensors, padded, cap, method, density, weights)
        # Host-side init, stacked once: one upload per state leaf instead
        # of 2N+1 tiny transfers (and N gram dispatches) per tensor.
        init_fn = (spec.init_state_host if spec is not None
                   and spec.init_state_host is not None
                   else als_device.init_state_host)
        inits = [
            (init_states[i] if init_states is not None
             and init_states[i] is not None
             else init_fn(shape, self.rank, int(seeds[i])))
            for i in range(B)
        ]
        state = (
            tuple(jnp.asarray(np.stack([st[0][d] for st in inits]))
                  for d in range(N)),
            tuple(jnp.asarray(np.stack([st[1][d] for st in inits]))
                  for d in range(N)),
            jnp.asarray(np.stack([st[2] for st in inits])),
        )
        carry = (
            state,
            jnp.ones((B,), dtype=bool),
            jnp.full((B,), -jnp.inf, dtype=jnp.float32),
            jnp.zeros((B,), dtype=jnp.int32),
        )
        return _PreparedBatch(
            requested=requested,
            batch=B,
            shape=shape,
            cap=cap,
            method=method,
            carry=carry,
            mode_data_all=mode_data_all,
            fit_data=fit_data,
            tol_dev=jnp.asarray(tol_b),
            max_iters_dev=jnp.asarray(n_iters_b),
            max_iters=int(n_iters_b.max()),
            pallas_meta=pallas_meta,
            lane_nnz=[int(t.nnz) for t in tensors],
            lane_of=lane_of,
            t_start=t_start,
        )

    def execute_prepared(self, prep: "_PreparedBatch | None"
                         ) -> list[CPDResult]:
        """DEVICE half: dispatch a prepared batch and materialize results.
        Single-device engines run the host-judged check-window loop; a
        mesh engine runs the pod block — the entire multi-window run is
        ONE dispatch with the convergence loop on device."""
        if prep is None:
            return []
        if self.mesh is not None:
            return self._execute_pod(prep)
        return self._execute_loop(prep)

    def decompose_batch(
        self,
        tensors: Sequence[SparseTensor],
        *,
        n_iters: int | Sequence[int] = 25,
        tol: float | Sequence[float] = 1e-5,
        seeds: Sequence[int] | None = None,
        nnz_cap: int | None = None,
        method: str = "cp",
        init_states: Sequence[tuple | None] | None = None,
        density: tuple | None = None,
        weights: Sequence | None = None,
    ) -> list[CPDResult]:
        """Decompose B same-shape tensors in vmapped lockstep.

        ``n_iters`` / ``tol`` / ``seeds`` may be scalars or per-tensor
        sequences (requests batched together keep their own budgets).
        ``method`` selects the decomposition method (all B requests share
        it — the scheduler keys buckets on method); ``init_states`` is an
        optional per-tensor list of host state tuples (see
        ``als_device.state_from_factors``) warm-starting individual
        requests — ``None`` entries fall back to the method's seeded init.
        ``weights`` is an optional per-tensor list of entry-weight vectors
        (canonical COO order; ``None`` entries mean all-ones) for
        weighted-fit methods — padding appends weight-0 slots, so a
        weighted batched request matches its sequential run.
        Returned ``CPDResult``s carry per-tensor factors/fits/iters;
        ``total_seconds`` and ``host_syncs`` are *batch-level* (shared by
        all B results — the whole point is that the batch paid them once).

        This is ``execute_prepared(prepare_batch(...))`` — the split
        exists so the scheduler can overlap host assembly with device
        compute (double buffering).
        """
        return self.execute_prepared(self.prepare_batch(
            tensors, n_iters=n_iters, tol=tol, seeds=seeds, nnz_cap=nnz_cap,
            method=method, init_states=init_states, density=density,
            weights=weights))

    def _execute_loop(self, prep: "_PreparedBatch") -> list[CPDResult]:
        """Single-device window loop: one dispatch + one active-mask host
        sync per check window (the pre-pod contract)."""
        carry = prep.carry
        B, N = prep.batch, len(prep.shape)
        fits_dev: list = []
        host_syncs = 0
        it = 0
        tr = obs_trace.active()
        while it < prep.max_iters:
            k = min(self.check_every, prep.max_iters - it)
            fn = _build_batched_block(
                self.backend, N, self.rank, prep.shape, prep.cap, B,
                self.interpret, self.donate, self.solver, k,
                prep.pallas_meta, prep.method,
            )
            # Per-window dispatch + active-mask sync: the disabled branch
            # pays one global read and zero allocations.
            if tr is None:
                carry, fits_blk = fn(carry, prep.mode_data_all,
                                     prep.fit_data, prep.tol_dev,
                                     prep.max_iters_dev)
                any_active = bool(np.any(jax.device_get(carry[1])))
            else:
                with tr.span("batched.window", cat="serve",
                             backend=self.backend, B=B, sweeps=k,
                             method=prep.method):
                    carry, fits_blk = fn(carry, prep.mode_data_all,
                                         prep.fit_data, prep.tol_dev,
                                         prep.max_iters_dev)
                    any_active = bool(np.any(jax.device_get(carry[1])))
            fits_dev.append(fits_blk)
            it += k
            host_syncs += 1          # the only in-loop sync: the active mask
            if not any_active:
                break

        host_syncs += 1              # final materialization
        fits_cat = (jnp.concatenate(fits_dev, axis=0) if fits_dev
                    else jnp.zeros((0, B), jnp.float32))   # n_iters <= 0
        return self._materialize(prep, carry, fits_cat, host_syncs,
                                 engine="batched")

    def _execute_pod(self, prep: "_PreparedBatch") -> list[CPDResult]:
        """Pod path: the whole multi-window run is ONE shard_map dispatch;
        convergence is judged on device (``lax.while_loop`` + mesh psum),
        so the only host sync is the final materialization."""
        B, N = prep.batch, len(prep.shape)
        n_dev = self.num_devices
        per_dev = B // n_dev
        max_windows = -(-prep.max_iters // self.check_every)
        if max_windows == 0:                       # n_iters <= 0
            return self._materialize(
                prep, prep.carry, jnp.zeros((0, B), jnp.float32), 1,
                engine="pod")
        fn = _build_pod_block(
            self.mesh, self.backend, N, self.rank, prep.shape, prep.cap,
            per_dev, self.interpret, self.donate, self.solver,
            self.check_every, max_windows, prep.pallas_meta, prep.method,
        )
        # Per-device request load for the dispatch span: lane i lands on
        # device i // per_dev (shard_map splits the leading axis into
        # contiguous blocks).  lane_nnz is already in lane (placed)
        # order; when placement ran, also record the arrival-order
        # counterfactual so the balance win is visible in the trace.
        dev_nnz = plan_mod.pod_device_nnz(prep.lane_nnz, n_dev)
        placement = {"lane_placement": "contiguous"}
        if prep.lane_of is not None:
            arrival = [prep.lane_nnz[prep.lane_of[i]] for i in range(B)]
            placement = {
                "lane_placement": "balanced",
                "device_nnz_contiguous":
                    plan_mod.pod_device_nnz(arrival, n_dev),
                "imbalance": plan_mod.pod_imbalance(prep.lane_nnz, n_dev),
                "imbalance_contiguous":
                    plan_mod.pod_imbalance(arrival, n_dev),
            }
        tr = obs_trace.active()
        if tr is None:
            carry, fits_buf, windows = fn(
                prep.carry, prep.mode_data_all, prep.fit_data,
                prep.tol_dev, prep.max_iters_dev)
            res = self._materialize(prep, carry, fits_buf, 1, engine="pod")
        else:
            with tr.span("pod.dispatch", cat="serve",
                         backend=self.backend, B=B, devices=n_dev,
                         B_per_device=per_dev, max_windows=max_windows,
                         sweeps_per_window=self.check_every,
                         nnz_cap=prep.cap, device_nnz=dev_nnz,
                         method=prep.method, **placement):
                carry, fits_buf, windows = fn(
                    prep.carry, prep.mode_data_all, prep.fit_data,
                    prep.tol_dev, prep.max_iters_dev)
                res = self._materialize(prep, carry, fits_buf, 1,
                                        engine="pod")
            # Window count is only known after the fetch (the loop ran
            # entirely on device) — record it as one aggregate event, not
            # per-window spans: there were no per-window host syncs to
            # hang spans off, which is the point.
            obs_trace.event("pod.window", cat="serve",
                            windows=int(windows), devices=n_dev,
                            B_per_device=per_dev,
                            sweeps_per_window=self.check_every)
        return res

    def _materialize(self, prep: "_PreparedBatch", carry, fits_cat,
                     host_syncs: int, engine: str) -> list[CPDResult]:
        """One batched device_get for everything; pod padding lanes (the
        repeated trailing requests) are dropped here."""
        N = len(prep.shape)
        state, _, _, done = carry
        factors_h, weights_h, done_h, fits_h = jax.device_get(
            (state[0], state[2], done, fits_cat))
        wall = obs_clock.now() - prep.t_start

        results = []
        for i in range(prep.requested):
            li = prep.lane_of[i] if prep.lane_of is not None else i
            ni = int(done_h[li])
            results.append(CPDResult(
                factors=[np.asarray(factors_h[d][li]) for d in range(N)],
                weights=np.asarray(weights_h[li], dtype=np.float64),
                fits=[float(f) for f in fits_h[:ni, li]],
                iters=ni,
                mttkrp_seconds=0.0,
                total_seconds=wall,
                host_syncs=host_syncs,
                engine=engine,
                method=prep.method,
            ))
        return results


@dataclasses.dataclass
class _PreparedBatch:
    """Host-assembled batch, ready to dispatch (see ``prepare_batch``).
    ``batch`` >= ``requested`` on the pod path (mesh-multiple padding);
    only the first ``requested`` lanes materialize into results."""

    requested: int
    batch: int
    shape: tuple[int, ...]
    cap: int
    method: str
    carry: tuple
    mode_data_all: tuple
    fit_data: tuple
    tol_dev: jnp.ndarray
    max_iters_dev: jnp.ndarray
    max_iters: int
    pallas_meta: tuple | None
    lane_nnz: list[int]
    # order[lane] inverse from load-aware placement: request i lives in
    # lane lane_of[i].  None when lanes are in arrival order.
    lane_of: list[int] | None
    t_start: float
