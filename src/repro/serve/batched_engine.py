"""Vmapped batched ALS engine: B same-bucket decompositions, one dispatch.

The small-tensor regime is overhead-dominated — a single sweep cannot
saturate the device — so the serving path stacks B bucket-mates (same
shape, nnz padded to the bucket cap, see ``serve.buckets``) and runs
``jax.vmap`` of the *same* closure-free sweep the sequential engine jits
(``core.als_device.build_sweep_fn``).  One dispatch then advances B
decompositions by a whole ``check_every`` window (``lax.scan``, exactly
mirroring the sequential engine's window structure):

  * per-tensor convergence masking: every tensor keeps sweeping until the
    whole batch is done, but a converged (or iteration-capped) tensor's
    state is frozen under ``jnp.where`` — its factors, fit, and iteration
    counter stop changing, so batching never alters an individual
    result.  Convergence is judged on device at window boundaries
    against the previous boundary's fit — the sequential engine's exact
    stopping rule, vectorized — so a request converges at the same
    iteration whichever front door served it (for a uniform-``n_iters``
    batch; mixed budgets can shift a straggler's window grid).
  * the batch state pytree is donated (off-CPU), so XLA reuses the B-way
    buffers in place across windows.
  * executables are cached per (bucket shape, nnz cap, B, rank, backend,
    solver, window): a warm bucket class pays zero retrace per batch.
    ``batched_cache_stats()`` exposes the counters.

Backends: ``segment`` (default; per-tensor mode layouts are stacked —
same padded nnz ⇒ identical array shapes regardless of which
load-balancing scheme each tensor picked) and ``coo`` (no host-side
layout preprocessing at all).  ``pallas`` is not batchable yet: its
packed slab shapes are data-dependent, so bucket-mates do not stack —
see the ROADMAP follow-up.
"""
from __future__ import annotations

import functools
import time
from typing import Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from ..core import als_device
from ..core.coo import SparseTensor
from ..core.cpd import CPDResult
from ..core.layout import build_all_mode_layouts
from .buckets import pad_tensor

_BATCH_BACKENDS = ("segment", "coo")


@functools.lru_cache(maxsize=None)
def _build_batched_block(backend: str, nmodes: int, rank: int,
                         shapes: tuple[int, ...], nnz_cap: int, batch: int,
                         interpret: bool, donate: bool, solver: str,
                         block: int):
    """Jitted ``lax.scan`` of ``block`` vmapped sweeps with per-tensor
    convergence masking.  ``nnz_cap`` and ``batch`` are part of the key so
    the cache honestly counts one executable per (bucket, B) class.

    carry = (state, active (B,) bool, last_fit (B,), done (B,) int32);
    returns (carry, fits (block, B))."""
    sweep = als_device.build_sweep_fn(backend, nmodes, rank, shapes,
                                      None, interpret, solver)
    vsweep = jax.vmap(sweep, in_axes=(0, 0, 0))

    def run_block(carry, mode_data_all, fit_data, tol_b, max_iters_b):
        fit_ref = carry[2]       # fit at the previous window boundary

        def body(c, _):
            state, active, last_fit, done = c
            new_state, fit = vsweep(state, mode_data_all, fit_data)

            def freeze(new, old):
                mask = active.reshape(
                    (active.shape[0],) + (1,) * (new.ndim - 1))
                return jnp.where(mask, new, old)

            state = jax.tree_util.tree_map(freeze, new_state, state)
            fit = jnp.where(active, fit, last_fit)
            done = done + active.astype(jnp.int32)
            active = active & (done < max_iters_b)
            return (state, active, fit, done), fit

        (state, active, fit, done), fits = lax.scan(body, carry, xs=None,
                                                    length=block)
        # Convergence is judged at the WINDOW boundary against the previous
        # boundary's fit — the same rule (and therefore the same stopping
        # iteration) as the sequential fused engine, just vectorized.
        active = active & ~(jnp.abs(fit - fit_ref) < tol_b)
        return (state, active, fit, done), fits

    return jax.jit(run_block, donate_argnums=(0,) if donate else ())


def batched_cache_stats():
    """(hits, misses, currsize) of the batched executable cache, keyed per
    (bucket, B, rank, backend, window)."""
    info = _build_batched_block.cache_info()
    return {"hits": info.hits, "misses": info.misses,
            "currsize": info.currsize}


class BatchedEngine:
    """Stacks same-bucket tensors and drives the vmapped fused sweep."""

    def __init__(self, rank: int, *, kappa: int = 1,
                 backend: str = "segment", check_every: int = 4,
                 interpret: bool = True, donate: bool | None = None,
                 solver: str = "auto"):
        if backend not in _BATCH_BACKENDS:
            raise ValueError(
                f"batched engine supports {_BATCH_BACKENDS}, got "
                f"{backend!r} (pallas slab shapes are data-dependent and "
                f"do not stack)")
        self.rank = rank
        self.kappa = kappa
        self.backend = backend
        self.check_every = max(1, int(check_every))
        self.interpret = bool(interpret)
        if donate is None:
            donate = jax.default_backend() != "cpu"
        self.donate = bool(donate)
        if solver == "auto":
            solver = "cho" if jax.default_backend() != "cpu" else "inv"
        if solver not in ("cho", "inv"):
            raise ValueError(f"unknown solver {solver!r}")
        self.solver = solver

    # -- data staging -------------------------------------------------------

    def _stack_batch(self, padded: list[SparseTensor]):
        """Stacked per-mode device arrays + fit data for the vmapped sweep."""
        N = padded[0].nmodes
        idx = jnp.asarray(np.stack([t.indices for t in padded]))
        vals = jnp.asarray(np.stack(
            [t.values.astype(np.float32) for t in padded]))
        norms = jnp.asarray(
            np.array([t.norm() ** 2 for t in padded], dtype=np.float32))
        fit_data = (idx, vals, norms)
        if self.backend == "coo":
            coo = (idx, vals)
            return tuple(coo for _ in range(N)), fit_data
        # segment: build each tensor's mode-specific layouts on host, then
        # stack.  Padding to a common nnz is exactly what makes the layout
        # arrays stack — every bucket-mate yields (nnz_cap, ·) per mode.
        per_mode: list[list[tuple]] = [[] for _ in range(N)]
        for t in padded:
            for d, lay in enumerate(build_all_mode_layouts(t, self.kappa)):
                im = lay.input_modes()
                per_mode[d].append((lay.indices[:, im], lay.rows,
                                    lay.values.astype(np.float32),
                                    lay.row_perm))
        mode_data_all = tuple(
            tuple(jnp.asarray(np.stack([rec[j] for rec in per_mode[d]]))
                  for j in range(4))
            for d in range(N)
        )
        return mode_data_all, fit_data

    # -- driver -------------------------------------------------------------

    def decompose_batch(
        self,
        tensors: Sequence[SparseTensor],
        *,
        n_iters: int | Sequence[int] = 25,
        tol: float | Sequence[float] = 1e-5,
        seeds: Sequence[int] | None = None,
        nnz_cap: int | None = None,
    ) -> list[CPDResult]:
        """Decompose B same-shape tensors in vmapped lockstep.

        ``n_iters`` / ``tol`` / ``seeds`` may be scalars or per-tensor
        sequences (requests batched together keep their own budgets).
        Returned ``CPDResult``s carry per-tensor factors/fits/iters;
        ``total_seconds`` and ``host_syncs`` are *batch-level* (shared by
        all B results — the whole point is that the batch paid them once).
        """
        tensors = list(tensors)
        if not tensors:
            return []
        t_start = time.perf_counter()
        B = len(tensors)
        shape = tuple(int(s) for s in tensors[0].shape)
        for t in tensors:
            if tuple(t.shape) != shape:
                raise ValueError(
                    f"batch mixes shapes {shape} and {tuple(t.shape)}; "
                    f"bucket before batching")
        N = len(shape)
        cap = int(nnz_cap) if nnz_cap is not None else max(t.nnz
                                                           for t in tensors)
        padded = [pad_tensor(t, cap) for t in tensors]

        n_iters_b = np.broadcast_to(
            np.asarray(n_iters, dtype=np.int32), (B,)).copy()
        tol_b = np.broadcast_to(
            np.asarray(tol, dtype=np.float32), (B,)).copy()
        if seeds is None:
            seeds = [0] * B
        if len(seeds) != B:
            raise ValueError("seeds must match batch size")

        mode_data_all, fit_data = self._stack_batch(padded)
        # Host-side init, stacked once: one upload per state leaf instead
        # of 2N+1 tiny transfers (and N gram dispatches) per tensor.
        inits = [als_device.init_state_host(shape, self.rank, int(s))
                 for s in seeds]
        state = (
            tuple(jnp.asarray(np.stack([st[0][d] for st in inits]))
                  for d in range(N)),
            tuple(jnp.asarray(np.stack([st[1][d] for st in inits]))
                  for d in range(N)),
            jnp.asarray(np.stack([st[2] for st in inits])),
        )
        carry = (
            state,
            jnp.ones((B,), dtype=bool),
            jnp.full((B,), -jnp.inf, dtype=jnp.float32),
            jnp.zeros((B,), dtype=jnp.int32),
        )
        tol_dev = jnp.asarray(tol_b)
        max_iters_dev = jnp.asarray(n_iters_b)

        max_iters = int(n_iters_b.max())
        fits_dev: list = []
        host_syncs = 0
        it = 0
        while it < max_iters:
            k = min(self.check_every, max_iters - it)
            fn = _build_batched_block(
                self.backend, N, self.rank, shape, cap, B,
                self.interpret, self.donate, self.solver, k,
            )
            carry, fits_blk = fn(carry, mode_data_all, fit_data,
                                 tol_dev, max_iters_dev)
            fits_dev.append(fits_blk)
            it += k
            host_syncs += 1          # the only in-loop sync: the active mask
            if not bool(np.any(jax.device_get(carry[1]))):
                break

        host_syncs += 1              # final materialization
        state, _, _, done = carry
        fits_cat = (jnp.concatenate(fits_dev, axis=0) if fits_dev
                    else jnp.zeros((0, B), jnp.float32))   # n_iters <= 0
        # One batched device_get for everything.
        factors_h, weights_h, done_h, fits_h = jax.device_get(
            (state[0], state[2], done, fits_cat))
        wall = time.perf_counter() - t_start

        results = []
        for i in range(B):
            ni = int(done_h[i])
            results.append(CPDResult(
                factors=[np.asarray(factors_h[d][i]) for d in range(N)],
                weights=np.asarray(weights_h[i], dtype=np.float64),
                fits=[float(f) for f in fits_h[:ni, i]],
                iters=ni,
                mttkrp_seconds=0.0,
                total_seconds=wall,
                host_syncs=host_syncs,
                engine="batched",
            ))
        return results
