"""Micro-batching request scheduler: per-bucket queues, submit/future
semantics, max-batch / max-wait flush triggers.

The service sits between callers (one ``SparseTensor`` per request) and
the vmapped ``BatchedEngine`` (B bucket-mates per dispatch):

  * ``submit()`` quantizes the request into its (shape, nnz-cap) bucket
    (``serve.buckets``), enqueues it, and returns a
    ``DecompositionFuture`` immediately.
  * a bucket flushes when its aging+occupancy score crosses 1.0:
    ``score = oldest_wait / max_wait_s + queued / max_batch``.  A full
    bucket flushes immediately (occupancy term alone reaches 1 — the
    throughput trigger), an expired one likewise (aging term alone — the
    latency trigger), and a partially-full bucket that has waited most of
    its budget flushes early rather than idling the device.  Every
    ``submit``/``poll`` re-scores ALL buckets and flushes the
    highest-scoring ready ones first, so the device is handed to the
    neediest bucket instead of whichever FIFO happened to expire — and
    because the aging term grows without bound, no bucket can be starved
    by heavier neighbors (tested).  ``flush()`` / ``Future.result()``
    still force a flush outright.
  * flushing pads every queued tensor to the bucket cap, runs one
    batched decomposition, resolves the futures, and records the batch
    in ``ServiceMetrics``.

The scheduler is deliberately event-driven rather than thread-driven:
flushes happen inside ``submit``/``poll``/``result`` calls, which makes
the trigger logic deterministic and unit-testable (inject ``clock``).
Queue state is guarded by an RLock, but batches are *popped* under the
lock and *executed* after releasing it, so a multi-second compile in one
bucket never blocks concurrent submitters (a popped batch can no longer
be double-flushed; each request belongs to exactly one batch).

Double-buffered dispatch (``double_buffer=True``): the flush path splits
at the engine's prepare/execute seam — host-side batch assembly
(``engine.prepare_batch``: padding, layout stacking, init states) runs on
the flushing caller's thread while the DEVICE half of the PREVIOUS flush
is still executing on a one-worker dispatch executor.  Flush N+1's
assembly therefore overlaps flush N's compute; the single worker keeps
device executions serialized (one accelerator, in-order futures).
``ServiceMetrics.record_dispatch`` accumulates the measured overlap and
per-device dispatch counters; ``join()`` (or ``Future.result()``) waits
out in-flight dispatches.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Callable

import numpy as np

from ..core import plan as plan_mod
from ..core.coo import SparseTensor
from ..core.cpd import CPDResult
from ..obs import clock as obs_clock
from ..obs import trace as obs_trace
from .batched_engine import BatchedEngine, batched_cache_stats
from .buckets import Bucket, BucketPolicy
from .metrics import BatchEvent, ServiceMetrics

# Modes with more rows than this keep the uniform planning prior instead
# of paying per-flush bincount+sort profiling on the caller's thread.
_DENSITY_MAX_ROWS = 65536


class DecompositionFuture:
    """Handle for a submitted request.  ``result()`` force-flushes the
    owning bucket if the request is still queued, so a caller that wants
    its answer *now* never deadlocks waiting for bucket-mates."""

    def __init__(self, scheduler: "BatchScheduler", bucket: Bucket):
        self._scheduler = scheduler
        self._bucket = bucket
        self._done = threading.Event()
        self._result: CPDResult | None = None
        self._exception: BaseException | None = None

    def done(self) -> bool:
        return self._done.is_set()

    def _resolve(self, result: CPDResult | None,
                 exc: BaseException | None = None):
        self._result = result
        self._exception = exc
        self._done.set()

    def result(self, timeout: float | None = None) -> CPDResult:
        """Without ``timeout``: force-flush the owning bucket if the
        request is still queued, run to completion, return.  With
        ``timeout``: wait that long for completion by another caller's
        flush (the bounded wait cannot itself start a flush, whose
        compile/execute time it could not honor) and raise
        ``TimeoutError`` on expiry."""
        if timeout is not None:
            if not self._done.wait(timeout):
                raise TimeoutError("decomposition not completed")
        elif not self._done.is_set():
            self._scheduler.flush(self._bucket)
            self._done.wait()      # another thread may own the batch
        if self._exception is not None:
            raise self._exception
        assert self._result is not None
        return self._result


@dataclasses.dataclass
class _Pending:
    tensor: SparseTensor
    future: DecompositionFuture
    n_iters: int
    tol: float
    seed: int
    t_submit: float
    init_state: tuple | None = None
    weights: np.ndarray | None = None


class BatchScheduler:
    """Shape-bucketed micro-batching front of the decomposition service."""

    def __init__(self, engine: BatchedEngine, *,
                 policy: BucketPolicy | None = None,
                 max_batch: int = 8,
                 max_wait_s: float = 0.005,
                 batch_quantum: int = 1,
                 metrics: ServiceMetrics | None = None,
                 double_buffer: bool = False,
                 clock: Callable[[], float] = obs_clock.now):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if batch_quantum < 1 or batch_quantum > max_batch:
            raise ValueError(
                f"batch_quantum must be in [1, max_batch], "
                f"got {batch_quantum}")
        self.engine = engine
        self.policy = policy or BucketPolicy()
        self.max_batch = int(max_batch)
        self.max_wait_s = float(max_wait_s)
        self.batch_quantum = int(batch_quantum)
        self.metrics = metrics or ServiceMetrics()
        self.clock = clock
        self._queues: dict[Bucket, list[_Pending]] = {}
        self._lock = threading.RLock()
        # Double-buffered dispatch: ONE worker so device executions stay
        # serialized (and in submission order) while the caller thread
        # assembles the next flush.  The exec-interval deque feeds the
        # overlap gauge: an assembly interval that intersects another
        # flush's device interval is time the host genuinely hid.
        self.double_buffer = bool(double_buffer)
        self._dispatch_pool = (ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="serve-dispatch")
            if double_buffer else None)
        self._inflight: set = set()
        self._exec_lock = threading.Lock()
        self._exec_intervals: collections.deque = collections.deque(
            maxlen=16)

    # -- request side -------------------------------------------------------

    def submit(self, tensor: SparseTensor, *, n_iters: int = 25,
               tol: float = 1e-5, seed: int = 0, method: str = "cp",
               init_state: tuple | None = None,
               weights: np.ndarray | None = None) -> DecompositionFuture:
        """Enqueue one request.  ``method`` routes to the decomposition
        method's (shape, nnz-bucket, method) class — a mixed-method
        stream batches per method but shares plans and kernels.
        ``init_state`` warm-starts this request (streaming sessions);
        ``weights`` carries per-entry observation confidences for
        weighted-fit methods ('masked') — bucket-mates keep their own
        weight vectors, and the flush pads each with weight-0 entries so
        batching stays exact.

        Weights are validated HERE, eagerly: a flush-time failure would
        belong to the whole batch and fail innocent bucket-mates'
        futures, so a malformed vector (wrong length, NaN, negative, or
        weights on a non-weighted method) must raise at the offending
        caller's submit instead."""
        if weights is not None:
            from ..core.als_device import validate_entry_weights
            from ..methods import get_method

            if not get_method(method).weighted_fit:
                raise ValueError(
                    f"per-entry weights require a weighted-fit method "
                    f"(e.g. 'masked'), got method={method!r}")
            weights = validate_entry_weights(tensor.nnz, weights)
        bucket = self.policy.bucket_for(tensor, method)
        now = self.clock()
        with self._lock:
            fut = DecompositionFuture(self, bucket)
            self._queues.setdefault(bucket, []).append(
                _Pending(tensor, fut, int(n_iters), float(tol), int(seed),
                         now, init_state, weights))
            self.metrics.record_submit(now)
            work = self._pop_ready()
            self._record_queue_locked()
        self._run_batches(work)
        return fut

    def poll(self) -> int:
        """Flush every bucket whose aging+occupancy score has crossed the
        threshold, neediest first.  Returns the number of batches
        flushed.  Call this from the serving loop between request
        arrivals."""
        with self._lock:
            work = self._pop_ready()
            self._record_queue_locked()
        self._run_batches(work)
        return len(work)

    def flush(self, bucket: Bucket | None = None) -> int:
        """Force-flush one bucket (or all).  Returns batches flushed."""
        with self._lock:
            buckets = ([bucket] if bucket is not None
                       else list(self._queues.keys()))
            work = []
            for b in buckets:
                while self._queues.get(b):
                    work.append(self._pop(b, "forced"))
            self._record_queue_locked()
        self._run_batches(work)
        return len(work)

    def pending(self, bucket: Bucket | None = None) -> int:
        with self._lock:
            if bucket is not None:
                return len(self._queues.get(bucket, []))
            return sum(len(q) for q in self._queues.values())

    def join(self) -> None:
        """Wait for every in-flight double-buffered dispatch to complete
        (no-op without ``double_buffer``).  Futures resolve as dispatches
        finish; call this before reading end-of-stream metrics."""
        while True:
            with self._lock:
                pending = list(self._inflight)
            if not pending:
                return
            for f in pending:
                f.result()

    # -- flush machinery ----------------------------------------------------
    # Pop under the lock, execute outside it: a popped batch belongs to
    # exactly one caller, so the engine (potentially a multi-second
    # compile) never runs inside the critical section.

    def _record_queue_locked(self) -> None:
        """Refresh the metrics queue-saturation gauges (pending depth +
        oldest queued age).  Caller holds ``self._lock``; the metrics
        object takes its own lock, which is safe — metrics never calls
        back into the scheduler."""
        depth = sum(len(q) for q in self._queues.values())
        oldest = min((q[0].t_submit for q in self._queues.values() if q),
                     default=None)
        age = 0.0 if oldest is None else max(self.clock() - oldest, 0.0)
        self.metrics.record_queue(depth, age)

    def _pop(self, bucket: Bucket, trigger: str):
        q = self._queues.get(bucket, [])
        batch, self._queues[bucket] = q[: self.max_batch], q[self.max_batch:]
        return bucket, batch, trigger

    def _score(self, q: list, now: float) -> float:
        """Aging + occupancy flush score; >= 1.0 means ready.  The aging
        term grows without bound, so every nonempty bucket eventually
        flushes regardless of how busy its neighbors are (starvation
        freedom); the occupancy term lets a filling bucket claim the
        device before its latency budget expires."""
        age = (now - q[0].t_submit) / self.max_wait_s if self.max_wait_s \
            else float("inf")
        return age + len(q) / self.max_batch

    def _pop_ready(self) -> list:
        """Pop every ready bucket (score >= 1), highest score first —
        the cross-bucket replacement for independent per-bucket FIFO
        expiry: when the device frees up, the neediest class wins."""
        now = self.clock()
        scored = []
        for b in list(self._queues.keys()):
            q = self._queues.get(b)
            if not q:
                continue
            s = self._score(q, now)
            if s >= 1.0:
                scored.append((s, b, len(q), now - q[0].t_submit))
        scored.sort(key=lambda e: -e[0])
        work = []
        for _, b, n, age in scored:
            trigger = ("max_batch" if n >= self.max_batch
                       else "max_wait" if age >= self.max_wait_s
                       else "aging")
            work.append(self._pop(b, trigger))
        return work

    def _run_batches(self, work: list) -> None:
        for bucket, batch, trigger in work:
            if batch:
                self._run_one(bucket, batch, trigger)

    def _run_one(self, bucket: Bucket, batch: list, trigger: str) -> None:
        # Cache counters are global; under concurrent flushes another
        # thread's compile can land inside this window, so per-batch
        # attribution is best-effort (totals stay exact).
        stats0 = batched_cache_stats()
        # Density feedback: the PREVIOUS flushes' observed row-density
        # EWMA prices this batch's bucket plan; this batch's own profile
        # is folded in afterwards for the next one (so the first flush of
        # a bucket runs under the uniform prior — by construction there
        # is nothing observed yet).
        density = self.metrics.row_density(bucket.key)
        # Batch-size quantization: B is part of the vmapped executable's
        # cache key, so a stream whose flushes land on varying batch
        # sizes retraces per size.  Rounding the dispatched B up to the
        # next multiple of ``batch_quantum`` (capped at max_batch) by
        # repeating the last request stabilizes that key component; the
        # duplicate slots are exact under vmap (independent lanes) and
        # their results are simply discarded below.
        q = self.batch_quantum
        target = min(self.max_batch, -(-len(batch) // q) * q)
        exec_batch = batch + [batch[-1]] * (target - len(batch))
        t0 = obs_clock.now()
        # The flush span carries the executable-cache hit/miss deltas as
        # attrs, so a trace ALONE reconstructs the stream's cache hit
        # rate (cross-checked against ServiceMetrics in tests/obs).
        with obs_trace.span("serve.flush", cat="serve",
                            bucket=str(bucket.key), batch=len(batch),
                            dispatched=len(exec_batch),
                            trigger=trigger,
                            double_buffer=self.double_buffer) as sp:
            # HOST half: padding, layout stacking, init assembly.  Under
            # double buffering this runs while the previous flush's
            # device half is still executing on the dispatch worker —
            # that intersection is the overlap gauge.
            try:
                prep = self.engine.prepare_batch(
                    [p.tensor for p in exec_batch],
                    n_iters=[p.n_iters for p in exec_batch],
                    tol=[p.tol for p in exec_batch],
                    seeds=[p.seed for p in exec_batch],
                    nnz_cap=bucket.nnz_cap,
                    method=bucket.method,
                    init_states=[p.init_state for p in exec_batch],
                    density=density,
                    weights=[p.weights for p in exec_batch],
                )
            except BaseException as exc:
                # Executor semantics: the failure belongs to the batch's
                # own futures (raised from their result()), never to
                # whichever caller's submit/poll happened to trigger the
                # flush — a submitter must still receive its future for
                # an unrelated bucket's engine error.
                sp.set(error=type(exc).__name__)
                for p in batch:
                    p.future._resolve(None, exc)
                return
            t_prep = obs_clock.now()
            assembly_s = t_prep - t0
            overlap_s = self._overlap_with_exec(t0, t_prep)
            if self._dispatch_pool is None:
                # Synchronous path (the default): device half inline,
                # span covers the whole flush — pre-pod behavior.
                self._execute_one(bucket, batch, exec_batch, trigger,
                                  prep, stats0, t0, assembly_s,
                                  overlap_s, sp)
            else:
                fut = self._dispatch_pool.submit(
                    self._execute_one, bucket, batch, exec_batch, trigger,
                    prep, stats0, t0, assembly_s, overlap_s, None)
                with self._lock:
                    self._inflight.add(fut)
                fut.add_done_callback(self._inflight_discard)
                sp.set(assembly_s=assembly_s, overlap_s=overlap_s,
                       dispatched_async=True)

    def _inflight_discard(self, fut) -> None:
        with self._lock:
            self._inflight.discard(fut)

    def _overlap_with_exec(self, a0: float, a1: float) -> float:
        """Seconds of the assembly interval [a0, a1] spent while some
        other flush's device dispatch was executing — the double-buffer
        overlap witness.  A still-running dispatch counts up to a1."""
        with self._exec_lock:
            intervals = [(e[0], e[1]) for e in self._exec_intervals]
        total = 0.0
        for e0, e1 in intervals:
            hi = a1 if e1 is None else min(a1, e1)
            total += max(0.0, hi - max(a0, e0))
        return total

    def _execute_one(self, bucket: Bucket, batch: list, exec_batch: list,
                     trigger: str, prep, stats0: dict, t0: float,
                     assembly_s: float, overlap_s: float, sp) -> None:
        """DEVICE half of one flush (+ future resolution and metrics).
        Runs inline on the flushing thread (sync path, ``sp`` = the open
        flush span) or on the one-worker dispatch executor (double
        buffering, ``sp`` = None and a ``serve.dispatch`` span is opened
        here)."""
        interval = [obs_clock.now(), None]
        with self._exec_lock:
            self._exec_intervals.append(interval)
        try:
            try:
                if sp is None:
                    with obs_trace.span("serve.dispatch", cat="serve",
                                        bucket=str(bucket.key),
                                        dispatched=len(exec_batch),
                                        devices=self.engine.num_devices,
                                        trigger=trigger):
                        results = self.engine.execute_prepared(prep)
                else:
                    results = self.engine.execute_prepared(prep)
            except BaseException as exc:
                if sp is not None:
                    sp.set(error=type(exc).__name__)
                for p in batch:
                    p.future._resolve(None, exc)
                return
        finally:
            interval[1] = obs_clock.now()
        execute_s = interval[1] - interval[0]
        wall = obs_clock.now() - t0
        stats1 = batched_cache_stats()
        if sp is not None:
            sp.set(wall_s=wall,
                   cache_hits=stats1["hits"] - stats0["hits"],
                   cache_misses=stats1["misses"] - stats0["misses"])
        now = self.clock()
        for p, res in zip(batch, results):
            p.future._resolve(res)
        # Per-mode observed row-density of this batch (unpadded tensors),
        # averaged across the batch, folded into the bucket's EWMA.  Modes
        # too large to profile cheaply (bincount+sort is O(I_d log I_d)
        # host work on the flushing caller's thread) are skipped — a None
        # profile keeps the uniform prior for that mode only.
        shape = bucket.shape
        profiles = tuple(
            (None if shape[d] > _DENSITY_MAX_ROWS else
             tuple(float(np.mean(col)) for col in zip(*[
                 plan_mod.density_profile(p.tensor.indices, shape, d)
                 for p in batch])))
            for d in range(len(shape))
        )
        mesh = self.engine.mesh
        device_ids = ([int(d.id) for d in mesh.devices.flat]
                      if mesh is not None else [0])
        with self._lock:
            self.metrics.record_density(bucket.key, profiles)
            self.metrics.record_batch(
                BatchEvent(
                    bucket_key=bucket.key,
                    batch_size=len(batch),
                    max_batch=self.max_batch,
                    real_nnz=sum(p.tensor.nnz for p in batch),
                    padded_nnz=bucket.nnz_cap * len(exec_batch),
                    wall_s=wall,
                    trigger=trigger,
                    cache_hits=stats1["hits"] - stats0["hits"],
                    cache_misses=stats1["misses"] - stats0["misses"],
                ),
                latencies_s=[now - p.t_submit for p in batch],
                now=now,
            )
            self.metrics.record_dispatch(
                devices=device_ids, assembly_s=assembly_s,
                execute_s=execute_s, overlap_s=overlap_s)


class DecompositionService:
    """Convenience facade: engine + scheduler + metrics in one object.

    >>> svc = DecompositionService(rank=16, max_batch=8)
    >>> futs = [svc.submit(t) for t in tensors]
    >>> svc.drain()
    >>> results = [f.result() for f in futs]
    """

    def __init__(self, rank: int, *, kappa: int = 1,
                 backend: str = "segment", check_every: int = 4,
                 policy: BucketPolicy | None = None, max_batch: int = 8,
                 max_wait_s: float = 0.005, batch_quantum: int = 1,
                 mesh=None, double_buffer: bool = False, slo=None,
                 clock: Callable[[], float] = obs_clock.now):
        self.engine = BatchedEngine(rank, kappa=kappa, backend=backend,
                                    check_every=check_every, mesh=mesh,
                                    batch_quantum=batch_quantum)
        # slo: an obs.health.SLOPolicy; snapshot() then carries a live
        # "health" section and breach onsets emit health.breach events.
        self.metrics = ServiceMetrics(slo=slo)
        self.scheduler = BatchScheduler(
            self.engine, policy=policy, max_batch=max_batch,
            max_wait_s=max_wait_s, batch_quantum=batch_quantum,
            double_buffer=double_buffer, metrics=self.metrics, clock=clock)

    def submit(self, tensor: SparseTensor, **kw) -> DecompositionFuture:
        return self.scheduler.submit(tensor, **kw)

    def poll(self) -> int:
        return self.scheduler.poll()

    def drain(self) -> int:
        """Flush everything still queued, then wait for any in-flight
        double-buffered dispatches to land (futures resolved)."""
        n = self.scheduler.flush()
        self.scheduler.join()
        return n

    def snapshot(self) -> dict:
        return self.metrics.snapshot()
