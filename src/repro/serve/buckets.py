"""Shape-bucketing policy for the decomposition service.

A vmapped batch can only stack tensors whose arrays have identical
shapes, and a cached executable only pays off when many requests map to
it.  The bucketing policy therefore quantizes every incoming
``SparseTensor`` into a ``(shape, nnz-bucket)`` class:

  * the dense shape is an exact key — factor matrices are (I_d, R), so
    tensors of different shapes can never share a sweep executable;
  * nnz is rounded UP to a bucket boundary and the tensor is padded with
    zero-valued entries at coordinate (0, …, 0) until it fills the
    bucket.  Everything in one bucket then shares a single compiled
    (and vmappable) sweep.

This is the request-stream analogue of the kernel-level padding the
load-balancing literature pays for uniform parallel work (Nisa et al.,
arXiv 1904.03329): a bounded padding overhead buys shape-uniform
batches.

Padding invariance
------------------
Appending a zero-valued nonzero at row 0 is an exact no-op for every
engine in this repo, not merely an approximate one:

  * MTTKRP: the padded entry contributes ``0.0 * prod(factor rows)`` =
    +0.0 to output row 0.  ``x + 0.0`` is bit-identical to ``x`` for
    every finite float except ``-0.0`` (values generated here are never
    exactly zero), and all layout sorts are stable, so real entries keep
    their relative accumulation order.
  * the sparse fit: padded values are 0, so the inner product and
    ``||X||`` are untouched.
  * the masked method additionally needs padding entries to carry
    observation weight 0 (a zero VALUE would claim the tensor is
    observed-zero at the origin); the batched engine builds those
    weights from the real-vs-padded split, restoring the same exactness.

``tests/serve/test_buckets.py`` asserts the resulting factors are
bit-identical, padded vs unpadded.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core import plan as plan_mod
from ..core.coo import SparseTensor


@dataclasses.dataclass(frozen=True, order=True)
class Bucket:
    """One (shape, nnz-cap, method) equivalence class of the request
    stream.  Method is part of the key because bucket-mates must share a
    sweep EXECUTABLE, and the method decides the sweep body (and, for
    'masked', even the mode-data layout) — a mixed-method stream
    therefore batches into per-method buckets that still share plans and
    kernels underneath."""

    shape: tuple[int, ...]
    nnz_cap: int
    method: str = "cp"

    @property
    def nmodes(self) -> int:
        return len(self.shape)

    @property
    def key(self) -> tuple:
        """Hashable identity used by metrics and density tracking."""
        return (self.shape, self.nnz_cap, self.method)

    def padding_fraction(self, nnz: int) -> float:
        """Fraction of the bucket's nnz slots wasted on zero padding."""
        return (self.nnz_cap - nnz) / self.nnz_cap if self.nnz_cap else 0.0


@dataclasses.dataclass(frozen=True)
class BucketPolicy:
    """nnz quantization rule — a thin front over the SAME
    ``core.plan.quantize_nnz`` the planning layer consumes, so padding
    policy and kernel packing can never disagree about a bucket's cap
    (the plan's static slab caps are a pure function of the cap this
    policy emits).

    mode:
      'quantum'   — round nnz up to the next multiple of ``quantum``
                    (default).  Worst-case padding fraction is
                    quantum / cap, i.e. < 15% once nnz > ~6.7·quantum;
                    the executable count grows linearly in the nnz
                    spread, which is fine in the small-tensor regime
                    where same-shape streams concentrate tightly.
      'geometric' — round nnz up to the next ``min_cap · growth^k``.
                    Bounded executable count for arbitrary nnz spreads
                    at the price of up to (1 - 1/growth) padding.
    """

    mode: str = "quantum"
    quantum: int = 128
    growth: float = 1.25
    min_cap: int = 128

    def __post_init__(self):
        if self.mode == "geometric" and self.growth <= 1.0:
            raise ValueError(f"geometric growth must be > 1, "
                             f"got {self.growth}")
        if self.quantum < 1 or self.min_cap < 1:
            raise ValueError("quantum and min_cap must be >= 1")

    @classmethod
    def for_plan(cls, tile: int = 256, **kw) -> "BucketPolicy":
        """Policy whose quantum is the plan's slab tile: every bucket cap
        then lands on a slab boundary, so nnz padding and slab-cap
        padding quantize identically (zero waste between the two)."""
        return cls(quantum=int(tile), min_cap=int(tile), **kw)

    def nnz_cap(self, nnz: int) -> int:
        return plan_mod.quantize_nnz(
            nnz, mode=self.mode, quantum=self.quantum,
            growth=self.growth, min_cap=self.min_cap)

    def bucket_for(self, tensor: SparseTensor, method: str = "cp") -> Bucket:
        return Bucket(tuple(int(s) for s in tensor.shape),
                      self.nnz_cap(tensor.nnz), method)


def pad_weights(weights: np.ndarray, nnz_cap: int) -> np.ndarray:
    """Extend a per-entry observation-weight vector with zeros to
    ``nnz_cap`` — the companion of ``pad_tensor`` for weighted methods,
    where padding entries must carry weight 0 (a zero VALUE alone would
    claim the tensor is observed-zero at the origin).  PR 5's conformance
    suite proved weight-0 == absent bit-identically, which is what makes
    the padded weighted decomposition exact."""
    w = np.asarray(weights, np.float32)
    if len(w) > nnz_cap:
        raise ValueError(
            f"weight vector length {len(w)} exceeds bucket cap {nnz_cap}")
    if len(w) == nnz_cap:
        return w
    return np.concatenate([w, np.zeros(nnz_cap - len(w), np.float32)])


def repeat_pad(seq, total: int) -> list:
    """Extend a per-request sequence to ``total`` entries by repeating the
    last one — the batch-axis analogue of ``pad_tensor``: under vmap (and
    the pod's shard_map) lanes are independent, so duplicated trailing
    requests compute real-but-discarded results and the kept lanes are
    bit-identical to an unpadded dispatch.  Used both by the scheduler's
    ``batch_quantum`` stabilizer and by the pod engine's mesh-multiple
    padding."""
    seq = list(seq)
    if not seq or total < len(seq):
        raise ValueError(f"cannot repeat-pad {len(seq)} items to {total}")
    return seq + [seq[-1]] * (total - len(seq))


def pad_tensor(tensor: SparseTensor, nnz_cap: int) -> SparseTensor:
    """Append zero-valued entries at coordinate (0, …, 0) until
    ``nnz == nnz_cap``.  Appending (not interleaving) keeps every real
    entry's position in the canonical order, which is what makes the
    padded decomposition bit-identical (stable layout sorts preserve
    relative order; +0.0 accumulation is exact)."""
    if tensor.nnz > nnz_cap:
        raise ValueError(
            f"tensor nnz {tensor.nnz} exceeds bucket cap {nnz_cap}")
    if tensor.nnz == nnz_cap:
        return tensor
    pad = nnz_cap - tensor.nnz
    idx = np.concatenate(
        [tensor.indices,
         np.zeros((pad, tensor.nmodes), dtype=tensor.indices.dtype)], axis=0)
    vals = np.concatenate(
        [tensor.values, np.zeros(pad, dtype=tensor.values.dtype)])
    return SparseTensor(idx, vals, tensor.shape)
