"""Service telemetry: throughput, latency percentiles, padding overhead,
batch occupancy, executable-cache hit rates.

The serving thesis (one small tensor cannot saturate the device) is only
validated by *stream-level* numbers, so the scheduler records one event
per flushed batch and one latency per completed request; ``snapshot()``
reduces them to the dashboard dict ``benchmarks/serve_bench.py`` prints.

Memory is bounded for long-running services: counts, padding, occupancy,
cache and trigger totals are running aggregates (exact over the full
uptime), while latency percentiles are computed over a sliding window of
the most recent ``window`` requests (and ``batches`` retains only the
most recent events, for debugging).

The metrics are also the serving layer's feedback channel INTO planning:
``record_density`` accumulates an EWMA of each bucket's observed per-mode
row-density profile (fraction of nnz mass per descending-sorted row bin,
``core.plan.density_profile``), and ``row_density`` hands the scheduler a
QUANTIZED copy to pass to ``core.plan.plan_bucket(density=...)`` — so a
skewed stream's tilings are priced against its real skew instead of the
uniform prior, while quantization (1/16 grid) bounds how many distinct
plans (and therefore executables) one bucket can cycle through.

Streaming sessions are a second write side: each ``StreamingCP`` routed
through a runner reports one ``record_stream_increment`` per update
(``start()`` registers the residency gauges without counting), and
``snapshot()["streams"]`` exposes the per-session gauges (bucket
residency, eviction counts, increment latency p50/p99) — how the
serving tier sees the stateful workload.

SLO health is the read side's judgment call: construct with
``slo=obs.health.SLOPolicy(...)`` and every ``snapshot()`` carries a
``health`` section — per-bucket/global p99 latency ceilings, queue
depth/age ceilings (fed by ``record_queue`` from the scheduler),
cache-hit / occupancy / overlap floors, streaming-increment ceilings —
with breach onsets emitted as edge-triggered ``health.breach`` trace
events so a JSONL trace alone reconstructs the incident timeline.

Thread safety: ServiceMetrics carries its OWN lock covering the batch /
request / density state.  (It used to lean on the scheduler's lock,
which left ``snapshot()`` — callable from any thread, and called by
dashboards while the service is live — racing ``record_batch`` /
``record_density`` mutations.)  Stream recording arrives from session
threads outside the scheduler and keeps its separate ``_streams_lock``;
the two locks are never held together, so no ordering constraint exists.
A concurrent read/write stress test (tests/serve/
test_metrics_concurrency.py) locks the discipline down.
"""
from __future__ import annotations

import collections
import dataclasses
import threading

import numpy as np

from ..obs import health as obs_health

_DENSITY_EWMA = 0.3
_DENSITY_QUANTUM = 1.0 / 16.0


@dataclasses.dataclass
class BatchEvent:
    bucket_key: tuple
    batch_size: int
    max_batch: int
    real_nnz: int          # sum of un-padded nnz over the batch
    padded_nnz: int        # batch_size * bucket nnz_cap
    wall_s: float
    trigger: str           # 'max_batch' | 'max_wait' | 'aging' | 'forced'
    cache_hits: int        # executable-cache hit delta for this flush
    cache_misses: int


class ServiceMetrics:
    """Accumulates per-request and per-batch events; ``snapshot()`` is the
    read side."""

    def __init__(self, window: int = 4096,
                 slo: "obs_health.SLOPolicy | None" = None):
        # Guards every non-stream field below.  Writers (scheduler
        # threads) and readers (snapshot from dashboard/bench threads)
        # may run concurrently; without this lock snapshot() could see
        # torn aggregates (e.g. completed bumped but latencies not yet
        # extended) or race dict resizes in _density.
        self._lock = threading.Lock()
        self.submitted = 0
        self.completed = 0
        self.batch_count = 0
        self.latencies_s: collections.deque = collections.deque(
            maxlen=window)
        # Per-bucket latency windows for the per-bucket p99 SLO targets;
        # same sliding-window discipline as the global deque.
        self._window = int(window)
        self._bucket_lat: dict[tuple, collections.deque] = {}
        self.batches: collections.deque = collections.deque(maxlen=window)
        self.t_first_submit: float | None = None
        self.t_last_complete: float | None = None
        self._real_nnz = 0
        self._padded_nnz = 0
        self._cache_hits = 0
        self._cache_misses = 0
        self._occupancy_sum = 0.0
        self._triggers = collections.Counter()
        # Pod / double-buffer dispatch gauges: one record per device
        # dispatch (flush device-half), split into host-assembly seconds,
        # device-execute seconds, and how much of the assembly overlapped
        # some OTHER flush's execute interval (the double-buffer witness).
        self._dispatches = 0
        self._assembly_s = 0.0
        self._execute_s = 0.0
        self._overlap_s = 0.0
        self._device_dispatches = collections.Counter()
        # bucket key -> list of per-mode EWMA row-density profiles
        self._density: dict[tuple, list[np.ndarray]] = {}
        # Queue gauges: the scheduler refreshes these on every
        # submit/poll/flush — current pending depth, age of the oldest
        # queued request, and their uptime peaks (the saturation SLOs).
        self._queue_depth = 0
        self._queue_age_s = 0.0
        self._queue_peak_depth = 0
        self._queue_peak_age_s = 0.0
        # session id -> per-session streaming gauges (own lock: sessions
        # record from outside the scheduler's critical section)
        self._streams: dict[str, dict] = {}
        self._streams_lock = threading.Lock()
        # SLO health: evaluated over the snapshot view; the monitor
        # edge-triggers health.breach/health.clear trace events.  No
        # policy -> the health section reports "disabled".
        self.slo = slo
        self._health = (obs_health.HealthMonitor(slo)
                        if slo is not None else None)

    # -- write side (own lock; callers need hold nothing) -------------------

    def record_submit(self, now: float):
        with self._lock:
            self.submitted += 1
            if self.t_first_submit is None:
                self.t_first_submit = now

    def record_batch(self, event: BatchEvent, latencies_s: list[float],
                     now: float):
        with self._lock:
            self.batches.append(event)
            self.batch_count += 1
            self.completed += event.batch_size
            self.latencies_s.extend(latencies_s)
            blat = self._bucket_lat.get(event.bucket_key)
            if blat is None:
                blat = self._bucket_lat[event.bucket_key] = \
                    collections.deque(maxlen=self._window)
            blat.extend(latencies_s)
            self.t_last_complete = now
            self._real_nnz += event.real_nnz
            self._padded_nnz += event.padded_nnz
            self._cache_hits += event.cache_hits
            self._cache_misses += event.cache_misses
            if event.max_batch:
                self._occupancy_sum += event.batch_size / event.max_batch
            self._triggers[event.trigger] += 1

    def record_dispatch(self, *, devices: list[int], assembly_s: float,
                        execute_s: float, overlap_s: float):
        """Fold one flush's dispatch timing into the pod gauges.
        ``devices`` lists the device ids the executable spanned (all mesh
        devices for a pod dispatch, ``[0]`` single-device); ``overlap_s``
        is the part of this flush's host assembly that ran while another
        flush's device half was executing."""
        with self._lock:
            self._dispatches += 1
            self._assembly_s += float(assembly_s)
            self._execute_s += float(execute_s)
            self._overlap_s += float(overlap_s)
            for d in devices:
                self._device_dispatches[int(d)] += 1

    def record_queue(self, depth: int, oldest_age_s: float):
        """Refresh the queue-saturation gauges (current pending depth +
        oldest queued request's age).  The scheduler calls this on every
        submit/poll/flush, so the gauge tracks the live queue; peaks are
        running maxima over the whole uptime."""
        with self._lock:
            self._queue_depth = int(depth)
            self._queue_age_s = float(oldest_age_s)
            self._queue_peak_depth = max(self._queue_peak_depth,
                                         self._queue_depth)
            self._queue_peak_age_s = max(self._queue_peak_age_s,
                                         self._queue_age_s)

    def record_density(self, bucket_key: tuple,
                       profiles: tuple[tuple[float, ...] | None, ...]):
        """EWMA-fold one flushed batch's observed per-mode row-density
        profiles into the bucket's running estimate.  A ``None`` profile
        (mode too large to profile cheaply) leaves that mode on the
        uniform prior."""
        with self._lock:
            cur = self._density.get(bucket_key)
            if cur is None:
                self._density[bucket_key] = [
                    None if p is None else np.asarray(p, dtype=np.float64)
                    for p in profiles]
                return
            for d, p in enumerate(profiles):
                if p is None:
                    continue
                if cur[d] is None:
                    cur[d] = np.asarray(p, dtype=np.float64)
                else:
                    cur[d] = (
                        (1.0 - _DENSITY_EWMA) * cur[d]
                        + _DENSITY_EWMA * np.asarray(p, dtype=np.float64))

    def row_density(self, bucket_key: tuple) -> tuple | None:
        """Quantized per-mode density profiles for ``plan_bucket`` (None
        until the bucket has flushed at least once; per-mode None where
        never profiled).  Quantizing to a 1/16 grid keeps the profile
        hashable AND bounds the number of distinct plans (hence
        executables) a drifting stream can induce."""
        with self._lock:
            cur = self._density.get(bucket_key)
            if cur is None:
                return None
            out = []
            for p in cur:
                if p is None:
                    out.append(None)
                    continue
                q = np.round(p / _DENSITY_QUANTUM) * _DENSITY_QUANTUM
                out.append(tuple(float(x) for x in q))
            return tuple(out)

    def record_stream_increment(self, session_id: str, *, bucket_cap: int,
                                nnz: int, evicted: int, wall_s: float,
                                merge_s: float, window: int = 512,
                                count: bool = True):
        """Fold one streaming update into the session's gauges: current
        bucket residency (cap + live nnz), cumulative increment/eviction
        counts, host-merge seconds, and a sliding window of increment
        wall times for the latency percentiles.  ``count=False``
        registers/refreshes the residency gauges without counting an
        increment or recording latency — the cold ``start()`` fit, whose
        compile-heavy wall time would poison the increment percentiles."""
        with self._streams_lock:
            s = self._streams.get(session_id)
            if s is None:
                s = self._streams[session_id] = {
                    "increments": 0, "evictions": 0, "merge_s": 0.0,
                    "lat": collections.deque(maxlen=window),
                }
            s["bucket_cap"] = int(bucket_cap)
            s["nnz"] = int(nnz)
            s["merge_s"] += float(merge_s)
            if count:
                s["increments"] += 1
                s["evictions"] += int(evicted)
                s["lat"].append(float(wall_s))

    # -- read side ----------------------------------------------------------

    def snapshot(self) -> dict:
        # Main state under self._lock; the stream gauges are appended
        # after releasing it (their own lock) so the two are never
        # nested.
        with self._lock:
            lat = np.asarray(self.latencies_s, dtype=np.float64)
            real, padded = self._real_nnz, self._padded_nnz
            hits, misses = self._cache_hits, self._cache_misses
            span = 0.0
            if (self.t_first_submit is not None
                    and self.t_last_complete is not None):
                span = max(self.t_last_complete - self.t_first_submit, 0.0)
            out = {
                "submitted": self.submitted,
                "completed": self.completed,
                "batches": self.batch_count,
                "throughput_rps": self.completed / span if span > 0 else 0.0,
                "latency_p50_s": (float(np.percentile(lat, 50))
                                  if lat.size else 0.0),
                "latency_p99_s": (float(np.percentile(lat, 99))
                                  if lat.size else 0.0),
                # str(bucket.key) -> windowed p99, for the per-bucket
                # latency SLO targets (and dashboards)
                "bucket_latency_p99_s": {
                    str(k): float(np.percentile(
                        np.asarray(d, dtype=np.float64), 99))
                    for k, d in self._bucket_lat.items() if len(d)
                },
                "queue": {
                    "depth": self._queue_depth,
                    "oldest_age_s": self._queue_age_s,
                    "peak_depth": self._queue_peak_depth,
                    "peak_age_s": self._queue_peak_age_s,
                },
                # fraction of device nnz-slots spent on zero padding
                "padding_overhead": (padded - real) / padded if padded
                else 0.0,
                "batch_occupancy": (self._occupancy_sum / self.batch_count
                                    if self.batch_count else 0.0),
                "cache_hits": hits,
                "cache_misses": misses,
                "cache_hit_rate": (hits / (hits + misses)
                                   if hits + misses else 0.0),
                "density_tracked_buckets": len(self._density),
                "flush_triggers": {
                    t: self._triggers.get(t, 0)
                    for t in ("max_batch", "max_wait", "aging", "forced")
                },
                "dispatch": {
                    "count": self._dispatches,
                    "assembly_s": self._assembly_s,
                    "execute_s": self._execute_s,
                    "overlap_s": self._overlap_s,
                    # fraction of host assembly time hidden behind device
                    # compute — 0 without double buffering, > 0 once the
                    # executor pipelines flushes
                    "overlap_fraction": (self._overlap_s / self._assembly_s
                                         if self._assembly_s > 0 else 0.0),
                    # fraction of service uptime the device(s) spent
                    # executing dispatches
                    "device_occupancy": (self._execute_s / span
                                         if span > 0 else 0.0),
                    "device_dispatches": dict(
                        sorted(self._device_dispatches.items())),
                },
            }
        out["streams"] = self._stream_snapshot()
        # Health last: the evaluator reads the snapshot view itself (a
        # consistent copy — no locks held), so the report always judges
        # exactly the gauges this snapshot exposes.  Breach onsets emit
        # health.breach trace events (edge-triggered, see obs.health).
        if self._health is None:
            out["health"] = {"status": "disabled", "checked": 0,
                             "breaches": []}
        else:
            out["health"] = self._health.observe(out)
        return out

    def _stream_snapshot(self) -> dict:
        with self._streams_lock:
            out = {}
            for sid, s in self._streams.items():
                lat = np.asarray(s["lat"], dtype=np.float64)
                out[sid] = {
                    "bucket_cap": s.get("bucket_cap", 0),
                    "nnz": s.get("nnz", 0),
                    "increments": s["increments"],
                    "evictions": s["evictions"],
                    "merge_s": s["merge_s"],
                    "increment_p50_s": (float(np.percentile(lat, 50))
                                        if lat.size else 0.0),
                    "increment_p99_s": (float(np.percentile(lat, 99))
                                        if lat.size else 0.0),
                }
            return out
