from .trainer import StragglerMonitor, Trainer

__all__ = ["StragglerMonitor", "Trainer"]
