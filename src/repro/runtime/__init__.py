from .trainer import ALSRunner, StragglerMonitor, Trainer

__all__ = ["ALSRunner", "StragglerMonitor", "Trainer"]
