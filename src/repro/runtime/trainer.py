"""Training orchestrator: checkpoint/restart, straggler monitoring,
elastic re-mesh.

Fault model (multi-pod deployment):
  * preemption/crash — every state that matters (params, optimizer,
    data-pipeline cursor, step) is checkpointed atomically; ``run()``
    always begins by restoring the latest committed checkpoint, so a
    restarted job continues bit-identically (deterministic pipeline).
  * stragglers — per-step wall time is tracked with an EWMA mean/var;
    steps beyond ``straggler_sigma`` deviations are logged and counted.
    (In SPMD one slow chip stalls the step itself, so detection here is
    per-step; a deployment feeds per-host heartbeats into the same
    monitor and evicts the slow host, then resumes elastically.)
  * elastic scaling — restore() reshards onto whatever mesh the restarted
    job has: the checkpoint is topology-free (host numpy), and target
    shardings come from the new mesh.  Tested 8 -> 4 devices in CI.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import numpy as np

import jax
import jax.numpy as jnp

from .. import optim
from ..checkpoint.manager import CheckpointManager
from ..obs import clock as obs_clock
from ..core.coo import SparseTensor
from ..core.cpd import CPDResult
from ..launch import shardings as shd
from ..launch import steps as steps_mod


@dataclasses.dataclass
class StragglerMonitor:
    alpha: float = 0.2
    sigma: float = 4.0
    warmup: int = 3
    mean: float = 0.0
    var: float = 0.0
    n: int = 0
    events: list = dataclasses.field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        self.n += 1
        if self.n <= self.warmup:
            self.mean = dt if self.n == 1 else (
                self.mean + (dt - self.mean) / self.n)
            self.var = max(self.var, (dt - self.mean) ** 2)
            return False
        flagged = bool(dt > self.mean + self.sigma * max(np.sqrt(self.var), 1e-4))
        if flagged:
            self.events.append((step, dt, self.mean))
        else:
            d = dt - self.mean
            self.mean += self.alpha * d
            self.var = (1 - self.alpha) * (self.var + self.alpha * d * d)
        return flagged


class ALSRunner:
    """Decomposition-as-a-service front door.

    ``mode="batched"`` (default) delegates to the serving subsystem
    (``repro.serve``): requests are quantized into (shape, nnz-bucket)
    classes, micro-batched per bucket, and executed as ONE vmapped fused
    sweep per batch — ``decompose_async``/``flush`` expose the
    throughput path, while the synchronous ``decompose`` force-flushes
    its own bucket (batch of whatever is queued there).
    ``mode="sequential"`` keeps the one-request-at-a-time fused engine.

    Either way the executable story is the same: the first request per
    class compiles, every later one reuses the cached executable — and
    ``history`` records the per-request executable-cache hit/miss delta,
    so a straggler caused by a retrace (cold bucket) is distinguishable
    from one caused by contention (warm bucket, slow host).  Each
    request's wall time feeds the same ``StragglerMonitor`` the trainer
    uses.
    """

    def __init__(self, rank: int, *, kappa: int = 1, backend: str = "segment",
                 engine: str = "fused", check_every: int = 4,
                 monitor: StragglerMonitor | None = None,
                 mode: str | None = None, max_batch: int = 8,
                 max_wait_s: float = 0.005, batch_quantum: int = 1,
                 policy=None):
        if mode is None:
            # Default to the batched service where it supports the
            # configuration (all three fused backends, pallas included
            # now that core.plan slab caps make its packings stack);
            # engine="host" keeps working via the sequential path
            # instead of failing construction.
            mode = ("batched" if engine == "fused"
                    and backend in ("segment", "coo", "pallas")
                    else "sequential")
        if mode not in ("batched", "sequential"):
            raise ValueError(f"unknown mode {mode!r}")
        if mode == "batched" and engine != "fused":
            raise ValueError("mode='batched' requires engine='fused'; "
                             "use mode='sequential' for engine='host'")
        self.rank = rank
        self.kappa = kappa
        self.backend = backend
        self.engine = engine
        self.check_every = check_every
        self.mode = mode
        self.monitor = monitor or StragglerMonitor()
        self.history: list[dict] = []
        self.service = None
        if mode == "batched":
            from ..serve import DecompositionService

            self.service = DecompositionService(
                rank, kappa=kappa, backend=backend, check_every=check_every,
                policy=policy, max_batch=max_batch, max_wait_s=max_wait_s,
                batch_quantum=batch_quantum)

    def _cache_stats(self) -> dict:
        if self.mode == "batched":
            from ..serve import batched_cache_stats

            return batched_cache_stats()
        from ..core.als_device import sweep_cache_stats

        return sweep_cache_stats()

    def _record(self, tensor: SparseTensor, res: CPDResult, dt: float,
                cache_before: dict, log: Callable[[str], None]) -> None:
        after = self._cache_stats()
        req = len(self.history) + 1
        flagged = self.monitor.observe(req, dt)
        rec = {"request": req, "shape": tuple(tensor.shape),
               "nnz": tensor.nnz, "fit": res.fits[-1] if res.fits else 0.0,
               "iters": res.iters, "host_syncs": res.host_syncs,
               "time_s": dt, "straggler": flagged,
               "sweep_cache_hits": after["hits"] - cache_before["hits"],
               "sweep_cache_misses": after["misses"] - cache_before["misses"]}
        self.history.append(rec)
        if flagged:
            cause = ("retrace" if rec["sweep_cache_misses"] else "contention")
            log(f"[als] request {req} STRAGGLER ({cause}): {dt*1e3:.0f} ms "
                f"(mean {self.monitor.mean*1e3:.0f} ms)")

    def decompose(self, tensor: SparseTensor, *, n_iters: int = 25,
                  tol: float = 1e-5, seed: int = 0, method: str = "cp",
                  init_state: tuple | None = None,
                  weights=None, verbose: bool = False,
                  log: Callable[[str], None] = print) -> CPDResult:
        """Decompose one tensor.  ``method`` selects the decomposition
        method ('cp', 'nncp', 'masked' — see ``repro.methods``); in
        batched mode the request lands in its (shape, nnz-bucket, method)
        class, so mixed-method callers batch per method automatically.
        ``init_state`` warm-starts from existing factors (streaming);
        ``weights`` carries per-entry observation confidences for
        weighted-fit methods ('masked')."""
        from ..core.cpd import cpd_als

        before = self._cache_stats()
        t0 = obs_clock.now()
        if self.mode == "batched":
            fut = self.service.submit(tensor, n_iters=n_iters, tol=tol,
                                      seed=seed, method=method,
                                      init_state=init_state,
                                      weights=weights)
            res = fut.result()    # force-flushes this request's bucket
            if verbose:           # post-hoc trajectory at window boundaries
                for i in range(self.check_every - 1, len(res.fits),
                               self.check_every):
                    log(f"  ALS iter {i + 1:3d}: fit={res.fits[i]:.6f} "
                        f"(batched/{method})")
        else:
            res = cpd_als(
                tensor, self.rank, kappa=self.kappa, n_iters=n_iters, tol=tol,
                seed=seed, backend=self.backend, engine=self.engine,
                check_every=self.check_every, method=method,
                init_state=init_state, weights=weights, verbose=verbose,
            )
        dt = obs_clock.now() - t0
        self._record(tensor, res, dt, before, log)
        return res

    def decompose_async(self, tensor: SparseTensor, *, n_iters: int = 25,
                        tol: float = 1e-5, seed: int = 0,
                        method: str = "cp", init_state: tuple | None = None,
                        weights=None):
        """Submit without blocking (batched mode only): returns a
        ``DecompositionFuture``.  The request completes when its bucket
        flushes (max-batch, max-wait via ``poll()``, ``flush()``, or the
        future's own ``result()``).  Async completions are recorded in
        ``service.metrics``, not ``history``."""
        if self.service is None:
            raise RuntimeError("decompose_async requires mode='batched'")
        return self.service.submit(tensor, n_iters=n_iters, tol=tol,
                                   seed=seed, method=method,
                                   init_state=init_state, weights=weights)

    def open_stream(self, *, method: str = "cp", refine_iters: int = 2,
                    policy="auto", decay: float | None = None,
                    weight_floor: float = 0.0,
                    resume_from: str | None = None,
                    session_id: str | None = None):
        """Open a streaming-CP session routed through this runner: every
        cold fit and warm refinement window goes through the same front
        door (and, in batched mode, the same bucketed service — so
        concurrent sessions of one bucket class batch together).

        ``policy`` / ``decay`` / ``weight_floor`` configure the session's
        bucket quantization and confidence-decay eviction (see
        ``StreamingCP``).  ``resume_from`` names a checkpoint directory:
        if it holds a committed session snapshot the stream resumes from
        it (same tensor, factors, seed, decay clock, and bucket cap —
        rerouted through THIS runner); otherwise a fresh session is
        returned, so one call site serves both cold start and restart
        after a crash."""
        from ..methods import StreamingCP

        if resume_from is not None:
            mgr = CheckpointManager(str(resume_from))
            if mgr.latest_step() is not None:
                return StreamingCP.restore(mgr, runner=self)
        return StreamingCP(self.rank, method=method, backend=self.backend,
                           kappa=self.kappa, check_every=self.check_every,
                           refine_iters=refine_iters, runner=self,
                           policy=policy, decay=decay,
                           weight_floor=weight_floor, session_id=session_id)

    def poll(self) -> int:
        return self.service.poll() if self.service else 0

    def flush(self) -> int:
        return self.service.drain() if self.service else 0


class Trainer:
    def __init__(self, model, *, mesh, pipeline, opt_cfg=None,
                 ckpt_dir: str | None = None, ckpt_every: int = 50,
                 keep: int = 3, microbatch: int = 1,
                 failure_hook: Callable[[int], None] | None = None):
        self.model = model
        self.mesh = mesh
        self.pipeline = pipeline
        self.opt_cfg = opt_cfg or optim.AdamWConfig()
        self.ckpt = CheckpointManager(ckpt_dir, keep=keep) if ckpt_dir else None
        self.ckpt_every = ckpt_every
        self.monitor = StragglerMonitor()
        self.failure_hook = failure_hook
        self.step = 0

        self.p_shard = shd.param_shardings(model, mesh)
        self.o_shard = shd.opt_state_shardings(self.p_shard, mesh)
        step_fn = steps_mod.make_train_step(model, self.opt_cfg,
                                            microbatch=microbatch)
        self._jitted = jax.jit(
            step_fn,
            in_shardings=(self.p_shard, self.o_shard, None),
            # Pin outputs to the same shardings: without this the compiler
            # may choose different ones, and the donated second-step inputs
            # then mismatch in_shardings.
            out_shardings=(self.p_shard, self.o_shard, None),
            donate_argnums=(0, 1),
        )
        self.params = None
        self.opt_state = None

    # -- state --------------------------------------------------------------

    def initialize(self, seed: int = 0):
        """Fresh init or restore-from-latest (fault-tolerant entry)."""
        if self.ckpt and self.ckpt.latest_step() is not None:
            template = {
                "params": self.model.abstract_params(),
                "opt": jax.eval_shape(optim.init_state,
                                      self.model.abstract_params()),
            }
            shards = {"params": self.p_shard, "opt": self.o_shard}
            state, extra = self.ckpt.restore(template=template,
                                             shardings=shards)
            self.params, self.opt_state = state["params"], state["opt"]
            self.step = int(extra["step"])
            self.pipeline.restore(extra["pipeline"])
            return "restored"
        with self.mesh:
            self.params = jax.jit(
                self.model.init, out_shardings=self.p_shard
            )(jax.random.PRNGKey(seed))
            self.opt_state = jax.jit(
                optim.init_state, out_shardings=self.o_shard
            )(self.params)
        return "initialized"

    def save(self, block: bool = False):
        if not self.ckpt:
            return
        self.ckpt.save(
            self.step,
            {"params": self.params, "opt": self.opt_state},
            extra={"step": self.step, "pipeline": self.pipeline.snapshot()},
            block=block,
        )

    # -- loop ----------------------------------------------------------------

    def run(self, num_steps: int, *, log_every: int = 10,
            log: Callable[[str], None] = print) -> list[dict]:
        if self.params is None:
            mode = self.initialize()
            log(f"[trainer] {mode} at step {self.step}")
        history = []
        with self.mesh:
            while self.step < num_steps:
                batch = next(self.pipeline)
                batch = {k: jnp.asarray(v) for k, v in batch.items()}
                t0 = obs_clock.now()
                self.params, self.opt_state, metrics = self._jitted(
                    self.params, self.opt_state, batch)
                jax.block_until_ready(metrics["loss"])
                dt = obs_clock.now() - t0
                self.step += 1
                flagged = self.monitor.observe(self.step, dt)
                rec = {"step": self.step,
                       "loss": float(metrics["loss"]),
                       "grad_norm": float(metrics.get("grad_norm", 0.0)),
                       "time_s": dt,
                       "straggler": flagged}
                history.append(rec)
                if self.step % log_every == 0:
                    log(f"[trainer] step {rec['step']:5d} "
                        f"loss {rec['loss']:.4f} ({dt*1e3:.0f} ms)"
                        + (" STRAGGLER" if flagged else ""))
                if self.ckpt and self.step % self.ckpt_every == 0:
                    self.save()
                if self.failure_hook:
                    self.failure_hook(self.step)   # may raise (tests)
        if self.ckpt:
            self.save(block=True)
        return history
