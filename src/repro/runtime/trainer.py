"""Training orchestrator: checkpoint/restart, straggler monitoring,
elastic re-mesh.

Fault model (multi-pod deployment):
  * preemption/crash — every state that matters (params, optimizer,
    data-pipeline cursor, step) is checkpointed atomically; ``run()``
    always begins by restoring the latest committed checkpoint, so a
    restarted job continues bit-identically (deterministic pipeline).
  * stragglers — per-step wall time is tracked with an EWMA mean/var;
    steps beyond ``straggler_sigma`` deviations are logged and counted.
    (In SPMD one slow chip stalls the step itself, so detection here is
    per-step; a deployment feeds per-host heartbeats into the same
    monitor and evicts the slow host, then resumes elastically.)
  * elastic scaling — restore() reshards onto whatever mesh the restarted
    job has: the checkpoint is topology-free (host numpy), and target
    shardings come from the new mesh.  Tested 8 -> 4 devices in CI.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import numpy as np

import jax
import jax.numpy as jnp

from .. import optim
from ..checkpoint.manager import CheckpointManager
from ..core.coo import SparseTensor
from ..core.cpd import CPDResult
from ..launch import shardings as shd
from ..launch import steps as steps_mod


@dataclasses.dataclass
class StragglerMonitor:
    alpha: float = 0.2
    sigma: float = 4.0
    warmup: int = 3
    mean: float = 0.0
    var: float = 0.0
    n: int = 0
    events: list = dataclasses.field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        self.n += 1
        if self.n <= self.warmup:
            self.mean = dt if self.n == 1 else (
                self.mean + (dt - self.mean) / self.n)
            self.var = max(self.var, (dt - self.mean) ** 2)
            return False
        flagged = bool(dt > self.mean + self.sigma * max(np.sqrt(self.var), 1e-4))
        if flagged:
            self.events.append((step, dt, self.mean))
        else:
            d = dt - self.mean
            self.mean += self.alpha * d
            self.var = (1 - self.alpha) * (self.var + self.alpha * d * d)
        return flagged


class ALSRunner:
    """Decomposition-as-a-service: serve CPD requests through the
    device-resident fused ALS engine.

    The serving pattern the fused engine is built for: many tensors of the
    same shape family arrive over time; the first request per (shape, rank,
    backend) compiles the sweep, every later one reuses the executable
    (see ``core.als_device`` — zero retrace).  Each request's wall time
    feeds the same ``StragglerMonitor`` the trainer uses, so a slow
    decomposition (retrace, contended host, pathological tensor) is flagged
    exactly like a slow training step.
    """

    def __init__(self, rank: int, *, kappa: int = 1, backend: str = "segment",
                 engine: str = "fused", check_every: int = 4,
                 monitor: StragglerMonitor | None = None):
        self.rank = rank
        self.kappa = kappa
        self.backend = backend
        self.engine = engine
        self.check_every = check_every
        self.monitor = monitor or StragglerMonitor()
        self.history: list[dict] = []

    def decompose(self, tensor: SparseTensor, *, n_iters: int = 25,
                  tol: float = 1e-5, seed: int = 0, verbose: bool = False,
                  log: Callable[[str], None] = print) -> CPDResult:
        from ..core.cpd import cpd_als

        t0 = time.perf_counter()
        res = cpd_als(
            tensor, self.rank, kappa=self.kappa, n_iters=n_iters, tol=tol,
            seed=seed, backend=self.backend, engine=self.engine,
            check_every=self.check_every, verbose=verbose,
        )
        dt = time.perf_counter() - t0
        req = len(self.history) + 1
        flagged = self.monitor.observe(req, dt)
        rec = {"request": req, "shape": tuple(tensor.shape),
               "nnz": tensor.nnz, "fit": res.fits[-1] if res.fits else 0.0,
               "iters": res.iters, "host_syncs": res.host_syncs,
               "time_s": dt, "straggler": flagged}
        self.history.append(rec)
        if flagged:
            log(f"[als] request {req} STRAGGLER: {dt*1e3:.0f} ms "
                f"(mean {self.monitor.mean*1e3:.0f} ms)")
        return res


class Trainer:
    def __init__(self, model, *, mesh, pipeline, opt_cfg=None,
                 ckpt_dir: str | None = None, ckpt_every: int = 50,
                 keep: int = 3, microbatch: int = 1,
                 failure_hook: Callable[[int], None] | None = None):
        self.model = model
        self.mesh = mesh
        self.pipeline = pipeline
        self.opt_cfg = opt_cfg or optim.AdamWConfig()
        self.ckpt = CheckpointManager(ckpt_dir, keep=keep) if ckpt_dir else None
        self.ckpt_every = ckpt_every
        self.monitor = StragglerMonitor()
        self.failure_hook = failure_hook
        self.step = 0

        self.p_shard = shd.param_shardings(model, mesh)
        self.o_shard = shd.opt_state_shardings(self.p_shard, mesh)
        step_fn = steps_mod.make_train_step(model, self.opt_cfg,
                                            microbatch=microbatch)
        self._jitted = jax.jit(
            step_fn,
            in_shardings=(self.p_shard, self.o_shard, None),
            # Pin outputs to the same shardings: without this the compiler
            # may choose different ones, and the donated second-step inputs
            # then mismatch in_shardings.
            out_shardings=(self.p_shard, self.o_shard, None),
            donate_argnums=(0, 1),
        )
        self.params = None
        self.opt_state = None

    # -- state --------------------------------------------------------------

    def initialize(self, seed: int = 0):
        """Fresh init or restore-from-latest (fault-tolerant entry)."""
        if self.ckpt and self.ckpt.latest_step() is not None:
            template = {
                "params": self.model.abstract_params(),
                "opt": jax.eval_shape(optim.init_state,
                                      self.model.abstract_params()),
            }
            shards = {"params": self.p_shard, "opt": self.o_shard}
            state, extra = self.ckpt.restore(template=template,
                                             shardings=shards)
            self.params, self.opt_state = state["params"], state["opt"]
            self.step = int(extra["step"])
            self.pipeline.restore(extra["pipeline"])
            return "restored"
        with self.mesh:
            self.params = jax.jit(
                self.model.init, out_shardings=self.p_shard
            )(jax.random.PRNGKey(seed))
            self.opt_state = jax.jit(
                optim.init_state, out_shardings=self.o_shard
            )(self.params)
        return "initialized"

    def save(self, block: bool = False):
        if not self.ckpt:
            return
        self.ckpt.save(
            self.step,
            {"params": self.params, "opt": self.opt_state},
            extra={"step": self.step, "pipeline": self.pipeline.snapshot()},
            block=block,
        )

    # -- loop ----------------------------------------------------------------

    def run(self, num_steps: int, *, log_every: int = 10,
            log: Callable[[str], None] = print) -> list[dict]:
        if self.params is None:
            mode = self.initialize()
            log(f"[trainer] {mode} at step {self.step}")
        history = []
        with self.mesh:
            while self.step < num_steps:
                batch = next(self.pipeline)
                batch = {k: jnp.asarray(v) for k, v in batch.items()}
                t0 = time.perf_counter()
                self.params, self.opt_state, metrics = self._jitted(
                    self.params, self.opt_state, batch)
                jax.block_until_ready(metrics["loss"])
                dt = time.perf_counter() - t0
                self.step += 1
                flagged = self.monitor.observe(self.step, dt)
                rec = {"step": self.step,
                       "loss": float(metrics["loss"]),
                       "grad_norm": float(metrics.get("grad_norm", 0.0)),
                       "time_s": dt,
                       "straggler": flagged}
                history.append(rec)
                if self.step % log_every == 0:
                    log(f"[trainer] step {rec['step']:5d} "
                        f"loss {rec['loss']:.4f} ({dt*1e3:.0f} ms)"
                        + (" STRAGGLER" if flagged else ""))
                if self.ckpt and self.step % self.ckpt_every == 0:
                    self.save()
                if self.failure_hook:
                    self.failure_hook(self.step)   # may raise (tests)
        if self.ckpt:
            self.save(block=True)
        return history
