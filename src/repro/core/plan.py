"""Static-shape partition plans: ONE planning layer for every execution path.

The paper's partitioning step (distribute nonzeros across SMs by sparsity
and dimensions) used to be re-derived ad hoc in three places — kernel slab
packing (`kernels.ops`), serving bucket padding (`serve.buckets`), and
per-device splits (`core.distributed`) — each with data-dependent shapes
that blocked composition with ``jax.vmap`` and ``shard_map``.  Following
the multi-GPU extension of this planning step (AMPED, arXiv 2507.15121)
and the fixed-granule load balancing of Nisa et al. (arXiv 1904.03329),
this module commits to **static-shape partition artifacts decided once**:

    ModeLayout / bucket class
            |
       PartitionPlan            (this module: cost model -> static caps)
            |
    +-------+-------------------+----------------------+
    | Pallas packing            | vmapped batch        | shard_map shards
    | (kernels.ops.pack_layout  | (serve.batched_engine| (core.distributed
    |  padded to slab_cap)      |  stacks bucket-mates)|  psum partials)
    +---------------------------+----------------------+

Three static quantities make the composition work:

  * ``quantize_nnz`` — the nnz cap of a (shape, nnz-bucket) request class.
    ``serve.buckets.BucketPolicy`` delegates here, so padding policy and
    kernel packing can never disagree on what a bucket holds.
  * ``slab_cap``     — an nnz-independent upper bound on the packed grid
    size: any tensor with ``nnz <= nnz_cap`` packs into at most
    ``ceil(I_d / block_rows) + nnz_cap // tile`` slabs.  Packing padded up
    to this cap (appended all-zero slabs on the last row block) is
    bit-identical to the unpadded packing and gives every bucket-mate the
    SAME array shapes — which is exactly what lets ``jax.vmap`` stack the
    Pallas backend.
  * ``DeviceShards`` — per-device rectangular slices of a mode layout
    (nnz padded to a common per-device cap) with *global* relabeled rows,
    so every device computes a partial MTTKRP into the full (I_d, R)
    output and a single ``psum`` combines them under ``shard_map``.

The tiling decisions themselves stay in the cost model
(`kernels.ops.estimate_pack_cost` / ``auto_tiles`` / ``auto_rank_block``);
this module is the single front door that consults it.
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

from ..kernels import ops as kops
from ..obs import trace as obs_trace
from .load_balance import Scheme

# Per-device nnz shards are padded up to a multiple of this, so tensors of
# similar size reuse the same distributed executable.
DEVICE_SHARD_QUANTUM = 64


# ---------------------------------------------------------------------------
# nnz quantization (the bucket <-> packing contract)
# ---------------------------------------------------------------------------


def quantize_nnz(nnz: int, *, mode: str = "quantum", quantum: int = 128,
                 growth: float = 1.25, min_cap: int = 128) -> int:
    """Round ``nnz`` up to its bucket cap.  This is THE quantization rule:
    ``serve.buckets.BucketPolicy`` calls it for padding policy and
    ``plan_bucket`` consumes its output for slab caps, so the two can
    never disagree.

    mode 'quantum': next multiple of ``quantum`` (linear executable count,
    worst-case padding quantum/cap).  mode 'geometric': next
    ``min_cap * growth^k`` (bounded executable count for arbitrary
    spreads, up to (1 - 1/growth) padding).
    """
    nnz = max(int(nnz), 1)
    if mode == "quantum":
        q = max(int(quantum), 1)
        return max(-(-nnz // q) * q, min_cap)
    if mode == "geometric":
        cap = float(min_cap)
        while cap < nnz:
            cap *= growth
        return int(np.ceil(cap))
    raise ValueError(f"unknown bucketing mode {mode!r}")


def session_cap(nnz: int, current_cap: int, policy) -> int:
    """Monotone per-session bucket cap: quantize ``nnz`` through
    ``policy`` (any object with an ``nnz_cap(nnz)`` rule, i.e. a
    ``serve.buckets.BucketPolicy``) but never below the session's
    ``current_cap``.  A streaming session's fit-time nnz is pinned to its
    largest-seen executable class: shrinking the cap after an eviction
    would present NEW (smaller) array shapes to the engine and retrace —
    the exact cost the quantization exists to avoid — whereas holding the
    old cap merely keeps some already-compiled zero-weight padding slots.
    With geometric bucketing, a session therefore compiles O(log peak
    nnz) executables over its whole lifetime."""
    return max(int(current_cap), int(policy.nnz_cap(nnz)))


def slab_cap(num_rows: int, nnz_cap: int, block_rows: int, tile: int) -> int:
    """Static upper bound on the packed grid size G for ANY tensor of this
    mode with ``nnz <= nnz_cap``:  every row block contributes at least one
    slab (``ceil(I_d / block_rows)`` total) and the data itself at most
    ``floor(nnz_cap / tile)`` extra full slabs, since
    ``ceil(x / t) <= 1 + floor(x / t)``.  Packing padded to this cap makes
    the slab arrays' shapes a pure function of the bucket class."""
    nb = max(1, -(-int(num_rows) // int(block_rows)))
    return nb + int(nnz_cap) // int(tile)


# ---------------------------------------------------------------------------
# Per-mode plans (the cost model's single front door)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModePlan:
    """Static packing/tiling decision for one output mode of a bucket class.

    Every field is a pure function of (shape, nnz_cap, rank, kappa) — no
    tensor data — so all bucket-mates share it, and it doubles as an
    executable-cache key component."""

    mode: int
    num_rows: int
    block_rows: int
    tile: int
    rank_block: int            # columns resident per kernel pass
    num_row_blocks: int
    slab_cap: int              # padded grid size G_cap (static)
    nnz_cap: int
    # Segment-backend partitioning decision for this mode: how many
    # partitions the mode layout is split into and under which
    # load-balancing scheme ('index' / 'nnz'; None = the paper's adaptive
    # threshold rule).  Defaults reproduce the caller's kappa untouched;
    # an OBSERVED density profile routes through the cost chooser
    # (``choose_segment_partition``) instead, so a skewed stream can move
    # the bucket onto a different kappa/scheme than the uniform prior
    # would pick.
    seg_kappa: int = 1
    seg_scheme: str | None = None

    @property
    def pallas_meta(self) -> tuple[int, int, int, int]:
        """The static tuple the fused sweep builder keys its cache on."""
        return (self.num_row_blocks, self.block_rows, self.tile,
                self.rank_block)


@dataclasses.dataclass(frozen=True)
class PartitionPlan:
    """All-modes static plan for one (shape, nnz_cap) class.

    Built once per bucket class (``plan_bucket``, cached) or once per
    tensor (``plan_tensor``); consumed by kernel packing, the vmapped
    batched engine, and the distributed shard builder."""

    shape: tuple[int, ...]
    nnz_cap: int
    rank: int
    kappa: int
    modes: tuple[ModePlan, ...]

    @property
    def nmodes(self) -> int:
        return len(self.shape)

    def pallas_meta(self) -> tuple:
        return tuple(m.pallas_meta for m in self.modes)

    def describe(self) -> str:
        """One-line plan fingerprint for benchmark attribution."""
        parts = []
        for m in self.modes:
            parts.append(f"m{m.mode}:br{m.block_rows}/t{m.tile}"
                         f"/rb{m.rank_block}/G{m.slab_cap}")
        return ";".join(parts)


class _UniformModeStats:
    """Duck-typed stand-in for a ``ModeLayout`` in the cost model when no
    tensor data exists yet (bucket-level planning): ``nnz_cap`` nonzeros
    spread uniformly over the mode's rows.  Exposes exactly the attributes
    ``kernels.ops.estimate_pack_cost`` consumes."""

    def __init__(self, shape: tuple[int, ...], mode: int, nnz: int):
        self.shape = tuple(int(s) for s in shape)
        self.mode = int(mode)
        self.num_rows = self.shape[mode]
        self.nnz = int(nnz)
        self.nmodes = len(self.shape)
        self.row_ptr = np.round(
            np.linspace(0.0, self.nnz, self.num_rows + 1)
        ).astype(np.int64)

    def input_modes(self):
        return [w for w in range(self.nmodes) if w != self.mode]


DENSITY_BINS = 8

# Segment-backend partition chooser (relative cost units of "one nnz of
# segmented-reduction work"): per-partition fixed overhead and per-output-row
# combine cost.  beta makes the optimal kappa finite (uniform loads would
# otherwise always want more partitions); gamma prices scheme 2's
# overlapping-output reduction against scheme 1's partition-local outputs.
SEG_PART_OVERHEAD = 16.0     # beta: nnz-equivalents per extra partition
SEG_COMBINE_COST = 1.0       # gamma: nnz-equivalents per combined output row


class _ObservedModeStats(_UniformModeStats):
    """Bucket-planning stand-in built from an OBSERVED row-density profile
    instead of the uniform prior: ``profile`` is the fraction of nnz mass
    in each of ``DENSITY_BINS`` equal row-count bins of the
    descending-sorted row loads (``serve.metrics`` accumulates it per
    bucket from real flushed batches).  Rows within a bin share its mass,
    so ``row_ptr`` reproduces the stream's skew at bin granularity and
    the cost model prices candidate tilings against what the bucket
    actually serves — the feedback loop that stops skewed streams being
    priced against a uniform distribution.

    Note the resulting ``slab_cap`` stays the data-independent worst-case
    bound (it is a function of the CHOSEN tiling only), so every bucket
    member still packs within the plan regardless of its true skew — the
    profile shifts the tiling *choice*, never the validity envelope."""

    def __init__(self, shape, mode, nnz, profile):
        super().__init__(shape, mode, nnz)
        masses = np.asarray(profile, dtype=np.float64)
        if masses.ndim != 1 or masses.size != DENSITY_BINS:
            raise ValueError(
                f"density profile must have {DENSITY_BINS} bins, got "
                f"{masses.shape}")
        masses = np.maximum(masses, 0.0)
        total = masses.sum()
        masses = (masses / total) if total > 0 else np.full(
            DENSITY_BINS, 1.0 / DENSITY_BINS)
        # Spread each bin's mass uniformly over its rows (descending-
        # sorted order — layouts relabel rows anyway, so the sorted
        # profile is the canonical representation).
        edges = np.round(np.linspace(0, self.num_rows,
                                     DENSITY_BINS + 1)).astype(np.int64)
        loads = np.zeros(self.num_rows, dtype=np.float64)
        for b in range(DENSITY_BINS):
            lo, hi = edges[b], edges[b + 1]
            if hi > lo:
                loads[lo:hi] = masses[b] * self.nnz / (hi - lo)
        row_ptr = np.zeros(self.num_rows + 1, dtype=np.float64)
        np.cumsum(loads, out=row_ptr[1:])
        self.row_ptr = np.round(row_ptr).astype(np.int64)


def density_profile(indices: np.ndarray, shape, mode: int,
                    bins: int = DENSITY_BINS) -> tuple[float, ...]:
    """Observed row-density profile of one tensor along ``mode``: fraction
    of nnz mass per equal-row-count bin of the DESCENDING-sorted row
    loads.  The serving metrics EWMA these per bucket class and feed them
    back into ``plan_bucket``."""
    num_rows = int(shape[mode])
    counts = np.sort(np.bincount(indices[:, mode],
                                 minlength=num_rows))[::-1]
    total = counts.sum()
    if total == 0:
        return tuple([1.0 / bins] * bins)
    edges = np.round(np.linspace(0, num_rows, bins + 1)).astype(np.int64)
    return tuple(
        float(counts[edges[b]:edges[b + 1]].sum() / total)
        for b in range(bins)
    )


def _lpt_makespan(loads: np.ndarray, kappa: int) -> float:
    """Max partition load of the greedy LPT assignment of descending
    ``loads`` onto ``kappa`` partitions — the same rule
    ``load_balance.partition_mode`` executes, priced here without
    building a layout."""
    if kappa <= 1:
        return float(loads.sum())
    import heapq

    heap = [0.0] * kappa
    for v in loads:
        heapq.heapreplace(heap, heap[0] + float(v))
    return float(max(heap))


def choose_segment_partition(stats, kappa_max: int) -> tuple[int, str]:
    """Pick (kappa, scheme) for the segment backend from a mode's row-load
    distribution (observed ``_ObservedModeStats`` or the uniform prior).

    Cost model, in units of one nnz of segmented-reduction work:

      scheme 'index' (1): LPT makespan over the row loads — a heavy row is
        atomic, so skew caps how far extra partitions help — plus
        ``SEG_PART_OVERHEAD`` per partition.
      scheme 'nnz' (2): perfectly balanced ``nnz/kappa`` plus
        ``SEG_COMBINE_COST`` per output row (the overlapping partial
        outputs must be combined) plus the same per-partition overhead.

    The argmin over kappa in {1, 2, 4, …, kappa_max} x both schemes is the
    bucket's segment partitioning.  With uniform loads the chosen kappa
    grows like sqrt(nnz / beta); a skewed profile plateaus the makespan at
    the heavy rows' mass, so the chooser settles on fewer partitions —
    which is exactly the observable the density feedback loop exists to
    move."""
    loads = np.sort(np.diff(stats.row_ptr))[::-1].astype(np.float64)
    nnz = float(loads.sum())
    best = (float("inf"), 1, "index")
    k = 1
    while k <= max(1, int(kappa_max)):
        over = SEG_PART_OVERHEAD * k
        c1 = _lpt_makespan(loads, k) + over
        c2 = (nnz / k
              + (SEG_COMBINE_COST * stats.num_rows if k > 1 else 0.0)
              + over)
        if c1 < best[0]:
            best = (c1, k, "index")
        if c2 < best[0]:
            best = (c2, k, "nnz")
        k *= 2
    _, k, scheme = best
    # A mode with fewer rows than partitions cannot index-partition
    # meaningfully; mirror the paper's threshold as a floor.
    if scheme == "index" and stats.num_rows < k:
        scheme = "nnz"
    return k, scheme


def _mode_plan(stats, mode: int, rank: int, factor_rows: int, nnz_cap: int,
               *, block_rows: int | None, tile: int | None,
               kappa: int = 1) -> ModePlan:
    if block_rows is None or tile is None:
        br, t = kops.auto_tiles(stats, rank=rank, factor_rows=factor_rows)
        block_rows = block_rows if block_rows is not None else br
        tile = tile if tile is not None else t
    num_inputs = len(stats.input_modes())
    rblk = kops.auto_rank_block(rank, block_rows, tile, factor_rows,
                                num_inputs) or rank
    nb = max(1, -(-stats.num_rows // block_rows))
    if isinstance(stats, _ObservedModeStats):
        # Observed density: the cost chooser decides the segment
        # partitioning (kappa is its ceiling).  Without a profile the
        # plan reproduces the caller's kappa and the adaptive scheme
        # rule untouched, so density-less paths stay bit-identical.
        seg_kappa, seg_scheme = choose_segment_partition(
            stats, max(int(kappa), DENSITY_BINS))
    else:
        seg_kappa, seg_scheme = max(1, int(kappa)), None
    return ModePlan(
        mode=mode,
        num_rows=stats.num_rows,
        block_rows=block_rows,
        tile=tile,
        rank_block=int(rblk),
        num_row_blocks=nb,
        slab_cap=slab_cap(stats.num_rows, nnz_cap, block_rows, tile),
        nnz_cap=int(nnz_cap),
        seg_kappa=seg_kappa,
        seg_scheme=seg_scheme,
    )


@functools.lru_cache(maxsize=None)
def plan_bucket(shape: tuple[int, ...], nnz_cap: int, rank: int,
                kappa: int = 1, *, block_rows: int | None = None,
                tile: int | None = None,
                density: tuple | None = None) -> PartitionPlan:
    """Static plan for a (shape, nnz_cap) bucket class — NO tensor data.

    The cost model prices each candidate tiling against a uniform nnz
    distribution by default (the only data-independent assumption
    available at bucket-planning time); ``density`` — a per-mode tuple of
    ``DENSITY_BINS`` observed row-mass fractions, fed back from
    ``serve.metrics`` — replaces the uniform prior with the stream's real
    skew.  Either way the resulting caps are valid for every member by
    construction (``slab_cap`` bounds any distribution).  Cached: all
    batches of a warm bucket class share one plan object (callers
    quantize the density profile so the cache stays small)."""
    shape = tuple(int(s) for s in shape)
    if density is not None and len(density) != len(shape):
        raise ValueError(
            f"density must carry one profile per mode ({len(shape)}), got "
            f"{len(density)}")
    modes = []
    for d in range(len(shape)):
        if density is not None and density[d] is not None:
            stats = _ObservedModeStats(shape, d, nnz_cap, density[d])
        else:
            stats = _UniformModeStats(shape, d, nnz_cap)
        factor_rows = sum(shape[w] for w in stats.input_modes())
        modes.append(_mode_plan(stats, d, rank, factor_rows, nnz_cap,
                                block_rows=block_rows, tile=tile,
                                kappa=kappa))
    plan = PartitionPlan(shape=shape, nnz_cap=int(nnz_cap), rank=int(rank),
                         kappa=int(kappa), modes=tuple(modes))
    # Inside the lru-cached body, so the event fires once per NOVEL
    # bucket class — a trace shows exactly which plans a stream induced
    # (with the chosen tile/rank-block/slab-cap per mode), never the
    # cache hits.
    obs_trace.event(
        "plan.build", cat="plan", shape=str(shape), nnz_cap=int(nnz_cap),
        rank=int(rank), kappa=int(kappa),
        observed_density=density is not None, plan=plan.describe(),
        tiles=[{"mode": m.mode, "block_rows": m.block_rows, "tile": m.tile,
                "rank_block": m.rank_block, "slab_cap": m.slab_cap}
               for m in plan.modes])
    return plan


def plan_layout(layout, rank: int, *, nnz_cap: int | None = None,
                block_rows: int | None = None,
                tile: int | None = None) -> ModePlan:
    """Plan one mode from a REAL layout (exact row distribution in the
    cost model).  Used by the sequential path; ``nnz_cap`` defaults to the
    layout's own nnz, i.e. no slab padding beyond the packing minimum."""
    factor_rows = sum(layout.shape[w] for w in layout.input_modes())
    cap = layout.nnz if nnz_cap is None else int(nnz_cap)
    return _mode_plan(layout, layout.mode, rank, factor_rows, cap,
                      block_rows=block_rows, tile=tile)


def plan_tensor(tensor, rank: int, kappa: int = 1, *,
                nnz_cap: int | None = None) -> PartitionPlan:
    """Per-tensor plan (bucket of one): quantizes nnz through the same
    ``quantize_nnz`` rule so a lone tensor and its bucket class agree."""
    cap = quantize_nnz(tensor.nnz) if nnz_cap is None else int(nnz_cap)
    return plan_bucket(tuple(int(s) for s in tensor.shape), cap, rank, kappa)


# ---------------------------------------------------------------------------
# Pod plans (the batch-axis shard_map path)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PodPlan:
    """How a dispatched batch of one bucket class spreads over a batch-axis
    device mesh: every device runs the SAME vmapped bucket executable on a
    ``B / num_devices`` sub-batch, so the whole pod shares one compiled
    pod block per (bucket, per-device B) class.

    ``dispatch_batch`` is the single sizing rule: the requested batch is
    rounded up to the scheduler's ``batch_quantum`` (the PR 6 executable-
    key stabilizer) and then to a mesh multiple, so ``shard_map`` slices
    the stacked arrays exactly — the padding slots are filled by
    repeating the last request (exact under vmap: independent lanes whose
    results are discarded)."""

    bucket: PartitionPlan
    num_devices: int
    batch_quantum: int = 1

    def dispatch_batch(self, batch: int) -> tuple[int, int]:
        """(total dispatched B, per-device sub-batch) for ``batch``
        queued requests."""
        if batch < 1:
            raise ValueError("batch must be >= 1")
        q = max(1, int(self.batch_quantum))
        tot = -(-int(batch) // q) * q
        n = max(1, int(self.num_devices))
        tot = -(-tot // n) * n
        return tot, tot // n


def plan_pod(shape: tuple[int, ...], nnz_cap: int, rank: int,
             kappa: int = 1, *, num_devices: int, batch_quantum: int = 1,
             density: tuple | None = None) -> PodPlan:
    """Pod plan for a (shape, nnz_cap) bucket class: the bucket's static
    ``plan_bucket`` plus the batch-axis sharding arithmetic."""
    return PodPlan(
        bucket=plan_bucket(tuple(int(s) for s in shape), int(nnz_cap),
                           int(rank), int(kappa), density=density),
        num_devices=int(num_devices),
        batch_quantum=int(batch_quantum),
    )


def pod_lane_order(nnz: list[int], num_devices: int) -> list[int]:
    """Load-aware lane placement for the pod's contiguous shard_map
    split: ``order[lane] = original request index`` such that device
    ``p`` executes lanes ``order[p*per_dev:(p+1)*per_dev]``.

    ``shard_map`` slices the stacked batch axis into contiguous
    per-device blocks, so a stream whose heavy requests cluster lands
    them all on one device.  Requests are dealt longest-processing-time
    first: descending by nnz (index-stable), each to the least-loaded
    device that still has a free lane.  The result is guaranteed no
    worse-balanced than the arrival order — if the greedy deal ever
    loses to it (possible on adversarial draws), the identity order is
    returned instead.  Identity also when the batch is not an exact
    mesh multiple (the engine pads first) or the mesh is trivial.
    """
    B = len(nnz)
    n = int(num_devices)
    identity = list(range(B))
    if n <= 1 or B == 0 or B % n:
        return identity
    per_dev = B // n
    ranked = sorted(identity, key=lambda i: (-int(nnz[i]), i))
    assign: list[list[int]] = [[] for _ in range(n)]
    loads = [0] * n
    for i in ranked:
        d = min((p for p in range(n) if len(assign[p]) < per_dev),
                key=lambda p: (loads[p], p))
        assign[d].append(i)
        loads[d] += int(nnz[i])
    order = [i for dev in assign for i in dev]
    if pod_imbalance(nnz, n, order) > pod_imbalance(nnz, n):
        return identity
    return order


def pod_device_nnz(nnz: list[int], num_devices: int,
                   order: list[int] | None = None) -> list[int]:
    """Per-device total nnz under the contiguous split of ``order``
    (identity when ``order`` is None) — the load the dispatch span and
    ``BENCH_pod.json`` record."""
    B = len(nnz)
    n = max(1, int(num_devices))
    lanes = list(range(B)) if order is None else list(order)
    per_dev = max(1, B // n)
    return [int(sum(nnz[i] for i in lanes[p * per_dev:(p + 1) * per_dev]))
            for p in range(n)]


def pod_imbalance(nnz: list[int], num_devices: int,
                  order: list[int] | None = None) -> float:
    """Max/mean per-device nnz factor (1.0 = perfectly balanced)."""
    loads = pod_device_nnz(nnz, num_devices, order)
    mean = sum(loads) / len(loads)
    return max(loads) / mean if mean > 0 else 1.0


# ---------------------------------------------------------------------------
# Per-device shards (the shard_map path)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DeviceShards:
    """Rectangular per-device arrays for one mode (leading dim = kappa).

    Rows are GLOBAL relabeled rows: every device produces a partial
    (I_d, R) output and ``psum`` combines them — scheme 1's partials have
    disjoint row support (the psum reduces to a concatenation, though it
    still pays full-array collective bandwidth — see the distributed
    module docstring), scheme 2's overlap (the analogue of the paper's
    global atomics).  Padding entries carry value 0 on row ``I_d - 1`` so
    each shard's rows stay sorted."""

    scheme: Scheme
    mode: int
    num_rows: int              # I_d
    nnz_per_dev: int           # padded nnz per device (static)
    idx: np.ndarray            # (kappa, nnz_per_dev, W) int32
    rows: np.ndarray           # (kappa, nnz_per_dev) int32 global relabeled
    vals: np.ndarray           # (kappa, nnz_per_dev) f32 (0 on padding)
    row_perm: np.ndarray       # (kappa, I_d) int32 (replicated copies)
    input_modes: tuple[int, ...]
    # Valued/weighted shards (the distributed masked path): the FULL
    # canonical coordinates of each shard entry — so a device can evaluate
    # the CP model (and hence the per-sweep residual) locally at its own
    # shard's coordinates from the replicated factors — plus per-entry
    # observation weights.  Padding entries carry weight 0, so they
    # contribute exactly +0.0 to the residual MTTKRP whatever coordinate
    # they alias (the general weight-0 mechanism).  None for value-baked
    # methods, which need neither.
    idx_full: np.ndarray | None = None   # (kappa, nnz_per_dev, N) int32
    ew: np.ndarray | None = None         # (kappa, nnz_per_dev) f32
    # Gather-collective arrays (scheme 1 only): each device's owned
    # RELABELED rows padded to a common cap, and the ORIGINAL row each
    # (device, slot) lands on — padding slots point at the dummy row I_d,
    # which the consumer slices off.  A scheme-1 partial output has
    # support only on its device's owned rows, so all-gathering just the
    # (rows_cap, R) owned slices and scattering through ``gather_map``
    # reconstructs the full factor while moving kappa*rows_cap*R floats
    # instead of the psum's kappa*I_d*R — saving ~(kappa-1)/kappa of the
    # collective payload.  None for scheme 2 (partials overlap; the psum
    # genuinely reduces).
    own_rows: np.ndarray | None = None   # (kappa, rows_cap) int32 relabeled
    gather_map: np.ndarray | None = None  # (kappa, rows_cap) int32 original

    @property
    def rows_cap(self) -> int:
        """Per-device owned-row cap of the gather collective (0 when the
        scheme does not support it)."""
        return 0 if self.own_rows is None else int(self.own_rows.shape[1])


def build_device_shards(layout, *, quantum: int = DEVICE_SHARD_QUANTUM,
                        weights: np.ndarray | None = None,
                        with_full_indices: bool = False) -> DeviceShards:
    """Slice a mode layout into kappa rectangular device shards.

    The per-device nnz cap is the max partition load rounded up to
    ``quantum`` — a static shape, so same-class tensors reuse the same
    shard_map executable.

    ``weights`` (canonical COO order) / ``with_full_indices`` populate the
    valued-shard fields consumed by the distributed masked path: each
    device then carries its entries' observation weights (0 on padding)
    and full coordinates alongside the structural arrays."""
    kappa = layout.kappa
    in_modes = layout.input_modes()
    off = layout.part_offsets
    max_nnz = int(np.diff(off).max()) if layout.nnz else 1
    cap = max(-(-max(max_nnz, 1) // quantum) * quantum, quantum)
    W = len(in_modes)
    idx = np.zeros((kappa, cap, W), np.int32)
    vals = np.zeros((kappa, cap), np.float32)
    # Padding rows sit at I_d - 1 (>= every real row in the slice), keeping
    # each shard sorted so the segmented reduction's sortedness hint holds.
    rows = np.full((kappa, cap), layout.num_rows - 1, np.int32)
    idx_full = (np.zeros((kappa, cap, layout.nmodes), np.int32)
                if with_full_indices else None)
    ew = np.zeros((kappa, cap), np.float32) if weights is not None else None
    w_lay = (np.asarray(weights, np.float32)[layout.perm]
             if weights is not None else None)
    for p in range(kappa):
        s, e = int(off[p]), int(off[p + 1])
        n = e - s
        idx[p, :n] = layout.indices[s:e][:, in_modes]
        vals[p, :n] = layout.values[s:e]
        rows[p, :n] = layout.rows[s:e]
        if idx_full is not None:
            idx_full[p, :n] = layout.indices[s:e]
        if ew is not None:
            ew[p, :n] = w_lay[s:e]
    row_perm = np.broadcast_to(
        layout.row_perm, (kappa,) + layout.row_perm.shape).copy()
    own_rows = gather_map = None
    if layout.scheme == Scheme.INDEX_PARTITION:
        # Scheme 1 partitions own disjoint contiguous relabeled ranges
        # [row_lo, row_hi): record each device's owned rows (padded to a
        # common cap by repeating an owned row — harmless, the padding
        # destination is the dummy row) and the ORIGINAL row each slot
        # scatters to (padding -> I_d, sliced off by the consumer).
        counts = (layout.row_hi - layout.row_lo).astype(np.int64)
        rcap = max(int(counts.max()) if kappa else 1, 1)
        own_rows = np.zeros((kappa, rcap), np.int32)
        gather_map = np.full((kappa, rcap), layout.num_rows, np.int32)
        for p in range(kappa):
            lo, hi = int(layout.row_lo[p]), int(layout.row_hi[p])
            n = hi - lo
            own_rows[p, :n] = np.arange(lo, hi, dtype=np.int32)
            own_rows[p, n:] = lo if n else 0
            gather_map[p, :n] = layout.row_perm[lo:hi]
    return DeviceShards(
        scheme=layout.scheme,
        mode=layout.mode,
        num_rows=layout.num_rows,
        nnz_per_dev=cap,
        idx=idx,
        rows=rows,
        vals=vals,
        row_perm=row_perm,
        input_modes=tuple(in_modes),
        idx_full=idx_full,
        ew=ew,
        own_rows=own_rows,
        gather_map=gather_map,
    )


def shard_fit_data(tensor, kappa: int, *,
                   quantum: int = DEVICE_SHARD_QUANTUM,
                   weights: np.ndarray | None = None):
    """Split the canonical COO across devices for the on-device sparse fit
    (inner product psums; zero padding contributes +0.0 exactly).

    With ``weights`` (per-entry observation weights, canonical order) the
    result is the WEIGHTED fit contract ``(idx, vals, ew, norm_sq)``:
    padding slots get weight 0, and ``norm_sq`` is the weighted
    ``sum_e w_e x_e^2`` (replicated per device) so every front door
    reports the same weighted fit."""
    nnz = tensor.nnz
    per = max(-(-max(-(-nnz // kappa), 1) // quantum) * quantum, quantum)
    idx = np.zeros((kappa, per, tensor.nmodes), np.int32)
    vals = np.zeros((kappa, per), np.float32)
    ew = np.zeros((kappa, per), np.float32) if weights is not None else None
    flat_v = tensor.values.astype(np.float32)
    flat_w = (np.asarray(weights, np.float32)
              if weights is not None else None)
    for p in range(kappa):
        s = p * per
        e = min(nnz, s + per)
        if e > s:
            idx[p, : e - s] = tensor.indices[s:e]
            vals[p, : e - s] = flat_v[s:e]
            if ew is not None:
                ew[p, : e - s] = flat_w[s:e]
    if ew is not None:
        norm_sq = np.broadcast_to(
            np.float32((flat_w * flat_v) @ flat_v), (kappa,)).copy()
        return idx, vals, ew, norm_sq
    norm_sq = np.broadcast_to(
        np.float32(tensor.norm() ** 2), (kappa,)).copy()
    return idx, vals, norm_sq
