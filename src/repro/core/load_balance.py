"""Adaptive load balancing (paper §III-B).

Two schemes, chosen adaptively per output mode against kappa partitions
(GPU SMs in the paper; devices x kernel grid blocks here):

  Scheme 1 (I_d >= kappa): distribute output-mode *indices* among
    partitions so each partition owns a disjoint set of output rows.
    Vertices (output indices) are ordered by hypergraph degree (number of
    incident nonzeros) and assigned greedily to the least-loaded partition
    (LPT — Graham's bound: max load <= 4/3 * optimal), with a cyclic
    variant matching the paper's description exactly.  No cross-partition
    output updates are needed (the TPU analogue of "local atomics only").

  Scheme 2 (I_d < kappa): distribute the *nonzeros* equally: sort
    hyperedges by output vertex id, split into kappa equal chunks.  Output
    rows are shared across partitions, so results must be combined (the
    TPU analogue of "global atomics" is a psum of the small dense output).

Partitioning is pure preprocessing on host numpy — it happens once per
tensor per mode and is amortized over all ALS iterations, identically to
the paper's preprocessing cost.
"""
from __future__ import annotations

import dataclasses
import enum

import numpy as np

from .coo import SparseTensor


class Scheme(enum.Enum):
    INDEX_PARTITION = 1  # paper's Load Balancing Scheme 1
    NNZ_PARTITION = 2    # paper's Load Balancing Scheme 2


@dataclasses.dataclass(frozen=True)
class Partitioning:
    """Result of partitioning one output mode across kappa partitions.

    Attributes:
      scheme: which load-balancing scheme was used.
      mode: the output mode d.
      kappa: number of partitions.
      perm: (nnz,) int64 permutation — ordering of the original COO nnz so
        partition p's nonzeros are the contiguous slice
        ``perm[offsets[p]:offsets[p+1]]``.
      offsets: (kappa+1,) int64 nnz boundaries per partition.
      vertex_part: (I_d,) int32 partition id per output index (scheme 1) or
        None (scheme 2 shares all vertices).
      row_ranges: (kappa, 2) int32 [lo, hi) of *relabeled* output rows per
        partition under scheme 1 (see layout.relabel), else None.
    """

    scheme: Scheme
    mode: int
    kappa: int
    perm: np.ndarray
    offsets: np.ndarray
    vertex_part: np.ndarray | None

    @property
    def loads(self) -> np.ndarray:
        return np.diff(self.offsets)

    def imbalance(self) -> float:
        """max partition load / mean load (1.0 == perfect)."""
        loads = self.loads
        mean = loads.mean() if len(loads) else 0.0
        return float(loads.max() / mean) if mean else 1.0


def choose_scheme(num_indices: int, kappa: int) -> Scheme:
    """The paper's adaptive rule: indices >= kappa -> scheme 1 else scheme 2."""
    return Scheme.INDEX_PARTITION if num_indices >= kappa else Scheme.NNZ_PARTITION


# -- beyond-paper: cost-model-driven scheme selection ------------------------
#
# The paper's threshold rule mispicks near the I_d ~ kappa boundary: a mode
# with I_d = 100 on kappa = 82 partitions is "scheme 1" by the rule, but its
# vertex partitioning is inherently lumpy (1-2 vertices per partition ->
# makespan ~2x mean), while scheme 2's perfectly balanced nnz split + one
# small reduction is cheaper.  Pricing BOTH schemes from the actual
# partitioning statistics and picking the argmin fixes those cells
# (EXPERIMENTS.md §Perf, fig4-cost rows).


@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    """Update-cost model; defaults are RTX-3090-class (paper's platform).
    For the TPU/shard_map path, ``atomic_tput`` prices the psum instead."""

    bw: float = 936.2e9           # global-memory B/s
    atomic_tput: float = 1.2e11   # shared-output update ops/s
    local_factor: float = 0.1     # partition-private update discount
    rank: int = 32
    float_bytes: int = 4


def scheme_cost(
    tensor: SparseTensor, mode: int, kappa: int, scheme: Scheme,
    *, profile: DeviceProfile = DeviceProfile(), assignment: str = "greedy",
) -> float:
    """Modeled execution time of one MTTKRP along ``mode`` under ``scheme``."""
    part = partition_mode(tensor, mode, kappa, scheme=scheme,
                          assignment=assignment)
    N, nnz = tensor.nmodes, tensor.nnz
    R, F = profile.rank, profile.float_bytes
    bytes_moved = nnz * (4 * N + 4) + nnz * (N - 1) * R * F \
        + tensor.shape[mode] * R * F
    traffic = bytes_moved / profile.bw * part.imbalance()
    updates = nnz * R / profile.atomic_tput
    if scheme == Scheme.INDEX_PARTITION:
        updates *= profile.local_factor
    return traffic + updates


def choose_scheme_cost_based(
    tensor: SparseTensor, mode: int, kappa: int,
    *, profile: DeviceProfile = DeviceProfile(), assignment: str = "greedy",
) -> Scheme:
    c1 = scheme_cost(tensor, mode, kappa, Scheme.INDEX_PARTITION,
                     profile=profile, assignment=assignment)
    c2 = scheme_cost(tensor, mode, kappa, Scheme.NNZ_PARTITION,
                     profile=profile, assignment=assignment)
    return Scheme.INDEX_PARTITION if c1 <= c2 else Scheme.NNZ_PARTITION


def partition_mode(
    tensor: SparseTensor,
    mode: int,
    kappa: int,
    *,
    scheme: Scheme | None = None,
    assignment: str = "greedy",
) -> Partitioning:
    """Partition the nonzeros of ``tensor`` for output ``mode`` into kappa parts.

    assignment: 'greedy' (LPT least-loaded, 4/3 bound) or 'cyclic' (paper's
      literal round-robin over the degree-ordered vertex list).
    """
    if kappa < 1:
        raise ValueError("kappa must be >= 1")
    I_d = tensor.shape[mode]
    if scheme is None:
        scheme = choose_scheme(I_d, kappa)
    idx_d = tensor.indices[:, mode].astype(np.int64)

    if scheme == Scheme.INDEX_PARTITION:
        degrees = np.bincount(idx_d, minlength=I_d)
        order = np.argsort(-degrees, kind="stable")  # I_{d-ordered}: heavy first
        vertex_part = np.empty(I_d, dtype=np.int32)
        if assignment == "cyclic":
            vertex_part[order] = np.arange(I_d, dtype=np.int32) % kappa
        elif assignment == "greedy":
            # LPT: heaviest-first onto least-loaded partition via a heap.
            import heapq

            heap = [(0, p) for p in range(kappa)]
            heapq.heapify(heap)
            for v in order:
                load, p = heapq.heappop(heap)
                vertex_part[v] = p
                heapq.heappush(heap, (load + int(degrees[v]), p))
        else:
            raise ValueError(f"unknown assignment {assignment!r}")
        nnz_part = vertex_part[idx_d]
        # Order nnz by (partition, output row) so each partition's slice is
        # already row-sorted -> segmented reduction needs no further sort.
        perm = np.lexsort((idx_d, nnz_part))
        counts = np.bincount(nnz_part, minlength=kappa)
        offsets = np.zeros(kappa + 1, dtype=np.int64)
        np.cumsum(counts, out=offsets[1:])
        return Partitioning(scheme, mode, kappa, perm, offsets, vertex_part)

    # Scheme 2: order hyperedges by output vertex id, split equally.
    perm = np.argsort(idx_d, kind="stable")
    nnz = tensor.nnz
    base, rem = divmod(nnz, kappa)
    counts = np.full(kappa, base, dtype=np.int64)
    counts[:rem] += 1
    offsets = np.zeros(kappa + 1, dtype=np.int64)
    np.cumsum(counts, out=offsets[1:])
    return Partitioning(scheme, mode, kappa, perm, offsets, None)


def balance_bound_holds(part: Partitioning, tensor: SparseTensor) -> bool:
    """Check Graham's 4/3 bound for greedy scheme-1 partitionings.

    The guarantee is max_load <= opt * 4/3 where opt >= max(mean_load,
    max_single_vertex_degree) — the latter because a vertex is atomic.
    """
    loads = part.loads.astype(np.float64)
    if part.scheme == Scheme.NNZ_PARTITION:
        return bool(loads.max() <= np.ceil(tensor.nnz / part.kappa))
    degrees = tensor.mode_degrees(part.mode).astype(np.float64)
    opt_lb = max(loads.sum() / part.kappa, degrees.max() if len(degrees) else 0.0)
    return bool(loads.max() <= (4.0 / 3.0) * opt_lb + 1e-9)
