"""CPD-ALS (Canonical Polyadic Decomposition via Alternating Least Squares).

The driver the paper's kernel exists to serve: for each mode d,
  M_d   = MTTKRP(X, factors, d)                      (the bottleneck)
  V     = hadamard_{w != d} (Y_w^T Y_w)              (R x R grams)
  Y_d   = M_d @ pinv(V)
  lambda= column norms; Y_d normalized
iterated until the fit converges.  Fit is computed sparsely:
  ||X - X_hat||^2 = ||X||^2 - 2<X, X_hat> + ||X_hat||^2
with <X, X_hat> = sum over nnz of X_hat at the nnz coordinates and
||X_hat||^2 = 1^T (hadamard of grams weighted by lambda) 1 — no dense
reconstruction ever materializes.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from ..obs import clock as obs_clock
from .coo import SparseTensor
from .mttkrp import MTTKRPPlan, make_plan, mttkrp


@dataclasses.dataclass
class CPDResult:
    factors: list[np.ndarray]     # column-normalized
    weights: np.ndarray           # (R,) lambda
    fits: list[float]             # fit per iteration (1 - relerr)
    iters: int
    mttkrp_seconds: float         # total time in the bottleneck kernel
    total_seconds: float
    host_syncs: int = 0           # device->host synchronizations performed
    engine: str = "host"          # which ALS engine produced this result
    method: str = "cp"            # which decomposition method produced it

    def reconstruct_at(self, indices: np.ndarray) -> np.ndarray:
        acc = np.ones((indices.shape[0], len(self.weights)))
        for d, F in enumerate(self.factors):
            acc = acc * F[indices[:, d]]
        return acc @ self.weights


def _innerprod_sparse(tensor: SparseTensor, factors, weights) -> float:
    acc = np.ones((tensor.nnz, len(weights)))
    for d, F in enumerate(factors):
        acc = acc * np.asarray(F)[tensor.indices[:, d]]
    return float(tensor.values @ (acc @ np.asarray(weights)))


def _model_norm_sq(factors, weights) -> float:
    R = len(weights)
    V = np.ones((R, R))
    for F in factors:
        F = np.asarray(F, dtype=np.float64)
        V = V * (F.T @ F)
    w = np.asarray(weights, dtype=np.float64)
    return float(w @ V @ w)


def cpd_als(
    tensor: SparseTensor,
    rank: int,
    *,
    plan: MTTKRPPlan | None = None,
    kappa: int = 1,
    n_iters: int = 25,
    tol: float = 1e-5,
    seed: int = 0,
    backend: str = "segment",
    engine: str = "fused",
    check_every: int = 1,
    method: str = "cp",
    init_state: tuple | None = None,
    weights: np.ndarray | None = None,
    mttkrp_fn: Callable | None = None,
    verbose: bool = False,
) -> CPDResult:
    """Run CPD-ALS.

    ``engine="fused"`` (default) delegates to the device-resident engine in
    ``als_device`` — the whole N-mode sweep is one jitted computation and
    the host syncs only every ``check_every`` iterations.  ``engine="host"``
    keeps the original per-mode host loop (useful for benchmarking the
    traffic the fused engine removes).  A custom ``mttkrp_fn(plan, factors,
    mode)`` forces the host loop (benchmarks time alternative formats
    through it).

    ``method`` selects the decomposition method from the ``repro.methods``
    registry ('cp', 'nncp', 'masked', …) — every method runs on the fused
    engine's shared MTTKRP substrate.  ``init_state`` (see
    ``als_device.state_from_factors``) warm-starts from existing factors
    (the streaming path).  ``weights`` — per-entry observation weights in
    canonical COO order for weighted-fit methods ('masked'): fractional
    confidences, weight 0 = entry treated as unobserved (exactly — a
    weight-0 entry yields factors bit-identical to omitting it)."""
    if engine not in ("fused", "host"):
        raise ValueError(f"unknown engine {engine!r}")
    # A custom mttkrp_fn forces the host loop (below), which is plain-CP
    # only — refuse rather than silently dropping method/init_state.
    if (engine == "host" or mttkrp_fn is not None) and (
            method != "cp" or init_state is not None or weights is not None):
        raise ValueError(
            "engine='host' (and the custom-mttkrp_fn host loop) supports "
            "only method='cp' with random init; methods, warm starts, and "
            "entry weights run on the fused engine")
    if engine == "fused" and mttkrp_fn is None:
        from .als_device import cpd_als_fused

        return cpd_als_fused(
            tensor, rank, plan=plan, kappa=kappa, n_iters=n_iters, tol=tol,
            seed=seed, backend=backend, check_every=check_every,
            method=method, init_state=init_state, weights=weights,
            verbose=verbose,
        )
    t_start = obs_clock.now()
    rng = np.random.default_rng(seed)
    N = tensor.nmodes
    if plan is None:
        plan = make_plan(tensor, kappa)
    factors = [
        jnp.asarray(rng.standard_normal((I, rank)).astype(np.float32))
        for I in tensor.shape
    ]
    weights = np.ones(rank, dtype=np.float64)
    norm_x_sq = tensor.norm() ** 2
    fits: list[float] = []
    mttkrp_t = 0.0
    host_syncs = 0
    last_fit = -np.inf

    grams = [np.asarray(F, np.float64).T @ np.asarray(F, np.float64) for F in factors]

    it = 0
    for it in range(1, n_iters + 1):
        for d in range(N):
            t0 = obs_clock.now()
            if mttkrp_fn is not None:
                M = mttkrp_fn(plan, factors, d)
            else:
                M = mttkrp(plan, factors, d, backend=backend)
            M = np.asarray(jax.block_until_ready(M), dtype=np.float64)
            host_syncs += 1
            mttkrp_t += obs_clock.now() - t0

            V = np.ones((rank, rank))
            for w in range(N):
                if w != d:
                    V = V * grams[w]
            # Ridge-regularized solve (V can be near-singular for skewed
            # real-world tensors; plain pinv SVD may fail to converge).
            ridge = 1e-10 * max(np.trace(V) / rank, 1.0)
            Vr = V + ridge * np.eye(rank)
            try:
                Yd = np.linalg.solve(Vr.T, M.T).T
            except np.linalg.LinAlgError:
                Yd = M @ np.linalg.pinv(Vr, rcond=1e-10)
            lam = np.linalg.norm(Yd, axis=0)
            lam = np.where(lam > 1e-12, lam, 1.0)
            Yd = Yd / lam
            weights = lam
            factors[d] = jnp.asarray(Yd.astype(np.float32))
            grams[d] = Yd.T @ Yd

        ip = _innerprod_sparse(tensor, factors, weights)
        model_sq = _model_norm_sq(factors, weights)
        host_syncs += N            # factor pulls for the sparse fit
        resid_sq = max(norm_x_sq - 2.0 * ip + model_sq, 0.0)
        fit = 1.0 - np.sqrt(resid_sq) / max(np.sqrt(norm_x_sq), 1e-12)
        fits.append(float(fit))
        if verbose:
            print(f"  ALS iter {it:3d}: fit={fit:.6f}")
        if abs(fit - last_fit) < tol:
            break
        last_fit = fit

    return CPDResult(
        factors=[np.asarray(F) for F in factors],
        weights=np.asarray(weights),
        fits=fits,
        iters=it,
        mttkrp_seconds=mttkrp_t,
        total_seconds=obs_clock.now() - t_start,
        host_syncs=host_syncs,
        engine="host",
    )
