"""COO sparse-tensor container + synthetic FROSTT-like generators.

The paper (Wijeratne et al., 2025) stores the input tensor in COOrdinate
format: each nonzero is a tuple <(c_0..c_{N-1}), val>.  ``SparseTensor``
is the host-side container; mode-specific layouts are built from it by
``repro.core.layout``.

All index arrays are int32 (the paper's "small tensor" regime guarantees
every mode dimension < 2^31) and values default to float32, matching the
paper's fp32 evaluation.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class SparseTensor:
    """An N-mode sparse tensor in COO format (host-resident numpy).

    Attributes:
      indices: (nnz, N) int32 — per-mode coordinates of each nonzero.
      values:  (nnz,) float — nonzero values.
      shape:   tuple of N ints — dense dimensions I_0..I_{N-1}.
    """

    indices: np.ndarray
    values: np.ndarray
    shape: tuple[int, ...]

    def __post_init__(self):
        if self.indices.ndim != 2:
            raise ValueError(f"indices must be (nnz, N), got {self.indices.shape}")
        if self.values.ndim != 1 or self.values.shape[0] != self.indices.shape[0]:
            raise ValueError("values must be (nnz,) aligned with indices")
        if self.indices.shape[1] != len(self.shape):
            raise ValueError(
                f"indices has {self.indices.shape[1]} modes, shape has {len(self.shape)}"
            )
        for d, I in enumerate(self.shape):
            if self.nnz and int(self.indices[:, d].max()) >= I:
                raise ValueError(f"mode-{d} index out of range (I_{d}={I})")

    @property
    def nnz(self) -> int:
        return int(self.indices.shape[0])

    @property
    def nmodes(self) -> int:
        return len(self.shape)

    @property
    def density(self) -> float:
        dense = float(np.prod([float(s) for s in self.shape]))
        return self.nnz / dense if dense else 0.0

    def mode_degrees(self, d: int) -> np.ndarray:
        """Hyperedge count incident on each mode-d vertex (hypergraph degree)."""
        return np.bincount(self.indices[:, d], minlength=self.shape[d]).astype(np.int64)

    def to_dense(self) -> np.ndarray:
        """Densify — only for tiny test tensors."""
        if float(np.prod([float(s) for s in self.shape])) > 5e7:
            raise ValueError("refusing to densify a large tensor")
        out = np.zeros(self.shape, dtype=self.values.dtype)
        # np.add.at handles duplicate coordinates by accumulation, matching
        # the semantics of MTTKRP over a COO list with possible duplicates.
        np.add.at(out, tuple(self.indices.T), self.values)
        return out

    def matricize(self, d: int) -> np.ndarray:
        """Mode-d matricization X_(d) as a dense (I_d, prod(I_w, w!=d)) matrix.

        Column ordering follows Kolda & Bader: the mode-w indices (w != d)
        sweep with the *lowest* remaining mode varying fastest.
        """
        dense = self.to_dense()
        order = [d] + [w for w in range(self.nmodes) if w != d]
        return np.transpose(dense, order).reshape(self.shape[d], -1)

    def deduplicate(self) -> "SparseTensor":
        """Sum values at duplicate coordinates (canonical COO)."""
        keys = _linearize(self.indices, self.shape)
        order = np.argsort(keys, kind="stable")
        keys_s = keys[order]
        uniq_mask = np.empty(len(keys_s), dtype=bool)
        uniq_mask[:1] = True
        uniq_mask[1:] = keys_s[1:] != keys_s[:-1]
        group = np.cumsum(uniq_mask) - 1
        vals = np.zeros(int(group[-1]) + 1 if len(group) else 0, dtype=self.values.dtype)
        np.add.at(vals, group, self.values[order])
        idx = self.indices[order][uniq_mask]
        return SparseTensor(idx, vals, self.shape)

    def permuted(self, perm: np.ndarray) -> "SparseTensor":
        return SparseTensor(self.indices[perm], self.values[perm], self.shape)

    def norm(self) -> float:
        return float(np.linalg.norm(self.values))


def _linearize(indices: np.ndarray, shape: Sequence[int]) -> np.ndarray:
    """Row-major linearized int64 keys for COO coordinates."""
    key = np.zeros(indices.shape[0], dtype=np.int64)
    for d, I in enumerate(shape):
        key = key * int(I) + indices[:, d].astype(np.int64)
    return key


# ---------------------------------------------------------------------------
# Synthetic generators
# ---------------------------------------------------------------------------


def random_sparse(
    shape: Sequence[int],
    nnz: int,
    *,
    seed: int = 0,
    distribution: str = "uniform",
    zipf_a: float = 1.3,
    dtype=np.float32,
) -> SparseTensor:
    """Random sparse tensor with `nnz` unique coordinates.

    distribution:
      'uniform'  — coordinates uniform per mode (unstructured).
      'zipf'     — per-mode Zipf-distributed indices (power-law hot rows),
                   which is what real FROSTT tensors look like and what makes
                   load balancing non-trivial (paper §III-B).
    """
    rng = np.random.default_rng(seed)
    shape = tuple(int(s) for s in shape)
    n = len(shape)
    # Oversample then dedupe to reach the requested unique nnz.
    want = nnz
    idx_parts = []
    attempts = 0
    seen: np.ndarray | None = None
    while True:
        m = max(int(want * 1.3) + 16, 64)
        cols = []
        for d, I in enumerate(shape):
            if distribution == "uniform" or I <= 2:
                c = rng.integers(0, I, size=m, dtype=np.int64)
            elif distribution == "zipf":
                z = rng.zipf(zipf_a, size=m).astype(np.int64) - 1
                c = z % I
            elif distribution == "powerlaw":
                # fiber-length skew like real FROSTT tensors: degree of the
                # r-th hottest index ~ (r+1)^-0.5 (hottest ~10-45x mean at
                # I=2048 but below nnz/kappa, matching real FROSTT fiber skew)
                p = (np.arange(I, dtype=np.float64) + 1.0) ** -0.5
                p /= p.sum()
                c = rng.choice(I, size=m, p=p)
            else:
                raise ValueError(f"unknown distribution {distribution!r}")
            cols.append(c)
        cand = np.stack(cols, axis=1)
        keys = _linearize(cand.astype(np.int32), shape)
        if seen is None:
            pool_keys = keys
            pool = cand
        else:
            pool_keys = np.concatenate([seen_keys, keys])  # noqa: F821
            pool = np.concatenate([seen, cand], axis=0)
        _, first = np.unique(pool_keys, return_index=True)
        first.sort()
        pool = pool[first]
        pool_keys = pool_keys[first]
        if len(pool) >= nnz or attempts > 20:
            idx = pool[:nnz]
            break
        seen, seen_keys = pool, pool_keys
        want = nnz - len(pool)
        attempts += 1
    vals = rng.standard_normal(len(idx)).astype(dtype)
    # Avoid exact zeros so nnz stays meaningful.
    vals = np.where(np.abs(vals) < 1e-3, 1e-3, vals).astype(dtype)
    order = np.lexsort(tuple(idx[:, d] for d in reversed(range(n))))
    return SparseTensor(idx[order].astype(np.int32), vals[order], shape)


def low_rank_sparse(
    shape: Sequence[int],
    nnz: int,
    rank: int,
    *,
    seed: int = 0,
    noise: float = 0.0,
    dtype=np.float32,
) -> tuple[SparseTensor, list[np.ndarray]]:
    """Sparse sampling of an exactly-rank-R CP tensor (for CPD recovery tests).

    Returns (tensor, true_factors). Values are the CP model evaluated at the
    sampled coordinates plus optional Gaussian noise.
    """
    rng = np.random.default_rng(seed)
    shape = tuple(int(s) for s in shape)
    factors = [rng.standard_normal((I, rank)).astype(dtype) for I in shape]
    base = random_sparse(shape, nnz, seed=seed + 1, distribution="uniform", dtype=dtype)
    vals = np.ones(base.nnz, dtype=np.float64)
    acc = np.ones((base.nnz, rank), dtype=np.float64)
    for d, F in enumerate(factors):
        acc *= F[base.indices[:, d]].astype(np.float64)
    vals = acc.sum(axis=1)
    if noise:
        vals = vals + noise * rng.standard_normal(base.nnz)
    return SparseTensor(base.indices, vals.astype(dtype), shape), factors


# FROSTT Table III shapes.  ``scale`` shrinks nnz (and mode sizes beyond a
# cap) so CPU CI remains fast while preserving the shape *ratios* that drive
# the adaptive load-balancer decisions (e.g. Chicago/Uber/Nips have modes
# with I_d < kappa, Enron/Nell have I_d >> kappa).
FROSTT_SHAPES: dict[str, tuple[tuple[int, ...], int]] = {
    "chicago": ((6_186, 24, 77, 32), 5_330_673),
    "enron": ((6_066, 5_699, 244_268, 1_176), 54_202_099),
    "nell-1": ((2_902_330, 2_143_368, 25_495_389), 143_599_552),
    "nips": ((2_482, 2_862, 14_036, 17), 3_101_609),
    "uber": ((183, 24, 1_140, 1_717), 3_309_490),
    "vast": ((165_427, 11_374, 2, 100, 89), 26_021_945),
}


def frostt_like(name: str, *, scale: float = 1.0, seed: int = 0) -> SparseTensor:
    """Synthetic stand-in for a FROSTT tensor (offline container: no download).

    Keeps the exact mode count and dimension *ratios* of Table III.  With
    ``scale < 1`` the nnz count shrinks by ``scale`` and any mode dimension
    larger than ``nnz_scaled`` is clamped (a mode can't have more useful
    indices than nonzeros).  Zipf-distributed indices reproduce the skewed
    fiber-length histograms of the real datasets.
    """
    key = name.lower()
    if key not in FROSTT_SHAPES:
        raise KeyError(f"unknown dataset {name!r}; options: {sorted(FROSTT_SHAPES)}")
    shape, nnz = FROSTT_SHAPES[key]
    nnz_s = max(int(nnz * scale), 128)
    # Small mode dims are kept EXACT — they decide which load-balancing
    # scheme the adaptive rule picks (the paper's central structure);
    # only large dims shrink, and never below what nnz can populate.
    shape_s = tuple(
        I if I <= 2048 else min(max(2048, int(I * scale * 4)), nnz_s)
        for I in shape
    )
    return random_sparse(shape_s, nnz_s, seed=seed, distribution="powerlaw")
