"""Core: the paper's contribution — mode-specific spMTTKRP + CPD-ALS.

Public API:
  SparseTensor, random_sparse, low_rank_sparse, frostt_like   (coo)
  Scheme, partition_mode, choose_scheme                       (load_balance)
  ModeLayout, build_mode_layout, build_all_mode_layouts       (layout)
  MTTKRPPlan, make_plan, mttkrp                               (mttkrp)
  cpd_als, CPDResult                                          (cpd)
"""
from .als_device import cpd_als_fused, state_from_factors, sweep_cache_stats
from .coo import SparseTensor, frostt_like, low_rank_sparse, random_sparse
from .cpd import CPDResult, cpd_als
from .layout import ModeLayout, build_all_mode_layouts, build_mode_layout, format_memory_report
from .load_balance import (DeviceProfile, Partitioning, Scheme,
                           balance_bound_holds, choose_scheme,
                           choose_scheme_cost_based, partition_mode,
                           scheme_cost)
from .mttkrp import MTTKRPPlan, make_plan, mttkrp, mttkrp_dense_ref
from .plan import (DeviceShards, ModePlan, PartitionPlan,
                   build_device_shards, density_profile, plan_bucket,
                   plan_layout, plan_tensor, quantize_nnz, slab_cap)

__all__ = [
    "DeviceShards", "ModePlan", "PartitionPlan", "build_device_shards",
    "plan_bucket", "plan_layout", "plan_tensor", "quantize_nnz", "slab_cap",
    "SparseTensor", "frostt_like", "low_rank_sparse", "random_sparse",
    "CPDResult", "cpd_als", "cpd_als_fused", "state_from_factors",
    "sweep_cache_stats", "density_profile",
    "ModeLayout", "build_all_mode_layouts", "build_mode_layout", "format_memory_report",
    "DeviceProfile", "Partitioning", "Scheme", "balance_bound_holds",
    "choose_scheme", "choose_scheme_cost_based", "partition_mode", "scheme_cost",
    "MTTKRPPlan", "make_plan", "mttkrp", "mttkrp_dense_ref",
]
