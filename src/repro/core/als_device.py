"""Device-resident fused CPD-ALS: one jitted XLA computation per sweep.

The paper's thesis is that in the small-tensor regime *overhead*, not
FLOPs, dominates — and the host-loop driver in ``core.cpd`` recreates at
the sweep level exactly the traffic the kernel eliminates at the nnz
level: every mode of every iteration syncs the MTTKRP result to host,
solves the normal equations in numpy, and re-uploads the factor
(~2·N·iters transfers).  This module fuses the entire N-mode sweep —
MTTKRP (segment / pallas / coo backend), gram updates, Cholesky ridge
solve with pinv fallback, column normalization, and the sparse fit — into
a single jit-compiled function with device-carried state:

  * factors / grams / weights never leave the device between iterations;
    the state pytree is donated so XLA reuses the buffers in place.
  * the ``check_every`` iterations between convergence checks run as ONE
    dispatch: a ``lax.scan`` over the sweep body, so the host pays a
    single call per check window instead of one per iteration.  The
    sparse fit (<X, X_hat> over nnz + the gram-product model norm) is
    computed on device every sweep; the host only *fetches* it at the
    window boundary, so host syncs drop from 2·N per iteration to 1/k
    (+1 final materialization).  ``CPDResult.host_syncs`` records the
    actual count.
  * compiled sweep blocks are cached per (backend, nmodes, rank, shapes,
    pallas tiling, block length, method): repeated decompositions of
    same-shape tensors — the serving scenario — pay zero retrace.
    ``sweep_cache_stats()`` exposes the hit/miss counters.

The sweep body itself is *closure-free over tensor data*: runtime arrays
(layout copies, nnz coordinates, fit data) are arguments, never captured
constants.  That is what lets ``repro.serve.batched_engine`` stack B
same-bucket tensors and ``jax.vmap`` the identical sweep into one
batched dispatch (see ``build_sweep_fn``).

Decomposition methods
---------------------
The MTTKRP substrate is method-agnostic: ``build_sweep_fn`` dispatches
the *update rule* through the ``repro.methods`` registry.  ``method=
"cp"`` is the inline unconstrained ALS path below; other methods
(nonnegative HALS, masked/weighted completion, …) receive a
``SweepContext`` carrying the shared MTTKRP primitives, the ridge
solver, and the sparse fit, and return a sweep with the SAME signature —
so every method rides the same executable cache, the same ``lax.scan``
window structure, and the same vmapped batched engine.

Every stage of the sweep is wrapped in ``jax.named_scope`` ("mttkrp",
"solve", "fit", …) so a profiler trace separates kernel time from solve
time; ``profile_mttkrp=True`` additionally times a jitted MTTKRP-only
replay of the same windows so ``CPDResult.mttkrp_seconds`` is populated
even without a trace viewer.

``core.cpd.cpd_als`` delegates here by default (``engine="fused"``); the
original host loop survives as ``engine="host"`` for benchmarking.
"""
from __future__ import annotations

import dataclasses
import functools
import inspect
from typing import Callable

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.scipy import linalg as jsla

from ..kernels import ref as kref
from ..kernels.mttkrp_pallas import mttkrp_pallas
from ..obs import clock as obs_clock
from ..obs import trace as obs_trace
from ..obs.ledger import LEDGER as _LEDGER
from .coo import SparseTensor
from .cpd import CPDResult
from .mttkrp import MTTKRPPlan, make_plan

_RIDGE_REL = 1e-10

# jax renamed pinv's cutoff kwarg rcond -> rtol; support both.
_PINV_KW = ("rtol" if "rtol" in inspect.signature(jnp.linalg.pinv).parameters
            else "rcond")


def _pinv(a):
    return jnp.linalg.pinv(a, **{_PINV_KW: 1e-10})


def resolve_solver(solver: str) -> str:
    """Resolve 'auto' to the per-backend normal-equations solver (shared
    by the fused, batched, and distributed engines so the same
    configuration can never pick different solvers by front door):
    'cho' (Cholesky — best on TPU/GPU) off-CPU, 'inv' (LU inverse) on
    CPU, where XLA's Cholesky/TriangularSolve custom calls cost ~5 ms
    even at R=16."""
    if solver == "auto":
        solver = "cho" if jax.default_backend() != "cpu" else "inv"
    if solver not in ("cho", "inv"):
        raise ValueError(f"unknown solver {solver!r}")
    return solver


# ---------------------------------------------------------------------------
# MTTKRP substrate (shared by every decomposition method)
# ---------------------------------------------------------------------------


def _build_one_mttkrp(backend: str, nmodes: int, shapes: tuple[int, ...],
                      pallas_meta: tuple | None, interpret: bool,
                      axis: str | None,
                      collectives: tuple[str, ...] | None = None):
    """``one_mttkrp(d, mode_data, factors) -> (I_d, R)`` with values baked
    into the mode data (the CP layout contract):

      segment: (idx, rows, vals, row_perm)
      pallas:  (rb_of, first, idx_packed, vals_packed, lrows_packed, row_perm)
      coo:     (indices, values)

    ``collectives`` (distributed segment path only): per-mode choice of
    how partial outputs combine across ``axis`` — "psum" (the default,
    works for both partition schemes) or "gather" (scheme 1 only: each
    device all-gathers just its OWNED row slice and scatters through the
    gathered destination map, moving ~1/kappa of the psum payload; mode
    data widens to ``(idx, rows, vals, row_perm, own_rows, gather_dst)``,
    see ``core.plan.DeviceShards.own_rows``).
    """
    in_modes = [tuple(w for w in range(nmodes) if w != d)
                for d in range(nmodes)]

    def one_mttkrp(d, mode_data, factors):
        """(I_d, R) f32 in ORIGINAL row order, entirely on device."""
        if backend == "segment":
            if (axis is not None and collectives is not None
                    and collectives[d] == "gather"):
                idx, rows, vals, row_perm, own_rows, gather_dst = mode_data
                out = kref.mttkrp_sorted_segments(
                    idx, rows, vals,
                    [factors[w] for w in in_modes[d]], shapes[d]
                )
                # Scheme-1 partials have support only on this device's
                # owned relabeled rows: gather those slices plus their
                # original-row destinations and scatter into a buffer
                # with one dummy row (I_d) absorbing the padding slots.
                own = out[own_rows]                        # (rows_cap, R)
                g_vals = lax.all_gather(own, axis)         # (κ, cap, R)
                g_dst = lax.all_gather(gather_dst, axis)   # (κ, cap)
                full = jnp.zeros((shapes[d] + 1, out.shape[-1]), out.dtype)
                full = full.at[g_dst.reshape(-1)].set(
                    g_vals.reshape(-1, out.shape[-1]))
                return full[: shapes[d]]
            idx, rows, vals, row_perm = mode_data
            out = kref.mttkrp_sorted_segments(
                idx, rows, vals, [factors[w] for w in in_modes[d]], shapes[d]
            )
            if axis is not None:      # combine per-device partials
                out = lax.psum(out, axis)
            return jnp.zeros_like(out).at[row_perm].set(out)
        if backend == "pallas":
            rb_of, first, idxp, valsp, lrowsp, row_perm = mode_data
            nrb, br, tile, rblk = pallas_meta[d]
            out = mttkrp_pallas(
                rb_of, first, idxp, valsp, lrowsp,
                [factors[w] for w in in_modes[d]],
                num_row_blocks=nrb, block_rows=br, tile=tile,
                rank_block=rblk, interpret=interpret,
            )[: shapes[d]]
            if axis is not None:
                out = lax.psum(out, axis)
            return jnp.zeros_like(out).at[row_perm].set(out)
        if backend == "coo":
            indices, values = mode_data
            out = kref.mttkrp_coo(
                indices, values, list(factors), d, shapes[d]
            )
            if axis is not None:
                out = lax.psum(out, axis)
            return out
        raise ValueError(f"unknown backend {backend!r}")

    return one_mttkrp


def _build_valued_mttkrp(backend: str, nmodes: int, shapes: tuple[int, ...],
                         pallas_meta: tuple | None, interpret: bool,
                         axis: str | None):
    """``mttkrp_valued(d, mode_data, factors, vals) -> (I_d, R)``: the
    mask-weighted entry point.  Mode data carries only the STRUCTURAL
    layout arrays; a fresh canonical-order value vector (e.g. the masked
    method's per-sweep residual) is threaded through the same kernels:

      segment: (idx, rows, row_perm, perm)            vals_layout = vals[perm]
      pallas:  (rb_of, first, idx_packed, lrows_packed,
                row_perm, perm, val_scatter)           scatter into the slabs
      coo:     (indices,)                              canonical order already

    With ``axis`` (the distributed shard_map path, segment backend only)
    the contract changes: mode data is the device-local structural shard
    ``(idx, rows, row_perm)`` and ``vals`` arrives in LAYOUT-SHARD order
    (each device evaluates its residual at its own shard's coordinates —
    see ``methods.masked``), so no canonical->layout permutation exists;
    the partial outputs are ``psum``-combined over the axis.
    """
    in_modes = [tuple(w for w in range(nmodes) if w != d)
                for d in range(nmodes)]

    if axis is not None:
        if backend != "segment":
            raise NotImplementedError(
                "the distributed valued MTTKRP runs on the segment backend "
                f"(shard_map path), got {backend!r}")

        def mttkrp_valued_dist(d, mode_data, factors, vals):
            idx, rows, row_perm = mode_data
            out = kref.mttkrp_sorted_segments(
                idx, rows, vals, [factors[w] for w in in_modes[d]], shapes[d]
            )
            out = lax.psum(out, axis)
            return jnp.zeros_like(out).at[row_perm].set(out)

        return mttkrp_valued_dist

    def mttkrp_valued(d, mode_data, factors, vals):
        if backend == "segment":
            idx, rows, row_perm, perm = mode_data
            out = kref.mttkrp_sorted_segments(
                idx, rows, vals[perm],
                [factors[w] for w in in_modes[d]], shapes[d]
            )
            return jnp.zeros_like(out).at[row_perm].set(out)
        if backend == "pallas":
            rb_of, first, idxp, lrowsp, row_perm, perm, scatter = mode_data
            nrb, br, tile, rblk = pallas_meta[d]
            valsp = jnp.zeros((1, idxp.shape[-1]), jnp.float32)
            valsp = valsp.at[0, scatter].set(vals[perm])
            out = mttkrp_pallas(
                rb_of, first, idxp, valsp, lrowsp,
                [factors[w] for w in in_modes[d]],
                num_row_blocks=nrb, block_rows=br, tile=tile,
                rank_block=rblk, interpret=interpret,
            )[: shapes[d]]
            return jnp.zeros_like(out).at[row_perm].set(out)
        if backend == "coo":
            (indices,) = mode_data
            return kref.mttkrp_coo(
                indices, vals, list(factors), d, shapes[d]
            )
        raise ValueError(f"unknown backend {backend!r}")

    return mttkrp_valued


def _hadamard_grams(grams, rank: int, exclude: int | None = None):
    V = jnp.ones((rank, rank), jnp.float32)
    for w, g in enumerate(grams):
        if w != exclude:
            V = V * g
    return V


def _build_solver(rank: int, solver: str, fallback: str):
    """``solve(M, V) -> Yd``: ridge-regularized normal-equations solve with
    the optional pinv rescue — the exact CP solve, shared with the masked
    method so both produce the same numerics."""
    eye = jnp.eye(rank, dtype=jnp.float32)

    def solve(M, V):
        ridge = _RIDGE_REL * jnp.maximum(jnp.trace(V) / rank, 1.0)
        Vr = V + ridge * eye
        # Ridge solve; pinv fallback if the factorization NaNs out
        # (V near-singular beyond what the ridge absorbs).  "cho" is
        # the Cholesky path (best on TPU/GPU); "inv" multiplies by the
        # explicit inverse — XLA's CPU Cholesky/TriangularSolve custom
        # calls cost ~5 ms even at R=16, an order of magnitude more
        # than the LU inverse, so "auto" picks per backend.
        if solver == "cho":
            Yd = jsla.cho_solve(jsla.cho_factor(Vr), M.T).T
        else:
            Yd = M @ jnp.linalg.inv(Vr)
        # lax.cond (not jnp.where) so the SVD-based pinv only runs on
        # the rare singular miss, never in the hot path.  (Under vmap
        # the cond lowers to a select and both branches run — the
        # batched engine therefore builds fallback='none' sweeps and
        # hoists one batch-level all-finite cond around the window.)
        if fallback == "cond":
            Yd = lax.cond(
                jnp.all(jnp.isfinite(Yd)),
                lambda yd, m, v: yd,
                lambda yd, m, v: m @ _pinv(v),
                Yd, M, Vr,
            )
        return Yd

    return solve


def normalize_columns(Yd):
    """Column-normalize, guarding dead columns; returns (Yd, lam)."""
    lam = jnp.linalg.norm(Yd, axis=0)
    lam = jnp.where(lam > 1e-12, lam, 1.0)
    return Yd / lam, lam


def _build_sparse_fit(nmodes: int, rank: int, axis: str | None):
    """On-device sparse fit (jnp ports of cpd._innerprod_sparse /
    cpd._model_norm_sq): no dense reconstruction, no host round-trip.
    Zero-valued padding entries (serve.buckets) contribute exactly +0.0
    to both the Hadamard accumulation and the inner product."""

    def sparse_fit(factors, grams, weights, fit_data):
        indices, values, norm_x_sq = fit_data
        acc = jnp.ones((values.shape[0], rank), jnp.float32)
        for d in range(nmodes):
            acc = acc * factors[d][indices[:, d]]
        ip = values @ (acc @ weights)
        if axis is not None:          # nnz are sharded across devices
            ip = lax.psum(ip, axis)
        V = _hadamard_grams(grams, rank)
        model_sq = weights @ V @ weights
        resid_sq = jnp.maximum(norm_x_sq - 2.0 * ip + model_sq, 0.0)
        return 1.0 - jnp.sqrt(resid_sq) / jnp.maximum(
            jnp.sqrt(norm_x_sq), 1e-12)

    return sparse_fit


def _build_weighted_fit(nmodes: int, rank: int, axis: str | None):
    """Observed-only weighted fit shared by the masked method across every
    execution path:  ``1 - sqrt(sum_e w_e (x_e - model_e)^2) /
    sqrt(sum_e w_e x_e^2)``.  ``fit_data = (indices, values,
    entry_weights, weighted_norm_sq)``; weight-0 entries (nnz padding, or
    entries the caller masked out) contribute exactly +0.0.  Under
    ``axis`` the nnz are device shards and the residual mass psums."""

    def weighted_fit(factors, weights, fit_data):
        indices, values, ew, norm_x_sq = fit_data
        acc = jnp.ones((values.shape[0], rank), jnp.float32)
        for d in range(nmodes):
            acc = acc * factors[d][indices[:, d]]
        resid = values - acc @ weights
        resid_sq = jnp.sum(ew * resid * resid)
        if axis is not None:
            resid_sq = lax.psum(resid_sq, axis)
        return 1.0 - jnp.sqrt(resid_sq) / jnp.maximum(
            jnp.sqrt(norm_x_sq), 1e-12)

    return weighted_fit


def validate_entry_weights(nnz: int, weights) -> np.ndarray:
    """Normalize a front-door per-entry weight vector: (nnz,) f32,
    finite, nonnegative.  Shared by every front door (sequential fused,
    batched service, distributed) so they can never disagree on what a
    legal weight vector is."""
    w = np.asarray(weights, dtype=np.float32).reshape(-1)
    if w.shape[0] != nnz:
        raise ValueError(
            f"entry weights must align with the nnz list: got {w.shape[0]} "
            f"weights for {nnz} nonzeros")
    if not np.all(np.isfinite(w)):
        raise ValueError("entry weights must be finite")
    if w.size and float(w.min()) < 0.0:
        raise ValueError("entry weights must be nonnegative")
    return w


def normalize_entry_weights(w: np.ndarray) -> np.ndarray:
    """EM stability normalization, applied by every weighted front door
    (sequential, batched, distributed — so they can never disagree): the
    masked method's filled-tensor update is a majorizer only for weights
    in [0, 1], while the weighted objective — argmin AND reported fit —
    is invariant under positive rescaling of the whole vector.  Dividing
    by ``max(1, w.max())`` therefore changes nothing the caller can
    observe except that the iteration is guaranteed stable.  Vectors
    already in [0, 1] pass through untouched (bit-exactly), and the map
    is idempotent."""
    m = float(w.max()) if w.size else 0.0
    return (w / np.float32(m)).astype(np.float32) if m > 1.0 else w


@dataclasses.dataclass(frozen=True)
class SweepContext:
    """Everything a decomposition method needs to build its sweep on the
    shared substrate.  ``repro.methods`` specs receive this and return
    ``sweep(state, mode_data_all, fit_data) -> (state, fit)`` — the same
    contract as the inline CP sweep, so method sweeps drop into the
    sequential scan block, the vmapped batched engine, and the executable
    cache unchanged."""

    backend: str
    nmodes: int
    rank: int
    shapes: tuple[int, ...]
    solver: str
    fallback: str
    axis: str | None
    one_mttkrp: Callable      # (d, mode_data, factors) -> (I_d, R)
    mttkrp_valued: Callable   # (d, mode_data, factors, vals) -> (I_d, R)
    solve: Callable           # (M, V) -> Yd  (ridge + pinv rescue)
    normalize: Callable       # (Yd) -> (Yd, lam)  (dead-column guard)
    sparse_fit: Callable      # (factors, grams, weights, fit_data) -> fit
    weighted_fit: Callable    # (factors, weights, fit_data4) -> fit
    hadamard: Callable        # (grams, exclude=None) -> (R, R)


# ---------------------------------------------------------------------------
# Closure-free sweep builder (shared by the sequential and batched engines)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def build_sweep_fn(backend: str, nmodes: int, rank: int,
                   shapes: tuple[int, ...],
                   pallas_meta: tuple | None,
                   interpret: bool, solver: str,
                   axis: str | None = None,
                   fallback: str = "cond",
                   method: str = "cp",
                   collectives: tuple[str, ...] | None = None):
    """Build (and cache) the *pure* one-full-sweep function for a static
    configuration: ``sweep(state, mode_data_all, fit_data) -> (state, fit)``.

    All runtime data (layout arrays, nnz coordinates, fit inputs) are
    arguments — the function closes over nothing but static ints — so it
    can be jitted directly (sequential engine), ``jax.vmap``-ed over a
    stacked leading axis (``serve.batched_engine``), or run inside
    ``shard_map`` (``core.distributed``): every tensor of the same
    (shape, nnz-bucket) class shares this one function object.

    ``axis``: a mesh axis name — mode data and fit data are then
    device-local shards and the sweep ``psum``s the partial MTTKRP output
    and the fit inner product over that axis (the distributed path).
    ``fallback``: 'cond' guards the solve with the pinv rescue (the
    sequential default); 'none' omits it so a batch-level all-finite cond
    can be hoisted AROUND the whole window (``serve.batched_engine``) —
    under vmap the per-element cond would lower to a select that always
    pays the small-R SVD.
    ``method``: which decomposition method's update rule runs on the
    substrate — 'cp' is the inline path below; anything else resolves
    through the ``repro.methods`` registry.
    ``collectives``: per-mode cross-device combine for the distributed
    segment path ("psum" | "gather"); see ``_build_one_mttkrp``.
    """
    if fallback not in ("cond", "none"):
        raise ValueError(f"unknown fallback {fallback!r}")
    if collectives is not None:
        if axis is None or backend != "segment":
            raise ValueError(
                "per-mode collectives apply to the distributed segment "
                "path only (axis set, backend='segment')")
        if len(collectives) != nmodes or any(
                c not in ("psum", "gather") for c in collectives):
            raise ValueError(f"bad collectives {collectives!r}")

    one_mttkrp = _build_one_mttkrp(backend, nmodes, shapes, pallas_meta,
                                   interpret, axis, collectives)
    solve = _build_solver(rank, solver, fallback)
    sparse_fit = _build_sparse_fit(nmodes, rank, axis)

    if method != "cp":
        from ..methods import get_method   # lazy: core must import clean

        spec = get_method(method)
        if spec.build_sweep is None:
            raise ValueError(
                f"method {method!r} has no sweep builder (stateful methods "
                f"drive the substrate through their session API)")
        mttkrp_valued = (
            _build_valued_mttkrp(backend, nmodes, shapes, pallas_meta,
                                 interpret, axis)
            if (axis is None or backend == "segment") else None)
        ctx = SweepContext(
            backend=backend, nmodes=nmodes, rank=rank, shapes=shapes,
            solver=solver, fallback=fallback, axis=axis,
            one_mttkrp=one_mttkrp, mttkrp_valued=mttkrp_valued,
            solve=solve, normalize=normalize_columns,
            sparse_fit=sparse_fit,
            weighted_fit=_build_weighted_fit(nmodes, rank, axis),
            hadamard=functools.partial(_hadamard_grams, rank=rank),
        )
        return spec.build_sweep(ctx)

    def sweep(state, mode_data_all, fit_data):
        factors, grams, weights = list(state[0]), list(state[1]), state[2]
        for d in range(nmodes):
            with jax.named_scope("mttkrp"):
                M = one_mttkrp(d, mode_data_all[d], factors)
            with jax.named_scope("solve"):
                V = _hadamard_grams(grams, rank, exclude=d)
                Yd = solve(M, V)
                Yd, lam = normalize_columns(Yd)
            factors[d] = Yd
            grams[d] = Yd.T @ Yd
            weights = lam
        with jax.named_scope("fit"):
            fit = sparse_fit(factors, grams, weights, fit_data)
        return (tuple(factors), tuple(grams), weights), fit

    return sweep


# ---------------------------------------------------------------------------
# Compiled sweep-block cache (lax.scan over one check window)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _build_sweep_block(backend: str, nmodes: int, rank: int,
                       shapes: tuple[int, ...],
                       pallas_meta: tuple | None,
                       interpret: bool, donate: bool, solver: str,
                       block: int, method: str = "cp"):
    """Jitted ``lax.scan`` of ``block`` consecutive sweeps: the whole
    check window is ONE dispatch.  Returns the carried state plus the
    per-iteration fit vector ``(block,)`` so the fit history stays
    complete.

    Each built block registers in the obs retrace ledger: the lru key
    here deliberately omits nnz (jit re-specializes per array shape
    inside one cache entry), so lru hits/misses alone cannot see the
    retrace a NOVEL nnz causes — the ledger's per-executable trace
    counts can."""
    sweep = build_sweep_fn(backend, nmodes, rank, shapes, pallas_meta,
                           interpret, solver, method=method)

    def run_block(state, mode_data_all, fit_data):
        def body(st, _):
            return sweep(st, mode_data_all, fit_data)

        state, fits = lax.scan(body, state, xs=None, length=block)
        return state, fits

    fn = jax.jit(run_block, donate_argnums=(0,) if donate else ())
    return _LEDGER.register(
        "sweep_block",
        (backend, nmodes, rank, shapes, "block", block, "method", method),
        fn)


@functools.lru_cache(maxsize=None)
def _build_mttkrp_block(backend: str, nmodes: int, rank: int,
                        shapes: tuple[int, ...],
                        pallas_meta: tuple | None,
                        interpret: bool, block: int):
    """Jitted MTTKRP-only replay of one check window: ``block`` sweeps of
    all N mode MTTKRPs with NO solve/normalize/fit.  Timing this against
    the full sweep block separates ``mttkrp_seconds`` from solve time
    (kernel cost does not depend on factor values, so replaying with the
    final factors is faithful).  The scalar reduction keeps XLA from
    eliding the kernels."""
    one_mttkrp = _build_one_mttkrp(backend, nmodes, shapes, pallas_meta,
                                   interpret, None)

    def run(factors, mode_data_all):
        def body(s, _):
            for d in range(nmodes):
                with jax.named_scope("mttkrp"):
                    M = one_mttkrp(d, mode_data_all[d], list(factors))
                s = s + jnp.sum(jnp.abs(M))
            return s, None

        s, _ = lax.scan(body, jnp.float32(0.0), xs=None, length=block)
        return s

    return _LEDGER.register(
        "mttkrp_block",
        (backend, nmodes, rank, shapes, "block", block),
        jax.jit(run))


def sweep_cache_stats():
    """(hits, misses, currsize) of the compiled sweep-block cache — the
    probe for 'repeated same-shape decompositions pay zero retrace'.
    ``runtime.ALSRunner`` records the per-request delta so retrace-induced
    stragglers are distinguishable from contention stragglers."""
    info = _build_sweep_block.cache_info()
    return {"hits": info.hits, "misses": info.misses,
            "currsize": info.currsize}


def sweep_trace_stats():
    """Total TRACES across all jitted sweep blocks — the probe the lru
    stats above cannot provide: nnz is not part of the lru key (jit
    re-specializes per argument shape inside one entry), so a stream of
    ever-novel nnz counts shows lru hits while silently retracing every
    call.  ``traces`` counts actual specializations (as a delta since
    the last ledger ``reset()`` — an autouse test fixture resets, so
    assertions cannot leak across tests); a zero-retrace streaming
    increment leaves it unchanged.  Best-effort: jax's ``_cache_size``
    is version-private, so absent introspection support this reports
    blocks only (traces=None).

    This is now a view over ``repro.obs.ledger.LEDGER`` (which also
    covers the MTTKRP-replay, batched, and distributed executables —
    query those kinds there); the old module-global registry is gone.
    """
    s = _LEDGER.stats("sweep_block")
    return {"blocks": s["blocks"], "traces": s["traces"]}


def _collect_mode_data(plan: MTTKRPPlan, backend: str, rank: int):
    """Per-mode device arrays (cached on the plan) + static pallas tiling."""
    N = plan.tensor.nmodes
    if backend == "segment":
        return tuple(plan.device_arrays(d) for d in range(N)), None
    if backend == "pallas":
        datas, metas = [], []
        for d in range(N):
            packed = plan.packed(d)
            mp = plan.mode_plan(d, rank)    # core.plan decides rank_block
            dev = plan.device_packed(d)
            datas.append(dev + (jnp.asarray(plan.layouts[d].row_perm),))
            metas.append((packed.num_row_blocks, packed.block_rows,
                          packed.tile, mp.rank_block))
        return tuple(datas), tuple(metas)
    if backend == "coo":
        coo = plan.device_coo()
        return tuple(coo for _ in range(N)), None
    raise ValueError(f"unknown backend {backend!r}")


def collect_structural_mode_data(plan: MTTKRPPlan, backend: str, rank: int):
    """Mode data for the *valued* MTTKRP contract (see
    ``_build_valued_mttkrp``): structural layout arrays plus the
    canonical->layout permutation (and canonical->slab scatter for
    pallas), NO baked values.  The masked method collects through here."""
    N = plan.tensor.nmodes
    if backend == "segment":
        datas = []
        for d in range(N):
            lay = plan.layouts[d]
            im = lay.input_modes()
            datas.append((
                jnp.asarray(lay.indices[:, im]),
                jnp.asarray(lay.rows),
                jnp.asarray(lay.row_perm),
                jnp.asarray(lay.perm.astype(np.int32)),
            ))
        return tuple(datas), None
    if backend == "pallas":
        datas, metas = [], []
        for d in range(N):
            packed = plan.packed(d)
            mp = plan.mode_plan(d, rank)
            lay = plan.layouts[d]
            datas.append((
                jnp.asarray(packed.rb_of),
                jnp.asarray(packed.first),
                jnp.asarray(packed.idx_packed),
                jnp.asarray(packed.lrows_packed),
                jnp.asarray(lay.row_perm),
                jnp.asarray(lay.perm.astype(np.int32)),
                jnp.asarray(packed.val_scatter),
            ))
            metas.append((packed.num_row_blocks, packed.block_rows,
                          packed.tile, mp.rank_block))
        return tuple(datas), tuple(metas)
    if backend == "coo":
        idx = jnp.asarray(plan.tensor.indices)
        return tuple((idx,) for _ in range(N)), None
    raise ValueError(f"unknown backend {backend!r}")


def init_state_host(tensor_shape, rank: int, seed: int):
    """Host-side (pure numpy) random init shared by every engine: same
    seed => same starting point for the host loop, the fused engine, and
    the batched engine.  Kept on host so the serving path can stack B of
    these and upload ONE array per state leaf instead of paying 2N+1 tiny
    transfers plus N gram matmul dispatches per tensor."""
    rng = np.random.default_rng(seed)
    factors = tuple(
        rng.standard_normal((I, rank)).astype(np.float32)
        for I in tensor_shape
    )
    grams = tuple(F.T @ F for F in factors)
    weights = np.ones((rank,), np.float32)
    return (factors, grams, weights)


def state_from_factors(factors, weights=None):
    """Host state tuple from explicit (e.g. previously fitted) factors:
    the warm-start entry the streaming method folds increments through.
    Grams are recomputed so the state is always self-consistent."""
    factors = tuple(np.asarray(F, dtype=np.float32) for F in factors)
    grams = tuple(F.T @ F for F in factors)
    rank = factors[0].shape[1]
    if weights is None:
        weights = np.ones((rank,), np.float32)
    return (factors, grams, np.asarray(weights, dtype=np.float32))


def init_state(tensor_shape, rank: int, seed: int):
    """Device-resident init for the sequential fused engine."""
    factors, grams, weights = init_state_host(tensor_shape, rank, seed)
    return (tuple(jnp.asarray(F) for F in factors),
            tuple(jnp.asarray(G) for G in grams),
            jnp.asarray(weights))


def _method_spec(method: str):
    if method == "cp":
        return None
    from ..methods import get_method

    spec = get_method(method)
    if spec.build_sweep is None:
        raise ValueError(
            f"method {method!r} is stateful; drive it through its session "
            f"API (e.g. repro.methods.StreamingCP / ALSRunner.open_stream)")
    return spec


def _host_state_to_device(state):
    return (tuple(jnp.asarray(F) for F in state[0]),
            tuple(jnp.asarray(G) for G in state[1]),
            jnp.asarray(state[2]))


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------


def cpd_als_fused(
    tensor: SparseTensor,
    rank: int,
    *,
    plan: MTTKRPPlan | None = None,
    kappa: int = 1,
    n_iters: int = 25,
    tol: float = 1e-5,
    seed: int = 0,
    backend: str = "segment",
    check_every: int = 1,
    interpret: bool = True,
    donate: bool | None = None,
    solver: str = "auto",
    method: str = "cp",
    init_state: tuple | None = None,
    weights: np.ndarray | None = None,
    profile_mttkrp: bool = False,
    verbose: bool = False,
) -> CPDResult:
    """Device-resident CPD-ALS.  Same initialization and update order as the
    host-loop ``cpd_als`` (identical seed ⇒ matching trajectories up to f32
    vs f64 solver precision), but every ``check_every``-iteration window
    runs as one compiled ``lax.scan`` dispatch and the host syncs only at
    window boundaries.

    ``method`` selects the update rule (see ``repro.methods``); every
    method shares this driver, the window scan, and the executable cache.
    ``init_state`` (a host state tuple, e.g. from ``state_from_factors``)
    warm-starts from existing factors instead of the seeded random init —
    the streaming method's incremental-fold entry.
    ``weights`` — per-entry observation weights in canonical COO order
    (fractional confidences; weight 0 = treat the entry as unobserved).
    Only weighted-fit methods ('masked') accept them; they flow into the
    method's fit data, never into the structural layouts, so weighted and
    unweighted requests share every packed artifact and executable.
    ``profile_mttkrp=True`` times a jitted MTTKRP-only replay of the same
    windows after the run so ``mttkrp_seconds`` is separable from solve
    time (named_scope annotations additionally mark the stages for real
    profiler traces).  The replay covers value-baked mode data only:
    for valued-mode-data methods (masked) ``mttkrp_seconds`` stays at the
    0.0 sentinel — use a named_scope profiler trace there.
    """
    t_start = obs_clock.now()
    N = tensor.nmodes
    check_every = max(1, int(check_every))
    spec = _method_spec(method)
    if weights is not None:
        if spec is None or not spec.weighted_fit:
            raise ValueError(
                f"per-entry weights require a weighted-fit method "
                f"(e.g. 'masked'), got method={method!r}")
        weights = normalize_entry_weights(
            validate_entry_weights(tensor.nnz, weights))
    if init_state is not None:
        state = _host_state_to_device(init_state)
    elif spec is not None and spec.init_state_host is not None:
        state = _host_state_to_device(
            spec.init_state_host(tensor.shape, rank, seed))
    else:
        # (init_state the *parameter* shadows the module-level helper here.)
        state = _host_state_to_device(
            init_state_host(tensor.shape, rank, seed))

    if donate is None:
        # Buffer donation is a no-op (with a warning) on CPU.
        donate = jax.default_backend() != "cpu"
    solver = resolve_solver(solver)

    structural = spec is not None and spec.valued_mode_data
    if plan is None and backend == "coo":
        # The coo backend needs no mode-specific layouts: skip the host-side
        # preprocessing (per-mode sorts) entirely and upload the raw COO.
        idx = jnp.asarray(tensor.indices)
        if structural:
            mode_data_all, pallas_meta = tuple((idx,) for _ in range(N)), None
        else:
            coo = (idx, jnp.asarray(tensor.values.astype(np.float32)))
            mode_data_all, pallas_meta = tuple(coo for _ in range(N)), None
    else:
        if plan is None:
            plan = make_plan(tensor, kappa)
        if structural:
            mode_data_all, pallas_meta = collect_structural_mode_data(
                plan, backend, rank)
        else:
            mode_data_all, pallas_meta = _collect_mode_data(
                plan, backend, rank)
    if spec is not None and spec.make_fit_data is not None:
        fit_data = spec.make_fit_data(tensor, weights)
    else:
        norm_x_sq = tensor.norm() ** 2
        fit_data = (
            jnp.asarray(tensor.indices),
            jnp.asarray(tensor.values.astype(np.float32)),
            jnp.asarray(norm_x_sq, jnp.float32),
        )

    shapes = tuple(int(s) for s in tensor.shape)
    n_blocks, rem = divmod(n_iters, check_every)
    sweep_k = _build_sweep_block(
        backend, N, rank, shapes, pallas_meta, bool(interpret), bool(donate),
        solver, check_every, method,
    ) if n_blocks else None
    sweep_rem = _build_sweep_block(
        backend, N, rank, shapes, pallas_meta, bool(interpret), bool(donate),
        solver, rem, method,
    ) if rem else None

    fits_dev: list = []
    host_syncs = 0
    last_fit = -np.inf
    it = 0
    windows_run: list[int] = []
    tr = obs_trace.active()
    for b in range(n_blocks + (1 if rem else 0)):
        k = check_every if b < n_blocks else rem
        fn = sweep_k if b < n_blocks else sweep_rem
        # Dispatch + the window-boundary fit sync, the per-window hot
        # path: the tracing-disabled branch pays one global read and
        # zero allocations (enforced by tests/obs/test_trace.py).
        if tr is None:
            state, fits_blk = fn(state, mode_data_all, fit_data)
            f = float(fits_blk[-1])             # the only in-loop host sync
        else:
            with tr.span("als.window", cat="als", backend=backend,
                         method=method, window=b, sweeps=k):
                state, fits_blk = fn(state, mode_data_all, fit_data)
                f = float(fits_blk[-1])         # the only in-loop host sync
        fits_dev.append(fits_blk)
        windows_run.append(k)
        it += k
        host_syncs += 1
        if verbose:
            print(f"  ALS iter {it:3d}: fit={f:.6f} ({method}/fused)")
        if abs(f - last_fit) < tol:
            break
        last_fit = f

    host_syncs += 1                             # final materialization
    # One batched device_get for the whole run (not a fetch per window),
    # so host_syncs honestly reflects the transfer count.
    fits = [float(f) for blk in jax.device_get(fits_dev) for f in blk]

    mttkrp_seconds = 0.0
    if profile_mttkrp and windows_run and not structural:
        mttkrp_seconds = _profile_mttkrp_replay(
            backend, N, rank, shapes, pallas_meta, bool(interpret),
            state[0], mode_data_all, windows_run)

    return CPDResult(
        factors=[np.asarray(F) for F in state[0]],
        weights=np.asarray(state[2], dtype=np.float64),
        fits=fits,
        iters=it,
        mttkrp_seconds=mttkrp_seconds,
        total_seconds=obs_clock.now() - t_start,
        host_syncs=host_syncs,
        engine="fused",
        method=method,
    )


def _profile_mttkrp_replay(backend, nmodes, rank, shapes, pallas_meta,
                           interpret, factors, mode_data_all,
                           windows_run) -> float:
    """Wall time of the MTTKRP-only replay of the run's check windows
    (compile excluded via a warm-up call per window length)."""
    total = 0.0
    for k in sorted(set(windows_run)):
        fn = _build_mttkrp_block(backend, nmodes, rank, shapes, pallas_meta,
                                 interpret, k)
        jax.block_until_ready(fn(factors, mode_data_all))   # warm-up
        reps = windows_run.count(k)
        with obs_trace.span("mttkrp.replay", cat="als", backend=backend,
                            block=k, reps=reps):
            t0 = obs_clock.now()
            for _ in range(reps):
                jax.block_until_ready(fn(factors, mode_data_all))
            total += obs_clock.now() - t0
    return total
