"""Device-resident fused CPD-ALS: one jitted XLA computation per sweep.

The paper's thesis is that in the small-tensor regime *overhead*, not
FLOPs, dominates — and the host-loop driver in ``core.cpd`` recreates at
the sweep level exactly the traffic the kernel eliminates at the nnz
level: every mode of every iteration syncs the MTTKRP result to host,
solves the normal equations in numpy, and re-uploads the factor
(~2·N·iters transfers).  This module fuses the entire N-mode sweep —
MTTKRP (segment / pallas / coo backend), gram updates, Cholesky ridge
solve with pinv fallback, column normalization, and the sparse fit — into
a single jit-compiled function with device-carried state:

  * factors / grams / weights never leave the device between iterations;
    the state pytree is donated so XLA reuses the buffers in place.
  * the sparse fit (<X, X_hat> over nnz + the gram-product model norm) is
    computed on device every sweep; the host only *fetches* it at the
    configurable every-``check_every``-iterations convergence check, so
    host syncs drop from 2·N per iteration to 1/k (+1 final
    materialization).  ``CPDResult.host_syncs`` records the actual count.
  * compiled sweeps are cached per (backend, nmodes, rank, shapes, pallas
    tiling): repeated decompositions of same-shape tensors — the serving
    scenario — pay zero retrace.  ``sweep_cache_stats()`` exposes the
    hit/miss counters.

``core.cpd.cpd_als`` delegates here by default (``engine="fused"``); the
original host loop survives as ``engine="host"`` for benchmarking.
"""
from __future__ import annotations

import functools
import inspect
import time

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.scipy import linalg as jsla

from ..kernels import ops as kops
from ..kernels import ref as kref
from ..kernels.mttkrp_pallas import mttkrp_pallas
from .coo import SparseTensor
from .cpd import CPDResult
from .mttkrp import MTTKRPPlan, make_plan

_RIDGE_REL = 1e-10

# jax renamed pinv's cutoff kwarg rcond -> rtol; support both.
_PINV_KW = ("rtol" if "rtol" in inspect.signature(jnp.linalg.pinv).parameters
            else "rcond")


def _pinv(a):
    return jnp.linalg.pinv(a, **{_PINV_KW: 1e-10})


# ---------------------------------------------------------------------------
# Compiled-sweep cache
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _build_sweep(backend: str, nmodes: int, rank: int,
                 shapes: tuple[int, ...],
                 pallas_meta: tuple | None,
                 interpret: bool, donate: bool, solver: str):
    """Build (and cache) the jitted one-full-sweep function for a static
    configuration.  Runtime data (layout arrays, nnz coordinates) are
    arguments, so every same-shape decomposition reuses the executable."""
    in_modes = [tuple(w for w in range(nmodes) if w != d)
                for d in range(nmodes)]

    def one_mttkrp(d, mode_data, factors):
        """(I_d, R) f32 in ORIGINAL row order, entirely on device."""
        if backend == "segment":
            idx, rows, vals, row_perm = mode_data
            out = kref.mttkrp_sorted_segments(
                idx, rows, vals, [factors[w] for w in in_modes[d]], shapes[d]
            )
            return jnp.zeros_like(out).at[row_perm].set(out)
        if backend == "pallas":
            rb_of, first, idxp, valsp, lrowsp, row_perm = mode_data
            nrb, br, tile, rblk = pallas_meta[d]
            out = mttkrp_pallas(
                rb_of, first, idxp, valsp, lrowsp,
                [factors[w] for w in in_modes[d]],
                num_row_blocks=nrb, block_rows=br, tile=tile,
                rank_block=rblk, interpret=interpret,
            )[: shapes[d]]
            return jnp.zeros_like(out).at[row_perm].set(out)
        if backend == "coo":
            indices, values = mode_data
            return kref.mttkrp_coo(
                indices, values, list(factors), d, shapes[d]
            )
        raise ValueError(f"unknown backend {backend!r}")

    def sweep(state, mode_data_all, fit_data):
        factors, grams, weights = list(state[0]), list(state[1]), state[2]
        eye = jnp.eye(rank, dtype=jnp.float32)
        for d in range(nmodes):
            M = one_mttkrp(d, mode_data_all[d], factors)
            V = jnp.ones((rank, rank), jnp.float32)
            for w in range(nmodes):
                if w != d:
                    V = V * grams[w]
            ridge = _RIDGE_REL * jnp.maximum(jnp.trace(V) / rank, 1.0)
            Vr = V + ridge * eye
            # Ridge solve; pinv fallback if the factorization NaNs out
            # (V near-singular beyond what the ridge absorbs).  "cho" is
            # the Cholesky path (best on TPU/GPU); "inv" multiplies by the
            # explicit inverse — XLA's CPU Cholesky/TriangularSolve custom
            # calls cost ~5 ms even at R=16, an order of magnitude more
            # than the LU inverse, so "auto" picks per backend.
            if solver == "cho":
                Yd = jsla.cho_solve(jsla.cho_factor(Vr), M.T).T
            else:
                Yd = M @ jnp.linalg.inv(Vr)
            # lax.cond (not jnp.where) so the SVD-based pinv only runs on
            # the rare singular miss, never in the hot path.
            Yd = lax.cond(
                jnp.all(jnp.isfinite(Yd)),
                lambda yd, m, v: yd,
                lambda yd, m, v: m @ _pinv(v),
                Yd, M, Vr,
            )
            lam = jnp.linalg.norm(Yd, axis=0)
            lam = jnp.where(lam > 1e-12, lam, 1.0)
            Yd = Yd / lam
            factors[d] = Yd
            grams[d] = Yd.T @ Yd
            weights = lam

        # Sparse fit, on device (jnp ports of cpd._innerprod_sparse /
        # cpd._model_norm_sq): no dense reconstruction, no host round-trip.
        indices, values, norm_x_sq = fit_data
        acc = jnp.ones((values.shape[0], rank), jnp.float32)
        for d in range(nmodes):
            acc = acc * factors[d][indices[:, d]]
        ip = values @ (acc @ weights)
        V = jnp.ones((rank, rank), jnp.float32)
        for g in grams:
            V = V * g
        model_sq = weights @ V @ weights
        resid_sq = jnp.maximum(norm_x_sq - 2.0 * ip + model_sq, 0.0)
        fit = 1.0 - jnp.sqrt(resid_sq) / jnp.maximum(
            jnp.sqrt(norm_x_sq), 1e-12)
        return (tuple(factors), tuple(grams), weights), fit

    return jax.jit(sweep, donate_argnums=(0,) if donate else ())


def sweep_cache_stats():
    """(hits, misses, currsize) of the compiled-sweep cache — the probe for
    'repeated same-shape decompositions pay zero retrace'."""
    info = _build_sweep.cache_info()
    return {"hits": info.hits, "misses": info.misses,
            "currsize": info.currsize}


def _collect_mode_data(plan: MTTKRPPlan, backend: str, rank: int):
    """Per-mode device arrays (cached on the plan) + static pallas tiling."""
    N = plan.tensor.nmodes
    if backend == "segment":
        return tuple(plan.device_arrays(d) for d in range(N)), None
    if backend == "pallas":
        datas, metas = [], []
        for d in range(N):
            packed = plan.packed(d)
            factor_rows = sum(plan.tensor.shape[w]
                              for w in packed.input_modes)
            rblk = kops.auto_rank_block(
                rank, packed.block_rows, packed.tile, factor_rows,
                len(packed.input_modes)
            ) or rank
            dev = plan.device_packed(d)
            datas.append(dev + (jnp.asarray(plan.layouts[d].row_perm),))
            metas.append((packed.num_row_blocks, packed.block_rows,
                          packed.tile, rblk))
        return tuple(datas), tuple(metas)
    if backend == "coo":
        coo = plan.device_coo()
        return tuple(coo for _ in range(N)), None
    raise ValueError(f"unknown backend {backend!r}")


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------


def cpd_als_fused(
    tensor: SparseTensor,
    rank: int,
    *,
    plan: MTTKRPPlan | None = None,
    kappa: int = 1,
    n_iters: int = 25,
    tol: float = 1e-5,
    seed: int = 0,
    backend: str = "segment",
    check_every: int = 1,
    interpret: bool = True,
    donate: bool | None = None,
    solver: str = "auto",
    verbose: bool = False,
) -> CPDResult:
    """Device-resident CPD-ALS.  Same initialization and update order as the
    host-loop ``cpd_als`` (identical seed ⇒ matching trajectories up to f32
    vs f64 solver precision), but the whole sweep runs as one compiled XLA
    computation and the host syncs only every ``check_every`` iterations."""
    t_start = time.perf_counter()
    rng = np.random.default_rng(seed)
    N = tensor.nmodes
    if plan is None:
        plan = make_plan(tensor, kappa)
    check_every = max(1, int(check_every))

    factors = tuple(
        jnp.asarray(rng.standard_normal((I, rank)).astype(np.float32))
        for I in tensor.shape
    )
    grams = tuple(F.T @ F for F in factors)
    weights = jnp.ones((rank,), jnp.float32)
    state = (factors, grams, weights)

    if donate is None:
        # Buffer donation is a no-op (with a warning) on CPU.
        donate = jax.default_backend() != "cpu"
    if solver == "auto":
        solver = "cho" if jax.default_backend() != "cpu" else "inv"
    if solver not in ("cho", "inv"):
        raise ValueError(f"unknown solver {solver!r}")

    mode_data_all, pallas_meta = _collect_mode_data(plan, backend, rank)
    norm_x_sq = tensor.norm() ** 2
    fit_data = (
        jnp.asarray(tensor.indices),
        jnp.asarray(tensor.values.astype(np.float32)),
        jnp.asarray(norm_x_sq, jnp.float32),
    )

    sweep = _build_sweep(
        backend, N, rank, tuple(int(s) for s in tensor.shape),
        pallas_meta, bool(interpret), bool(donate), solver,
    )

    fits_dev: list = []
    host_syncs = 0
    last_fit = -np.inf
    it = 0
    for it in range(1, n_iters + 1):
        state, fit = sweep(state, mode_data_all, fit_data)
        fits_dev.append(fit)
        if it % check_every == 0 or it == n_iters:
            f = float(fit)                      # the only in-loop host sync
            host_syncs += 1
            if verbose:
                print(f"  ALS iter {it:3d}: fit={f:.6f} (fused)")
            if abs(f - last_fit) < tol:
                break
            last_fit = f

    host_syncs += 1                             # final materialization
    # One batched device_get for the whole run (not a fetch per iteration),
    # so host_syncs honestly reflects the transfer count.
    fits = [float(f) for f in jax.device_get(fits_dev)]
    return CPDResult(
        factors=[np.asarray(F) for F in state[0]],
        weights=np.asarray(state[2], dtype=np.float64),
        fits=fits,
        iters=it,
        mttkrp_seconds=0.0,                     # fused: not separable
        total_seconds=time.perf_counter() - t_start,
        host_syncs=host_syncs,
        engine="fused",
    )
