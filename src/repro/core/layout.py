"""Mode-specific tensor layouts (paper §III — the core contribution).

For every mode d of the input tensor we build a dedicated copy whose
nonzeros are ordered for mode-d-as-output execution:

  * scheme 1: sorted by (owning partition, output row) — each partition's
    slice is contiguous AND row-sorted, so the update is a segmented
    reduction entirely local to the partition (no cross-partition output
    traffic; the TPU analogue of the paper's SM-local atomic update).
  * scheme 2: sorted by output row, split into equal-nnz slices — each
    partition produces a dense partial output that is summed (psum),
    the TPU analogue of global atomics.

Output rows are *relabeled* so each scheme-1 partition owns a contiguous
row range [row_lo, row_hi).  The kernel computes in relabeled space; the
MTTKRP wrapper permutes rows back at the end (one (I_d, R) gather per
mode, amortized over the whole ALS sweep — this plays the role of the
paper's free choice of vertex ordering).

All of this is host-side preprocessing, done once per tensor and reused
across every ALS iteration, mirroring the paper's preprocessing stage.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .coo import SparseTensor
from .load_balance import Partitioning, Scheme, partition_mode


@dataclasses.dataclass(frozen=True)
class ModeLayout:
    """Mode-d copy of the tensor, execution-ready.

    Attributes:
      mode: output mode d.
      shape: dense tensor shape.
      scheme: load-balancing scheme used.
      kappa: number of partitions (devices or kernel blocks).
      indices: (nnz, N) int32 — COO indices permuted into execution order.
        Input-mode columns keep their ORIGINAL labels (they index input
        factor matrices directly); the output-mode column also keeps the
        original label (use ``rows`` for the relabeled one).
      rows: (nnz,) int32 — RELABELED output row per nonzero (sorted within
        each partition).
      values: (nnz,) float32 — values permuted into execution order.
      perm: (nnz,) int64 — permutation from the canonical COO order.
      part_offsets: (kappa+1,) int64 — nnz slice per partition.
      row_perm: (I_d,) int32 — relabeled row -> original row id.
      row_lo/row_hi: (kappa,) int32 — relabeled row range owned per
        partition (scheme 1); scheme 2 shares [0, I_d) for all.
      row_ptr: (I_d+1,) int64 — CSR-style offsets of each relabeled row in
        the permuted nnz arrays (valid because rows are sorted per
        partition and partitions own disjoint contiguous relabeled ranges
        under scheme 1; under scheme 2 rows are globally sorted).
    """

    mode: int
    shape: tuple[int, ...]
    scheme: Scheme
    kappa: int
    indices: np.ndarray
    rows: np.ndarray
    values: np.ndarray
    perm: np.ndarray
    part_offsets: np.ndarray
    row_perm: np.ndarray
    row_lo: np.ndarray
    row_hi: np.ndarray
    row_ptr: np.ndarray

    @property
    def nnz(self) -> int:
        return int(self.values.shape[0])

    @property
    def nmodes(self) -> int:
        return len(self.shape)

    @property
    def num_rows(self) -> int:
        return int(self.shape[self.mode])

    def input_modes(self) -> list[int]:
        return [w for w in range(self.nmodes) if w != self.mode]

    def unrelabel_rows(self, out_relabeled: np.ndarray) -> np.ndarray:
        """Map a kernel output in relabeled row space back to original rows."""
        out = np.empty_like(out_relabeled)
        out[self.row_perm] = out_relabeled
        return out

    def nbytes(self, float_bits: int = 32) -> int:
        """Paper §III-C memory model: sum_h log2(I_h) + beta_float per nnz,
        rounded up to the practical int32/float32 arrays we actually store."""
        return self.indices.nbytes + self.values.nbytes + self.rows.nbytes


def build_mode_layout(
    tensor: SparseTensor,
    mode: int,
    kappa: int,
    *,
    scheme: Scheme | None = None,
    assignment: str = "greedy",
    policy: str = "threshold",
) -> ModeLayout:
    """Construct the mode-``mode`` copy partitioned across ``kappa`` units.

    policy (when scheme is None): 'threshold' = the paper's adaptive rule;
    'cost' = beyond-paper cost-model argmin (load_balance.scheme_cost).
    """
    if scheme is None and policy == "cost":
        from .load_balance import choose_scheme_cost_based

        scheme = choose_scheme_cost_based(tensor, mode, kappa,
                                          assignment=assignment)
    part: Partitioning = partition_mode(
        tensor, mode, kappa, scheme=scheme, assignment=assignment
    )
    I_d = tensor.shape[mode]
    idx_perm = tensor.indices[part.perm]
    val_perm = tensor.values[part.perm]

    if part.scheme == Scheme.INDEX_PARTITION:
        assert part.vertex_part is not None
        # Relabel rows: sort rows by (partition, original id); rank = new id.
        row_order = np.lexsort((np.arange(I_d), part.vertex_part))
        row_perm = row_order.astype(np.int32)          # new -> old
        row_rank = np.empty(I_d, dtype=np.int32)       # old -> new
        row_rank[row_order] = np.arange(I_d, dtype=np.int32)
        rows = row_rank[idx_perm[:, mode]]
        # Contiguous relabeled row range per partition.
        counts = np.bincount(part.vertex_part, minlength=kappa)
        row_hi = np.cumsum(counts).astype(np.int32)
        row_lo = (row_hi - counts).astype(np.int32)
    else:
        row_perm = np.arange(I_d, dtype=np.int32)
        rows = idx_perm[:, mode].astype(np.int32)
        row_lo = np.zeros(kappa, dtype=np.int32)
        row_hi = np.full(kappa, I_d, dtype=np.int32)

    # rows must be globally sorted: scheme 2 sorts by row; scheme 1 sorts by
    # (partition, row) and partitions own increasing relabeled ranges.
    row_ptr = np.zeros(I_d + 1, dtype=np.int64)
    np.cumsum(np.bincount(rows, minlength=I_d), out=row_ptr[1:])

    return ModeLayout(
        mode=mode,
        shape=tensor.shape,
        scheme=part.scheme,
        kappa=kappa,
        indices=idx_perm.astype(np.int32),
        rows=rows.astype(np.int32),
        values=val_perm,
        perm=part.perm,
        part_offsets=part.offsets,
        row_perm=row_perm,
        row_lo=row_lo,
        row_hi=row_hi,
        row_ptr=row_ptr,
    )


def build_all_mode_layouts(
    tensor: SparseTensor,
    kappa: int,
    *,
    scheme: Scheme | None = None,
    assignment: str = "greedy",
    policy: str = "threshold",
) -> list[ModeLayout]:
    """The paper's full mode-specific format: one execution-ready copy per mode."""
    return [
        build_mode_layout(tensor, d, kappa, scheme=scheme,
                          assignment=assignment, policy=policy)
        for d in range(tensor.nmodes)
    ]


def format_memory_report(tensor: SparseTensor, layouts: list[ModeLayout]) -> dict:
    """Fig-5-style memory accounting: N copies + factor matrices (R=32 fp32)."""
    R = 32
    copies = sum(l.nbytes() for l in layouts)
    factors = sum(int(I) * R * 4 for I in tensor.shape)
    # Paper's analytic model: |x|_bits = sum_h log2(I_h) + 32 bits per nnz.
    analytic_bits_per_nnz = sum(np.log2(max(2, I)) for I in tensor.shape) + 32
    analytic = int(tensor.nmodes * tensor.nnz * analytic_bits_per_nnz / 8)
    return {
        "nnz": tensor.nnz,
        "copies_bytes": int(copies),
        "factors_bytes": int(factors),
        "total_bytes": int(copies + factors),
        "analytic_copies_bytes": analytic,
    }
