"""spMTTKRP engines over mode-specific layouts.

Backends:
  'segment' — vectorized jnp: fused gather–Hadamard–segment_sum on the
              sorted layout.  Production CPU path and kernel oracle.
  'pallas'  — the TPU Pallas kernel (interpret=True on CPU).
  'coo'     — unsorted elementwise formulation (naive baseline; materializes
              the (nnz, R) intermediate the paper eliminates).

All backends return the output factor in ORIGINAL row order, f32.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import numpy as np

import jax
import jax.numpy as jnp

from ..kernels import ops as kops
from ..kernels import ref as kref
from ..kernels.mttkrp_pallas import mttkrp_pallas
from . import plan as plan_mod
from .coo import SparseTensor
from .layout import ModeLayout, build_all_mode_layouts
from .load_balance import Scheme


@dataclasses.dataclass
class MTTKRPPlan:
    """Preprocessing product: all mode copies + (lazily) packed slabs.

    This is the paper's "mode-specific tensor format": built once, reused
    for every ALS iteration along every mode.  When a ``partition``
    (``core.plan.PartitionPlan``) is attached, every packing follows its
    static per-mode decisions — same plan in, same array shapes out, which
    is what lets the sequential path produce bit-identical results to the
    plan's vmapped and distributed consumers.
    """

    tensor: SparseTensor
    kappa: int
    layouts: list[ModeLayout]
    assignment: str = "greedy"
    block_rows: int = kops.DEFAULT_BLOCK_ROWS
    tile: int = kops.DEFAULT_TILE
    partition: plan_mod.PartitionPlan | None = None
    _packed: dict[int, kops.PackedModeLayout] = dataclasses.field(default_factory=dict)
    _dev_arrays: dict[int, tuple] = dataclasses.field(default_factory=dict)
    _dev_packed: dict[int, tuple] = dataclasses.field(default_factory=dict)
    _dev_coo: tuple | None = None

    def packed(self, mode: int) -> kops.PackedModeLayout:
        if mode not in self._packed:
            if self.partition is not None:
                mp = self.partition.modes[mode]
                self._packed[mode] = kops.pack_layout(
                    self.layouts[mode], block_rows=mp.block_rows,
                    tile=mp.tile, num_slabs_cap=mp.slab_cap,
                )
            else:
                self._packed[mode] = kops.pack_layout(
                    self.layouts[mode], block_rows=self.block_rows,
                    tile=self.tile,
                )
        return self._packed[mode]

    def mode_plan(self, mode: int, rank: int) -> plan_mod.ModePlan:
        """The static per-mode plan this tensor executes under: the
        attached partition plan when present (bucket semantics), else a
        per-layout plan pinned to the actual packing's tiling.  All
        rank-block decisions flow through here (core.plan's cost model)."""
        if self.partition is not None and self.partition.rank == rank:
            return self.partition.modes[mode]
        p = self.packed(mode)
        return plan_mod.plan_layout(self.layouts[mode], rank,
                                    block_rows=p.block_rows, tile=p.tile)

    def device_arrays(self, mode: int):
        """Layout arrays as jnp device arrays (cached)."""
        if mode not in self._dev_arrays:
            lay = self.layouts[mode]
            in_modes = lay.input_modes()
            self._dev_arrays[mode] = (
                jnp.asarray(lay.indices[:, in_modes]),
                jnp.asarray(lay.rows),
                jnp.asarray(lay.values),
                jnp.asarray(lay.row_perm),
            )
        return self._dev_arrays[mode]

    def device_packed(self, mode: int) -> tuple:
        """Packed slab arrays as jnp device arrays (cached): uploaded once,
        reused by every pallas-backend call and the fused ALS engine."""
        if mode not in self._dev_packed:
            p = self.packed(mode)
            self._dev_packed[mode] = (
                jnp.asarray(p.rb_of),
                jnp.asarray(p.first),
                jnp.asarray(p.idx_packed),
                jnp.asarray(p.vals_packed),
                jnp.asarray(p.lrows_packed),
            )
        return self._dev_packed[mode]

    def device_coo(self) -> tuple:
        """COO indices/values as jnp device arrays (cached): the coo backend
        previously re-uploaded both from host numpy on every call."""
        if self._dev_coo is None:
            self._dev_coo = (
                jnp.asarray(self.tensor.indices),
                jnp.asarray(self.tensor.values),
            )
        return self._dev_coo


def make_plan(
    tensor: SparseTensor,
    kappa: int,
    *,
    scheme: Scheme | None = None,
    assignment: str = "greedy",
    policy: str = "threshold",
    block_rows: int = kops.DEFAULT_BLOCK_ROWS,
    tile: int = kops.DEFAULT_TILE,
    partition: plan_mod.PartitionPlan | None = None,
) -> MTTKRPPlan:
    layouts = build_all_mode_layouts(
        tensor, kappa, scheme=scheme, assignment=assignment, policy=policy
    )
    return MTTKRPPlan(
        tensor=tensor,
        kappa=kappa,
        layouts=layouts,
        assignment=assignment,
        block_rows=block_rows,
        tile=tile,
        partition=partition,
    )


@functools.partial(jax.jit, static_argnames=("num_rows",))
def _segment_backend(input_indices, rows, values, factors, row_perm, num_rows):
    out_rel = kref.mttkrp_sorted_segments(
        input_indices, rows, values, list(factors), num_rows
    )
    # relabeled -> original rows: out[row_perm[i]] = out_rel[i]
    return jnp.zeros_like(out_rel).at[row_perm].set(out_rel)


@functools.partial(jax.jit, static_argnames=("mode", "num_rows"))
def _coo_backend(indices, values, factors, mode, num_rows):
    return kref.mttkrp_coo(indices, values, list(factors), mode, num_rows)


def mttkrp(
    plan: MTTKRPPlan,
    factors: Sequence[jnp.ndarray],
    mode: int,
    *,
    backend: str = "segment",
    interpret: bool = True,
    rank_block: int | None = None,
) -> jnp.ndarray:
    """MTTKRP along ``mode``: returns (I_mode, R) f32 in original row order."""
    lay = plan.layouts[mode]
    in_modes = lay.input_modes()
    in_factors = [factors[w] for w in in_modes]

    if backend == "segment":
        idx, rows, vals, row_perm = plan.device_arrays(mode)
        return _segment_backend(
            idx, rows, vals, tuple(in_factors), row_perm, lay.num_rows
        )
    if backend == "pallas":
        packed = plan.packed(mode)
        if rank_block is None:
            rank = int(in_factors[0].shape[1])
            rank_block = plan.mode_plan(mode, rank).rank_block
        rb_of, first, idxp, valsp, lrowsp = plan.device_packed(mode)
        out_rel = mttkrp_pallas(
            rb_of, first, idxp, valsp, lrowsp, in_factors,
            num_row_blocks=packed.num_row_blocks,
            block_rows=packed.block_rows, tile=packed.tile,
            rank_block=rank_block, interpret=interpret,
        )[: packed.num_rows]
        return jnp.zeros_like(out_rel).at[jnp.asarray(lay.row_perm)].set(out_rel)
    if backend == "coo":
        indices, values = plan.device_coo()
        return _coo_backend(
            indices, values,
            tuple(jnp.asarray(f) for f in factors),
            mode, lay.num_rows,
        )
    raise ValueError(f"unknown backend {backend!r}")


def mttkrp_dense_ref(tensor: SparseTensor, factors: Sequence[np.ndarray], mode: int) -> np.ndarray:
    return kref.mttkrp_dense(tensor, list(factors), mode)
