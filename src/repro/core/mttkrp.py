"""spMTTKRP engines over mode-specific layouts.

Backends:
  'segment' — vectorized jnp: fused gather–Hadamard–segment_sum on the
              sorted layout.  Production CPU path and kernel oracle.
  'pallas'  — the TPU Pallas kernel (interpret=True on CPU).
  'coo'     — unsorted elementwise formulation (naive baseline; materializes
              the (nnz, R) intermediate the paper eliminates).

All backends return the output factor in ORIGINAL row order, f32.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import numpy as np

import jax
import jax.numpy as jnp

from ..kernels import ops as kops
from ..kernels import ref as kref
from .coo import SparseTensor
from .layout import ModeLayout, build_all_mode_layouts
from .load_balance import Scheme


@dataclasses.dataclass
class MTTKRPPlan:
    """Preprocessing product: all mode copies + (lazily) packed slabs.

    This is the paper's "mode-specific tensor format": built once, reused
    for every ALS iteration along every mode.
    """

    tensor: SparseTensor
    kappa: int
    layouts: list[ModeLayout]
    assignment: str = "greedy"
    block_rows: int = kops.DEFAULT_BLOCK_ROWS
    tile: int = kops.DEFAULT_TILE
    _packed: dict[int, kops.PackedModeLayout] = dataclasses.field(default_factory=dict)
    _dev_arrays: dict[int, tuple] = dataclasses.field(default_factory=dict)

    def packed(self, mode: int) -> kops.PackedModeLayout:
        if mode not in self._packed:
            self._packed[mode] = kops.pack_layout(
                self.layouts[mode], block_rows=self.block_rows, tile=self.tile
            )
        return self._packed[mode]

    def device_arrays(self, mode: int):
        """Layout arrays as jnp device arrays (cached)."""
        if mode not in self._dev_arrays:
            lay = self.layouts[mode]
            in_modes = lay.input_modes()
            self._dev_arrays[mode] = (
                jnp.asarray(lay.indices[:, in_modes]),
                jnp.asarray(lay.rows),
                jnp.asarray(lay.values),
                jnp.asarray(lay.row_perm),
            )
        return self._dev_arrays[mode]


def make_plan(
    tensor: SparseTensor,
    kappa: int,
    *,
    scheme: Scheme | None = None,
    assignment: str = "greedy",
    policy: str = "threshold",
    block_rows: int = kops.DEFAULT_BLOCK_ROWS,
    tile: int = kops.DEFAULT_TILE,
) -> MTTKRPPlan:
    layouts = build_all_mode_layouts(
        tensor, kappa, scheme=scheme, assignment=assignment, policy=policy
    )
    return MTTKRPPlan(
        tensor=tensor,
        kappa=kappa,
        layouts=layouts,
        assignment=assignment,
        block_rows=block_rows,
        tile=tile,
    )


@functools.partial(jax.jit, static_argnames=("num_rows",))
def _segment_backend(input_indices, rows, values, factors, row_perm, num_rows):
    out_rel = kref.mttkrp_sorted_segments(
        input_indices, rows, values, list(factors), num_rows
    )
    # relabeled -> original rows: out[row_perm[i]] = out_rel[i]
    return jnp.zeros_like(out_rel).at[row_perm].set(out_rel)


def mttkrp(
    plan: MTTKRPPlan,
    factors: Sequence[jnp.ndarray],
    mode: int,
    *,
    backend: str = "segment",
    interpret: bool = True,
) -> jnp.ndarray:
    """MTTKRP along ``mode``: returns (I_mode, R) f32 in original row order."""
    lay = plan.layouts[mode]
    in_modes = lay.input_modes()
    in_factors = [factors[w] for w in in_modes]

    if backend == "segment":
        idx, rows, vals, row_perm = plan.device_arrays(mode)
        return _segment_backend(
            idx, rows, vals, tuple(in_factors), row_perm, lay.num_rows
        )
    if backend == "pallas":
        packed = plan.packed(mode)
        out_rel = kops.mttkrp_packed(packed, in_factors, interpret=interpret)
        return jnp.zeros_like(out_rel).at[jnp.asarray(lay.row_perm)].set(out_rel)
    if backend == "coo":
        return kref.mttkrp_coo(
            jnp.asarray(plan.tensor.indices),
            jnp.asarray(plan.tensor.values),
            [jnp.asarray(f) for f in factors],
            mode,
            lay.num_rows,
        )
    raise ValueError(f"unknown backend {backend!r}")


def mttkrp_dense_ref(tensor: SparseTensor, factors: Sequence[np.ndarray], mode: int) -> np.ndarray:
    return kref.mttkrp_dense(tensor, list(factors), mode)
