"""Distributed spMTTKRP + fused CPD-ALS via shard_map — κ partitions ↦ κ devices.

The paper maps κ tensor partitions onto κ GPU SMs.  Here κ is the device
count of a 1-D mesh axis (named "sm" in homage).  Per-device shards come
from the single planning layer (``core.plan.build_device_shards``): each
device holds a rectangular, zero-padded slice of the mode layout with
GLOBAL relabeled rows, computes a partial (I_d, R) MTTKRP, and a single
``psum`` combines the partials:

  Scheme 1 (I_d ≥ κ): partials have disjoint row support, so the psum is
    mathematically a concatenation — but it still transfers the full
    (I_d, R) array per device.  A row-sharded output path that skips the
    collective entirely (the paper's "local atomics only" property, which
    the pre-plan host loop kept) is a recorded ROADMAP follow-up; the
    unified psum buys one executable for both schemes and the fused
    window in exchange.
  Scheme 2 (I_d < κ): partials overlap and the psum genuinely reduces —
    the analogue of global atomics, chosen exactly when I_d < κ so the
    payload is tiny.

``cpd_als_distributed`` is the fused engine's distributed twin: it runs
``core.als_device.build_sweep_fn(axis="sm")`` — the SAME closure-free
sweep the sequential and batched engines execute, with psums at the two
shard-crossing points — under ``shard_map``, scanning a whole
``check_every`` window as ONE dispatch.  The host syncs only at window
boundaries (the fit scalar), never inside a window: zero per-iteration
host traffic, matching the single-device fused engine's contract.

Decomposition methods ride the same path (``method=``): value-baked
sweeps (cp, nncp) reuse the standard 4-array mode shards unchanged,
while valued/weighted methods (masked completion) get shards that also
carry full coordinates, values, and per-entry observation weights
(``core.plan.DeviceShards.idx_full`` / ``.ew``) — each device evaluates
the per-sweep residual at its own shard's coordinates from the
replicated factors, the partial residual MTTKRPs psum, the closed-form
dense correction is replicated-exact (no collective), and the weighted
fit psums per-shard residual mass.  ``weights=`` threads user-supplied
fractional observation confidences through the shards, matching the
sequential and batched front doors to fp32 tolerance (pinned by
``tests/conformance``).
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P
try:
    from jax import shard_map
except ImportError:  # older jax keeps shard_map under experimental
    from jax.experimental.shard_map import shard_map

from ..obs import clock as obs_clock
from ..obs import trace as obs_trace
from ..obs.ledger import LEDGER as _LEDGER
from . import plan as plan_mod
from .als_device import (_host_state_to_device, _method_spec,
                         build_sweep_fn, normalize_entry_weights,
                         resolve_solver, validate_entry_weights)
from .als_device import init_state as _device_init_state
from .coo import SparseTensor
from .cpd import CPDResult
from .layout import build_mode_layout
from .load_balance import Scheme

AXIS = "sm"


@dataclasses.dataclass
class DistributedPlan:
    """All-modes distributed plan over a 1-D device mesh: one
    ``core.plan.DeviceShards`` per mode plus sharded fit data.

    ``method`` is part of the plan identity: valued/weighted methods
    (masked) shard different arrays (full coordinates + entry weights),
    so a plan built for one method cannot silently serve another."""

    tensor: SparseTensor
    mesh: Mesh
    modes: list[plan_mod.DeviceShards]
    fit_shards: tuple  # (idx (κ,per,N), vals (κ,per)[, ew], norm_sq (κ,))
    method: str = "cp"

    @property
    def kappa(self) -> int:
        return self.mesh.devices.size


def make_distributed_plan(
    tensor: SparseTensor,
    mesh: Mesh | None = None,
    *,
    scheme: Scheme | None = None,
    assignment: str = "greedy",
    method: str = "cp",
    weights: np.ndarray | None = None,
) -> DistributedPlan:
    """Build per-device shards for ``method``.  Value-baked methods get
    the standard structural shards; valued/weighted ones (masked) get
    shards carrying full coordinates and per-entry observation weights
    (``weights=`` — canonical COO order, defaulting to all-ones; padding
    slots are weight 0, the exact-no-op mechanism)."""
    if mesh is None:
        mesh = Mesh(np.array(jax.devices()), (AXIS,))
    spec = _method_spec(method)
    structural = spec is not None and spec.valued_mode_data
    weighted = spec is not None and spec.weighted_fit
    if weights is not None:
        if not weighted:
            raise ValueError(
                f"per-entry weights require a weighted-fit method "
                f"(e.g. 'masked'), got method={method!r}")
        weights = normalize_entry_weights(
            validate_entry_weights(tensor.nnz, weights))
    ew_full = None
    if weighted:
        ew_full = (np.ones(tensor.nnz, np.float32) if weights is None
                   else weights)
    κ = int(mesh.devices.size)
    modes = []
    for d in range(tensor.nmodes):
        lay = build_mode_layout(tensor, d, κ, scheme=scheme,
                                assignment=assignment)
        modes.append(plan_mod.build_device_shards(
            lay,
            weights=ew_full if structural else None,
            with_full_indices=structural,
        ))
    fit = plan_mod.shard_fit_data(tensor, κ, weights=ew_full)
    return DistributedPlan(tensor=tensor, mesh=mesh, modes=modes,
                           fit_shards=fit, method=method)


# ---------------------------------------------------------------------------
# One-shot distributed MTTKRP (kept for benchmarks / the kernel oracle)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("num_rows", "mesh_"))
def _dist_mttkrp(idx, rows, vals, factors, num_rows, mesh_):
    """shard_map body dispatcher (jitted once per shape)."""
    from ..kernels import ref as kref

    def body(idx_s, rows_s, vals_s, *facs):
        out = kref.mttkrp_sorted_segments(
            idx_s[0], rows_s[0], vals_s[0], list(facs), num_rows
        )
        return lax.psum(out, AXIS)

    in_specs = (P(AXIS), P(AXIS), P(AXIS)) + tuple(P() for _ in factors)
    fn = shard_map(body, mesh=mesh_, in_specs=in_specs, out_specs=P(),
                   check_rep=False)
    return fn(idx, rows, vals, *factors)


def mttkrp_distributed(
    plan: DistributedPlan,
    factors,
    mode: int,
) -> jnp.ndarray:
    """Distributed MTTKRP along ``mode``; returns (I_d, R) f32, original rows."""
    m = plan.modes[mode]
    facs = tuple(jnp.asarray(factors[w]) for w in m.input_modes)
    out = _dist_mttkrp(
        jnp.asarray(m.idx),
        jnp.asarray(m.rows),
        jnp.asarray(m.vals),
        facs,
        num_rows=m.num_rows,
        mesh_=plan.mesh,
    )
    # relabeled -> original rows (replicated output, replicated gather).
    return jnp.zeros_like(out).at[jnp.asarray(m.row_perm[0])].set(out)


# ---------------------------------------------------------------------------
# Fused distributed ALS (shard_map of the one-dispatch-per-window sweep)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _build_dist_sweep_block(mesh_: Mesh, nmodes: int, rank: int,
                            shapes: tuple[int, ...], solver: str,
                            block: int, method: str = "cp",
                            mode_widths: tuple[int, ...] = None,
                            fit_width: int = 3,
                            collectives: tuple[str, ...] | None = None):
    """Jitted shard_map of ``block`` consecutive distributed sweeps.

    The body squeezes each device's leading shard dim and scans the SAME
    sweep the fused engine uses (``build_sweep_fn`` with ``axis=AXIS``):
    the whole check window is one dispatch, partial MTTKRPs combine
    inside it, and state stays replicated (identical on every device
    because the collective outputs are identical).  Cached per (mesh,
    shapes, rank, solver, window, method, collectives) — shard caps live
    in the array shapes, so same-class tensors reuse the executable.

    ``mode_widths`` / ``fit_width``: how many sharded arrays each mode /
    the fit contract contributes — 4/3 per mode for value-baked psum
    sweeps (cp, nncp), 6 for a gather-collective mode (owned-row slice
    and destination map ride along), 6/4 for the valued+weighted masked
    contract (full coordinates and entry weights).
    ``collectives``: per-mode "psum"/"gather" choice forwarded to
    ``build_sweep_fn`` (gather = the scheme-1 payload fix)."""
    if mode_widths is None:
        mode_widths = (4,) * nmodes
    sweep = build_sweep_fn("segment", nmodes, rank, shapes, None, True,
                           solver, axis=AXIS, method=method,
                           collectives=collectives)
    offs = [0]
    for w in mode_widths:
        offs.append(offs[-1] + w)

    def body(state, *flat):
        md = tuple(
            tuple(jnp.squeeze(a, 0) for a in flat[offs[d]: offs[d + 1]])
            for d in range(nmodes)
        )
        fd = tuple(jnp.squeeze(a, 0) for a in flat[offs[-1]:])

        def step(st, _):
            return sweep(st, md, fd)

        state, fits = lax.scan(step, state, xs=None, length=block)
        return state, fits

    n_sharded = offs[-1] + fit_width
    fn = shard_map(
        body, mesh=mesh_,
        in_specs=(P(),) + tuple(P(AXIS) for _ in range(n_sharded)),
        out_specs=(P(), P()),
        check_rep=False,
    )
    return _LEDGER.register(
        "dist_block",
        (nmodes, rank, shapes, "kappa", int(mesh_.devices.size),
         "block", block, "method", method, "collectives", collectives),
        jax.jit(fn))


def resolve_collectives(plan: DistributedPlan,
                        collective: str) -> tuple[str, ...] | None:
    """Per-mode collective tuple for ``collective`` ("psum" | "gather").

    "gather" applies per mode only where the shards support it (scheme 1,
    value-baked): scheme-2 modes keep the psum (their partials genuinely
    overlap), so a mixed-scheme tensor still benefits on the modes that
    can.  Returns None for the pure-psum configuration so the executable
    cache key (and hence every pre-existing cache entry) is unchanged."""
    if collective == "psum":
        return None
    if collective != "gather":
        raise ValueError(f"unknown collective {collective!r}")
    if plan.modes[0].idx_full is not None:
        raise ValueError(
            "collective='gather' supports value-baked methods only "
            "(cp, nncp); the valued/weighted contract psums residual "
            "MTTKRPs")
    out = tuple("gather" if m.own_rows is not None else "psum"
                for m in plan.modes)
    return out


def collective_payload_bytes(plan: DistributedPlan, rank: int,
                             collectives: tuple[str, ...] | None) -> int:
    """Bytes crossing the mesh per sweep to combine the N mode outputs:
    psum moves every device's full (I_d, R) partial; gather moves each
    device's (rows_cap, R) owned slice plus its int32 destination map."""
    κ = plan.kappa
    total = 0
    for d, m in enumerate(plan.modes):
        if collectives is not None and collectives[d] == "gather":
            total += κ * m.rows_cap * (rank * 4 + 4)
        else:
            total += κ * m.num_rows * rank * 4
    return int(total)


def _collect_dist_data(plan: DistributedPlan,
                       collectives: tuple[str, ...] | None = None):
    """Flat per-mode + fit device arrays in the order the sweep expects:
    ``(idx, rows, vals, row_perm)`` per mode for value-baked psum sweeps
    (``+ (own_rows, gather_map)`` for gather-collective modes),
    ``(idx, rows, row_perm, idx_full, vals, ew)`` for the valued/weighted
    masked contract (see ``methods.masked``).  Also returns the per-mode
    widths for the flat-arg slicing."""
    flat = []
    widths = []
    for d, m in enumerate(plan.modes):
        if m.idx_full is not None:
            flat += [jnp.asarray(m.idx), jnp.asarray(m.rows),
                     jnp.asarray(m.row_perm), jnp.asarray(m.idx_full),
                     jnp.asarray(m.vals), jnp.asarray(m.ew)]
            widths.append(6)
        elif collectives is not None and collectives[d] == "gather":
            flat += [jnp.asarray(m.idx), jnp.asarray(m.rows),
                     jnp.asarray(m.vals), jnp.asarray(m.row_perm),
                     jnp.asarray(m.own_rows), jnp.asarray(m.gather_map)]
            widths.append(6)
        else:
            flat += [jnp.asarray(m.idx), jnp.asarray(m.rows),
                     jnp.asarray(m.vals), jnp.asarray(m.row_perm)]
            widths.append(4)
    flat += [jnp.asarray(a) for a in plan.fit_shards]
    return flat, tuple(widths)


def cpd_als_distributed(
    tensor: SparseTensor,
    rank: int,
    mesh: Mesh | None = None,
    *,
    plan: DistributedPlan | None = None,
    n_iters: int = 25,
    tol: float = 1e-5,
    seed: int = 0,
    check_every: int = 1,
    solver: str = "auto",
    method: str = "cp",
    weights: np.ndarray | None = None,
    init_state: tuple | None = None,
    collective: str = "psum",
    verbose: bool = False,
) -> CPDResult:
    """Distributed CPD-ALS: the fused one-dispatch-per-window sweep under
    shard_map.  Same init and update order as single-device ``cpd_als``
    (identical seed ⇒ matching factors to fp32 tolerance); the host
    fetches only the window-boundary fit scalar — zero per-iteration
    syncs inside a check window.

    ``method`` selects the decomposition method (sweep-based methods
    only: cp, nncp, masked); ``weights`` threads per-entry observation
    confidences through the shards for weighted-fit methods; and
    ``init_state`` warm-starts from existing factors — the same contracts
    as the sequential and batched front doors, so the three agree to fp32
    tolerance (``tests/conformance``).

    ``collective`` — how per-device partial mode outputs combine:
    "psum" (default; both schemes) or "gather" (scheme-1 modes all-gather
    just their owned row slices, ~1/kappa of the psum payload; scheme-2
    modes silently keep the psum).  Both produce identical factors up to
    fp32 summation order."""
    t_start = obs_clock.now()
    spec = _method_spec(method)
    if plan is None:
        plan = make_distributed_plan(tensor, mesh, method=method,
                                     weights=weights)
    elif plan.method != method:
        raise ValueError(
            f"distributed plan was built for method {plan.method!r}, "
            f"got method={method!r}; rebuild with make_distributed_plan")
    elif weights is not None:
        raise ValueError(
            "pass weights to make_distributed_plan (they are sharded into "
            "the plan); a prebuilt plan already carries its weights")
    N = tensor.nmodes
    shapes = tuple(int(s) for s in tensor.shape)
    check_every = max(1, int(check_every))
    solver = resolve_solver(solver)

    if init_state is not None:
        state = _host_state_to_device(init_state)
    elif spec is not None and spec.init_state_host is not None:
        state = _host_state_to_device(
            spec.init_state_host(tensor.shape, rank, seed))
    else:
        # (init_state the *parameter* shadows the module-level helper.)
        state = _device_init_state(tensor.shape, rank, seed)
    collectives = resolve_collectives(plan, collective)
    flat, mode_widths = _collect_dist_data(plan, collectives)
    fit_width = len(plan.fit_shards)

    n_blocks, rem = divmod(n_iters, check_every)
    fn_k = _build_dist_sweep_block(plan.mesh, N, rank, shapes, solver,
                                   check_every, method, mode_widths,
                                   fit_width, collectives
                                   ) if n_blocks else None
    fn_rem = _build_dist_sweep_block(plan.mesh, N, rank, shapes, solver,
                                     rem, method, mode_widths,
                                     fit_width, collectives
                                     ) if rem else None

    κ = plan.kappa
    shard_nnz = [int(m.nnz_per_dev) for m in plan.modes]
    fits_dev: list = []
    host_syncs = 0
    last_fit = -np.inf
    it = 0
    tr = obs_trace.active()
    for b in range(n_blocks + (1 if rem else 0)):
        k = check_every if b < n_blocks else rem
        fn = fn_k if b < n_blocks else fn_rem
        # Per-window shard_map dispatch; the span carries the mesh size
        # and the per-mode padded shard nnz so a trace attributes window
        # time to shard load.  Disabled branch: one global read, zero
        # allocations.
        if tr is None:
            state, fits_blk = fn(state, *flat)
            f = float(fits_blk[-1])             # the only in-loop host sync
        else:
            with tr.span("dist.window", cat="dist", method=method,
                         kappa=κ, window=b, sweeps=k,
                         shard_nnz=shard_nnz):
                state, fits_blk = fn(state, *flat)
                f = float(fits_blk[-1])         # the only in-loop host sync
        fits_dev.append(fits_blk)
        it += k
        host_syncs += 1
        if verbose:
            print(f"  ALS iter {it:3d}: fit={f:.6f} (distributed)")
        if abs(f - last_fit) < tol:
            break
        last_fit = f

    host_syncs += 1                             # final materialization
    fits = [float(f) for blk in jax.device_get(fits_dev) for f in blk]
    return CPDResult(
        factors=[np.asarray(F) for F in state[0]],
        weights=np.asarray(state[2], dtype=np.float64),
        fits=fits,
        iters=it,
        mttkrp_seconds=0.0,
        total_seconds=obs_clock.now() - t_start,
        host_syncs=host_syncs,
        engine="distributed",
        method=method,
    )
