"""Distributed spMTTKRP via shard_map — κ partitions ↦ κ devices.

The paper maps κ tensor partitions onto κ GPU SMs.  Here κ is the device
count of a 1-D mesh axis (named "sm" in homage).  The two load-balancing
schemes become two communication patterns:

  Scheme 1 (I_d ≥ κ): each device owns a disjoint, contiguous block of
    *relabeled* output rows and exactly the nonzeros incident on them.
    Output factor shards never leave the device — zero collective traffic
    for the update (the paper's "local atomics only", exceeded: not even
    local atomics, just a segmented reduce).  Input factor matrices are
    replicated (all-gathered once per mode, small in the paper's regime).

  Scheme 2 (I_d < κ): nonzeros are split equally; every device produces a
    dense (I_d, R) partial result and a single psum combines them — the
    TPU-native analogue of the paper's global atomic updates.  Because
    this path is chosen exactly when I_d < κ, the psum payload is tiny.

Preprocessing (`DistributedPlan`) pads per-device slices to a common shape
so shard_map sees rectangular arrays; padding entries carry value 0.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
try:
    from jax import shard_map
except ImportError:  # older jax keeps shard_map under experimental
    from jax.experimental.shard_map import shard_map

from ..kernels import ref as kref
from .coo import SparseTensor
from .layout import ModeLayout, build_mode_layout
from .load_balance import Scheme

AXIS = "sm"


@dataclasses.dataclass(frozen=True)
class DistributedModeArrays:
    """Rectangular per-device arrays for one mode (leading dim = κ)."""

    scheme: Scheme
    num_rows: int                 # I_d
    rows_per_dev: int             # padded relabeled rows per device (scheme 1)
    idx: np.ndarray               # (κ, max_nnz, W) int32 input-mode indices
    rows_local: np.ndarray        # (κ, max_nnz) int32 device-local output rows
    vals: np.ndarray              # (κ, max_nnz) f32 (0 on padding)
    row_gather: np.ndarray        # (I_d, 2) int32: original row -> (device, local row)
    input_modes: tuple[int, ...]


def build_distributed_mode(layout: ModeLayout) -> DistributedModeArrays:
    κ = layout.kappa
    in_modes = layout.input_modes()
    off = layout.part_offsets
    max_nnz = int(np.diff(off).max()) if layout.nnz else 1
    max_nnz = max(max_nnz, 1)
    W = len(in_modes)
    idx = np.zeros((κ, max_nnz, W), np.int32)
    vals = np.zeros((κ, max_nnz), np.float32)
    rows_local = np.zeros((κ, max_nnz), np.int32)

    if layout.scheme == Scheme.INDEX_PARTITION:
        rows_per_dev = int((layout.row_hi - layout.row_lo).max()) if κ else 0
        rows_per_dev = max(rows_per_dev, 1)
    else:
        rows_per_dev = layout.num_rows

    for p in range(κ):
        s, e = int(off[p]), int(off[p + 1])
        n = e - s
        idx[p, :n] = layout.indices[s:e][:, in_modes]
        vals[p, :n] = layout.values[s:e]
        if layout.scheme == Scheme.INDEX_PARTITION:
            rows_local[p, :n] = layout.rows[s:e] - layout.row_lo[p]
        else:
            rows_local[p, :n] = layout.rows[s:e]
        # padding rows point at local row 0 with value 0 — harmless.

    # original row -> (device, local slot) for reassembly (scheme 1).
    row_gather = np.zeros((layout.num_rows, 2), np.int32)
    if layout.scheme == Scheme.INDEX_PARTITION:
        for p in range(κ):
            lo, hi = int(layout.row_lo[p]), int(layout.row_hi[p])
            rel = np.arange(lo, hi)
            orig = layout.row_perm[rel]
            row_gather[orig, 0] = p
            row_gather[orig, 1] = rel - lo
    else:
        row_gather[:, 0] = 0
        row_gather[:, 1] = np.arange(layout.num_rows)

    return DistributedModeArrays(
        scheme=layout.scheme,
        num_rows=layout.num_rows,
        rows_per_dev=rows_per_dev,
        idx=idx,
        rows_local=rows_local,
        vals=vals,
        row_gather=row_gather,
        input_modes=tuple(in_modes),
    )


@dataclasses.dataclass
class DistributedPlan:
    """All-modes distributed MTTKRP plan over a 1-D device mesh."""

    tensor: SparseTensor
    mesh: Mesh
    modes: list[DistributedModeArrays]

    @property
    def kappa(self) -> int:
        return self.mesh.devices.size


def make_distributed_plan(
    tensor: SparseTensor,
    mesh: Mesh | None = None,
    *,
    scheme: Scheme | None = None,
    assignment: str = "greedy",
) -> DistributedPlan:
    if mesh is None:
        mesh = Mesh(np.array(jax.devices()), (AXIS,))
    κ = int(mesh.devices.size)
    modes = []
    for d in range(tensor.nmodes):
        lay = build_mode_layout(tensor, d, κ, scheme=scheme, assignment=assignment)
        modes.append(build_distributed_mode(lay))
    return DistributedPlan(tensor=tensor, mesh=mesh, modes=modes)


@partial(jax.jit, static_argnames=("rows_per_dev", "mesh_", "scheme1"))
def _dist_mttkrp(idx, rows_local, vals, factors, rows_per_dev, mesh_, scheme1):
    """shard_map body dispatcher (jitted once per shape/scheme)."""
    mesh = mesh_

    def body(idx_s, rows_s, vals_s, *facs):
        # idx_s: (1, max_nnz, W); squeeze the device dim.
        out = kref.mttkrp_sorted_segments(
            idx_s[0], rows_s[0], vals_s[0], list(facs), rows_per_dev
        )
        if not scheme1:
            out = jax.lax.psum(out, AXIS)
        return out[None]

    in_specs = (P(AXIS), P(AXIS), P(AXIS)) + tuple(P() for _ in factors)
    out_specs = P(AXIS) if scheme1 else P(None)
    fn = shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    return fn(idx, rows_local, vals, *factors)


def mttkrp_distributed(
    plan: DistributedPlan,
    factors: Sequence[jnp.ndarray],
    mode: int,
) -> jnp.ndarray:
    """Distributed MTTKRP along ``mode``; returns (I_d, R) f32, original rows."""
    m = plan.modes[mode]
    facs = tuple(jnp.asarray(factors[w]) for w in m.input_modes)
    scheme1 = m.scheme == Scheme.INDEX_PARTITION
    out = _dist_mttkrp(
        jnp.asarray(m.idx),
        jnp.asarray(m.rows_local),
        jnp.asarray(m.vals),
        facs,
        rows_per_dev=m.rows_per_dev,
        mesh_=plan.mesh,
        scheme1=scheme1,
    )
    # out: (κ, rows_per_dev, R) for scheme 1; (κ, I_d, R) replicated for 2.
    if scheme1:
        dev = jnp.asarray(m.row_gather[:, 0])
        slot = jnp.asarray(m.row_gather[:, 1])
        return out[dev, slot]
    return out[0]


def cpd_als_distributed(tensor: SparseTensor, rank: int, mesh: Mesh | None = None, **kw):
    """CPD-ALS with the distributed engine (drop-in for core.cpd.cpd_als)."""
    from .cpd import cpd_als

    dplan = make_distributed_plan(tensor, mesh)

    def engine(_plan, factors, mode):
        return mttkrp_distributed(dplan, factors, mode)

    return cpd_als(tensor, rank, mttkrp_fn=engine, **kw)
