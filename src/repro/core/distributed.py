"""Distributed spMTTKRP + fused CPD-ALS via shard_map — κ partitions ↦ κ devices.

The paper maps κ tensor partitions onto κ GPU SMs.  Here κ is the device
count of a 1-D mesh axis (named "sm" in homage).  Per-device shards come
from the single planning layer (``core.plan.build_device_shards``): each
device holds a rectangular, zero-padded slice of the mode layout with
GLOBAL relabeled rows, computes a partial (I_d, R) MTTKRP, and a single
``psum`` combines the partials:

  Scheme 1 (I_d ≥ κ): partials have disjoint row support, so the psum is
    mathematically a concatenation — but it still transfers the full
    (I_d, R) array per device.  A row-sharded output path that skips the
    collective entirely (the paper's "local atomics only" property, which
    the pre-plan host loop kept) is a recorded ROADMAP follow-up; the
    unified psum buys one executable for both schemes and the fused
    window in exchange.
  Scheme 2 (I_d < κ): partials overlap and the psum genuinely reduces —
    the analogue of global atomics, chosen exactly when I_d < κ so the
    payload is tiny.

``cpd_als_distributed`` is the fused engine's distributed twin: it runs
``core.als_device.build_sweep_fn(axis="sm")`` — the SAME closure-free
sweep the sequential and batched engines execute, with psums at the two
shard-crossing points — under ``shard_map``, scanning a whole
``check_every`` window as ONE dispatch.  The host syncs only at window
boundaries (the fit scalar), never inside a window: zero per-iteration
host traffic, matching the single-device fused engine's contract.
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P
try:
    from jax import shard_map
except ImportError:  # older jax keeps shard_map under experimental
    from jax.experimental.shard_map import shard_map

import time

from . import plan as plan_mod
from .als_device import build_sweep_fn, init_state, resolve_solver
from .coo import SparseTensor
from .cpd import CPDResult
from .layout import build_mode_layout
from .load_balance import Scheme

AXIS = "sm"


@dataclasses.dataclass
class DistributedPlan:
    """All-modes distributed plan over a 1-D device mesh: one
    ``core.plan.DeviceShards`` per mode plus sharded fit data."""

    tensor: SparseTensor
    mesh: Mesh
    modes: list[plan_mod.DeviceShards]
    fit_shards: tuple  # (idx (κ,per,N), vals (κ,per), norm_sq (κ,))

    @property
    def kappa(self) -> int:
        return self.mesh.devices.size


def make_distributed_plan(
    tensor: SparseTensor,
    mesh: Mesh | None = None,
    *,
    scheme: Scheme | None = None,
    assignment: str = "greedy",
) -> DistributedPlan:
    if mesh is None:
        mesh = Mesh(np.array(jax.devices()), (AXIS,))
    κ = int(mesh.devices.size)
    modes = []
    for d in range(tensor.nmodes):
        lay = build_mode_layout(tensor, d, κ, scheme=scheme,
                                assignment=assignment)
        modes.append(plan_mod.build_device_shards(lay))
    fit = plan_mod.shard_fit_data(tensor, κ)
    return DistributedPlan(tensor=tensor, mesh=mesh, modes=modes,
                           fit_shards=fit)


# ---------------------------------------------------------------------------
# One-shot distributed MTTKRP (kept for benchmarks / the kernel oracle)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("num_rows", "mesh_"))
def _dist_mttkrp(idx, rows, vals, factors, num_rows, mesh_):
    """shard_map body dispatcher (jitted once per shape)."""
    from ..kernels import ref as kref

    def body(idx_s, rows_s, vals_s, *facs):
        out = kref.mttkrp_sorted_segments(
            idx_s[0], rows_s[0], vals_s[0], list(facs), num_rows
        )
        return lax.psum(out, AXIS)

    in_specs = (P(AXIS), P(AXIS), P(AXIS)) + tuple(P() for _ in factors)
    fn = shard_map(body, mesh=mesh_, in_specs=in_specs, out_specs=P(),
                   check_rep=False)
    return fn(idx, rows, vals, *factors)


def mttkrp_distributed(
    plan: DistributedPlan,
    factors,
    mode: int,
) -> jnp.ndarray:
    """Distributed MTTKRP along ``mode``; returns (I_d, R) f32, original rows."""
    m = plan.modes[mode]
    facs = tuple(jnp.asarray(factors[w]) for w in m.input_modes)
    out = _dist_mttkrp(
        jnp.asarray(m.idx),
        jnp.asarray(m.rows),
        jnp.asarray(m.vals),
        facs,
        num_rows=m.num_rows,
        mesh_=plan.mesh,
    )
    # relabeled -> original rows (replicated output, replicated gather).
    return jnp.zeros_like(out).at[jnp.asarray(m.row_perm[0])].set(out)


# ---------------------------------------------------------------------------
# Fused distributed ALS (shard_map of the one-dispatch-per-window sweep)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _build_dist_sweep_block(mesh_: Mesh, nmodes: int, rank: int,
                            shapes: tuple[int, ...], solver: str,
                            block: int):
    """Jitted shard_map of ``block`` consecutive distributed sweeps.

    The body squeezes each device's leading shard dim and scans the SAME
    sweep the fused engine uses (``build_sweep_fn`` with ``axis=AXIS``):
    the whole check window is one dispatch, partial MTTKRPs psum inside
    it, and state stays replicated (identical on every device because the
    psummed inputs are identical).  Cached per (mesh, shapes, rank,
    solver, window) — shard caps live in the array shapes, so same-class
    tensors reuse the executable."""
    sweep = build_sweep_fn("segment", nmodes, rank, shapes, None, True,
                           solver, axis=AXIS)

    def body(state, *flat):
        md = tuple(
            tuple(jnp.squeeze(a, 0) for a in flat[4 * d: 4 * d + 4])
            for d in range(nmodes)
        )
        fd = tuple(jnp.squeeze(a, 0) for a in flat[4 * nmodes:])

        def step(st, _):
            return sweep(st, md, fd)

        state, fits = lax.scan(step, state, xs=None, length=block)
        return state, fits

    n_sharded = 4 * nmodes + 3
    fn = shard_map(
        body, mesh=mesh_,
        in_specs=(P(),) + tuple(P(AXIS) for _ in range(n_sharded)),
        out_specs=(P(), P()),
        check_rep=False,
    )
    return jax.jit(fn)


def _collect_dist_data(plan: DistributedPlan):
    """Flat per-mode + fit device arrays in the order the body expects."""
    flat = []
    for m in plan.modes:
        flat += [jnp.asarray(m.idx), jnp.asarray(m.rows),
                 jnp.asarray(m.vals), jnp.asarray(m.row_perm)]
    flat += [jnp.asarray(a) for a in plan.fit_shards]
    return flat


def cpd_als_distributed(
    tensor: SparseTensor,
    rank: int,
    mesh: Mesh | None = None,
    *,
    plan: DistributedPlan | None = None,
    n_iters: int = 25,
    tol: float = 1e-5,
    seed: int = 0,
    check_every: int = 1,
    solver: str = "auto",
    verbose: bool = False,
) -> CPDResult:
    """Distributed CPD-ALS: the fused one-dispatch-per-window sweep under
    shard_map.  Same init and update order as single-device ``cpd_als``
    (identical seed ⇒ matching factors to fp32 tolerance); the host
    fetches only the window-boundary fit scalar — zero per-iteration
    syncs inside a check window."""
    t_start = time.perf_counter()
    if plan is None:
        plan = make_distributed_plan(tensor, mesh)
    N = tensor.nmodes
    shapes = tuple(int(s) for s in tensor.shape)
    check_every = max(1, int(check_every))
    solver = resolve_solver(solver)

    state = init_state(tensor.shape, rank, seed)
    flat = _collect_dist_data(plan)

    n_blocks, rem = divmod(n_iters, check_every)
    fn_k = _build_dist_sweep_block(plan.mesh, N, rank, shapes, solver,
                                   check_every) if n_blocks else None
    fn_rem = _build_dist_sweep_block(plan.mesh, N, rank, shapes, solver,
                                     rem) if rem else None

    fits_dev: list = []
    host_syncs = 0
    last_fit = -np.inf
    it = 0
    for b in range(n_blocks + (1 if rem else 0)):
        k = check_every if b < n_blocks else rem
        fn = fn_k if b < n_blocks else fn_rem
        state, fits_blk = fn(state, *flat)
        fits_dev.append(fits_blk)
        it += k
        f = float(fits_blk[-1])                 # the only in-loop host sync
        host_syncs += 1
        if verbose:
            print(f"  ALS iter {it:3d}: fit={f:.6f} (distributed)")
        if abs(f - last_fit) < tol:
            break
        last_fit = f

    host_syncs += 1                             # final materialization
    fits = [float(f) for blk in jax.device_get(fits_dev) for f in blk]
    return CPDResult(
        factors=[np.asarray(F) for F in state[0]],
        weights=np.asarray(state[2], dtype=np.float64),
        fits=fits,
        iters=it,
        mttkrp_seconds=0.0,
        total_seconds=time.perf_counter() - t_start,
        host_syncs=host_syncs,
        engine="distributed",
    )
