"""Fault-tolerant checkpointing: atomic, versioned, async-capable,
reshard-on-restore.

Layout:  <dir>/step_<N>/
            meta.msgpack        tree structure + shapes + dtypes + extras
            arrays.npz          flattened leaves (host numpy)
         <dir>/step_<N>.done    commit marker (atomic rename)

Guarantees:
  * atomicity — a checkpoint is visible only after its .done marker is
    renamed into place; torn writes are never restored.
  * keep-k GC of committed checkpoints; torn ones are pruned on start.
  * restore-to-different-topology (elastic): arrays are loaded on host
    and device_put against the *target* shardings, so a 512-chip
    checkpoint restores onto 256 chips (or 8 CPU test devices) unchanged.
  * async save: the device->host pull happens synchronously (cheap), the
    file write runs on a worker thread so the train loop is not blocked.
"""
from __future__ import annotations

import os
import re
import shutil
import threading
from typing import Any

import msgpack
from ..obs import clock as obs_clock
import numpy as np

import jax


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in p)
             for p, _ in flat]
    leaves = [v for _, v in flat]
    return paths, leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3, async_save: bool = True):
        self.dir = str(directory)
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        os.makedirs(self.dir, exist_ok=True)
        self._prune_torn()

    # -- discovery ----------------------------------------------------------

    def _steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)\.done", name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self._steps()
        return steps[-1] if steps else None

    def _prune_torn(self):
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and not os.path.exists(
                    os.path.join(self.dir, f"{name}.done")):
                shutil.rmtree(os.path.join(self.dir, name), ignore_errors=True)

    # -- save ----------------------------------------------------------------

    def save(self, step: int, tree: Any, *, extra: dict | None = None,
             block: bool = False):
        """Snapshot ``tree`` (params/opt state/pipeline...) at ``step``."""
        self.wait()  # one in-flight save at a time
        paths, leaves, _ = _flatten_with_paths(tree)
        host = [np.asarray(x) for x in leaves]   # device -> host, sync
        meta = {
            "step": step,
            "paths": paths,
            "shapes": [list(a.shape) for a in host],
            "dtypes": [str(a.dtype) for a in host],
            "extra": extra or {},
            "time": obs_clock.wall(),   # epoch timestamp, not a duration
        }

        def _write():
            tmp = os.path.join(self.dir, f".tmp_step_{step}")
            final = os.path.join(self.dir, f"step_{step}")
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp)
            with open(os.path.join(tmp, "meta.msgpack"), "wb") as f:
                f.write(msgpack.packb(meta))
            np.savez(os.path.join(tmp, "arrays.npz"),
                     **{f"a{i}": a for i, a in enumerate(host)})
            shutil.rmtree(final, ignore_errors=True)
            os.rename(tmp, final)
            # commit marker: atomic rename
            marker_tmp = os.path.join(self.dir, f".tmp_step_{step}.done")
            with open(marker_tmp, "w") as f:
                f.write("ok")
            os.rename(marker_tmp, os.path.join(self.dir, f"step_{step}.done"))
            self._gc()

        if self.async_save and not block:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self._steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)
            try:
                os.remove(os.path.join(self.dir, f"step_{s}.done"))
            except OSError:
                pass

    # -- restore --------------------------------------------------------------

    def _load_host(self, step: int | None):
        """Shared committed-checkpoint loader: meta + host arrays."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        base = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(base, "meta.msgpack"), "rb") as f:
            meta = msgpack.unpackb(f.read())
        data = np.load(os.path.join(base, "arrays.npz"))
        host = [data[f"a{i}"] for i in range(len(meta["paths"]))]
        return meta, host

    def restore_items(self, step: int | None = None) -> tuple[dict, dict]:
        """Template-free restore: ``(dict of path -> host array, extra)``.

        The template-based ``restore`` demands exact shapes known up
        front — right for fixed training state, wrong for consumers whose
        array shapes are part of the checkpointed state itself (e.g. a
        streaming session's growing nonzero set).  Those rebuild from the
        flat path map and the ``extra`` metadata instead."""
        meta, host = self._load_host(step)
        return dict(zip(meta["paths"], host)), meta.get("extra", {})

    def restore(self, step: int | None = None, *, template: Any = None,
                shardings: Any = None) -> tuple[Any, dict]:
        """Load checkpoint ``step`` (default latest).

        template: pytree giving the target structure (required).
        shardings: optional matching pytree of NamedShardings — arrays are
          device_put against them (elastic restore onto any topology).
        Returns (tree, extra)."""
        meta, host = self._load_host(step)

        if template is None:
            raise ValueError("restore requires a template pytree")
        t_paths, t_leaves, treedef = _flatten_with_paths(template)
        if t_paths != meta["paths"]:
            missing = set(meta["paths"]) ^ set(t_paths)
            raise ValueError(
                f"checkpoint/template structure mismatch; differing: "
                f"{sorted(missing)[:5]}...")
        for a, t in zip(host, t_leaves):
            if tuple(a.shape) != tuple(t.shape):
                raise ValueError(
                    f"shape mismatch {a.shape} vs {t.shape} on restore")

        if shardings is not None:
            s_leaves = jax.tree.leaves(
                shardings, is_leaf=lambda x: hasattr(x, "spec"))
            out = [jax.device_put(a.astype(t.dtype), s)
                   for a, t, s in zip(host, t_leaves, s_leaves)]
        else:
            out = [jax.device_put(a.astype(t.dtype)) for a, t in
                   zip(host, t_leaves)]
        return jax.tree.unflatten(treedef, out), meta.get("extra", {})
