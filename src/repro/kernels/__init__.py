"""Pallas TPU kernels for the paper's compute hot-spot (spMTTKRP).

mttkrp_pallas.py — pl.pallas_call kernel: slab-packed segmented MTTKRP
                   with one-hot MXU gather/scatter and BlockSpec VMEM
                   tiling (scalar-prefetched output-block schedule).
ops.py           — host-side slab packing, jit wrappers, BlockSpec
                   auto-tuning (beyond-paper).
ref.py           — pure-jnp oracles (dense matricization / COO /
                   sorted-segment formulations).
"""
from .mttkrp_pallas import mttkrp_pallas
from .ops import (DEFAULT_BLOCK_ROWS, DEFAULT_TILE, PackedModeLayout,
                  auto_tiles, estimate_pack_cost, mttkrp_packed,
                  mttkrp_packed_ref, pack_layout, pack_slabs)

__all__ = [
    "mttkrp_pallas", "DEFAULT_BLOCK_ROWS", "DEFAULT_TILE",
    "PackedModeLayout", "auto_tiles", "estimate_pack_cost",
    "mttkrp_packed", "mttkrp_packed_ref", "pack_layout", "pack_slabs",
]
