"""Pure-jnp / numpy oracles for spMTTKRP.

Three reference levels, used to cross-validate each other and the Pallas
kernel:

  * ``mttkrp_dense``      — numpy, literal Eq.(1): X_(d) @ KRP(factors).
                            Only for tiny test tensors.
  * ``mttkrp_coo``        — jnp, elementwise COO formulation (Fig. 1 of the
                            paper) with a materialized (nnz, R) Khatri-Rao
                            intermediate + segment_sum.  This is also the
                            "naive / ParTI-like" baseline in benchmarks.
  * ``mttkrp_sorted_segments`` — jnp, the layout-aware formulation the
                            Pallas kernel implements (rows pre-sorted, so
                            segment_sum can assert sortedness).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp


def khatri_rao(mats: list[np.ndarray]) -> np.ndarray:
    """Column-wise Khatri-Rao product, row-major sweep (lowest mode fastest
    to match ``SparseTensor.matricize`` column ordering)."""
    out = mats[0]
    for m in mats[1:]:
        # (I, R) x (J, R) -> (I*J, R) with J varying fastest.
        out = (out[:, None, :] * m[None, :, :]).reshape(-1, out.shape[1])
    return out


def mttkrp_dense(tensor, factors: list[np.ndarray], mode: int) -> np.ndarray:
    """Numpy dense oracle: X_(d) @ (KRP of input factors)."""
    others = [factors[w] for w in range(len(factors)) if w != mode]
    return tensor.matricize(mode) @ khatri_rao(others)


def mttkrp_coo(
    indices: jnp.ndarray,
    values: jnp.ndarray,
    factors: list[jnp.ndarray],
    mode: int,
    num_rows: int,
    entry_weights: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Elementwise COO MTTKRP (unsorted; materializes the (nnz, R) Hadamard
    intermediate — the traffic the paper's fused kernel avoids).

    ``entry_weights`` (per-nonzero observation weights) scale each entry's
    contribution; weight 0 is an exact +0.0 no-op, the general form of the
    zero-value padding invariance."""
    if entry_weights is not None:
        values = values.astype(jnp.float32) * entry_weights.astype(jnp.float32)
    acc = values[:, None].astype(jnp.float32)
    for w in range(len(factors)):
        if w == mode:
            continue
        acc = acc * jnp.take(factors[w], indices[:, w], axis=0).astype(jnp.float32)
    return jax.ops.segment_sum(acc, indices[:, mode], num_segments=num_rows)


def cp_model_at_coords(
    indices: jnp.ndarray,         # (nnz, N) int32 canonical COO coordinates
    factors: list[jnp.ndarray],   # N factor matrices (I_d, R)
    weights: jnp.ndarray,         # (R,)
) -> jnp.ndarray:
    """CP model values at sparse coordinates: sum_r w_r * prod_d Y_d[i_d, r]."""
    acc = jnp.ones((indices.shape[0], weights.shape[0]), jnp.float32)
    for d, fac in enumerate(factors):
        acc = acc * jnp.take(fac, indices[:, d], axis=0).astype(jnp.float32)
    return acc @ weights.astype(jnp.float32)


def mttkrp_masked_residual(
    indices: jnp.ndarray,         # (nnz, N) int32 observed coordinates
    values: jnp.ndarray,          # (nnz,) observed values
    entry_weights: jnp.ndarray,   # (nnz,) observation weights (0 = missing)
    factors: list[jnp.ndarray],   # N factor matrices (I_d, R)
    weights: jnp.ndarray,         # (R,) lambda
    mode: int,
    num_rows: int,
) -> jnp.ndarray:
    """Mask-weighted MTTKRP of the EM-filled tensor (tensor completion).

    The filled tensor is ``Xf = model + W * (X - model)`` (observed entries
    keep their values, unobserved ones are imputed from the current model),
    so its MTTKRP splits into a sparse residual term over observed
    coordinates — the SAME spMTTKRP kernel, with values ``w_e*(x - model)``
    — plus a rank-R closed form for the dense model term:
    ``MTTKRP(model, d) = (Y_d * lambda) @ hadamard_{w != d}(Y_w^T Y_w)``.
    Zero-weight entries contribute exactly +0.0, which is what keeps the
    serving path's nnz padding an exact no-op for the masked method.
    """
    resid = entry_weights.astype(jnp.float32) * (
        values.astype(jnp.float32) - cp_model_at_coords(indices, factors, weights))
    sparse = mttkrp_coo(indices, resid, factors, mode, num_rows)
    rank = weights.shape[0]
    V = jnp.ones((rank, rank), jnp.float32)
    for w, fac in enumerate(factors):
        if w != mode:
            fac = fac.astype(jnp.float32)
            V = V * (fac.T @ fac)
    dense = (factors[mode].astype(jnp.float32)
             * weights[None, :].astype(jnp.float32)) @ V
    return sparse + dense


def mttkrp_sorted_segments(
    input_indices: jnp.ndarray,   # (nnz, W) int32, input-mode columns only
    rows: jnp.ndarray,            # (nnz,) int32 relabeled output rows, sorted
    values: jnp.ndarray,          # (nnz,)
    factors: list[jnp.ndarray],   # W input factor matrices (I_w, R)
    num_rows: int,
    entry_weights: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Layout-aware oracle: same math as the Pallas kernel, f32 accumulate.

    ``entry_weights`` (layout order, aligned with ``values``) scale each
    entry's contribution — weight-0 entries vanish exactly, so a weighted
    layout and the same layout with those entries removed accumulate
    bit-identically."""
    if entry_weights is not None:
        values = values.astype(jnp.float32) * entry_weights.astype(jnp.float32)
    acc = values[:, None].astype(jnp.float32)
    for w, fac in enumerate(factors):
        acc = acc * jnp.take(fac, input_indices[:, w], axis=0).astype(jnp.float32)
    return jax.ops.segment_sum(
        acc, rows, num_segments=num_rows, indices_are_sorted=True
    )
