"""Pure-jnp / numpy oracles for spMTTKRP.

Three reference levels, used to cross-validate each other and the Pallas
kernel:

  * ``mttkrp_dense``      — numpy, literal Eq.(1): X_(d) @ KRP(factors).
                            Only for tiny test tensors.
  * ``mttkrp_coo``        — jnp, elementwise COO formulation (Fig. 1 of the
                            paper) with a materialized (nnz, R) Khatri-Rao
                            intermediate + segment_sum.  This is also the
                            "naive / ParTI-like" baseline in benchmarks.
  * ``mttkrp_sorted_segments`` — jnp, the layout-aware formulation the
                            Pallas kernel implements (rows pre-sorted, so
                            segment_sum can assert sortedness).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp


def khatri_rao(mats: list[np.ndarray]) -> np.ndarray:
    """Column-wise Khatri-Rao product, row-major sweep (lowest mode fastest
    to match ``SparseTensor.matricize`` column ordering)."""
    out = mats[0]
    for m in mats[1:]:
        # (I, R) x (J, R) -> (I*J, R) with J varying fastest.
        out = (out[:, None, :] * m[None, :, :]).reshape(-1, out.shape[1])
    return out


def mttkrp_dense(tensor, factors: list[np.ndarray], mode: int) -> np.ndarray:
    """Numpy dense oracle: X_(d) @ (KRP of input factors)."""
    others = [factors[w] for w in range(len(factors)) if w != mode]
    return tensor.matricize(mode) @ khatri_rao(others)


def mttkrp_coo(
    indices: jnp.ndarray,
    values: jnp.ndarray,
    factors: list[jnp.ndarray],
    mode: int,
    num_rows: int,
) -> jnp.ndarray:
    """Elementwise COO MTTKRP (unsorted; materializes the (nnz, R) Hadamard
    intermediate — the traffic the paper's fused kernel avoids)."""
    acc = values[:, None].astype(jnp.float32)
    for w in range(len(factors)):
        if w == mode:
            continue
        acc = acc * jnp.take(factors[w], indices[:, w], axis=0).astype(jnp.float32)
    return jax.ops.segment_sum(acc, indices[:, mode], num_segments=num_rows)


def mttkrp_sorted_segments(
    input_indices: jnp.ndarray,   # (nnz, W) int32, input-mode columns only
    rows: jnp.ndarray,            # (nnz,) int32 relabeled output rows, sorted
    values: jnp.ndarray,          # (nnz,)
    factors: list[jnp.ndarray],   # W input factor matrices (I_w, R)
    num_rows: int,
) -> jnp.ndarray:
    """Layout-aware oracle: same math as the Pallas kernel, f32 accumulate."""
    acc = values[:, None].astype(jnp.float32)
    for w, fac in enumerate(factors):
        acc = acc * jnp.take(fac, input_indices[:, w], axis=0).astype(jnp.float32)
    return jax.ops.segment_sum(
        acc, rows, num_segments=num_rows, indices_are_sorted=True
    )
