"""Pallas TPU kernel for sorted segmented spMTTKRP.

TPU-native re-think of the paper's R x P thread-block kernel (§IV-B):

  * The mode-specific layout pre-sorts nonzeros by (relabeled) output row,
    so the scatter-update becomes a *segmented reduction* — no atomics
    (TPU has none; the paper's Local_Update/Global_Update dichotomy moves
    to the partitioning level, see core/distributed.py).
  * Nonzeros are packed into fixed ``tile``-sized slabs grouped under
    ``block_rows``-sized output row blocks (see ops.pack_slabs).  Grid =
    one step per slab; consecutive slabs of the same row block revisit the
    same output block, which therefore stays resident in VMEM and is only
    written back to HBM once per row block — this is the paper's
    "eliminate intermediate-value traffic" property, realized through the
    Pallas pipeline instead of L1 atomics.
  * Rank is tiled: the grid is 2-D ``(R_blocks, G)`` with the slab
    dimension minor, so each rank block makes one full pass over the
    slabs while only ``rank_block`` factor/output columns are resident in
    VMEM.  Columns are independent in MTTKRP, so rank tiling is exact
    (bit-identical to the single-block kernel) and removes the hard VMEM
    rank ceiling the single-block version had.
  * Factor-row gathers and the final scatter-reduce both become one-hot
    matmuls on the MXU when the index space is small (`onehot`), else
    vector gathers (`take`).  The Hadamard accumulator ``l`` (paper's
    l(r)) lives in VREGs/VMEM for its whole life.

Block layout (VMEM, per grid step):
  idx_ref   : (W, T)   int32   input-mode indices (lane dim = T)
  val_ref   : (1, T)   float   nonzero values
  lrow_ref  : (1, T)   int32   output row local to this row block
  factors   : (I_w, RB) each   one rank block of each factor matrix
  out_ref   : (BR, RB) float32 one (row block, rank block) output tile,
                               revisited across slabs of the row block

Scalar-prefetch:
  rb_of (G,) int32  output row-block id per grid step (drives out index_map)
  first (G,) int32  1 on the first slab of each row block (zero-init gate)
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(
    rb_of_ref,
    first_ref,
    idx_ref,
    val_ref,
    lrow_ref,
    *refs,
    num_inputs: int,
    block_rows: int,
    tile: int,
    gather_onehot_max: int,
):
    factor_refs = refs[:num_inputs]
    out_ref = refs[num_inputs]
    g = pl.program_id(1)          # slab index (minor grid dimension)

    @pl.when(first_ref[g] == 1)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    vals = val_ref[0, :].astype(jnp.float32)          # (T,)
    prod = vals[:, None]                              # (T, 1) -> bcast to (T, RB)
    for w in range(num_inputs):
        fac = factor_refs[w]
        idx_w = idx_ref[w, :]                         # (T,)
        I_w = fac.shape[0]
        if I_w <= gather_onehot_max:
            # Gather as a one-hot matmul: MXU-friendly, no random access.
            iota = lax.broadcasted_iota(jnp.int32, (tile, I_w), 1)
            onehot = (idx_w[:, None] == iota).astype(jnp.float32)
            fw = jnp.dot(
                onehot, fac[...].astype(jnp.float32),
                preferred_element_type=jnp.float32,
            )
        else:
            # Vector gather from the VMEM-resident factor block.
            fw = jnp.take(fac[...], idx_w, axis=0).astype(jnp.float32)
        prod = prod * fw                              # Hadamard accumulate (VREG)

    # Segmented reduce into the row block: one-hot^T @ prod on the MXU.
    lrow = lrow_ref[0, :]                             # (T,)
    iota_r = lax.broadcasted_iota(jnp.int32, (tile, block_rows), 1)
    scatter = (lrow[:, None] == iota_r).astype(jnp.float32)   # (T, BR)
    out_ref[...] += jnp.dot(
        scatter.T, prod, preferred_element_type=jnp.float32
    )


def mttkrp_pallas(
    rb_of: jnp.ndarray,          # (G,) int32
    first: jnp.ndarray,          # (G,) int32
    idx_packed: jnp.ndarray,     # (W, G*T) int32
    vals_packed: jnp.ndarray,    # (1, G*T) float
    lrows_packed: jnp.ndarray,   # (1, G*T) int32
    factors: Sequence[jnp.ndarray],  # W arrays (I_w, R)
    *,
    num_row_blocks: int,
    block_rows: int,
    tile: int,
    rank_block: int | None = None,
    interpret: bool = True,
    gather_onehot_max: int = 2048,
) -> jnp.ndarray:
    """Run the segmented MTTKRP kernel. Returns (num_row_blocks*block_rows, R) f32.

    ``rank_block`` tiles the rank dimension: each rank block re-streams the
    slabs with only that block of factor/output columns in VMEM.  ``None``
    (or >= R) keeps the whole rank resident — the original behavior.
    """
    W = idx_packed.shape[0]
    if W != len(factors):
        raise ValueError(f"{W} index rows but {len(factors)} input factors")
    G = rb_of.shape[0]
    if idx_packed.shape[1] != G * tile:
        raise ValueError("packed arrays must have G*tile columns")
    R = factors[0].shape[1]
    if rank_block is None or rank_block >= R:
        rank_block = R
    if rank_block < 1:
        raise ValueError(f"rank_block must be >= 1, got {rank_block}")
    num_rank_blocks = -(-R // rank_block)
    R_pad = num_rank_blocks * rank_block
    if R_pad != R:
        # Zero-pad the rank dimension so it divides evenly; padded columns
        # compute zeros and are sliced off below.
        factors = [
            jnp.pad(f, ((0, 0), (0, R_pad - R))) for f in factors
        ]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(num_rank_blocks, G),
        in_specs=[
            pl.BlockSpec((W, tile), lambda r, g, rb, fi: (0, g)),
            pl.BlockSpec((1, tile), lambda r, g, rb, fi: (0, g)),
            pl.BlockSpec((1, tile), lambda r, g, rb, fi: (0, g)),
        ]
        + [
            pl.BlockSpec((f.shape[0], rank_block), lambda r, g, rb, fi: (0, r))
            for f in factors
        ],
        out_specs=pl.BlockSpec(
            (block_rows, rank_block), lambda r, g, rb, fi: (rb[g], r)
        ),
    )
    kernel = functools.partial(
        _kernel,
        num_inputs=W,
        block_rows=block_rows,
        tile=tile,
        gather_onehot_max=gather_onehot_max,
    )
    out_shape = jax.ShapeDtypeStruct(
        (num_row_blocks * block_rows, R_pad), jnp.float32
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(rb_of, first, idx_packed, vals_packed, lrows_packed, *factors)
    if R_pad != R:
        out = out[:, :R]
    return out
