"""Jit'd wrappers + host-side slab packing for the Pallas MTTKRP kernel.

``pack_slabs`` converts a row-sorted mode layout into the fixed-shape slab
arrays the kernel consumes.  Packing is one-time host preprocessing per
mode copy (amortized over all ALS iterations), mirroring the paper's
format-construction stage.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

import jax
import jax.numpy as jnp

from ..obs import trace as obs_trace
from . import ref as ref_mod
from .mttkrp_pallas import mttkrp_pallas

DEFAULT_TILE = 256
DEFAULT_BLOCK_ROWS = 128


@dataclasses.dataclass(frozen=True)
class PackedModeLayout:
    """Device-ready slab packing of one mode layout (or one partition of it).

    Shapes: G grid steps, T = tile nonzeros per slab, W input modes.
    """

    mode: int
    num_rows: int              # relabeled rows covered (<= num_row_blocks*BR)
    num_row_blocks: int
    block_rows: int
    tile: int
    rb_of: np.ndarray          # (G,) int32
    first: np.ndarray          # (G,) int32
    idx_packed: np.ndarray     # (W, G*T) int32
    vals_packed: np.ndarray    # (1, G*T) float32
    lrows_packed: np.ndarray   # (1, G*T) int32
    input_modes: tuple[int, ...]
    pad_fraction: float        # padding overhead (diagnostic)
    num_real_slabs: int = -1   # slabs before cap padding (-1: no padding)
    # (nnz,) int32: flat position in vals_packed[0] of each *layout-order*
    # entry.  Entries map to exactly one valid slot, so scattering a fresh
    # value vector through this map rebuilds vals_packed on device — the
    # mask-weighted MTTKRP path re-threads per-sweep residual values
    # through the SAME packed slabs without repacking on host.
    val_scatter: np.ndarray | None = None
    # (1, G*T) float32 per-entry observation weights packed alongside the
    # values (None when the layout is unweighted).  Padding slots carry
    # weight 0 — the SAME exact-no-op mechanism slab/nnz padding uses, now
    # general: any entry the caller down-weights to 0 vanishes from the
    # accumulation bit-exactly.
    wts_packed: np.ndarray | None = None

    @property
    def num_slabs(self) -> int:
        return int(self.rb_of.shape[0])

    def weighted_vals(self) -> np.ndarray:
        """Kernel-ready weighted values: ``vals_packed * wts_packed`` (or
        ``vals_packed`` unchanged for an unweighted packing).  Feeding
        these to the kernel computes the weighted MTTKRP with zero extra
        device work — weights are folded at pack time."""
        if self.wts_packed is None:
            return self.vals_packed
        return (self.vals_packed * self.wts_packed).astype(np.float32)

    @property
    def bucket_key(self) -> tuple:
        """Static identity of this packing's shapes: every packed layout
        with the same key has identical array shapes, so bucket-mates
        stack along a new leading axis (the vmapped Pallas path)."""
        return (self.mode, self.num_rows, self.num_row_blocks,
                self.block_rows, self.tile, self.num_slabs,
                self.input_modes)


def pack_slabs(
    input_indices: np.ndarray,   # (nnz, W) int32 — input-mode columns only
    rows: np.ndarray,            # (nnz,) int32 — relabeled rows, sorted
    values: np.ndarray,          # (nnz,)
    num_rows: int,
    *,
    mode: int = 0,
    input_modes: Sequence[int] = (),
    block_rows: int = DEFAULT_BLOCK_ROWS,
    tile: int = DEFAULT_TILE,
    num_slabs_cap: int | None = None,
    weights: np.ndarray | None = None,
) -> PackedModeLayout:
    """Pack row-sorted COO data into per-row-block slabs of ``tile`` nonzeros.

    Every row block gets >= 1 slab (empty blocks get one all-padding slab so
    their output block is zero-initialized).  Padding entries carry value 0
    and indices 0, contributing nothing.

    ``weights`` — optional per-entry observation weights aligned with
    ``values`` (layout order).  They are packed into ``wts_packed`` through
    the identical slab placement (padding slots get weight 0), so
    ``weighted_vals()`` is the weighted kernel input.

    ``num_slabs_cap`` (from ``core.plan.slab_cap``) pads the grid with
    appended all-zero slabs on the LAST row block, making the packed array
    shapes a pure function of the plan rather than the data: bucket-mates
    stack for ``jax.vmap``.  The padding is bit-exact — the real slabs are
    untouched (appending cannot shift slab boundaries) and each extra slab
    contributes ``+= 0.0`` to an already-initialized output block.
    """
    tr = obs_trace.active()
    if tr is None:
        return _pack_slabs_impl(
            input_indices, rows, values, num_rows, mode=mode,
            input_modes=input_modes, block_rows=block_rows, tile=tile,
            num_slabs_cap=num_slabs_cap, weights=weights)
    with tr.span("pack.slabs", cat="kernels", mode=int(mode),
                 nnz=len(values), num_rows=int(num_rows),
                 block_rows=int(block_rows), tile=int(tile)) as sp:
        p = _pack_slabs_impl(
            input_indices, rows, values, num_rows, mode=mode,
            input_modes=input_modes, block_rows=block_rows, tile=tile,
            num_slabs_cap=num_slabs_cap, weights=weights)
        sp.set(slabs=p.num_slabs, real_slabs=p.num_real_slabs,
               pad_fraction=round(p.pad_fraction, 4))
        return p


def _pack_slabs_impl(
    input_indices: np.ndarray,
    rows: np.ndarray,
    values: np.ndarray,
    num_rows: int,
    *,
    mode: int = 0,
    input_modes: Sequence[int] = (),
    block_rows: int = DEFAULT_BLOCK_ROWS,
    tile: int = DEFAULT_TILE,
    num_slabs_cap: int | None = None,
    weights: np.ndarray | None = None,
) -> PackedModeLayout:
    nnz = len(values)
    if nnz and not bool(np.all(rows[:-1] <= rows[1:])):
        raise ValueError("rows must be sorted (build via core.layout)")
    W = input_indices.shape[1]
    nb = max(1, -(-num_rows // block_rows))
    row_ptr = np.zeros(num_rows + 1, dtype=np.int64)
    np.cumsum(np.bincount(rows, minlength=num_rows), out=row_ptr[1:])
    starts = row_ptr[np.minimum(np.arange(nb) * block_rows, num_rows)]
    ends = row_ptr[np.minimum((np.arange(nb) + 1) * block_rows, num_rows)]
    lens = ends - starts
    slabs_per_block = np.maximum(1, -(-lens // tile))
    G = int(slabs_per_block.sum())

    slab_block = np.repeat(np.arange(nb, dtype=np.int64), slabs_per_block)
    # Rank of each slab within its block.
    block_start_slab = np.zeros(nb, dtype=np.int64)
    np.cumsum(slabs_per_block[:-1], out=block_start_slab[1:])
    rank = np.arange(G, dtype=np.int64) - block_start_slab[slab_block]

    src_start = starts[slab_block] + rank * tile
    length = np.clip(ends[slab_block] - src_start, 0, tile)
    src = src_start[:, None] + np.arange(tile, dtype=np.int64)[None, :]
    valid = np.arange(tile)[None, :] < length[:, None]
    src_c = np.minimum(src, max(nnz - 1, 0))

    if weights is not None and len(weights) != nnz:
        raise ValueError(
            f"weights length {len(weights)} != nnz {nnz}")
    wts_p = None
    if nnz:
        vals_p = np.where(valid, values[src_c], 0).astype(np.float32)
        if weights is not None:
            wts_p = np.where(valid, weights[src_c], 0).astype(np.float32)
        idx_p = np.where(valid[:, :, None], input_indices[src_c], 0).astype(np.int32)
        lrow_p = np.where(
            valid, rows[src_c] - slab_block[:, None] * block_rows, 0
        ).astype(np.int32)
        # Invert the (layout entry -> packed slot) placement: slabs tile
        # each row block's [start, end) range contiguously, so every layout
        # position lands in exactly one valid slot.  Cap padding appends
        # whole slabs, which leaves these flat positions untouched.
        flat = (np.arange(G, dtype=np.int64)[:, None] * tile
                + np.arange(tile, dtype=np.int64)[None, :])
        val_scatter = np.empty(nnz, dtype=np.int32)
        val_scatter[src[valid]] = flat[valid].astype(np.int32)
    else:
        vals_p = np.zeros((G, tile), np.float32)
        if weights is not None:
            wts_p = np.zeros((G, tile), np.float32)
        idx_p = np.zeros((G, tile, W), np.int32)
        lrow_p = np.zeros((G, tile), np.int32)
        val_scatter = np.zeros(0, dtype=np.int32)

    G_real = G
    if num_slabs_cap is not None:
        if G > num_slabs_cap:
            raise ValueError(
                f"packing needs {G} slabs but the plan caps at "
                f"{num_slabs_cap}; nnz exceeds the plan's nnz_cap")
        extra = num_slabs_cap - G
        if extra:
            # Appended zero slabs revisit the last row block: first=0 (no
            # re-init), values 0, local row 0 — an exact += 0.0.
            slab_block = np.concatenate(
                [slab_block, np.full(extra, nb - 1, dtype=np.int64)])
            rank = np.concatenate(
                [rank, np.ones(extra, dtype=np.int64)])   # never first
            vals_p = np.concatenate(
                [vals_p, np.zeros((extra, tile), np.float32)])
            if wts_p is not None:
                wts_p = np.concatenate(
                    [wts_p, np.zeros((extra, tile), np.float32)])
            idx_p = np.concatenate(
                [idx_p, np.zeros((extra, tile, W), np.int32)])
            lrow_p = np.concatenate(
                [lrow_p, np.zeros((extra, tile), np.int32)])
            G = num_slabs_cap

    pad = 1.0 - (nnz / float(G * tile)) if G else 0.0
    return PackedModeLayout(
        mode=mode,
        num_rows=num_rows,
        num_row_blocks=nb,
        block_rows=block_rows,
        tile=tile,
        rb_of=slab_block.astype(np.int32),
        first=(rank == 0).astype(np.int32),
        idx_packed=np.ascontiguousarray(
            idx_p.reshape(G * tile, W).T.astype(np.int32)
        ),
        vals_packed=vals_p.reshape(1, G * tile),
        lrows_packed=lrow_p.reshape(1, G * tile).astype(np.int32),
        input_modes=tuple(input_modes) or tuple(range(W)),
        pad_fraction=float(pad),
        num_real_slabs=G_real,
        val_scatter=val_scatter,
        wts_packed=(None if wts_p is None
                    else wts_p.reshape(1, G * tile).astype(np.float32)),
    )


def pack_layout(layout, *, block_rows: int = DEFAULT_BLOCK_ROWS,
                tile: int = DEFAULT_TILE,
                num_slabs_cap: int | None = None,
                weights: np.ndarray | None = None) -> PackedModeLayout:
    """Pack a ``core.layout.ModeLayout`` for kernel execution.

    With ``num_slabs_cap`` (see ``core.plan``) the packing is padded to the
    plan's static grid size — bucket-keyed: every layout of the same
    (shape, nnz-bucket) class yields identically-shaped arrays.

    ``weights`` — per-entry observation weights in CANONICAL COO order
    (the front-door contract); the layout's permutation maps them to the
    packed slots alongside the values."""
    in_modes = layout.input_modes()
    return pack_slabs(
        layout.indices[:, in_modes],
        layout.rows,
        layout.values,
        layout.num_rows,
        mode=layout.mode,
        input_modes=in_modes,
        block_rows=block_rows,
        tile=tile,
        num_slabs_cap=num_slabs_cap,
        weights=(None if weights is None
                 else np.asarray(weights, np.float32)[layout.perm]),
    )


# -- beyond-paper: BlockSpec auto-tuning -------------------------------------
#
# The cost model below is consumed through ``core.plan`` (the single
# planning layer): ``plan_bucket`` prices candidate tilings against a
# uniform-distribution stand-in, ``plan_layout`` against the real layout.
# ``estimate_pack_cost``/``auto_tiles`` accept either — they only read
# ``num_rows`` / ``nnz`` / ``nmodes`` / ``row_ptr``.

_MXU_DIM = 128
_VMEM_BYTES = 16 * 2**20
_STEP_OVERHEAD_SLOTS = 192   # pipeline bubble per grid step, in slot units


def tile_candidates():
    return [(br, t) for br in (8, 32, 128, 256) for t in (64, 128, 256, 512)]


def auto_rank_block(rank: int, block_rows: int, tile: int, factor_rows: int,
                    num_inputs: int, *, vmem_budget: int = _VMEM_BYTES) -> int:
    """Largest rank block whose VMEM working set (slabs + one output tile +
    one column block of every input factor) fits ``vmem_budget``.

    Returns ``rank`` when the whole rank fits (no tiling), else the widest
    feasible block, preferring lane-aligned multiples of 128.  Returns 0
    when even a single column cannot fit (slab arrays alone overflow).
    """
    fixed = (num_inputs + 2) * tile * 4
    per_col = (block_rows + factor_rows) * 4
    avail = vmem_budget - fixed
    if avail < per_col:
        return 0
    max_cols = int(avail // per_col)
    if max_cols >= rank:
        return rank
    if max_cols >= _MXU_DIM:
        return (max_cols // _MXU_DIM) * _MXU_DIM
    return max_cols


def estimate_pack_cost(layout, block_rows: int, tile: int, rank: int,
                       factor_rows: int, *,
                       vmem_budget: int = _VMEM_BYTES) -> dict:
    """Closed-form kernel cost for a (block_rows, tile) choice — no packing.

    slots      = sum over row blocks of ceil(len/tile)*tile  (incl. padding)
    mxu_factor = cost of the (tile x block_rows) scatter matmul relative to
                 a lane-saturated tile (block_rows < 128 wastes MXU columns;
                 block_rows > 128 adds proportional work)
    vmem       = slabs + one (row block, rank block) output tile + one rank
                 block of the resident factors; when the full rank does not
                 fit, the rank dimension is tiled (grid (R_blocks, G)) and
                 every rank block re-streams the slabs, multiplying cost.
    """
    nb = max(1, -(-layout.num_rows // block_rows))
    row_ptr = layout.row_ptr
    starts = row_ptr[np.minimum(np.arange(nb) * block_rows, layout.num_rows)]
    ends = row_ptr[np.minimum((np.arange(nb) + 1) * block_rows,
                              layout.num_rows)]
    slabs = np.maximum(1, -(-(ends - starts) // tile))
    G = int(slabs.sum())
    slots = G * tile
    pad = 1.0 - layout.nnz / max(slots, 1)
    mxu_factor = max(block_rows, _MXU_DIM) / _MXU_DIM
    W = layout.nmodes - 1
    rank_block = auto_rank_block(rank, block_rows, tile, factor_rows, W,
                                 vmem_budget=vmem_budget)
    num_rank_blocks = -(-rank // rank_block) if rank_block else 0
    vmem = ((W + 2) * tile * 4
            + (block_rows + factor_rows) * min(rank_block, rank) * 4)
    cost = (slots * mxu_factor + G * _STEP_OVERHEAD_SLOTS) * max(
        num_rank_blocks, 1)
    return {"block_rows": block_rows, "tile": tile, "grid": G,
            "pad_fraction": pad, "vmem": int(vmem),
            "rank_block": int(rank_block),
            "num_rank_blocks": int(num_rank_blocks),
            "vmem_ok": bool(rank_block >= 1 and vmem <= vmem_budget),
            "cost": float(cost) if num_rank_blocks else float("inf")}


def auto_tiles(layout, rank: int = 32, factor_rows: int | None = None):
    """Pick (block_rows, tile) minimizing the modeled kernel cost under the
    VMEM budget.  The default (128, 256) is good for dense-ish modes; skewed
    or tiny modes prefer smaller row blocks (less slab padding).  Candidates
    whose factors only fit via rank tiling are costed with the re-streaming
    multiplier rather than rejected."""
    if factor_rows is None:
        factor_rows = sum(layout.shape[w] for w in layout.input_modes())
    best = None
    for br, t in tile_candidates():
        c = estimate_pack_cost(layout, br, t, rank, factor_rows)
        if not c["vmem_ok"]:
            continue
        if best is None or c["cost"] < best["cost"]:
            best = c
    if best is None:   # slab arrays alone overflow VMEM: nothing feasible
        best = estimate_pack_cost(layout, DEFAULT_BLOCK_ROWS, DEFAULT_TILE,
                                  rank, factor_rows)
    return best["block_rows"], best["tile"]


def mttkrp_packed(
    packed: PackedModeLayout,
    factors: Sequence[jnp.ndarray],
    *,
    rank_block: int | None = None,
    interpret: bool = True,
    gather_onehot_max: int = 2048,
) -> jnp.ndarray:
    """Run the Pallas kernel on a packed layout.  ``factors`` are the input
    factor matrices in ``packed.input_modes`` order.  Returns the relabeled
    (num_rows, R) f32 output (trailing padding rows stripped).

    A weighted packing (``pack_layout(weights=...)``) executes the
    WEIGHTED MTTKRP: the kernel consumes ``weighted_vals()`` — values
    pre-multiplied by their observation weights at the packed slots — so
    weight-0 entries vanish exactly with zero extra device work.

    ``rank_block=None`` auto-sizes the rank tile from the VMEM model: the
    full rank stays resident when it fits, else the widest feasible column
    block is used and the kernel makes one slab pass per rank block."""
    if rank_block is None:
        rank = int(factors[0].shape[1])
        factor_rows = sum(int(f.shape[0]) for f in factors)
        rank_block = auto_rank_block(
            rank, packed.block_rows, packed.tile, factor_rows, len(factors)
        ) or rank
    out = mttkrp_pallas(
        jnp.asarray(packed.rb_of),
        jnp.asarray(packed.first),
        jnp.asarray(packed.idx_packed),
        jnp.asarray(packed.weighted_vals()),
        jnp.asarray(packed.lrows_packed),
        [jnp.asarray(f) for f in factors],
        num_row_blocks=packed.num_row_blocks,
        block_rows=packed.block_rows,
        tile=packed.tile,
        rank_block=rank_block,
        interpret=interpret,
        gather_onehot_max=gather_onehot_max,
    )
    return out[: packed.num_rows]


def mttkrp_packed_ref(
    packed: PackedModeLayout, factors: Sequence[jnp.ndarray]
) -> jnp.ndarray:
    """jnp oracle evaluated on the *packed* arrays (padding included) —
    bit-for-bit the same data the kernel sees (weighted values for a
    weighted packing, like ``mttkrp_packed``)."""
    idx = jnp.asarray(packed.idx_packed).T            # (G*T, W)
    vals = jnp.asarray(packed.weighted_vals())[0]
    # Reconstruct absolute relabeled rows from block-local ones.
    lrows = jnp.asarray(packed.lrows_packed)[0]
    rb = jnp.repeat(jnp.asarray(packed.rb_of), packed.tile)
    rows = lrows + rb * packed.block_rows
    out = ref_mod.mttkrp_sorted_segments(
        idx, rows, vals, [jnp.asarray(f) for f in factors],
        packed.num_row_blocks * packed.block_rows,
    )
    return out[: packed.num_rows]
