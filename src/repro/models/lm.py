"""Decoder-only LM assembly: segments of stacked blocks, scanned with
jax.lax.scan (keeps the HLO one-layer-sized at 512 devices), with KV /
SSM caches threaded through the scan, modality prefixes (VLM patch
embeddings), Hymba meta tokens, and optional remat.

A model is a list of ``Segment``s.  Dense archs have one segment; Hymba
is [global, swa-stack, global, swa-stack, global] so its sliding-window
layers can (a) carry a different mask and (b) later use window-sized
caches; Whisper's decoder reuses these blocks via encdec.py.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from . import blocks as blk
from .base import ModelConfig, ShapeCfg, token_specs
from .common import (PSpec, abstract_params, apply_norm, build_params,
                     constrain, logical_axes, norm_specs,
                     softmax_cross_entropy, stack_specs)


@dataclasses.dataclass(frozen=True)
class Segment:
    kind: str          # dense | moe | mamba | hymba
    n_layers: int
    window: int = 0    # sliding-window size for attention (0 = full)
    name: str = ""


def model_segments(cfg: ModelConfig) -> list[Segment]:
    if cfg.family in ("dense", "vlm"):
        return [Segment("dense", cfg.num_layers, cfg.attn_window, "layers")]
    if cfg.family == "moe":
        return [Segment("moe", cfg.num_layers, 0, "layers")]
    if cfg.family == "ssm":
        return [Segment("mamba", cfg.num_layers, 0, "layers")]
    if cfg.family == "hybrid":
        # global full-attention layers at first / middle / last (Hymba).
        g = sorted(set(cfg.global_attn_layers or (0, cfg.num_layers // 2,
                                                  cfg.num_layers - 1)))
        segs: list[Segment] = []
        prev = 0
        for i, gl in enumerate(g):
            if gl > prev:
                segs.append(Segment("hymba", gl - prev, cfg.attn_window,
                                    f"swa_{i}"))
            segs.append(Segment("hymba", 1, 0, f"global_{gl}"))
            prev = gl + 1
        if prev < cfg.num_layers:
            segs.append(Segment("hymba", cfg.num_layers - prev,
                                cfg.attn_window, f"swa_tail"))
        return segs
    raise ValueError(f"family {cfg.family!r} not handled by lm.py")


class LM:
    """Functional decoder-only language model."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.segments = model_segments(cfg)

    # -- parameters ---------------------------------------------------------

    def param_specs(self) -> dict:
        cfg = self.cfg
        d, V = cfg.d_model, cfg.padded_vocab
        specs: dict[str, Any] = {"final_norm": norm_specs(cfg.norm, d)}
        if cfg.cpd_embed_rank:
            from . import factorized_embed as fe

            specs["embed_cpd"] = fe.cpd_embed_specs(V, d, cfg.cpd_embed_rank)
            specs["unembed"] = PSpec((d, V), ("fsdp", "vocab"))
        else:
            specs["embed"] = PSpec((V, d), ("vocab", "fsdp"), "embed",
                                   scale=0.02)
            if not cfg.tie_embeddings:
                specs["unembed"] = PSpec((d, V), ("fsdp", "vocab"))
        if cfg.num_meta_tokens:
            specs["meta_tokens"] = PSpec(
                (cfg.num_meta_tokens, d), (None, "fsdp"), "normal", scale=0.02
            )
        segs = {}
        for i, seg in enumerate(self.segments):
            s = blk.block_specs(cfg, seg.kind)
            segs[f"seg{i}_{seg.name or seg.kind}"] = (
                stack_specs(s, seg.n_layers) if seg.n_layers > 1 else s
            )
        specs["segments"] = segs
        return specs

    def init(self, key):
        return build_params(self.param_specs(), key, self.cfg.param_dtype)

    def abstract_params(self):
        return abstract_params(self.param_specs(), self.cfg.param_dtype)

    def param_axes(self):
        return logical_axes(self.param_specs())

    def _seg_keys(self) -> list[str]:
        return [f"seg{i}_{s.name or s.kind}" for i, s in enumerate(self.segments)]

    # -- caches -------------------------------------------------------------

    def init_cache(self, batch: int, max_len: int, *, dtype=jnp.bfloat16,
                   quant_kv: bool = False) -> dict:
        cfg = self.cfg
        caches: dict[str, Any] = {"pos": jnp.zeros((), jnp.int32)}
        total = max_len + cfg.num_meta_tokens + cfg.num_prefix_tokens
        for i, seg in enumerate(self.segments):
            # window-limited segments still get full-length buffers only if
            # global; SWA segments cap at window (+ meta prefix).
            seg_len = total if not seg.window else min(total, seg.window)
            one = blk.init_block_cache(cfg, seg.kind, batch, seg_len,
                                       dtype, quant_kv)
            if seg.n_layers > 1:
                one = jax.tree.map(
                    lambda a: jnp.broadcast_to(a, (seg.n_layers, *a.shape)), one
                )
            caches[self._seg_keys()[i]] = one
        return caches

    # -- forward ------------------------------------------------------------

    def _tok_embed(self, params, tokens):
        cfg = self.cfg
        if cfg.cpd_embed_rank:
            from . import factorized_embed as fe

            return fe.cpd_embed_lookup(
                params["embed_cpd"], tokens, cfg.padded_vocab
            ).astype(cfg.param_dtype)
        return jnp.take(params["embed"], tokens, axis=0)

    def _embed(self, params, tokens, prefix_embeds=None):
        cfg = self.cfg
        x = self._tok_embed(params, tokens)
        n_prefix = 0
        if cfg.num_meta_tokens and "meta_tokens" in params:
            meta = jnp.broadcast_to(
                params["meta_tokens"][None], (x.shape[0], cfg.num_meta_tokens,
                                              cfg.d_model)
            ).astype(x.dtype)
            x = jnp.concatenate([meta, x], axis=1)
            n_prefix += cfg.num_meta_tokens
        if prefix_embeds is not None:
            x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
            n_prefix += prefix_embeds.shape[1]
        if cfg.pos_embedding == "sinusoidal":
            x = x + _sinusoid(jnp.arange(x.shape[1]), cfg.d_model).astype(x.dtype)
        # seq_act: optional Megatron-SP sharding of the residual stream
        return constrain(x, "batch", "seq_act", None), n_prefix

    def _run_segments(self, params, x, *, caches=None, q0=0, train=False):
        cfg = self.cfg
        aux_total = jnp.zeros((), jnp.float32)
        new_caches: dict[str, Any] = {}
        pos = caches["pos"] if caches is not None else None
        keys = self._seg_keys()

        for i, seg in enumerate(self.segments):
            p_seg = params["segments"][keys[i]]
            c_seg = caches.get(keys[i]) if caches is not None else None

            def one_layer(x, p, c, _seg=seg):
                return blk.block_apply(
                    cfg, _seg.kind, p, x, cache=c, pos=pos,
                    window=_seg.window, q0=q0, train=train,
                )

            if cfg.remat != "none":
                one_layer = jax.checkpoint(
                    one_layer,
                    policy=jax.checkpoint_policies.nothing_saveable
                    if cfg.remat == "full"
                    else jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
                )

            if seg.n_layers > 1 and cfg.scan_layers:
                def body(carry, xs, _f=one_layer):
                    h, aux = carry
                    p, c = xs
                    h, c2, a = _f(h, p, c)
                    return (h, aux + a), c2

                (x, aux_total), seg_cache = lax.scan(
                    body, (x, aux_total), (p_seg, c_seg)
                )
            elif seg.n_layers > 1:
                # unrolled: exact per-layer HLO (dry-run cost accounting; on
                # real hw also enables cross-layer fusion)
                outs = []
                for li in range(seg.n_layers):
                    p_li = jax.tree.map(lambda a: a[li], p_seg)
                    c_li = (jax.tree.map(lambda a: a[li], c_seg)
                            if c_seg is not None else None)
                    x, c2, a = one_layer(x, p_li, c_li)
                    aux_total = aux_total + a
                    outs.append(c2)
                seg_cache = (
                    jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
                    if outs and outs[0] else {}
                )
            else:
                x, seg_cache, a = one_layer(x, p_seg, c_seg)
                aux_total = aux_total + a
            if caches is not None:
                new_caches[keys[i]] = seg_cache
        if caches is not None:
            # advance the shared position cursor by the query length
            new_caches["pos"] = caches["pos"] + x.shape[1]
        return x, new_caches if caches is not None else None, aux_total

    def _logits(self, params, x):
        cfg = self.cfg
        x = apply_norm(cfg.norm, x, params["final_norm"])
        un = (params["embed"].T
              if cfg.tie_embeddings and not cfg.cpd_embed_rank
              else params["unembed"])
        logits = x @ un.astype(x.dtype)
        return constrain(logits, "batch", None, "vocab")

    def forward(self, params, tokens, *, prefix_embeds=None, train=False):
        x, n_prefix = self._embed(params, tokens, prefix_embeds)
        x, _, aux = self._run_segments(params, x, train=train)
        logits = self._logits(params, x)
        return logits[:, n_prefix:], aux

    def loss(self, params, batch) -> tuple[jnp.ndarray, dict]:
        cfg = self.cfg
        if cfg.loss_chunk:
            # chunked CE: never materializes the full (B, S, V) f32 logits —
            # per-chunk logits are rematerialized in the backward (§Perf)
            x, n_prefix = self._embed(params, batch["tokens"],
                                      batch.get("prefix_embeds"))
            x, _, aux = self._run_segments(params, x, train=True)
            x = x[:, n_prefix:]
            labels = batch["labels"]
            C = cfg.loss_chunk
            S = x.shape[1]
            nc = -(-S // C)
            x = jnp.pad(x, ((0, 0), (0, nc * C - S), (0, 0)))
            labels = jnp.pad(labels, ((0, 0), (0, nc * C - S)),
                             constant_values=-1)
            xc = jnp.moveaxis(x.reshape(x.shape[0], nc, C, -1), 1, 0)
            lc = jnp.moveaxis(labels.reshape(labels.shape[0], nc, C), 1, 0)

            @jax.checkpoint
            def chunk_ce(carry, xs):
                xch, lch = xs
                logits = self._logits(params, xch)
                lse = jax.scipy.special.logsumexp(
                    logits.astype(jnp.float32), axis=-1)
                safe = jnp.maximum(lch, 0)
                ll = jnp.take_along_axis(
                    logits.astype(jnp.float32), safe[..., None], axis=-1
                )[..., 0]
                ce_i = (lse - ll) + 1e-4 * lse**2
                valid = (lch >= 0).astype(jnp.float32)
                return (carry[0] + (ce_i * valid).sum(),
                        carry[1] + valid.sum()), None

            (ce_sum, n), _ = lax.scan(chunk_ce, (0.0, 0.0), (xc, lc))
            ce = ce_sum / jnp.maximum(n, 1.0)
            loss = ce + 0.01 * aux
            return loss, {"ce": ce, "aux": aux, "loss": loss}
        logits, aux = self.forward(
            params, batch["tokens"], prefix_embeds=batch.get("prefix_embeds"),
            train=True,
        )
        ce = softmax_cross_entropy(logits, batch["labels"])
        loss = ce + 0.01 * aux
        return loss, {"ce": ce, "aux": aux, "loss": loss}

    # -- serving ------------------------------------------------------------

    def prefill(self, params, tokens, cache, *, prefix_embeds=None):
        x, n_prefix = self._embed(params, tokens, prefix_embeds)
        x, cache2, _ = self._run_segments(params, x, caches=cache)
        logits = self._logits(params, x[:, -1:])
        return logits, cache2

    def decode_step(self, params, tokens, cache):
        """tokens (B, 1) -> (logits (B,1,V), new cache)."""
        cfg = self.cfg
        x = self._tok_embed(params, tokens)
        if cfg.pos_embedding == "sinusoidal":
            x = x + _sinusoid(cache["pos"][None], cfg.d_model).astype(x.dtype)
        x = constrain(x, "batch", None, None)
        x, cache2, _ = self._run_segments(params, x, caches=cache)
        return self._logits(params, x), cache2


def _sinusoid(positions, d):
    half = d // 2
    freq = jnp.exp(-jnp.arange(half, dtype=jnp.float32) * (jnp.log(10000.0) / half))
    ang = positions.astype(jnp.float32)[:, None] * freq[None]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)[None]
