"""Dense MLP (SwiGLU/GeGLU/GELU) + sorted-capacity Mixture-of-Experts.

MoE design (dbrx 16e/top-4, granite 32e/top-8): tokens are routed top-k,
sorted by expert id, gathered into per-expert capacity buffers, processed
by a batched (E, C, d) x (E, d, ff) einsum — a grouped GEMM the SPMD
partitioner can shard on the expert axis (expert parallelism) and/or the
ff axis (tensor parallelism) — and scattered back weighted by router
probs.  Static shapes throughout (capacity drop, GShard-style); dropped
tokens fall back to the residual stream.

The token->expert dispatch is itself a sparse mode-contraction, and the
adaptive rule of the paper (partition *indices* when plentiful, partition
*nonzeros* + reduce when not) is mirrored here: experts (few) are the
"small output mode", so dispatch partitions tokens and reduces — the
paper's scheme-2 shape (see DESIGN.md §4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .common import PSpec, constrain


def mlp_specs(cfg, d_ff: int | None = None) -> dict:
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    if cfg.activation in ("swiglu", "geglu"):
        return {
            "wi": PSpec((d, ff), ("fsdp", "tensor")),
            "wg": PSpec((d, ff), ("fsdp", "tensor")),
            "wo": PSpec((ff, d), ("tensor", "fsdp")),
        }
    return {
        "wi": PSpec((d, ff), ("fsdp", "tensor")),
        "wo": PSpec((ff, d), ("tensor", "fsdp")),
    }


def mlp_apply(cfg, p, x):
    h = x @ p["wi"]
    if cfg.activation == "swiglu":
        h = jax.nn.silu(x @ p["wg"]) * h
    elif cfg.activation == "geglu":
        h = jax.nn.gelu(x @ p["wg"]) * h
    elif cfg.activation == "relu2":   # squared ReLU (Nemotron / Minitron)
        h = jnp.square(jax.nn.relu(h))
    else:
        h = jax.nn.gelu(h)
    h = constrain(h, "batch", None, "tensor")
    return h @ p["wo"]


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------


def moe_specs(cfg) -> dict:
    d, E, ff = cfg.d_model, cfg.num_experts, cfg.moe_dff
    return {
        "router": PSpec((d, E), ("fsdp", None), dtype=jnp.float32),
        "wi": PSpec((E, d, ff), ("experts", "fsdp", "tensor")),
        "wg": PSpec((E, d, ff), ("experts", "fsdp", "tensor")),
        "wo": PSpec((E, ff, d), ("experts", "tensor", "fsdp")),
    }


def moe_apply(cfg, p, x, *, train=True):
    """x: (B, S, d) -> (B, S, d), plus load-balance aux loss (returned 2nd).

    Dispatch is PER BATCH ROW (group = sequence): sort, capacity and
    gather/scatter all act on (B, S*k) so no cross-device data dependence
    is introduced — the batch axis sharding survives into the grouped
    GEMM (a globally-sorted dispatch forces GSPMD to all-gather the whole
    token set and replicate expert compute across the data axis; measured
    5x FLOP inflation in the dry run — see EXPERIMENTS.md §Perf).

    ``train=False`` (eval/serving) takes the dispatch-free dense path:
    capacity dropping depends on the surrounding sequence (which tokens
    share an expert), so a capacity-dropped token would decode differently
    than it forwards — inference must be drop-free for decode/forward
    parity.
    """
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.num_experts_per_tok

    if not train or getattr(cfg, "moe_dense_eval", False):
        return _moe_dense_eval(cfg, p, x)

    logits = x.astype(jnp.float32) @ p["router"]              # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert = lax.top_k(probs, k)                         # (B, S, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # GShard aux loss: mean prob per expert * fraction routed per expert.
    me = probs.mean(axis=(0, 1))
    ce = jnp.zeros((E,), jnp.float32).at[expert.reshape(-1)].add(
        1.0) / (B * S * k)
    aux = E * jnp.sum(me * ce)

    # Per-row capacity (GShard-style dropping keeps shapes static).
    C = int(cfg.capacity_factor * S * k / E)
    C = max(8, -(-C // 8) * 8)

    fe = expert.reshape(B, S * k)                              # (B, S*k)
    ft = jnp.broadcast_to(jnp.arange(S)[:, None], (S, k)).reshape(1, S * k)
    ft = jnp.broadcast_to(ft, (B, S * k))
    fg = gate.reshape(B, S * k)
    order = jnp.argsort(fe, axis=1)                            # stable per row
    se = jnp.take_along_axis(fe, order, axis=1)
    st = jnp.take_along_axis(ft, order, axis=1)
    sg = jnp.take_along_axis(fg, order, axis=1)
    seg_pos = jax.vmap(_segment_positions)(se)
    keep = seg_pos < C
    slot = jnp.where(keep, se * C + seg_pos, E * C)            # drop -> E*C

    # Gather tokens into per-row (E*C, d) buffers (extra row absorbs drops).
    rows = jnp.arange(B)[:, None]
    xs = jnp.take_along_axis(x, st[..., None], axis=1)         # (B, S*k, d)
    buf = jnp.zeros((B, E * C + 1, d), x.dtype).at[rows, slot].set(xs)
    xe = buf[:, : E * C].reshape(B, E, C, d)
    xe = constrain(xe, "batch", "experts", None, None)

    h = jnp.einsum("becd,edf->becf", xe, p["wi"])
    g = jnp.einsum("becd,edf->becf", xe, p["wg"])
    h = jax.nn.silu(g) * h
    h = constrain(h, "batch", "experts", None, "tensor")
    ye = jnp.einsum("becf,efd->becd", h, p["wo"])              # (B, E, C, d)

    # Scatter back, weighted by gate prob.
    yf = ye.reshape(B, E * C, d)
    contrib = (jnp.where(keep, sg, 0.0) * keep)[..., None].astype(x.dtype)
    safe_slot = jnp.minimum(slot, E * C - 1)
    gathered = jnp.take_along_axis(yf, safe_slot[..., None], axis=1)
    y = jnp.zeros((B, S, d), x.dtype).at[rows, st].add(gathered * contrib)
    return y, aux


def _moe_dense_eval(cfg, p, x):
    """Dispatch-free MoE: every expert processes every token; top-k gate
    weights zero out the rest (§Perf hillclimb for FINE-GRAINED MoE).

    Rationale: with tiny per-expert d_ff (granite: 512) the sort + scatter +
    capacity-buffer traffic of real dispatch exceeds the cost of simply
    computing all experts (E/k more FLOPs) when the cell is memory-bound —
    napkin math and the measured before/after live in EXPERIMENTS.md §Perf.
    No tokens are dropped (better quality than capacity dispatch, too).
    """
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    logits = x.astype(jnp.float32) @ p["router"]              # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert = lax.top_k(probs, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    w = jnp.zeros_like(probs).at[
        jnp.arange(B)[:, None, None], jnp.arange(S)[None, :, None], expert
    ].set(gate)                                               # (B, S, E)

    me = probs.mean(axis=(0, 1))
    ce = jnp.zeros((E,), jnp.float32).at[expert.reshape(-1)].add(
        1.0) / (B * S * k)
    aux = E * jnp.sum(me * ce)

    h = jnp.einsum("bsd,edf->ebsf", x, p["wi"])
    g = jnp.einsum("bsd,edf->ebsf", x, p["wg"])
    h = jax.nn.silu(g) * h
    h = h * jnp.moveaxis(w, -1, 0)[..., None].astype(h.dtype)
    h = constrain(h, "experts", "batch", None, None)
    y = jnp.einsum("ebsf,efd->bsd", h, p["wo"])
    return y, aux


def _segment_positions(sorted_ids):
    """Rank of each element within its (sorted) segment: [0,0,1,2,0,1,...]."""
    n = sorted_ids.shape[0]
    idx = jnp.arange(n)
    # index of segment start for each element
    is_start = jnp.concatenate(
        [jnp.ones((1,), jnp.bool_), sorted_ids[1:] != sorted_ids[:-1]]
    )
    start_idx = jnp.where(is_start, idx, 0)
    start_idx = lax.associative_scan(jnp.maximum, start_idx)
    return idx - start_idx
