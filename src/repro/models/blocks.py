"""Decoder blocks: dense / MoE / Mamba2 / Hymba-hybrid, with a uniform
(block_specs, block_apply, init_block_cache) interface so segments of any
kind can be stacked, scanned, and cached interchangeably.

Cache dtype may be int8 (quantized KV, per-position absmax scales) — a
serving optimization for the decode_32k / long_500k cells.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import attention as attn_mod
from . import mlp as mlp_mod
from . import ssm as ssm_mod
from .common import PSpec, apply_norm, norm_specs


def block_specs(cfg, kind: str) -> dict:
    d = cfg.d_model
    if kind == "dense":
        return {
            "ln1": norm_specs(cfg.norm, d),
            "attn": attn_mod.attn_specs(cfg),
            "ln2": norm_specs(cfg.norm, d),
            "mlp": mlp_mod.mlp_specs(cfg),
        }
    if kind == "moe":
        return {
            "ln1": norm_specs(cfg.norm, d),
            "attn": attn_mod.attn_specs(cfg),
            "ln2": norm_specs(cfg.norm, d),
            "moe": mlp_mod.moe_specs(cfg),
        }
    if kind == "mamba":
        return {
            "ln1": norm_specs(cfg.norm, d),
            "ssm": ssm_mod.ssm_specs(cfg),
        }
    if kind == "hymba":
        return {
            "ln1": norm_specs(cfg.norm, d),
            "attn": attn_mod.attn_specs(cfg),
            "ssm": ssm_mod.ssm_specs(cfg),
            "attn_out_scale": {"scale": PSpec((d,), (None,), "zeros")},
            "ssm_out_scale": {"scale": PSpec((d,), (None,), "zeros")},
            "ln2": norm_specs(cfg.norm, d),
            "mlp": mlp_mod.mlp_specs(cfg),
        }
    raise ValueError(f"unknown block kind {kind!r}")


def init_block_cache(cfg, kind: str, batch: int, max_len: int, dtype, quant: bool):
    cache: dict = {}
    if kind in ("dense", "moe", "hymba"):
        kv_dtype = jnp.int8 if quant else dtype
        shape = (batch, max_len, cfg.num_kv_heads, cfg.head_dim)
        cache["k"] = jnp.zeros(shape, kv_dtype)
        cache["v"] = jnp.zeros(shape, kv_dtype)
        if quant:
            cache["k_scale"] = jnp.zeros(shape[:3] + (1,), jnp.float32)
            cache["v_scale"] = jnp.zeros(shape[:3] + (1,), jnp.float32)
    if kind in ("mamba", "hymba"):
        st = ssm_mod.init_ssm_state(cfg, batch, dtype)
        cache["ssm"] = st["ssm"]
        cache["conv"] = st["conv"]
    return cache


def block_apply(cfg, kind: str, p, x, *, cache=None, pos=None, window=0, q0=0,
                train=True):
    """Apply one block.  Returns (x_out, new_cache, aux_loss).

    ``cache`` is this layer's slice (no 'pos'; the scalar position is
    passed separately so it can live once per segment, not per layer).
    ``train=False`` switches MoE blocks to drop-free dense-eval dispatch.
    """
    aux = jnp.zeros((), jnp.float32)
    new_cache: dict = {}

    if kind == "mamba":
        h = apply_norm(cfg.norm, x, p["ln1"])
        st = _ssm_state(cache, pos)
        if st is not None and x.shape[1] == 1:
            y, st2 = ssm_mod.ssd_decode_step(cfg, p["ssm"], h, st)
        else:
            y, st2 = ssm_mod.ssd_apply(cfg, p["ssm"], h, state=st)
        if st2 is not None:
            new_cache.update({"ssm": st2["ssm"], "conv": st2["conv"]})
        return x + y, new_cache, aux

    if kind == "hymba":
        h = apply_norm(cfg.norm, x, p["ln1"])
        acache = _attn_cache(cache, pos)
        a, ac2 = attn_mod.attention(cfg, p["attn"], h, cache=acache,
                                    q0=q0, window=window)
        st = _ssm_state(cache, pos)
        if st is not None and x.shape[1] == 1:
            s, st2 = ssm_mod.ssd_decode_step(cfg, p["ssm"], h, st)
        else:
            s, st2 = ssm_mod.ssd_apply(cfg, p["ssm"], h, state=st)
        # Hymba: mean of the two normalized branch outputs.
        y = 0.5 * (
            apply_norm("rmsnorm", a, p["attn_out_scale"])
            + apply_norm("rmsnorm", s, p["ssm_out_scale"])
        )
        x = x + y
        h2 = apply_norm(cfg.norm, x, p["ln2"])
        x = x + mlp_mod.mlp_apply(cfg, p["mlp"], h2)
        if ac2 is not None:
            new_cache.update({k: v for k, v in ac2.items() if k != "pos"})
        if st2 is not None:
            new_cache.update({"ssm": st2["ssm"], "conv": st2["conv"]})
        return x, new_cache, aux

    # dense / moe transformer block
    h = apply_norm(cfg.norm, x, p["ln1"])
    acache = _attn_cache(cache, pos)
    a, ac2 = attn_mod.attention(cfg, p["attn"], h, cache=acache, q0=q0,
                                window=window)
    x = x + a
    h2 = apply_norm(cfg.norm, x, p["ln2"])
    if kind == "moe":
        y, aux = mlp_mod.moe_apply(cfg, p["moe"], h2, train=train)
    else:
        y = mlp_mod.mlp_apply(cfg, p["mlp"], h2)
    x = x + y
    if ac2 is not None:
        new_cache.update({k: v for k, v in ac2.items() if k != "pos"})
    return x, new_cache, aux


def _attn_cache(cache, pos):
    if cache is None or "k" not in cache:
        return None
    c = {"k": cache["k"], "v": cache["v"], "pos": pos}
    if "k_scale" in cache:
        c["k_scale"] = cache["k_scale"]
        c["v_scale"] = cache["v_scale"]
    return c


def _ssm_state(cache, pos):
    if cache is None or "ssm" not in cache:
        return None
    return {"ssm": cache["ssm"], "conv": cache["conv"], "pos": pos}
