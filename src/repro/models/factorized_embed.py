"""CPD-factorized embedding tables — the paper's technique as a first-class
LM feature.

A (V, d) embedding table is reshaped to a 3-mode tensor (V1, V2, d) with
V <= V1*V2 and stored as its rank-R CP factors A (V1,R), B (V2,R),
C (d,R):

    E[v, :] = sum_r A[v1, r] * B[v2, r] * C[:, r],   v = v1 * V2 + v2

Parameters drop from V*d to (V1+V2+d)*R — e.g. qwen's 152k x 2560 table
at R=256: 389M -> 0.26M+... (~99.7% smaller), at the cost of an R-dim
Hadamard per lookup.

THE CONNECTION TO THE PAPER: the training batch of token ids is a sparse
3-mode tensor X with nonzeros at (v1(t), v2(t), pos(t)), value 1.  The
embedding-gradient updates

    dA[v1, :] += B[v2, :] * <dY[pos, :], C>        (and symmetrically dB)

are EXACTLY mode-0 / mode-1 spMTTKRP over X with factors (A, B, dY@C) —
the same sorted segmented scatter-reduce the Pallas kernel executes.
``grad_factors_mttkrp`` computes them through repro.core's engine and is
tested to match jax.grad of the dense formulation
(tests/models/test_factorized_embed.py).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from .common import PSpec


def factor_vocab(V: int) -> tuple[int, int]:
    """Near-square (V1, V2) with V1*V2 >= V."""
    v1 = int(np.ceil(np.sqrt(V)))
    v2 = -(-V // v1)
    return v1, v2


def cpd_embed_specs(V: int, d: int, rank: int) -> dict:
    V1, V2 = factor_vocab(V)
    return {
        "A": PSpec((V1, rank), ("vocab", None), "normal", scale=0.5),
        "B": PSpec((V2, rank), ("vocab", None), "normal", scale=0.5),
        "C": PSpec((d, rank), ("fsdp", None), "normal", scale=0.08),
    }


def split_ids(tokens, V: int):
    V1, V2 = factor_vocab(V)
    return tokens // V2, tokens % V2


def cpd_embed_lookup(p: dict, tokens, V: int):
    """tokens (B, S) int32 -> embeddings (B, S, d)."""
    i1, i2 = split_ids(tokens, V)
    a = jnp.take(p["A"], i1, axis=0)          # (B, S, R)
    b = jnp.take(p["B"], i2, axis=0)          # (B, S, R)
    return jnp.einsum("bsr,dr->bsd", a * b, p["C"])


def dense_table(p: dict, V: int):
    """Materialized (V, d) table (reference/small-V export)."""
    V1, V2 = factor_vocab(V)
    full = jnp.einsum("ir,jr,dr->ijd", p["A"], p["B"], p["C"])
    return full.reshape(V1 * V2, -1)[:V]


def compression_ratio(V: int, d: int, rank: int) -> float:
    V1, V2 = factor_vocab(V)
    return (V * d) / ((V1 + V2 + d) * rank)


# ---------------------------------------------------------------------------
# The gradient as spMTTKRP (paper's kernel in the training path)
# ---------------------------------------------------------------------------


def batch_as_sparse_tensor(tokens, V: int):
    """The token batch as a 3-mode sparse tensor (V1, V2, n_positions)."""
    from ..core.coo import SparseTensor

    V1, V2 = factor_vocab(V)
    flat = np.asarray(tokens).reshape(-1)
    i1, i2 = flat // V2, flat % V2
    pos = np.arange(flat.shape[0])
    idx = np.stack([i1, i2, pos], axis=1).astype(np.int32)
    vals = np.ones(flat.shape[0], dtype=np.float32)
    return SparseTensor(idx, vals, (V1, V2, flat.shape[0]))


def grad_factors_mttkrp(p: dict, tokens, dY, V: int, *, kappa: int = 8,
                        backend: str = "segment"):
    """dLoss/dA and dLoss/dB via the paper's MTTKRP engine.

    dY: (B, S, d) upstream gradient.  Builds the batch sparse tensor, maps
    dY through C (the third 'factor' is dY @ C), and runs mode-0 / mode-1
    spMTTKRP with the adaptive-load-balanced layouts.
    """
    from ..core import make_plan, mttkrp

    t = batch_as_sparse_tensor(tokens, V)
    g = dY.reshape(-1, dY.shape[-1]) @ p["C"]           # (positions, R)
    factors = [p["A"], p["B"], g]
    plan = make_plan(t, kappa)
    dA = mttkrp(plan, factors, 0, backend=backend)
    dB = mttkrp(plan, factors, 1, backend=backend)
    return dA, dB
