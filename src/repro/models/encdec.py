"""Encoder-decoder transformer (Whisper-large-v3 backbone).

Per the assignment, the conv frontend is a STUB: ``input_specs`` supplies
precomputed mel-frame embeddings (B, enc_seq, d) — the two strided conv1d
layers of Whisper live outside the modeled backbone.  Positions are
sinusoidal (Whisper uses sinusoids on the encoder; we use them on both
sides — noted in DESIGN.md).

Decoder = self-attn (causal, cached) + cross-attn (encoder KV, computed
once at prefill) + MLP.  Both stacks are scanned.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from . import attention as attn_mod
from . import mlp as mlp_mod
from .base import ModelConfig
from .common import (PSpec, abstract_params, apply_norm, build_params,
                     constrain, logical_axes, norm_specs,
                     softmax_cross_entropy, stack_specs)
from .lm import _sinusoid


def _enc_block_specs(cfg):
    return {
        "ln1": norm_specs(cfg.norm, cfg.d_model),
        "attn": attn_mod.attn_specs(cfg),
        "ln2": norm_specs(cfg.norm, cfg.d_model),
        "mlp": mlp_mod.mlp_specs(cfg),
    }


def _dec_block_specs(cfg):
    return {
        "ln1": norm_specs(cfg.norm, cfg.d_model),
        "attn": attn_mod.attn_specs(cfg),
        "lnx": norm_specs(cfg.norm, cfg.d_model),
        "xattn": attn_mod.attn_specs(cfg, cross=True),
        "ln2": norm_specs(cfg.norm, cfg.d_model),
        "mlp": mlp_mod.mlp_specs(cfg),
    }


class EncDec:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # -- parameters ---------------------------------------------------------

    def param_specs(self) -> dict:
        cfg = self.cfg
        d, V = cfg.d_model, cfg.padded_vocab
        return {
            "embed": PSpec((V, d), ("vocab", "fsdp"), "embed", scale=0.02),
            "enc": stack_specs(_enc_block_specs(cfg), cfg.enc_layers),
            "enc_norm": norm_specs(cfg.norm, d),
            "dec": stack_specs(_dec_block_specs(cfg), cfg.num_layers),
            "final_norm": norm_specs(cfg.norm, d),
            "unembed": PSpec((d, V), ("fsdp", "vocab")),
        }

    def init(self, key):
        return build_params(self.param_specs(), key, self.cfg.param_dtype)

    def abstract_params(self):
        return abstract_params(self.param_specs(), self.cfg.param_dtype)

    def param_axes(self):
        return logical_axes(self.param_specs())

    # -- encoder ------------------------------------------------------------

    def encode(self, params, encoder_embeds):
        cfg = self.cfg
        x = encoder_embeds.astype(cfg.param_dtype)
        x = x + _sinusoid(jnp.arange(x.shape[1]), cfg.d_model).astype(x.dtype)
        x = constrain(x, "batch", None, None)

        def body(h, p):
            a, _ = attn_mod.attention(
                cfg, p["attn"], apply_norm(cfg.norm, h, p["ln1"]), causal=False)
            h = h + a
            h = h + mlp_mod.mlp_apply(cfg, p["mlp"],
                                      apply_norm(cfg.norm, h, p["ln2"]))
            return h, None

        fn = body
        if cfg.remat != "none":
            fn = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
        if cfg.scan_layers:
            x, _ = lax.scan(fn, x, params["enc"])
        else:
            for li in range(cfg.enc_layers):
                x, _ = fn(x, jax.tree.map(lambda a: a[li], params["enc"]))
        return apply_norm(cfg.norm, x, params["enc_norm"])

    # -- decoder ------------------------------------------------------------

    def _dec_layer(self, p, h, *, self_cache, cross_kv, pos, enc_out):
        cfg = self.cfg
        acache = None
        if self_cache is not None:
            acache = {"k": self_cache["k"], "v": self_cache["v"], "pos": pos}
        a, ac2 = attn_mod.attention(
            cfg, p["attn"], apply_norm(cfg.norm, h, p["ln1"]), cache=acache)
        h = h + a
        # cross attention: either precomputed KV (decode) or fresh from enc_out
        hq = apply_norm(cfg.norm, h, p["lnx"])
        if cross_kv is not None:
            xa, _ = attn_mod.attention(cfg, p["xattn"], hq, xkv=None,
                                       cache=cross_kv)
        else:
            xa, _ = attn_mod.attention(cfg, p["xattn"], hq, xkv=enc_out)
        h = h + xa
        h = h + mlp_mod.mlp_apply(cfg, p["mlp"], apply_norm(cfg.norm, h, p["ln2"]))
        new_cache = {k: v for k, v in (ac2 or {}).items() if k != "pos"}
        return h, new_cache

    def _run_decoder(self, params, x, *, cache=None, enc_out=None):
        cfg = self.cfg
        pos = cache["pos"] if cache is not None else None

        def body(carry, xs):
            h = carry
            if cache is not None:
                p, sc, xk, xv = xs
                h, c2 = self._dec_layer(p, h, self_cache=sc,
                                        cross_kv={"k": xk, "v": xv},
                                        pos=pos, enc_out=None)
                return h, c2
            p = xs
            h, _ = self._dec_layer(p, h, self_cache=None, cross_kv=None,
                                   pos=None, enc_out=enc_out)
            return h, None

        fn = body
        if cfg.remat != "none" and cache is None:
            fn = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)

        if cache is not None:
            xs = (params["dec"], cache["self"], cache["cross_k"], cache["cross_v"])
            if cfg.scan_layers:
                x, new_self = lax.scan(fn, x, xs)
            else:
                outs = []
                for li in range(cfg.num_layers):
                    x, c2 = fn(x, jax.tree.map(lambda a: a[li], xs))
                    outs.append(c2)
                new_self = jax.tree.map(lambda *v: jnp.stack(v), *outs)
            new_cache = dict(cache)
            new_cache["self"] = new_self
            new_cache["pos"] = cache["pos"] + x.shape[1]
            return x, new_cache
        if cfg.scan_layers:
            x, _ = lax.scan(fn, x, params["dec"])
        else:
            for li in range(cfg.num_layers):
                x, _ = fn(x, jax.tree.map(lambda a: a[li], params["dec"]))
        return x, None

    def _logits(self, params, x):
        x = apply_norm(self.cfg.norm, x, params["final_norm"])
        logits = x @ params["unembed"].astype(x.dtype)
        return constrain(logits, "batch", None, "vocab")

    # -- public api ---------------------------------------------------------

    def forward(self, params, tokens, encoder_embeds):
        cfg = self.cfg
        enc_out = self.encode(params, encoder_embeds)
        x = jnp.take(params["embed"], tokens, axis=0)
        x = x + _sinusoid(jnp.arange(x.shape[1]), cfg.d_model).astype(x.dtype)
        x = constrain(x, "batch", None, None)
        x, _ = self._run_decoder(params, x, enc_out=enc_out)
        return self._logits(params, x), jnp.zeros((), jnp.float32)

    def loss(self, params, batch):
        logits, aux = self.forward(params, batch["tokens"], batch["encoder_embeds"])
        ce = softmax_cross_entropy(logits, batch["labels"])
        return ce, {"ce": ce, "aux": aux, "loss": ce}

    def init_cache(self, batch: int, max_len: int, *, dtype=jnp.bfloat16,
                   quant_kv: bool = False) -> dict:
        cfg = self.cfg
        L = cfg.num_layers
        kv_dtype = jnp.int8 if quant_kv else dtype
        shape = (L, batch, max_len, cfg.num_kv_heads, cfg.head_dim)
        xshape = (L, batch, cfg.enc_seq, cfg.num_kv_heads, cfg.head_dim)
        self_cache = {"k": jnp.zeros(shape, kv_dtype),
                      "v": jnp.zeros(shape, kv_dtype)}
        if quant_kv:
            self_cache["k_scale"] = jnp.zeros(shape[:4] + (1,), jnp.float32)
            self_cache["v_scale"] = jnp.zeros(shape[:4] + (1,), jnp.float32)
        return {
            "self": self_cache,
            "cross_k": jnp.zeros(xshape, dtype),
            "cross_v": jnp.zeros(xshape, dtype),
            "pos": jnp.zeros((), jnp.int32),
        }

    def prefill(self, params, tokens, cache, *, encoder_embeds):
        """Encode audio, precompute cross KV, prefill decoder self-attn."""
        cfg = self.cfg
        enc_out = self.encode(params, encoder_embeds)

        # per-layer cross KV from the encoder output
        def xkv(p):
            B, Se, _ = enc_out.shape
            k = (enc_out @ p["xattn"]["wk"]).reshape(B, Se, cfg.num_kv_heads,
                                                     cfg.head_dim)
            v = (enc_out @ p["xattn"]["wv"]).reshape(B, Se, cfg.num_kv_heads,
                                                     cfg.head_dim)
            return k, v

        ck, cv = jax.vmap(xkv)(params["dec"])
        cache = dict(cache)
        cache["cross_k"] = ck.astype(cache["cross_k"].dtype)
        cache["cross_v"] = cv.astype(cache["cross_v"].dtype)

        x = jnp.take(params["embed"], tokens, axis=0)
        x = x + _sinusoid(jnp.arange(x.shape[1]), cfg.d_model).astype(x.dtype)
        x, cache = self._run_decoder(params, x, cache=cache)
        return self._logits(params, x[:, -1:]), cache

    def decode_step(self, params, tokens, cache):
        cfg = self.cfg
        x = jnp.take(params["embed"], tokens, axis=0)
        x = x + _sinusoid(cache["pos"][None], cfg.d_model).astype(x.dtype)
        x, cache = self._run_decoder(params, x, cache=cache)
        return self._logits(params, x), cache
