"""Shared model machinery: parameter specs, logical-axis sharding, norms,
RoPE, losses.

Parameters are described ONCE as ``PSpec`` trees (shape + logical axes +
init); ``build_params`` materializes arrays, ``abstract_params`` gives
ShapeDtypeStructs (dry-run), ``logical_axes`` the matching axes tree.
Logical axis names are resolved to mesh axes by launch/shardings.py.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PSpec:
    """One parameter: shape, logical sharding axes, initializer."""

    shape: tuple[int, ...]
    axes: tuple[Any, ...]           # logical axis name (str) or None per dim
    init: str = "fan_in"            # fan_in | normal | zeros | ones | embed
    scale: float = 1.0
    dtype: Any = None               # None -> model default

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def stack_specs(tree, n: int):
    """Prepend a ('layers',) stacking dim of size n to every spec in tree."""
    return jax.tree.map(
        lambda s: PSpec((n, *s.shape), ("layers", *s.axes), s.init, s.scale, s.dtype),
        tree,
        is_leaf=lambda x: isinstance(x, PSpec),
    )


def _init_array(key, spec: PSpec, default_dtype):
    dtype = spec.dtype or default_dtype
    shape = spec.shape
    if spec.init == "zeros":
        return jnp.zeros(shape, dtype)
    if spec.init == "ones":
        return jnp.ones(shape, dtype)
    if spec.init == "normal":
        return (spec.scale * jax.random.normal(key, shape)).astype(dtype)
    if spec.init == "embed":
        return (spec.scale * jax.random.normal(key, shape)).astype(dtype)
    if spec.init == "fan_in":
        # stacked specs: fan_in excludes the leading 'layers' dim
        dims = shape[1:] if spec.axes and spec.axes[0] == "layers" else shape
        fan_in = dims[0] if dims else 1
        std = spec.scale / np.sqrt(max(fan_in, 1))
        return (std * jax.random.normal(key, shape)).astype(dtype)
    raise ValueError(f"unknown init {spec.init!r}")


def build_params(specs, key, default_dtype=jnp.bfloat16):
    leaves, treedef = jax.tree.flatten(
        specs, is_leaf=lambda x: isinstance(x, PSpec)
    )
    keys = jax.random.split(key, len(leaves))
    arrays = [_init_array(k, s, default_dtype) for k, s in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, arrays)


def abstract_params(specs, default_dtype=jnp.bfloat16):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype or default_dtype),
        specs,
        is_leaf=lambda x: isinstance(x, PSpec),
    )


def logical_axes(specs):
    return jax.tree.map(
        lambda s: s.axes, specs, is_leaf=lambda x: isinstance(x, PSpec)
    )


# ---------------------------------------------------------------------------
# Logical-axis activation constraints
# ---------------------------------------------------------------------------

# Default logical -> mesh translation; launch/shardings.py may override via
# set_rules().  Tuples mean "sharded over multiple mesh axes".
_DEFAULT_RULES: dict[str, Any] = {
    "batch": ("pod", "data"),
    "fsdp": "data",        # weight embed-dim sharding (ZeRO-3)
    "tensor": "model",     # TP: heads / d_ff / vocab
    "experts": "model",
    "seq": None,           # set to 'data' for context-parallel decode
    "seq_act": None,       # set to 'model' for Megatron-SP residual stream
    "kv_heads": None,      # set to 'model' for TP-sharded KV caches
    "kv_hd": None,         # fallback when kv head count doesn't divide
    "layers": None,
    "vocab": "model",
}
_rules = dict(_DEFAULT_RULES)


def set_rules(**kw):
    _rules.update(kw)


def get_rules() -> dict:
    return dict(_rules)


def reset_rules():
    _rules.clear()
    _rules.update(_DEFAULT_RULES)


def _mesh_axes_of(mesh) -> set:
    return set(mesh.axis_names)


def to_pspec(axes: tuple, mesh=None):
    """Translate logical axes to a PartitionSpec, dropping mesh axes that are
    absent or that do not divide the corresponding dim (caller checks dims)."""
    from jax.sharding import PartitionSpec as P

    names = []
    for a in axes:
        r = _rules.get(a) if isinstance(a, str) else None
        names.append(r)
    return P(*names)


def resolve_pspec(axes: tuple, shape: tuple, mesh):
    """PartitionSpec with divisibility + axis-existence checks per dim.

    A mesh axis may appear at most once in a spec, so logical axes are
    resolved left-to-right and later dims drop any mesh axis already
    claimed (e.g. MoE ('experts','fsdp','tensor') -> ('model','data',None):
    the expert dim wins the model axis; per-expert ff stays unsharded)."""
    from jax.sharding import PartitionSpec as P

    avail = dict(zip(mesh.axis_names, mesh.devices.shape))
    used: set = set()
    out = []
    for dim, a in zip(shape, axes):
        r = _rules.get(a) if isinstance(a, str) else None
        if r is None:
            out.append(None)
            continue
        axes_tuple = (r,) if isinstance(r, str) else tuple(r)
        axes_tuple = tuple(x for x in axes_tuple if x in avail and x not in used)
        size = int(np.prod([avail[x] for x in axes_tuple])) if axes_tuple else 1
        if axes_tuple and dim % size == 0:
            out.append(axes_tuple if len(axes_tuple) > 1 else axes_tuple[0])
            used.update(axes_tuple)
        else:
            out.append(None)
    return P(*out)


def constrain(x, *axes):
    """with_sharding_constraint using logical axes; no-op outside a mesh."""
    from jax._src import mesh as mesh_lib

    env_mesh = mesh_lib.thread_resources.env.physical_mesh
    if env_mesh.empty:
        return x
    spec = resolve_pspec(tuple(axes), x.shape, env_mesh)
    return lax.with_sharding_constraint(x, spec)


# ---------------------------------------------------------------------------
# Layers
# ---------------------------------------------------------------------------


def rms_norm(x, scale, eps=1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * lax.rsqrt(var + eps)).astype(dt) * (1.0 + scale.astype(dt))


def layer_norm(x, scale, bias, eps=1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean((x32 - mu) ** 2, axis=-1, keepdims=True)
    y = (x32 - mu) * lax.rsqrt(var + eps)
    return y.astype(dt) * scale.astype(dt) + bias.astype(dt)


def apply_norm(kind: str, x, p):
    if kind == "rmsnorm":
        return rms_norm(x, p["scale"])
    return layer_norm(x, p["scale"], p["bias"])


def norm_specs(kind: str, d: int) -> dict:
    if kind == "rmsnorm":
        return {"scale": PSpec((d,), (None,), "zeros")}
    return {"scale": PSpec((d,), (None,), "ones"), "bias": PSpec((d,), (None,), "zeros")}


def rope_freqs(head_dim: int, theta: float, positions):
    """positions: (..., S) int -> cos/sin (..., S, head_dim/2) f32."""
    half = head_dim // 2
    inv = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (B, S, H, hd); cos/sin: (B, S, hd/2) or (S, hd/2)."""
    dt = x.dtype
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    if cos.ndim == 2:
        c, s = cos[None, :, None, :], sin[None, :, None, :]
    else:
        c, s = cos[:, :, None, :], sin[:, :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(dt)


def softmax_cross_entropy(logits, labels, *, z_loss: float = 1e-4, mask=None):
    """logits (B,S,V) f32-upcast CE with optional z-loss and label mask.
    labels < 0 are ignored."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    safe_labels = jnp.maximum(labels, 0)
    ll = jnp.take_along_axis(logits, safe_labels[..., None], axis=-1)[..., 0]
    ce = lse - ll
    if z_loss:
        ce = ce + z_loss * lse**2
    valid = (labels >= 0).astype(jnp.float32)
    if mask is not None:
        valid = valid * mask.astype(jnp.float32)
    return (ce * valid).sum() / jnp.maximum(valid.sum(), 1.0)


def pad_vocab(v: int, multiple: int = 256) -> int:
    return -(-v // multiple) * multiple
