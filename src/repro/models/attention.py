"""Grouped-query attention with flash-style chunked online softmax (pure XLA).

Why no Pallas here: the dry-run must ``.lower().compile()`` on the CPU
backend, where TPU Pallas kernels cannot compile (interpret mode cannot be
jit-compiled into the SPMD program).  The chunked online-softmax
formulation below gives flash-attention's O(S) memory profile in plain
XLA, which the TPU compiler maps onto fused MXU loops; a Splash-style
Pallas kernel is a drop-in swap on real hardware.

Supports: GQA (num_kv_heads < num_heads), QKV bias (Qwen), RoPE or
sinusoidal positions, sliding-window masks (Hymba), cross-attention
(Whisper), KV-cache decode with context-parallel cache sharding.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from .common import PSpec, apply_rope, constrain, rope_freqs

NEG_INF = -2.0e38


def attn_specs(cfg, *, cross: bool = False) -> dict:
    d = cfg.d_model
    qf = cfg.num_heads * cfg.head_dim
    kf = cfg.num_kv_heads * cfg.head_dim
    specs = {
        "wq": PSpec((d, qf), ("fsdp", "tensor")),
        "wk": PSpec((d, kf), ("fsdp", "tensor")),
        "wv": PSpec((d, kf), ("fsdp", "tensor")),
        "wo": PSpec((qf, d), ("tensor", "fsdp")),
    }
    if cfg.qkv_bias and not cross:
        specs["bq"] = PSpec((qf,), (None,), "zeros")
        specs["bk"] = PSpec((kf,), (None,), "zeros")
        specs["bv"] = PSpec((kf,), (None,), "zeros")
    return specs


def _project_qkv(cfg, p, xq, xkv):
    B, Sq, _ = xq.shape
    Skv = xkv.shape[1]
    q = xq @ p["wq"]
    k = xkv @ p["wk"]
    v = xkv @ p["wv"]
    if "bq" in p:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    q = q.reshape(B, Sq, cfg.num_heads, cfg.head_dim)
    k = k.reshape(B, Skv, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(B, Skv, cfg.num_kv_heads, cfg.head_dim)
    return q, k, v


def _positions_embed(cfg, q, k, q_pos, k_pos):
    if cfg.pos_embedding == "rope":
        cq, sq = rope_freqs(cfg.head_dim, cfg.rope_theta, q_pos)
        ck, sk = rope_freqs(cfg.head_dim, cfg.rope_theta, k_pos)
        q = apply_rope(q, cq, sq)
        k = apply_rope(k, ck, sk)
    return q, k


def _chunked_attention(
    q, k, v, *, num_kv: int, q0, causal: bool, window: int, chunk: int,
    bf16_dot: bool = False,
):
    """Flash-style attention.  q (B,Sq,H,hd), k/v (B,Skv,KH,hd) -> (B,Sq,H,hd).

    Scans q in chunks of `chunk`; inner scan over kv chunks keeps running
    (max, denom, acc) — peak memory O(B*H*chunk^2) instead of O(B*H*Sq*Skv).
    ``q0`` is the absolute position of q[0] (decode offset / meta tokens).
    """
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    G = H // num_kv
    scale = hd ** -0.5

    qc = min(chunk, Sq)
    kc = min(chunk, Skv)
    # pad to multiples
    Sq_p = -(-Sq // qc) * qc
    Skv_p = -(-Skv // kc) * kc
    q = jnp.pad(q, ((0, 0), (0, Sq_p - Sq), (0, 0), (0, 0)))
    k = jnp.pad(k, ((0, 0), (0, Skv_p - Skv), (0, 0), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, Skv_p - Skv), (0, 0), (0, 0)))
    nq, nk = Sq_p // qc, Skv_p // kc

    dot_dt = jnp.bfloat16 if bf16_dot else jnp.float32
    qs = (q.reshape(B, nq, qc, num_kv, G, hd).astype(jnp.float32)
          * scale).astype(dot_dt)
    ks = k.reshape(B, nk, kc, num_kv, hd).astype(dot_dt)
    vs = v.reshape(B, nk, kc, num_kv, hd).astype(dot_dt)
    # scan over kv chunks as leading axis
    ks = jnp.moveaxis(ks, 1, 0)  # (nk, B, kc, KH, hd)
    vs = jnp.moveaxis(vs, 1, 0)
    qs = jnp.moveaxis(qs, 1, 0)  # (nq, B, qc, KH, G, hd)

    kv_valid = jnp.arange(Skv_p) < Skv

    def q_step(_, q_in):
        qi, qchunk = q_in  # scalar index, (B,qc,KH,G,hd)
        q_pos = q0 + qi * qc + jnp.arange(qc)

        def kv_step(carry, kv_in):
            m, l, acc = carry
            ki, kchunk, vchunk, valid = kv_in
            k_pos = ki * kc + jnp.arange(kc)
            s = jnp.einsum(
                "bqkgd,bskd->bkgqs", qchunk, kchunk,
                preferred_element_type=jnp.float32,
            )  # (B, KH, G, qc, kc)
            mask = valid[None, :]
            if causal:
                mask = mask & (k_pos[None, :] <= q_pos[:, None])
            if window:
                mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum(
                "bkgqs,bskd->bkgqd", p.astype(dot_dt), vchunk,
                preferred_element_type=jnp.float32,
            )
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, num_kv, G, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, num_kv, G, qc), jnp.float32)
        a0 = jnp.zeros((B, num_kv, G, qc, hd), jnp.float32)
        (m, l, acc), _ = lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.arange(nk), ks, vs, kv_valid.reshape(nk, kc)),
        )
        out = acc / jnp.maximum(l[..., None], 1e-30)  # (B,KH,G,qc,hd)
        return None, jnp.moveaxis(out, 3, 1).reshape(B, qc, num_kv * G, hd)

    _, outs = lax.scan(q_step, None, (jnp.arange(nq), qs))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, Sq_p, H, hd)[:, :Sq]
    return out


def attention(
    cfg,
    p: dict,
    x,
    *,
    xkv=None,                 # cross-attention context (None = self)
    cache: dict | None = None,
    q0=0,                     # absolute position of first query
    causal: bool = True,
    window: int = 0,
):
    """Full attention block: project → rope → (cache) → attend → out-proj.

    cache: {"k","v": (B, S_max, KH, hd), "pos": ()} — decode appends at
    ``pos`` and attends over the first pos+Sq entries.  Returns
    (out (B,Sq,d), new_cache | None).
    """
    B, Sq, _ = x.shape
    # cross-attention: fresh context (xkv) or precomputed KV (cache w/o pos)
    cross = xkv is not None or (cache is not None and "pos" not in cache)
    src = xkv if xkv is not None else x
    q, k, v = _project_qkv(cfg, p, x, src)
    q = constrain(q, "batch", None, "tensor", None)

    new_cache = None
    if cache is not None and cross:
        # cross-attention against precomputed encoder KV (no causal mask)
        out = _decode_attention(
            cfg, q, cache["k"], cache["v"],
            jnp.asarray(0, jnp.int32), Sq, causal=False, window=0, full_len=True,
        )
    elif cache is not None and Sq <= 8:
        # decode: rope at absolute cache position, append, single-pass attend
        pos = cache["pos"]
        S_buf = cache["k"].shape[1]
        ring = bool(window) and S_buf == window   # window-sized ring buffer
        k_pos = pos + jnp.arange(Sq)
        q, k = _rope_decode(cfg, q, k, k_pos)
        wpos = (pos % S_buf) if ring else pos
        if "k_scale" in cache:  # int8-quantized cache
            kq, ks = quantize_kv(k)
            vq, vs = quantize_kv(v)
            ck = lax.dynamic_update_slice_in_dim(cache["k"], kq, wpos, axis=1)
            cv = lax.dynamic_update_slice_in_dim(cache["v"], vq, wpos, axis=1)
            cks = lax.dynamic_update_slice_in_dim(cache["k_scale"], ks, wpos, axis=1)
            cvs = lax.dynamic_update_slice_in_dim(cache["v_scale"], vs, wpos, axis=1)
            new_cache = {"k": ck, "v": cv, "k_scale": cks, "v_scale": cvs,
                         "pos": pos + Sq}
            k_eff = ck.astype(jnp.bfloat16) * cks.astype(jnp.bfloat16)
            v_eff = cv.astype(jnp.bfloat16) * cvs.astype(jnp.bfloat16)
        else:
            ck = lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), wpos, axis=1)
            cv = lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), wpos, axis=1)
            new_cache = {"k": ck, "v": cv, "pos": pos + Sq}
            k_eff, v_eff = ck, cv
        if ring:
            # absolute position stored in each ring slot (-1 if not yet used)
            slots = jnp.arange(S_buf)
            kp = pos - ((pos - slots) % S_buf)
            slot_pos = jnp.where(kp <= pos, kp, -1)
        else:
            slot_pos = jnp.arange(S_buf)
        out = _decode_attention(cfg, q, k_eff, v_eff, pos, Sq,
                                causal=causal, window=window,
                                slot_pos=slot_pos)
    else:
        # train / prefill: chunked flash-style attention
        positions = q0 + jnp.arange(Sq)
        kv_positions = jnp.arange(src.shape[1]) + (0 if cross else q0)
        if cfg.pos_embedding == "rope" and not cross:
            q, k = _positions_embed(cfg, q, k, positions[None], kv_positions[None])
        out = _chunked_attention(
            q, k, v, num_kv=cfg.num_kv_heads, q0=q0,
            causal=causal and not cross, window=window, chunk=cfg.attn_chunk,
            bf16_dot=getattr(cfg, "attn_bf16_dot", False),
        )
        if cache is not None:
            # prefill: persist KV into the cache buffer.  Window-sized ring
            # buffers keep only the last S_buf tokens, placed at slot
            # (absolute_position % S_buf) so decode can continue the ring.
            S_buf = cache["k"].shape[1]
            pos0 = cache["pos"]

            def _store(buf, x_new, quantized=False):
                if Sq <= S_buf:
                    return lax.dynamic_update_slice_in_dim(
                        buf, x_new.astype(buf.dtype),
                        pos0 % S_buf if S_buf > 1 else pos0, axis=1)
                tail = x_new[:, -S_buf:]
                tail_pos = pos0 + Sq - S_buf + jnp.arange(S_buf)
                slots = tail_pos % S_buf
                return buf.at[:, slots].set(tail.astype(buf.dtype))

            if "k_scale" in cache:
                kq, ks = quantize_kv(k)
                vq, vs = quantize_kv(v)
                new_cache = {
                    "k": _store(cache["k"], kq),
                    "v": _store(cache["v"], vq),
                    "k_scale": _store(cache["k_scale"], ks),
                    "v_scale": _store(cache["v_scale"], vs),
                    "pos": pos0 + Sq,
                }
            else:
                new_cache = {
                    "k": _store(cache["k"], k),
                    "v": _store(cache["v"], v),
                    "pos": pos0 + Sq,
                }

    out = out.astype(x.dtype).reshape(B, Sq, cfg.num_heads * cfg.head_dim)
    out = constrain(out, "batch", None, "tensor")
    return out @ p["wo"], new_cache


def _rope_decode(cfg, q, k, k_pos):
    """Apply rope at absolute cache positions (decode: q at pos..pos+Sq)."""
    if cfg.pos_embedding != "rope":
        return q, k
    c, s = rope_freqs(cfg.head_dim, cfg.rope_theta, k_pos[None, :])
    return apply_rope(q, c, s), apply_rope(k, c, s)


def _decode_attention(cfg, q, k, v, pos, Sq, *, causal, window,
                      full_len=False, slot_pos=None):
    """Single-pass attention of Sq queries against a (possibly partially
    filled) cache of length S_max.  Memory (B,H,Sq,S_max) f32 scores — fine
    for Sq<=8; the cache seq dim may be sharded (context parallelism), in
    which case GSPMD turns the softmax reductions into collectives.

    ``slot_pos`` (S_max,) gives the absolute token position held by each
    cache slot (ring buffers permute it; -1 marks unused slots)."""
    B, _, H, hd = q.shape
    KH = cfg.num_kv_heads
    G = H // KH
    S_max = k.shape[1]
    k = constrain(k, "batch", "seq", "kv_heads", "kv_hd")
    v = constrain(v, "batch", "seq", "kv_heads", "kv_hd")
    if getattr(cfg, "attn_bf16_dot", False):
        # bf16 operands, f32 accumulation: native MXU mode; avoids
        # materializing an f32 copy of the whole KV cache (§Perf)
        q5 = (q.reshape(B, Sq, KH, G, hd) * hd**-0.5).astype(jnp.bfloat16)
        s = jnp.einsum("bqkgd,bskd->bkgqs", q5, k.astype(jnp.bfloat16),
                       preferred_element_type=jnp.float32)
    else:
        q5 = q.reshape(B, Sq, KH, G, hd).astype(jnp.float32) * hd**-0.5
        s = jnp.einsum("bqkgd,bskd->bkgqs", q5, k.astype(jnp.float32),
                       preferred_element_type=jnp.float32)
    k_idx = jnp.arange(S_max) if slot_pos is None else slot_pos
    q_pos = pos + jnp.arange(Sq)
    if full_len:
        valid = jnp.ones((Sq, S_max), bool)
    else:
        valid = k_idx[None, :] >= 0
        if causal:
            valid = valid & (k_idx[None, :] <= q_pos[:, None])
        if window:
            valid = valid & (k_idx[None, :] > q_pos[:, None] - window)
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    if getattr(cfg, "attn_bf16_dot", False):
        out = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(jnp.bfloat16),
                         v.astype(jnp.bfloat16),
                         preferred_element_type=jnp.float32)
    else:
        out = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32),
                         preferred_element_type=jnp.float32)
    return out.reshape(B, Sq, H, hd)


def quantize_kv(x):
    """Per-(batch, position, head) absmax int8 quantization of (B,S,KH,hd)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def init_attn_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16) -> dict:
    shape = (batch, max_len, cfg.num_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "pos": jnp.zeros((), jnp.int32),
    }
