"""Mamba-2 SSD (state-space duality) blocks [arXiv:2405.21060].

Chunked SSD: the selective state-space recurrence
    h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t x_t ;  y_t = C_t h_t + D x_t
is evaluated in O(S * Q) time by splitting the sequence into chunks of Q:
  * intra-chunk: a masked (Q x Q) "attention" term  C_i L_ij B_j^T x_j,
  * inter-chunk: per-chunk input states, combined by a sequential scan
    over chunks carrying the (H, P, N) state, then broadcast back.
This is the "matrix-transformer dual" form — MXU-friendly einsums instead
of an elementwise scan over time.

Decode is the recurrent form: constant-size state per layer
(conv window + (H, P, N) SSM state), so a 524k-token context costs the
same per step as an 8-token one — this is why mamba2/hymba run the
``long_500k`` cell while full-attention archs skip it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .common import PSpec, constrain

A_MIN, A_MAX = 1.0, 16.0
DT_MIN, DT_MAX = 1e-3, 1e-1


def ssm_specs(cfg) -> dict:
    d = cfg.d_model
    di = cfg.d_inner
    H = cfg.ssm_heads
    G = cfg.ssm_ngroups
    N = cfg.ssm_state
    conv_dim = di + 2 * G * N
    return {
        # projections: [z (di), x (di), B (G*N), C (G*N), dt (H)]
        "in_proj": PSpec((d, 2 * di + 2 * G * N + H), ("fsdp", "tensor")),
        "conv_w": PSpec((cfg.conv_kernel, conv_dim), (None, "tensor")),
        "conv_b": PSpec((conv_dim,), ("tensor",), "zeros"),
        "A_log": PSpec((H,), ("tensor",), "zeros"),
        "D": PSpec((H,), ("tensor",), "zeros"),
        "dt_bias": PSpec((H,), ("tensor",), "zeros"),
        "norm_scale": PSpec((di,), (None,), "zeros"),
        "out_proj": PSpec((di, d), ("tensor", "fsdp")),
    }


def _split_proj(cfg, zxbcdt):
    di, G, N, H = cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di : 2 * di + 2 * G * N]
    dt = zxbcdt[..., 2 * di + 2 * G * N :]
    return z, xBC, dt


def _causal_conv(xBC, w, b):
    """Depthwise causal conv1d: xBC (B,S,D), w (K,D)."""
    K = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(xBC)
    for i in range(K):
        out = out + pad[:, i : i + xBC.shape[1]] * w[i]
    return jax.nn.silu(out + b)


def _gated_rmsnorm(y, z, scale, eps=1e-6):
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), -1, keepdims=True)
    return (y.astype(jnp.float32) * lax.rsqrt(var + eps)).astype(y.dtype) * (
        1.0 + scale.astype(y.dtype)
    )


def ssd_apply(cfg, p, x, *, state=None):
    """Train/prefill SSD.  x (B,S,d) -> (y (B,S,d), final_state | None).

    state (if given) must be a fresh decode-state dict; prefill fills it.
    """
    B, S, d = x.shape
    di, G, N, H = cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_heads
    P = cfg.ssm_head_dim
    Q = min(cfg.ssm_chunk, S)
    S_p = -(-S // Q) * Q

    zxbcdt = x @ p["in_proj"]
    z, xBC_raw, dt = _split_proj(cfg, zxbcdt)
    xBC = _causal_conv(xBC_raw, p["conv_w"], p["conv_b"])
    xs = xBC[..., :di]
    Bm = xBC[..., di : di + G * N].reshape(B, S, G, N)
    Cm = xBC[..., di + G * N :].reshape(B, S, G, N)

    A = -jnp.exp(p["A_log"].astype(jnp.float32))                    # (H,) < 0
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])     # (B,S,H)
    xh = xs.reshape(B, S, H, P)
    # broadcast groups -> heads
    hpg = H // G
    Bh = jnp.repeat(Bm, hpg, axis=2)                                # (B,S,H,N)
    Ch = jnp.repeat(Cm, hpg, axis=2)

    # pad to chunk multiple
    if S_p != S:
        padw = ((0, 0), (0, S_p - S))
        xh = jnp.pad(xh, padw + ((0, 0), (0, 0)))
        Bh = jnp.pad(Bh, padw + ((0, 0), (0, 0)))
        Ch = jnp.pad(Ch, padw + ((0, 0), (0, 0)))
        dt = jnp.pad(dt, padw + ((0, 0),))
    nC = S_p // Q
    xc = xh.reshape(B, nC, Q, H, P)
    Bc = Bh.reshape(B, nC, Q, H, N)
    Cc = Ch.reshape(B, nC, Q, H, N)
    dtc = dt.reshape(B, nC, Q, H)

    dA = dtc * A                                                    # (B,nC,Q,H)
    cum = jnp.cumsum(dA, axis=2)                                    # within-chunk
    # intra-chunk (diagonal block): y_ij = C_i . B_j * exp(cum_i - cum_j) * dt_j
    Lmask = jnp.tril(jnp.ones((Q, Q), bool))
    decay = jnp.exp(
        jnp.clip(cum[:, :, :, None, :] - cum[:, :, None, :, :], -60.0, 0.0)
    )                                                               # (B,nC,Qi,Qj,H)
    CB = jnp.einsum("bcihn,bcjhn->bcijh", Cc, Bc,
                    preferred_element_type=jnp.float32)
    W = CB * decay * dtc[:, :, None, :, :]
    W = jnp.where(Lmask[None, None, :, :, None], W, 0.0)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", W, xc.astype(jnp.float32),
                         preferred_element_type=jnp.float32)

    # chunk input states: sum_j exp(cum_Q - cum_j) dt_j B_j x_j^T  -> (B,nC,H,N,P)
    seg = jnp.exp(jnp.clip(cum[:, :, -1:, :] - cum, -60.0, 0.0))    # (B,nC,Q,H)
    Sin = jnp.einsum("bcjh,bcjhn,bcjhp->bchnp", seg * dtc, Bc,
                     xc.astype(jnp.float32), preferred_element_type=jnp.float32)

    # sequential scan over chunks: h_{c} = exp(sum dA_c) h_{c-1} + Sin_c
    chunk_decay = jnp.exp(jnp.clip(cum[:, :, -1, :], -60.0, 0.0))   # (B,nC,H)

    if state is not None and "ssm" in state:
        h0 = state["ssm"].astype(jnp.float32)                       # (B,H,N,P)
    else:
        h0 = jnp.zeros((B, H, N, P), jnp.float32)

    def chunk_step(h, ins):
        cd, s_in = ins                                              # (B,H), (B,H,N,P)
        h_new = h * cd[..., None, None] + s_in
        return h_new, h

    (h_final, h_prevs) = lax.scan(
        chunk_step, h0,
        (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(Sin, 1, 0)),
    )
    h_prev = jnp.moveaxis(h_prevs, 0, 1)                            # state BEFORE chunk c

    # inter-chunk output: y_i += C_i exp(cum_i) h_prev
    inter_decay = jnp.exp(jnp.clip(cum, -60.0, 0.0))                # (B,nC,Q,H)
    y_inter = jnp.einsum("bcihn,bchnp->bcihp", Cc * inter_decay[..., None],
                         h_prev, preferred_element_type=jnp.float32)

    y = (y_intra + y_inter).reshape(B, S_p, H, P)[:, :S]
    y = y + xh.reshape(B, S_p, H, P)[:, :S].astype(jnp.float32) * p["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(B, S, di).astype(x.dtype)
    y = _gated_rmsnorm(y, z, p["norm_scale"])
    y = constrain(y, "batch", None, "tensor")
    out = y @ p["out_proj"]

    new_state = None
    if state is not None:
        K = cfg.conv_kernel
        conv_tail = jnp.pad(
            xBC_raw, ((0, 0), (max(K - 1 - S, 0), 0), (0, 0))
        )[:, -(K - 1):]
        new_state = {
            "ssm": h_final.astype(jnp.float32),
            "conv": conv_tail.astype(x.dtype),
            "pos": state["pos"] + S,
        }
    return out, new_state


def ssd_decode_step(cfg, p, x, state):
    """Single-token recurrent step.  x (B,1,d); state {ssm (B,H,N,P),
    conv (B,K-1,conv_dim), pos ()} -> (y (B,1,d), new state)."""
    B = x.shape[0]
    di, G, N, H = cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_heads
    P = cfg.ssm_head_dim
    K = cfg.conv_kernel

    zxbcdt = x @ p["in_proj"]                                       # (B,1,·)
    z, xBC, dt = _split_proj(cfg, zxbcdt)
    # conv over (window, new token)
    window = jnp.concatenate([state["conv"], xBC], axis=1)          # (B,K,D)
    conv_out = (window * p["conv_w"][None]).sum(axis=1, keepdims=True)
    xBC = jax.nn.silu(conv_out + p["conv_b"])
    xs = xBC[..., :di]
    Bm = xBC[..., di : di + G * N].reshape(B, G, N)
    Cm = xBC[..., di + G * N :].reshape(B, G, N)
    hpg = H // G
    Bh = jnp.repeat(Bm, hpg, axis=1)                                # (B,H,N)
    Ch = jnp.repeat(Cm, hpg, axis=1)

    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    decay = jnp.exp(dtv * A)                                        # (B,H)
    xhead = xs[:, 0].reshape(B, H, P).astype(jnp.float32)
    h = state["ssm"] * decay[..., None, None] + jnp.einsum(
        "bh,bhn,bhp->bhnp", dtv, Bh.astype(jnp.float32), xhead
    )
    y = jnp.einsum("bhn,bhnp->bhp", Ch.astype(jnp.float32), h)
    y = y + xhead * p["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(B, 1, di).astype(x.dtype)
    y = _gated_rmsnorm(y, z, p["norm_scale"])
    out = y @ p["out_proj"]
    new_state = {
        "ssm": h,
        "conv": window[:, 1:],
        "pos": state["pos"] + 1,
    }
    return out, new_state


def init_ssm_state(cfg, batch: int, dtype=jnp.bfloat16) -> dict:
    di, G, N, H = cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_heads
    P = cfg.ssm_head_dim
    conv_dim = di + 2 * G * N
    return {
        "ssm": jnp.zeros((batch, H, N, P), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, conv_dim), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }
