"""Model zoo: unified access to all assigned architectures."""
from .base import SHAPES, ModelConfig, ShapeCfg, shape_applicable, token_specs
from .encdec import EncDec
from .lm import LM


def get_model(cfg: ModelConfig):
    if cfg.family == "encdec":
        return EncDec(cfg)
    return LM(cfg)


__all__ = [
    "SHAPES", "ModelConfig", "ShapeCfg", "shape_applicable", "token_specs",
    "EncDec", "LM", "get_model",
]
