"""Model configuration + assigned input-shape registry.

Every assigned architecture instantiates ``ModelConfig`` (exact numbers in
repro/configs/<id>.py).  ``SHAPES`` is the assignment's per-arch shape set;
``input_specs`` produces ShapeDtypeStruct stand-ins for the dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from .common import pad_vocab


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch: str
    family: str                   # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # attention options
    qkv_bias: bool = False
    pos_embedding: str = "rope"               # rope | sinusoidal
    rope_theta: float = 10_000.0
    attn_window: int = 0                      # 0 = full causal
    global_attn_layers: tuple[int, ...] = ()  # hybrid: full-attn layer ids
    attn_chunk: int = 512                     # online-softmax q-chunk

    # mlp
    activation: str = "swiglu"                # swiglu | gelu | geglu
    norm: str = "rmsnorm"
    tie_embeddings: bool = False

    # moe
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_dff: int = 0
    capacity_factor: float = 1.25

    # ssm (mamba2 / hymba)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_ngroups: int = 1
    ssm_chunk: int = 256
    conv_kernel: int = 4

    # hybrid (hymba)
    num_meta_tokens: int = 0

    # enc-dec (whisper)
    enc_layers: int = 0
    enc_seq: int = 0                          # encoder frames (stub frontend)

    # vlm
    num_prefix_tokens: int = 0                # visual patch embeddings

    # numerics / execution
    dtype: str = "bfloat16"
    remat: str = "full"                       # none | full | dots
    scan_layers: bool = True                  # False: unroll (exact HLO costs)
    vocab_round: int = 256

    # perf levers (§Perf hillclimb; default False == paper-faithful baseline)
    attn_bf16_dot: bool = False               # bf16 MXU dots w/ f32 accum
    moe_dense_eval: bool = False              # dispatch-free MoE (fine-grained)
    loss_chunk: int = 0                       # chunked CE (tokens per chunk)

    # the paper's technique as an LM feature: CPD-factorized embedding
    # table of this rank (0 = dense table); see models/factorized_embed.py
    cpd_embed_rank: int = 0

    @property
    def padded_vocab(self) -> int:
        return pad_vocab(self.vocab_size, self.vocab_round)

    @property
    def param_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    @property
    def d_inner(self) -> int:                 # ssm inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_subquadratic(self) -> bool:
        """Can this arch decode at 500k context without full quadratic attn?"""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs have an autoregressive decoder

    def param_count(self) -> int:
        """Approximate parameter count (embedding + blocks), for 6ND."""
        d, L = self.d_model, self.num_layers
        emb = self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        n = emb
        qf = self.num_heads * self.head_dim
        kf = self.num_kv_heads * self.head_dim
        attn = d * qf + 2 * d * kf + qf * d
        if self.family == "ssm":
            n += L * _mamba_params(self)
        elif self.family == "hybrid":
            mlp = 3 * d * self.d_ff
            n += L * (attn + _mamba_params(self) + mlp)
        else:
            if self.num_experts:
                mlp = self.num_experts * 3 * d * self.moe_dff + d * self.num_experts
            else:
                mult = 3 if self.activation in ("swiglu", "geglu") else 2
                mlp = mult * d * self.d_ff
            n += L * (attn + mlp)
            if self.enc_layers:
                n += self.enc_layers * (attn + 2 * d * self.d_ff)
                n += self.num_layers * (d * qf + 2 * d * kf + qf * d)  # cross attn
        return int(n)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k experts only)."""
        if not self.num_experts:
            return self.param_count()
        d, L = self.d_model, self.num_layers
        full = self.param_count()
        moe_all = L * self.num_experts * 3 * d * self.moe_dff
        moe_active = L * self.num_experts_per_tok * 3 * d * self.moe_dff
        return int(full - moe_all + moe_active)


def _mamba_params(cfg: "ModelConfig") -> int:
    d, di = cfg.d_model, cfg.d_inner
    G, N, H = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_heads
    conv_dim = di + 2 * G * N
    return (
        d * (2 * di + 2 * G * N + H)      # in_proj
        + cfg.conv_kernel * conv_dim      # depthwise conv
        + di * d                          # out_proj
        + 3 * H + di                      # A_log, D, dt_bias, norm
    )


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    kind: str        # train | prefill | decode


SHAPES: dict[str, ShapeCfg] = {
    "train_4k": ShapeCfg("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCfg("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeCfg) -> tuple[bool, str]:
    """Per-assignment skip rules. Returns (runnable, reason-if-not)."""
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return False, (
            "long_500k requires sub-quadratic attention; "
            f"{cfg.arch} is pure full-attention (skip per assignment)"
        )
    return True, ""


def token_specs(cfg: ModelConfig, shape: ShapeCfg) -> dict[str, Any]:
    """ShapeDtypeStruct inputs for the step function of this (arch, shape).

    train:   tokens/labels (B, S); modality stubs add prefix embeddings.
    prefill: tokens (B, S) (+ stubs); produces logits + cache.
    decode:  tokens (B, 1) + cache of length S (built via eval_shape of
             init_cache by the concrete model, not here).
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    specs: dict[str, Any] = {}
    if shape.kind == "train":
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
        specs["labels"] = jax.ShapeDtypeStruct((B, S), i32)
    elif shape.kind == "prefill":
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
    else:  # decode
        specs["tokens"] = jax.ShapeDtypeStruct((B, 1), i32)
    if cfg.num_prefix_tokens and shape.kind != "decode":
        specs["prefix_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.num_prefix_tokens, cfg.d_model), jnp.bfloat16
        )
    if cfg.enc_layers and shape.kind != "decode":
        specs["encoder_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.enc_seq, cfg.d_model), jnp.bfloat16
        )
    return specs
