"""Deterministic, checkpointable data pipeline.

Large-scale requirement: after a preemption, the restarted trainer must
see exactly the batch sequence it would have seen — so the pipeline state
is just (seed, step) and batch generation is a pure function of them.
Host sharding: each data-parallel host generates only its slice
(process_index/process_count), so no host materializes the global batch.

The synthetic stream is a fixed-vocabulary Markov-ish token generator —
structure enough for a ~100M-param example model to show a real loss
curve (examples/train_lm.py) without shipping a corpus in the container.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class PipelineState:
    seed: int
    step: int

    def to_dict(self):
        return {"seed": self.seed, "step": self.step}

    @classmethod
    def from_dict(cls, d):
        return cls(int(d["seed"]), int(d["step"]))


class TokenPipeline:
    """Infinite deterministic token stream of (tokens, labels) batches."""

    def __init__(self, vocab_size: int, batch: int, seq_len: int, *,
                 seed: int = 0, process_index: int = 0,
                 process_count: int = 1):
        if batch % process_count:
            raise ValueError("global batch must divide process count")
        self.vocab = int(vocab_size)
        self.batch = int(batch)
        self.local_batch = batch // process_count
        self.seq = int(seq_len)
        self.state = PipelineState(seed, 0)
        self.process_index = process_index
        self.process_count = process_count

    def _gen(self, step: int) -> dict:
        rng = np.random.default_rng(
            (self.state.seed * 1_000_003 + step) % (2**63)
        )
        # skip other hosts' slices deterministically
        all_tok = self._markov(rng, self.batch, self.seq + 1)
        lo = self.process_index * self.local_batch
        tok = all_tok[lo : lo + self.local_batch]
        return {
            "tokens": tok[:, :-1].astype(np.int32),
            "labels": tok[:, 1:].astype(np.int32),
        }

    def _markov(self, rng, b, s):
        """Blockwise-correlated stream: token_{t+1} = f(token_t) + noise.
        Gives a learnable bigram structure (loss drops below unigram)."""
        base = rng.integers(0, self.vocab, size=(b, 1), dtype=np.int64)
        steps = rng.integers(1, 17, size=(b, s), dtype=np.int64)
        noise = rng.random((b, s)) < 0.1
        rand = rng.integers(0, self.vocab, size=(b, s), dtype=np.int64)
        out = np.zeros((b, s), dtype=np.int64)
        cur = base[:, 0]
        for t in range(s):
            cur = (cur * 31 + steps[:, t]) % self.vocab
            cur = np.where(noise[:, t], rand[:, t], cur)
            out[:, t] = cur
        return out

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        batch = self._gen(self.state.step)
        self.state.step += 1
        return batch

    # -- checkpoint integration --------------------------------------------

    def snapshot(self) -> dict:
        return self.state.to_dict()

    def restore(self, d: dict):
        self.state = PipelineState.from_dict(d)
