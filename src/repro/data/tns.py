"""FROSTT ``.tns`` sparse-tensor file I/O.

Format: whitespace-separated lines of N 1-based indices + value; comment
lines start with '#'.  This is the real loader a deployment would use
against the FROSTT downloads; the offline container exercises it via
round-trip tests and synthetic tensors (core.coo.frostt_like).
"""
from __future__ import annotations

import gzip

import numpy as np

from ..core.coo import SparseTensor


def read_tns(path: str, *, dtype=np.float32) -> SparseTensor:
    opener = gzip.open if str(path).endswith(".gz") else open
    idx_rows: list[list[int]] = []
    vals: list[float] = []
    with opener(path, "rt") as f:
        for line in f:
            s = line.strip()
            if not s or s.startswith(("#", "%")):
                continue
            parts = s.split()
            idx_rows.append([int(p) for p in parts[:-1]])
            vals.append(float(parts[-1]))
    if not idx_rows:
        raise ValueError(f"{path}: empty tensor file")
    idx = np.asarray(idx_rows, dtype=np.int64) - 1   # 1-based -> 0-based
    if idx.min() < 0:
        raise ValueError(f"{path}: index underflow (file must be 1-based)")
    shape = tuple(int(m) + 1 for m in idx.max(axis=0))
    return SparseTensor(idx.astype(np.int32), np.asarray(vals, dtype=dtype),
                        shape)


def write_tns(path: str, t: SparseTensor):
    opener = gzip.open if str(path).endswith(".gz") else open
    with opener(path, "wt") as f:
        f.write(f"# {t.nmodes}-mode tensor, shape {t.shape}, nnz {t.nnz}\n")
        for i in range(t.nnz):
            idx = " ".join(str(int(c) + 1) for c in t.indices[i])
            f.write(f"{idx} {float(t.values[i]):.9g}\n")
