from .pipeline import PipelineState, TokenPipeline
from .tns import read_tns, write_tns

__all__ = ["PipelineState", "TokenPipeline", "read_tns", "write_tns"]
