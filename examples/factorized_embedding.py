"""The paper's technique inside the LM: CPD-factorized embedding tables.

Trains two small LMs — dense embedding vs rank-R CPD-factorized embedding
(cfg.cpd_embed_rank) — and shows the parameter savings with comparable
loss.  The factor gradients ARE spMTTKRPs of the token batch (see
repro/models/factorized_embed.py and its tests).

    PYTHONPATH=src python examples/factorized_embedding.py
"""
import dataclasses

import jax

from repro import optim
from repro.configs import get_config, reduce_config
from repro.data import TokenPipeline
from repro.launch.mesh import make_host_mesh
from repro.models import get_model
from repro.models import factorized_embed as fe
from repro.runtime import Trainer

base = dataclasses.replace(
    reduce_config(get_config("qwen1.5-4b")),
    vocab_size=8192, d_model=96, num_heads=6, num_kv_heads=2, head_dim=16,
    num_layers=2, d_ff=256,
)

for label, cfg in [
    ("dense-embed", base),
    ("cpd-embed-r32", dataclasses.replace(base, cpd_embed_rank=32)),
]:
    model = get_model(cfg)
    n = sum(int(x.size) for x in jax.tree.leaves(model.abstract_params()))
    pipe = TokenPipeline(cfg.vocab_size, batch=8, seq_len=64, seed=1)
    tr = Trainer(model, mesh=make_host_mesh(), pipeline=pipe,
                 opt_cfg=optim.AdamWConfig(lr=2e-3, warmup_steps=5,
                                           total_steps=60))
    h = tr.run(60, log_every=1000)
    extra = ""
    if cfg.cpd_embed_rank:
        extra = (f" (table compression "
                 f"{fe.compression_ratio(cfg.padded_vocab, cfg.d_model, cfg.cpd_embed_rank):.0f}x)")
    print(f"{label:14s}: params={n:>9,d} loss {h[0]['loss']:.3f} -> "
          f"{h[-1]['loss']:.3f}{extra}")
