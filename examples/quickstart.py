"""Quickstart: decompose a small sparse tensor with CPD-ALS.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import cpd_als, low_rank_sparse, make_plan, mttkrp, random_sparse

# 1. a synthetic 3-mode sparse tensor (power-law index skew, like FROSTT)
t = random_sparse((500, 120, 40), 20_000, seed=0, distribution="powerlaw")
print(f"tensor {t.shape}, nnz={t.nnz}, density={t.density:.2e}")

# 2. the paper's preprocessing: one mode-specific layout per mode,
#    adaptive load balancing across kappa partitions
plan = make_plan(t, kappa=82)
for d, lay in enumerate(plan.layouts):
    print(f"  mode {d}: scheme={lay.scheme.name} "
          f"(I_d={t.shape[d]}, partitions={lay.kappa})")

# 3. one MTTKRP along mode 0 (the bottleneck kernel)
R = 16
rng = np.random.default_rng(0)
factors = [np.random.default_rng(d).standard_normal((I, R)).astype(np.float32)
           for d, I in enumerate(t.shape)]
M = mttkrp(plan, factors, mode=0)
print(f"MTTKRP mode 0 -> {M.shape}")

# 4. full CPD-ALS — the default engine is the device-resident fused sweep:
#    MTTKRP, gram updates, solve, normalization, and the sparse fit run as
#    ONE jitted computation; the host syncs only at the convergence check.
res = cpd_als(t, rank=R, plan=plan, n_iters=10, check_every=2, verbose=True)
print(f"final fit {res.fits[-1]:.4f} in {res.iters} iters "
      f"[{res.engine} engine, {res.host_syncs} host syncs] "
      f"in {res.total_seconds:.2f}s")

# 5. the original per-mode host loop survives for comparison
res_h = cpd_als(t, rank=R, plan=plan, n_iters=10, engine="host")
print(f"host engine: {res_h.host_syncs} host syncs, "
      f"MTTKRP time {res_h.mttkrp_seconds:.2f}s of {res_h.total_seconds:.2f}s")
