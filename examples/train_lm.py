"""End-to-end driver: train a ~100M-param LM for a few hundred steps with
checkpointing and automatic restart recovery.

    PYTHONPATH=src python examples/train_lm.py [--steps N] [--arch ID]

Re-running the same command resumes from the latest checkpoint.
"""
import argparse
import dataclasses

from repro import optim
from repro.configs import get_config
from repro.data import TokenPipeline
from repro.launch.mesh import make_host_mesh
from repro.models import get_model
from repro.runtime import Trainer

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--arch", default="internvl2-1b")
ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
args = ap.parse_args()

# ~100M-param sibling of the assigned arch (12 layers, d=512)
cfg = dataclasses.replace(
    get_config(args.arch),
    num_layers=12, d_model=512, num_heads=8, num_kv_heads=2, head_dim=64,
    d_ff=2048, vocab_size=32_000, num_prefix_tokens=0, dtype="float32",
    remat="none", attn_chunk=128,
)
model = get_model(cfg)
n = sum(int(x.size) for x in __import__("jax").tree.leaves(model.abstract_params()))
print(f"arch={cfg.arch}-sibling params={n/1e6:.1f}M")

pipe = TokenPipeline(cfg.vocab_size, batch=8, seq_len=256, seed=0)
trainer = Trainer(
    model, mesh=make_host_mesh(), pipeline=pipe,
    opt_cfg=optim.AdamWConfig(lr=3e-4, warmup_steps=20,
                              total_steps=args.steps),
    ckpt_dir=args.ckpt, ckpt_every=50,
)
history = trainer.run(args.steps, log_every=10)
print(f"loss {history[0]['loss']:.3f} -> {history[-1]['loss']:.3f} "
      f"over {len(history)} steps (straggler events: "
      f"{len(trainer.monitor.events)})")
