"""CPD of a FROSTT-like tensor, comparing execution engines + schemes.

    PYTHONPATH=src python examples/decompose_tensor.py [dataset] [--pallas] [--host]

``--host`` uses the original per-mode host loop; the default is the fused
device-resident engine (one jitted sweep per iteration).
"""
import sys
import time

from repro.core import Scheme, cpd_als, frostt_like, make_plan

name = sys.argv[1] if len(sys.argv) > 1 and not sys.argv[1].startswith("-") \
    else "chicago"
use_pallas = "--pallas" in sys.argv
engine = "host" if "--host" in sys.argv else "fused"
t = frostt_like(name, scale=0.01, seed=0)
print(f"{name}: shape={t.shape} nnz={t.nnz} engine={engine}")

for label, scheme in [("adaptive", None),
                      ("scheme-1 only", Scheme.INDEX_PARTITION),
                      ("scheme-2 only", Scheme.NNZ_PARTITION)]:
    plan = make_plan(t, kappa=82, scheme=scheme)
    backend = "pallas" if use_pallas else "segment"
    t0 = time.perf_counter()
    res = cpd_als(t, rank=32, plan=plan, n_iters=3, backend=backend,
                  engine=engine, check_every=3, tol=-1.0)
    wall = time.perf_counter() - t0
    print(f"  {label:14s} [{backend}/{res.engine}]: fit={res.fits[-1]:.4f} "
          f"wall={wall:.3f}s syncs={res.host_syncs}")
