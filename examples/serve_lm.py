"""Serving example: batched prefill + greedy decode with a KV cache
(optionally int8-quantized).

    PYTHONPATH=src python examples/serve_lm.py [--quant-kv]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduce_config
from repro.launch import steps as steps_mod
from repro.models import get_model

ap = argparse.ArgumentParser()
ap.add_argument("--quant-kv", action="store_true")
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--prompt-len", type=int, default=64)
ap.add_argument("--gen", type=int, default=32)
args = ap.parse_args()

cfg = reduce_config(get_config("qwen1.5-4b"),
                    num_layers=4, d_model=128, num_heads=8, num_kv_heads=4,
                    head_dim=16, d_ff=512, vocab_size=4096)
model = get_model(cfg)
params = model.init(jax.random.PRNGKey(0))

B, P, G = args.batch, args.prompt_len, args.gen
prompts = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0, cfg.vocab_size)
cache = model.init_cache(B, P + G, dtype=jnp.float32, quant_kv=args.quant_kv)

decode = jax.jit(steps_mod.make_decode_step(model), donate_argnums=(1,))

t0 = time.perf_counter()
logits, cache = model.prefill(params, prompts, cache)
tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
prefill_s = time.perf_counter() - t0

out = [tok]
t0 = time.perf_counter()
for _ in range(G - 1):
    tok, cache = decode(params, cache, {"tokens": tok})
    tok = tok[:, None]
    out.append(tok)
jax.block_until_ready(tok)
decode_s = time.perf_counter() - t0

gen = jnp.concatenate(out, axis=1)
kv = "int8" if args.quant_kv else "bf16/f32"
print(f"served batch={B} prompt={P} gen={G} (kv cache: {kv})")
print(f"prefill {prefill_s*1e3:.1f} ms; decode {decode_s/max(G-1,1)*1e3:.2f} "
      f"ms/token; sample tokens: {gen[0, :10].tolist()}")
